"""L2 JAX model: the PiC-BNN binary MLP, in three equivalent forms.

1. `forward_float` — training-time forward (latent float weights, STE
   binarization, batch norm); used only by train.py.
2. `forward_digital` — the *software baseline*: exact digital BNN with
   float-folded BN constants (the "95.2 % / 99 %" reference in Fig. 5).
3. `forward_cam` — the CAM-mapped model: integer pad-encoded BN constants,
   per-segment rows (DESIGN.md §4), midpoint-threshold hidden layer and the
   Algorithm-1 HD-threshold-sweep output layer with per-class majority
   voting.  This is the graph AOT-lowered to artifacts/*.hlo.txt and the
   bit-exact twin of the rust CAM path at nominal PVT.

All binary codes are +/-1 float32.  sign(0) := +1 everywhere (the MLSA
fires on ties: mismatches <= tolerance).
"""

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import physics
from .kernels import matchline as k_ml
from .kernels import xnor_popcount as k_xp


# ----------------------------------------------------------------------
# Device geometry: logical CAM configurations of the 128-kbit array.
# ----------------------------------------------------------------------

CONFIGS = {  # name -> (rows, cols)
    "512x256": (512, 256),
    "1024x128": (1024, 128),
    "2048x64": (2048, 64),
}
# NOTE: (rows, cols) here follows the paper's "RxC" naming where the first
# number is the word width in bits (columns of one row) — e.g. "1024x128"
# stores 128 words of 1024 bits.  We keep (width, words) order throughout.


def pick_config(width_bits: int) -> Tuple[str, int, int]:
    """Smallest logical config whose word width fits `width_bits`."""
    for name in ("512x256", "1024x128", "2048x64"):
        w, words = CONFIGS[name]
        if width_bits <= w:
            return name, w, words
    raise ValueError(f"row of {width_bits} bits exceeds the widest config")


# ----------------------------------------------------------------------
# CAM mapping of one binary linear layer (+ folded BN constant).
# ----------------------------------------------------------------------


@dataclasses.dataclass
class LayerMap:
    """Integer-exact mapping of a binary layer onto CAM rows.

    A neuron j with weights w_j (+/-1, length n_in) and folded constant C_j
    becomes `n_seg` CAM rows of `seg_width` cells each: `payload` weight
    cells plus pads, `q[s, j]` of which are mismatching.  Segment s fires
    iff HD_seg <= seg_width/2  <=>  dot_seg + (pads_s - 2 q_sj) >= 0.
    The neuron output is the majority of segment fires (ties fire).
    """

    weights: np.ndarray        # (n_out, n_in) +/-1 float32
    q: np.ndarray              # (n_seg, n_out) int32 mismatching pads
    seg_bounds: np.ndarray     # (n_seg + 1,) int32 payload slice bounds
    seg_width: int             # cells per row (CAM word width)
    config: str                # logical CAM configuration name

    @property
    def n_seg(self) -> int:
        return len(self.seg_bounds) - 1

    @property
    def n_out(self) -> int:
        return self.weights.shape[0]

    @property
    def n_in(self) -> int:
        return self.weights.shape[1]

    def seg_payload(self, s: int) -> int:
        return int(self.seg_bounds[s + 1] - self.seg_bounds[s])

    def seg_pads(self, s: int) -> int:
        return self.seg_width - self.seg_payload(s)


def map_layer(weights: np.ndarray, c: np.ndarray, *, q_offset: np.ndarray | None = None) -> LayerMap:
    """Map (weights, folded constant C) onto CAM rows.

    If the layer fits one config word, a single segment carries all inputs
    and C is pad-encoded to the nearest even integer.  Wider layers are
    split into equal segments (each <= widest word incl. a pad budget) with
    C distributed across segments proportionally to payload.

    q_offset (n_out,) optionally shifts the mismatching-pad counts uniformly
    per neuron — the output layer's sweep-window centring (DESIGN.md §4).
    """
    n_out, n_in = weights.shape
    widest = CONFIGS["2048x64"][0]
    min_pads = max(8, n_out // 16)  # always keep some pad budget
    if n_in + min_pads <= widest:
        config, seg_width, _ = pick_config(n_in + min_pads)
        bounds = np.array([0, n_in], dtype=np.int32)
        n_seg = 1
    else:
        n_seg = int(np.ceil((n_in + min_pads) / widest))
        config, seg_width = "2048x64", widest
        cuts = np.linspace(0, n_in, n_seg + 1)
        bounds = np.rint(cuts).astype(np.int32)

    q = np.zeros((n_seg, n_out), dtype=np.int32)
    for s in range(n_seg):
        payload = int(bounds[s + 1] - bounds[s])
        pads = seg_width - payload
        frac = payload / n_in
        c_seg = c * frac
        # pads contribute dot_pad = pads - 2q; want dot_pad ~= c_seg
        q_s = np.rint((pads - c_seg) / 2.0).astype(np.int64)
        if q_offset is not None:
            q_s = q_s + q_offset.astype(np.int64)
        q[s] = np.clip(q_s, 0, pads).astype(np.int32)
    return LayerMap(weights=weights.astype(np.float32), q=q,
                    seg_bounds=bounds, seg_width=seg_width, config=config)


def layer_c_effective(lm: LayerMap) -> np.ndarray:
    """The integer constant each segment actually realises: pads - 2q."""
    pads = np.array([lm.seg_pads(s) for s in range(lm.n_seg)], dtype=np.int64)
    return (pads[:, None] - 2 * lm.q.astype(np.int64)).astype(np.float32)


# ----------------------------------------------------------------------
# Forward passes.
# ----------------------------------------------------------------------


def forward_digital(x, w1, c1, w2, c2):
    """Software-baseline BNN: exact digital fold, float constants.

    x: (B, n_in) +/-1.  Returns (logits (B, n_cls) float, hidden (B, h)).
    logits_j = dot(hidden, w2_j) + c2_j; prediction = argmax.
    """
    d1 = k_xp.xnor_popcount_dot(x, w1)
    h = jnp.where(d1 + c1[None, :] >= 0.0, 1.0, -1.0)
    d2 = k_xp.xnor_popcount_dot(h, w2)
    return d2 + c2[None, :], h


def _cam_layer_fires(x, lm: LayerMap):
    """Per-segment HD and midpoint fires for one mapped layer.

    Returns (hd_total (B, n_seg, n_out), fires (B, n_out) +/-1).
    """
    b = x.shape[0]
    hds = []
    for s in range(lm.n_seg):
        lo, hi = int(lm.seg_bounds[s]), int(lm.seg_bounds[s + 1])
        w_seg = jnp.asarray(lm.weights[:, lo:hi])
        hd_w = k_xp.hamming_distance(x[:, lo:hi], w_seg)  # (B, n_out)
        hd = hd_w + jnp.asarray(lm.q[s].astype(np.float32))[None, :]
        hds.append(hd)
    hd_total = jnp.stack(hds, axis=1)  # (B, n_seg, n_out)
    half = lm.seg_width / 2.0
    seg_fire = (hd_total <= half)
    # majority of segments, ties fire (matches MLSA tie->fire convention)
    n_fire = seg_fire.sum(axis=1)
    fires = jnp.where(n_fire * 2 >= lm.n_seg, 1.0, -1.0)
    return hd_total, fires.astype(jnp.float32)


def forward_cam(x, lm1: LayerMap, lm2: LayerMap, schedule):
    """CAM-mapped Algorithm 1: returns (votes (B, n_cls) i32, pred (B,) i32).

    Hidden layer: one midpoint-threshold execution (Algorithm 1 line 2).
    Output layer: HD-threshold sweep over `schedule` (K executions), one
    vote per (class, threshold) with HD_total <= threshold, per-class vote
    count, argmax with lowest-index tie-break.
    """
    _, h = _cam_layer_fires(x, lm1)
    hd2, _ = _cam_layer_fires(h, lm2)
    assert lm2.n_seg == 1, "output layer must fit a single CAM word"
    hd2 = hd2[:, 0, :]  # (B, n_cls)
    votes = k_ml.threshold_sweep_votes(hd2, jnp.asarray(schedule, jnp.float32))
    pred = jnp.argmax(votes, axis=-1).astype(jnp.int32)
    return votes.astype(jnp.int32), pred


def forward_cam_param(x, w1, q1, w2, q2, seg_bounds1, seg_width1,
                      seg_width2, schedule):
    """forward_cam with mapped params as *runtime arrays* (for AOT lowering).

    Same math as forward_cam but every tensor is a traced argument so the
    lowered HLO takes weights/pads as parameters — one artifact per
    topology, reusable across retrained weights.
    seg_bounds1 is static (python tuple), as are widths.
    """
    # hidden layer
    hds = []
    n_seg = len(seg_bounds1) - 1
    for s in range(n_seg):
        lo, hi = seg_bounds1[s], seg_bounds1[s + 1]
        hd_w = k_xp.hamming_distance(x[:, lo:hi], w1[:, lo:hi])
        hds.append(hd_w + q1[s][None, :])
    hd1 = jnp.stack(hds, axis=1)
    seg_fire = hd1 <= (seg_width1 / 2.0)
    h = jnp.where(seg_fire.sum(axis=1) * 2 >= n_seg, 1.0, -1.0).astype(jnp.float32)
    # output layer
    hd_w2 = k_xp.hamming_distance(h, w2)
    hd2 = hd_w2 + q2[0][None, :]
    votes = k_ml.threshold_sweep_votes(hd2, schedule)
    pred = jnp.argmax(votes, axis=-1).astype(jnp.int32)
    return votes.astype(jnp.int32), pred


# ----------------------------------------------------------------------
# Vote semantics shared with rust (prefix schedules for Fig. 5).
# ----------------------------------------------------------------------

def prefix_schedule(k: int) -> np.ndarray:
    """First k thresholds of the Algorithm-1 schedule {0, 2, ..., 64}."""
    full = np.asarray(physics.HD_SCHEDULE, dtype=np.float32)
    return full[:k]


def accuracy_top_k(votes: np.ndarray, labels: np.ndarray, k: int = 1) -> float:
    """TOP-k accuracy with lowest-class-index tie-breaking (stable sort)."""
    # sort by (-votes, class_index): argsort of -votes is stable in numpy
    order = np.argsort(-votes, axis=-1, kind="stable")
    topk = order[:, :k]
    return float((topk == labels[:, None]).any(axis=1).mean())
