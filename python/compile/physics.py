"""Shared analog matchline physics constants and closed-form model.

This module is the single source of truth for the *functional* matchline
model used by the L1 Pallas kernel (`kernels/matchline.py`), the pure-jnp
oracle (`kernels/ref.py`), and — by mirrored constants — the rust analog
simulator (`rust/src/analog/constants.rs`).  The rust side carries the full
Monte-Carlo/PVT machinery; this side is the deterministic nominal model
used for AOT artifacts and cross-validation vectors.

Model (DESIGN.md §4):

    V_ML(t)   = V_DD * exp(-m * g(V_eval) * t / C_ML)
    g(V)      = K_G * max(V - V_TH, 0)              [S]   (triode-ish)
    t_s(V_st) = TAU0 * V_DD / max(V_st - V_TH, EPS) [s]   (starved delay)
    match    <=> V_ML(t_s) > V_ref

Solving for the mismatch-count threshold ("HD tolerance"):

    hd_tol(vref, veval, vst) = C_ML * ln(V_DD / vref) / (g(veval) * t_s(vst))

A row *fires* ('1') iff its mismatch count m <= hd_tol.
"""

import math

# 65 nm-flavoured *effective* constants.  The silicon Table I voltage
# combinations encode the real chip's nonlinear MLSA/discharge behaviour; our
# closed-form model cannot (and per DESIGN.md §1 need not) hit the same
# absolute voltages.  The constants are chosen so the three knobs cover the
# full required tolerance dynamic range — hd_tol from <1 bit up to >n/2 for
# every row length the device supports (256/1024/2048 cells) — over the
# legal voltage windows V_ref in [0.6, 1.2], V_eval in [0.3, 1.2],
# V_st in [0.6, 1.2].  Table I is then *regenerated* by calibration search
# (accel::VoltageController), reproducing its structure, not its millivolts.
# Mirror of rust/src/analog/constants.rs — keep in sync.
V_DD = 1.2          # V   supply
V_TH = 0.25         # V   effective NMOS threshold at 25C
K_G = 8.93e-7       # S/V transconductance-ish slope of the M_eval stack
C_ML = 12e-15       # F   matchline capacitance for a 256-cell row
TAU0 = 0.8e-9       # s   delay-element unit time constant
EPS = 1e-3

# Legal tuning windows for the three user-configurable voltages.
VREF_RANGE = (0.6, 1.2)
VEVAL_RANGE = (0.3, 1.2)
VST_RANGE = (0.6, 1.2)

# Per-row capacitance scales with the number of cells hanging on the ML.
C_ML_PER_CELL = C_ML / 256.0


def g_eval(veval: float) -> float:
    """Conductance of one mismatching pulldown path, gated by V_eval."""
    return K_G * max(veval - V_TH, 0.0)


def t_sample(vst: float) -> float:
    """MLSA sampling time set by the V_st-starved delay line."""
    return TAU0 * V_DD / max(vst - V_TH, EPS)


def hd_tolerance(vref: float, veval: float, vst: float, n_cells: int = 256) -> float:
    """Closed-form HD tolerance threshold for a row of `n_cells` cells.

    A search with mismatch count m yields a match (logic '1') iff
    m <= hd_tolerance(...).  Monotonicity (paper §III): decreasing vref,
    decreasing veval, or decreasing vst (later... earlier sampling; see
    DESIGN.md) each increase the tolerance.
    """
    if vref >= V_DD:
        return 0.0
    c_ml = C_ML_PER_CELL * n_cells
    denom = g_eval(veval) * t_sample(vst)
    if denom <= 0.0:
        return float(n_cells)
    return c_ml * math.log(V_DD / vref) / denom


def v_ml(m: int, t: float, veval: float, n_cells: int = 256) -> float:
    """Matchline voltage at time t with m mismatching cells."""
    c_ml = C_ML_PER_CELL * n_cells
    return V_DD * math.exp(-m * g_eval(veval) * t / c_ml)


# The Algorithm-1 sweep: HD threshold in {0, 2, 4, ..., 64} -> 33 executions.
HD_SCHEDULE = tuple(range(0, 65, 2))
N_EXECUTIONS = len(HD_SCHEDULE)  # 33
