"""AOT lowering: jax model -> HLO *text* artifacts for the rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (consumed by rust/src/runtime/):
    {name}_infer.hlo.txt      full Algorithm-1 inference graph for a fixed
                              batch: params = (x, w1, q1, w2, q2, schedule)
                              -> (votes i32, pred i32)
    matchline_fire.hlo.txt    the L1 matchline kernel standalone (cross-
                              validation vectors vs the rust analog model)
    xnor_dot.hlo.txt          the L1 binary-dot kernel standalone

Run once via `make artifacts` (after train.py has produced the weights);
python never runs on the request path.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as modelmod
from . import physics
from .kernels import matchline as k_ml
from .kernels import xnor_popcount as k_xp

BATCH = 64  # fixed AOT batch; the rust coordinator pads partial batches


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_infer(meta: dict) -> str:
    """Lower forward_cam_param for one model topology."""
    n_in = meta["n_in"]
    n_h = meta["n_hidden"]
    n_cls = meta["n_classes"]
    bounds = tuple(meta["seg_bounds_l1"])
    sw1 = meta["seg_width_l1"]
    sw2 = meta["seg_width_l2"]
    n_seg = len(bounds) - 1
    k = len(meta["schedule"])

    def fn(x, w1, q1, w2, q2, schedule):
        votes, pred = modelmod.forward_cam_param(
            x, w1, q1, w2, q2, bounds, sw1, sw2, schedule
        )
        return votes, pred

    spec = lambda shape, dt=jnp.float32: jax.ShapeDtypeStruct(shape, dt)
    lowered = jax.jit(fn).lower(
        spec((BATCH, n_in)),
        spec((n_h, n_in)),
        spec((n_seg, n_h)),
        spec((n_cls, n_h)),
        spec((1, n_cls)),
        spec((k,)),
    )
    return to_hlo_text(lowered)


def lower_matchline(batch=256, rows=64, n_cells=256) -> str:
    def fn(m, v):
        return (k_ml.matchline_fire(m, v, n_cells=n_cells),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((batch, rows), jnp.float32),
        jax.ShapeDtypeStruct((3,), jnp.float32),
    )
    return to_hlo_text(lowered)


def lower_xnor_dot(batch=64, m=128, n=1024) -> str:
    def fn(x, w):
        return (k_xp.xnor_popcount_dot(x, w),)

    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((batch, n), jnp.float32),
        jax.ShapeDtypeStruct((m, n), jnp.float32),
    )
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    for name in ("mnist", "hg"):
        meta_path = os.path.join(args.out, f"{name}_meta.json")
        if not os.path.exists(meta_path):
            print(f"[aot] skip {name}: no {meta_path} (run compile.train first)")
            continue
        with open(meta_path) as f:
            meta = json.load(f)
        text = lower_infer(meta)
        out = os.path.join(args.out, f"{name}_infer.hlo.txt")
        with open(out, "w") as f:
            f.write(text)
        print(f"[aot] wrote {out} ({len(text)} chars, batch={BATCH})")

    for fname, fn in (
        ("matchline_fire.hlo.txt", lower_matchline),
        ("xnor_dot.hlo.txt", lower_xnor_dot),
    ):
        out = os.path.join(args.out, fname)
        text = fn()
        with open(out, "w") as f:
            f.write(text)
        print(f"[aot] wrote {out} ({len(text)} chars)")


if __name__ == "__main__":
    main()
