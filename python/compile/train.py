"""Build-time training of the PiC-BNN binary MLPs (straight-through
estimator), BN folding, CAM mapping, and artifact export.

Runs once from `make artifacts`:

    python -m compile.train --out ../artifacts

Produces, per model (mnist, hg):
    {name}_weights.bin   packed mapped model (rust/src/bnn/model.rs loads it)
    {name}_test.bin      packed test split (rust/src/data/loader.rs)
    {name}_meta.json     dims, seeds, baseline accuracies, mapping info

The exported model is the *mapped* one (integer pad-encoded constants,
segment bounds) so rust and python execute bit-identical math.
"""

import argparse
import functools
import json
import os
import struct

import jax
import jax.numpy as jnp
import numpy as np

from . import data as datamod
from . import model as modelmod
from . import physics
from .kernels import ref


# ----------------------------------------------------------------------
# STE training forward.
# ----------------------------------------------------------------------


@jax.custom_vjp
def sign_ste(v):
    return jnp.where(v >= 0.0, 1.0, -1.0)


def _sign_fwd(v):
    return sign_ste(v), v


def _sign_bwd(v, g):
    return (g * (jnp.abs(v) <= 1.0).astype(g.dtype),)


sign_ste.defvjp(_sign_fwd, _sign_bwd)


def init_params(key, n_in, n_hidden, n_cls):
    k1, k2 = jax.random.split(key)
    s1 = 1.0 / np.sqrt(n_in)
    s2 = 1.0 / np.sqrt(n_hidden)
    return {
        "w1": jax.random.uniform(k1, (n_hidden, n_in), minval=-s1, maxval=s1),
        "gamma": jnp.ones((n_hidden,)),
        "beta": jnp.zeros((n_hidden,)),
        "w2": jax.random.uniform(k2, (n_cls, n_hidden), minval=-s2, maxval=s2),
        "b2": jnp.zeros((n_cls,)),
    }


def forward_train(params, x, bn_state, *, train: bool, momentum=0.9, eps=1e-5):
    """Training forward; returns (logits, new_bn_state, hidden)."""
    w1b = sign_ste(params["w1"])
    d1 = x @ w1b.T
    if train:
        mu = d1.mean(axis=0)
        var = d1.var(axis=0) + 1e-3
        new_state = {
            "mean": momentum * bn_state["mean"] + (1 - momentum) * mu,
            "var": momentum * bn_state["var"] + (1 - momentum) * var,
        }
    else:
        mu, var = bn_state["mean"], bn_state["var"]
        new_state = bn_state
    yhat = (d1 - mu) / jnp.sqrt(var + eps) * params["gamma"] + params["beta"]
    h = sign_ste(yhat)
    w2b = sign_ste(params["w2"])
    d2 = h @ w2b.T
    logits = d2 + params["b2"]
    return logits, new_state, h


def loss_fn(params, x, y, bn_state, n_hidden):
    logits, new_state, _ = forward_train(params, x, bn_state, train=True)
    scaled = logits / np.sqrt(n_hidden)
    logp = jax.nn.log_softmax(scaled, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
    return nll, new_state


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1 ** t), m)
    vhat = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2 ** t), v)
    new_params = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    # keep latent binary weights in [-1, 1] (standard BNN clipping)
    for k in ("w1", "w2"):
        new_params[k] = jnp.clip(new_params[k], -1.0, 1.0)
    return new_params, {"m": m, "v": v, "t": t}


@functools.partial(jax.jit, static_argnames=("n_hidden", "lr"))
def train_step(params, opt, bn_state, x, y, *, n_hidden, lr):
    (loss, new_bn), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        params, x, y, bn_state, n_hidden
    )
    params, opt = adam_update(params, grads, opt, lr)
    return params, opt, new_bn, loss


def train_model(x_tr, y_tr, n_hidden, n_cls, *, epochs, seed, batch=128, lr=2e-3):
    n, n_in = x_tr.shape
    key = jax.random.PRNGKey(seed)
    params = init_params(key, n_in, n_hidden, n_cls)
    opt = adam_init(params)
    bn_state = {"mean": jnp.zeros((n_hidden,)), "var": jnp.ones((n_hidden,))}
    rng = np.random.default_rng(seed)
    xj = jnp.asarray(x_tr)
    yj = jnp.asarray(y_tr)
    steps = n // batch
    for ep in range(epochs):
        perm = rng.permutation(n)
        ep_lr = lr * (0.5 ** (ep // 10))
        losses = []
        for s in range(steps):
            idx = perm[s * batch : (s + 1) * batch]
            params, opt, bn_state, loss = train_step(
                params, opt, bn_state, xj[idx], yj[idx], n_hidden=n_hidden, lr=ep_lr
            )
            losses.append(float(loss))
        if ep % 5 == 0 or ep == epochs - 1:
            print(f"  epoch {ep:3d}  loss {np.mean(losses):.4f}")
    return params, bn_state


# ----------------------------------------------------------------------
# Fold + map + evaluate.
# ----------------------------------------------------------------------


def fold_model(params, bn_state, eps=1e-5):
    """Fold BN into (flipped weights, float constants) — digital baseline."""
    w1 = np.asarray(jnp.where(params["w1"] >= 0.0, 1.0, -1.0))
    w2 = np.asarray(jnp.where(params["w2"] >= 0.0, 1.0, -1.0))
    flip, c1 = ref.fold_bn_constant(
        params["gamma"], params["beta"], bn_state["mean"], bn_state["var"], eps
    )
    flip = np.asarray(flip)
    c1 = np.asarray(c1)
    w1f = w1 * flip[:, None]
    c2 = np.asarray(params["b2"], dtype=np.float64)
    return w1f.astype(np.float32), c1.astype(np.float64), w2.astype(np.float32), c2


def sweep_window_offset(x_tr, y_tr, w1f, c1, w2, c2, lm1, target_med=24.0,
                        batch=512):
    """Scalar pad offset centring target-class HD in the sweep window.

    Computes the output-layer HD (weights part + base pad encoding) of the
    *target* class over the training set using the CAM hidden layer, and
    returns round(target_med - median) — the uniform shift applied to every
    class's mismatching-pad count (order-preserving).
    """
    lm2_base = modelmod.map_layer(w2, c2)
    meds = []
    for lo in range(0, len(x_tr), batch):
        xb = jnp.asarray(x_tr[lo : lo + batch])
        _, h = modelmod._cam_layer_fires(xb, lm1)
        hd2, _ = modelmod._cam_layer_fires(h, lm2_base)
        hd2 = np.asarray(hd2[:, 0, :])
        meds.append(hd2[np.arange(len(hd2)), y_tr[lo : lo + batch]])
    med = float(np.median(np.concatenate(meds)))
    return int(round(target_med - med)), med


def eval_digital(x, y, w1f, c1, w2, c2, batch=1024):
    preds, top2 = [], []
    for lo in range(0, len(x), batch):
        logits, _ = modelmod.forward_digital(jnp.asarray(x[lo : lo + batch]), w1f,
                                             jnp.asarray(c1, jnp.float32), w2,
                                             jnp.asarray(c2, jnp.float32))
        logits = np.asarray(logits)
        order = np.argsort(-logits, axis=-1, kind="stable")
        preds.append(order[:, 0])
        top2.append((order[:, :2] == y[lo : lo + batch, None]).any(axis=1))
    top1 = float((np.concatenate(preds) == y).mean())
    return top1, float(np.concatenate(top2).mean())


def eval_cam(x, y, lm1, lm2, schedule, batch=512):
    v_all = []
    for lo in range(0, len(x), batch):
        xb = x[lo : lo + batch]
        votes, _ = modelmod.forward_cam(jnp.asarray(xb), lm1, lm2, schedule)
        v_all.append(np.asarray(votes))
    votes = np.concatenate(v_all)
    return (
        modelmod.accuracy_top_k(votes, y, 1),
        modelmod.accuracy_top_k(votes, y, 2),
        votes,
    )


# ----------------------------------------------------------------------
# Export format (see rust/src/bnn/model.rs and rust/src/data/loader.rs).
# ----------------------------------------------------------------------


def pack_bits_pm1(arr_pm1: np.ndarray) -> np.ndarray:
    """Pack +/-1 rows into u64 words, bit i of word i//64 set iff +1."""
    n, m = arr_pm1.shape
    bits = (arr_pm1 > 0).astype(np.uint8)
    pad = (-m) % 64
    if pad:
        bits = np.concatenate([bits, np.zeros((n, pad), np.uint8)], axis=1)
    bits = bits.reshape(n, -1, 64)
    weights = (1 << np.arange(64, dtype=np.uint64))[None, None, :]
    return (bits.astype(np.uint64) * weights).sum(axis=2, dtype=np.uint64)


def write_weights_bin(path, layers, schedule):
    """layers: list of LayerMap."""
    with open(path, "wb") as f:
        f.write(b"PICBNN1\x00")
        f.write(struct.pack("<I", len(layers)))
        for lm in layers:
            f.write(struct.pack("<IIII", lm.n_out, lm.n_in, lm.n_seg, lm.seg_width))
            f.write(np.asarray(lm.seg_bounds, "<u4").tobytes())
            f.write(np.asarray(lm.q, "<i4").tobytes())
            packed = pack_bits_pm1(lm.weights)
            f.write(packed.astype("<u8").tobytes())
        sched = np.asarray(schedule, np.int32)
        f.write(struct.pack("<I", len(sched)))
        f.write(sched.astype("<i4").tobytes())


def write_test_bin(path, x_pm1, y):
    with open(path, "wb") as f:
        f.write(b"PICTEST1")
        n, m = x_pm1.shape
        n_cls = int(y.max()) + 1
        f.write(struct.pack("<III", n, m, n_cls))
        f.write(y.astype("<u1").tobytes())
        f.write(pack_bits_pm1(x_pm1).astype("<u8").tobytes())


# ----------------------------------------------------------------------
# Per-model pipeline.
# ----------------------------------------------------------------------


def build(name, x_tr, y_tr, x_te, y_te, n_hidden, n_cls, out_dir, *, epochs,
          seed):
    print(f"[{name}] training {x_tr.shape[1]} -> {n_hidden} -> {n_cls} "
          f"({len(x_tr)} train / {len(x_te)} test)")
    params, bn_state = train_model(x_tr, y_tr, n_hidden, n_cls,
                                   epochs=epochs, seed=seed)
    w1f, c1, w2, c2 = fold_model(params, bn_state)
    top1_sw, top2_sw = eval_digital(x_te, y_te, w1f, c1, w2, c2)
    print(f"[{name}] software baseline top1 {top1_sw:.4f} top2 {top2_sw:.4f}")

    lm1 = modelmod.map_layer(w1f, c1)
    q_off, med = sweep_window_offset(x_tr, y_tr, w1f, c1, w2, c2, lm1)
    lm2 = modelmod.map_layer(
        w2, c2, q_offset=np.full(n_cls, q_off, dtype=np.int64)
    )
    schedule = np.asarray(physics.HD_SCHEDULE, np.float32)
    top1_cam, top2_cam, _ = eval_cam(x_te, y_te, lm1, lm2, schedule)
    print(f"[{name}] CAM-mapped (nominal) top1 {top1_cam:.4f} top2 {top2_cam:.4f} "
          f"(target-HD median {med:.1f}, offset {q_off})")

    write_weights_bin(os.path.join(out_dir, f"{name}_weights.bin"),
                      [lm1, lm2], physics.HD_SCHEDULE)
    write_test_bin(os.path.join(out_dir, f"{name}_test.bin"), x_te, y_te)
    meta = {
        "name": name,
        "n_in": int(x_tr.shape[1]),
        "n_hidden": int(n_hidden),
        "n_classes": int(n_cls),
        "seed": seed,
        "epochs": epochs,
        "layer_configs": [lm1.config, lm2.config],
        "seg_bounds_l1": [int(v) for v in lm1.seg_bounds],
        "seg_width_l1": lm1.seg_width,
        "seg_width_l2": lm2.seg_width,
        "sweep_q_offset": q_off,
        "target_hd_median": med,
        "schedule": list(physics.HD_SCHEDULE),
        "software_top1": top1_sw,
        "software_top2": top2_sw,
        "cam_nominal_top1": top1_cam,
        "cam_nominal_top2": top2_cam,
        "paper_software_top1": 0.952 if name == "mnist" else 0.99,
        "paper_cam_top1": 0.952 if name == "mnist" else 0.935,
    }
    with open(os.path.join(out_dir, f"{name}_meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--epochs-mnist", type=int, default=25)
    ap.add_argument("--epochs-hg", type=int, default=20)
    ap.add_argument("--quick", action="store_true",
                    help="tiny datasets/epochs for smoke testing")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.quick:
        xtr, ytr, xte, yte = datamod.make_mnist_like(1000, 200)
        build("mnist", xtr, ytr, xte, yte, 128, 10, args.out, epochs=3, seed=3)
        xtr, ytr, xte, yte = datamod.make_hg_like(600, 150)
        build("hg", xtr, ytr, xte, yte, 128, 20, args.out, epochs=3, seed=5)
        return

    xtr, ytr, xte, yte = datamod.make_mnist_like()
    build("mnist", xtr, ytr, xte, yte, 128, 10, args.out,
          epochs=args.epochs_mnist, seed=3)
    xtr, ytr, xte, yte = datamod.make_hg_like()
    build("hg", xtr, ytr, xte, yte, 128, 20, args.out,
          epochs=args.epochs_hg, seed=5)


if __name__ == "__main__":
    main()
