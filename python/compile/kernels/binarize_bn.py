"""L1 Pallas kernel: batch-norm + sign binarization (training-time layer).

Used by the L2 model's reference forward pass and by train.py's export
validation: sign(BN(y)) must equal sign(flip*y + C) after folding, which is
what the CAM implements with C_j match/mismatch padding cells.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bn_sign_kernel(y_ref, p_ref, o_ref, *, eps):
    # y_ref: (BB, M); p_ref: (4, M) rows = gamma, beta, mean, var
    y = y_ref[...]
    gamma = p_ref[0, :]
    beta = p_ref[1, :]
    mean = p_ref[2, :]
    var = p_ref[3, :]
    yhat = (y - mean[None, :]) / jnp.sqrt(var[None, :] + eps) * gamma[None, :] + beta[None, :]
    o_ref[...] = jnp.where(yhat >= 0.0, 1.0, -1.0).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_b", "eps"))
def binarize_bn(y, gamma, beta, mean, var, *, eps=1e-5, block_b=64):
    """sign(batchnorm(y)) with sign(0) := +1.

    y: (B, M) float32 pre-activations; BN params: (M,) each.
    Returns (B, M) float32 in {-1.0, +1.0}.
    """
    b0, m = y.shape
    bb = min(block_b, b0)
    pad_b = (-b0) % bb
    if pad_b:
        y = jnp.concatenate([y, jnp.zeros((pad_b, m), y.dtype)], axis=0)
    b = b0 + pad_b
    params = jnp.stack([gamma, beta, mean, var]).astype(jnp.float32)  # (4, M)
    return pl.pallas_call(
        functools.partial(_bn_sign_kernel, eps=eps),
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, m), lambda i: (i, 0)),
            pl.BlockSpec((4, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=True,
    )(y.astype(jnp.float32), params)[:b0]
