"""L1 Pallas kernel: binary dot product (XNOR + POPCOUNT) as a tiled matvec.

The paper's CAM computes one neuron's XNOR-popcount per row per cycle in
analog; the TPU translation (DESIGN.md §3) is a VMEM-tiled binary matmul:
activations and weights are +/-1 codes, XNOR(w, x) == w*x on that domain,
and POPCOUNT-in-+/-1-arithmetic is the row sum — so a tile of the binary
layer is a small matmul the MXU would chew through at bf16; here we keep
f32 and run under interpret=True (CPU PJRT cannot execute Mosaic).

Tiling: grid over (B/BB, M/BM), with the full reduction dimension N resident
per tile — the BNN layers here have N <= 4096, i.e. <= 16 KiB per f32 row,
so an (BB=64, N) activation block plus a (BM=128, N) weight block fit VMEM
(<= ~3 MiB) with room for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_B = 64
DEFAULT_BLOCK_M = 128


def _dot_kernel(x_ref, w_ref, o_ref):
    # x_ref: (BB, N), w_ref: (BM, N)  ->  o_ref: (BB, BM)
    x = x_ref[...]
    w = w_ref[...]
    o_ref[...] = jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_b", "block_m"))
def xnor_popcount_dot(x, w, *, block_b=DEFAULT_BLOCK_B, block_m=DEFAULT_BLOCK_M):
    """+/-1 binary dot product: returns x @ w.T via a Pallas grid.

    x: (B, N) float32 in {-1,+1};  w: (M, N) float32 in {-1,+1}.
    B and M are padded up to block multiples internally (pad rows are +1
    codes; the padded outputs are sliced away before returning).
    """
    b0, n = x.shape
    m0, n2 = w.shape
    assert n == n2, f"reduction dim mismatch {n} vs {n2}"
    bb = min(block_b, b0)
    bm = min(block_m, m0)
    pad_b = (-b0) % bb
    pad_m = (-m0) % bm
    if pad_b:
        x = jnp.concatenate([x, jnp.ones((pad_b, n), x.dtype)], axis=0)
    if pad_m:
        w = jnp.concatenate([w, jnp.ones((pad_m, n), w.dtype)], axis=0)
    b, m = b0 + pad_b, m0 + pad_m
    grid = (b // bb, m // bm)
    out = pl.pallas_call(
        _dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=True,
    )(x, w)
    return out[:b0, :m0]


def hamming_distance(x, w, **kw):
    """HD between +/-1 codes using the Pallas dot: (N - dot) / 2."""
    n = x.shape[-1]
    return (n - xnor_popcount_dot(x, w, **kw)) * 0.5
