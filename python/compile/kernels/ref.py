"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: each kernel in this package must
match its oracle bit-for-bit (integer outputs) or to float tolerance
(analog model outputs) under pytest + hypothesis sweeps.
"""

import jax.numpy as jnp

from .. import physics


def xnor_popcount_dot(x, w):
    """Binary dot product via XNOR+POPCOUNT, expressed on +/-1 floats.

    x: (B, N) in {-1, +1};  w: (M, N) in {-1, +1}.
    Returns (B, M) float32: sum_i XNOR(w_mi, x_bi) in +/-1 arithmetic,
    i.e. exactly x @ w.T (each agreeing bit contributes +1, else -1).
    """
    return jnp.matmul(x, w.T).astype(jnp.float32)


def hamming_distance(x, w):
    """HD between +/-1 codes: number of disagreeing positions. (B, M)."""
    n = x.shape[-1]
    dot = xnor_popcount_dot(x, w)
    return ((n - dot) / 2.0).astype(jnp.float32)


def hd_tolerance(vref, veval, vst, n_cells):
    """Vectorised closed-form HD tolerance (see python/compile/physics.py)."""
    c_ml = physics.C_ML_PER_CELL * n_cells
    g = physics.K_G * jnp.maximum(veval - physics.V_TH, 0.0)
    ts = physics.TAU0 * physics.V_DD / jnp.maximum(vst - physics.V_TH, physics.EPS)
    denom = g * ts
    tol = jnp.where(
        denom > 0.0,
        c_ml
        * jnp.log(physics.V_DD / jnp.minimum(vref, physics.V_DD - 1e-9))
        / jnp.maximum(denom, 1e-30),
        jnp.asarray(float(n_cells)),
    )
    return jnp.where(vref >= physics.V_DD, 0.0, tol)


def matchline_fire(mismatches, vref, veval, vst, n_cells):
    """MLSA decision: 1.0 where the row fires (m <= hd_tol), else 0.0."""
    tol = hd_tolerance(vref, veval, vst, n_cells)
    return (mismatches <= tol).astype(jnp.float32)


def binarize_bn(y, gamma, beta, mean, var, eps=1e-5):
    """sign(batchnorm(y)) with sign(0) := +1, on float pre-activations."""
    yhat = (y - mean) / jnp.sqrt(var + eps) * gamma + beta
    return jnp.where(yhat >= 0.0, 1.0, -1.0).astype(jnp.float32)


def fold_bn_constant(gamma, beta, mean, var, eps=1e-5):
    """Fold BN into (flip, C): sign(BN(y)) == sign(flip * y + C).

    flip in {-1, +1} handles gamma's sign (gamma == 0 treated as making the
    neuron constant: sign(beta)).  For gamma != 0,
    C = sign(gamma) * (beta*sqrt(var+eps)/gamma - mean) and the folded
    pre-activation is flip*y + C.
    """
    s = jnp.sqrt(var + eps)
    safe_gamma = jnp.where(gamma == 0.0, 1.0, gamma)
    c = beta * s / safe_gamma - mean
    flip = jnp.where(gamma < 0.0, -1.0, 1.0)
    c = flip * c
    # gamma == 0: output is sign(beta) regardless of y -> huge C carries it.
    c = jnp.where(gamma == 0.0, jnp.where(beta >= 0.0, 1e9, -1e9), c)
    return flip, c


def output_layer_votes(hd, schedule):
    """Thermometer readout: votes_c = #{tol in schedule : hd_c <= tol}.

    hd: (B, M) float; schedule: (K,) float.  Returns (B, M) int32.
    """
    fired = hd[..., None] <= jnp.asarray(schedule, dtype=hd.dtype)[None, None, :]
    return fired.sum(axis=-1).astype(jnp.int32)
