"""L1 Pallas kernel: functional matchline/MLSA model.

Maps per-row mismatch counts + the three user-configurable voltages
(V_ref, V_eval, V_st) to MLSA fire bits, using the closed-form discharge
model of python/compile/physics.py.  This is the deterministic (nominal-PVT)
twin of the rust analog simulator's hot path; the two are cross-validated by
vectors generated in python/tests/test_matchline.py and consumed by
rust/tests/analog_cross_check.rs.

The threshold-sweep variant evaluates the whole Algorithm-1 schedule in one
kernel invocation: silicon repeats the search serially re-tuning voltages;
a vector machine broadcasts the popcount against a threshold lane instead —
the honest TPU translation of "multiple executions" (DESIGN.md §3).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import physics


def _tol_expr(vref, veval, vst, n_cells):
    """HD tolerance, branch-free (matches ref.hd_tolerance)."""
    c_ml = physics.C_ML_PER_CELL * n_cells
    g = physics.K_G * jnp.maximum(veval - physics.V_TH, 0.0)
    ts = physics.TAU0 * physics.V_DD / jnp.maximum(vst - physics.V_TH, physics.EPS)
    denom = g * ts
    tol = jnp.where(
        denom > 0.0,
        c_ml
        * jnp.log(physics.V_DD / jnp.minimum(vref, physics.V_DD - 1e-9))
        / jnp.maximum(denom, 1e-30),
        jnp.full_like(denom, float(n_cells)),
    )
    return jnp.where(vref >= physics.V_DD, jnp.zeros_like(tol), tol)


def _fire_kernel(m_ref, v_ref, o_ref, *, n_cells):
    # m_ref: (BB, R) mismatch counts; v_ref: (1, 3) voltages -> o_ref: (BB, R)
    m = m_ref[...]
    vref, veval, vst = v_ref[0, 0], v_ref[0, 1], v_ref[0, 2]
    tol = _tol_expr(vref, veval, vst, n_cells)
    o_ref[...] = (m <= tol).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("n_cells", "block_b"))
def matchline_fire(mismatches, voltages, *, n_cells, block_b=64):
    """MLSA decisions for a batch of searches under one voltage setting.

    mismatches: (B, R) float32 per-row mismatch counts.
    voltages:   (3,)   float32 (V_ref, V_eval, V_st).
    Returns (B, R) float32 in {0.0, 1.0}.
    """
    b0, r = mismatches.shape
    bb = min(block_b, b0)
    pad_b = (-b0) % bb
    if pad_b:
        mismatches = jnp.concatenate(
            [mismatches, jnp.zeros((pad_b, r), mismatches.dtype)], axis=0)
    b = b0 + pad_b
    v = voltages.reshape(1, 3).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_fire_kernel, n_cells=n_cells),
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
            pl.BlockSpec((1, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r), jnp.float32),
        interpret=True,
    )(mismatches.astype(jnp.float32), v)[:b0]


def _votes_kernel(hd_ref, sched_ref, o_ref):
    # hd_ref: (BB, R); sched_ref: (1, K) -> o_ref: (BB, R) vote counts
    hd = hd_ref[...]
    sched = sched_ref[...]  # (1, K)
    fired = hd[:, :, None] <= sched[None, 0, :]  # (BB, R, K)
    o_ref[...] = fired.sum(axis=-1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_b",))
def threshold_sweep_votes(hd, schedule, *, block_b=64):
    """Vote counts over the Algorithm-1 HD-threshold schedule, one call.

    hd: (B, R) float32;  schedule: (K,) float32 thresholds.
    Returns (B, R) float32 vote counts (0..K).
    """
    b0, r = hd.shape
    k = schedule.shape[0]
    bb = min(block_b, b0)
    pad_b = (-b0) % bb
    if pad_b:
        hd = jnp.concatenate([hd, jnp.zeros((pad_b, r), hd.dtype)], axis=0)
    b = b0 + pad_b
    sched = schedule.reshape(1, k).astype(jnp.float32)
    return pl.pallas_call(
        _votes_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((bb, r), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r), jnp.float32),
        interpret=True,
    )(hd.astype(jnp.float32), sched)[:b0]
