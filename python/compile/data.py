"""Synthetic dataset generators (MNIST-like digits, Hand-Gesture-like masks).

The paper evaluates on MNIST (28x28, 10 classes) and the Kaggle Hand Gesture
dataset (64x64, 20 classes).  Neither is fetchable in this offline
environment, so we generate procedural stand-ins with the same shapes and
class counts (DESIGN.md §1).  The generators are deterministic from a seed;
train.py exports the *test split* to artifacts/ so the rust side evaluates
the exact same images the model was trained against.

All images are binary, returned as +/-1 float32 (the BNN input code).
"""

import numpy as np

# ----------------------------------------------------------------------
# MNIST-like digits: 5x7 pixel-font glyphs, randomly placed/scaled/rotated
# into 28x28, plus salt-and-pepper noise.
# ----------------------------------------------------------------------

_GLYPHS = {
    0: ["#####", "#...#", "#...#", "#...#", "#...#", "#...#", "#####"],
    1: ["..#..", ".##..", "..#..", "..#..", "..#..", "..#..", ".###."],
    2: ["#####", "....#", "....#", "#####", "#....", "#....", "#####"],
    3: ["#####", "....#", "....#", ".####", "....#", "....#", "#####"],
    4: ["#...#", "#...#", "#...#", "#####", "....#", "....#", "....#"],
    5: ["#####", "#....", "#....", "#####", "....#", "....#", "#####"],
    6: ["#####", "#....", "#....", "#####", "#...#", "#...#", "#####"],
    7: ["#####", "....#", "...#.", "..#..", "..#..", ".#...", ".#..."],
    8: ["#####", "#...#", "#...#", "#####", "#...#", "#...#", "#####"],
    9: ["#####", "#...#", "#...#", "#####", "....#", "....#", "#####"],
}


def _glyph_array(digit: int) -> np.ndarray:
    rows = _GLYPHS[digit]
    return np.array([[1.0 if ch == "#" else 0.0 for ch in row] for row in rows],
                    dtype=np.float32)  # (7, 5)


def _render_batch(glyphs, size, scales, angles, shifts, noise_p, rng):
    """Rasterise a batch of (gh, gw) glyphs into (size, size) binary images.

    Inverse-map each target pixel through rotation+scale+shift back into
    glyph coordinates, nearest-sample; then flip pixels with prob noise_p.
    """
    n = len(glyphs)
    gh, gw = glyphs[0].shape
    yy, xx = np.meshgrid(np.arange(size), np.arange(size), indexing="ij")
    cy = cx = (size - 1) / 2.0
    out = np.zeros((n, size, size), dtype=np.float32)
    for i in range(n):
        s, a = scales[i], angles[i]
        dy, dx = shifts[i]
        ca, sa = np.cos(-a), np.sin(-a)
        # target -> centred -> unrotate -> unscale -> glyph coords
        ty = (yy - cy - dy)
        tx = (xx - cx - dx)
        gy = (ca * ty - sa * tx) / s / (size / (gh + 2.0)) + (gh - 1) / 2.0
        gx = (sa * ty + ca * tx) / s / (size / (gw + 2.0)) + (gw - 1) / 2.0
        iy = np.rint(gy).astype(np.int64)
        ix = np.rint(gx).astype(np.int64)
        valid = (iy >= 0) & (iy < gh) & (ix >= 0) & (ix < gw)
        img = np.zeros((size, size), dtype=np.float32)
        img[valid] = glyphs[i][iy[valid], ix[valid]]
        out[i] = img
    flips = rng.random(out.shape) < noise_p
    out = np.where(flips, 1.0 - out, out)
    return out


def make_mnist_like(n_train=8000, n_test=2000, seed=7, noise_p=0.06):
    """Synthetic MNIST: (x_train, y_train, x_test, y_test); x in {-1,+1}^784."""
    rng = np.random.default_rng(seed)
    n = n_train + n_test
    labels = rng.integers(0, 10, n)
    glyphs = [_glyph_array(int(d)) for d in labels]
    scales = rng.uniform(0.75, 1.15, n)
    angles = rng.uniform(-0.22, 0.22, n)  # ~ +/-12.5 deg
    shifts = rng.uniform(-3.0, 3.0, (n, 2))
    imgs = _render_batch(glyphs, 28, scales, angles, shifts, noise_p, rng)
    x = (imgs.reshape(n, 784) * 2.0 - 1.0).astype(np.float32)
    y = labels.astype(np.int32)
    return x[:n_train], y[:n_train], x[n_train:], y[n_train:]


# ----------------------------------------------------------------------
# Hand-Gesture-like: 20 classes = 20 distinct finger-raise patterns on a
# parametric hand silhouette (palm ellipse + up to 5 finger capsules),
# rendered at 64x64 with pose jitter + noise.
# ----------------------------------------------------------------------

# 20 of the 32 possible 5-finger patterns, chosen to be mutually distinct.
_FINGER_PATTERNS = [
    (0, 0, 0, 0, 1), (0, 0, 0, 1, 1), (0, 0, 1, 1, 1), (0, 1, 1, 1, 1),
    (1, 1, 1, 1, 1), (1, 0, 0, 0, 0), (1, 1, 0, 0, 0), (1, 1, 1, 0, 0),
    (1, 1, 1, 1, 0), (0, 1, 0, 1, 0), (1, 0, 1, 0, 1), (0, 0, 1, 0, 0),
    (0, 1, 1, 0, 0), (0, 0, 0, 1, 0), (1, 0, 0, 0, 1), (0, 1, 0, 0, 1),
    (1, 0, 1, 1, 0), (0, 1, 1, 1, 0), (1, 1, 0, 1, 1), (1, 0, 0, 1, 0),
]
_FINGER_ANGLES = np.linspace(-0.75, 0.75, 5)  # radians around 'up'


def _render_hand(size, pattern, palm_r, f_len, f_w, angle, shift, rng):
    yy, xx = np.meshgrid(np.arange(size, dtype=np.float32),
                         np.arange(size, dtype=np.float32), indexing="ij")
    cy = size * 0.62 + shift[0]
    cx = size * 0.50 + shift[1]
    img = ((yy - cy) ** 2 / (palm_r * 1.15) ** 2
           + (xx - cx) ** 2 / palm_r ** 2) <= 1.0
    for k, up in enumerate(pattern):
        if not up:
            continue
        a = _FINGER_ANGLES[k] + angle
        # finger = capsule from palm edge outward
        base_y = cy - palm_r * 0.9 * np.cos(_FINGER_ANGLES[k])
        base_x = cx + palm_r * 0.9 * np.sin(_FINGER_ANGLES[k])
        tip_y = base_y - f_len * np.cos(a)
        tip_x = base_x + f_len * np.sin(a)
        # distance from each pixel to the segment base->tip
        vy, vx = tip_y - base_y, tip_x - base_x
        L2 = vy * vy + vx * vx + 1e-6
        t = np.clip(((yy - base_y) * vy + (xx - base_x) * vx) / L2, 0.0, 1.0)
        d2 = (yy - (base_y + t * vy)) ** 2 + (xx - (base_x + t * vx)) ** 2
        img |= d2 <= f_w ** 2
    return img.astype(np.float32)


def make_hg_like(n_train=4000, n_test=1000, seed=11, noise_p=0.015):
    """Synthetic hand gestures: x in {-1,+1}^4096, 20 classes."""
    rng = np.random.default_rng(seed)
    n = n_train + n_test
    labels = rng.integers(0, 20, n)
    imgs = np.zeros((n, 64, 64), dtype=np.float32)
    for i in range(n):
        pat = _FINGER_PATTERNS[labels[i]]
        imgs[i] = _render_hand(
            64, pat,
            palm_r=rng.uniform(9.0, 12.0),
            f_len=rng.uniform(16.0, 22.0),
            f_w=rng.uniform(2.2, 3.2),
            angle=rng.uniform(-0.12, 0.12),
            shift=rng.uniform(-3.0, 3.0, 2),
            rng=rng,
        )
    flips = rng.random(imgs.shape) < noise_p
    imgs = np.where(flips, 1.0 - imgs, imgs)
    x = (imgs.reshape(n, 4096) * 2.0 - 1.0).astype(np.float32)
    y = labels.astype(np.int32)
    return x[:n_train], y[:n_train], x[n_train:], y[n_train:]
