"""Synthetic dataset generators: determinism, shape, and class invariants."""

import numpy as np
import pytest

from compile import data as datamod


def test_mnist_like_shapes_and_values():
    xtr, ytr, xte, yte = datamod.make_mnist_like(200, 50, seed=1)
    assert xtr.shape == (200, 784) and xte.shape == (50, 784)
    assert ytr.shape == (200,) and yte.shape == (50,)
    assert set(np.unique(xtr)) <= {-1.0, 1.0}
    assert ytr.min() >= 0 and ytr.max() <= 9


def test_mnist_like_deterministic():
    a = datamod.make_mnist_like(100, 20, seed=42)
    b = datamod.make_mnist_like(100, 20, seed=42)
    for va, vb in zip(a, b):
        np.testing.assert_array_equal(va, vb)


def test_mnist_like_seed_changes_data():
    a = datamod.make_mnist_like(100, 20, seed=1)[0]
    b = datamod.make_mnist_like(100, 20, seed=2)[0]
    assert not np.array_equal(a, b)


def test_mnist_like_classes_covered():
    _, ytr, _, _ = datamod.make_mnist_like(500, 10, seed=3)
    assert len(np.unique(ytr)) == 10


def test_mnist_like_glyphs_distinct():
    """Noise-free class prototypes must be pairwise distinguishable."""
    xtr, ytr, _, _ = datamod.make_mnist_like(2000, 10, seed=5, noise_p=0.0)
    protos = np.stack([xtr[ytr == c].mean(axis=0) for c in range(10)])
    for i in range(10):
        for j in range(i + 1, 10):
            assert np.abs(protos[i] - protos[j]).sum() > 10.0, (i, j)


def test_hg_like_shapes_and_values():
    xtr, ytr, xte, yte = datamod.make_hg_like(100, 30, seed=1)
    assert xtr.shape == (100, 4096) and xte.shape == (30, 4096)
    assert set(np.unique(xtr)) <= {-1.0, 1.0}
    assert ytr.min() >= 0 and ytr.max() <= 19


def test_hg_like_deterministic():
    a = datamod.make_hg_like(50, 10, seed=9)
    b = datamod.make_hg_like(50, 10, seed=9)
    for va, vb in zip(a, b):
        np.testing.assert_array_equal(va, vb)


def test_hg_patterns_unique():
    pats = datamod._FINGER_PATTERNS
    assert len(pats) == 20
    assert len(set(pats)) == 20


def test_hg_finger_count_visible():
    """More raised fingers -> more foreground pixels, on average."""
    xtr, ytr, _, _ = datamod.make_hg_like(600, 10, seed=2, noise_p=0.0)
    counts = np.array([sum(p) for p in datamod._FINGER_PATTERNS])
    fg = np.array([
        (xtr[ytr == c] > 0).mean() if (ytr == c).any() else np.nan
        for c in range(20)
    ])
    lo = np.nanmean(fg[counts <= 1])
    hi = np.nanmean(fg[counts >= 4])
    assert hi > lo
