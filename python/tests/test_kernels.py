"""Pallas kernels vs pure-jnp oracles: the core L1 correctness signal.

Hypothesis sweeps shapes and values; integer-valued outputs must match
bit-for-bit, analog-model outputs to tight float tolerance.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile import physics
from compile.kernels import binarize_bn as k_bb
from compile.kernels import matchline as k_ml
from compile.kernels import ref
from compile.kernels import xnor_popcount as k_xp

HYP = hypothesis.settings(max_examples=25, deadline=None)


def pm1(rng, shape):
    v = np.sign(rng.standard_normal(shape)).astype(np.float32)
    v[v == 0] = 1.0
    return v


# ------------------------------------------------------------------
# xnor_popcount
# ------------------------------------------------------------------


@HYP
@hypothesis.given(
    b=st.integers(1, 130),
    m=st.integers(1, 140),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**31),
)
def test_xnor_dot_matches_ref(b, m, n, seed):
    rng = np.random.default_rng(seed)
    x, w = pm1(rng, (b, n)), pm1(rng, (m, n))
    got = k_xp.xnor_popcount_dot(jnp.asarray(x), jnp.asarray(w))
    want = ref.xnor_popcount_dot(jnp.asarray(x), jnp.asarray(w))
    assert got.shape == (b, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@HYP
@hypothesis.given(
    b=st.integers(1, 80), m=st.integers(1, 80), n=st.integers(1, 256),
    seed=st.integers(0, 2**31),
)
def test_hamming_distance_integer_range(b, m, n, seed):
    rng = np.random.default_rng(seed)
    x, w = pm1(rng, (b, n)), pm1(rng, (m, n))
    hd = np.asarray(k_xp.hamming_distance(jnp.asarray(x), jnp.asarray(w)))
    assert hd.min() >= 0 and hd.max() <= n
    # integral values
    np.testing.assert_array_equal(hd, np.rint(hd))
    # identity row: HD(x, x) == 0
    hd_self = np.asarray(k_xp.hamming_distance(jnp.asarray(x), jnp.asarray(x)))
    np.testing.assert_array_equal(np.diag(hd_self[: min(b, b)]), 0.0)


def test_xnor_dot_block_shapes_agree():
    rng = np.random.default_rng(0)
    x, w = pm1(rng, (128, 784)), pm1(rng, (128, 784))
    base = np.asarray(k_xp.xnor_popcount_dot(jnp.asarray(x), jnp.asarray(w)))
    for bb, bm in [(16, 16), (32, 128), (64, 64), (128, 32)]:
        got = np.asarray(
            k_xp.xnor_popcount_dot(jnp.asarray(x), jnp.asarray(w), block_b=bb, block_m=bm)
        )
        np.testing.assert_array_equal(got, base)


# ------------------------------------------------------------------
# matchline
# ------------------------------------------------------------------


@HYP
@hypothesis.given(
    b=st.integers(1, 100),
    r=st.integers(1, 64),
    n_cells=st.sampled_from([256, 512, 1024, 2048]),
    vref=st.floats(0.6, 1.2),
    veval=st.floats(0.3, 1.2),
    vst=st.floats(0.6, 1.2),
    seed=st.integers(0, 2**31),
)
def test_matchline_fire_matches_ref(b, r, n_cells, vref, veval, vst, seed):
    rng = np.random.default_rng(seed)
    m = rng.integers(0, n_cells + 1, (b, r)).astype(np.float32)
    v = jnp.asarray([vref, veval, vst], jnp.float32)
    got = k_ml.matchline_fire(jnp.asarray(m), v, n_cells=n_cells)
    want = ref.matchline_fire(jnp.asarray(m), vref, veval, vst, n_cells)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@HYP
@hypothesis.given(
    b=st.integers(1, 100), r=st.integers(1, 32), k=st.integers(1, 33),
    seed=st.integers(0, 2**31),
)
def test_sweep_votes_matches_ref(b, r, k, seed):
    rng = np.random.default_rng(seed)
    hd = rng.integers(0, 300, (b, r)).astype(np.float32)
    sched = np.arange(0, 2 * k, 2, dtype=np.float32)
    got = k_ml.threshold_sweep_votes(jnp.asarray(hd), jnp.asarray(sched))
    want = ref.output_layer_votes(jnp.asarray(hd), sched)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want, np.float32))


def test_sweep_votes_monotone_in_hd():
    # lower HD never gets fewer votes
    hd = np.arange(0, 130, dtype=np.float32).reshape(1, -1)
    sched = np.arange(0, 65, 2, dtype=np.float32)
    votes = np.asarray(k_ml.threshold_sweep_votes(jnp.asarray(hd), jnp.asarray(sched)))[0]
    assert (np.diff(votes) <= 0).all()
    assert votes[0] == 33 and votes[-1] == 0


# ------------------------------------------------------------------
# binarize_bn
# ------------------------------------------------------------------


@HYP
@hypothesis.given(
    b=st.integers(1, 100), m=st.integers(1, 160), seed=st.integers(0, 2**31)
)
def test_binarize_bn_matches_ref(b, m, seed):
    rng = np.random.default_rng(seed)
    y = (rng.standard_normal((b, m)) * 20).astype(np.float32)
    gamma = rng.standard_normal(m).astype(np.float32)
    beta = rng.standard_normal(m).astype(np.float32)
    mean = (rng.standard_normal(m) * 5).astype(np.float32)
    var = (rng.random(m) * 10 + 0.05).astype(np.float32)
    args = tuple(map(jnp.asarray, (y, gamma, beta, mean, var)))
    got = k_bb.binarize_bn(*args)
    want = ref.binarize_bn(*args)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@HYP
@hypothesis.given(m=st.integers(1, 200), seed=st.integers(0, 2**31))
def test_fold_bn_equivalence(m, seed):
    """sign(BN(y)) == sign(flip*y + C) away from the decision boundary."""
    rng = np.random.default_rng(seed)
    y = (rng.standard_normal((64, m)) * 30).astype(np.float32)
    gamma = rng.standard_normal(m).astype(np.float32)
    gamma[np.abs(gamma) < 1e-3] = 1e-3  # avoid the gamma==0 special case here
    beta = rng.standard_normal(m).astype(np.float32)
    mean = (rng.standard_normal(m) * 5).astype(np.float32)
    var = (rng.random(m) * 10 + 0.05).astype(np.float32)
    args = tuple(map(jnp.asarray, (gamma, beta, mean, var)))
    flip, c = ref.fold_bn_constant(*args)
    folded = jnp.where(flip[None, :] * jnp.asarray(y) + c[None, :] >= 0, 1.0, -1.0)
    bn = ref.binarize_bn(jnp.asarray(y), *args)
    # exclude points numerically on the boundary (fold reassociates floats)
    yhat = (y - np.asarray(mean)) / np.sqrt(np.asarray(var) + 1e-5) * np.asarray(gamma) + np.asarray(beta)
    safe = np.abs(yhat) > 1e-4
    np.testing.assert_array_equal(np.asarray(folded)[safe], np.asarray(bn)[safe])


def test_fold_bn_gamma_zero():
    gamma = jnp.asarray([0.0, 0.0])
    beta = jnp.asarray([1.0, -1.0])
    mean = jnp.asarray([0.0, 0.0])
    var = jnp.asarray([1.0, 1.0])
    flip, c = ref.fold_bn_constant(gamma, beta, mean, var)
    y = jnp.asarray([[5.0, 5.0], [-5.0, -5.0]])
    folded = jnp.where(flip[None, :] * y + c[None, :] >= 0, 1.0, -1.0)
    want = ref.binarize_bn(y, gamma, beta, mean, var)
    np.testing.assert_array_equal(np.asarray(folded), np.asarray(want))
