"""L2 model: CAM mapping invariants and forward-path equivalences."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as modelmod
from compile import physics

HYP = hypothesis.settings(max_examples=20, deadline=None)


def pm1(rng, shape):
    v = np.sign(rng.standard_normal(shape)).astype(np.float32)
    v[v == 0] = 1.0
    return v


# ------------------------------------------------------------------
# config picking / mapping
# ------------------------------------------------------------------


def test_pick_config():
    assert modelmod.pick_config(136)[0] == "512x256"
    assert modelmod.pick_config(512)[0] == "512x256"
    assert modelmod.pick_config(513)[0] == "1024x128"
    assert modelmod.pick_config(792)[0] == "1024x128"
    assert modelmod.pick_config(2048)[0] == "2048x64"
    with pytest.raises(ValueError):
        modelmod.pick_config(2049)


def test_map_layer_mnist_shapes():
    rng = np.random.default_rng(0)
    w = pm1(rng, (128, 784))
    c = rng.standard_normal(128) * 10
    lm = modelmod.map_layer(w, c)
    assert lm.config == "1024x128"
    assert lm.n_seg == 1
    assert lm.seg_width == 1024
    assert lm.seg_pads(0) == 240
    assert (lm.q >= 0).all() and (lm.q <= 240).all()


def test_map_layer_hg_segmentation():
    rng = np.random.default_rng(1)
    w = pm1(rng, (128, 4096))
    c = rng.standard_normal(128) * 10
    lm = modelmod.map_layer(w, c)
    assert lm.config == "2048x64"
    assert lm.n_seg == 3
    assert lm.seg_bounds[0] == 0 and lm.seg_bounds[-1] == 4096
    # payload + pads == word width in every segment
    for s in range(lm.n_seg):
        assert lm.seg_payload(s) + lm.seg_pads(s) == 2048
        assert (lm.q[s] >= 0).all() and (lm.q[s] <= lm.seg_pads(s)).all()


@HYP
@hypothesis.given(
    n_out=st.integers(1, 40),
    n_in=st.sampled_from([64, 128, 784, 1000]),
    scale=st.floats(0.0, 50.0),
    seed=st.integers(0, 2**31),
)
def test_map_layer_c_encoding_error_below_1(n_out, n_in, scale, seed):
    """Pad encoding realises C to within rounding (<= 1.0) when in range."""
    rng = np.random.default_rng(seed)
    w = pm1(rng, (n_out, n_in))
    c = rng.standard_normal(n_out) * scale
    lm = modelmod.map_layer(w, c)
    pads = lm.seg_pads(0)
    ce = modelmod.layer_c_effective(lm)[0]
    in_range = np.abs(c) <= pads - 2  # not clamped
    assert np.all(np.abs(ce[in_range] - c[in_range]) <= 1.0 + 1e-6)
    # clamped values saturate at +/- pads
    assert np.all(np.abs(ce) <= pads)


def test_map_layer_q_offset_shifts_uniformly():
    rng = np.random.default_rng(2)
    w = pm1(rng, (10, 128))
    c = rng.standard_normal(10) * 5
    base = modelmod.map_layer(w, c)
    off = modelmod.map_layer(w, c, q_offset=np.full(10, 7))
    free = (base.q + 7 <= base.seg_pads(0)) & (base.q + 7 >= 0)
    np.testing.assert_array_equal(off.q[free], base.q[free] + 7)


# ------------------------------------------------------------------
# forward equivalences
# ------------------------------------------------------------------


def _rand_model(rng, n_in=100, n_h=32, n_cls=10, c_scale=4.0):
    w1 = pm1(rng, (n_h, n_in))
    c1 = rng.standard_normal(n_h) * c_scale
    w2 = pm1(rng, (n_cls, n_h))
    c2 = rng.standard_normal(n_cls) * c_scale
    return w1, c1, w2, c2


@HYP
@hypothesis.given(seed=st.integers(0, 2**31))
def test_cam_hidden_equals_digital_hidden_single_segment(seed):
    """With one segment + midpoint threshold, the CAM hidden layer equals
    sign(dot + C_int) where C_int is the pad-encoded (rounded) constant."""
    rng = np.random.default_rng(seed)
    w1, c1, w2, c2 = _rand_model(rng)
    x = pm1(rng, (16, 100))
    lm1 = modelmod.map_layer(w1, c1)
    _, fires = modelmod._cam_layer_fires(jnp.asarray(x), lm1)
    ce = modelmod.layer_c_effective(lm1)[0]
    d1 = x @ w1.T
    want = np.where(d1 + ce[None, :] >= 0, 1.0, -1.0)
    np.testing.assert_array_equal(np.asarray(fires), want)


@HYP
@hypothesis.given(seed=st.integers(0, 2**31))
def test_forward_cam_param_matches_forward_cam(seed):
    rng = np.random.default_rng(seed)
    w1, c1, w2, c2 = _rand_model(rng)
    x = pm1(rng, (8, 100))
    lm1 = modelmod.map_layer(w1, c1)
    lm2 = modelmod.map_layer(w2, c2)
    sched = jnp.asarray(modelmod.prefix_schedule(33))
    votes_a, pred_a = modelmod.forward_cam(jnp.asarray(x), lm1, lm2, sched)
    votes_b, pred_b = modelmod.forward_cam_param(
        jnp.asarray(x), jnp.asarray(lm1.weights),
        jnp.asarray(lm1.q.astype(np.float32)), jnp.asarray(lm2.weights),
        jnp.asarray(lm2.q.astype(np.float32)),
        tuple(int(v) for v in lm1.seg_bounds), lm1.seg_width, lm2.seg_width,
        sched,
    )
    np.testing.assert_array_equal(np.asarray(votes_a), np.asarray(votes_b))
    np.testing.assert_array_equal(np.asarray(pred_a), np.asarray(pred_b))


def test_votes_monotone_in_schedule_prefix():
    """Votes under schedule prefix k are a prefix-sum: v_k <= v_{k+1}."""
    rng = np.random.default_rng(3)
    w1, c1, w2, c2 = _rand_model(rng)
    x = pm1(rng, (8, 100))
    lm1 = modelmod.map_layer(w1, c1)
    lm2 = modelmod.map_layer(w2, c2)
    prev = None
    for k in (1, 9, 17, 33):
        votes, _ = modelmod.forward_cam(
            jnp.asarray(x), lm1, lm2, jnp.asarray(modelmod.prefix_schedule(k))
        )
        votes = np.asarray(votes)
        if prev is not None:
            assert (votes >= prev).all()
        prev = votes


def test_segmented_majority_tie_fires():
    """Even segment count with split decision -> tie -> fire (+1)."""
    # 2 segments: one fires, one doesn't => n_fire*2 == n_seg => +1
    n_in = 4096
    rng = np.random.default_rng(4)
    w = pm1(rng, (4, n_in))
    lm = modelmod.map_layer(w, np.zeros(4))
    assert lm.n_seg >= 2  # sanity: segmentation engaged


def test_accuracy_top_k_tiebreak_lowest_index():
    votes = np.array([[5, 5, 1], [1, 7, 7]], dtype=np.int32)
    labels = np.array([1, 1], dtype=np.int32)
    # sample0: classes 0,1 tie at 5 -> top1 = class 0 (lowest index) -> wrong
    # sample1: classes 1,2 tie at 7 -> top1 = class 1 -> right
    assert modelmod.accuracy_top_k(votes, labels, 1) == pytest.approx(0.5)
    assert modelmod.accuracy_top_k(votes, labels, 2) == pytest.approx(1.0)


def test_prefix_schedule():
    np.testing.assert_array_equal(modelmod.prefix_schedule(3), [0.0, 2.0, 4.0])
    assert len(modelmod.prefix_schedule(33)) == 33
    assert modelmod.prefix_schedule(33)[-1] == 64.0
