"""Training path: STE gradients, Adam, fold, export packing."""

import io
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as datamod
from compile import model as modelmod
from compile import train as trainmod


def test_sign_ste_forward():
    v = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
    out = np.asarray(trainmod.sign_ste(v))
    np.testing.assert_array_equal(out, [-1.0, -1.0, 1.0, 1.0, 1.0])


def test_sign_ste_gradient_hardtanh():
    g = jax.grad(lambda v: trainmod.sign_ste(v).sum())(
        jnp.asarray([-2.0, -0.5, 0.5, 2.0])
    )
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 1.0, 0.0])


def test_adam_moves_params_and_clips_latents():
    params = {"w1": jnp.asarray([[0.99]]), "gamma": jnp.asarray([1.0]),
              "beta": jnp.asarray([0.0]), "w2": jnp.asarray([[-0.99]]),
              "b2": jnp.asarray([0.0])}
    grads = {"w1": jnp.asarray([[-1.0]]), "gamma": jnp.asarray([0.5]),
             "beta": jnp.asarray([0.5]), "w2": jnp.asarray([[1.0]]),
             "b2": jnp.asarray([0.5])}
    opt = trainmod.adam_init(params)
    p1, _ = trainmod.adam_update(params, grads, opt, lr=0.05)
    assert float(p1["w1"][0, 0]) <= 1.0
    assert float(p1["w2"][0, 0]) >= -1.0
    assert float(p1["gamma"][0]) != 1.0


def test_training_reduces_loss_tiny():
    xtr, ytr, xte, yte = datamod.make_mnist_like(600, 100, seed=8)
    params, bn = trainmod.train_model(xtr, ytr, 32, 10, epochs=4, seed=0)
    w1f, c1, w2, c2 = trainmod.fold_model(params, bn)
    top1, top2 = trainmod.eval_digital(xte, yte, jnp.asarray(w1f),
                                       c1, jnp.asarray(w2), c2)
    assert top1 > 0.5  # far above chance (0.1)
    assert top2 >= top1


def test_fold_model_binary_weights():
    xtr, ytr, _, _ = datamod.make_mnist_like(300, 10, seed=8)
    params, bn = trainmod.train_model(xtr, ytr, 16, 10, epochs=1, seed=0)
    w1f, c1, w2, c2 = trainmod.fold_model(params, bn)
    assert set(np.unique(w1f)) <= {-1.0, 1.0}
    assert set(np.unique(w2)) <= {-1.0, 1.0}
    assert c1.shape == (16,) and c2.shape == (10,)


# ------------------------------------------------------------------
# export packing
# ------------------------------------------------------------------


def unpack_bits_pm1(packed, m):
    n, words = packed.shape
    out = np.empty((n, m), np.float32)
    for j in range(m):
        out[:, j] = np.where((packed[:, j // 64] >> np.uint64(j % 64)) & np.uint64(1), 1.0, -1.0)
    return out


def test_pack_bits_roundtrip():
    rng = np.random.default_rng(0)
    for m in (1, 63, 64, 65, 784, 4096):
        arr = np.sign(rng.standard_normal((5, m))).astype(np.float32)
        arr[arr == 0] = 1.0
        packed = trainmod.pack_bits_pm1(arr)
        assert packed.shape == (5, (m + 63) // 64)
        np.testing.assert_array_equal(unpack_bits_pm1(packed, m), arr)


def test_weights_bin_format(tmp_path):
    rng = np.random.default_rng(1)
    w = np.sign(rng.standard_normal((10, 100))).astype(np.float32)
    w[w == 0] = 1.0
    lm = modelmod.map_layer(w, rng.standard_normal(10) * 3)
    path = tmp_path / "m.bin"
    trainmod.write_weights_bin(str(path), [lm], (0, 2, 4))
    raw = path.read_bytes()
    assert raw[:8] == b"PICBNN1\x00"
    (n_layers,) = struct.unpack_from("<I", raw, 8)
    assert n_layers == 1
    n_out, n_in, n_seg, seg_w = struct.unpack_from("<IIII", raw, 12)
    assert (n_out, n_in, n_seg) == (10, 100, 1)
    assert seg_w == lm.seg_width
    # schedule trailer
    k = struct.unpack_from("<I", raw, len(raw) - 4 - 3 * 4)[0]
    assert k == 3
    sched = struct.unpack_from("<3i", raw, len(raw) - 3 * 4)
    assert sched == (0, 2, 4)


def test_test_bin_format(tmp_path):
    rng = np.random.default_rng(2)
    x = np.sign(rng.standard_normal((7, 130))).astype(np.float32)
    x[x == 0] = 1.0
    y = rng.integers(0, 5, 7).astype(np.int32)
    path = tmp_path / "t.bin"
    trainmod.write_test_bin(str(path), x, y)
    raw = path.read_bytes()
    assert raw[:8] == b"PICTEST1"
    n, m, ncls = struct.unpack_from("<III", raw, 8)
    assert (n, m) == (7, 130)
    assert ncls == int(y.max()) + 1
    labels = np.frombuffer(raw, np.uint8, count=7, offset=20)
    np.testing.assert_array_equal(labels, y.astype(np.uint8))
    words = (130 + 63) // 64
    packed = np.frombuffer(raw, "<u8", offset=20 + 7).reshape(7, words)
    np.testing.assert_array_equal(unpack_bits_pm1(packed, 130), x)
