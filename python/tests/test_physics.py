"""Analog matchline physics: monotonicity and range invariants (paper §III)."""

import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

from compile import physics

HYP = hypothesis.settings(max_examples=50, deadline=None)


def test_tolerance_zero_at_vdd():
    # V_ref = V_DD -> ML never above reference after precharge decay -> tol 0
    assert physics.hd_tolerance(physics.V_DD, 0.9, 1.0) == 0.0


@HYP
@hypothesis.given(
    v1=st.floats(0.6, 1.19), v2=st.floats(0.6, 1.19),
    veval=st.floats(0.31, 1.2), vst=st.floats(0.6, 1.2),
)
def test_lower_vref_raises_tolerance(v1, v2, veval, vst):
    lo, hi = min(v1, v2), max(v1, v2)
    assert physics.hd_tolerance(lo, veval, vst) >= physics.hd_tolerance(hi, veval, vst)


@HYP
@hypothesis.given(
    vref=st.floats(0.6, 1.19), v1=st.floats(0.31, 1.2), v2=st.floats(0.31, 1.2),
    vst=st.floats(0.6, 1.2),
)
def test_lower_veval_raises_tolerance(vref, v1, v2, vst):
    lo, hi = min(v1, v2), max(v1, v2)
    assert physics.hd_tolerance(vref, lo, vst) >= physics.hd_tolerance(vref, hi, vst)


@HYP
@hypothesis.given(
    vref=st.floats(0.6, 1.19), veval=st.floats(0.31, 1.2),
    v1=st.floats(0.6, 1.2), v2=st.floats(0.6, 1.2),
)
def test_higher_vst_raises_tolerance(vref, veval, v1, v2):
    # higher V_st -> earlier sampling (shorter delay) -> less discharge -> more tolerant
    lo, hi = min(v1, v2), max(v1, v2)
    assert physics.hd_tolerance(vref, veval, hi) >= physics.hd_tolerance(vref, veval, lo)


@pytest.mark.parametrize("n", [256, 1024, 2048])
def test_dynamic_range_covers_midpoint(n):
    """The knobs must reach tolerance > n/2 (majority op) and < 1 (exact)."""
    hi = physics.hd_tolerance(physics.VREF_RANGE[0], physics.VEVAL_RANGE[0] + 1e-4,
                              physics.VST_RANGE[1], n)
    lo = physics.hd_tolerance(1.19, physics.VEVAL_RANGE[1], physics.VST_RANGE[1], n)
    assert hi > n / 2, hi
    assert lo < max(1.0, n / 128), lo


@HYP
@hypothesis.given(
    m1=st.integers(0, 256), m2=st.integers(0, 256),
    veval=st.floats(0.31, 1.2), t=st.floats(1e-10, 5e-9),
)
def test_vml_monotone_in_mismatches(m1, m2, veval, t):
    lo, hi = min(m1, m2), max(m1, m2)
    assert physics.v_ml(lo, t, veval) >= physics.v_ml(hi, t, veval)


def test_vml_zero_mismatch_holds_vdd():
    assert physics.v_ml(0, 10e-9, 1.0) == pytest.approx(physics.V_DD)


def test_fire_decision_consistent_with_tolerance():
    """m <= tol  <=>  V_ML(t_s) > V_ref (the two formulations agree)."""
    for vref, veval, vst in [(0.8, 0.9, 1.1), (0.65, 0.5, 0.9), (1.1, 1.1, 0.7)]:
        tol = physics.hd_tolerance(vref, veval, vst, 256)
        ts = physics.t_sample(vst)
        for m in range(0, 257, 8):
            fire_tol = m <= tol
            fire_vml = physics.v_ml(m, ts, veval) > vref
            # boundary cell can differ by float assoc; allow |m - tol| tiny
            if abs(m - tol) > 1e-6:
                assert fire_tol == fire_vml, (m, tol, vref, veval, vst)


def test_schedule_is_paper_algorithm1():
    assert physics.HD_SCHEDULE[0] == 0
    assert physics.HD_SCHEDULE[-1] == 64
    assert len(physics.HD_SCHEDULE) == 33
    assert all(b - a == 2 for a, b in zip(physics.HD_SCHEDULE, physics.HD_SCHEDULE[1:]))
