"""AOT lowering: HLO text artifacts are well-formed and numerically faithful."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot as aotmod
from compile import model as modelmod


def test_matchline_hlo_text_wellformed():
    text = aotmod.lower_matchline(batch=8, rows=4, n_cells=256)
    assert "ENTRY" in text
    assert "HloModule" in text
    # two parameters (mismatches, voltages)
    assert "parameter(0)" in text and "parameter(1)" in text


def test_xnor_dot_hlo_text_wellformed():
    text = aotmod.lower_xnor_dot(batch=8, m=16, n=64)
    assert "ENTRY" in text and "HloModule" in text


def test_lower_infer_from_meta_like():
    meta = {
        "n_in": 100, "n_hidden": 16, "n_classes": 4,
        "seg_bounds_l1": [0, 100], "seg_width_l1": 128, "seg_width_l2": 512,
        "schedule": list(range(0, 65, 2)),
    }
    text = aotmod.lower_infer(meta)
    assert "ENTRY" in text
    # 6 parameters: x, w1, q1, w2, q2, schedule
    for i in range(6):
        assert f"parameter({i})" in text, i


def test_lowered_graph_matches_eager():
    """The jitted/lowered function computes the same votes as forward_cam."""
    rng = np.random.default_rng(0)
    w1 = np.sign(rng.standard_normal((16, 100))).astype(np.float32)
    w1[w1 == 0] = 1
    w2 = np.sign(rng.standard_normal((4, 16))).astype(np.float32)
    w2[w2 == 0] = 1
    c1 = rng.standard_normal(16) * 3
    c2 = rng.standard_normal(4) * 3
    lm1 = modelmod.map_layer(w1, c1)
    lm2 = modelmod.map_layer(w2, c2)
    x = np.sign(rng.standard_normal((8, 100))).astype(np.float32)
    x[x == 0] = 1
    sched = jnp.arange(0, 65, 2, dtype=jnp.float32)
    votes_e, pred_e = modelmod.forward_cam(jnp.asarray(x), lm1, lm2, sched)

    bounds = tuple(int(v) for v in lm1.seg_bounds)
    fn = jax.jit(
        lambda x_, w1_, q1_, w2_, q2_, s_: modelmod.forward_cam_param(
            x_, w1_, q1_, w2_, q2_, bounds, lm1.seg_width, lm2.seg_width, s_
        )
    )
    votes_j, pred_j = fn(
        jnp.asarray(x), jnp.asarray(lm1.weights),
        jnp.asarray(lm1.q.astype(np.float32)), jnp.asarray(lm2.weights),
        jnp.asarray(lm2.q.astype(np.float32)), sched,
    )
    np.testing.assert_array_equal(np.asarray(votes_e), np.asarray(votes_j))
    np.testing.assert_array_equal(np.asarray(pred_e), np.asarray(pred_j))


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", "mnist_meta.json")),
    reason="artifacts not built",
)
def test_shipped_artifacts_consistent_with_meta():
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    for name in ("mnist", "hg"):
        with open(os.path.join(root, f"{name}_meta.json")) as f:
            meta = json.load(f)
        hlo = open(os.path.join(root, f"{name}_infer.hlo.txt")).read()
        assert "ENTRY" in hlo
        # batch and n_in appear in the entry signature
        assert f"{aotmod.BATCH},{meta['n_in']}" in hlo.replace(" ", "")
