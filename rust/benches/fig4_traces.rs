//! Experiment F4 — regenerate paper Fig. 4: matchline discharge waveforms
//! V_ML(t) for rows with fewer / equal / more mismatches than the majority
//! point, the MLSA sampling instant, and the resulting decisions.  Printed
//! as aligned series (time in ns, voltage in V) suitable for plotting.

use picbnn::analog::{MatchlineModel, Pvt, RowVariation};
use picbnn::benchkit::Table;

fn main() {
    let n_cells = 256;
    let model = MatchlineModel::new(n_cells, Pvt::nominal());
    // majority operating point: tolerance at n/2
    let ctl = picbnn::accel::VoltageController::new(n_cells, Pvt::nominal());
    let p = ctl.calibrate((n_cells / 2) as u32, 2.0).expect("majority point");
    let v = p.voltages;
    let ts = model.sampling_time(&v);
    println!(
        "majority operating point: V_ref={:.0} mV V_eval={:.0} mV V_st={:.0} mV",
        v.vref * 1e3,
        v.veval * 1e3,
        v.vst * 1e3
    );
    println!("MLSA sampling time t_s = {:.2} ns; tolerance = {:.1} mismatches\n", ts * 1e9, p.achieved_tol);

    let majority = (n_cells / 2) as u32;
    let cases = [
        ("matches >> mismatches", majority / 4),
        ("just under majority", majority - 8),
        ("at majority", majority),
        ("just over majority", majority + 8),
        ("mismatches >> matches", majority * 7 / 4),
    ];
    let n_pts = 17;
    let mut table = Table::new(
        "F4: V_ML(t) traces [V] (columns = time in ns; * = sampled at t_s)",
        &{
            let mut h = vec!["mismatches".to_string()];
            for i in 0..n_pts {
                let t = 2.0 * ts * i as f64 / (n_pts - 1) as f64;
                let mark = if (t - ts).abs() < ts / (n_pts as f64) { "*" } else { "" };
                h.push(format!("{:.2}{mark}", t * 1e9));
            }
            h.iter().map(|s| s.as_str().to_owned()).collect::<Vec<_>>()
        }
        .iter()
        .map(String::as_str)
        .collect::<Vec<_>>(),
    );
    for (label, m) in cases {
        let trace = model.trace(m, 2.0 * ts, n_pts, &v);
        let mut row = vec![format!("{m} ({label})")];
        for (_, vml) in &trace {
            row.push(format!("{vml:.3}"));
        }
        table.row(row);
    }
    table.print();

    println!("\ndecisions at t_s (fires = V_ML > V_ref = {:.3} V):", v.vref);
    for (label, m) in cases {
        let fires = model.fires_nominal(m, &v, &RowVariation::nominal());
        println!(
            "  m = {m:<4} ({label:<24}) V_ML(t_s) = {:.3} V  ->  {}",
            model.v_ml(m, ts, &v),
            if fires { "'1' (+1)" } else { "'0' (-1)" }
        );
    }
    println!("\npaper Fig. 4: green (slow discharge, match) crosses V_ref after t_s;");
    println!("black (fast discharge, mismatch majority) crosses before t_s — same shape.");
}
