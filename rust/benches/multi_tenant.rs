//! Experiment A5 — multi-tenant serving: one macro budget, two model
//! shapes (MNIST-shaped + HG-shaped) behind one `MultiServer`.
//!
//! Sweeps the shared budget from full residency for both tenants down
//! through threshold sharing into the cold-spill regime, recording per
//! tenant: steady-state programming cycles, retunes/batch, and device
//! inferences/s.  Also measures the traffic-aware pinning acceptance
//! case: on a skewed schedule (one threshold value holding 8 of 12
//! positions), histogram-driven point pinning must pay at most the
//! cyclic `K − d` retunes/batch and strictly fewer than prefix pinning.
//!
//! Run: `cargo bench --bench multi_tenant`
//! (CI runs it under `PICBNN_BENCH_QUICK=1`.)

use std::time::Duration;

use picbnn::accel::{BatchPolicy, MacroPool, Pipeline, PipelineOptions, PoolMode};
use picbnn::benchkit::{
    bench_artifact_path, emit_json, quick_mode, synth_bits, synth_model, BenchRecord, Table,
};
use picbnn::bnn::model::MappedModel;
use picbnn::cam::NoiseMode;
use picbnn::server::MultiServer;
use picbnn::util::bitops::BitVec;
use picbnn::util::rng::Rng;
use picbnn::util::Timer;

/// MNIST-shaped synthetic model: 784 -> 128 -> 10 at the 1024x128
/// configuration (1 hidden load + 33 thresholds = 34 macros full).
fn mnist_shaped(seed: u64) -> MappedModel {
    synth_model(seed, 0x31A7, &[(128, 784, 1024), (10, 128, 512)])
}

/// HG-shaped synthetic model: 1500 -> 384 -> 6 at the 2048x64
/// configuration (6 hidden loads + 33 thresholds = 39 macros full).
fn hg_shaped(seed: u64) -> MappedModel {
    synth_model(seed, 0xBE9C, &[(384, 1500, 2048), (6, 384, 512)])
}

fn main() {
    let t0 = Timer::start();
    let quick = quick_mode();
    let n_img = if quick { 16 } else { 64 };
    let batches = if quick { 2u64 } else { 4 };
    let opts = PipelineOptions {
        noise: NoiseMode::Nominal,
        ..Default::default()
    };
    let policy = BatchPolicy {
        max_batch: n_img,
        max_wait: Duration::from_millis(1),
    };

    let mnist = mnist_shaped(7);
    let hg = hg_shaped(8);
    let models = [&mnist, &hg];
    let names = ["mnist-shaped", "hg-shaped"];
    let mut rng = Rng::new(3, 5);
    let imgs: Vec<Vec<BitVec>> = models
        .iter()
        .map(|m| (0..n_img).map(|_| synth_bits(m.n_in(), &mut rng)).collect())
        .collect();
    let required: usize = models
        .iter()
        .map(|m| MacroPool::macros_required(m, &opts))
        .sum();
    assert_eq!(required, 34 + 39, "the acceptance shapes");

    // reference predictions (budget-independent in nominal mode) + the
    // reload scheduler's steady-state programming bill per tenant
    let mut want = Vec::new();
    let mut reload_prog = Vec::new();
    for (m, tenant_imgs) in models.iter().zip(&imgs) {
        let mut pipe = Pipeline::new(m, opts);
        want.push(pipe.classify_batch(tenant_imgs));
        pipe.take_stats(0);
        for _ in 0..batches {
            pipe.classify_batch(tenant_imgs);
        }
        reload_prog.push(pipe.take_stats(batches * n_img as u64).programming_cycles());
    }

    let mut table = Table::new(
        &format!(
            "A5: one budget, two tenants — steady state, {batches} × {n_img} images per \
             tenant, full residency = {required} macros"
        ),
        &[
            "budget",
            "tenant",
            "plan",
            "program cyc",
            "retunes/batch",
            "device inf/s",
        ],
    );
    let mut records: Vec<BenchRecord> = Vec::new();
    for budget in [required, 48, 24, 8] {
        let mut server = MultiServer::new(&models, opts, policy, budget);
        // warmup epoch: construction programming + first funnel parks
        for t in 0..2 {
            for img in &imgs[t] {
                server.submit(t, img.clone());
            }
        }
        server.poll(true);
        server.take_device_stats(0);
        server.take_device_stats(1);
        // steady state: tenants interleave epoch by epoch
        let mut steady_responses = Vec::new();
        for _ in 0..batches {
            for t in 0..2 {
                for img in &imgs[t] {
                    server.submit(t, img.clone());
                }
            }
            steady_responses.extend(server.poll(true));
        }
        for t in 0..2 {
            let stats = server.take_device_stats(t);
            let plan = server.pool().tenant(t).plan().expect("resident tenant");
            assert_eq!(server.pool().tenant(t).mode(), PoolMode::Resident);
            let retunes_per_batch = stats.events.retunes as f64 / batches as f64;
            if plan.spill_active() {
                // cold-spill reprograms, but strictly less than reload
                assert!(stats.programming_cycles() > 0, "spill reprograms");
                assert!(
                    stats.programming_cycles() < reload_prog[t],
                    "budget {budget} tenant {t}: spill {} vs reload {}",
                    stats.programming_cycles(),
                    reload_prog[t]
                );
            } else {
                assert_eq!(
                    stats.programming_cycles(),
                    0,
                    "budget {budget} tenant {t}: resident steady state must not program"
                );
            }
            assert!(
                stats.events.retunes <= plan.predicted_retunes_per_batch() * batches,
                "budget {budget} tenant {t}: retunes exceed the plan's cost model"
            );
            table.row(vec![
                budget.to_string(),
                names[t].into(),
                plan.describe(),
                stats.programming_cycles().to_string(),
                format!("{retunes_per_batch:.1}"),
                format!("{:.0}", stats.inferences_per_s()),
            ]);
            let tag = format!("tenants=2 budget={budget} {}", names[t]);
            records.push(BenchRecord::new(
                &format!("{tag} [device inf/s]"),
                1e9 / stats.inferences_per_s(),
                Some(stats.inferences_per_s()),
            ));
            records.push(BenchRecord::new(
                &format!("{tag} [retunes/batch]"),
                retunes_per_batch,
                None,
            ));
            records.push(BenchRecord::new(
                &format!("{tag} [programming cycles]"),
                stats.programming_cycles() as f64,
                None,
            ));
        }
        // tenant isolation: steady responses equal the standalone
        // reference predictions, per tenant, in submission order
        steady_responses.sort_by_key(|r| (r.tenant, r.id));
        for t in 0..2 {
            let tenant_resp: Vec<_> = steady_responses
                .iter()
                .filter(|r| r.tenant == t)
                .collect();
            assert_eq!(tenant_resp.len(), batches as usize * n_img);
            for (i, r) in tenant_resp.iter().enumerate() {
                let (votes, pred) = &want[t][i % n_img];
                assert_eq!(&r.prediction, pred, "budget {budget} tenant {t}");
                assert_eq!(&r.votes, votes, "budget {budget} tenant {t}");
            }
        }
    }
    table.print();

    // --- traffic-aware pinning on a skewed schedule (acceptance) ---
    // threshold value 0 holds 8 of 12 positions (skew 8× ≥ 2×); at a
    // budget of 4 macros the prefix rule pins d = 2 positions, so the
    // classic bound is K − d = 10 retunes/batch
    let mut skewed = mnist_shaped(9);
    skewed.schedule = vec![0, 0, 0, 0, 0, 0, 0, 0, 8, 16, 24, 32];
    let skew_imgs: Vec<BitVec> = (0..n_img)
        .map(|_| synth_bits(skewed.n_in(), &mut rng))
        .collect();
    let budget = 4;
    let prefix = MacroPool::with_capacity(&skewed, opts, budget);
    let traffic = MacroPool::with_traffic(&skewed, opts, budget, 1, &[1; 12]);
    let d = prefix.plan().unwrap().pinned as u64;
    let bound = skewed.schedule.len() as u64 - d;
    prefix.classify_batch(&skew_imgs); // warmup parks
    traffic.classify_batch(&skew_imgs);
    prefix.take_stats(0);
    traffic.take_stats(0);
    for _ in 0..batches {
        prefix.classify_batch(&skew_imgs);
        traffic.classify_batch(&skew_imgs);
    }
    let p = prefix.take_stats(batches * n_img as u64);
    let t = traffic.take_stats(batches * n_img as u64);
    let p_rpb = p.events.retunes as f64 / batches as f64;
    let t_rpb = t.events.retunes as f64 / batches as f64;
    assert!(
        t.events.retunes <= bound * batches,
        "traffic-aware {t_rpb}/batch exceeds the K−d bound {bound}"
    );
    assert!(
        t.events.retunes < p.events.retunes,
        "traffic-aware {t_rpb}/batch must beat prefix {p_rpb}/batch on 8× skew"
    );
    println!(
        "\nskewed schedule (8× skew, budget {budget}): K−d bound {bound}, \
         prefix {p_rpb:.1} retunes/batch, traffic-aware {t_rpb:.1} retunes/batch"
    );
    records.push(BenchRecord::new("skew K-d bound [retunes/batch]", bound as f64, None));
    records.push(BenchRecord::new("skew prefix [retunes/batch]", p_rpb, None));
    records.push(BenchRecord::new("skew traffic-aware [retunes/batch]", t_rpb, None));

    emit_json(bench_artifact_path("BENCH_multi_tenant.json"), &records)
        .expect("write BENCH_multi_tenant.json");
    println!("\n[multi_tenant done in {:.1}s]", t0.elapsed_s());
}
