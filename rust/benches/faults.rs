//! Fault-drill acceptance bench: an escalating, seed-replayable fault
//! schedule injected into a serving engine whose scrub maintenance task
//! must detect, repair, and fully heal it — plus a refusal drill that
//! drives one output slot past every recovery rung and checks the typed
//! rejection at admission.
//!
//! Scenario A (healing drill): `FaultPlan::escalating` lands transient
//! upsets, stuck bitcells (within the spare budget), dead matchlines,
//! and rail drift across every resident site while the engine serves
//! fixed epochs.  Measured, in deterministic device accounting:
//!  * during-drill prediction mismatch vs a never-faulted twin pool
//!    (bounded — faults are live between injection and repair);
//!  * scrub/repair counters as surfaced in the lane's `ServerMetrics`;
//!  * post-drill mismatch, which must be exactly zero: every repair rung
//!    short of quarantine restores bit-exact nominal predictions.
//!
//! Scenario B (refusal drill): dead rows past the spare budget on an
//! output slot with no rebuild budget.  The pool must land on
//! `DegradedMode::Refusing` and the engine must shed new work with the
//! typed `RejectReason::Degraded` — never serve silently wrong answers.
//!
//! Scenario C (recovery drill): one copy of a replicated hidden load is
//! written off (quarantine + failover), the operator re-admits it, the
//! first probation flakes on its final canary lap (re-quarantined, lap
//! requirement doubled), and the second — escalated — probation passes.
//! Measured: probation laps, re-quarantines, re-admissions, and the
//! capacity recovered through the canary gate; the recovered pool must
//! match a never-faulted twin bit-exactly.
//!
//! The fault seed comes from `PICBNN_FAULT_SEED` (default 0xD1CE) so CI
//! can pin a fixed drill; results go to `BENCH_faults.json` (quick mode
//! writes `BENCH_faults_quick.json` so a smoke run never replaces the
//! committed baseline).  CI runs it under `PICBNN_BENCH_QUICK=1`,
//! including a forced-scalar lane (the drill is backend-independent).

use std::time::Duration;

use picbnn::accel::{BatchPolicy, MacroPool, PipelineOptions, ScrubConfig, ScrubController};
use picbnn::benchkit::{
    bench_artifact_path, emit_json, quick_mode, synth_bits, synth_model, BenchRecord, Table,
};
use picbnn::cam::{
    DegradedMode, FaultKind, FaultPlan, FaultSite, NoiseMode, DEFAULT_PROBATION_LAPS,
    DEFAULT_SPARE_ROWS,
};
use picbnn::server::{Clock, Engine, RejectReason};
use picbnn::util::bitops::BitVec;
use picbnn::util::rng::Rng;
use picbnn::util::Timer;

fn fault_seed() -> u64 {
    std::env::var("PICBNN_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1CE)
}

fn main() {
    let t0 = Timer::start();
    let quick = quick_mode();
    let seed = fault_seed();
    let opts = PipelineOptions {
        noise: NoiseMode::Nominal,
        ..Default::default()
    };
    // drill fixture: 64 -> 8 -> 6 with a 9-point schedule, so the pool
    // holds one hidden load plus nine output slots — ten fault sites
    let mut model = synth_model(60, 0xFA17, &[(8, 64, 512), (6, 8, 512)]);
    model.schedule = (0..=16).step_by(2).collect();
    let budget = MacroPool::macros_required(&model, &opts);

    let per_batch = if quick { 4 } else { 16 };
    let stride = if quick { 2u64 } else { 4 };
    let mut rng = Rng::new(seed, 7);
    let images: Vec<BitVec> = (0..per_batch).map(|_| synth_bits(64, &mut rng)).collect();

    // ---- scenario A: escalating drill against a serving engine ----
    let engine = Engine::single(
        &model,
        opts,
        BatchPolicy {
            max_batch: per_batch,
            max_wait: Duration::ZERO,
        },
        budget,
    )
    .with_clock(Clock::simulated())
    .with_scrub(
        0,
        seed,
        ScrubConfig {
            rows_per_turn: 64, // ~one lap per inter-epoch gap
            ..Default::default()
        },
    );
    let sites = engine.single_pool().fault_sites();
    assert!(!sites.is_empty(), "bench pool must be resident");
    let plan = FaultPlan::escalating(seed, &sites, per_batch as u64, stride);
    let injected = plan.len();
    let last_at = plan.events.iter().map(|e| e.at_image).max().unwrap();
    engine.single_pool().inject_fault_plan(plan);

    let twin = MacroPool::with_capacity(&model, opts, budget);
    // enough epochs to activate every event, plus healing margin
    let drill_epochs = (last_at / per_batch as u64) as usize + 1 + 6;
    let mut drill_mismatches = 0u64;
    let mut last_bad_epoch: Option<usize> = None;
    let mut base = 0u64;
    for epoch in 0..drill_epochs {
        for img in &images {
            engine.submit(0, img.clone()).expect("drill lane is unbounded");
        }
        let mut got = engine.flush();
        assert_eq!(got.len(), per_batch, "every drill request must complete");
        got.sort_by_key(|r| r.id);
        let want = twin.classify_batch_at(&images, base);
        let bad = got
            .iter()
            .zip(&want)
            .filter(|(r, (_, pred))| r.prediction != *pred)
            .count() as u64;
        drill_mismatches += bad;
        if bad > 0 {
            last_bad_epoch = Some(epoch);
        }
        base += per_batch as u64;
        // an idle tick guarantees a scrub turn even if the flush raced
        let _ = engine.poll();
    }
    let offered = (drill_epochs * per_batch) as u64;
    let mismatch_rate = drill_mismatches as f64 / offered as f64;

    // acceptance: bounded damage while faults are live...
    assert!(
        mismatch_rate < 0.5,
        "drill mismatch rate {mismatch_rate:.3} is out of bounds"
    );
    let m = engine.lane_metrics(0);
    assert!(m.scrubbed_rows > 0, "scrub progress must surface");
    assert!(m.faults_detected > 0, "the drill must be detected");
    assert!(m.faults_repaired > 0, "the drill must be repaired");
    assert_eq!(m.replica_quarantines, 0, "the drill stays within spares");
    assert_eq!(m.unrepairable, 0, "nothing in the drill is terminal");
    assert_eq!(m.degraded, DegradedMode::Nominal, "the pool must fully heal");

    // ...and exact recovery afterwards: a verification epoch bit-equal
    // to the never-faulted twin
    for img in &images {
        engine.submit(0, img.clone()).expect("verify lane is unbounded");
    }
    let mut got = engine.flush();
    got.sort_by_key(|r| r.id);
    let want = twin.classify_batch_at(&images, base);
    let residual = got
        .iter()
        .zip(&want)
        .filter(|(r, (votes, pred))| r.prediction != *pred || &r.votes != votes)
        .count();
    assert_eq!(residual, 0, "healed engine must match the twin bit-exactly");

    // ---- scenario B: refusal drill (typed degradation) ----
    let refusal = Engine::single(
        &model,
        opts,
        BatchPolicy {
            max_batch: per_batch,
            max_wait: Duration::ZERO,
        },
        budget,
    )
    .with_clock(Clock::simulated())
    .with_scrub(
        0,
        seed ^ 0x0BAD,
        ScrubConfig {
            rows_per_turn: 1 << 20,
            max_rebuilds: 0,
            ..Default::default()
        },
    );
    let mut kill = FaultPlan::default();
    for row in 0..=DEFAULT_SPARE_ROWS {
        kill.push(
            0,
            FaultSite::Output { slot: Some(0) },
            FaultKind::DeadRow {
                row,
                always_fire: true,
            },
        );
    }
    refusal.single_pool().inject_fault_plan(kill);
    for img in &images {
        refusal.submit(0, img.clone()).expect("admission starts open");
    }
    assert_eq!(refusal.flush().len(), per_batch);
    let _ = refusal.poll(); // idle tick: the scrub turn that gives up
    let rm = refusal.lane_metrics(0);
    assert!(rm.unrepairable > 0, "spare exhaustion must be terminal");
    assert_eq!(rm.degraded, DegradedMode::Refusing);
    let err = refusal
        .submit(0, images[0].clone())
        .expect_err("a refusing pool must shed new work");
    assert_eq!(err.reason, RejectReason::Degraded, "the rejection is typed");
    let shed = refusal.lane_metrics(0).shed;
    assert!(shed > 0, "the shed must surface in metrics");

    // ---- scenario C: recovery drill (operator re-admission) ----
    let rec_pool = MacroPool::with_capacity_for_workers(&model, opts, budget + 1, 2);
    let rec_twin = MacroPool::with_capacity_for_workers(&model, opts, budget + 1, 2);
    assert_eq!(
        rec_pool.fault_sites()[0].replicas,
        2,
        "the surplus macro must buy a hidden replica"
    );
    let mut kill = FaultPlan::default();
    for row in 0..=DEFAULT_SPARE_ROWS {
        kill.push(
            0,
            FaultSite::Hidden {
                layer: 0,
                load: 0,
                replica: Some(0),
            },
            FaultKind::DeadRow {
                row,
                always_fire: true,
            },
        );
    }
    rec_pool.inject_fault_plan(kill);
    let mut rec_base = 0u64;
    rec_pool.classify_batch_at(&images, rec_base);
    rec_twin.classify_batch_at(&images, rec_base);
    rec_base += per_batch as u64;
    let mut rec_ctl = ScrubController::new(
        seed ^ 0xCAFE,
        ScrubConfig {
            rows_per_turn: 1 << 20,
            max_rebuilds: 0,
            workers: 2,
            ..Default::default()
        },
    );
    let mut rec = rec_ctl.maintain(&rec_pool);
    assert_eq!(rec.quarantines, 1, "the dying copy must be retired");
    assert_eq!(rec_ctl.degraded_mode(), DegradedMode::Failover);
    assert_eq!(
        rec_pool.fault_sites()[0].replicas,
        1,
        "failover serves on the surviving copy"
    );
    for _ in 0..12 {
        rec.add(&rec_ctl.maintain(&rec_pool)); // drain the re-plan
    }
    // first probation flakes on its final canary lap: a dead row lands
    // on the probation side-array (replica indices past the live copies)
    assert!(rec_pool.un_quarantine(0, 0), "re-admission must engage");
    for _ in 0..DEFAULT_PROBATION_LAPS - 1 {
        rec.add(&rec_ctl.maintain(&rec_pool));
    }
    let mut flake = FaultPlan::default();
    flake.push(
        rec_base,
        FaultSite::Hidden {
            layer: 0,
            load: 0,
            replica: Some(1),
        },
        FaultKind::DeadRow {
            row: 0,
            always_fire: false,
        },
    );
    rec_pool.inject_fault_plan(flake);
    rec_pool.classify_batch_at(&images, rec_base);
    rec_twin.classify_batch_at(&images, rec_base);
    rec_base += per_batch as u64;
    rec.add(&rec_ctl.maintain(&rec_pool));
    assert_eq!(rec.probation_failures, 1, "the flake must re-quarantine");
    assert_eq!(rec.readmissions, 0, "no silent re-admission");
    // the second probation (lap requirement doubled) passes
    assert!(rec_pool.un_quarantine(0, 0));
    for _ in 0..(DEFAULT_PROBATION_LAPS << 1) {
        rec.add(&rec_ctl.maintain(&rec_pool));
    }
    assert_eq!(rec.readmissions, 1, "the canary gate must readmit");
    let capacity_back = rec_pool.fault_sites()[0].replicas;
    assert_eq!(capacity_back, 2, "re-admission must restore capacity");
    assert_eq!(
        rec_ctl.degraded_mode(),
        DegradedMode::Nominal,
        "re-admission is the one path out of Failover"
    );
    assert_eq!(
        rec_pool.classify_batch_at(&images, rec_base),
        rec_twin.classify_batch_at(&images, rec_base),
        "recovered pool must match the twin bit-exactly"
    );
    let capacity_recovered = capacity_back - 1;

    let mut table = Table::new(
        "faults: escalating drill + refusal drill (seeded, replayable)",
        &["measure", "value"],
    );
    table.row(vec!["fault seed".into(), format!("{seed:#x}")]);
    table.row(vec!["events injected".into(), injected.to_string()]);
    table.row(vec!["drill epochs".into(), drill_epochs.to_string()]);
    table.row(vec![
        "mismatch rate (drill)".into(),
        format!("{mismatch_rate:.4}"),
    ]);
    table.row(vec![
        "last unhealed epoch".into(),
        last_bad_epoch.map_or("-".into(), |e| e.to_string()),
    ]);
    table.row(vec!["rows scrubbed".into(), m.scrubbed_rows.to_string()]);
    table.row(vec!["faults detected".into(), m.faults_detected.to_string()]);
    table.row(vec!["faults repaired".into(), m.faults_repaired.to_string()]);
    table.row(vec!["replica rebuilds".into(), m.replica_rebuilds.to_string()]);
    table.row(vec!["post-heal mismatches".into(), residual.to_string()]);
    table.row(vec![
        "refusal: unrepairable".into(),
        rm.unrepairable.to_string(),
    ]);
    table.row(vec!["refusal: typed sheds".into(), shed.to_string()]);
    table.row(vec![
        "recovery: probation laps".into(),
        rec.probation_laps.to_string(),
    ]);
    table.row(vec![
        "recovery: re-quarantines".into(),
        rec.probation_failures.to_string(),
    ]);
    table.row(vec![
        "recovery: readmissions".into(),
        rec.readmissions.to_string(),
    ]);
    table.row(vec![
        "recovery: capacity recovered".into(),
        capacity_recovered.to_string(),
    ]);
    table.print();

    let records = vec![
        BenchRecord::new("faults drill [events injected]", injected as f64, None),
        BenchRecord::new("faults drill [mismatch rate]", mismatch_rate, None),
        BenchRecord::new(
            "faults drill [last unhealed epoch]",
            last_bad_epoch.map_or(-1.0, |e| e as f64),
            None,
        ),
        BenchRecord::new("faults drill [rows scrubbed]", m.scrubbed_rows as f64, None),
        BenchRecord::new("faults drill [detected]", m.faults_detected as f64, None),
        BenchRecord::new("faults drill [repaired]", m.faults_repaired as f64, None),
        BenchRecord::new("faults drill [rebuilds]", m.replica_rebuilds as f64, None),
        BenchRecord::new("faults drill [post-heal mismatches]", residual as f64, None),
        BenchRecord::new("faults refusal [unrepairable]", rm.unrepairable as f64, None),
        BenchRecord::new("faults refusal [typed sheds]", shed as f64, None),
        BenchRecord::new(
            "faults recovery [probation laps]",
            rec.probation_laps as f64,
            None,
        ),
        BenchRecord::new(
            "faults recovery [re-quarantines]",
            rec.probation_failures as f64,
            None,
        ),
        BenchRecord::new("faults recovery [readmissions]", rec.readmissions as f64, None),
        BenchRecord::new(
            "faults recovery [capacity recovered]",
            capacity_recovered as f64,
            None,
        ),
    ];
    let out_path = if quick {
        bench_artifact_path("BENCH_faults_quick.json")
    } else {
        bench_artifact_path("BENCH_faults.json")
    };
    emit_json(&out_path, &records).expect("write faults bench artifact");
    println!("\n[faults done in {:.1}s]", t0.elapsed_s());
}
