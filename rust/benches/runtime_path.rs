//! Experiment A3 — architecture ablation: the two execution backends
//! (native analog CAM simulator vs the PJRT-compiled AOT JAX/Pallas graph)
//! must agree bit-for-bit in nominal mode; this bench also compares their
//! host-side throughput (the PJRT path is the fast functional reference,
//! the simulator the evaluated device).

use picbnn::accel::{Pipeline, PipelineOptions};
use picbnn::benchkit::Table;
use picbnn::bnn::infer::digital_forward;
use picbnn::bnn::model::MappedModel;
use picbnn::cam::NoiseMode;
use picbnn::data::TestSet;
use picbnn::runtime::InferEngine;
use picbnn::util::Timer;

fn main() {
    let t0 = Timer::start();
    let dir = picbnn::artifacts_dir();
    let mut table = Table::new(
        "A3: execution backend comparison (nominal mode, host wall-clock)",
        &["model", "backend", "images", "agree", "host img/s"],
    );
    for name in ["mnist", "hg"] {
        let Ok(model) = MappedModel::load(dir.join(format!("{name}_weights.bin"))) else {
            println!("skipping {name}: artifacts not built");
            return;
        };
        let test = TestSet::load(dir.join(format!("{name}_test.bin"))).expect("test set");
        let n = 512.min(test.len());
        // digital reference (ground truth)
        let want: Vec<_> = test.images[..n]
            .iter()
            .map(|x| digital_forward(&model, x, &model.schedule))
            .collect();

        // native CAM simulator
        let mut pipe = Pipeline::new(
            &model,
            PipelineOptions {
                noise: NoiseMode::Nominal,
                ..Default::default()
            },
        );
        let t = Timer::start();
        let mut got = Vec::with_capacity(n);
        for chunk in test.images[..n].chunks(256) {
            got.extend(pipe.classify_batch(chunk));
        }
        let sim_rate = n as f64 / t.elapsed_s();
        let sim_agree = got == want;
        table.row(vec![
            name.into(),
            "CAM simulator".into(),
            n.to_string(),
            sim_agree.to_string(),
            format!("{sim_rate:.0}"),
        ]);

        // PJRT path
        match InferEngine::load(name, &model) {
            Ok(engine) => {
                let t = Timer::start();
                let got = engine.classify_all(&test.images[..n]).expect("pjrt");
                let rate = n as f64 / t.elapsed_s();
                let agree = got == want;
                table.row(vec![
                    name.into(),
                    "PJRT (AOT HLO)".into(),
                    n.to_string(),
                    agree.to_string(),
                    format!("{rate:.0}"),
                ]);
                assert!(agree, "{name}: PJRT diverged from digital reference");
            }
            Err(e) => println!("{name}: PJRT unavailable: {e}"),
        }
        assert!(sim_agree, "{name}: simulator diverged from digital reference");
    }
    table.print();
    println!("\n[runtime_path done in {:.1}s]", t0.elapsed_s());
}
