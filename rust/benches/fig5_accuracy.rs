//! Experiment F5 — regenerate paper Fig. 5: TOP-1 and TOP-2 accuracy vs
//! the number of output-layer executions (prefix of the HD-threshold
//! schedule, 1..33) for both datasets, on the analog CAM simulator, with
//! the software baseline as the reference line.

use picbnn::accel::{evaluate, Pipeline, PipelineOptions};
use picbnn::baseline::{digital_predict, digital_top2};
use picbnn::benchkit::Table;
use picbnn::bnn::model::MappedModel;
use picbnn::data::{ModelMeta, TestSet};
use picbnn::util::Timer;

fn main() {
    let t = Timer::start();
    let dir = picbnn::artifacts_dir();
    for name in ["mnist", "hg"] {
        let Ok(model) = MappedModel::load(dir.join(format!("{name}_weights.bin"))) else {
            println!("skipping {name}: artifacts not built");
            continue;
        };
        let test = TestSet::load(dir.join(format!("{name}_test.bin"))).expect("test set");
        let meta = ModelMeta::load(dir.join(format!("{name}_meta.json"))).expect("meta");
        let n = 1000.min(test.len());

        // software baseline reference
        let (mut sw1, mut sw2) = (0usize, 0usize);
        for (x, &y) in test.images[..n].iter().zip(&test.labels[..n]) {
            if digital_predict(&model, x) == y as usize {
                sw1 += 1;
            }
            if digital_top2(&model, x).contains(&(y as usize)) {
                sw2 += 1;
            }
        }

        let mut table = Table::new(
            &format!(
                "F5 ({name}): accuracy vs output-layer executions (analog CAM, {n} images)"
            ),
            &["executions", "max HD thr", "TOP-1", "TOP-2"],
        );
        for k in [1usize, 3, 5, 9, 13, 17, 21, 25, 29, 33] {
            let mut pipe = Pipeline::new(
                &model,
                PipelineOptions {
                    schedule_prefix: Some(k),
                    ..Default::default()
                },
            );
            let mut votes = Vec::with_capacity(n);
            for chunk in test.images[..n].chunks(256) {
                votes.extend(pipe.classify_batch(chunk).into_iter().map(|(v, _)| v));
            }
            let acc = evaluate(&votes, &test.labels[..n]);
            table.row(vec![
                k.to_string(),
                (2 * (k - 1)).to_string(),
                format!("{:.4}", acc.top1),
                format!("{:.4}", acc.top2),
            ]);
        }
        table.row(vec![
            "digital (mapped)".into(),
            "-".into(),
            format!("{:.4}", sw1 as f64 / n as f64),
            format!("{:.4}", sw2 as f64 / n as f64),
        ]);
        table.row(vec![
            "software (float fold)".into(),
            "-".into(),
            format!("{:.4}", meta.software_top1),
            format!("{:.4}", meta.software_top2),
        ]);
        table.print();
        println!(
            "paper: {name} saturates at top1 {:.3} (software {:.3}); accuracy must\nrise with executions and plateau near the baseline.\n",
            meta.paper_cam_top1, meta.paper_software_top1
        );
    }
    println!("[fig5_accuracy done in {:.1}s]", t.elapsed_s());
}
