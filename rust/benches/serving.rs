//! End-to-end serving bench: the admission-controlled engine on a
//! simulated clock, driven open-loop by deterministic arrival processes
//! (`server::loadgen`), with the device paced by its own measured
//! per-image service time (`ServiceModel::DevicePaced`).  Everything —
//! arrival times, batch closings, shedding, latency percentiles — is
//! virtual-time discrete-event simulation, so the numbers are bit-exact
//! reproducible across runs and hosts.
//!
//! Three scenarios:
//!  * steady   — Poisson at half capacity, single tenant: the latency
//!               floor (p50/p99/p999) and goodput under headroom.
//!  * overload — bursty offered load above capacity on two tenants, one
//!               guaranteed and one best-effort with a bounded queue:
//!               the engine must shed the best-effort lane with typed
//!               `Rejected { QueueFull }` responses while the guaranteed
//!               lane's p99 stays bounded (the PR's acceptance run).
//!  * diurnal  — sinusoidal day over a 3-million synthetic-user
//!               population, single tenant: goodput tracking a moving
//!               rate.
//!
//! Results go to `BENCH_serving.json` (full mode; quick mode writes
//! `BENCH_serving_quick.json` so a smoke run never replaces the
//! committed baseline), and the steady and diurnal goodput records gate
//! against the committed baseline with the same quick/backend-mismatch
//! skip rules as the hotpath bench.  CI runs this under
//! `PICBNN_BENCH_QUICK=1` including a forced-scalar lane.

use std::time::Duration;

use picbnn::accel::{BatchPolicy, MacroPool, PipelineOptions};
use picbnn::benchkit::{
    bench_artifact_path, compare_baseline, emit_json, quick_mode, synth_bits, synth_model,
    BenchRecord, Table,
};
use picbnn::cam::NoiseMode;
use picbnn::server::{
    AdmissionPolicy, ArrivalProcess, Clock, Engine, QosClass, RejectReason, Rejected,
    ServiceModel, Workload,
};
use picbnn::util::bitops::BitVec;
use picbnn::util::rng::Rng;
use picbnn::util::Timer;

/// Scenario records gated against the committed baseline in full mode.
/// Both goodput records carry `Some(throughput)` (stored inverted as
/// inf/s, so "higher value = slower" matches the gate's direction).
const BASELINE_GATED: [&str; 2] = [
    "serving steady poisson [goodput inf/s]",
    "serving diurnal [goodput inf/s]",
];

/// Images cycled through per tenant (arrival's user id picks one).
const IMAGE_POOL: usize = 32;

fn fmt_ms(v: f64) -> String {
    if v.is_nan() {
        "-".to_string()
    } else {
        format!("{v:.3}")
    }
}

/// Run one workload through the engine as a discrete-event loop: admit
/// every arrival that is due at the current virtual time (one hoisted
/// clock read per admission burst), then poll; when the device is idle,
/// jump the clock to the next arrival.  Device service advances the
/// clock inside `poll` (DevicePaced), so offered load above capacity
/// piles arrivals into the admission bursts — exactly where bounded
/// queue depths shed.  Deadline-only closings between arrivals are
/// handled by the final flush (the arrival spacing here is much finer
/// than the budgets, so the distortion is nil).
fn drive(
    engine: &Engine<'_>,
    workload: &Workload,
    images: &[Vec<BitVec>],
) -> (usize, Vec<Rejected>) {
    let clock = engine.clock();
    let mut served = 0usize;
    let mut rejections = Vec::new();
    let mut i = 0;
    while i < workload.arrivals.len() {
        if workload.arrivals[i].at > clock.now() {
            clock.advance_to(workload.arrivals[i].at);
        }
        let now = clock.now();
        while i < workload.arrivals.len() && workload.arrivals[i].at <= now {
            let a = &workload.arrivals[i];
            let img = images[a.tenant][(a.user % IMAGE_POOL as u64) as usize].clone();
            match engine.submit_at(a.tenant, img, None, now) {
                Ok(_) => {}
                Err(r) => rejections.push(r),
            }
            i += 1;
        }
        served += engine.poll().len();
    }
    served += engine.flush().len();
    (served, rejections)
}

fn image_pool(n_in: usize, rng: &mut Rng) -> Vec<BitVec> {
    (0..IMAGE_POOL).map(|_| synth_bits(n_in, rng)).collect()
}

fn main() {
    let t0 = Timer::start();
    let quick = quick_mode();
    let opts = PipelineOptions {
        noise: NoiseMode::Nominal,
        ..Default::default()
    };
    let policy = BatchPolicy {
        max_batch: 16,
        max_wait: Duration::from_millis(1),
    };
    let mut rng = Rng::new(0x5E4E, 1);
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut table = Table::new(
        "serving: open-loop virtual-time scenarios",
        &[
            "scenario",
            "tenant",
            "class",
            "offered/s",
            "goodput/s",
            "p50 ms",
            "p99 ms",
            "p999 ms",
            "shed %",
        ],
    );

    // small synthetic models keep the host-side classify cost trivial;
    // the *device* pacing comes from the pool's own cycle model
    let model_a = synth_model(21, 0x5E4E, &[(32, 64, 512), (10, 32, 512)]);
    let model_b = synth_model(22, 0x5E4E, &[(24, 64, 512), (6, 24, 512)]);
    let macros_a = MacroPool::macros_required(&model_a, &opts);
    let macros_b = MacroPool::macros_required(&model_b, &opts);
    let imgs_a = image_pool(64, &mut rng);
    let imgs_b = image_pool(64, &mut rng);

    // ---- scenario 1: steady Poisson at half capacity, single tenant ----
    {
        let engine =
            Engine::single(&model_a, opts, policy, macros_a).with_clock(Clock::simulated());
        let pacing = engine.calibrate_device_pacing(&[imgs_a.clone()]);
        let ServiceModel::DevicePaced(ref per_image) = pacing else {
            unreachable!("calibration returns DevicePaced");
        };
        let capacity = 1.0 / per_image[0].as_secs_f64();
        let engine = engine.with_service(pacing.clone());
        engine.reset_latency_metrics(0);

        let n_arrivals = if quick { 400 } else { 8_000 };
        let rate = capacity * 0.5;
        let horizon = Duration::from_secs_f64(n_arrivals as f64 / rate);
        let wl = Workload::generate(
            &ArrivalProcess::Poisson { rate },
            horizon,
            1_000_000,
            &[],
            0xA11A,
        );
        let start = engine.clock().now();
        let (served, rejections) = drive(&engine, &wl, &[imgs_a.clone()]);
        let window_s = (engine.clock().now() - start).as_secs_f64();
        assert!(rejections.is_empty(), "unbounded lane must not shed");
        assert_eq!(served, wl.len(), "every arrival served");
        let m = engine.lane_metrics(0);
        let goodput = m.goodput(window_s);
        assert!(
            m.p99_ms().is_finite() && m.p999_ms() >= m.p99_ms() && m.p99_ms() >= m.p50_ms(),
            "percentiles must be finite and ordered"
        );
        table.row(vec![
            "steady".into(),
            "0".into(),
            "guaranteed".into(),
            format!("{:.0}", wl.offered_rate(horizon)),
            format!("{goodput:.0}"),
            fmt_ms(m.p50_ms()),
            fmt_ms(m.p99_ms()),
            fmt_ms(m.p999_ms()),
            format!("{:.1}", m.shed_rate() * 100.0),
        ]);
        records.push(BenchRecord::new(
            "serving steady poisson [goodput inf/s]",
            1e9 / goodput,
            Some(goodput),
        ));
        for (name, value) in [
            ("serving steady poisson [p50 ms]", m.p50_ms() * 1e6),
            ("serving steady poisson [p99 ms]", m.p99_ms() * 1e6),
            ("serving steady poisson [p999 ms]", m.p999_ms() * 1e6),
            ("serving steady poisson [shed rate]", m.shed_rate()),
        ] {
            records.push(BenchRecord::new(name, value, None));
        }
    }

    // ---- scenario 2: bursty overload, guaranteed vs best-effort ----
    {
        let budget = macros_a + macros_b;
        let engine = Engine::multi(&[&model_a, &model_b], opts, policy, budget, &[])
            .with_clock(Clock::simulated())
            .with_admission(
                0,
                AdmissionPolicy {
                    class: QosClass::Guaranteed,
                    max_depth: usize::MAX,
                },
            )
            .with_admission(
                1,
                AdmissionPolicy {
                    class: QosClass::BestEffort,
                    max_depth: 4 * policy.max_batch,
                },
            );
        let pacing = engine.calibrate_device_pacing(&[imgs_a.clone(), imgs_b.clone()]);
        let ServiceModel::DevicePaced(ref per_image) = pacing else {
            unreachable!("calibration returns DevicePaced");
        };
        // aggregate capacity bound: the slower tenant's service rate
        let capacity = 1.0 / per_image[0].max(per_image[1]).as_secs_f64();
        let engine = engine.with_service(pacing.clone());
        engine.reset_latency_metrics(0);
        engine.reset_latency_metrics(1);

        // tenant 0 (guaranteed) gets 25% of the trace: ~0.5x capacity
        // even at the burst peak; tenant 1 (best-effort) takes the rest
        // and overloads the device during bursts
        let n_arrivals = if quick { 800 } else { 16_000 };
        let burst = capacity * 2.0;
        let base = capacity * 0.4;
        let mean_rate = burst * 0.25 + base * 0.75;
        let horizon = Duration::from_secs_f64(n_arrivals as f64 / mean_rate);
        let period = Duration::from_secs_f64(horizon.as_secs_f64() / 8.0);
        let wl = Workload::generate(
            &ArrivalProcess::Bursty {
                base,
                burst,
                period,
                duty: 0.25,
            },
            horizon,
            1_000_000,
            &[0.25, 0.75],
            0xB0B5,
        );
        let start = engine.clock().now();
        let (served, rejections) = drive(&engine, &wl, &[imgs_a.clone(), imgs_b.clone()]);
        let window_s = (engine.clock().now() - start).as_secs_f64();

        // the acceptance criteria: overload sheds best-effort only, with
        // typed QueueFull rejections, and the guaranteed class keeps a
        // bounded p99
        assert!(
            !rejections.is_empty(),
            "offered load above capacity must shed the bounded lane"
        );
        for r in &rejections {
            assert_eq!(r.tenant, 1, "only the best-effort lane may shed");
            assert!(
                matches!(r.reason, RejectReason::QueueFull { .. }),
                "sheds carry the typed queue-full reason, got {:?}",
                r.reason
            );
        }
        let mg = engine.lane_metrics(0);
        let mb = engine.lane_metrics(1);
        assert_eq!(mg.shed, 0, "guaranteed lane admitted everything");
        assert_eq!(mb.shed, rejections.len() as u64);
        assert_eq!(
            served as u64 + mb.shed,
            wl.len() as u64,
            "every arrival either served or typed-rejected"
        );
        // guaranteed p99 bound: deadline wait (its full default budget)
        // plus a generous multiple of batch service time
        let batch_service_ms = per_image[0].as_secs_f64() * 1e3 * policy.max_batch as f64;
        let bound_ms = policy.default_budget().as_secs_f64() * 1e3 + 32.0 * batch_service_ms;
        assert!(
            mg.p99_ms() <= bound_ms,
            "guaranteed p99 {:.3} ms blew the {bound_ms:.3} ms bound",
            mg.p99_ms()
        );
        assert!(
            mb.p99_ms() > mg.p99_ms(),
            "overload must land on the best-effort lane (be p99 {:.3} vs g p99 {:.3})",
            mb.p99_ms(),
            mg.p99_ms()
        );
        for (t, class, m) in [(0usize, "guaranteed", &mg), (1, "best-effort", &mb)] {
            let offered = (m.admitted + m.shed) as f64 / window_s;
            table.row(vec![
                "overload".into(),
                t.to_string(),
                class.into(),
                format!("{offered:.0}"),
                format!("{:.0}", m.goodput(window_s)),
                fmt_ms(m.p50_ms()),
                fmt_ms(m.p99_ms()),
                fmt_ms(m.p999_ms()),
                format!("{:.1}", m.shed_rate() * 100.0),
            ]);
            records.push(BenchRecord::new(
                &format!("serving overload {class} [p99 ms]"),
                m.p99_ms() * 1e6,
                None,
            ));
            records.push(BenchRecord::new(
                &format!("serving overload {class} [shed rate]"),
                m.shed_rate(),
                None,
            ));
        }
    }

    // ---- scenario 3: diurnal day over a 3M-user population ----
    {
        let engine =
            Engine::single(&model_a, opts, policy, macros_a).with_clock(Clock::simulated());
        let pacing = engine.calibrate_device_pacing(&[imgs_a.clone()]);
        let ServiceModel::DevicePaced(ref per_image) = pacing else {
            unreachable!("calibration returns DevicePaced");
        };
        let capacity = 1.0 / per_image[0].as_secs_f64();
        let engine = engine.with_service(pacing.clone());
        engine.reset_latency_metrics(0);

        let n_arrivals = if quick { 400 } else { 8_000 };
        let mean_rate = capacity * 0.45; // mid between trough and peak
        let horizon = Duration::from_secs_f64(n_arrivals as f64 / mean_rate);
        let wl = Workload::generate(
            &ArrivalProcess::Diurnal {
                trough: capacity * 0.1,
                peak: capacity * 0.8,
                day: horizon,
            },
            horizon,
            3_000_000,
            &[],
            0xD1A1,
        );
        let start = engine.clock().now();
        let (served, rejections) = drive(&engine, &wl, &[imgs_a.clone()]);
        let window_s = (engine.clock().now() - start).as_secs_f64();
        assert!(rejections.is_empty(), "under-capacity day must not shed");
        assert_eq!(served, wl.len());
        let m = engine.lane_metrics(0);
        table.row(vec![
            "diurnal".into(),
            "0".into(),
            "guaranteed".into(),
            format!("{:.0}", wl.offered_rate(horizon)),
            format!("{:.0}", m.goodput(window_s)),
            fmt_ms(m.p50_ms()),
            fmt_ms(m.p99_ms()),
            fmt_ms(m.p999_ms()),
            format!("{:.1}", m.shed_rate() * 100.0),
        ]);
        records.push(BenchRecord::new(
            "serving diurnal [goodput inf/s]",
            1e9 / m.goodput(window_s),
            Some(m.goodput(window_s)),
        ));
        records.push(BenchRecord::new("serving diurnal [p99 ms]", m.p99_ms() * 1e6, None));
    }

    table.print();

    // gate before emit_json overwrites the committed baseline; quick runs
    // write a separate artifact (same protocol as the hotpath bench)
    let baseline_path = bench_artifact_path("BENCH_serving.json");
    let regressions = compare_baseline(&baseline_path, &records, &BASELINE_GATED, 0.2);
    let out_path = if quick {
        bench_artifact_path("BENCH_serving_quick.json")
    } else {
        baseline_path
    };
    emit_json(&out_path, &records).expect("write serving bench artifact");
    if !quick {
        assert!(
            regressions.is_empty(),
            "serving goodput regressed >20% vs the committed baseline:\n{}",
            regressions.join("\n")
        );
    }
    println!("\n[serving done in {:.1}s]", t0.elapsed_s());
}
