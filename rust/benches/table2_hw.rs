//! Experiment T2 — regenerate paper Table II: throughput, power, energy
//! efficiency, and area from event-level accounting of the full Algorithm-1
//! workload on the analog simulator (batched, as measured on silicon).

use picbnn::accel::{Pipeline, PipelineOptions};
use picbnn::benchkit::Table;
use picbnn::bnn::model::MappedModel;
use picbnn::data::TestSet;
use picbnn::energy;
use picbnn::util::Timer;

fn main() {
    let t = Timer::start();
    let dir = picbnn::artifacts_dir();
    let mut table = Table::new(
        "T2: hardware parameters (batch 256, full Algorithm-1 schedule)",
        &["metric", "mnist", "hg", "paper (mnist)"],
    );
    let mut cols: Vec<Vec<String>> = Vec::new();
    for name in ["mnist", "hg"] {
        let Ok(model) = MappedModel::load(dir.join(format!("{name}_weights.bin"))) else {
            println!("skipping {name}: artifacts not built");
            return;
        };
        let test = TestSet::load(dir.join(format!("{name}_test.bin"))).expect("test set");
        let n = 1024.min(test.len());
        let mut pipe = Pipeline::new(&model, PipelineOptions::default());
        for chunk in test.images[..n].chunks(256) {
            pipe.classify_batch(chunk);
        }
        let stats = pipe.take_stats(n as u64);
        let r = energy::report(&stats);
        cols.push(vec![
            format!("{:.0}", r.inf_per_s),
            format!("{:.3}", r.power_w * 1e3),
            format!("{:.0}", r.inf_per_s_per_w / 1e6),
            format!("{:.0}", r.ops_per_w / 1e12),
            format!("{:.1}", r.cycles_per_inference),
            format!("{:.2}", r.macro_area_mm2),
            format!("{:.2}", r.soc_area_mm2),
            format!("{:.1}", 1e9 * r.energy.total() / r.inferences as f64),
        ]);
    }
    let rows = [
        ("throughput (inf/s)", "560000"),
        ("power (mW)", "0.8"),
        ("efficiency (M inf/s/W)", "703"),
        ("efficiency (TOPS/W)", "184 ('TOPs/s')"),
        ("cycles / inference", "~44.6"),
        ("macro area (mm²)", "0.87"),
        ("SoC area (mm²)", "2.38"),
        ("energy / inference (nJ)", "~1.43"),
    ];
    for (i, (metric, paper)) in rows.iter().enumerate() {
        table.row(vec![
            metric.to_string(),
            cols[0][i].clone(),
            cols[1][i].clone(),
            paper.to_string(),
        ]);
    }
    table.print();
    println!("\nHG is slower than MNIST because its input layer needs 6 weight");
    println!("reloads/batch (384 rows of 2048 bits vs 64 resident) + 32 I/O cycles");
    println!("per 4096-bit image; the paper reports MNIST-only throughput.");
    println!("\n[table2_hw done in {:.1}s]", t.elapsed_s());
}
