//! Hot-path microbenchmarks for the §Perf optimisation loop: packed
//! Hamming distance, array search, row programming, vote accumulation,
//! and the end-to-end per-image cost on both models.

use picbnn::accel::{Pipeline, PipelineOptions};
use picbnn::benchkit::{bench, black_box};
use picbnn::bnn::model::MappedModel;
use picbnn::cam::{CamArray, CamConfig};
use picbnn::data::TestSet;
use picbnn::util::bitops::{hamming_words, BitMatrix, BitVec};
use picbnn::util::rng::Rng;

fn rand_bits(n: usize, rng: &mut Rng) -> BitVec {
    let mut v = BitVec::zeros(n);
    for i in 0..n {
        v.set(i, rng.chance(0.5));
    }
    v
}

fn main() {
    let mut rng = Rng::new(1, 1);

    // packed hamming over one 1024-bit row
    let a = rand_bits(1024, &mut rng);
    let b = rand_bits(1024, &mut rng);
    let r = bench("hamming_1024b_single_row", || {
        black_box(hamming_words(black_box(a.words()), black_box(b.words())));
    });
    println!(
        "  -> {:.2} G row-bits/s",
        r.throughput(1024.0) / 1e9
    );

    // full-matrix hamming (128 rows of 1024)
    let rows: Vec<BitVec> = (0..128).map(|_| rand_bits(1024, &mut rng)).collect();
    let m = BitMatrix::from_rows(&rows);
    let q = rand_bits(1024, &mut rng);
    let mut out = Vec::new();
    let r = bench("hamming_all_128x1024", || {
        m.hamming_all(black_box(&q), &mut out);
        black_box(&out);
    });
    println!("  -> {:.2} M row-searches/s", r.throughput(128.0) / 1e6);

    // array search (nominal + analog)
    for (label, mut cam) in [
        ("search_1024x128_nominal", CamArray::nominal(CamConfig::W1024x128)),
        ("search_1024x128_analog", CamArray::analog(CamConfig::W1024x128, 7)),
    ] {
        for row in 0..128 {
            let data = rand_bits(1024, &mut rng);
            cam.write_row(row, &data);
        }
        cam.set_voltages(picbnn::analog::Voltages::new(0.75, 0.5, 1.0));
        let q = rand_bits(1024, &mut rng);
        let (mut mm, mut ff) = (Vec::new(), Vec::new());
        let r = bench(label, || {
            cam.search_into(black_box(&q), &mut mm, &mut ff);
            black_box(&ff);
        });
        println!("  -> {:.2} M row-evals/s", r.throughput(128.0) / 1e6);
    }

    // row programming
    {
        let mut cam = CamArray::analog(CamConfig::W1024x128, 9);
        let data = rand_bits(1024, &mut rng);
        let mut row = 0usize;
        bench("write_row_1024b", || {
            cam.write_row(black_box(row), black_box(&data));
            row = (row + 1) % 128;
        });
    }

    // end-to-end per-image (batch-256 amortised)
    let dir = picbnn::artifacts_dir();
    for name in ["mnist", "hg"] {
        let Ok(model) = MappedModel::load(dir.join(format!("{name}_weights.bin"))) else {
            println!("skipping {name} e2e micro: artifacts not built");
            continue;
        };
        let test = TestSet::load(dir.join(format!("{name}_test.bin"))).expect("test set");
        let mut pipe = Pipeline::new(&model, PipelineOptions::default());
        let imgs: Vec<BitVec> = test.images[..256.min(test.len())].to_vec();
        let r = bench(&format!("pipeline_batch256_{name}"), || {
            black_box(pipe.classify_batch(black_box(&imgs)));
        });
        println!(
            "  -> {:.0} host images/s (simulator speed, not device speed)",
            r.throughput(imgs.len() as f64)
        );
    }
}
