//! Hot-path microbenchmarks for the §Perf optimisation loop: packed
//! Hamming distance (single-query and query-batched, per popcount
//! backend), array search (sequential and batched, both noise modes),
//! row programming, vote accumulation, and the end-to-end per-image cost
//! on both models.
//!
//! Results are persisted to `BENCH_hotpath.json` at the repo root
//! (`benchkit::emit_json`; every record carries the active Hamming
//! backend) so later PRs can diff the perf trajectory — and in full mode
//! this run *gates* on it: the batched search cases fail if their
//! throughput regressed more than 20% against the committed baseline,
//! and the dispatched backend must not lose to the scalar reference on
//! the batched kernel.  Under `PICBNN_BENCH_QUICK=1` (CI — including
//! non-AVX2 runners, where dispatch falls back to SWAR) every bench runs
//! single-iteration smoke samples and the artifact goes to
//! `BENCH_hotpath_quick.json` instead, so a smoke run can never replace
//! the committed full-mode baseline; the batched-vs-sequential parity
//! checks still run, so a kernel regression that panics or mis-shapes
//! output fails the pipeline.

use picbnn::accel::{Pipeline, PipelineOptions};
use picbnn::benchkit::{
    bench, bench_artifact_path, black_box, compare_baseline, emit_json, quick_mode, BenchRecord,
};
use picbnn::bnn::model::MappedModel;
use picbnn::cam::{CamArray, CamConfig, NoiseMode};
use picbnn::data::TestSet;
use picbnn::util::bitops::{
    active_backend, available_backends, hamming_words, BitMatrix, BitVec, HammingBackend,
};
use picbnn::util::rng::Rng;

fn rand_bits(n: usize, rng: &mut Rng) -> BitVec {
    let mut v = BitVec::zeros(n);
    for i in 0..n {
        v.set(i, rng.chance(0.5));
    }
    v
}

/// A fully programmed 1024x128 array at the metastable-band probe point.
fn probe_array(noise: NoiseMode, seed: u64) -> CamArray {
    let mut cam = match noise {
        NoiseMode::Nominal => CamArray::nominal(CamConfig::W1024x128),
        NoiseMode::Analog => CamArray::analog(CamConfig::W1024x128, seed),
    };
    let mut rng = Rng::new(seed ^ 0xDA7A, 2);
    for row in 0..128 {
        cam.write_row(row, &rand_bits(1024, &mut rng));
    }
    cam.set_voltages(picbnn::analog::Voltages::new(0.75, 0.5, 1.0));
    cam
}

/// Batched vs sequential parity on twin arrays: mismatches, fires, and
/// per-query RNG stream positions must be bit-identical (the kernel's
/// draw-order contract; this is the CI smoke check, not a timing).
fn check_batch_parity(noise: NoiseMode, queries: &[BitVec]) {
    let mut seq = probe_array(noise, 77);
    let mut bat = probe_array(noise, 77);
    let mut rngs_a: Vec<Rng> = (0..queries.len() as u64).map(|i| Rng::new(13, i)).collect();
    let mut rngs_b = rngs_a.clone();
    let (mut sm, mut sf) = (Vec::new(), Vec::new());
    let (mut seq_m, mut seq_f) = (Vec::new(), Vec::new());
    for (i, q) in queries.iter().enumerate() {
        seq.search_into_rng(q, &mut sm, &mut sf, &mut rngs_a[i]);
        seq_m.extend_from_slice(&sm);
        seq_f.push(sf.clone());
    }
    let (mut bm, mut bf) = (Vec::new(), BitMatrix::default());
    bat.search_batch_into_rngs(queries, &mut rngs_b, &mut bm, &mut bf);
    assert_eq!(bm, seq_m, "{noise:?}: batched mismatch counts diverged");
    for (i, f) in seq_f.iter().enumerate() {
        for r in 0..128 {
            assert_eq!(bf.get(i, r), f[r], "{noise:?}: fires q{i} r{r}");
        }
    }
    for (i, (ra, rb)) in rngs_a.iter().zip(&rngs_b).enumerate() {
        assert_eq!(
            format!("{ra:?}"),
            format!("{rb:?}"),
            "{noise:?}: rng stream {i} position diverged"
        );
    }
    assert_eq!(seq.clock.cycles, bat.clock.cycles, "{noise:?}: cycles");
    assert_eq!(seq.events, bat.events, "{noise:?}: event accounting");
}

/// The batched-search acceptance cases gated against the committed
/// `BENCH_hotpath.json` baseline in full mode.
const BASELINE_GATED: [&str; 2] = [
    "search_batch64_1024x128_nominal",
    "search_batch64_1024x128_analog",
];

fn main() {
    let mut rng = Rng::new(1, 1);
    let mut records: Vec<BenchRecord> = Vec::new();
    println!(
        "hamming backend: {} (force with PICBNN_FORCE_BACKEND=scalar|swar|avx2)",
        active_backend().name()
    );

    // packed hamming over one 1024-bit row
    let a = rand_bits(1024, &mut rng);
    let b = rand_bits(1024, &mut rng);
    let r = bench("hamming_1024b_single_row", || {
        black_box(hamming_words(black_box(a.words()), black_box(b.words())));
    });
    println!("  -> {:.2} G row-bits/s", r.throughput(1024.0) / 1e9);
    records.push(r.record(Some(1024.0)));

    // full-matrix hamming: one query vs the register-tiled batch kernel
    let rows: Vec<BitVec> = (0..128).map(|_| rand_bits(1024, &mut rng)).collect();
    let m = BitMatrix::from_rows(&rows);
    let q = rand_bits(1024, &mut rng);
    let mut out = Vec::new();
    let r = bench("hamming_all_128x1024", || {
        m.hamming_all(black_box(&q), &mut out);
        black_box(&out);
    });
    println!("  -> {:.2} M row-searches/s", r.throughput(128.0) / 1e6);
    records.push(r.record(Some(128.0)));

    let queries64: Vec<BitVec> = (0..64).map(|_| rand_bits(1024, &mut rng)).collect();
    let r = bench("hamming_all_batch64_128x1024", || {
        m.hamming_all_batch(black_box(&queries64), &mut out);
        black_box(&out);
    });
    println!(
        "  -> {:.2} M row-searches/s (query-batched, dispatched)",
        r.throughput(64.0 * 128.0) / 1e6
    );
    records.push(r.record(Some(64.0 * 128.0)));

    // per-backend A/B on the same batched kernel (the only backend-
    // dependent stage of the search path): parity against scalar, then a
    // timing per runnable backend.  Full mode asserts the dispatched
    // backend does not lose to the scalar reference.
    let mut backend_rate = std::collections::BTreeMap::new();
    let mut scalar_out = Vec::new();
    m.hamming_all_batch_with(HammingBackend::Scalar, &queries64, &mut scalar_out);
    for backend in available_backends() {
        let mut check = Vec::new();
        m.hamming_all_batch_with(backend, &queries64, &mut check);
        assert_eq!(check, scalar_out, "{backend:?} diverged from scalar");
        let label = format!("hamming_batch64_128x1024_{}", backend.name());
        let r = bench(&label, || {
            m.hamming_all_batch_with(backend, black_box(&queries64), &mut out);
            black_box(&out);
        });
        println!(
            "  -> {:.2} M row-searches/s ({})",
            r.throughput(64.0 * 128.0) / 1e6,
            backend.name()
        );
        backend_rate.insert(backend.name(), r.throughput(64.0 * 128.0));
        // this record timed an explicit backend, not the dispatched one —
        // persist the backend actually benchmarked
        let mut rec = r.record(Some(64.0 * 128.0));
        rec.backend = backend.name();
        records.push(rec);
    }

    // array search, sequential baseline (nominal + analog)
    let mut single_rate = std::collections::BTreeMap::new();
    for (label, noise) in [
        ("search_1024x128_nominal", NoiseMode::Nominal),
        ("search_1024x128_analog", NoiseMode::Analog),
    ] {
        let mut cam = probe_array(noise, 7);
        let q = rand_bits(1024, &mut rng);
        let (mut mm, mut ff) = (Vec::new(), Vec::new());
        let r = bench(label, || {
            cam.search_into(black_box(&q), &mut mm, &mut ff);
            black_box(&ff);
        });
        println!("  -> {:.2} M row-evals/s", r.throughput(128.0) / 1e6);
        single_rate.insert(noise as usize, r.throughput(128.0));
        records.push(r.record(Some(128.0)));
    }

    // the batched kernel (acceptance variants): 64 queries per device
    // batch, per-image noise streams, packed fires.  Speedup asserts are
    // deferred until after emit_json so a below-threshold run still
    // persists its measurements.
    let mut speedups: Vec<(&str, f64)> = Vec::new();
    for (label, noise) in [
        ("search_batch64_1024x128_nominal", NoiseMode::Nominal),
        ("search_batch64_1024x128_analog", NoiseMode::Analog),
    ] {
        check_batch_parity(noise, &queries64[..16]);
        let mut cam = probe_array(noise, 7);
        let mut rngs: Vec<Rng> = (0..64u64).map(|i| Rng::new(0xBA7C, i)).collect();
        let (mut mm, mut ff) = (Vec::new(), BitMatrix::default());
        // warm the threshold cache so quick mode's first sample is honest
        cam.search_batch_into_rngs(&queries64, &mut rngs, &mut mm, &mut ff);
        let r = bench(label, || {
            cam.search_batch_into_rngs(black_box(&queries64), &mut rngs, &mut mm, &mut ff);
            black_box(&ff);
        });
        let rate = r.throughput(64.0 * 128.0);
        let speedup = rate / single_rate[&(noise as usize)];
        println!(
            "  -> {:.2} M row-evals/s ({speedup:.1}x vs single-query)",
            rate / 1e6
        );
        records.push(r.record(Some(64.0 * 128.0)));
        speedups.push((label, speedup));
    }

    // row programming
    {
        let mut cam = CamArray::analog(CamConfig::W1024x128, 9);
        let data = rand_bits(1024, &mut rng);
        let mut row = 0usize;
        let r = bench("write_row_1024b", || {
            cam.write_row(black_box(row), black_box(&data));
            row = (row + 1) % 128;
        });
        records.push(r.record(None));
    }

    // end-to-end per-image (batch-256 amortised)
    let dir = picbnn::artifacts_dir();
    for name in ["mnist", "hg"] {
        let Ok(model) = MappedModel::load(dir.join(format!("{name}_weights.bin"))) else {
            println!("skipping {name} e2e micro: artifacts not built");
            continue;
        };
        let test = TestSet::load(dir.join(format!("{name}_test.bin"))).expect("test set");
        let mut pipe = Pipeline::new(&model, PipelineOptions::default());
        let imgs: Vec<BitVec> = test.images[..256.min(test.len())].to_vec();
        let r = bench(&format!("pipeline_batch256_{name}"), || {
            black_box(pipe.classify_batch(black_box(&imgs)));
        });
        println!(
            "  -> {:.0} host images/s (simulator speed, not device speed)",
            r.throughput(imgs.len() as f64)
        );
        records.push(r.record(Some(imgs.len() as f64)));
    }

    // regression gate input: read the *committed* baseline before
    // emit_json overwrites it with this run's records.  Quick-mode runs
    // write to a separate artifact so a CI / local smoke run can never
    // replace the committed full-mode baseline with single-iteration
    // samples (which compare_baseline would then skip, silently
    // disarming the gate for every later full run).
    let baseline_path = bench_artifact_path("BENCH_hotpath.json");
    let regressions = compare_baseline(&baseline_path, &records, &BASELINE_GATED, 0.2);
    let out_path = if quick_mode() {
        bench_artifact_path("BENCH_hotpath_quick.json")
    } else {
        baseline_path
    };
    emit_json(&out_path, &records).expect("write hotpath bench artifact");

    // acceptance gates, after the artifact is safely on disk; quick
    // mode's single-iteration timings are too noisy to gate on
    if !quick_mode() {
        for (label, speedup) in &speedups {
            assert!(
                *speedup >= 2.0,
                "{label}: batched kernel must be >= 2x the single-query \
                 baseline, got {speedup:.2}x"
            );
        }
        // the dispatched backend must be at least as fast as the scalar
        // reference on the batched kernel (small tolerance for timing
        // noise when the dispatched backend *is* scalar)
        let scalar = backend_rate["scalar"];
        let dispatched = backend_rate[active_backend().name()];
        assert!(
            dispatched >= scalar * 0.9,
            "dispatched backend {} ({dispatched:.3e}/s) lost to scalar ({scalar:.3e}/s)",
            active_backend().name()
        );
        assert!(
            regressions.is_empty(),
            "batched throughput regressed >20% vs the committed baseline:\n{}",
            regressions.join("\n")
        );
    }
}
