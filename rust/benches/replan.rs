//! Re-planning convergence bench: a sustained skew flip on a resident
//! pool, steered by the online `ReplanController` in the inter-batch
//! gaps (the engine's maintenance seam drives the same loop in serving).
//!
//! Scenario: a model whose output schedule has one dominant operating
//! point plus a distinct tail, pooled at a budget where the pinned set
//! genuinely matters.  The uniform-traffic plan pins the dominant class;
//! the measured traffic then flips onto three tail points, so the static
//! plan's shared funnel keeps cycling (retune stalls every batch) until
//! the controller re-plans and live-migrates the pins onto the hot band.
//!
//! Measured phases, all in deterministic device-cycle accounting:
//!  * static    — retunes/batch of the pre-flip placement serving the
//!                flipped skew (the cost of never re-planning).
//!  * converged — retunes/batch after the controller's migration lands.
//!  * payback   — one-shot migration cost (row writes + re-park retunes)
//!                against the measured per-batch saving: must repay
//!                within the controller's own cost horizon.
//!
//! The bench asserts the PR's acceptance criteria — strictly lower
//! steady-state retunes/batch than the static plan and payback within
//! the horizon — and writes `BENCH_replan.json` (quick mode writes
//! `BENCH_replan_quick.json` so a smoke run never replaces the committed
//! baseline).  CI runs it under `PICBNN_BENCH_QUICK=1`, including a
//! forced-scalar lane (the numbers are backend-independent by design).

use picbnn::accel::{MacroPool, MigrationStats, PipelineOptions, ReplanConfig, ReplanController};
use picbnn::benchkit::{
    bench_artifact_path, emit_json, quick_mode, synth_bits, synth_model, BenchRecord, Table,
};
use picbnn::cam::NoiseMode;
use picbnn::util::bitops::BitVec;
use picbnn::util::rng::Rng;
use picbnn::util::Timer;

/// Serve `batches` position-restricted batches and return the retune
/// stalls per batch the device actually paid (drained counters, so each
/// window starts clean).
fn measure_retunes(
    pool: &MacroPool<'_>,
    images: &[BitVec],
    band: &[usize],
    base: &mut u64,
    batches: u64,
) -> f64 {
    pool.take_stats(0);
    for _ in 0..batches {
        pool.classify_batch_positions(images, *base, band);
        *base += images.len() as u64;
    }
    pool.take_stats(0).events.retunes as f64 / batches as f64
}

fn main() {
    let t0 = Timer::start();
    let quick = quick_mode();
    let opts = PipelineOptions {
        noise: NoiseMode::Nominal,
        ..Default::default()
    };
    // the replan fixture shape: 8 hidden neurons / 3 classes on 64-bit
    // inputs, with a schedule of one 8-position dominant class plus four
    // distinct tail points — at a 4-macro budget the pinned set matters
    let mut model = synth_model(44, 0x5E4E, &[(8, 64, 512), (3, 8, 512)]);
    model.schedule = vec![0, 0, 0, 0, 0, 0, 0, 0, 8, 16, 24, 32];
    let budget = 4usize;
    let pool = MacroPool::with_capacity(&model, opts, budget);
    assert!(pool.plan().is_some(), "bench pool must be resident");
    let before_plan = pool.plan().unwrap();

    let per_batch = if quick { 8 } else { 32 };
    let window = if quick { 8u64 } else { 64 };
    let mut rng = Rng::new(0x5E4E, 7);
    let images: Vec<BitVec> = (0..per_batch).map(|_| synth_bits(64, &mut rng)).collect();
    // the flipped skew: sustained banded traffic on three tail points
    // the uniform-traffic incumbent mostly left unpinned
    let band = [8usize, 9, 10];
    let mut base = 0u64;

    // phase 1: the static plan pays for the flip every batch
    let retunes_static = measure_retunes(&pool, &images, &band, &mut base, window);

    // phase 2: the control loop reacts — maintain once per inter-batch
    // gap until the migration it admits has fully landed
    let cfg = ReplanConfig {
        period: 2,
        decay: 0.5,
        ..ReplanConfig::default()
    };
    let mut ctl = ReplanController::new(&pool, budget, cfg);
    let mut spent = MigrationStats::default();
    let mut rounds = 0u64;
    while ctl.migrations_started == 0 || ctl.migration_in_flight() {
        pool.classify_batch_positions(&images, base, &band);
        base += images.len() as u64;
        spent.add(&ctl.maintain(&pool));
        rounds += 1;
        assert!(rounds < 400, "controller failed to converge on the flip");
    }
    assert_ne!(
        pool.plan().unwrap().pin_slot,
        before_plan.pin_slot,
        "the migration must move the pinned set"
    );

    // phase 3: steady state after the migration landed
    let retunes_converged = measure_retunes(&pool, &images, &band, &mut base, window);

    // acceptance: strictly fewer retune stalls than the static plan, and
    // the one-shot migration cost repaid within the controller's horizon
    assert!(
        retunes_converged < retunes_static,
        "converged placement must beat the static plan \
         ({retunes_converged:.2} vs {retunes_static:.2} retunes/batch)"
    );
    let saved_cycles_per_batch =
        (retunes_static - retunes_converged) * cfg.cycles_per_retune as f64;
    let payback_batches = spent.programming_cycles() as f64 / saved_cycles_per_batch;
    assert!(
        payback_batches <= cfg.horizon_batches as f64,
        "migration cost {} cycles never repays within {} batches",
        spent.programming_cycles(),
        cfg.horizon_batches
    );

    let mut table = Table::new(
        "replan: skew-flip convergence (device-cycle accounting)",
        &["phase", "retunes/batch", "steps", "row writes", "payback batches"],
    );
    table.row(vec![
        "static".into(),
        format!("{retunes_static:.2}"),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    table.row(vec![
        "converged".into(),
        format!("{retunes_converged:.2}"),
        spent.steps.to_string(),
        spent.row_writes.to_string(),
        format!("{payback_batches:.2}"),
    ]);
    table.print();

    let records = vec![
        BenchRecord::new("replan skew-flip [retunes/batch static]", retunes_static, None),
        BenchRecord::new("replan skew-flip [retunes/batch converged]", retunes_converged, None),
        BenchRecord::new("replan skew-flip [rounds to converge]", rounds as f64, None),
        BenchRecord::new("replan skew-flip [migration steps]", spent.steps as f64, None),
        BenchRecord::new("replan skew-flip [migration row writes]", spent.row_writes as f64, None),
        BenchRecord::new("replan skew-flip [migration retunes]", spent.retunes as f64, None),
        BenchRecord::new("replan skew-flip [payback batches]", payback_batches, None),
    ];
    let out_path = if quick {
        bench_artifact_path("BENCH_replan_quick.json")
    } else {
        bench_artifact_path("BENCH_replan.json")
    };
    emit_json(&out_path, &records).expect("write replan bench artifact");
    println!("\n[replan done in {:.1}s]", t0.elapsed_s());
}
