//! Ablation of the paper's FIRST core idea (the law of large numbers):
//! "if the fully connected layer is executed multiple times under
//! (slightly) different conditions, the average of the target class
//! output will converge" — so on a *noisier* device, more executions
//! should recover more accuracy.
//!
//! Sweep: per-evaluation noise scale × number of output-layer executions.
//! Expected shape: at 1× noise the curve saturates early; as noise grows,
//! few-execution accuracy collapses while the 33-execution majority keeps
//! recovering most of it — the quantitative content of the LLN claim.

use picbnn::accel::{evaluate, Pipeline, PipelineOptions};
use picbnn::benchkit::Table;
use picbnn::bnn::model::MappedModel;
use picbnn::data::TestSet;
use picbnn::util::Timer;

fn main() {
    let t = Timer::start();
    let dir = picbnn::artifacts_dir();
    let Ok(model) = MappedModel::load(dir.join("mnist_weights.bin")) else {
        println!("skipping: artifacts not built");
        return;
    };
    let test = TestSet::load(dir.join("mnist_test.bin")).expect("test set");
    let n = 1000.min(test.len());

    let scales = [1.0f64, 4.0, 8.0, 16.0, 32.0];
    let execs = [9usize, 17, 25, 33];
    let mut table = Table::new(
        "LLN ablation: TOP-1 vs noise scale × output-layer executions (MNIST)",
        &{
            let mut h = vec!["noise ×".to_string()];
            for k in execs {
                h.push(format!("{k} exec"));
            }
            h.push("recovery (33 vs 9)".into());
            h
        }
        .iter()
        .map(String::as_str)
        .collect::<Vec<_>>(),
    );
    for &scale in &scales {
        let mut row = vec![format!("{scale:.0}")];
        let mut acc9 = 0.0;
        let mut acc33 = 0.0;
        for &k in &execs {
            let mut pipe = Pipeline::new(
                &model,
                PipelineOptions {
                    schedule_prefix: Some(k),
                    noise_scale: scale,
                    ..Default::default()
                },
            );
            let mut votes = Vec::with_capacity(n);
            for chunk in test.images[..n].chunks(256) {
                votes.extend(pipe.classify_batch(chunk).into_iter().map(|(v, _)| v));
            }
            let acc = evaluate(&votes, &test.labels[..n]).top1;
            if k == 9 {
                acc9 = acc;
            }
            if k == 33 {
                acc33 = acc;
            }
            row.push(format!("{acc:.4}"));
        }
        row.push(format!("{:+.4}", acc33 - acc9));
        table.row(row);
    }
    table.print();
    println!("\nexpected shape (paper §IV, first idea): the more the device's");
    println!("evaluations differ run-to-run, the more the repeated-execution");
    println!("majority matters — the 33-execution column degrades far more");
    println!("slowly with noise than the few-execution columns.");
    println!("\n[ablation_noise done in {:.1}s]", t.elapsed_s());
}
