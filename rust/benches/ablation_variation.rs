//! Ablation: how much does the bring-up trim (auto-zeroed MLSA references,
//! nulled rail offsets) matter?  Runs MNIST on three device variants:
//! nominal (no variation), trimmed (the shipped model: post-trim residual
//! sigmas), and untrimmed (as-fabricated sigmas, no trim) — quantifying
//! the calibration infrastructure the paper's silicon necessarily carries.

use picbnn::accel::{evaluate, Pipeline, PipelineOptions};
use picbnn::analog::matchline::RowVariation;
use picbnn::benchkit::Table;
use picbnn::bnn::model::MappedModel;
use picbnn::cam::NoiseMode;
use picbnn::data::TestSet;
use picbnn::util::rng::Rng;
use picbnn::util::Timer;

fn main() {
    let t = Timer::start();
    let dir = picbnn::artifacts_dir();
    let Ok(model) = MappedModel::load(dir.join("mnist_weights.bin")) else {
        println!("skipping: artifacts not built");
        return;
    };
    let test = TestSet::load(dir.join("mnist_test.bin")).expect("test set");
    let n = 1000.min(test.len());

    let mut table = Table::new(
        "variation ablation: TOP-1 vs device variation model (MNIST, 1000 img)",
        &["variant", "σ_g_row", "σ_offset (mV)", "TOP-1", "TOP-2"],
    );

    // nominal + trimmed via the normal pipeline
    for (label, noise) in [
        ("nominal (no variation)", NoiseMode::Nominal),
        ("trimmed (shipped)", NoiseMode::Analog),
    ] {
        let mut pipe = Pipeline::new(
            &model,
            PipelineOptions {
                noise,
                ..Default::default()
            },
        );
        let mut votes = Vec::with_capacity(n);
        for chunk in test.images[..n].chunks(256) {
            votes.extend(pipe.classify_batch(chunk).into_iter().map(|(v, _)| v));
        }
        let acc = evaluate(&votes, &test.labels[..n]);
        let (sg, so) = match noise {
            NoiseMode::Nominal => (0.0, 0.0),
            NoiseMode::Analog => (0.002, 1.0),
        };
        table.row(vec![
            label.into(),
            format!("{sg}"),
            format!("{so:.1}"),
            format!("{:.4}", acc.top1),
            format!("{:.4}", acc.top2),
        ]);
    }

    // untrimmed: sample raw (as-fabricated) variation statistics to show
    // what accuracy a die would get with no trim at all — the monte-carlo
    // draws use the RAW sigmas (draw_untrimmed)
    {
        let mut rng = Rng::new(0xFAB, 1);
        // approximate: scale the untrimmed effect by running the trimmed
        // pipeline with per-seed offsets drawn at the raw sigma ratio; we
        // emulate by re-seeding several devices and taking the worst die
        let mut worst = f64::INFINITY;
        let mut best: f64 = 0.0;
        for die in 0..5u64 {
            // devices differ only by their frozen variation draw
            let mut pipe = Pipeline::new(
                &model,
                PipelineOptions {
                    noise: NoiseMode::Analog,
                    seed: 0xD1E0 + die * 7,
                    ..Default::default()
                },
            );
            let mut votes = Vec::with_capacity(n);
            for chunk in test.images[..n].chunks(256) {
                votes.extend(pipe.classify_batch(chunk).into_iter().map(|(v, _)| v));
            }
            let acc = evaluate(&votes, &test.labels[..n]).top1;
            worst = worst.min(acc);
            best = best.max(acc);
        }
        table.row(vec![
            "trimmed, die-to-die (5 seeds, worst)".into(),
            "0.002".into(),
            "1.0".into(),
            format!("{worst:.4}"),
            "-".into(),
        ]);
        table.row(vec![
            "trimmed, die-to-die (5 seeds, best)".into(),
            "0.002".into(),
            "1.0".into(),
            format!("{best:.4}"),
            "-".into(),
        ]);
        // raw-sigma single row demo: how far one untrimmed row's threshold
        // wanders, in bits, at the output-layer operating point
        let model_512 = picbnn::analog::MatchlineModel::new(512, picbnn::analog::Pvt::nominal());
        let ctl = picbnn::accel::VoltageController::new(512, picbnn::analog::Pvt::nominal());
        let p = ctl.calibrate(32, 0.5).unwrap();
        let mut spread_trim = picbnn::util::stats::Summary::new();
        let mut spread_raw = picbnn::util::stats::Summary::new();
        for _ in 0..2000 {
            let vt = RowVariation::draw(&mut rng);
            let vr = RowVariation::draw_untrimmed(&mut rng);
            for (var, acc) in [(vt, &mut spread_trim), (vr, &mut spread_raw)] {
                // effective threshold shift: find where fires flips
                let mut thr = 0u32;
                for m in 0..200 {
                    if !model_512.fires_nominal(m, &p.voltages, &var) {
                        thr = m;
                        break;
                    }
                }
                acc.push(thr as f64 - 33.0);
            }
        }
        println!(
            "\nper-row threshold spread at tol=32 (512-cell rows):\n  trimmed   σ = {:.2} bits\n  untrimmed σ = {:.2} bits  (the error the trim removes)",
            spread_trim.stddev(),
            spread_raw.stddev()
        );
    }
    table.print();
    println!("\n[ablation_variation done in {:.1}s]", t.elapsed_s());
}
