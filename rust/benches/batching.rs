//! Experiment A2 — the paper §V-B batching claim: voltage retuning "is not
//! an immediate operation", so the same (V_ref, V_eval, V_st) combination
//! is applied to many images before retuning.  Throughput vs batch size,
//! decomposed into search cycles, programming cycles, and retune stalls.

use picbnn::accel::{Pipeline, PipelineOptions};
use picbnn::benchkit::Table;
use picbnn::bnn::model::MappedModel;
use picbnn::data::TestSet;
use picbnn::util::Timer;

fn main() {
    let t = Timer::start();
    let dir = picbnn::artifacts_dir();
    for name in ["mnist", "hg"] {
        let Ok(model) = MappedModel::load(dir.join(format!("{name}_weights.bin"))) else {
            println!("skipping {name}: artifacts not built");
            return;
        };
        let test = TestSet::load(dir.join(format!("{name}_test.bin"))).expect("test set");
        let n = 512.min(test.len());
        let mut table = Table::new(
            &format!("A2 ({name}): throughput vs retune-batch size ({n} images)"),
            &["batch", "cycles/inf", "retunes", "stall (µs/inf)", "inf/s"],
        );
        for batch in [1usize, 4, 16, 64, 256] {
            let mut pipe = Pipeline::new(&model, PipelineOptions::default());
            for chunk in test.images[..n].chunks(batch) {
                pipe.classify_batch(chunk);
            }
            let stats = pipe.take_stats(n as u64);
            table.row(vec![
                batch.to_string(),
                format!("{:.1}", stats.cycles_per_inference()),
                stats.events.retunes.to_string(),
                format!("{:.2}", stats.stall_s * 1e6 / n as f64),
                format!("{:.0}", stats.inferences_per_s()),
            ]);
        }
        table.print();
    }
    println!("\nexpected shape: at batch 1 every image pays 33 retunes (+ full");
    println!("reprogramming for multi-load models); throughput grows with batch and");
    println!("saturates once search cycles dominate — the paper's amortisation.");
    println!("\n[batching done in {:.1}s]", t.elapsed_s());
}
