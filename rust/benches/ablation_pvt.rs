//! Experiment A1 — the paper's §II-C claim, made measurable: TDC-readout
//! CAM BNNs suffer *systematic* classification error under PVT drift
//! (taps calibrated at one corner decode wrongly at another, and majority
//! voting over identically-biased samples cannot fix it), while PiC-BNN's
//! threshold-sweep + per-class majority tolerates the same drift because
//! each execution re-derives the decision from a freshly-referenced
//! comparison.

use picbnn::accel::{evaluate, Pipeline, PipelineOptions};
use picbnn::analog::{Pvt, Voltages};
use picbnn::baseline::{tdc_predict, tdc_predict_fixed_threshold, TdcReadout};
use picbnn::benchkit::Table;
use picbnn::bnn::model::MappedModel;
use picbnn::data::TestSet;
use picbnn::util::rng::Rng;
use picbnn::util::Timer;

fn main() {
    let t = Timer::start();
    let dir = picbnn::artifacts_dir();
    let Ok(model) = MappedModel::load(dir.join("mnist_weights.bin")) else {
        println!("skipping: artifacts not built");
        return;
    };
    let test = TestSet::load(dir.join("mnist_test.bin")).expect("test set");
    let n = 500.min(test.len());

    // TDC taps calibrated once at the nominal corner (as in [34]).
    let tdc = TdcReadout::calibrate(512, Pvt::nominal(), Voltages::new(0.8, 0.7, 1.0));

    let mut table = Table::new(
        "A1: TOP-1 accuracy under temperature / supply drift (MNIST, 500 images)",
        &["corner", "temp (°C)", "V_DD (V)", "PiC-BNN", "TDC argmax", "TDC fixed-thr"],
    );
    let corners = [
        ("cold", 0.0, 1.2),
        ("nominal", 25.0, 1.2),
        ("warm", 55.0, 1.2),
        ("hot", 85.0, 1.2),
        ("brown-out", 25.0, 1.14),
        ("overdrive", 25.0, 1.26),
        ("hot+brown-out", 85.0, 1.14),
    ];
    for (label, temp, vdd) in corners {
        let pvt = Pvt {
            temp_c: temp,
            vdd,
            ..Pvt::nominal()
        };
        // PiC-BNN: the pipeline *recalibrates its voltages at this corner*
        // — cheap, because calibration is a register write, not a tap
        // redesign; the paper's scheme retunes rails anyway per threshold.
        let mut pipe = Pipeline::new(
            &model,
            PipelineOptions {
                pvt,
                ..Default::default()
            },
        );
        let mut votes = Vec::with_capacity(n);
        for chunk in test.images[..n].chunks(256) {
            votes.extend(pipe.classify_batch(chunk).into_iter().map(|(v, _)| v));
        }
        let pic = evaluate(&votes, &test.labels[..n]).top1;

        // TDC: taps stay at the calibration corner (the §II-C failure mode:
        // a delay tap is a physical structure, not a register).
        let mut rng = Rng::new(42, 42);
        let tdc_correct = test.images[..n]
            .iter()
            .zip(&test.labels[..n])
            .filter(|(x, &y)| tdc_predict(&model, &tdc, x, pvt, &mut rng) == y as usize)
            .count();
        // [34]-style absolute readout: a fixed decoded-HD threshold per
        // class decision (calibrated mid-sweep at nominal)
        let tdc_fixed_correct = test.images[..n]
            .iter()
            .zip(&test.labels[..n])
            .filter(|(x, &y)| {
                tdc_predict_fixed_threshold(&model, &tdc, x, pvt, &mut rng, 40) == y as usize
            })
            .count();
        table.row(vec![
            label.to_string(),
            format!("{temp:.0}"),
            format!("{vdd:.2}"),
            format!("{:.4}", pic),
            format!("{:.4}", tdc_correct as f64 / n as f64),
            format!("{:.4}", tdc_fixed_correct as f64 / n as f64),
        ]);
    }
    table.print();
    println!("\nfindings (paper §II-C, made precise): the *absolute* TDC readout —");
    println!("a fixed time/count threshold per class decision, as in [34] — collapses");
    println!("under drift because decoded counts scale while the hardwired threshold");
    println!("does not (systematic, repetition cannot help).  An argmax-style TDC is");
    println!("ratio-invariant and only mildly hurt.  PiC-BNN stays at baseline at every");
    println!("corner because its thresholds are *voltage registers*, recalibrated per");
    println!("corner for the cost of a DAC write.");
    println!("\n[ablation_pvt done in {:.1}s]", t.elapsed_s());
}
