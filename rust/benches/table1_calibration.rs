//! Experiment T1 — regenerate paper Table I: voltage triples realising HD
//! tolerance targets {0, 4, ..., 36}, via the calibration search against
//! the analog model, with behavioural verification at each point.
//! Also reports the Algorithm-1 schedule calibration on 512-cell words
//! (what the MNIST output layer actually uses).

use picbnn::accel::VoltageController;
use picbnn::analog::Pvt;
use picbnn::benchkit::Table;
use picbnn::util::Timer;

fn main() {
    let t = Timer::start();

    // --- Table I proper: 256-cell rows, targets {0, 4, ..., 36} ---
    let ctl = VoltageController::new(256, Pvt::nominal());
    let mut table = Table::new(
        "T1: calibrated (V_ref, V_eval, V_st) -> HD tolerance, 256-cell rows",
        &["HD tol", "V_ref (mV)", "V_eval (mV)", "V_st (mV)", "achieved", "FA", "FR"],
    );
    for target in (0..=36).step_by(4) {
        let p = ctl
            .calibrate(target, 0.5)
            .or_else(|| ctl.calibrate(target, 2.0))
            .expect("target unreachable");
        let (fa, fr) = ctl.verify(&p, 8);
        table.row(vec![
            target.to_string(),
            format!("{:.0}", p.voltages.vref * 1e3),
            format!("{:.0}", p.voltages.veval * 1e3),
            format!("{:.0}", p.voltages.vst * 1e3),
            format!("{:.2}", p.achieved_tol),
            fa.to_string(),
            fr.to_string(),
        ]);
    }
    table.print();
    println!("paper Table I: same targets, silicon-specific millivolts; FA/FR = ");
    println!("false accepts/rejects over a ±8-bit probe around each target (want 0/0).");

    // --- the working schedules the pipeline calibrates ---
    for (cells, label) in [(512usize, "output layer (512-cell words)"),
                           (1024, "hidden midpoint (1024-cell words)")] {
        let ctl = VoltageController::new(cells, Pvt::nominal());
        let targets: Vec<u32> = if cells == 512 {
            (0..=64).step_by(2).collect()
        } else {
            vec![512]
        };
        let points = ctl.calibrate_schedule(&targets);
        let worst = points
            .iter()
            .map(|p| (p.achieved_tol - (p.target_tol as f64 + 0.5)).abs())
            .fold(0.0f64, f64::max);
        println!(
            "\n{label}: {} targets calibrated, worst placement error {:.3} bits",
            points.len(),
            worst
        );
    }

    println!("\n[table1_calibration done in {:.1}s]", t.elapsed_s());
}
