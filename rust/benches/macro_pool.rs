//! Experiment A4 — resident multi-macro pool vs single-macro reload
//! scheduler: steady-state device cost per inference.
//!
//! The reload `Pipeline` reprograms the hidden layer every batch (the
//! output rows evict it) and retunes the rails for all 33 output
//! thresholds of every batch; the resident `MacroPool` pays programming
//! and retuning once at construction.  This bench measures both engines on
//! the same synthetic MNIST-shaped model (784 -> 128 -> 10; no artifacts
//! needed) and reports steady-state cycles/inference, programming cycles,
//! and retune stalls.
//!
//! Run: `cargo bench --bench macro_pool`

use picbnn::accel::{MacroPool, Pipeline, PipelineOptions, PoolMode};
use picbnn::benchkit::Table;
use picbnn::bnn::model::{MappedLayer, MappedModel};
use picbnn::cam::NoiseMode;
use picbnn::util::bitops::{BitMatrix, BitVec};
use picbnn::util::rng::Rng;
use picbnn::util::Timer;

fn rand_bits(n: usize, rng: &mut Rng) -> BitVec {
    let mut v = BitVec::zeros(n);
    for i in 0..n {
        v.set(i, rng.chance(0.5));
    }
    v
}

/// Single-segment random layer (mirrors the python mapper's shape).
fn layer(rng: &mut Rng, n_out: usize, n_in: usize, width: usize) -> MappedLayer {
    let rows: Vec<BitVec> = (0..n_out).map(|_| rand_bits(n_in, rng)).collect();
    let pads = width - n_in;
    let q = vec![(0..n_out)
        .map(|_| rng.range_u64(0, pads as u64) as i32)
        .collect()];
    MappedLayer {
        weights: BitMatrix::from_rows(&rows),
        q,
        seg_bounds: vec![0, n_in],
        seg_width: width,
    }
}

fn mnist_shaped(seed: u64) -> MappedModel {
    let mut rng = Rng::new(seed, 0xBE9C);
    let l1 = layer(&mut rng, 128, 784, 1024);
    let l2 = layer(&mut rng, 10, 128, 512);
    let m = MappedModel {
        layers: vec![l1, l2],
        schedule: (0..=64).step_by(2).collect(),
    };
    for l in &m.layers {
        l.validate().expect("synthetic layer valid");
    }
    m
}

fn main() {
    let t0 = Timer::start();
    let model = mnist_shaped(7);
    let mut rng = Rng::new(3, 3);
    let images: Vec<BitVec> = (0..256).map(|_| rand_bits(784, &mut rng)).collect();
    let opts = PipelineOptions {
        noise: NoiseMode::Nominal,
        ..Default::default()
    };
    let batches = 8usize;
    let n_inf = (batches * images.len()) as u64;

    // --- resident pool: program once, serve forever ---
    let pool = MacroPool::new(&model, opts);
    assert_eq!(pool.mode(), PoolMode::Resident);
    pool.classify_batch(&images); // warmup epoch
    let warm = pool.take_stats(images.len() as u64);
    let t = Timer::start();
    for _ in 0..batches {
        pool.classify_batch(&images);
    }
    let host_pool = t.elapsed_s();
    let pool_stats = pool.take_stats(n_inf);

    // --- reload pipeline: reprogram + retune every batch ---
    let mut pipe = Pipeline::new(&model, opts);
    pipe.classify_batch(&images); // same warmup treatment
    pipe.take_stats(images.len() as u64);
    let t = Timer::start();
    for _ in 0..batches {
        pipe.classify_batch(&images);
    }
    let host_pipe = t.elapsed_s();
    let pipe_stats = pipe.take_stats(n_inf);

    let mut table = Table::new(
        &format!(
            "A4: resident MacroPool ({} macros) vs reload Pipeline — steady state, \
             {batches} × {} images",
            pool.n_macros(),
            images.len()
        ),
        &[
            "engine",
            "cycles/inf",
            "program cyc",
            "retunes",
            "stall µs/inf",
            "device inf/s",
            "host img/s",
        ],
    );
    for (name, stats, host) in [
        ("MacroPool (resident)", &pool_stats, host_pool),
        ("Pipeline (reload)", &pipe_stats, host_pipe),
    ] {
        table.row(vec![
            name.to_string(),
            format!("{:.1}", stats.cycles_per_inference()),
            stats.programming_cycles().to_string(),
            stats.events.retunes.to_string(),
            format!("{:.3}", stats.stall_s * 1e6 / n_inf as f64),
            format!("{:.0}", stats.inferences_per_s()),
            format!("{:.0}", n_inf as f64 / host),
        ]);
    }
    table.print();

    println!(
        "\nwarmup epoch (pool construction + first batch): {} programming cycles, \
         {} retune events",
        warm.programming_cycles(),
        warm.events.retunes
    );
    assert_eq!(
        pool_stats.programming_cycles(),
        0,
        "resident steady state must not program"
    );
    assert_eq!(pool_stats.events.retunes, 0, "resident steady state must not retune");
    assert!(
        pool_stats.cycles_per_inference() < pipe_stats.cycles_per_inference(),
        "resident pool must beat the reload scheduler: {} vs {}",
        pool_stats.cycles_per_inference(),
        pipe_stats.cycles_per_inference()
    );
    println!(
        "\nresident advantage: {:.1}% fewer device cycles per inference",
        100.0 * (1.0 - pool_stats.cycles_per_inference() / pipe_stats.cycles_per_inference())
    );
    println!("\n[macro_pool done in {:.1}s]", t0.elapsed_s());
}
