//! Experiment A4 — capacity-aware placement: steady-state device cost as
//! the macro budget shrinks from full residency to the single-macro
//! reload scheduler.
//!
//! The model is HG-shaped for the planner's acceptance case: 6 hidden
//! loads + 33 output thresholds = 39 macros for full residency, planned
//! down into 16.  Under the degraded budget every hidden load keeps its
//! dedicated macro (zero steady-state programming) while the output
//! thresholds share: 9 stay pinned, the other 24 funnel through one
//! LRU-parked slot and pay a tracked retune per operating-point switch —
//! still strictly cheaper than the reload `Pipeline`, which reprograms
//! every hidden load *and* retunes all 33 thresholds every batch.
//!
//! Run: `cargo bench --bench macro_pool`

use picbnn::accel::{MacroPool, Pipeline, PipelineOptions, PoolMode};
use picbnn::benchkit::{bench_artifact_path, emit_json, synth_bits, synth_model, BenchRecord, Table};
use picbnn::bnn::model::MappedModel;
use picbnn::cam::NoiseMode;
use picbnn::util::bitops::BitVec;
use picbnn::util::rng::Rng;
use picbnn::util::Timer;

/// HG-shaped synthetic model: 1500 -> 384 -> 6.  The hidden layer runs at
/// the 2048x64 configuration, so its 384 neurons need 6 weight loads;
/// with the 33-threshold schedule that is 39 macros for full residency.
fn hg_shaped(seed: u64) -> MappedModel {
    synth_model(seed, 0xBE9C, &[(384, 1500, 2048), (6, 384, 512)])
}

struct Run {
    label: String,
    macros: usize,
    cpi: f64,
    program: u64,
    retunes_per_batch: f64,
    stall_us_per_inf: f64,
    inf_s: f64,
    host_img_s: f64,
}

fn main() {
    let t0 = Timer::start();
    let model = hg_shaped(7);
    let mut rng = Rng::new(3, 3);
    let images: Vec<BitVec> = (0..128).map(|_| synth_bits(1500, &mut rng)).collect();
    let opts = PipelineOptions {
        noise: NoiseMode::Nominal,
        ..Default::default()
    };
    let batches = 4usize;
    let n_inf = (batches * images.len()) as u64;
    let required = MacroPool::macros_required(&model, &opts);
    assert_eq!(required, 39, "the acceptance shape: 6 loads + 33 thresholds");

    let mut runs: Vec<Run> = Vec::new();
    for (name, budget) in [("full residency", required), ("degraded", 16)] {
        let pool = MacroPool::with_capacity(&model, opts, budget);
        assert_eq!(pool.mode(), PoolMode::Resident, "{name}");
        let plan = pool.plan().unwrap();
        println!("budget {budget:>2} ({name}): {}", plan.describe());
        pool.classify_batch(&images); // warmup epoch
        pool.take_stats(images.len() as u64);
        let t = Timer::start();
        for _ in 0..batches {
            pool.classify_batch(&images);
        }
        let host = t.elapsed_s();
        let stats = pool.take_stats(n_inf);
        assert_eq!(
            stats.programming_cycles(),
            0,
            "{name}: resident steady state must not program"
        );
        assert!(
            stats.events.retunes <= plan.predicted_retunes_per_batch() * batches as u64,
            "{name}: retunes exceed the plan's cost model"
        );
        runs.push(Run {
            label: format!("MacroPool ({budget} macros, {name})"),
            macros: pool.n_macros(),
            cpi: stats.cycles_per_inference(),
            program: stats.programming_cycles(),
            retunes_per_batch: stats.events.retunes as f64 / batches as f64,
            stall_us_per_inf: stats.stall_s * 1e6 / n_inf as f64,
            inf_s: stats.inferences_per_s(),
            host_img_s: n_inf as f64 / host,
        });
    }

    // --- reload pipeline: reprogram + retune every batch ---
    let mut pipe = Pipeline::new(&model, opts);
    pipe.classify_batch(&images); // same warmup treatment
    pipe.take_stats(images.len() as u64);
    let t = Timer::start();
    for _ in 0..batches {
        pipe.classify_batch(&images);
    }
    let host = t.elapsed_s();
    let stats = pipe.take_stats(n_inf);
    runs.push(Run {
        label: "Pipeline (1 macro, reload)".into(),
        macros: 1,
        cpi: stats.cycles_per_inference(),
        program: stats.programming_cycles(),
        retunes_per_batch: stats.events.retunes as f64 / batches as f64,
        stall_us_per_inf: stats.stall_s * 1e6 / n_inf as f64,
        inf_s: stats.inferences_per_s(),
        host_img_s: n_inf as f64 / host,
    });

    let mut table = Table::new(
        &format!(
            "A4: placement plan vs macro budget — steady state, {batches} × {} images, \
             full residency = {required} macros",
            images.len()
        ),
        &[
            "engine",
            "macros",
            "cycles/inf",
            "program cyc",
            "retunes/batch",
            "stall µs/inf",
            "device inf/s",
            "host img/s",
        ],
    );
    for r in &runs {
        table.row(vec![
            r.label.clone(),
            r.macros.to_string(),
            format!("{:.1}", r.cpi),
            r.program.to_string(),
            format!("{:.1}", r.retunes_per_batch),
            format!("{:.3}", r.stall_us_per_inf),
            format!("{:.0}", r.inf_s),
            format!("{:.0}", r.host_img_s),
        ]);
    }
    table.print();

    let (full, degraded, reload) = (&runs[0], &runs[1], &runs[2]);
    assert_eq!(full.retunes_per_batch, 0.0, "full residency never retunes");
    assert!(
        degraded.retunes_per_batch < reload.retunes_per_batch,
        "degraded budget must retune strictly less than reload: {} vs {}",
        degraded.retunes_per_batch,
        reload.retunes_per_batch
    );
    assert!(reload.program > 0, "reload reprograms every batch");
    assert!(
        degraded.cpi < reload.cpi,
        "degraded residency must beat the reload scheduler: {} vs {}",
        degraded.cpi,
        reload.cpi
    );
    println!(
        "\ndegraded-budget advantage over reload: {:.1}% fewer device cycles/inf, \
         {:.0} fewer retunes/batch (cost model bound held)",
        100.0 * (1.0 - degraded.cpi / reload.cpi),
        reload.retunes_per_batch - degraded.retunes_per_batch
    );

    // persist the perf trajectory: host ns/image + host img/s per engine,
    // plus the device-clock inferences/s the paper's numbers live in
    let records: Vec<BenchRecord> = runs
        .iter()
        .flat_map(|r| {
            [
                BenchRecord::new(&r.label, 1e9 / r.host_img_s, Some(r.host_img_s)),
                BenchRecord::new(
                    &format!("{} [device inf/s]", r.label),
                    1e9 / r.inf_s,
                    Some(r.inf_s),
                ),
            ]
        })
        .collect();
    emit_json(bench_artifact_path("BENCH_macro_pool.json"), &records)
        .expect("write BENCH_macro_pool.json");
    println!("\n[macro_pool done in {:.1}s]", t0.elapsed_s());
}
