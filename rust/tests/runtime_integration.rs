//! PJRT runtime integration: the AOT-lowered Algorithm-1 graph must agree
//! bit-for-bit with the nominal CAM pipeline and the digital reference on
//! the real artifacts.  Skipped (with notice) when artifacts are absent.

use picbnn::accel::{Pipeline, PipelineOptions};
use picbnn::bnn::infer::digital_forward;
use picbnn::bnn::model::MappedModel;
use picbnn::cam::NoiseMode;
use picbnn::data::TestSet;
use picbnn::runtime::InferEngine;

fn load(name: &str) -> Option<(MappedModel, TestSet)> {
    let dir = picbnn::artifacts_dir();
    if !dir.join(format!("{name}_infer.hlo.txt")).exists() {
        return None;
    }
    Some((
        MappedModel::load(dir.join(format!("{name}_weights.bin"))).ok()?,
        TestSet::load(dir.join(format!("{name}_test.bin"))).ok()?,
    ))
}

#[test]
fn pjrt_matches_digital_reference_mnist() {
    let Some((model, test)) = load("mnist") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = match InferEngine::load("mnist", &model) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let n = 128.min(test.len());
    let got = engine.classify_all(&test.images[..n]).expect("classify");
    for (img, (votes, pred)) in test.images[..n].iter().zip(&got) {
        let (want_votes, want_pred) = digital_forward(&model, img, &model.schedule);
        assert_eq!(votes, &want_votes, "votes mismatch");
        assert_eq!(pred, &want_pred, "pred mismatch");
    }
}

#[test]
fn pjrt_matches_nominal_cam_pipeline_mnist() {
    let Some((model, test)) = load("mnist") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = match InferEngine::load("mnist", &model) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let mut pipe = Pipeline::new(
        &model,
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        },
    );
    let n = 64.min(test.len());
    let pjrt = engine.classify_batch(&test.images[..n]).unwrap();
    let cam = pipe.classify_batch(&test.images[..n]);
    assert_eq!(pjrt, cam, "the two execution backends must agree");
}

#[test]
fn pjrt_matches_digital_reference_hg() {
    let Some((model, test)) = load("hg") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = match InferEngine::load("hg", &model) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    let n = 64.min(test.len());
    let got = engine.classify_all(&test.images[..n]).expect("classify");
    for (img, (votes, pred)) in test.images[..n].iter().zip(&got) {
        let (want_votes, want_pred) = digital_forward(&model, img, &model.schedule);
        assert_eq!(votes, &want_votes);
        assert_eq!(pred, &want_pred);
    }
}

#[test]
fn pjrt_partial_batches_pad_correctly() {
    let Some((model, test)) = load("mnist") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let engine = match InferEngine::load("mnist", &model) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: {e}");
            return;
        }
    };
    // 1, 63, 64, 65 image batches must all work and agree with full-batch
    for n in [1usize, 63, 64, 65] {
        let n = n.min(test.len());
        let got = engine.classify_all(&test.images[..n]).unwrap();
        assert_eq!(got.len(), n);
        for (img, (votes, pred)) in test.images[..n].iter().zip(&got) {
            let (want_votes, want_pred) = digital_forward(&model, img, &model.schedule);
            assert_eq!(votes, &want_votes, "n={n}");
            assert_eq!(pred, &want_pred, "n={n}");
        }
    }
}
