//! Cross-validation of the rust analog model against the python functional
//! twin (`python/compile/physics.py`): the nominal closed-form tolerance
//! and fire decisions must agree on a dense grid.  The python constants are
//! re-stated here (they are the contract); if either side drifts, this
//! test and python/tests/test_physics.py catch it.

use picbnn::analog::constants as k;
use picbnn::analog::{MatchlineModel, Pvt, RowVariation, Voltages};

#[test]
fn constants_match_python_physics() {
    // python/compile/physics.py values
    assert_eq!(k::V_DD, 1.2);
    assert_eq!(k::V_TH, 0.25);
    assert_eq!(k::K_G, 8.93e-7);
    assert_eq!(k::C_ML_256, 12e-15);
    assert_eq!(k::TAU0, 0.8e-9);
    assert_eq!(k::VREF_RANGE, (0.6, 1.2));
    assert_eq!(k::VEVAL_RANGE, (0.3, 1.2));
    assert_eq!(k::VST_RANGE, (0.6, 1.2));
}

/// Reference implementation transcribed from python physics.hd_tolerance.
fn py_hd_tolerance(vref: f64, veval: f64, vst: f64, n_cells: usize) -> f64 {
    if vref >= 1.2 {
        return 0.0;
    }
    let c_ml = 12e-15 / 256.0 * n_cells as f64;
    let g = 8.93e-7 * (veval - 0.25f64).max(0.0);
    let ts = 0.8e-9 * 1.2 / (vst - 0.25f64).max(1e-3);
    let denom = g * ts;
    if denom <= 0.0 {
        return n_cells as f64;
    }
    c_ml * (1.2f64 / vref).ln() / denom
}

#[test]
fn tolerance_agrees_with_python_on_grid() {
    for n_cells in [256usize, 512, 1024, 2048] {
        let model = MatchlineModel::new(n_cells, Pvt::nominal());
        let mut vref = 0.6;
        while vref <= 1.19 {
            let mut veval = 0.3;
            while veval <= 1.2 {
                let mut vst = 0.6;
                while vst <= 1.2 {
                    let v = Voltages::new(vref, veval, vst);
                    let rust = model.hd_tolerance(&v);
                    let py = py_hd_tolerance(vref, veval, vst, n_cells);
                    let err = (rust - py).abs() / py.max(1e-9);
                    assert!(
                        err < 1e-9,
                        "n={n_cells} v=({vref},{veval},{vst}): {rust} vs {py}"
                    );
                    vst += 0.075;
                }
                veval += 0.075;
            }
            vref += 0.075;
        }
    }
}

#[test]
fn fire_decisions_agree_with_python_semantics() {
    // python ref.matchline_fire: fire iff m <= tol
    let model = MatchlineModel::new(256, Pvt::nominal());
    let var = RowVariation::nominal();
    for &(vref, veval, vst) in &[
        (0.775, 0.6, 1.1),
        (0.7, 0.45, 1.1),
        (0.95, 0.525, 1.1),
        (1.0, 0.475, 0.725),
    ] {
        let v = Voltages::new(vref, veval, vst);
        let tol = py_hd_tolerance(vref, veval, vst, 256);
        for m in 0..=256u32 {
            if (m as f64 - tol).abs() < 1e-6 {
                continue;
            }
            assert_eq!(
                model.fires_nominal(m, &v, &var),
                (m as f64) <= tol,
                "m={m} tol={tol} v={v:?}"
            );
        }
    }
}

#[test]
fn schedule_constants_match() {
    // python physics.HD_SCHEDULE = 0..=64 step 2 (33 executions)
    let sched: Vec<i32> = (0..=64).step_by(2).collect();
    assert_eq!(sched.len(), 33);
    // the shipped model artifacts carry the same schedule
    if let Ok(model) = picbnn::bnn::model::MappedModel::load(
        picbnn::artifacts_dir().join("mnist_weights.bin"),
    ) {
        assert_eq!(model.schedule, sched);
    } else {
        eprintln!("skipping artifact schedule check: artifacts not built");
    }
}
