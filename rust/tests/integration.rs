//! End-to-end integration tests over the real artifacts (skipped with a
//! notice when `make artifacts` hasn't run) and synthetic models.

use picbnn::accel::{evaluate, Pipeline, PipelineOptions};
use picbnn::baseline::digital_predict;
use picbnn::bnn::infer::digital_forward;
use picbnn::bnn::model::MappedModel;
use picbnn::cam::NoiseMode;
use picbnn::data::{ModelMeta, TestSet};

fn load(name: &str) -> Option<(MappedModel, TestSet, ModelMeta)> {
    let dir = picbnn::artifacts_dir();
    let model = MappedModel::load(dir.join(format!("{name}_weights.bin"))).ok()?;
    let test = TestSet::load(dir.join(format!("{name}_test.bin"))).ok()?;
    let meta = ModelMeta::load(dir.join(format!("{name}_meta.json"))).ok()?;
    Some((model, test, meta))
}

#[test]
fn mnist_nominal_cam_matches_python_nominal_eval() {
    // the rust nominal CAM path must reproduce python's eval_cam votes
    // (cam_nominal_top1 in the meta) exactly, over the full test set
    let Some((model, test, meta)) = load("mnist") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut pipe = Pipeline::new(
        &model,
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        },
    );
    let mut votes = Vec::new();
    for chunk in test.images.chunks(512) {
        votes.extend(pipe.classify_batch(chunk).into_iter().map(|(v, _)| v));
    }
    let acc = evaluate(&votes, &test.labels);
    assert!(
        (acc.top1 - meta.cam_nominal_top1).abs() < 1e-9,
        "rust nominal {} vs python nominal {}",
        acc.top1,
        meta.cam_nominal_top1
    );
}

#[test]
fn mnist_analog_reaches_paper_regime() {
    let Some((model, test, meta)) = load("mnist") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut pipe = Pipeline::new(&model, PipelineOptions::default());
    let n = 1000.min(test.len());
    let mut votes = Vec::new();
    for chunk in test.images[..n].chunks(256) {
        votes.extend(pipe.classify_batch(chunk).into_iter().map(|(v, _)| v));
    }
    let acc = evaluate(&votes, &test.labels[..n]);
    // paper: analog CAM reaches the software baseline (95.2%); allow the
    // simulator a small noise haircut from its own baseline
    assert!(
        acc.top1 > meta.cam_nominal_top1 - 0.03,
        "analog top1 {} too far below nominal {}",
        acc.top1,
        meta.cam_nominal_top1
    );
}

#[test]
fn hg_analog_tracks_nominal_with_segmentation_gap() {
    let Some((model, test, meta)) = load("hg") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut pipe = Pipeline::new(&model, PipelineOptions::default());
    let n = 500.min(test.len());
    let mut votes = Vec::new();
    for chunk in test.images[..n].chunks(256) {
        votes.extend(pipe.classify_batch(chunk).into_iter().map(|(v, _)| v));
    }
    let acc = evaluate(&votes, &test.labels[..n]);
    // paper shape: CAM HG accuracy sits below the software baseline
    // (93.5% vs 99%) but stays high
    assert!(acc.top1 > 0.80, "hg analog top1 {}", acc.top1);
    assert!(
        acc.top1 < meta.software_top1,
        "segmentation gap should persist"
    );
}

#[test]
fn digital_baseline_beats_chance_and_bounds_cam() {
    let Some((model, test, _)) = load("mnist") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let n = 500.min(test.len());
    let correct = test.images[..n]
        .iter()
        .zip(&test.labels[..n])
        .filter(|(x, &y)| digital_predict(&model, x) == y as usize)
        .count();
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.9, "digital baseline {acc}");
}

#[test]
fn prefix_schedule_accuracy_monotone_overall() {
    // Fig. 5 shape: accuracy with 1 execution << accuracy with 33
    let Some((model, test, _)) = load("mnist") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let n = 400.min(test.len());
    let acc_k = |k: usize| {
        let mut pipe = Pipeline::new(
            &model,
            PipelineOptions {
                noise: NoiseMode::Nominal,
                schedule_prefix: Some(k),
                ..Default::default()
            },
        );
        let mut votes = Vec::new();
        for chunk in test.images[..n].chunks(256) {
            votes.extend(pipe.classify_batch(chunk).into_iter().map(|(v, _)| v));
        }
        evaluate(&votes, &test.labels[..n]).top1
    };
    let a1 = acc_k(1);
    let a9 = acc_k(9);
    let a33 = acc_k(33);
    assert!(a33 > a1 + 0.05, "a1={a1} a33={a33}");
    assert!(a33 >= a9 - 0.01, "a9={a9} a33={a33}");
}

#[test]
fn device_throughput_in_paper_order_of_magnitude() {
    let Some((model, test, _)) = load("mnist") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut pipe = Pipeline::new(&model, PipelineOptions::default());
    let n = 512.min(test.len());
    for chunk in test.images[..n].chunks(256) {
        pipe.classify_batch(chunk);
    }
    let stats = pipe.take_stats(n as u64);
    let inf_s = stats.inferences_per_s();
    // paper: 560 K inf/s; accept the same order of magnitude
    assert!(
        (1e5..2e6).contains(&inf_s),
        "modelled throughput {inf_s} inf/s"
    );
    let report = picbnn::energy::report(&stats);
    assert!(
        (0.1e-3..5e-3).contains(&report.power_w),
        "modelled power {} W",
        report.power_w
    );
}

#[test]
fn nominal_digital_and_cam_forward_agree_on_artifacts() {
    // bit-exactness on the real mnist model, per image
    let Some((model, test, _)) = load("mnist") else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut pipe = Pipeline::new(
        &model,
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        },
    );
    let n = 64.min(test.len());
    let got = pipe.classify_batch(&test.images[..n]);
    for (img, (votes, pred)) in test.images[..n].iter().zip(&got) {
        let (want_votes, want_pred) = digital_forward(&model, img, &model.schedule);
        assert_eq!(votes, &want_votes);
        assert_eq!(pred, &want_pred);
    }
}
