//! Tier-1 gate: the real tree must be lint-clean.
//!
//! This is the same scan `cargo run --release --bin picbnn-lint`
//! performs, run from `cargo test` so invariant regressions fail CI
//! even in lanes that never invoke the binary.  Suppressed findings
//! are allowed (each carries a justification pragma); unsuppressed
//! ones are not.

use picbnn::analysis;
use std::path::Path;

/// The repo root, robust to whatever cwd the test harness uses: walk up
/// from the manifest dir until `Cargo.toml` + `rust/src` both exist.
fn repo_root() -> std::path::PathBuf {
    let start = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".to_string());
    let mut dir = Path::new(&start).to_path_buf();
    loop {
        if dir.join("Cargo.toml").is_file() && dir.join("rust/src").is_dir() {
            return dir;
        }
        if !dir.pop() {
            return Path::new(".").to_path_buf();
        }
    }
}

#[test]
fn tree_is_lint_clean() {
    let root = repo_root();
    let report = analysis::lint_tree(&root).expect("lint walks the tree");
    assert!(
        report.files_scanned > 50,
        "suspiciously few files scanned ({}) — lint_tree is looking at the wrong root: {}",
        report.files_scanned,
        root.display()
    );
    assert!(
        report.clean(),
        "unsuppressed lint findings in the tree:\n{}",
        report.render_human()
    );
}

#[test]
fn suppressions_are_the_known_set() {
    // every pragma in the tree is intentional and reviewed — pin the
    // count so a drive-by allow shows up in review as a diff here too
    let report = analysis::lint_tree(&repo_root()).expect("lint walks the tree");
    let mut sites: Vec<String> = report
        .suppressed
        .iter()
        .map(|s| format!("{}:{}", s.file, s.rule))
        .collect();
    sites.sort();
    assert_eq!(
        sites,
        vec![
            "rust/src/accel/macro_pool.rs:lock-discipline",
            "rust/src/accel/macro_pool.rs:lock-discipline",
        ],
        "suppression set changed — update this pin alongside DETERMINISM.md"
    );
}

#[test]
fn json_output_parses_and_agrees() {
    let report = analysis::lint_tree(&repo_root()).expect("lint walks the tree");
    let json = picbnn::util::json::Json::parse(&report.to_json().to_string())
        .expect("lint JSON round-trips");
    assert_eq!(
        json.get("clean"),
        Some(&picbnn::util::json::Json::Bool(report.clean()))
    );
    assert_eq!(
        json.get("files_scanned").and_then(|v| v.as_i64()),
        Some(report.files_scanned as i64)
    );
}
