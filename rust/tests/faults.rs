//! Fault-injection and self-healing properties (the robustness tentpole):
//! deterministic fault plans, scrub-and-repair bit-exactness, graceful
//! typed degradation, and replayability of whole fault drills.
//!
//! The claims under test, end to end:
//!
//! * an **empty** fault plan is bit-invisible — predictions, cycle
//!   counts, and event counters match a twin pool that never had a plan
//!   injected (the zero-cost guarantee);
//! * any stuck-at pattern **within the spare-row budget** is scrubbed
//!   away and the repaired pool returns to bit-exact agreement with a
//!   never-faulted twin, in both noise modes;
//! * a whole escalating fault drill — injection, detection, repair
//!   schedule, degradation rung — **replays bit-identically** from the
//!   same seeds;
//! * replica-symmetric faults leave predictions **invariant across
//!   worker counts** (the virtual-time scheduling claim);
//! * transients self-clear, spare exhaustion on an output slot ends in
//!   **typed refusal**, spare exhaustion on one hidden replica ends in
//!   **quarantine + bit-exact failover**;
//! * the whole loop holds under **concurrent serving** on the engine's
//!   maintenance seam.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use picbnn::accel::{
    BatchPolicy, MacroPool, PipelineOptions, RepairAction, ScrubConfig, ScrubController,
    ScrubStats,
};
use picbnn::bnn::mapping::program_row;
use picbnn::bnn::model::{MappedLayer, MappedModel};
use picbnn::cam::{
    DegradedMode, FaultKind, FaultPlan, FaultSite, NoiseMode, RailId, DEFAULT_SPARE_ROWS,
};
use picbnn::server::{Clock, Engine};
use picbnn::testkit::{forall, prop_assert, Gen};
use picbnn::util::bitops::{BitMatrix, BitVec};
use picbnn::util::rng::Rng;

fn opts_for(analog: bool) -> PipelineOptions {
    PipelineOptions {
        noise: if analog {
            NoiseMode::Analog
        } else {
            NoiseMode::Nominal
        },
        ..Default::default()
    }
}

/// Exhaustive single-turn scrub: one `maintain()` laps the whole pool.
fn full_pass(workers: usize) -> ScrubConfig {
    ScrubConfig {
        rows_per_turn: 1 << 20,
        workers,
        ..Default::default()
    }
}

/// Draw a random single-segment mapped layer (props.rs fixture).
fn gen_layer(g: &mut Gen, n_out: usize, n_in: usize, width: usize) -> MappedLayer {
    let rows: Vec<BitVec> = (0..n_out)
        .map(|_| BitVec::from_pm1(&g.pm1_vec(n_in)))
        .collect();
    let pads = width - n_in;
    let q = vec![(0..n_out)
        .map(|_| g.usize_in(0, pads) as i32)
        .collect::<Vec<_>>()];
    MappedLayer {
        weights: BitMatrix::from_rows(&rows),
        q,
        seg_bounds: vec![0, n_in],
        seg_width: width,
    }
}

fn gen_model(g: &mut Gen) -> MappedModel {
    let n_in = g.usize_in(16, 120);
    let h = g.usize_in(4, 24);
    let n_cls = g.usize_in(2, 10);
    let l1 = gen_layer(g, h, n_in, (n_in + 16).max(64));
    let l2 = gen_layer(g, n_cls, h, (h + 16).max(64));
    MappedModel {
        layers: vec![l1, l2],
        schedule: (0..=64).step_by(2).collect(),
    }
}

/// Deterministic fixture for the directed drills: 64 → 8 → 6 with a
/// short schedule (6 output classes so an output slot can outlast the
/// spare budget; 8 hidden rows so a replica can, too).
fn fixed_model(seed: u64) -> MappedModel {
    let mut rng = Rng::new(seed, 77);
    let mut mk = |n_out: usize, n_in: usize, width: usize| {
        let rows: Vec<BitVec> = (0..n_out)
            .map(|_| {
                let mut v = BitVec::zeros(n_in);
                for i in 0..n_in {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect();
        let pads = width - n_in;
        let q = vec![(0..n_out)
            .map(|_| rng.range_u64(0, pads as u64) as i32)
            .collect()];
        MappedLayer {
            weights: BitMatrix::from_rows(&rows),
            q,
            seg_bounds: vec![0, n_in],
            seg_width: width,
        }
    };
    let l1 = mk(8, 64, 128);
    let l2 = mk(6, 8, 128);
    MappedModel {
        layers: vec![l1, l2],
        schedule: (0..=16).step_by(2).collect(),
    }
}

fn rand_images(n: usize, bits: usize, seed: u64) -> Vec<BitVec> {
    let mut rng = Rng::new(seed, 1);
    (0..n)
        .map(|_| {
            let mut v = BitVec::zeros(bits);
            for i in 0..bits {
                v.set(i, rng.chance(0.5));
            }
            v
        })
        .collect()
}

#[test]
fn prop_empty_fault_plan_is_bit_invisible() {
    // the zero-cost guarantee: injecting an empty plan changes nothing —
    // not predictions, not cycle accounting, not event counters — in
    // either noise mode
    forall(6, 4501, |g| {
        let model = gen_model(g);
        let images: Vec<BitVec> = (0..6)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(model.n_in())))
            .collect();
        for analog in [false, true] {
            let opts = opts_for(analog);
            let req = MacroPool::macros_required(&model, &opts);
            let pool = MacroPool::with_capacity(&model, opts, req);
            let twin = MacroPool::with_capacity(&model, opts, req);
            pool.inject_fault_plan(FaultPlan::default());
            let mut base = 0u64;
            for _ in 0..2 {
                prop_assert(
                    pool.classify_batch_at(&images, base)
                        == twin.classify_batch_at(&images, base),
                    format!("analog={analog}: empty plan perturbed predictions"),
                )?;
                base += images.len() as u64;
            }
            let a = pool.take_stats(base);
            let b = twin.take_stats(base);
            prop_assert(a.cycles == b.cycles, "empty plan changed cycle counts")?;
            prop_assert(a.stall_s == b.stall_s, "empty plan changed stall time")?;
            prop_assert(a.events == b.events, "empty plan changed event counters")?;
            prop_assert(
                a.degraded == DegradedMode::Nominal,
                "empty plan degraded the pool",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_stuck_at_within_spares_repairs_bit_exact() {
    // the tentpole's repair property: ANY stuck-at pattern touching at
    // most DEFAULT_SPARE_ROWS rows of one site is scrubbed away, and the
    // repaired pool's predictions are bit-exact against a never-faulted
    // twin — in both noise modes.  (A stuck cell whose forced value
    // agrees with the stored bit is genuinely harmless: undetectable by
    // design, and invisible to predictions, so it cannot break either
    // assertion below.)
    forall(6, 4503, |g| {
        let model = gen_model(g);
        let images: Vec<BitVec> = (0..5)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(model.n_in())))
            .collect();
        for analog in [false, true] {
            let opts = opts_for(analog);
            let req = MacroPool::macros_required(&model, &opts);
            let pool = MacroPool::with_capacity(&model, opts, req);
            let twin = MacroPool::with_capacity(&model, opts, req);
            let sites = pool.fault_sites();
            prop_assert(!sites.is_empty(), "full residency must expose sites")?;
            let site = sites[g.usize_in(0, sites.len() - 1)];
            // distinct rows within the spare budget, random cells on each
            let mut avail: Vec<usize> = (0..site.rows).collect();
            let k = g.usize_in(1, DEFAULT_SPARE_ROWS.min(site.rows));
            let mut plan = FaultPlan::default();
            for _ in 0..k {
                let row = avail.swap_remove(g.usize_in(0, avail.len() - 1));
                for _ in 0..g.usize_in(1, 2) {
                    let col = g.usize_in(0, site.width - 1);
                    let bit = g.bool();
                    plan.push(0, site.site, FaultKind::StuckBit { row, col, bit });
                }
            }
            pool.inject_fault_plan(plan);
            // first batch activates the faults; the scrub pass repairs
            pool.classify_batch_at(&images, 0);
            let mut ctl = ScrubController::new(11, full_pass(1));
            let d1 = ctl.maintain(&pool);
            prop_assert(d1.rows_scrubbed > 0, "scrub made no progress")?;
            prop_assert(
                d1.repairs == d1.faults_detected,
                format!(
                    "analog={analog}: {} detected but {} repaired in place",
                    d1.faults_detected, d1.repairs
                ),
            )?;
            prop_assert(
                d1.rebuilds == 0 && d1.quarantines == 0 && d1.unrepairable == 0,
                "within the spare budget nothing may escalate",
            )?;
            // a second full pass over the repaired pool finds nothing
            let d2 = ctl.maintain(&pool);
            prop_assert(
                d2.faults_detected == 0,
                format!("analog={analog}: residual faults after repair"),
            )?;
            prop_assert(
                ctl.degraded_mode() == DegradedMode::Nominal,
                "repair must keep the pool nominal",
            )?;
            // post-repair predictions are bit-exact against the twin
            let base = images.len() as u64;
            prop_assert(
                pool.classify_batch_at(&images, base)
                    == twin.classify_batch_at(&images, base),
                format!("analog={analog}: repaired pool diverged from the twin"),
            )?;
        }
        Ok(())
    });
}

/// One full escalating fault drill: serve batches, maintain between
/// them, record everything observable.
#[allow(clippy::type_complexity)]
fn run_drill(
    model: &MappedModel,
    plan: &FaultPlan,
    images: &[BitVec],
    rounds: usize,
) -> (
    Vec<Vec<(Vec<u32>, usize)>>,
    Vec<picbnn::accel::FaultReport>,
    ScrubStats,
    DegradedMode,
) {
    let opts = opts_for(true);
    let req = MacroPool::macros_required(model, &opts);
    let pool = MacroPool::with_capacity_for_workers(model, opts, req + 1, 2);
    pool.inject_fault_plan(plan.clone());
    let mut ctl = ScrubController::new(
        0xD2,
        ScrubConfig {
            rows_per_turn: 8,
            workers: 2,
            ..Default::default()
        },
    );
    let mut preds = Vec::new();
    let mut base = 0u64;
    for _ in 0..rounds {
        preds.push(pool.classify_batch_at(images, base));
        base += images.len() as u64;
        ctl.maintain(&pool);
    }
    (preds, ctl.take_reports(), ctl.stats(), ctl.degraded_mode())
}

#[test]
fn fault_drill_replays_bit_identically() {
    // satellite 3: same FaultPlan seed + same workload trace → bit-
    // identical fault reports, repair schedule, predictions, and final
    // degradation rung, run to run (fixed worker shape: the escalating
    // plan's replica-0 phase is deliberately asymmetric, so cross-worker
    // invariance is the next test's job, on a symmetric plan)
    let model = fixed_model(4507);
    let images = rand_images(6, 64, 17);
    let opts = opts_for(true);
    let req = MacroPool::macros_required(&model, &opts);
    let sites = MacroPool::with_capacity_for_workers(&model, opts, req + 1, 2).fault_sites();
    assert!(
        sites.iter().any(|s| s.replicas > 1),
        "the drill needs a replicated hidden load for its failover phase"
    );
    let plan = FaultPlan::escalating(0xD1, &sites, images.len() as u64, 4);
    assert!(!plan.is_empty());
    let last_at = plan.events.iter().map(|e| e.at_image).max().unwrap();
    let rounds = (last_at / images.len() as u64) as usize + 16;
    let a = run_drill(&model, &plan, &images, rounds);
    let b = run_drill(&model, &plan, &images, rounds);
    assert_eq!(a.0, b.0, "prediction traces diverged between replays");
    assert_eq!(a.1, b.1, "fault reports diverged between replays");
    assert_eq!(a.2, b.2, "repair schedules diverged between replays");
    assert_eq!(a.3, b.3, "degradation rungs diverged between replays");
    assert!(a.2.faults_detected > 0, "the drill detected nothing");
    assert!(a.2.repairs > 0, "the drill repaired nothing");
}

#[test]
fn symmetric_fault_plan_is_worker_count_invariant() {
    // faults that hit every replica identically (replica: None, slot:
    // None) are scheduled in image-stream time, so predictions are
    // invariant across worker counts / replica fan-outs.  Transients are
    // deliberately excluded: their burn-down counters live per physical
    // array, so per-copy routing makes them worker-shape-dependent by
    // design (which is why FaultPlan::escalating keeps its asymmetric
    // phases out of this invariance claim).
    let model = fixed_model(4511);
    let images = rand_images(6, 64, 19);
    let hidden = FaultSite::Hidden {
        layer: 0,
        load: 0,
        replica: None,
    };
    let mut plan = FaultPlan::default();
    for row in 0..3usize {
        let golden = program_row(&model.layers[0], 0, row);
        plan.push(
            0,
            hidden,
            FaultKind::StuckBit {
                row,
                col: 0,
                bit: !golden.get(0),
            },
        );
    }
    plan.push(
        6,
        hidden,
        FaultKind::DeadRow {
            row: 3,
            always_fire: true,
        },
    );
    plan.push(
        12,
        hidden,
        FaultKind::DacDrift {
            rail: RailId::Vref,
            volts: 0.004,
        },
    );
    let out_golden = program_row(&model.layers[1], 0, 0);
    plan.push(
        12,
        FaultSite::Output { slot: None },
        FaultKind::StuckBit {
            row: 0,
            col: 0,
            bit: !out_golden.get(0),
        },
    );
    for analog in [false, true] {
        let opts = opts_for(analog);
        let req = MacroPool::macros_required(&model, &opts);
        let one = MacroPool::with_capacity_for_workers(&model, opts, req + 2, 1);
        let three = MacroPool::with_capacity_for_workers(&model, opts, req + 2, 3);
        one.inject_fault_plan(plan.clone());
        three.inject_fault_plan(plan.clone());
        let mut base = 0u64;
        for round in 0..4 {
            assert_eq!(
                one.classify_batch_at(&images, base),
                three.classify_batch_at(&images, base),
                "analog={analog} round={round}: symmetric faults must not \
                 depend on the worker shape"
            );
            base += images.len() as u64;
        }
    }
}

#[test]
fn transient_upsets_self_clear_without_repair() {
    // a transient inverts its row's next N evaluations and then clears
    // itself: the following batch is already bit-exact again, and the
    // scrub pass — arriving after the burn-down — finds nothing to fix
    let model = fixed_model(4513);
    let images = rand_images(4, 64, 23);
    for analog in [false, true] {
        let opts = opts_for(analog);
        let req = MacroPool::macros_required(&model, &opts);
        let pool = MacroPool::with_capacity(&model, opts, req);
        let twin = MacroPool::with_capacity(&model, opts, req);
        let mut plan = FaultPlan::default();
        plan.push(
            0,
            FaultSite::Hidden {
                layer: 0,
                load: 0,
                replica: None,
            },
            FaultKind::Transient {
                row: 0,
                searches: 2,
            },
        );
        pool.inject_fault_plan(plan);
        // batch 1: the upset may flip predictions (4 evaluations of the
        // row burn the 2-search counter down); no assertion on values
        pool.classify_batch_at(&images, 0);
        // batch 2: self-cleared, bit-exact against the twin
        let base = images.len() as u64;
        assert_eq!(
            pool.classify_batch_at(&images, base),
            twin.classify_batch_at(&images, base),
            "analog={analog}: transient failed to self-clear"
        );
        let mut ctl = ScrubController::new(13, full_pass(1));
        let d = ctl.maintain(&pool);
        assert!(d.rows_scrubbed > 0);
        assert_eq!(
            d.faults_detected, 0,
            "analog={analog}: a burned-down transient left residue"
        );
        assert_eq!(ctl.degraded_mode(), DegradedMode::Nominal);
    }
}

#[test]
fn output_slot_beyond_spares_refuses_typed() {
    // graceful degradation's last rung: dead rows past the spare budget
    // on an output slot (no quarantine path — the threshold sweep needs
    // every slot) drive the pool to typed refusal, never to silently
    // wrong answers.  max_rebuilds: 0 jumps the ladder straight there.
    let model = fixed_model(4517);
    let images = rand_images(4, 64, 29);
    let opts = opts_for(false);
    let req = MacroPool::macros_required(&model, &opts);
    let pool = MacroPool::with_capacity(&model, opts, req);
    let slot = FaultSite::Output { slot: Some(0) };
    assert!(
        pool.output_rows() > DEFAULT_SPARE_ROWS,
        "fixture must have more output rows than spares"
    );
    let mut plan = FaultPlan::default();
    for row in 0..=DEFAULT_SPARE_ROWS {
        plan.push(
            0,
            slot,
            FaultKind::DeadRow {
                row,
                always_fire: row % 2 == 0,
            },
        );
    }
    pool.inject_fault_plan(plan);
    pool.classify_batch_at(&images, 0);
    let mut ctl = ScrubController::new(
        17,
        ScrubConfig {
            max_rebuilds: 0,
            ..full_pass(1)
        },
    );
    let d = ctl.maintain(&pool);
    assert!(
        d.faults_detected > DEFAULT_SPARE_ROWS as u64,
        "every dead row must be flagged"
    );
    assert_eq!(
        d.repairs, DEFAULT_SPARE_ROWS as u64,
        "exactly the spare budget is remapped"
    );
    assert_eq!(d.unrepairable, 1, "the row past the spares is terminal");
    assert_eq!(ctl.degraded_mode(), DegradedMode::Refusing);
    assert_eq!(pool.degraded_mode(), DegradedMode::Refusing);
    assert!(
        ctl.take_reports()
            .iter()
            .any(|r| r.action == RepairAction::Unrepairable),
        "the terminal outcome must be reported"
    );
    // the rung is stamped into the device stats for observability
    assert_eq!(pool.take_stats(4).degraded, DegradedMode::Refusing);
}

#[test]
fn hidden_replica_quarantine_fails_over_bit_exact() {
    // spare exhaustion on ONE copy of a replicated hidden load ends in
    // quarantine, not refusal: the surviving identically-seeded sibling
    // keeps serving bit-exactly, and the pool reports Failover
    let model = fixed_model(4519);
    let images = rand_images(6, 64, 31);
    for analog in [false, true] {
        let opts = opts_for(analog);
        let req = MacroPool::macros_required(&model, &opts);
        let pool = MacroPool::with_capacity_for_workers(&model, opts, req + 1, 2);
        let twin = MacroPool::with_capacity_for_workers(&model, opts, req + 1, 2);
        let sites = pool.fault_sites();
        assert_eq!(
            sites[0].replicas, 2,
            "the surplus macro must buy a hidden replica"
        );
        let mut plan = FaultPlan::default();
        for row in 0..=DEFAULT_SPARE_ROWS {
            plan.push(
                0,
                FaultSite::Hidden {
                    layer: 0,
                    load: 0,
                    replica: Some(0),
                },
                FaultKind::DeadRow {
                    row,
                    always_fire: true,
                },
            );
        }
        pool.inject_fault_plan(plan);
        pool.classify_batch_at(&images, 0);
        let mut ctl = ScrubController::new(
            19,
            ScrubConfig {
                max_rebuilds: 0,
                ..full_pass(2)
            },
        );
        let d = ctl.maintain(&pool);
        assert_eq!(
            d.quarantines, 1,
            "analog={analog}: the dying copy must be retired"
        );
        assert_eq!(d.unrepairable, 0, "quarantine is not refusal");
        assert_eq!(ctl.degraded_mode(), DegradedMode::Failover);
        assert_eq!(pool.degraded_mode(), DegradedMode::Failover);
        // drain the post-quarantine re-plan (one migration step per turn)
        for _ in 0..12 {
            ctl.maintain(&pool);
        }
        assert!(!ctl.migration_in_flight(), "the re-plan must converge");
        // failover is bit-exact: the surviving replica answers exactly
        // as the never-faulted twin does
        let base = images.len() as u64;
        assert_eq!(
            pool.classify_batch_at(&images, base),
            twin.classify_batch_at(&images, base),
            "analog={analog}: failover must not change predictions"
        );
    }
}

#[test]
fn concurrent_serving_heals_under_scrub() {
    // the whole loop on the engine's maintenance seam, with worker
    // threads polling concurrently: inject, serve (faults activate),
    // scrub + repair between batches, then serve a second epoch that is
    // bit-exact against a never-faulted sequential pool
    let model = fixed_model(4523);
    let images = rand_images(8, 64, 37);
    let opts = opts_for(false);
    let req = MacroPool::macros_required(&model, &opts);
    let engine = Engine::single(
        &model,
        opts,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::ZERO,
        },
        req,
    )
    .with_clock(Clock::simulated())
    .with_scrub(0, 23, full_pass(1));
    let mut plan = FaultPlan::default();
    for row in 0..3usize {
        let golden = program_row(&model.layers[0], 0, row);
        plan.push(
            0,
            FaultSite::Hidden {
                layer: 0,
                load: 0,
                replica: None,
            },
            FaultKind::StuckBit {
                row,
                col: 0,
                bit: !golden.get(0),
            },
        );
    }
    engine.single_pool().inject_fault_plan(plan);
    // epoch 1: concurrent pollers race the submissions; whichever
    // worker ticks last runs the scrub turn that repairs the damage
    let collected = Mutex::new(Vec::new());
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        for _ in 0..2 {
            s.spawn(|| {
                while !stop.load(Ordering::Acquire) {
                    let got = engine.poll();
                    if got.is_empty() {
                        std::thread::yield_now();
                    } else {
                        collected.lock().unwrap().extend(got);
                    }
                }
            });
        }
        for img in &images {
            engine.submit(0, img.clone()).unwrap();
        }
        stop.store(true, Ordering::Release);
    });
    collected.lock().unwrap().extend(engine.flush());
    assert_eq!(collected.into_inner().unwrap().len(), images.len());
    // idle ticks guarantee a full scrub turn after fault activation
    for _ in 0..3 {
        assert!(engine.poll().is_empty());
    }
    let m = engine.lane_metrics(0);
    assert!(m.scrubbed_rows > 0, "scrub progress must surface");
    assert!(m.faults_detected > 0, "the stuck rows must be flagged");
    assert_eq!(m.faults_repaired, m.faults_detected, "repaired in place");
    assert_eq!(m.unrepairable, 0);
    assert_eq!(m.degraded, DegradedMode::Nominal);
    // epoch 2: bit-exact against a never-faulted sequential pool over
    // the same noise-stream range (request ids 8..16)
    for img in &images {
        engine.submit(0, img.clone()).unwrap();
    }
    let mut got = engine.flush();
    assert_eq!(got.len(), images.len());
    got.sort_by_key(|r| r.id);
    let twin = MacroPool::with_capacity(&model, opts, req);
    let want = twin.classify_batch_at(&images, images.len() as u64);
    for (r, (votes, pred)) in got.iter().zip(&want) {
        assert_eq!(r.prediction, *pred, "healed engine diverged from twin");
        assert_eq!(&r.votes, votes);
    }
}
