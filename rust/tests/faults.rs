//! Fault-injection and self-healing properties (the robustness tentpole):
//! deterministic fault plans, scrub-and-repair bit-exactness, graceful
//! typed degradation, and replayability of whole fault drills.
//!
//! The claims under test, end to end:
//!
//! * an **empty** fault plan is bit-invisible — predictions, cycle
//!   counts, and event counters match a twin pool that never had a plan
//!   injected (the zero-cost guarantee);
//! * any stuck-at pattern **within the spare-row budget** is scrubbed
//!   away and the repaired pool returns to bit-exact agreement with a
//!   never-faulted twin, in both noise modes;
//! * a whole escalating fault drill — injection, detection, repair
//!   schedule, degradation rung — **replays bit-identically** from the
//!   same seeds;
//! * replica-symmetric faults leave predictions **invariant across
//!   worker counts** (the virtual-time scheduling claim);
//! * transients self-clear, spare exhaustion on an output slot ends in
//!   **typed refusal**, spare exhaustion on one hidden replica ends in
//!   **quarantine + bit-exact failover**;
//! * the whole loop holds under **concurrent serving** on the engine's
//!   maintenance seam;
//! * operator **re-admission** is canary-gated: a re-admitted macro
//!   carries zero traffic through probation, passes N consecutive clean
//!   laps, and rejoins serving **bit-exactly** (identical seeding); a
//!   flaky macro is re-quarantined with an **escalating lap requirement**
//!   — never silently readmitted;
//! * the **shared fleet maintenance budget** isolates tenants: a
//!   fault-heavy lane cannot starve a sibling's scrub cursor.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use picbnn::accel::{
    BatchPolicy, FleetConfig, FleetMaintenance, MacroPool, MultiPool, PipelineOptions,
    RepairAction, ScrubConfig, ScrubController, ScrubStats,
};
use picbnn::bnn::mapping::program_row;
use picbnn::bnn::model::{MappedLayer, MappedModel};
use picbnn::cam::{
    DegradedMode, FaultKind, FaultPlan, FaultSite, HealthState, NoiseMode, RailId,
    DEFAULT_PROBATION_LAPS, DEFAULT_SPARE_ROWS,
};
use picbnn::server::{Clock, Engine, MultiServer};
use picbnn::testkit::{forall, prop_assert, Gen};
use picbnn::util::bitops::{BitMatrix, BitVec};
use picbnn::util::rng::Rng;

fn opts_for(analog: bool) -> PipelineOptions {
    PipelineOptions {
        noise: if analog {
            NoiseMode::Analog
        } else {
            NoiseMode::Nominal
        },
        ..Default::default()
    }
}

/// Exhaustive single-turn scrub: one `maintain()` laps the whole pool.
fn full_pass(workers: usize) -> ScrubConfig {
    ScrubConfig {
        rows_per_turn: 1 << 20,
        workers,
        ..Default::default()
    }
}

/// Draw a random single-segment mapped layer (props.rs fixture).
fn gen_layer(g: &mut Gen, n_out: usize, n_in: usize, width: usize) -> MappedLayer {
    let rows: Vec<BitVec> = (0..n_out)
        .map(|_| BitVec::from_pm1(&g.pm1_vec(n_in)))
        .collect();
    let pads = width - n_in;
    let q = vec![(0..n_out)
        .map(|_| g.usize_in(0, pads) as i32)
        .collect::<Vec<_>>()];
    MappedLayer {
        weights: BitMatrix::from_rows(&rows),
        q,
        seg_bounds: vec![0, n_in],
        seg_width: width,
    }
}

fn gen_model(g: &mut Gen) -> MappedModel {
    let n_in = g.usize_in(16, 120);
    let h = g.usize_in(4, 24);
    let n_cls = g.usize_in(2, 10);
    let l1 = gen_layer(g, h, n_in, (n_in + 16).max(64));
    let l2 = gen_layer(g, n_cls, h, (h + 16).max(64));
    MappedModel {
        layers: vec![l1, l2],
        schedule: (0..=64).step_by(2).collect(),
    }
}

/// Deterministic fixture for the directed drills: 64 → 8 → 6 with a
/// short schedule (6 output classes so an output slot can outlast the
/// spare budget; 8 hidden rows so a replica can, too).
fn fixed_model(seed: u64) -> MappedModel {
    let mut rng = Rng::new(seed, 77);
    let mut mk = |n_out: usize, n_in: usize, width: usize| {
        let rows: Vec<BitVec> = (0..n_out)
            .map(|_| {
                let mut v = BitVec::zeros(n_in);
                for i in 0..n_in {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect();
        let pads = width - n_in;
        let q = vec![(0..n_out)
            .map(|_| rng.range_u64(0, pads as u64) as i32)
            .collect()];
        MappedLayer {
            weights: BitMatrix::from_rows(&rows),
            q,
            seg_bounds: vec![0, n_in],
            seg_width: width,
        }
    };
    let l1 = mk(8, 64, 128);
    let l2 = mk(6, 8, 128);
    MappedModel {
        layers: vec![l1, l2],
        schedule: (0..=16).step_by(2).collect(),
    }
}

fn rand_images(n: usize, bits: usize, seed: u64) -> Vec<BitVec> {
    let mut rng = Rng::new(seed, 1);
    (0..n)
        .map(|_| {
            let mut v = BitVec::zeros(bits);
            for i in 0..bits {
                v.set(i, rng.chance(0.5));
            }
            v
        })
        .collect()
}

#[test]
fn prop_empty_fault_plan_is_bit_invisible() {
    // the zero-cost guarantee: injecting an empty plan changes nothing —
    // not predictions, not cycle accounting, not event counters — in
    // either noise mode
    forall(6, 4501, |g| {
        let model = gen_model(g);
        let images: Vec<BitVec> = (0..6)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(model.n_in())))
            .collect();
        for analog in [false, true] {
            let opts = opts_for(analog);
            let req = MacroPool::macros_required(&model, &opts);
            let pool = MacroPool::with_capacity(&model, opts, req);
            let twin = MacroPool::with_capacity(&model, opts, req);
            pool.inject_fault_plan(FaultPlan::default());
            let mut base = 0u64;
            for _ in 0..2 {
                prop_assert(
                    pool.classify_batch_at(&images, base)
                        == twin.classify_batch_at(&images, base),
                    format!("analog={analog}: empty plan perturbed predictions"),
                )?;
                base += images.len() as u64;
            }
            let a = pool.take_stats(base);
            let b = twin.take_stats(base);
            prop_assert(a.cycles == b.cycles, "empty plan changed cycle counts")?;
            prop_assert(a.stall_s == b.stall_s, "empty plan changed stall time")?;
            prop_assert(a.events == b.events, "empty plan changed event counters")?;
            prop_assert(
                a.degraded == DegradedMode::Nominal,
                "empty plan degraded the pool",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_stuck_at_within_spares_repairs_bit_exact() {
    // the tentpole's repair property: ANY stuck-at pattern touching at
    // most DEFAULT_SPARE_ROWS rows of one site is scrubbed away, and the
    // repaired pool's predictions are bit-exact against a never-faulted
    // twin — in both noise modes.  (A stuck cell whose forced value
    // agrees with the stored bit is genuinely harmless: undetectable by
    // design, and invisible to predictions, so it cannot break either
    // assertion below.)
    forall(6, 4503, |g| {
        let model = gen_model(g);
        let images: Vec<BitVec> = (0..5)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(model.n_in())))
            .collect();
        for analog in [false, true] {
            let opts = opts_for(analog);
            let req = MacroPool::macros_required(&model, &opts);
            let pool = MacroPool::with_capacity(&model, opts, req);
            let twin = MacroPool::with_capacity(&model, opts, req);
            let sites = pool.fault_sites();
            prop_assert(!sites.is_empty(), "full residency must expose sites")?;
            let site = sites[g.usize_in(0, sites.len() - 1)];
            // distinct rows within the spare budget, random cells on each
            let mut avail: Vec<usize> = (0..site.rows).collect();
            let k = g.usize_in(1, DEFAULT_SPARE_ROWS.min(site.rows));
            let mut plan = FaultPlan::default();
            for _ in 0..k {
                let row = avail.swap_remove(g.usize_in(0, avail.len() - 1));
                for _ in 0..g.usize_in(1, 2) {
                    let col = g.usize_in(0, site.width - 1);
                    let bit = g.bool();
                    plan.push(0, site.site, FaultKind::StuckBit { row, col, bit });
                }
            }
            pool.inject_fault_plan(plan);
            // first batch activates the faults; the scrub pass repairs
            pool.classify_batch_at(&images, 0);
            let mut ctl = ScrubController::new(11, full_pass(1));
            let d1 = ctl.maintain(&pool);
            prop_assert(d1.rows_scrubbed > 0, "scrub made no progress")?;
            prop_assert(
                d1.repairs == d1.faults_detected,
                format!(
                    "analog={analog}: {} detected but {} repaired in place",
                    d1.faults_detected, d1.repairs
                ),
            )?;
            prop_assert(
                d1.rebuilds == 0 && d1.quarantines == 0 && d1.unrepairable == 0,
                "within the spare budget nothing may escalate",
            )?;
            // a second full pass over the repaired pool finds nothing
            let d2 = ctl.maintain(&pool);
            prop_assert(
                d2.faults_detected == 0,
                format!("analog={analog}: residual faults after repair"),
            )?;
            prop_assert(
                ctl.degraded_mode() == DegradedMode::Nominal,
                "repair must keep the pool nominal",
            )?;
            // post-repair predictions are bit-exact against the twin
            let base = images.len() as u64;
            prop_assert(
                pool.classify_batch_at(&images, base)
                    == twin.classify_batch_at(&images, base),
                format!("analog={analog}: repaired pool diverged from the twin"),
            )?;
        }
        Ok(())
    });
}

/// One full escalating fault drill: serve batches, maintain between
/// them, record everything observable.
#[allow(clippy::type_complexity)]
fn run_drill(
    model: &MappedModel,
    plan: &FaultPlan,
    images: &[BitVec],
    rounds: usize,
) -> (
    Vec<Vec<(Vec<u32>, usize)>>,
    Vec<picbnn::accel::FaultReport>,
    ScrubStats,
    DegradedMode,
) {
    let opts = opts_for(true);
    let req = MacroPool::macros_required(model, &opts);
    let pool = MacroPool::with_capacity_for_workers(model, opts, req + 1, 2);
    pool.inject_fault_plan(plan.clone());
    let mut ctl = ScrubController::new(
        0xD2,
        ScrubConfig {
            rows_per_turn: 8,
            workers: 2,
            ..Default::default()
        },
    );
    let mut preds = Vec::new();
    let mut base = 0u64;
    for _ in 0..rounds {
        preds.push(pool.classify_batch_at(images, base));
        base += images.len() as u64;
        ctl.maintain(&pool);
    }
    (preds, ctl.take_reports(), ctl.stats(), ctl.degraded_mode())
}

#[test]
fn fault_drill_replays_bit_identically() {
    // satellite 3: same FaultPlan seed + same workload trace → bit-
    // identical fault reports, repair schedule, predictions, and final
    // degradation rung, run to run (fixed worker shape: the escalating
    // plan's replica-0 phase is deliberately asymmetric, so cross-worker
    // invariance is the next test's job, on a symmetric plan)
    let model = fixed_model(4507);
    let images = rand_images(6, 64, 17);
    let opts = opts_for(true);
    let req = MacroPool::macros_required(&model, &opts);
    let sites = MacroPool::with_capacity_for_workers(&model, opts, req + 1, 2).fault_sites();
    assert!(
        sites.iter().any(|s| s.replicas > 1),
        "the drill needs a replicated hidden load for its failover phase"
    );
    let plan = FaultPlan::escalating(0xD1, &sites, images.len() as u64, 4);
    assert!(!plan.is_empty());
    let last_at = plan.events.iter().map(|e| e.at_image).max().unwrap();
    let rounds = (last_at / images.len() as u64) as usize + 16;
    let a = run_drill(&model, &plan, &images, rounds);
    let b = run_drill(&model, &plan, &images, rounds);
    assert_eq!(a.0, b.0, "prediction traces diverged between replays");
    assert_eq!(a.1, b.1, "fault reports diverged between replays");
    assert_eq!(a.2, b.2, "repair schedules diverged between replays");
    assert_eq!(a.3, b.3, "degradation rungs diverged between replays");
    assert!(a.2.faults_detected > 0, "the drill detected nothing");
    assert!(a.2.repairs > 0, "the drill repaired nothing");
}

#[test]
fn symmetric_fault_plan_is_worker_count_invariant() {
    // faults that hit every replica identically (replica: None, slot:
    // None) are scheduled in image-stream time, so predictions are
    // invariant across worker counts / replica fan-outs.  Transients are
    // deliberately excluded: their burn-down counters live per physical
    // array, so per-copy routing makes them worker-shape-dependent by
    // design (which is why FaultPlan::escalating keeps its asymmetric
    // phases out of this invariance claim).
    let model = fixed_model(4511);
    let images = rand_images(6, 64, 19);
    let hidden = FaultSite::Hidden {
        layer: 0,
        load: 0,
        replica: None,
    };
    let mut plan = FaultPlan::default();
    for row in 0..3usize {
        let golden = program_row(&model.layers[0], 0, row);
        plan.push(
            0,
            hidden,
            FaultKind::StuckBit {
                row,
                col: 0,
                bit: !golden.get(0),
            },
        );
    }
    plan.push(
        6,
        hidden,
        FaultKind::DeadRow {
            row: 3,
            always_fire: true,
        },
    );
    plan.push(
        12,
        hidden,
        FaultKind::DacDrift {
            rail: RailId::Vref,
            volts: 0.004,
        },
    );
    let out_golden = program_row(&model.layers[1], 0, 0);
    plan.push(
        12,
        FaultSite::Output { slot: None },
        FaultKind::StuckBit {
            row: 0,
            col: 0,
            bit: !out_golden.get(0),
        },
    );
    for analog in [false, true] {
        let opts = opts_for(analog);
        let req = MacroPool::macros_required(&model, &opts);
        let one = MacroPool::with_capacity_for_workers(&model, opts, req + 2, 1);
        let three = MacroPool::with_capacity_for_workers(&model, opts, req + 2, 3);
        one.inject_fault_plan(plan.clone());
        three.inject_fault_plan(plan.clone());
        let mut base = 0u64;
        for round in 0..4 {
            assert_eq!(
                one.classify_batch_at(&images, base),
                three.classify_batch_at(&images, base),
                "analog={analog} round={round}: symmetric faults must not \
                 depend on the worker shape"
            );
            base += images.len() as u64;
        }
    }
}

#[test]
fn transient_upsets_self_clear_without_repair() {
    // a transient inverts its row's next N evaluations and then clears
    // itself: the following batch is already bit-exact again, and the
    // scrub pass — arriving after the burn-down — finds nothing to fix
    let model = fixed_model(4513);
    let images = rand_images(4, 64, 23);
    for analog in [false, true] {
        let opts = opts_for(analog);
        let req = MacroPool::macros_required(&model, &opts);
        let pool = MacroPool::with_capacity(&model, opts, req);
        let twin = MacroPool::with_capacity(&model, opts, req);
        let mut plan = FaultPlan::default();
        plan.push(
            0,
            FaultSite::Hidden {
                layer: 0,
                load: 0,
                replica: None,
            },
            FaultKind::Transient {
                row: 0,
                searches: 2,
            },
        );
        pool.inject_fault_plan(plan);
        // batch 1: the upset may flip predictions (4 evaluations of the
        // row burn the 2-search counter down); no assertion on values
        pool.classify_batch_at(&images, 0);
        // batch 2: self-cleared, bit-exact against the twin
        let base = images.len() as u64;
        assert_eq!(
            pool.classify_batch_at(&images, base),
            twin.classify_batch_at(&images, base),
            "analog={analog}: transient failed to self-clear"
        );
        let mut ctl = ScrubController::new(13, full_pass(1));
        let d = ctl.maintain(&pool);
        assert!(d.rows_scrubbed > 0);
        assert_eq!(
            d.faults_detected, 0,
            "analog={analog}: a burned-down transient left residue"
        );
        assert_eq!(ctl.degraded_mode(), DegradedMode::Nominal);
    }
}

#[test]
fn output_slot_beyond_spares_refuses_typed() {
    // graceful degradation's last rung: dead rows past the spare budget
    // on an output slot (no quarantine path — the threshold sweep needs
    // every slot) drive the pool to typed refusal, never to silently
    // wrong answers.  max_rebuilds: 0 jumps the ladder straight there.
    let model = fixed_model(4517);
    let images = rand_images(4, 64, 29);
    let opts = opts_for(false);
    let req = MacroPool::macros_required(&model, &opts);
    let pool = MacroPool::with_capacity(&model, opts, req);
    let slot = FaultSite::Output { slot: Some(0) };
    assert!(
        pool.output_rows() > DEFAULT_SPARE_ROWS,
        "fixture must have more output rows than spares"
    );
    let mut plan = FaultPlan::default();
    for row in 0..=DEFAULT_SPARE_ROWS {
        plan.push(
            0,
            slot,
            FaultKind::DeadRow {
                row,
                always_fire: row % 2 == 0,
            },
        );
    }
    pool.inject_fault_plan(plan);
    pool.classify_batch_at(&images, 0);
    let mut ctl = ScrubController::new(
        17,
        ScrubConfig {
            max_rebuilds: 0,
            ..full_pass(1)
        },
    );
    let d = ctl.maintain(&pool);
    assert!(
        d.faults_detected > DEFAULT_SPARE_ROWS as u64,
        "every dead row must be flagged"
    );
    assert_eq!(
        d.repairs, DEFAULT_SPARE_ROWS as u64,
        "exactly the spare budget is remapped"
    );
    assert_eq!(d.unrepairable, 1, "the row past the spares is terminal");
    assert_eq!(ctl.degraded_mode(), DegradedMode::Refusing);
    assert_eq!(pool.degraded_mode(), DegradedMode::Refusing);
    assert!(
        ctl.take_reports()
            .iter()
            .any(|r| r.action == RepairAction::Unrepairable),
        "the terminal outcome must be reported"
    );
    // the rung is stamped into the device stats for observability
    assert_eq!(pool.take_stats(4).degraded, DegradedMode::Refusing);
}

#[test]
fn hidden_replica_quarantine_fails_over_bit_exact() {
    // spare exhaustion on ONE copy of a replicated hidden load ends in
    // quarantine, not refusal: the surviving identically-seeded sibling
    // keeps serving bit-exactly, and the pool reports Failover
    let model = fixed_model(4519);
    let images = rand_images(6, 64, 31);
    for analog in [false, true] {
        let opts = opts_for(analog);
        let req = MacroPool::macros_required(&model, &opts);
        let pool = MacroPool::with_capacity_for_workers(&model, opts, req + 1, 2);
        let twin = MacroPool::with_capacity_for_workers(&model, opts, req + 1, 2);
        let sites = pool.fault_sites();
        assert_eq!(
            sites[0].replicas, 2,
            "the surplus macro must buy a hidden replica"
        );
        let mut plan = FaultPlan::default();
        for row in 0..=DEFAULT_SPARE_ROWS {
            plan.push(
                0,
                FaultSite::Hidden {
                    layer: 0,
                    load: 0,
                    replica: Some(0),
                },
                FaultKind::DeadRow {
                    row,
                    always_fire: true,
                },
            );
        }
        pool.inject_fault_plan(plan);
        pool.classify_batch_at(&images, 0);
        let mut ctl = ScrubController::new(
            19,
            ScrubConfig {
                max_rebuilds: 0,
                ..full_pass(2)
            },
        );
        let d = ctl.maintain(&pool);
        assert_eq!(
            d.quarantines, 1,
            "analog={analog}: the dying copy must be retired"
        );
        assert_eq!(d.unrepairable, 0, "quarantine is not refusal");
        assert_eq!(ctl.degraded_mode(), DegradedMode::Failover);
        assert_eq!(pool.degraded_mode(), DegradedMode::Failover);
        // drain the post-quarantine re-plan (one migration step per turn)
        for _ in 0..12 {
            ctl.maintain(&pool);
        }
        assert!(!ctl.migration_in_flight(), "the re-plan must converge");
        // failover is bit-exact: the surviving replica answers exactly
        // as the never-faulted twin does
        let base = images.len() as u64;
        assert_eq!(
            pool.classify_batch_at(&images, base),
            twin.classify_batch_at(&images, base),
            "analog={analog}: failover must not change predictions"
        );
    }
}

#[test]
fn concurrent_serving_heals_under_scrub() {
    // the whole loop on the engine's maintenance seam, with worker
    // threads polling concurrently: inject, serve (faults activate),
    // scrub + repair between batches, then serve a second epoch that is
    // bit-exact against a never-faulted sequential pool
    let model = fixed_model(4523);
    let images = rand_images(8, 64, 37);
    let opts = opts_for(false);
    let req = MacroPool::macros_required(&model, &opts);
    let engine = Engine::single(
        &model,
        opts,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::ZERO,
        },
        req,
    )
    .with_clock(Clock::simulated())
    .with_scrub(0, 23, full_pass(1));
    let mut plan = FaultPlan::default();
    for row in 0..3usize {
        let golden = program_row(&model.layers[0], 0, row);
        plan.push(
            0,
            FaultSite::Hidden {
                layer: 0,
                load: 0,
                replica: None,
            },
            FaultKind::StuckBit {
                row,
                col: 0,
                bit: !golden.get(0),
            },
        );
    }
    engine.single_pool().inject_fault_plan(plan);
    // epoch 1: concurrent pollers race the submissions; whichever
    // worker ticks last runs the scrub turn that repairs the damage
    let collected = Mutex::new(Vec::new());
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        for _ in 0..2 {
            s.spawn(|| {
                while !stop.load(Ordering::Acquire) {
                    let got = engine.poll();
                    if got.is_empty() {
                        std::thread::yield_now();
                    } else {
                        collected.lock().unwrap().extend(got);
                    }
                }
            });
        }
        for img in &images {
            engine.submit(0, img.clone()).unwrap();
        }
        stop.store(true, Ordering::Release);
    });
    collected.lock().unwrap().extend(engine.flush());
    assert_eq!(collected.into_inner().unwrap().len(), images.len());
    // idle ticks guarantee a full scrub turn after fault activation
    for _ in 0..3 {
        assert!(engine.poll().is_empty());
    }
    let m = engine.lane_metrics(0);
    assert!(m.scrubbed_rows > 0, "scrub progress must surface");
    assert!(m.faults_detected > 0, "the stuck rows must be flagged");
    assert_eq!(m.faults_repaired, m.faults_detected, "repaired in place");
    assert_eq!(m.unrepairable, 0);
    assert_eq!(m.degraded, DegradedMode::Nominal);
    // epoch 2: bit-exact against a never-faulted sequential pool over
    // the same noise-stream range (request ids 8..16)
    for img in &images {
        engine.submit(0, img.clone()).unwrap();
    }
    let mut got = engine.flush();
    assert_eq!(got.len(), images.len());
    got.sort_by_key(|r| r.id);
    let twin = MacroPool::with_capacity(&model, opts, req);
    let want = twin.classify_batch_at(&images, images.len() as u64);
    for (r, (votes, pred)) in got.iter().zip(&want) {
        assert_eq!(r.prediction, *pred, "healed engine diverged from twin");
        assert_eq!(&r.votes, votes);
    }
}

#[test]
fn readmission_after_canary_gate_is_bit_exact() {
    // the re-admission tentpole: quarantine one copy of a replicated
    // hidden load, drain the health-aware re-plan, then walk the
    // operator workflow — un_quarantine → probation (zero traffic) →
    // N consecutive clean canary laps → readmitted as a live replica.
    // Identical seeding makes the readmitted macro bit-identical to the
    // copy a never-faulted twin holds, in both noise modes, and the
    // re-admission is the one path that lifts Failover back to Nominal.
    let model = fixed_model(4519);
    let images = rand_images(6, 64, 43);
    for analog in [false, true] {
        let opts = opts_for(analog);
        let req = MacroPool::macros_required(&model, &opts);
        let pool = MacroPool::with_capacity_for_workers(&model, opts, req + 1, 2);
        let twin = MacroPool::with_capacity_for_workers(&model, opts, req + 1, 2);
        assert_eq!(pool.fault_sites()[0].replicas, 2);
        let mut plan = FaultPlan::default();
        for row in 0..=DEFAULT_SPARE_ROWS {
            plan.push(
                0,
                FaultSite::Hidden {
                    layer: 0,
                    load: 0,
                    replica: Some(0),
                },
                FaultKind::DeadRow {
                    row,
                    always_fire: true,
                },
            );
        }
        pool.inject_fault_plan(plan);
        let mut base = 0u64;
        pool.classify_batch_at(&images, base);
        twin.classify_batch_at(&images, base);
        base += images.len() as u64;
        let mut ctl = ScrubController::new(
            19,
            ScrubConfig {
                max_rebuilds: 0,
                ..full_pass(2)
            },
        );
        let d = ctl.maintain(&pool);
        assert_eq!(
            d.quarantines, 1,
            "analog={analog}: the dying copy must be retired"
        );
        assert_eq!(pool.health_quarantined(), 1);
        assert_eq!(ctl.degraded_mode(), DegradedMode::Failover);
        for _ in 0..12 {
            ctl.maintain(&pool);
        }
        assert!(!ctl.migration_in_flight(), "the re-plan must converge");
        // operator re-admission: exactly one macro is on the ladder
        assert!(pool.un_quarantine(0, 0), "analog={analog}: re-admission");
        assert!(!pool.un_quarantine(0, 0), "only one macro is written off");
        // probation carries zero serving traffic: predictions stay
        // bit-exact against the twin through every canary lap
        let mut total = ScrubStats::default();
        for _ in 0..DEFAULT_PROBATION_LAPS {
            assert_eq!(
                pool.classify_batch_at(&images, base),
                twin.classify_batch_at(&images, base),
                "analog={analog}: probation must not serve"
            );
            base += images.len() as u64;
            total.add(&ctl.maintain(&pool));
        }
        assert_eq!(total.probation_laps, u64::from(DEFAULT_PROBATION_LAPS));
        assert_eq!(
            total.readmissions, 1,
            "analog={analog}: the canary gate must open"
        );
        assert_eq!(total.probation_failures, 0);
        assert_eq!(pool.health_quarantined(), 0);
        // the only path out of Failover runs through the canary gate
        assert_eq!(ctl.degraded_mode(), DegradedMode::Nominal);
        assert_eq!(pool.degraded_mode(), DegradedMode::Nominal);
        // capacity is genuinely back: the load holds two live replicas
        assert_eq!(pool.fault_sites()[0].replicas, 2);
        let h = pool.health_registry().get(&FaultSite::Hidden {
            layer: 0,
            load: 0,
            replica: Some(0),
        });
        assert_eq!(h.state, HealthState::Readmitted);
        assert_eq!(h.readmissions, 1);
        // and the readmitted replica answers bit-exactly
        assert_eq!(
            pool.classify_batch_at(&images, base),
            twin.classify_batch_at(&images, base),
            "analog={analog}: readmitted replica diverged from the twin"
        );
    }
}

#[test]
fn flaky_probation_macro_requarantines_with_escalating_backoff() {
    // probation is a gate, not a formality: a flaky macro passes N-1
    // canary laps and fails the last one — it must be re-quarantined
    // (never silently readmitted) and its next probation must demand
    // twice the laps.  The whole drill replays bit-identically.
    let model = fixed_model(4519);
    let images = rand_images(6, 64, 47);
    let drill = |analog: bool, seed: u64| {
        let opts = opts_for(analog);
        let req = MacroPool::macros_required(&model, &opts);
        let pool = MacroPool::with_capacity_for_workers(&model, opts, req + 1, 2);
        let twin = MacroPool::with_capacity_for_workers(&model, opts, req + 1, 2);
        let mut plan = FaultPlan::default();
        for row in 0..=DEFAULT_SPARE_ROWS {
            plan.push(
                0,
                FaultSite::Hidden {
                    layer: 0,
                    load: 0,
                    replica: Some(0),
                },
                FaultKind::DeadRow {
                    row,
                    always_fire: true,
                },
            );
        }
        pool.inject_fault_plan(plan);
        let mut base = 0u64;
        pool.classify_batch_at(&images, base);
        twin.classify_batch_at(&images, base);
        base += images.len() as u64;
        let mut ctl = ScrubController::new(
            seed,
            ScrubConfig {
                max_rebuilds: 0,
                ..full_pass(2)
            },
        );
        let mut total = ctl.maintain(&pool);
        assert_eq!(total.quarantines, 1);
        for _ in 0..12 {
            total.add(&ctl.maintain(&pool));
        }
        assert!(pool.un_quarantine(0, 0));
        // N-1 clean canary laps: the gate stays closed ...
        for _ in 0..DEFAULT_PROBATION_LAPS - 1 {
            total.add(&ctl.maintain(&pool));
        }
        assert_eq!(total.probation_laps, u64::from(DEFAULT_PROBATION_LAPS - 1));
        assert_eq!(total.readmissions, 0, "the gate must still be closed");
        // ... then the macro flakes: a dead row lands on the probation
        // side-array just before the final lap (replica indices past the
        // live copies address probation macros in admission order, and
        // the live replica is unharmed)
        let mut flake = FaultPlan::default();
        flake.push(
            base,
            FaultSite::Hidden {
                layer: 0,
                load: 0,
                replica: Some(1),
            },
            FaultKind::DeadRow {
                row: 0,
                always_fire: false,
            },
        );
        pool.inject_fault_plan(flake);
        assert_eq!(
            pool.classify_batch_at(&images, base),
            twin.classify_batch_at(&images, base),
            "a probation flake must never touch serving"
        );
        base += images.len() as u64;
        total.add(&ctl.maintain(&pool));
        assert_eq!(total.probation_failures, 1, "the flake must fail the canary");
        assert_eq!(total.readmissions, 0, "no silent re-admission");
        assert_eq!(pool.health_quarantined(), 1, "back on the quarantine ladder");
        assert_eq!(ctl.degraded_mode(), DegradedMode::Failover);
        let site = FaultSite::Hidden {
            layer: 0,
            load: 0,
            replica: Some(0),
        };
        assert_eq!(pool.health_registry().get(&site).probation_failures, 1);
        // second attempt (a fresh replacement macro): the lap
        // requirement has doubled
        assert!(pool.un_quarantine(0, 0));
        let h = pool.health_registry().get(&site);
        assert_eq!(h.state, HealthState::Probation);
        assert_eq!(
            h.required_laps,
            DEFAULT_PROBATION_LAPS << 1,
            "back-off must escalate"
        );
        for _ in 0..h.required_laps {
            total.add(&ctl.maintain(&pool));
        }
        assert_eq!(total.readmissions, 1);
        assert_eq!(pool.health_quarantined(), 0);
        assert_eq!(ctl.degraded_mode(), DegradedMode::Nominal);
        let got = pool.classify_batch_at(&images, base);
        assert_eq!(
            got,
            twin.classify_batch_at(&images, base),
            "recovered pool diverged from the twin"
        );
        (
            got,
            total.probation_laps,
            total.probation_failures,
            total.readmissions,
        )
    };
    for analog in [false, true] {
        assert_eq!(
            drill(analog, 19),
            drill(analog, 19),
            "analog={analog}: the back-off drill must replay bit-exactly"
        );
    }
}

#[test]
fn prop_fleet_budget_isolates_healthy_lanes() {
    // the shared maintenance budget is metered by deficit round-robin,
    // so a fault-heavy tenant cannot starve its siblings' scrub cursors.
    // Two claims over random tenant mixes: (1) sibling lanes' lap and
    // detection counters are bit-identical whether or not lane 0 is
    // being bombed (isolation), and (2) every lane's cursor progress —
    // the bombed one included — tracks its fair credit share to within
    // one lap plus the carry bank (bounded gap).
    forall(4, 4531, |g| {
        let n_tenants = g.usize_in(2, 3);
        let models: Vec<MappedModel> = (0..n_tenants).map(|_| gen_model(g)).collect();
        let refs: Vec<&MappedModel> = models.iter().collect();
        let opts = opts_for(false);
        let budget = refs
            .iter()
            .map(|m| MacroPool::macros_required(m, &opts))
            .sum::<usize>();
        let images: Vec<BitVec> = (0..4)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(models[0].n_in())))
            .collect();
        let probe = MultiPool::new(&refs, opts, budget);
        prop_assert(probe.plan().is_some(), "the budget must fit the floors")?;
        let lane_rows: Vec<usize> = (0..n_tenants)
            .map(|t| probe.tenant(t).fault_sites().iter().map(|s| s.rows).sum())
            .collect();
        let gaps = lane_rows.iter().max().unwrap() * 2;
        let cfg = FleetConfig {
            rows_per_gap: 2 * n_tenants,
            carry_cap: 16,
            scrub: ScrubConfig::default(),
            replan: None,
        };
        let run = |faulty: bool| {
            let pool = MultiPool::new(&refs, opts, budget);
            if faulty {
                // bomb lane 0 with dead rows within the spare budget:
                // every one is detected and remapped, on lane 0's credit
                let site = pool.tenant(0).fault_sites()[0];
                let mut plan = FaultPlan::default();
                for row in 0..DEFAULT_SPARE_ROWS.min(site.rows) {
                    plan.push(
                        0,
                        site.site,
                        FaultKind::DeadRow {
                            row,
                            always_fire: true,
                        },
                    );
                }
                pool.tenant(0).inject_fault_plan(plan);
            }
            pool.classify_batch_at(0, &images, 0);
            let mut fleet = FleetMaintenance::new(&pool, 31, cfg);
            for _ in 0..gaps {
                fleet.maintain(&pool);
            }
            (0..n_tenants)
                .map(|t| (fleet.lane_laps(t), fleet.lane_scrub(t).stats().faults_detected))
                .collect::<Vec<_>>()
        };
        let clean = run(false);
        let bombed = run(true);
        prop_assert(bombed[0].1 > 0, "the bombed lane must see its faults")?;
        for t in 1..n_tenants {
            prop_assert(
                clean[t] == bombed[t],
                format!("lane {t}: a sibling's faults leaked into its maintenance"),
            )?;
            prop_assert(bombed[t].1 == 0, format!("lane {t} saw phantom faults"))?;
        }
        // bounded gap: a lane's cursor progress (laps x rows, give or
        // take the lap in flight and the deferred wrap) stays within the
        // carry bank of its fair credit share
        let quantum = cfg.rows_per_gap / n_tenants;
        for t in 0..n_tenants {
            prop_assert(
                (bombed[t].0 as usize + 2) * lane_rows[t] + cfg.carry_cap >= gaps * quantum,
                format!(
                    "lane {t}: {} laps of {} rows lag the fair share of {} gaps",
                    bombed[t].0, lane_rows[t], gaps
                ),
            )?;
        }
        Ok(())
    });
}

#[test]
fn multi_tenant_drill_recovers_capacity_through_operator_readmission() {
    // the fleet drill on the MultiServer facade (the CI chaos lane runs
    // this under a pinned fault seed): a storm writes off tenant 0's
    // only copy of a hidden load (cold spill + Failover) while tenant 1
    // serves untouched under the shared maintenance budget; the operator
    // re-admits the macro, the canary gate passes, and tenant 0 comes
    // back Nominal with its capacity restored — bit-exact against a
    // never-faulted twin.
    let a = fixed_model(4519);
    let b = fixed_model(4527);
    let models = [&a, &b];
    let opts = opts_for(false);
    let req: usize = models
        .iter()
        .map(|m| MacroPool::macros_required(m, &opts))
        .sum();
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: Duration::ZERO,
    };
    let mut srv = MultiServer::new(&models, opts, policy, req).with_fleet_maintenance(
        37,
        FleetConfig {
            rows_per_gap: 1 << 16,
            carry_cap: 1 << 16,
            scrub: ScrubConfig {
                max_rebuilds: 0,
                workers: 1,
                ..ScrubConfig::default()
            },
            replan: None,
        },
    );
    let images = rand_images(6, 64, 53);
    // kill tenant 0's only copy of hidden load (0, 0) beyond the spares
    let mut plan = FaultPlan::default();
    for row in 0..=DEFAULT_SPARE_ROWS {
        plan.push(
            0,
            FaultSite::Hidden {
                layer: 0,
                load: 0,
                replica: Some(0),
            },
            FaultKind::DeadRow {
                row,
                always_fire: true,
            },
        );
    }
    srv.pool().tenant(0).inject_fault_plan(plan);
    // epoch 1: both tenants serve; the storm lands on tenant 0
    for img in &images {
        srv.submit(0, img.clone());
        srv.submit(1, img.clone());
    }
    let got = srv.poll(true);
    assert_eq!(got.len(), 2 * images.len());
    // idle gaps: detection → spare exhaustion → quarantine of the last
    // copy (cold spill) → the health-aware re-plan drains
    for _ in 0..24 {
        assert!(srv.poll(false).is_empty());
    }
    let snap = srv.health_snapshot();
    assert_eq!(snap.len(), 2);
    assert_eq!(snap[0].degraded, DegradedMode::Failover);
    assert_eq!(snap[0].quarantined, 1);
    assert_eq!(snap[0].readmissions, 0);
    assert_eq!(snap[1].degraded, DegradedMode::Nominal);
    assert_eq!(snap[1].quarantined, 0);
    let m0 = srv.metrics(0);
    assert_eq!(m0.replica_quarantines, 1);
    assert!(m0.faults_detected > 0);
    assert_eq!(
        srv.metrics(1).faults_detected,
        0,
        "tenant 1 must be untouched"
    );
    // operator workflow: re-admit, then let the shared budget canary-lap
    assert!(srv.un_quarantine(0, 0, 0));
    assert!(!srv.un_quarantine(0, 0, 0), "one macro is on the ladder");
    for _ in 0..DEFAULT_PROBATION_LAPS + 2 {
        assert!(srv.poll(false).is_empty());
    }
    let h0 = srv.health(0);
    assert_eq!(h0.quarantined, 0);
    assert_eq!(h0.readmissions, 1, "the canary gate must readmit");
    assert_eq!(h0.probation_failures, 0);
    assert_eq!(
        h0.degraded,
        DegradedMode::Nominal,
        "re-admission must lift Failover"
    );
    let site = FaultSite::Hidden {
        layer: 0,
        load: 0,
        replica: Some(0),
    };
    assert_eq!(h0.registry.get(&site).state, HealthState::Readmitted);
    // capacity restored: the load is resident again with one live copy
    assert_eq!(
        srv.pool().tenant(0).plan().unwrap().hidden_replicas[0][0],
        1
    );
    // epoch 2: bit-exact against never-faulted twins on the same
    // noise-stream range for both tenants
    for img in &images {
        srv.submit(0, img.clone());
        srv.submit(1, img.clone());
    }
    let mut got = srv.poll(true);
    assert_eq!(got.len(), 2 * images.len());
    got.sort_by_key(|r| (r.tenant, r.id));
    let base = images.len() as u64;
    for (t, model) in models.iter().enumerate() {
        let twin =
            MacroPool::with_capacity(model, opts, MacroPool::macros_required(model, &opts));
        let want = twin.classify_batch_at(&images, base);
        let lane: Vec<_> = got.iter().filter(|r| r.tenant == t).collect();
        assert_eq!(lane.len(), want.len());
        for (r, (votes, pred)) in lane.iter().zip(&want) {
            assert_eq!(r.prediction, *pred, "tenant {t} diverged after recovery");
            assert_eq!(&r.votes, votes);
        }
    }
}
