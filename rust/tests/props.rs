//! Cross-module property tests (the testkit mini-framework): coordinator
//! invariants — mapping/routing/batching/placement — over random models.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use picbnn::accel::{
    planner, BatchPolicy, MacroPool, MigrationStats, MultiPool, Pipeline, PipelineOptions,
    ReplanConfig, ReplanController,
};
use picbnn::analog::{MatchlineModel, Pvt, Voltages};
use picbnn::bnn::infer::{digital_forward, sweep_votes};
use picbnn::bnn::mapping::{expected_mismatches, program_row, segment_query};
use picbnn::bnn::model::{MappedLayer, MappedModel};
use picbnn::cam::{CamArray, CamConfig, NoiseMode};
use picbnn::server::{Clock, Engine};
use picbnn::testkit::{forall, prop_assert, Gen};
use picbnn::util::bitops::{
    available_backends, hamming_words, hamming_words_masked_with, hamming_words_with, BitMatrix,
    BitVec, HammingBackend,
};
use picbnn::util::rng::Rng;

/// Draw a random single-segment mapped layer.
fn gen_layer(g: &mut Gen, n_out: usize, n_in: usize, width: usize) -> MappedLayer {
    let rows: Vec<BitVec> = (0..n_out)
        .map(|_| BitVec::from_pm1(&g.pm1_vec(n_in)))
        .collect();
    let pads = width - n_in;
    let q = vec![(0..n_out)
        .map(|_| g.usize_in(0, pads) as i32)
        .collect::<Vec<_>>()];
    MappedLayer {
        weights: BitMatrix::from_rows(&rows),
        q,
        seg_bounds: vec![0, n_in],
        seg_width: width,
    }
}

fn gen_model(g: &mut Gen) -> MappedModel {
    let n_in = g.usize_in(16, 120);
    let h = g.usize_in(4, 24);
    let n_cls = g.usize_in(2, 10);
    let l1 = gen_layer(g, h, n_in, (n_in + 16).max(64));
    let l2 = gen_layer(g, n_cls, h, (h + 16).max(64));
    MappedModel {
        layers: vec![l1, l2],
        schedule: (0..=64).step_by(2).collect(),
    }
}

#[test]
fn prop_row_query_mismatch_identity() {
    // HD(programmed row, segment query) == HD_w + q for every neuron
    forall(60, 101, |g| {
        let n_out = g.usize_in(1, 12);
        let n_in = g.usize_in(8, 100);
        let layer = gen_layer(g, n_out, n_in, 128);
        layer.validate().map_err(|e| e)?;
        let x = BitVec::from_pm1(&g.pm1_vec(layer.n_in()));
        for j in 0..layer.n_out() {
            let row = program_row(&layer, 0, j);
            let q = segment_query(&layer, 0, &x);
            prop_assert(
                hamming_words(row.words(), q.words()) == expected_mismatches(&layer, 0, j, &x),
                format!("neuron {j}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_nominal_pipeline_equals_digital_reference() {
    // the device (no noise) and the in-memory reference are bit-identical
    forall(25, 103, |g| {
        let model = gen_model(g);
        let mut pipe = Pipeline::new(
            &model,
            PipelineOptions {
                noise: NoiseMode::Nominal,
                ..Default::default()
            },
        );
        let n_img = g.usize_in(1, 6);
        let images: Vec<BitVec> = (0..n_img)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(model.n_in())))
            .collect();
        let got = pipe.classify_batch(&images);
        for (img, (votes, pred)) in images.iter().zip(&got) {
            let (want_votes, want_pred) = digital_forward(&model, img, &model.schedule);
            prop_assert(votes == &want_votes, "votes")?;
            prop_assert(pred == &want_pred, "pred")?;
        }
        Ok(())
    });
}

#[test]
fn prop_batch_invariance_nominal() {
    // classifying images in different batch groupings gives identical
    // results in nominal mode (state is reprogrammed identically)
    forall(15, 107, |g| {
        let model = gen_model(g);
        let images: Vec<BitVec> = (0..8)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(model.n_in())))
            .collect();
        let opts = PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        };
        let mut one = Pipeline::new(&model, opts);
        let all = one.classify_batch(&images);
        let mut two = Pipeline::new(&model, opts);
        let mut split = Vec::new();
        for chunk in images.chunks(3) {
            split.extend(two.classify_batch(chunk));
        }
        prop_assert(all == split, "batch grouping changed results")?;
        Ok(())
    });
}

#[test]
fn prop_planner_never_exceeds_the_budget() {
    // over random load shapes, schedules, budgets, and worker counts:
    // a plan either fits the budget exactly or is refused (only below
    // the cold-spill floor), resident loads keep >= 1 macro, spill
    // plans keep exactly one funnel, and pinned thresholds never exceed
    // the schedule
    forall(300, 131, |g| {
        let n_layers = g.usize_in(1, 4);
        let rows: Vec<Vec<usize>> = (0..n_layers)
            .map(|_| {
                let loads = g.usize_in(1, 8);
                (0..loads).map(|_| g.usize_in(1, 256)).collect()
            })
            .collect();
        let hidden: usize = rows.iter().map(Vec::len).sum();
        let schedule_len = g.usize_in(0, 40);
        let budget = g.usize_in(0, 120);
        let workers = g.usize_in(0, 12);
        let min_output = schedule_len.min(1);
        match planner::plan(&rows, schedule_len, budget, workers) {
            None => {
                // refusal only below the floor: full residency for a
                // single-load model, the 2-macro spill floor otherwise
                let floor = if hidden >= 2 {
                    2.min(hidden + min_output)
                } else {
                    hidden + min_output
                };
                prop_assert(
                    budget < floor,
                    format!("refused a feasible budget {budget} (hidden {hidden})"),
                )?
            }
            Some(p) => {
                prop_assert(
                    p.macros_used() <= budget,
                    format!("{} macros over budget {budget}", p.macros_used()),
                )?;
                if p.spill_active() {
                    prop_assert(
                        budget < hidden + min_output,
                        "spill above the full-residency floor",
                    )?;
                    prop_assert(
                        p.pinned == 0 && p.shared_slots == 1,
                        "spill plans keep exactly the funnel",
                    )?;
                    prop_assert(
                        p.hidden_macros() >= 1,
                        "spill keeps at least one resident load",
                    )?;
                    prop_assert(!p.replication_active(), "spill plans never replicate")?;
                } else {
                    prop_assert(
                        p.hidden_replicas.iter().flatten().all(|&r| r >= 1),
                        "hidden load lost its macro",
                    )?;
                }
                prop_assert(
                    p.hidden_replicas
                        .iter()
                        .flatten()
                        .all(|&r| r <= workers.max(1)),
                    "replicas exceed the worker count",
                )?;
                prop_assert(
                    p.pinned_positions() <= schedule_len,
                    "pinned past the schedule",
                )?;
                prop_assert(
                    p.pinned_positions() == schedule_len || p.shared_slots >= 1,
                    "unpinned thresholds need a shared slot",
                )?;
                prop_assert(
                    p.pin_slot.iter().flatten().all(|&s| s < p.pinned),
                    "pin routes to a nonexistent slot",
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tenant_isolation_under_any_budget_split() {
    // the multi-tenant analogue of prop_budget_never_changes_nominal_
    // predictions: for any feasible budget split, traffic-share skew,
    // noise mode, and interleaving of tenant batches, each tenant's
    // results are bit-identical to the same model running alone on a
    // pool built from its tenant plan — and (nominal) to the reload
    // Pipeline
    forall(6, 139, |g| {
        let ma = gen_model(g);
        let mb = gen_model(g);
        let analog = g.bool();
        let opts = PipelineOptions {
            noise: if analog {
                NoiseMode::Analog
            } else {
                NoiseMode::Nominal
            },
            ..Default::default()
        };
        let full = MacroPool::macros_required(&ma, &opts)
            + MacroPool::macros_required(&mb, &opts);
        let budget = g.usize_in(4, full + 4);
        let shares = [g.usize_in(1, 5) as f64, g.usize_in(1, 5) as f64];
        let models = [&ma, &mb];
        let pool = MultiPool::with_shares(&models, opts, budget, 1, &shares);
        let tp = match pool.plan() {
            Some(tp) => tp,
            None => return Ok(()), // below the tenancy floors
        };
        prop_assert(
            tp.macros_used() <= budget,
            format!("{} macros over budget {budget}", tp.macros_used()),
        )?;
        let alone = [
            MacroPool::with_plan(&ma, opts, tp.plans[0].clone()),
            MacroPool::with_plan(&mb, opts, tp.plans[1].clone()),
        ];
        let imgs: Vec<Vec<BitVec>> = models
            .iter()
            .map(|m| {
                (0..6)
                    .map(|_| BitVec::from_pm1(&g.pm1_vec(m.n_in())))
                    .collect()
            })
            .collect();
        // random interleaving of tenant batches (explicit stream bases so
        // the standalone pool replays the identical noise streams)
        let mut base = [0u64; 2];
        for _ in 0..5 {
            let t = g.usize_in(0, 1);
            let lo = g.usize_in(0, imgs[t].len() - 1);
            let hi = g.usize_in(lo + 1, imgs[t].len());
            let chunk = &imgs[t][lo..hi];
            prop_assert(
                pool.classify_batch_at(t, chunk, base[t])
                    == alone[t].classify_batch_at(chunk, base[t]),
                format!("tenant {t} diverged from its standalone pool"),
            )?;
            base[t] += chunk.len() as u64;
        }
        if !analog {
            for (t, m) in models.iter().enumerate() {
                let mut pipe = Pipeline::new(m, opts);
                prop_assert(
                    pool.classify_batch_at(t, &imgs[t], 0) == pipe.classify_batch(&imgs[t]),
                    format!("tenant {t} diverged from the reload pipeline"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_async_engine_bit_identical_to_sync_pool() {
    // the serving tentpole's correctness claim: any interleaving of
    // submissions and polls — across tenant lanes, batch sizes, and
    // worker-thread counts — yields predictions, vote vectors, and RNG
    // draw order bit-identical to a sequential classify_batch_at on a
    // standalone pool, in BOTH noise modes.  Holds because request ids
    // double as noise-stream indices: FIFO lanes drain dense id ranges,
    // so every device batch replays exactly the streams the sequential
    // path would, no matter who polls or when.
    forall(4, 241, |g| {
        let ma = gen_model(g);
        let mb = gen_model(g);
        let models = [&ma, &mb];
        let counts = [g.usize_in(2, 7), g.usize_in(2, 7)];
        let imgs: Vec<Vec<BitVec>> = models
            .iter()
            .zip(counts)
            .map(|(m, n)| {
                (0..n)
                    .map(|_| BitVec::from_pm1(&g.pm1_vec(m.n_in())))
                    .collect()
            })
            .collect();
        let max_batch = g.usize_in(1, 5);
        // either "batch only when full" (simulated time never advances,
        // so half-budget never fires) or "instantly due" (every poll
        // closes whatever is queued) — opposite interleaving extremes
        let max_wait = if g.bool() {
            Duration::from_secs(3600)
        } else {
            Duration::ZERO
        };
        let n_workers = g.usize_in(1, 3);
        // random interleaving of the two tenants' submission sequences
        let mut order: Vec<usize> = vec![vec![0; counts[0]], vec![1; counts[1]]].concat();
        for i in (1..order.len()).rev() {
            let j = g.usize_in(0, i);
            order.swap(i, j);
        }
        for analog in [false, true] {
            let opts = PipelineOptions {
                noise: if analog {
                    NoiseMode::Analog
                } else {
                    NoiseMode::Nominal
                },
                ..Default::default()
            };
            // full residency: the engine's batched path must never fall
            // back to the reload pipeline (which ignores stream bases)
            let full = MacroPool::macros_required(&ma, &opts)
                + MacroPool::macros_required(&mb, &opts);
            let want: Vec<Vec<(Vec<u32>, usize)>> = models
                .iter()
                .enumerate()
                .map(|(t, m)| {
                    let req = MacroPool::macros_required(m, &opts);
                    MacroPool::with_capacity(m, opts, req).classify_batch_at(&imgs[t], 0)
                })
                .collect();
            let policy = BatchPolicy {
                max_batch,
                max_wait,
            };
            let engine = Engine::multi(&models, opts, policy, full, &[1.0, 1.0])
                .with_clock(Clock::simulated());
            let collected = Mutex::new(Vec::new());
            let stop = AtomicBool::new(false);
            std::thread::scope(|s| {
                for _ in 0..n_workers {
                    s.spawn(|| {
                        while !stop.load(Ordering::Acquire) {
                            let got = engine.poll();
                            if got.is_empty() {
                                std::thread::yield_now();
                            } else {
                                collected.lock().unwrap().extend(got);
                            }
                        }
                    });
                }
                let mut next = [0usize; 2];
                for &t in &order {
                    engine
                        .submit(t, imgs[t][next[t]].clone())
                        .expect("lanes are unbounded");
                    next[t] += 1;
                }
                stop.store(true, Ordering::Release);
            });
            collected.lock().unwrap().extend(engine.flush());
            let mut got = collected.into_inner().unwrap();
            prop_assert(
                got.len() == counts[0] + counts[1],
                format!(
                    "analog={analog} workers={n_workers}: {} of {} responses",
                    got.len(),
                    counts[0] + counts[1]
                ),
            )?;
            got.sort_by_key(|r| (r.tenant, r.id));
            for t in 0..2 {
                let lane: Vec<_> = got.iter().filter(|r| r.tenant == t).collect();
                prop_assert(lane.len() == counts[t], format!("tenant {t} responses"))?;
                for (i, r) in lane.iter().enumerate() {
                    prop_assert(r.id == i as u64, format!("tenant {t}: id gap at {i}"))?;
                    prop_assert(
                        r.votes == want[t][i].0 && r.prediction == want[t][i].1,
                        format!(
                            "analog={analog} workers={n_workers} max_batch={max_batch}: \
                             tenant {t} image {i} diverged from the sequential pool"
                        ),
                    )?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_budget_never_changes_nominal_predictions() {
    // any viable budget (sharing, partial pinning, replication) yields
    // the reload Pipeline's exact votes in nominal mode — and so does any
    // chunking of the batched search kernel the pool now runs on
    forall(8, 137, |g| {
        let model = gen_model(g);
        let opts = PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        };
        let images: Vec<BitVec> = (0..6)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(model.n_in())))
            .collect();
        let mut pipe = Pipeline::new(&model, opts);
        let want = pipe.classify_batch(&images);
        let required = MacroPool::macros_required(&model, &opts);
        let budget = g.usize_in(2, required + 4);
        let pool = MacroPool::with_capacity_for_workers(&model, opts, budget, 3);
        prop_assert(
            pool.classify_batch(&images) == want,
            format!("budget {budget} changed predictions"),
        )?;
        // sweep the batched path's chunk shapes: device-batch size is an
        // execution detail, never a semantic one
        let chunk = g.usize_in(1, images.len());
        let mut split = Vec::new();
        for c in images.chunks(chunk) {
            split.extend(pool.classify_batch(c));
        }
        prop_assert(
            split == want,
            format!("budget {budget} chunk {chunk} changed the batched kernel's predictions"),
        )?;
        Ok(())
    });
}

#[test]
fn prop_hamming_backends_bit_identical_to_scalar() {
    // the SIMD-dispatch contract: every backend this host can run
    // (scalar, SWAR, AVX2 when detected) computes exactly the scalar
    // reference's counts — single pairs, the masked variant, and the
    // register-tiled batch kernel — over random widths crossing the
    // 4-word chunk boundary and batch sizes crossing QUERY_TILE.  Exact
    // counts mean the choice of backend can never change a decision, so
    // nominal/analog predictions are dispatch-independent by
    // construction (CI additionally re-runs this whole suite under
    // PICBNN_FORCE_BACKEND=scalar to pin RNG draw-order independence).
    forall(30, 227, |g| {
        let cols = g.usize_in(1, 1600);
        let n_rows = g.usize_in(1, 12);
        let nq = g.usize_in(1, 19);
        let rows: Vec<BitVec> = (0..n_rows)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(cols)))
            .collect();
        let m = BitMatrix::from_rows(&rows);
        let queries: Vec<BitVec> = (0..nq)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(cols)))
            .collect();
        let mask = BitVec::from_pm1(&g.pm1_vec(cols));
        let mut want = Vec::new();
        m.hamming_all_batch_with(HammingBackend::Scalar, &queries, &mut want);
        for backend in available_backends() {
            let mut got = Vec::new();
            m.hamming_all_batch_with(backend, &queries, &mut got);
            prop_assert(got == want, format!("{backend:?}: batch kernel"))?;
            prop_assert(
                hamming_words_with(backend, rows[0].words(), queries[0].words())
                    == hamming_words_with(
                        HammingBackend::Scalar,
                        rows[0].words(),
                        queries[0].words(),
                    ),
                format!("{backend:?}: single pair"),
            )?;
            prop_assert(
                hamming_words_masked_with(
                    backend,
                    rows[0].words(),
                    queries[0].words(),
                    mask.words(),
                ) == hamming_words_masked_with(
                    HammingBackend::Scalar,
                    rows[0].words(),
                    queries[0].words(),
                    mask.words(),
                ),
                format!("{backend:?}: masked variant"),
            )?;
        }
        // and the dispatched production entries agree with scalar too
        let mut dispatched = Vec::new();
        m.hamming_all_batch(&queries, &mut dispatched);
        prop_assert(dispatched == want, "dispatched batch entry")?;
        prop_assert(
            hamming_words(rows[0].words(), queries[0].words())
                == hamming_words_with(HammingBackend::Scalar, rows[0].words(), queries[0].words()),
            "dispatched single pair",
        )?;
        Ok(())
    });
}

#[test]
fn prop_batch_search_bit_identical_to_sequential() {
    // the tentpole contract: `search_batch_into_rngs` over any batch size,
    // either noise mode, and across interleaved retunes/row-writes (cache
    // invalidation soundness) is bit-identical to N sequential
    // `search_into_rng` calls — mismatch counts, fires, per-stream RNG
    // positions, and cycle/event accounting
    forall(20, 211, |g| {
        let cfg = CamConfig::all()[g.usize_in(0, 2)];
        let analog = g.bool();
        let seed = g.usize_in(0, 1 << 20) as u64;
        let width = cfg.width();
        let mk = |noise| CamArray::new(cfg, Pvt::nominal(), noise, seed);
        let noise = if analog {
            NoiseMode::Analog
        } else {
            NoiseMode::Nominal
        };
        let (mut seq, mut bat) = (mk(noise), mk(noise));
        let n_rows = g.usize_in(1, 16).min(cfg.rows());
        for r in 0..n_rows {
            let data = BitVec::from_pm1(&g.pm1_vec(width));
            seq.write_row(r, &data);
            bat.write_row(r, &data);
        }
        if g.bool() && n_rows > 1 {
            // punch a hole so the kernel's non-prefix fallback is covered
            let hole = g.usize_in(0, n_rows - 1);
            seq.clear_row(hole);
            bat.clear_row(hole);
        }
        for round in 0..2u64 {
            // rails chosen anew each round: the second round exercises the
            // threshold caches across a retune + a row rewrite
            let v = Voltages::new(
                g.f64_in(0.62, 1.15),
                g.f64_in(0.35, 1.1),
                g.f64_in(0.65, 1.15),
            );
            seq.set_voltages(v);
            bat.set_voltages(v);
            let nq = g.usize_in(1, 11);
            let queries: Vec<BitVec> = (0..nq)
                .map(|_| BitVec::from_pm1(&g.pm1_vec(width)))
                .collect();
            let mut rngs_seq: Vec<Rng> = (0..nq as u64)
                .map(|i| Rng::new(seed ^ 0x5EED, round * 100 + i))
                .collect();
            let mut rngs_bat = rngs_seq.clone();
            let (mut sm, mut sf) = (Vec::new(), Vec::new());
            let (mut want_m, mut want_f) = (Vec::new(), Vec::new());
            for (i, q) in queries.iter().enumerate() {
                seq.search_into_rng(q, &mut sm, &mut sf, &mut rngs_seq[i]);
                want_m.extend_from_slice(&sm);
                want_f.push(sf.clone());
            }
            let (mut bm, mut bf) = (Vec::new(), BitMatrix::default());
            bat.search_batch_into_rngs(&queries, &mut rngs_bat, &mut bm, &mut bf);
            prop_assert(bm == want_m, format!("round {round}: mismatch counts"))?;
            for (i, f) in want_f.iter().enumerate() {
                for r in 0..cfg.rows() {
                    prop_assert(
                        bf.get(i, r) == f[r],
                        format!("round {round}: fires q{i} r{r}"),
                    )?;
                }
            }
            for (i, (ra, rb)) in rngs_seq.iter().zip(&rngs_bat).enumerate() {
                prop_assert(
                    format!("{ra:?}") == format!("{rb:?}"),
                    format!("round {round}: rng stream {i} position"),
                )?;
            }
            prop_assert(
                seq.clock.cycles == bat.clock.cycles,
                format!("round {round}: cycles"),
            )?;
            prop_assert(seq.events == bat.events, format!("round {round}: events"))?;
            // interleaved programming between rounds: both paths must drop
            // their caches identically
            let rewrite = g.usize_in(0, n_rows - 1);
            let data = BitVec::from_pm1(&g.pm1_vec(width));
            seq.write_row(rewrite, &data);
            bat.write_row(rewrite, &data);
        }
        Ok(())
    });
}

#[test]
fn prop_live_migration_is_bit_stable_in_both_noise_modes() {
    // the re-planning tentpole's correctness claim: interleaving any
    // prefix of a MigrationPlan between batches never changes a
    // prediction.  After every applied step the migrating pool matches
    // BOTH a static pool built directly at the intermediate placement
    // and the pool that never migrated, replaying the same noise-stream
    // bases (the identical-seeding rule).  Random drift traces price the
    // candidate; random budgets cover grow, shrink, and sharing shifts.
    // Analog iterations skip spill placements: reprogramming a funnel
    // that already served is bit-stable in nominal mode only.
    forall(6, 251, |g| {
        let model = gen_model(g);
        let analog = g.bool();
        let opts = PipelineOptions {
            noise: if analog {
                NoiseMode::Analog
            } else {
                NoiseMode::Nominal
            },
            ..Default::default()
        };
        let required = MacroPool::macros_required(&model, &opts);
        let src = g.usize_in(2, required + 3);
        let dst = g.usize_in(2, required + 3);
        let pool = MacroPool::with_capacity_for_workers(&model, opts, src, 2);
        let start = match pool.plan() {
            Some(p) => p,
            None => return Ok(()), // below every floor: reload mode
        };
        // random drift trace: a random histogram prices the re-plan
        let hist: Vec<u64> = (0..start.schedule_len)
            .map(|_| g.usize_in(0, 9) as u64)
            .collect();
        let rows = pool.hidden_load_rows();
        let points = pool.schedule_points();
        let cand = match planner::plan_traffic(&rows, &points, Some(&hist), None, dst, 2) {
            Some(p) => p,
            None => return Ok(()),
        };
        if analog && (start.spill_active() || cand.spill_active()) {
            return Ok(());
        }
        let mp = start.repriced(Some(&hist)).diff(&cand);
        if mp.is_empty() {
            return Ok(());
        }
        // the pool that never migrates, and per-step static rebuilds
        let frozen = MacroPool::with_plan(&model, opts, start.clone());
        let images: Vec<BitVec> = (0..3)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(model.n_in())))
            .collect();
        let mut base = 0u64;
        for k in 0..mp.steps.len() {
            pool.apply_migration_step(&mp, k);
            if g.bool() {
                continue; // some gaps apply several steps with no batch
            }
            let staged = MacroPool::with_plan(&model, opts, pool.plan().unwrap());
            let got = pool.classify_batch_at(&images, base);
            prop_assert(
                got == staged.classify_batch_at(&images, base),
                format!("step {k}: diverged from a static pool at the same placement"),
            )?;
            prop_assert(
                got == frozen.classify_batch_at(&images, base),
                format!("step {k}: diverged from the never-migrated pool"),
            )?;
            base += images.len() as u64;
        }
        // landed: the fold over the source reproduces the pool's plan,
        // and a pool built directly at the target serves identically
        prop_assert(
            pool.plan().unwrap() == mp.target(&start),
            "migrated pool did not land on the diff target",
        )?;
        let landed = MacroPool::with_plan(&model, opts, mp.target(&start));
        let got = pool.classify_batch_at(&images, base);
        prop_assert(
            got == landed.classify_batch_at(&images, base),
            "landed pool diverged from a static pool at the target",
        )?;
        prop_assert(
            got == frozen.classify_batch_at(&images, base),
            "landed pool diverged from the never-migrated pool",
        )?;
        Ok(())
    });
}

#[test]
fn prop_tenant_churn_preserves_sibling_bit_exactness() {
    // runtime add_tenant / remove_tenant mid-stream: the sitting
    // tenant's predictions replay bit-identically through the churn —
    // including while its own migration is half-applied — in both noise
    // modes, and the newcomer matches a standalone pool on its plan.
    // one checkpoint: tenant 0 of `pool` vs the standalone pool, at a
    // shared advancing stream base (identical seeding makes the streams
    // line up regardless of what either pool served before)
    fn stream_matches(
        pool: &MultiPool<'_>,
        alone: &MacroPool<'_>,
        imgs: &[BitVec],
        base: &mut u64,
    ) -> bool {
        let same = pool.classify_batch_at(0, imgs, *base) == alone.classify_batch_at(imgs, *base);
        *base += imgs.len() as u64;
        same
    }
    forall(4, 263, |g| {
        let ma = gen_model(g);
        let mb = gen_model(g);
        let analog = g.bool();
        let opts = PipelineOptions {
            noise: if analog {
                NoiseMode::Analog
            } else {
                NoiseMode::Nominal
            },
            ..Default::default()
        };
        // budget covers both residency floors, so churn re-plans always
        // succeed (migs are never the empty fall-back vec)
        let budget = MacroPool::macros_required(&ma, &opts)
            + MacroPool::macros_required(&mb, &opts)
            + g.usize_in(0, 4);
        let models = [&ma];
        let mut pool = MultiPool::with_shares(&models, opts, budget, 1, &[1.0]);
        let start_a = pool.plan().expect("floor covered").plans[0].clone();
        if analog && start_a.spill_active() {
            return Ok(()); // funnel reprogramming is nominal-only
        }
        let alone_a = MacroPool::with_plan(&ma, opts, start_a);
        let imgs_a: Vec<BitVec> = (0..6)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(ma.n_in())))
            .collect();
        let imgs_b: Vec<BitVec> = (0..4)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(mb.n_in())))
            .collect();
        let mut base_a = 0u64;
        prop_assert(
            stream_matches(&pool, &alone_a, &imgs_a, &mut base_a),
            "pre-churn baseline",
        )?;
        // admit tenant b mid-stream and interleave a's batches with the
        // incremental application of a's migration steps
        let migs = pool.add_tenant(&mb, 1.0);
        prop_assert(migs.len() == 2, "one migration per tenant")?;
        prop_assert(migs[1].is_empty(), "the newcomer is built at target")?;
        if analog {
            // proportional-fair sharing may push either tenant into
            // spill at this budget; the analog claim stops there
            let tp = pool.plan().expect("floor covered");
            if tp.plans.iter().any(|p| p.spill_active())
                || migs[0].target(&tp.plans[0]).spill_active()
            {
                return Ok(());
            }
        }
        for k in 0..migs[0].steps.len() {
            pool.apply_migration_step(0, &migs[0], k);
            prop_assert(
                stream_matches(&pool, &alone_a, &imgs_a, &mut base_a),
                format!("analog={analog}: sibling diverged at add step {k}"),
            )?;
        }
        // the newcomer serves exactly like a standalone pool on its plan
        let plan_b = pool.plan().expect("resident tenancy").plans[1].clone();
        let alone_b = MacroPool::with_plan(&mb, opts, plan_b);
        prop_assert(
            pool.classify_batch_at(1, &imgs_b, 0) == alone_b.classify_batch_at(&imgs_b, 0),
            "newcomer diverged from its standalone pool",
        )?;
        // retire tenant b: the survivor grows back over the freed budget,
        // still bit-stable through every step
        let migs = pool.remove_tenant(1);
        prop_assert(migs.len() == 1, "one migration for the survivor")?;
        if analog {
            let tp = pool.plan().expect("floor covered");
            if tp.plans[0].spill_active() || migs[0].target(&tp.plans[0]).spill_active() {
                return Ok(());
            }
        }
        for k in 0..migs[0].steps.len() {
            pool.apply_migration_step(0, &migs[0], k);
            prop_assert(
                stream_matches(&pool, &alone_a, &imgs_a, &mut base_a),
                format!("analog={analog}: sibling diverged at remove step {k}"),
            )?;
        }
        prop_assert(
            stream_matches(&pool, &alone_a, &imgs_a, &mut base_a),
            "post-churn steady state",
        )?;
        Ok(())
    });
}

#[test]
fn prop_controller_never_exceeds_its_cost_horizon() {
    // the controller's cost-model contract: every migration it starts
    // satisfies pays_off under its own config — it never applies a step
    // of a plan whose modeled programming cost exceeds the steady-state
    // savings over the configured horizon — and the programming cycles
    // it actually spends stay within the sum of those per-migration
    // horizon budgets.
    forall(8, 269, |g| {
        let model = gen_model(g);
        let opts = PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        };
        let required = MacroPool::macros_required(&model, &opts);
        let budget = g.usize_in(2, required + 2);
        let pool = MacroPool::with_capacity(&model, opts, budget);
        if pool.plan().is_none() {
            return Ok(()); // reload mode: nothing to steer
        }
        let cfg = ReplanConfig {
            period: g.usize_in(1, 3) as u64,
            decay: [0.0, 0.5, 0.75][g.usize_in(0, 2)],
            min_improvement: [0.0, 0.2, 0.5][g.usize_in(0, 2)],
            horizon_batches: g.usize_in(1, 64) as u64,
            cycles_per_retune: g.usize_in(1, 200) as u64,
            workers: 1,
        };
        let mut ctl = ReplanController::new(&pool, budget, cfg);
        let images: Vec<BitVec> = (0..2)
            .map(|_| BitVec::from_pm1(&g.pm1_vec(model.n_in())))
            .collect();
        let schedule_len = pool.plan().unwrap().schedule_len;
        let rows = pool.hidden_load_rows();
        let output_rows = pool.output_rows();
        let mut base = 0u64;
        let mut spent = MigrationStats::default();
        let mut allowance = 0u64;
        for _ in 0..20 {
            // random banded traffic drifts the measured skew around
            let lo = g.usize_in(0, schedule_len - 1);
            let hi = g.usize_in(lo, schedule_len - 1);
            let band: Vec<usize> = (lo..=hi).collect();
            pool.classify_batch_positions(&images, base, &band);
            base += images.len() as u64;
            let was_in_flight = ctl.migration_in_flight();
            spent.add(&ctl.maintain(&pool));
            if !was_in_flight && ctl.migration_in_flight() {
                // a migration was just admitted: it must repay in time
                let mp = ctl.inflight_plan().expect("in flight");
                let repays =
                    mp.pays_off(&rows, output_rows, cfg.horizon_batches, cfg.cycles_per_retune);
                prop_assert(repays, "started a migration that cannot repay its cost")?;
                let saved =
                    mp.steady_cycles_saved_per_batch(&rows, output_rows, cfg.cycles_per_retune);
                prop_assert(saved > 0, "accepted migration with no saving")?;
                allowance += cfg.horizon_batches.saturating_mul(saved as u64);
            }
        }
        prop_assert(
            spent.programming_cycles() <= allowance,
            format!(
                "spent {} programming cycles against a horizon allowance of {allowance}",
                spent.programming_cycles()
            ),
        )?;
        Ok(())
    });
}

#[test]
fn prop_sweep_votes_monotone_and_bounded() {
    forall(100, 109, |g| {
        let k = g.usize_in(1, 33);
        let schedule: Vec<i32> = (0..k as i32).map(|i| 2 * i).collect();
        let n = g.usize_in(1, 20);
        let hd: Vec<u32> = (0..n).map(|_| g.usize_in(0, 200) as u32).collect();
        let votes = sweep_votes(&hd, &schedule);
        for (i, &v) in votes.iter().enumerate() {
            prop_assert(v <= k as u32, format!("vote {v} > {k}"))?;
            for (j, &w) in votes.iter().enumerate() {
                if hd[i] < hd[j] {
                    prop_assert(v >= w, format!("monotonicity {i},{j}"))?;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cam_search_tolerance_semantics() {
    // for random rails, fires <=> mismatches <= tol (nominal mode)
    forall(40, 113, |g| {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let vref = g.f64_in(0.6, 1.19);
        let veval = g.f64_in(0.35, 1.2);
        let vst = g.f64_in(0.6, 1.2);
        cam.set_voltages(Voltages::new(vref, veval, vst));
        let stored = BitVec::from_pm1(&g.pm1_vec(512));
        cam.write_row(0, &stored);
        let flips = g.usize_in(0, 512);
        let mut query = stored.clone();
        for i in 0..flips {
            query.flip(i);
        }
        let tol = cam.current_tolerance();
        if (flips as f64 - tol).abs() < 0.5 {
            return Ok(()); // boundary cell: quantization ambiguity
        }
        let fires = cam.search(&query)[0];
        prop_assert(
            fires == (flips as f64 <= tol),
            format!("flips {flips} tol {tol}"),
        )?;
        Ok(())
    });
}

#[test]
fn prop_tolerance_scales_linearly_with_row_length() {
    // hd_tolerance(n) ∝ n at fixed voltages (C_ML scales with cells)
    forall(50, 127, |g| {
        let v = Voltages::new(
            g.f64_in(0.6, 1.15),
            g.f64_in(0.35, 1.2),
            g.f64_in(0.6, 1.2),
        );
        let t256 = MatchlineModel::new(256, Pvt::nominal()).hd_tolerance(&v);
        let t1024 = MatchlineModel::new(1024, Pvt::nominal()).hd_tolerance(&v);
        prop_assert(
            (t1024 - 4.0 * t256).abs() < 1e-6 * t1024.max(1.0),
            format!("{t256} vs {t1024}"),
        )?;
        Ok(())
    });
}
