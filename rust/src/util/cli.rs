//! Minimal CLI argument parser (the crates.io `clap` family is unavailable
//! in this offline environment; see DESIGN.md §1).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — `flag_names` lists options
    /// that take no value.
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        out.flags.push(stripped.to_string());
                    } else {
                        out.opts.insert(stripped.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from std::env::args (skipping argv[0]).
    pub fn parse(flag_names: &[&str]) -> Args {
        Args::parse_from(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.get(name) {
            Some(s) => s.parse().unwrap_or(default),
            None => default,
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str], flags: &[&str]) -> Args {
        Args::parse_from(v.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn key_value_pairs() {
        let a = args(&["--model", "mnist", "--batch=32"], &[]);
        assert_eq!(a.get("model"), Some("mnist"));
        assert_eq!(a.get_parse("batch", 0usize), 32);
    }

    #[test]
    fn flags_and_positional() {
        let a = args(&["run", "--verbose", "--n", "5", "extra"], &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_parse("n", 0u32), 5);
        assert_eq!(a.positional(), &["run".to_string(), "extra".to_string()]);
    }

    #[test]
    fn trailing_flag() {
        let a = args(&["--quick"], &[]);
        assert!(a.flag("quick"));
    }

    #[test]
    fn flag_before_another_option() {
        let a = args(&["--quick", "--n", "3"], &[]);
        assert!(a.flag("quick"));
        assert_eq!(a.get_parse("n", 0u32), 3);
    }

    #[test]
    fn defaults() {
        let a = args(&[], &[]);
        assert_eq!(a.get_or("model", "mnist"), "mnist");
        assert_eq!(a.get_parse("batch", 64usize), 64);
        assert!(!a.flag("verbose"));
    }
}
