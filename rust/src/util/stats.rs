//! Lightweight descriptive statistics for benches and metrics.

use crate::util::rng::Rng;

/// Samples retained for percentile estimation (see [`Summary`]).
pub const SUMMARY_RESERVOIR_CAP: usize = 4096;

/// Online summary of a sample with bounded memory.
///
/// Count, mean, standard deviation, min, and max are exact over every
/// value ever pushed (Welford accumulation).  Percentiles come from a
/// deterministic reservoir (Algorithm R over a fixed-seed PRNG) of at
/// most [`SUMMARY_RESERVOIR_CAP`] samples: exact while the sample fits
/// the reservoir, an unbiased estimate beyond it.
///
/// The previous implementation stored every sample forever and re-sorted
/// the whole vector per `percentile` call — a long-running `Server`
/// pushing one latency per request grew without bound.  The reservoir
/// caps both the memory and the per-read sort at the reservoir size.
#[derive(Clone, Debug)]
pub struct Summary {
    count: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    cap: usize,
    reservoir: Vec<f64>,
    rng: Rng,
}

impl Default for Summary {
    fn default() -> Self {
        Summary::new()
    }
}

impl Summary {
    pub fn new() -> Self {
        Summary::with_reservoir(SUMMARY_RESERVOIR_CAP)
    }

    /// Summary with an explicit reservoir capacity (≥ 1).
    pub fn with_reservoir(cap: usize) -> Self {
        assert!(cap > 0, "reservoir capacity must be positive");
        Summary {
            count: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            cap,
            reservoir: Vec::new(),
            // fixed seed: summaries are deterministic across runs
            rng: Rng::new(0x5EED_0A11_CA55_E77E, 0x51),
        }
    }

    pub fn push(&mut self, v: f64) {
        self.count += 1;
        let delta = v - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (v - self.mean);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if self.reservoir.len() < self.cap {
            self.reservoir.push(v);
        } else {
            // Algorithm R: the i-th value replaces a uniform slot with
            // probability cap/i, keeping every prefix uniformly sampled
            let j = self.rng.below(self.count);
            if (j as usize) < self.cap {
                self.reservoir[j as usize] = v;
            }
        }
    }

    pub fn extend(&mut self, vs: &[f64]) {
        for &v in vs {
            self.push(v);
        }
    }

    /// Total values observed (not the retained sample count).
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Samples currently retained for percentile estimation — bounded by
    /// the reservoir capacity no matter how many values were pushed.
    pub fn stored(&self) -> usize {
        self.reservoir.len()
    }

    /// Whether percentiles are exact (every observation retained).
    pub fn is_exact(&self) -> bool {
        self.count as usize == self.reservoir.len()
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.mean
    }

    /// Sample standard deviation (n−1 denominator; 0 for n<2).
    pub fn stddev(&self) -> f64 {
        if self.count < 2 {
            return 0.0;
        }
        (self.m2 / (self.count - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Linear-interpolated percentile, p in [0, 100], over the retained
    /// sample (exact below the reservoir capacity).
    ///
    /// An empty summary returns the `NaN` sentinel — never an index
    /// panic — so metrics consumers (an idle `ServerMetrics`, a report
    /// printed before the first request) can query unconditionally and
    /// render a placeholder.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.reservoir.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.reservoir.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Fixed-width histogram over [lo, hi); out-of-range values clamp to the
/// edge buckets, NaN values are ignored.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_buckets: usize) -> Self {
        assert!(hi > lo && n_buckets > 0);
        Histogram {
            lo,
            hi,
            buckets: vec![0; n_buckets],
            count: 0,
        }
    }

    pub fn record(&mut self, v: f64) {
        // a NaN would land in bucket 0 through the `as i64` cast below,
        // silently skewing the low tail — drop it instead
        if v.is_nan() {
            return;
        }
        let n = self.buckets.len();
        let idx = ((v - self.lo) / (self.hi - self.lo) * n as f64) as i64;
        let idx = idx.clamp(0, n as i64 - 1) as usize;
        self.buckets[idx] += 1;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Approximate quantile from bucket mass (bucket midpoint).  `q` is
    /// clamped to [0, 1]; `q = 1` reports the highest *occupied* bucket
    /// rather than the range edge.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 || q.is_nan() {
            return f64::NAN;
        }
        // clamp the rank below the total mass so the scan always lands in
        // an occupied bucket (q=1 used to fall off the loop and report
        // `hi` even with all mass in bucket 0)
        let target = ((q.clamp(0.0, 1.0) * self.count as f64) as u64).min(self.count - 1);
        let mut acc = 0;
        let width = (self.hi - self.lo) / self.buckets.len() as f64;
        for (i, &b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc > target {
                return self.lo + (i as f64 + 0.5) * width;
            }
        }
        self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - 1.5811388).abs() < 1e-6);
    }

    #[test]
    fn empty_summary_percentiles_are_nan_not_a_panic() {
        // pinned behaviour: zero samples → NaN sentinel (an idle server's
        // p50/p99 query must not index into an empty reservoir)
        let s = Summary::new();
        assert!(s.is_empty());
        assert!(s.percentile(50.0).is_nan());
        assert!(s.percentile(99.0).is_nan());
        assert!(s.median().is_nan());
        assert!(s.mean().is_nan());
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Summary::new();
        s.extend(&[0.0, 10.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn small_samples_are_exact() {
        // below the reservoir capacity nothing is sampled away: the
        // percentiles are identical to the full-retention implementation
        let mut s = Summary::new();
        let vals: Vec<f64> = (0..1000).map(|i| (i * 7 % 1000) as f64).collect();
        s.extend(&vals);
        assert!(s.is_exact());
        assert_eq!(s.stored(), 1000);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 499.5);
        assert!((s.percentile(99.0) - 989.01).abs() < 1e-9);
        assert_eq!(s.percentile(100.0), 999.0);
    }

    #[test]
    fn memory_stays_bounded_after_a_million_pushes() {
        // regression: the old Summary kept every sample forever — a
        // long-running server grew without bound
        let mut s = Summary::new();
        for i in 0..1_000_000u64 {
            s.push((i % 1000) as f64);
        }
        assert_eq!(s.len(), 1_000_000, "observation count is exact");
        assert!(
            s.stored() <= SUMMARY_RESERVOIR_CAP,
            "reservoir leaked: {}",
            s.stored()
        );
        assert!(!s.is_exact());
        // exact moments survive the sampling
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 999.0);
        assert!((s.mean() - 499.5).abs() < 1e-6);
        // the reservoir estimate tracks the true uniform distribution
        let p50 = s.percentile(50.0);
        assert!((p50 - 499.5).abs() < 50.0, "p50 {p50}");
        let p99 = s.percentile(99.0);
        assert!((p99 - 990.0).abs() < 15.0, "p99 {p99}");
    }

    #[test]
    fn reservoir_is_deterministic() {
        let fill = |n: u64| {
            let mut s = Summary::new();
            for i in 0..n {
                s.push((i % 777) as f64);
            }
            (s.percentile(50.0), s.percentile(99.0))
        };
        assert_eq!(fill(100_000), fill(100_000));
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..1000 {
            h.record((i % 100) as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.quantile(0.5) - 50.0).abs() < 2.0);
        assert!((h.quantile(0.9) - 90.0).abs() < 2.0);
    }

    #[test]
    fn histogram_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.record(-5.0);
        h.record(50.0);
        assert_eq!(h.buckets()[0], 1);
        assert_eq!(h.buckets()[9], 1);
    }

    #[test]
    fn quantile_one_lands_in_occupied_bucket() {
        // regression: with all mass in bucket 0, quantile(1.0) reported
        // the range edge `hi` instead of the occupied bucket
        let mut h = Histogram::new(0.0, 100.0, 10);
        h.record(1.0);
        h.record(2.0);
        assert!((h.quantile(1.0) - 5.0).abs() < 1e-12, "{}", h.quantile(1.0));
        assert!((h.quantile(0.5) - 5.0).abs() < 1e-12);
        // out-of-range q clamps instead of scanning past the buckets
        assert!((h.quantile(2.0) - 5.0).abs() < 1e-12);
        assert!((h.quantile(-1.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_ignores_nan() {
        // regression: NaN `as i64` is 0, so NaN records landed in bucket 0
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.record(f64::NAN);
        assert_eq!(h.count(), 0);
        assert_eq!(h.buckets()[0], 0);
        assert!(h.quantile(0.5).is_nan());
        assert!(h.quantile(f64::NAN).is_nan());
        h.record(3.0);
        assert!(h.quantile(f64::NAN).is_nan());
    }
}
