//! Lightweight descriptive statistics for benches and metrics.

/// Online + batch summary of a sample.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { values: Vec::new() }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn extend(&mut self, vs: &[f64]) {
        self.values.extend_from_slice(vs);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Sample standard deviation (n−1 denominator; 0 for n<2).
    pub fn stddev(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let ss: f64 = self.values.iter().map(|v| (v - m) * (v - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Fixed-width histogram over [lo, hi); out-of-range values clamp to the
/// edge buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    buckets: Vec<u64>,
    count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_buckets: usize) -> Self {
        assert!(hi > lo && n_buckets > 0);
        Histogram {
            lo,
            hi,
            buckets: vec![0; n_buckets],
            count: 0,
        }
    }

    pub fn record(&mut self, v: f64) {
        let n = self.buckets.len();
        let idx = ((v - self.lo) / (self.hi - self.lo) * n as f64) as i64;
        let idx = idx.clamp(0, n as i64 - 1) as usize;
        self.buckets[idx] += 1;
        self.count += 1;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Approximate quantile from bucket mass (bucket midpoint).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = (q * self.count as f64) as u64;
        let mut acc = 0;
        let width = (self.hi - self.lo) / self.buckets.len() as f64;
        for (i, &b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc > target {
                return self.lo + (i as f64 + 0.5) * width;
            }
        }
        self.hi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - 1.5811388).abs() < 1e-6);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Summary::new();
        s.extend(&[0.0, 10.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = Histogram::new(0.0, 100.0, 100);
        for i in 0..1000 {
            h.record((i % 100) as f64);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.quantile(0.5) - 50.0).abs() < 2.0);
        assert!((h.quantile(0.9) - 90.0).abs() < 2.0);
    }

    #[test]
    fn histogram_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.record(-5.0);
        h.record(50.0);
        assert_eq!(h.buckets()[0], 1);
        assert_eq!(h.buckets()[9], 1);
    }
}
