//! Minimal JSON reader/writer (serde is unavailable offline; DESIGN.md §1).
//!
//! The reader handles the subset we produce/consume: objects, arrays,
//! strings (with \\-escapes and \uXXXX), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|v| v as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 9e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(vs) => {
                out.push('[');
                for (i, v) in vs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }
}

/// Convenience builder for writing result objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": -2.5}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("c").unwrap().get("d").unwrap().as_f64(),
            Some(-2.5)
        );
        // reparse what we serialize
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_real_meta_shape() {
        let src = r#"{"name":"mnist","n_in":784,"schedule":[0,2,4],"software_top1":0.9625}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("mnist"));
        assert_eq!(v.get("n_in").unwrap().as_i64(), Some(784));
        assert_eq!(v.get("schedule").unwrap().as_arr().unwrap().len(), 3);
        assert!((v.get("software_top1").unwrap().as_f64().unwrap() - 0.9625).abs() < 1e-9);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }
}
