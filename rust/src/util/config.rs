//! Minimal TOML-subset configuration parser + typed experiment config.
//!
//! Supports the subset our configs use: `[section]` headers, `key = value`
//! with string/int/float/bool/array-of-scalars values, `#` comments.
//! (serde/toml are unavailable offline — DESIGN.md §1.)

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed config: section -> key -> value ("" = top-level section).
#[derive(Clone, Debug, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(val.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(cfg)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Config, String> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("read {}: {e}", path.as_ref().display()))?;
        Config::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    pub fn i64_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(Vec::new()));
        }
        return inner
            .split(',')
            .map(|item| parse_value(item.trim()))
            .collect::<Result<Vec<_>, _>>()
            .map(Value::Arr);
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    Err(format!("cannot parse value: {s:?}"))
}

// ----------------------------------------------------------------------
// Typed experiment configuration (the launcher's schema).
// ----------------------------------------------------------------------

/// Full run configuration for the launcher (`picbnn run --config …`).
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub model: String,
    pub limit: usize,
    pub batch: usize,
    pub threads: usize,
    pub executions: Option<usize>,
    pub noise: String,   // "analog" | "nominal"
    pub seed: u64,
    pub temp_c: f64,
    pub vdd: f64,
    pub backend: String, // "cam" | "pjrt" | "both"
    pub report_energy: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "mnist".into(),
            limit: usize::MAX,
            batch: 256,
            threads: 1,
            executions: None,
            noise: "analog".into(),
            seed: 0xB11A,
            temp_c: 25.0,
            vdd: 1.2,
            backend: "cam".into(),
            report_energy: true,
        }
    }
}

impl RunConfig {
    pub fn from_config(cfg: &Config) -> Result<RunConfig, String> {
        let d = RunConfig::default();
        let noise = cfg.str_or("run", "noise", &d.noise);
        if !matches!(noise.as_str(), "analog" | "nominal") {
            return Err(format!("run.noise must be analog|nominal, got {noise:?}"));
        }
        let backend = cfg.str_or("run", "backend", &d.backend);
        if !matches!(backend.as_str(), "cam" | "pjrt" | "both") {
            return Err(format!("run.backend must be cam|pjrt|both, got {backend:?}"));
        }
        Ok(RunConfig {
            model: cfg.str_or("run", "model", &d.model),
            limit: cfg.i64_or("run", "limit", i64::MAX) as usize,
            batch: cfg.i64_or("run", "batch", d.batch as i64) as usize,
            threads: cfg.i64_or("run", "threads", d.threads as i64) as usize,
            executions: cfg
                .get("run", "executions")
                .and_then(Value::as_i64)
                .map(|v| v as usize),
            noise,
            seed: cfg.i64_or("run", "seed", d.seed as i64) as u64,
            temp_c: cfg.f64_or("pvt", "temp_c", d.temp_c),
            vdd: cfg.f64_or("pvt", "vdd", d.vdd),
            backend,
            report_energy: cfg.bool_or("run", "report_energy", d.report_energy),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment: mnist full run
[run]
model = "mnist"
limit = 1000
batch = 128          # retune-batch size
executions = 33
noise = "analog"
threads = 4
report_energy = true

[pvt]
temp_c = 85.0
vdd = 1.14
"#;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(SAMPLE).unwrap();
        assert_eq!(cfg.str_or("run", "model", "x"), "mnist");
        assert_eq!(cfg.i64_or("run", "limit", 0), 1000);
        assert_eq!(cfg.f64_or("pvt", "temp_c", 0.0), 85.0);
        assert!(cfg.bool_or("run", "report_energy", false));
        assert_eq!(cfg.get("run", "nope"), None);
    }

    #[test]
    fn comments_and_strings() {
        let cfg = Config::parse("name = \"a # not comment\" # real comment").unwrap();
        assert_eq!(cfg.str_or("", "name", ""), "a # not comment");
    }

    #[test]
    fn arrays() {
        let cfg = Config::parse("xs = [1, 2, 3]\nys = []").unwrap();
        let xs = match cfg.get("", "xs") {
            Some(Value::Arr(v)) => v.clone(),
            other => panic!("{other:?}"),
        };
        assert_eq!(xs, vec![Value::Int(1), Value::Int(2), Value::Int(3)]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("just a line").is_err());
        assert!(Config::parse("k = ").is_err());
    }

    #[test]
    fn run_config_roundtrip_and_validation() {
        let cfg = Config::parse(SAMPLE).unwrap();
        let rc = RunConfig::from_config(&cfg).unwrap();
        assert_eq!(rc.model, "mnist");
        assert_eq!(rc.executions, Some(33));
        assert_eq!(rc.threads, 4);
        assert_eq!(rc.temp_c, 85.0);
        assert_eq!(rc.vdd, 1.14);

        let bad = Config::parse("[run]\nnoise = \"loud\"").unwrap();
        assert!(RunConfig::from_config(&bad).is_err());
        let bad2 = Config::parse("[run]\nbackend = \"gpu\"").unwrap();
        assert!(RunConfig::from_config(&bad2).is_err());
    }

    #[test]
    fn defaults_when_missing() {
        let rc = RunConfig::from_config(&Config::parse("").unwrap()).unwrap();
        assert_eq!(rc.model, "mnist");
        assert_eq!(rc.batch, 256);
        assert_eq!(rc.noise, "analog");
    }
}
