//! Packed ±1 bit vectors: the storage/compute format of the BNN fast path.
//!
//! Convention (shared with `python/compile/train.py::pack_bits_pm1`):
//! bit `i` lives in word `i / 64` at position `i % 64`, and a set bit
//! encodes +1 ("logic '1'"), a clear bit −1 ("logic '0'").
//!
//! ## The query-batched Hamming kernel
//!
//! [`BitMatrix::hamming_all_batch`] is the simulator's innermost loop.  It
//! inverts the naive loop order: instead of re-streaming the whole stored
//! matrix once per query, each row's words are loaded **once** and
//! XOR/popcounted against a register tile of up to [`QUERY_TILE`] queries
//! (the tile's words stay in L1/registers, and the per-query accumulators
//! form independent dependency chains, so the popcounts pipeline instead
//! of serialising on one accumulator).  Fire vectors on the batch path are
//! word-packed `u64` bitmasks (a `BitMatrix` row per query, walked with
//! [`BitMatrix::row_ones`]) rather than `Vec<bool>`, so vote accumulation
//! touches only firing rows.
//!
//! The tile shape is free to change: mismatch counts are exact integers,
//! so any traversal order yields bit-identical results.  What is *pinned*
//! is downstream of this kernel — `cam::CamArray` consumes the counts in
//! ascending-row order per query so the metastable-band noise draws hit
//! each per-image RNG stream in exactly the order the sequential path
//! used (see `cam/array.rs`); keep the count pass separate from any
//! RNG-consuming pass when extending this module.
//!
//! ## Runtime-dispatched popcount backends
//!
//! The XOR/popcount primitive behind every Hamming entry point runs on
//! one of three [`HammingBackend`]s, selected **once per process** (an
//! enum cached in a `OnceLock` — no trait objects on the hot path):
//!
//! * `Scalar` — the per-word `count_ones` loop, the portable reference
//!   every other backend is property-tested against;
//! * `Swar` — a 4×u64-unrolled loop over the branch-free SWAR popcount
//!   (no target features required; the unroll breaks the accumulator
//!   dependency chain);
//! * `Avx2` — 256-bit XOR + nibble-LUT popcount via `std::arch`
//!   (`_mm256_shuffle_epi8` + `_mm256_sad_epu8`), processing four words
//!   per lane with the accumulator tiling widened accordingly.
//!
//! Selection prefers AVX2 when `is_x86_feature_detected!("avx2")` holds
//! and falls back to SWAR otherwise.  All backends compute *exact*
//! popcounts, so results are bit-identical by construction (see the
//! `*_with` entry points and the backend property tests).
//!
//! **Forcing a backend when bisecting perf:** set
//! `PICBNN_FORCE_BACKEND=scalar|swar|avx2` before the process starts
//! (the choice is latched on first use).  Forcing `avx2` on a host
//! without AVX2 quietly downgrades to `swar` — executing the kernel
//! would be undefined behaviour — so A/B tooling should read the backend
//! actually used from [`active_backend`] (bench records persist it).
//! Unknown values fall back to auto-detection.  The `unsafe` surface is
//! confined to `#[target_feature(enable = "avx2")]` functions that are
//! only reachable behind the runtime CPUID check.

/// Number of u64 words needed for `n` bits.
#[inline]
pub const fn words_for(n: usize) -> usize {
    n.div_ceil(64)
}

/// A packed ±1 vector of fixed logical length.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

impl BitVec {
    /// All −1 (all bits clear).
    pub fn zeros(len: usize) -> Self {
        BitVec {
            words: vec![0; words_for(len)],
            len,
        }
    }

    /// All +1 (all payload bits set; tail bits of the last word stay clear).
    pub fn ones(len: usize) -> Self {
        let mut v = BitVec {
            words: vec![!0u64; words_for(len)],
            len,
        };
        v.mask_tail();
        v
    }

    /// From ±1 i8 values (+1 -> set).
    pub fn from_pm1(vals: &[i8]) -> Self {
        let mut v = BitVec::zeros(vals.len());
        for (i, &x) in vals.iter().enumerate() {
            if x > 0 {
                v.set(i, true);
            }
        }
        v
    }

    /// From raw packed words (validates tail bits are clear).
    pub fn from_words(words: Vec<u64>, len: usize) -> Self {
        assert_eq!(words.len(), words_for(len));
        let mut v = BitVec { words, len };
        v.mask_tail();
        v
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// ±1 view of bit `i`.
    #[inline]
    pub fn pm1(&self, i: usize) -> i32 {
        if self.get(i) {
            1
        } else {
            -1
        }
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let w = &mut self.words[i / 64];
        if v {
            *w |= 1 << (i % 64);
        } else {
            *w &= !(1 << (i % 64));
        }
    }

    /// Flip bit `i`.
    #[inline]
    pub fn flip(&mut self, i: usize) {
        self.words[i / 64] ^= 1 << (i % 64);
    }

    /// Count of set bits (+1 entries).
    pub fn count_ones(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Hamming distance to another vector of the same length.
    ///
    /// This is the packed-XNOR hot path: HD = popcount(a XOR b).
    #[inline]
    pub fn hamming(&self, other: &BitVec) -> u32 {
        debug_assert_eq!(self.len, other.len);
        hamming_words(&self.words, &other.words)
    }

    /// ±1 dot product: n − 2·HD.
    #[inline]
    pub fn dot_pm1(&self, other: &BitVec) -> i32 {
        self.len as i32 - 2 * self.hamming(other) as i32
    }

    /// Slice of bits [lo, hi) as a new BitVec (used for row segmentation).
    /// Word-level shift-copy: O(words), not O(bits).
    pub fn slice(&self, lo: usize, hi: usize) -> BitVec {
        assert!(lo <= hi && hi <= self.len);
        let len = hi - lo;
        let mut out = BitVec::zeros(len);
        copy_bits(&self.words, lo, len, &mut out.words, 0);
        out.mask_tail();
        out
    }

    /// Overwrite bits [dst_lo, dst_lo+len) of `self` with bits
    /// [src_lo, src_lo+len) of `src` (word-level).
    pub fn write_range(&mut self, dst_lo: usize, src: &BitVec, src_lo: usize, len: usize) {
        assert!(src_lo + len <= src.len && dst_lo + len <= self.len);
        copy_bits(&src.words, src_lo, len, &mut self.words, dst_lo);
        self.mask_tail();
    }

    fn mask_tail(&mut self) {
        let tail = self.len % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }
}

/// Copy `len` bits from `src` starting at bit `src_lo` into `dst` starting
/// at bit `dst_lo`, using word-level shifts (O(len/64), not O(len)).
/// Bits of `dst` outside the target range are preserved.
pub fn copy_bits(src: &[u64], src_lo: usize, len: usize, dst: &mut [u64], dst_lo: usize) {
    if len == 0 {
        return;
    }
    // read bit i (relative) from src
    let read = |i: usize| -> u64 {
        let bit = src_lo + i;
        (src[bit / 64] >> (bit % 64)) & 1
    };
    // fast path: both word-aligned
    if src_lo % 64 == 0 && dst_lo % 64 == 0 {
        let full = len / 64;
        let sw = src_lo / 64;
        let dw = dst_lo / 64;
        dst[dw..dw + full].copy_from_slice(&src[sw..sw + full]);
        let tail = len % 64;
        if tail != 0 {
            let mask = (1u64 << tail) - 1;
            dst[dw + full] = (dst[dw + full] & !mask) | (src[sw + full] & mask);
        }
        return;
    }
    // general path: gather 64-bit windows with a double-word shift
    let shift = src_lo % 64;
    let sbase = src_lo / 64;
    let gather = |widx: usize| -> u64 {
        // the 64 source bits starting at src_lo + widx*64
        let lo = src[sbase + widx] >> shift;
        let hi_idx = sbase + widx + 1;
        let hi = if shift == 0 || hi_idx >= src.len() {
            0
        } else {
            src[hi_idx] << (64 - shift)
        };
        lo | hi
    };
    let mut written = 0usize;
    while written < len {
        let n = (len - written).min(64);
        let chunk = if written / 64 * 64 == written && n == 64 && src_lo + written + 64 <= src.len() * 64
        {
            gather(written / 64)
        } else {
            // boundary chunk: assemble bit-by-bit (at most 2 per call)
            let mut w = 0u64;
            for b in 0..n {
                w |= read(written + b) << b;
            }
            w
        };
        // scatter chunk into dst at dst_lo + written
        let pos = dst_lo + written;
        let dwi = pos / 64;
        let doff = pos % 64;
        let mask = if n == 64 { !0u64 } else { (1u64 << n) - 1 };
        dst[dwi] = (dst[dwi] & !(mask << doff)) | ((chunk & mask) << doff);
        let spill = (doff + n).saturating_sub(64);
        if spill > 0 {
            let smask = (1u64 << spill) - 1;
            dst[dwi + 1] =
                (dst[dwi + 1] & !smask) | ((chunk >> (n - spill)) & smask);
        }
        written += n;
    }
}

// ---------------------------------------------------------------------
// Runtime-dispatched Hamming backends (module docs)
// ---------------------------------------------------------------------

/// Popcount backend behind every Hamming entry point (module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HammingBackend {
    /// Portable per-word `count_ones` loop — the bit-exact reference.
    Scalar,
    /// 4×u64-unrolled branch-free SWAR popcount (no target features).
    Swar,
    /// 256-bit XOR + nibble-LUT popcount (`std::arch`), gated at runtime
    /// on `is_x86_feature_detected!("avx2")`.
    Avx2,
}

impl HammingBackend {
    /// Stable lower-case name (`PICBNN_FORCE_BACKEND` values; persisted
    /// in bench records).
    pub fn name(self) -> &'static str {
        match self {
            HammingBackend::Scalar => "scalar",
            HammingBackend::Swar => "swar",
            HammingBackend::Avx2 => "avx2",
        }
    }
}

/// Parse a `PICBNN_FORCE_BACKEND` value; `None` = auto-detect.
fn parse_backend(s: &str) -> Option<HammingBackend> {
    match s {
        "scalar" => Some(HammingBackend::Scalar),
        "swar" => Some(HammingBackend::Swar),
        "avx2" => Some(HammingBackend::Avx2),
        _ => None,
    }
}

/// Whether the AVX2 kernels may execute on this host (runtime CPUID).
fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Every backend that can run on this host, scalar first (the reference
/// the backend property tests compare against).
pub fn available_backends() -> Vec<HammingBackend> {
    let mut v = vec![HammingBackend::Scalar, HammingBackend::Swar];
    if avx2_available() {
        v.push(HammingBackend::Avx2);
    }
    v
}

static ACTIVE_BACKEND: std::sync::OnceLock<HammingBackend> = std::sync::OnceLock::new();

/// The backend every dispatching entry point runs on, selected once per
/// process: `PICBNN_FORCE_BACKEND` if set (an unrunnable or unknown
/// value downgrades — module docs), else AVX2 when detected, else SWAR.
pub fn active_backend() -> HammingBackend {
    *ACTIVE_BACKEND.get_or_init(|| {
        let forced = std::env::var("PICBNN_FORCE_BACKEND")
            .ok()
            .and_then(|v| parse_backend(&v));
        match forced {
            Some(HammingBackend::Avx2) if !avx2_available() => HammingBackend::Swar,
            Some(b) => b,
            None if avx2_available() => HammingBackend::Avx2,
            None => HammingBackend::Swar,
        }
    })
}

/// Explicit-backend entry points refuse backends the host cannot run
/// (the alternative is undefined behaviour, not a wrong answer).
fn assert_backend_runnable(backend: HammingBackend) {
    assert!(
        backend != HammingBackend::Avx2 || avx2_available(),
        "AVX2 backend requested on a host without AVX2 (pick from available_backends())"
    );
}

/// Branch-free SWAR popcount (Hacker's Delight §5-1) — exact for every
/// input; the `Swar` backend's primitive.
#[inline]
const fn popcount64(x: u64) -> u32 {
    let x = x - ((x >> 1) & 0x5555_5555_5555_5555);
    let x = (x & 0x3333_3333_3333_3333) + ((x >> 2) & 0x3333_3333_3333_3333);
    let x = (x + (x >> 4)) & 0x0F0F_0F0F_0F0F_0F0F;
    (x.wrapping_mul(0x0101_0101_0101_0101) >> 56) as u32
}

#[inline]
fn hamming_words_scalar(a: &[u64], b: &[u64]) -> u32 {
    let mut acc = 0u32;
    for (x, y) in a.iter().zip(b) {
        acc += (x ^ y).count_ones();
    }
    acc
}

fn hamming_words_swar(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len().min(b.len());
    let chunks = n / 4;
    let mut acc = [0u32; 4];
    for c in 0..chunks {
        let i = 4 * c;
        acc[0] += popcount64(a[i] ^ b[i]);
        acc[1] += popcount64(a[i + 1] ^ b[i + 1]);
        acc[2] += popcount64(a[i + 2] ^ b[i + 2]);
        acc[3] += popcount64(a[i + 3] ^ b[i + 3]);
    }
    let mut t = acc[0] + acc[1] + acc[2] + acc[3];
    for i in 4 * chunks..n {
        t += popcount64(a[i] ^ b[i]);
    }
    t
}

#[inline]
fn hamming_words_masked_scalar(a: &[u64], b: &[u64], mask: &[u64]) -> u32 {
    let mut acc = 0u32;
    for ((x, y), k) in a.iter().zip(b).zip(mask) {
        acc += ((x ^ y) & k).count_ones();
    }
    acc
}

fn hamming_words_masked_swar(a: &[u64], b: &[u64], mask: &[u64]) -> u32 {
    let n = a.len().min(b.len()).min(mask.len());
    let chunks = n / 4;
    let mut acc = [0u32; 4];
    for c in 0..chunks {
        let i = 4 * c;
        acc[0] += popcount64((a[i] ^ b[i]) & mask[i]);
        acc[1] += popcount64((a[i + 1] ^ b[i + 1]) & mask[i + 1]);
        acc[2] += popcount64((a[i + 2] ^ b[i + 2]) & mask[i + 2]);
        acc[3] += popcount64((a[i + 3] ^ b[i + 3]) & mask[i + 3]);
    }
    let mut t = acc[0] + acc[1] + acc[2] + acc[3];
    for i in 4 * chunks..n {
        t += popcount64((a[i] ^ b[i]) & mask[i]);
    }
    t
}

/// AVX2 kernels: 256-bit XOR + nibble-LUT popcount (Mula's scheme —
/// `_mm256_shuffle_epi8` per nibble, byte sums folded through
/// `_mm256_sad_epu8` into four u64 lanes).  Every function here is
/// `unsafe` + `#[target_feature(enable = "avx2")]` and is reachable only
/// behind the runtime `avx2_available()` check — the module's single
/// safety obligation.  Word tails shorter than one 256-bit lane fall to
/// the scalar loop, so any slice length is exact.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use core::arch::x86_64::*;

    /// Byte-wise popcount of one 256-bit lane.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcount_bytes(x: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, 0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3,
            2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(x, low);
        let hi = _mm256_and_si256(_mm256_srli_epi32::<4>(x), low);
        _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi))
    }

    /// Sum of the four u64 lanes of a `_mm256_sad_epu8` accumulator.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi64(acc: __m256i) -> u64 {
        let mut lanes = [0u64; 4];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        lanes[0] + lanes[1] + lanes[2] + lanes[3]
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn hamming_words(a: &[u64], b: &[u64]) -> u32 {
        let n = a.len().min(b.len());
        let chunks = n / 4;
        let zero = _mm256_setzero_si256();
        let mut acc = zero;
        for c in 0..chunks {
            let va = _mm256_loadu_si256(a.as_ptr().add(4 * c) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(4 * c) as *const __m256i);
            let cnt = popcount_bytes(_mm256_xor_si256(va, vb));
            acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, zero));
        }
        let mut t = hsum_epi64(acc) as u32;
        for i in 4 * chunks..n {
            t += (a[i] ^ b[i]).count_ones();
        }
        t
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn hamming_words_masked(a: &[u64], b: &[u64], mask: &[u64]) -> u32 {
        let n = a.len().min(b.len()).min(mask.len());
        let chunks = n / 4;
        let zero = _mm256_setzero_si256();
        let mut acc = zero;
        for c in 0..chunks {
            let va = _mm256_loadu_si256(a.as_ptr().add(4 * c) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(4 * c) as *const __m256i);
            let vk = _mm256_loadu_si256(mask.as_ptr().add(4 * c) as *const __m256i);
            let x = _mm256_and_si256(_mm256_xor_si256(va, vb), vk);
            acc = _mm256_add_epi64(acc, _mm256_sad_epu8(popcount_bytes(x), zero));
        }
        let mut t = hsum_epi64(acc) as u32;
        for i in 4 * chunks..n {
            t += ((a[i] ^ b[i]) & mask[i]).count_ones();
        }
        t
    }

    /// One register tile of the batched kernel: the row streamed in
    /// 256-bit lanes against `K` query slices, `K` independent
    /// `sad_epu8` accumulator chains (the scalar tile's accumulator
    /// tiling widened to four words per step).  Callers validated every
    /// slice to `stride` words at batch entry.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn tile_rows<const K: usize>(
        data: &[u64],
        stride: usize,
        rows: usize,
        qs: &[&[u64]; K],
        out: &mut [u32],
        out_stride: usize,
    ) {
        let zero = _mm256_setzero_si256();
        let chunks = stride / 4;
        for r in 0..rows {
            let row = &data[r * stride..(r + 1) * stride];
            let mut acc = [zero; K];
            for c in 0..chunks {
                let w = _mm256_loadu_si256(row.as_ptr().add(4 * c) as *const __m256i);
                for k in 0..K {
                    let q = _mm256_loadu_si256(qs[k].as_ptr().add(4 * c) as *const __m256i);
                    let cnt = popcount_bytes(_mm256_xor_si256(w, q));
                    acc[k] = _mm256_add_epi64(acc[k], _mm256_sad_epu8(cnt, zero));
                }
            }
            for k in 0..K {
                let mut t = hsum_epi64(acc[k]) as u32;
                for i in 4 * chunks..stride {
                    t += (row[i] ^ qs[k][i]).count_ones();
                }
                out[k * out_stride + r] = t;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn hamming_words_avx2(a: &[u64], b: &[u64]) -> u32 {
    // SAFETY: `HammingBackend::Avx2` only reaches a dispatch arm behind
    // `avx2_available()` — backend selection and the `_with` guards.
    unsafe { avx2::hamming_words(a, b) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn hamming_words_avx2(a: &[u64], b: &[u64]) -> u32 {
    hamming_words_swar(a, b) // unreachable: Avx2 is never selected here
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn hamming_words_masked_avx2(a: &[u64], b: &[u64], mask: &[u64]) -> u32 {
    // SAFETY: as `hamming_words_avx2`.
    unsafe { avx2::hamming_words_masked(a, b, mask) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn hamming_words_masked_avx2(a: &[u64], b: &[u64], mask: &[u64]) -> u32 {
    hamming_words_masked_swar(a, b, mask)
}

/// Hamming distance between equal-length word slices (dispatched to
/// [`active_backend`]; exact on every backend).
#[inline]
pub fn hamming_words(a: &[u64], b: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    match active_backend() {
        HammingBackend::Scalar => hamming_words_scalar(a, b),
        HammingBackend::Swar => hamming_words_swar(a, b),
        HammingBackend::Avx2 => hamming_words_avx2(a, b),
    }
}

/// [`hamming_words`] on an explicit backend (A/B runs and the backend
/// bit-identity tests).  Panics if `backend` cannot run on this host —
/// pick from [`available_backends`].
pub fn hamming_words_with(backend: HammingBackend, a: &[u64], b: &[u64]) -> u32 {
    assert_backend_runnable(backend);
    debug_assert_eq!(a.len(), b.len());
    match backend {
        HammingBackend::Scalar => hamming_words_scalar(a, b),
        HammingBackend::Swar => hamming_words_swar(a, b),
        HammingBackend::Avx2 => hamming_words_avx2(a, b),
    }
}

/// Hamming distance over driven columns only: popcount((a ^ b) & mask)
/// (the ternary-search primitive — masked columns never open a discharge
/// path, see `cam::ops::masked_search`).  Dispatched like
/// [`hamming_words`].
#[inline]
pub fn hamming_words_masked(a: &[u64], b: &[u64], mask: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), mask.len());
    match active_backend() {
        HammingBackend::Scalar => hamming_words_masked_scalar(a, b, mask),
        HammingBackend::Swar => hamming_words_masked_swar(a, b, mask),
        HammingBackend::Avx2 => hamming_words_masked_avx2(a, b, mask),
    }
}

/// [`hamming_words_masked`] on an explicit backend (see
/// [`hamming_words_with`]).
pub fn hamming_words_masked_with(
    backend: HammingBackend,
    a: &[u64],
    b: &[u64],
    mask: &[u64],
) -> u32 {
    assert_backend_runnable(backend);
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), mask.len());
    match backend {
        HammingBackend::Scalar => hamming_words_masked_scalar(a, b, mask),
        HammingBackend::Swar => hamming_words_masked_swar(a, b, mask),
        HammingBackend::Avx2 => hamming_words_masked_avx2(a, b, mask),
    }
}

/// Queries per register tile of the batched Hamming kernel.  Eight 32-bit
/// accumulators plus the row word fit comfortably in registers, and an
/// 8-query × 32-word tile (2 KiB of query words) stays L1-resident.
pub const QUERY_TILE: usize = 8;

/// One register tile, scalar backend: `K` query word-slices held live
/// against each streamed row, `K` independent accumulator chains.
fn tile_rows_scalar<const K: usize>(
    data: &[u64],
    stride: usize,
    rows: usize,
    qs: &[&[u64]; K],
    out: &mut [u32],
    out_stride: usize,
) {
    for r in 0..rows {
        let row = &data[r * stride..(r + 1) * stride];
        let mut acc = [0u32; K];
        for (i, &w) in row.iter().enumerate() {
            for (k, q) in qs.iter().enumerate() {
                acc[k] += (w ^ q[i]).count_ones();
            }
        }
        for (k, &a) in acc.iter().enumerate() {
            out[k * out_stride + r] = a;
        }
    }
}

/// One register tile, SWAR backend: the row streamed four words per step
/// through [`popcount64`], `K` accumulator chains as in the scalar tile.
fn tile_rows_swar<const K: usize>(
    data: &[u64],
    stride: usize,
    rows: usize,
    qs: &[&[u64]; K],
    out: &mut [u32],
    out_stride: usize,
) {
    let chunks = stride / 4;
    for r in 0..rows {
        let row = &data[r * stride..(r + 1) * stride];
        let mut acc = [0u32; K];
        for c in 0..chunks {
            let i = 4 * c;
            for (k, q) in qs.iter().enumerate() {
                acc[k] += popcount64(row[i] ^ q[i])
                    + popcount64(row[i + 1] ^ q[i + 1])
                    + popcount64(row[i + 2] ^ q[i + 2])
                    + popcount64(row[i + 3] ^ q[i + 3]);
            }
        }
        for i in 4 * chunks..stride {
            for (k, q) in qs.iter().enumerate() {
                acc[k] += popcount64(row[i] ^ q[i]);
            }
        }
        for (k, &a) in acc.iter().enumerate() {
            out[k * out_stride + r] = a;
        }
    }
}

/// The enum dispatch at the heart of the batched kernel: one validated
/// tile handed to the selected backend (no trait objects; the backend
/// was chosen once at batch entry).
fn tile_rows_dispatch<const K: usize>(
    backend: HammingBackend,
    data: &[u64],
    stride: usize,
    rows: usize,
    qs: &[&[u64]; K],
    out: &mut [u32],
    out_stride: usize,
) {
    match backend {
        HammingBackend::Scalar => tile_rows_scalar::<K>(data, stride, rows, qs, out, out_stride),
        HammingBackend::Swar => tile_rows_swar::<K>(data, stride, rows, qs, out, out_stride),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `Avx2` only reaches a dispatch arm behind
        // `avx2_available()` — backend selection and the `_with` guards.
        HammingBackend::Avx2 => unsafe {
            avx2::tile_rows::<K>(data, stride, rows, qs, out, out_stride)
        },
        #[cfg(not(target_arch = "x86_64"))]
        HammingBackend::Avx2 => tile_rows_swar::<K>(data, stride, rows, qs, out, out_stride),
    }
}

/// A dense row-major matrix of packed ±1 rows (e.g. a binary weight matrix:
/// `rows` neurons × `cols` inputs), rows padded to whole words.
#[derive(Clone, Debug)]
pub struct BitMatrix {
    data: Vec<u64>,
    rows: usize,
    cols: usize,
    stride: usize, // words per row
}

impl BitMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        let stride = words_for(cols);
        BitMatrix {
            data: vec![0; rows * stride],
            rows,
            cols,
            stride,
        }
    }

    /// Assemble from per-row BitVecs (all of length `cols`).
    pub fn from_rows(rows: &[BitVec]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        let mut m = BitMatrix::zeros(rows.len(), cols);
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), cols);
            m.row_words_mut(r).copy_from_slice(row.words());
        }
        m
    }

    /// From raw packed words laid out row-major with this stride.
    pub fn from_words(data: Vec<u64>, rows: usize, cols: usize) -> Self {
        let stride = words_for(cols);
        assert_eq!(data.len(), rows * stride);
        BitMatrix {
            data,
            rows,
            cols,
            stride,
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row_words(&self, r: usize) -> &[u64] {
        &self.data[r * self.stride..(r + 1) * self.stride]
    }

    #[inline]
    pub fn row_words_mut(&mut self, r: usize) -> &mut [u64] {
        &mut self.data[r * self.stride..(r + 1) * self.stride]
    }

    pub fn row(&self, r: usize) -> BitVec {
        BitVec::from_words(self.row_words(r).to_vec(), self.cols)
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        (self.row_words(r)[c / 64] >> (c % 64)) & 1 == 1
    }

    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        let stride = self.stride;
        let w = &mut self.data[r * stride + c / 64];
        if v {
            *w |= 1 << (c % 64);
        } else {
            *w &= !(1 << (c % 64));
        }
    }

    /// Reshape in place to `rows` × `cols`, zero-filled, reusing the
    /// existing allocation (batch-path scratch: steady-state calls with a
    /// stable shape never reallocate).
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.stride = words_for(cols);
        self.data.clear();
        self.data.resize(rows * self.stride, 0);
    }

    /// Indices of set bits in row `r`, ascending (walks the packed fires
    /// bitmask one `trailing_zeros` per set bit, so vote accumulation
    /// costs O(fires), not O(rows)).
    pub fn row_ones(&self, r: usize) -> RowOnes<'_> {
        let words = self.row_words(r);
        RowOnes {
            words,
            word_idx: 0,
            cur: words.first().copied().unwrap_or(0),
        }
    }

    /// HD between `query` and every row; appends into `out`.
    pub fn hamming_all(&self, query: &BitVec, out: &mut Vec<u32>) {
        debug_assert_eq!(query.len(), self.cols);
        out.clear();
        out.reserve(self.rows);
        for r in 0..self.rows {
            out.push(hamming_words(self.row_words(r), query.words()));
        }
    }

    /// HD between every query and every row, query-batched: resizes `out`
    /// to `queries.len() * rows` and writes `out[q * rows + r]`.
    ///
    /// This is the register-tiled kernel described in the module docs:
    /// each row's words are streamed once per tile of [`QUERY_TILE`]
    /// queries instead of once per query, on the dispatched
    /// [`active_backend`].
    pub fn hamming_all_batch(&self, queries: &[BitVec], out: &mut Vec<u32>) {
        out.clear();
        out.resize(queries.len() * self.rows, 0);
        self.hamming_rows_batch_into(self.rows, queries, out, self.rows);
    }

    /// [`BitMatrix::hamming_all_batch`] on an explicit backend (A/B runs
    /// and the backend bit-identity tests; production paths dispatch on
    /// [`active_backend`]).  Panics if `backend` cannot run on this host.
    pub fn hamming_all_batch_with(
        &self,
        backend: HammingBackend,
        queries: &[BitVec],
        out: &mut Vec<u32>,
    ) {
        assert_backend_runnable(backend);
        out.clear();
        out.resize(queries.len() * self.rows, 0);
        self.batch_core(
            backend,
            self.rows,
            queries.len(),
            |i| queries[i].words(),
            out,
            self.rows,
        );
    }

    /// [`BitMatrix::hamming_all_batch`] restricted to the first `rows`
    /// rows, writing `out[q * out_stride + r]` (entries past `rows` are
    /// left untouched).  `cam::CamArray` uses this to tile over the
    /// programmed row prefix only.
    pub fn hamming_rows_batch_into(
        &self,
        rows: usize,
        queries: &[BitVec],
        out: &mut [u32],
        out_stride: usize,
    ) {
        self.batch_core(
            active_backend(),
            rows,
            queries.len(),
            |i| queries[i].words(),
            out,
            out_stride,
        );
    }

    /// [`BitMatrix::hamming_rows_batch_into`] with the queries packed as
    /// the rows of another `BitMatrix` (`queries.rows()` queries of
    /// `queries.cols()` bits) — the allocation-free batch path: engines
    /// reuse one query block across batches instead of building
    /// per-query `BitVec`s.
    pub fn hamming_rows_batch_from(
        &self,
        rows: usize,
        queries: &BitMatrix,
        out: &mut [u32],
        out_stride: usize,
    ) {
        assert_eq!(queries.cols, self.cols, "query width mismatch");
        self.batch_core(
            active_backend(),
            rows,
            queries.rows,
            |i| queries.row_words(i),
            out,
            out_stride,
        );
    }

    /// The shared batch loop: validate once per batch entry (the
    /// per-query width check is hoisted out of the tile row loops), then
    /// hand register tiles of up to [`QUERY_TILE`] query slices to the
    /// selected backend.
    fn batch_core<'q, F: Fn(usize) -> &'q [u64]>(
        &self,
        backend: HammingBackend,
        rows: usize,
        nq: usize,
        q_words: F,
        out: &mut [u32],
        out_stride: usize,
    ) {
        assert!(rows <= self.rows, "row limit exceeds the matrix");
        assert!(rows <= out_stride, "output stride too small");
        if nq == 0 {
            return;
        }
        assert!(
            out.len() >= (nq - 1) * out_stride + rows,
            "output buffer too small"
        );
        // single batch-entry validation: every tile below trusts the
        // slices to span exactly `stride` words
        for i in 0..nq {
            assert_eq!(q_words(i).len(), self.stride, "query width mismatch");
        }
        let (data, stride) = (&self.data[..], self.stride);
        let mut q0 = 0usize;
        while q0 < nq {
            let k = (nq - q0).min(QUERY_TILE);
            let out_tile = &mut out[q0 * out_stride..];
            // one arm per const tile width, all sharing the same call body
            macro_rules! tile {
                ($k:literal) => {
                    tile_rows_dispatch::<$k>(
                        backend,
                        data,
                        stride,
                        rows,
                        &core::array::from_fn(|j| q_words(q0 + j)),
                        out_tile,
                        out_stride,
                    )
                };
            }
            match k {
                8 => tile!(8),
                7 => tile!(7),
                6 => tile!(6),
                5 => tile!(5),
                4 => tile!(4),
                3 => tile!(3),
                2 => tile!(2),
                1 => tile!(1),
                _ => unreachable!("tiles span 1..={QUERY_TILE} queries"),
            }
            q0 += k;
        }
    }

    /// The backing words, row-major with `words_for(cols)` words per row
    /// (e.g. for pointer-stability assertions on scratch reuse).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.data
    }
}

impl Default for BitMatrix {
    fn default() -> Self {
        BitMatrix::zeros(0, 0)
    }
}

/// Iterator over the set-bit indices of one packed row
/// (see [`BitMatrix::row_ones`]).
pub struct RowOnes<'a> {
    words: &'a [u64],
    word_idx: usize,
    cur: u64,
}

impl Iterator for RowOnes<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        while self.cur == 0 {
            self.word_idx += 1;
            if self.word_idx >= self.words.len() {
                return None;
            }
            self.cur = self.words[self.word_idx];
        }
        let bit = self.cur.trailing_zeros() as usize;
        self.cur &= self.cur - 1;
        Some(self.word_idx * 64 + bit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn words_for_boundaries() {
        assert_eq!(words_for(0), 0);
        assert_eq!(words_for(1), 1);
        assert_eq!(words_for(64), 1);
        assert_eq!(words_for(65), 2);
        assert_eq!(words_for(784), 13);
    }

    #[test]
    fn set_get_roundtrip() {
        let mut v = BitVec::zeros(130);
        v.set(0, true);
        v.set(63, true);
        v.set(64, true);
        v.set(129, true);
        for i in 0..130 {
            assert_eq!(v.get(i), matches!(i, 0 | 63 | 64 | 129), "{i}");
        }
        assert_eq!(v.count_ones(), 4);
    }

    #[test]
    fn ones_respects_tail() {
        let v = BitVec::ones(70);
        assert_eq!(v.count_ones(), 70);
        assert_eq!(v.words()[1] >> 6, 0);
    }

    #[test]
    fn hamming_matches_naive() {
        let mut rng = Rng::new(1, 1);
        for len in [1usize, 63, 64, 65, 784, 1024] {
            let mut a = BitVec::zeros(len);
            let mut b = BitVec::zeros(len);
            for i in 0..len {
                a.set(i, rng.chance(0.5));
                b.set(i, rng.chance(0.5));
            }
            let naive = (0..len).filter(|&i| a.get(i) != b.get(i)).count() as u32;
            assert_eq!(a.hamming(&b), naive, "len {len}");
        }
    }

    #[test]
    fn dot_pm1_identity() {
        let v = BitVec::from_pm1(&[1, -1, 1, 1, -1]);
        assert_eq!(v.dot_pm1(&v), 5);
        let w = BitVec::from_pm1(&[-1, 1, -1, -1, 1]);
        assert_eq!(v.dot_pm1(&w), -5);
    }

    #[test]
    fn slice_extracts_bits() {
        let v = BitVec::from_pm1(&[1, -1, 1, 1, -1, 1, -1, -1]);
        let s = v.slice(2, 6);
        assert_eq!(s.len(), 4);
        assert_eq!(
            (0..4).map(|i| s.pm1(i)).collect::<Vec<_>>(),
            vec![1, 1, -1, 1]
        );
    }

    #[test]
    fn copy_bits_matches_naive_reference() {
        let mut rng = Rng::new(17, 3);
        for _ in 0..300 {
            let src_bits = rng.range_u64(1, 300) as usize;
            let dst_bits = rng.range_u64(1, 300) as usize;
            let mut src = BitVec::zeros(src_bits);
            let mut dst = BitVec::zeros(dst_bits);
            for i in 0..src_bits {
                src.set(i, rng.chance(0.5));
            }
            for i in 0..dst_bits {
                dst.set(i, rng.chance(0.5));
            }
            let max_len = src_bits.min(dst_bits);
            let len = rng.range_u64(0, max_len as u64) as usize;
            let src_lo = rng.range_u64(0, (src_bits - len) as u64) as usize;
            let dst_lo = rng.range_u64(0, (dst_bits - len) as u64) as usize;
            // naive reference
            let mut want = dst.clone();
            for i in 0..len {
                want.set(dst_lo + i, src.get(src_lo + i));
            }
            let mut got = dst.clone();
            got.write_range(dst_lo, &src, src_lo, len);
            assert_eq!(
                got, want,
                "src_bits={src_bits} dst_bits={dst_bits} len={len} src_lo={src_lo} dst_lo={dst_lo}"
            );
        }
    }

    #[test]
    fn slice_matches_naive_on_random_ranges() {
        let mut rng = Rng::new(23, 5);
        for _ in 0..200 {
            let bits = rng.range_u64(1, 3000) as usize;
            let mut v = BitVec::zeros(bits);
            for i in 0..bits {
                v.set(i, rng.chance(0.5));
            }
            let hi = rng.range_u64(0, bits as u64) as usize;
            let lo = rng.range_u64(0, hi as u64) as usize;
            let s = v.slice(lo, hi);
            for i in 0..(hi - lo) {
                assert_eq!(s.get(i), v.get(lo + i), "bits={bits} lo={lo} hi={hi} i={i}");
            }
            assert_eq!(s.count_ones(), (lo..hi).filter(|&i| v.get(i)).count() as u32);
        }
    }

    #[test]
    fn copy_bits_unaligned_src_roundtrip() {
        // force the general (shift-gather) path: src_lo % 64 != 0, spans
        // long enough to exercise whole-word windows plus boundary chunks
        let mut rng = Rng::new(41, 9);
        for _ in 0..400 {
            let src_bits = 64 + rng.range_u64(1, 2048) as usize;
            let dst_bits = 64 + rng.range_u64(1, 2048) as usize;
            let mut src = BitVec::zeros(src_bits);
            let mut dst = BitVec::zeros(dst_bits);
            for i in 0..src_bits {
                src.set(i, rng.chance(0.5));
            }
            for i in 0..dst_bits {
                dst.set(i, rng.chance(0.5));
            }
            let max_len = (src_bits - 63).min(dst_bits);
            let len = rng.range_u64(0, max_len as u64) as usize;
            // src_lo deliberately word-misaligned (bump off alignment when
            // the range still fits)
            let mut src_lo = rng.range_u64(0, (src_bits - len) as u64) as usize;
            if src_lo % 64 == 0 && src_lo + 1 + len <= src_bits {
                src_lo += 1;
            }
            let dst_lo = rng.range_u64(0, (dst_bits - len) as u64) as usize;
            let mut want = dst.clone();
            for i in 0..len {
                want.set(dst_lo + i, src.get(src_lo + i));
            }
            let mut got = dst.clone();
            got.write_range(dst_lo, &src, src_lo, len);
            assert_eq!(
                got, want,
                "src_bits={src_bits} dst_bits={dst_bits} len={len} src_lo={src_lo} dst_lo={dst_lo}"
            );
        }
    }

    #[test]
    fn from_words_masks_tail_at_odd_lengths() {
        // tail bits of the last word beyond `len` must be cleared, so the
        // vector equals the same content built bit-by-bit and hamming /
        // count_ones never see ghost bits
        for len in [1usize, 63, 65, 100, 127, 129, 700, 784] {
            let dirty = vec![!0u64; words_for(len)];
            let v = BitVec::from_words(dirty, len);
            assert_eq!(v.count_ones() as usize, len, "len {len}");
            let want = BitVec::ones(len);
            assert_eq!(v, want, "len {len}");
            // round-trip through words() preserves the masked form
            let v2 = BitVec::from_words(v.words().to_vec(), len);
            assert_eq!(v2, v, "len {len}");
            assert_eq!(v.hamming(&BitVec::zeros(len)) as usize, len);
        }
    }

    #[test]
    fn from_words_roundtrip_random_unaligned_lengths() {
        let mut rng = Rng::new(77, 13);
        for _ in 0..100 {
            // lengths deliberately not multiples of 64
            let len = (rng.range_u64(1, 2000) as usize) | 1;
            let mut v = BitVec::zeros(len);
            for i in 0..len {
                v.set(i, rng.chance(0.5));
            }
            let rt = BitVec::from_words(v.words().to_vec(), len);
            assert_eq!(rt, v, "len {len}");
            assert_eq!(rt.count_ones(), v.count_ones());
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic]
    fn hamming_words_rejects_length_mismatch_in_debug() {
        let a = [0u64; 3];
        let b = [0u64; 2];
        let _ = hamming_words(&a, &b);
    }

    #[test]
    fn matrix_rows_roundtrip() {
        let rows: Vec<BitVec> = (0..5)
            .map(|r| {
                let mut v = BitVec::zeros(100);
                v.set(r * 7, true);
                v
            })
            .collect();
        let m = BitMatrix::from_rows(&rows);
        assert_eq!(m.rows(), 5);
        assert_eq!(m.cols(), 100);
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(&m.row(r), row);
        }
    }

    #[test]
    fn hamming_all_batch_matches_per_row_for_every_tile_shape() {
        // batch sizes crossing the QUERY_TILE boundary, plus odd widths so
        // the last word is partial
        let mut rng = Rng::new(9, 31);
        for cols in [64usize, 257, 1024] {
            let rows: Vec<BitVec> = (0..13)
                .map(|_| {
                    let mut v = BitVec::zeros(cols);
                    for i in 0..cols {
                        v.set(i, rng.chance(0.5));
                    }
                    v
                })
                .collect();
            let m = BitMatrix::from_rows(&rows);
            for nq in [1usize, 2, 7, 8, 9, 17] {
                let queries: Vec<BitVec> = (0..nq)
                    .map(|_| {
                        let mut v = BitVec::zeros(cols);
                        for i in 0..cols {
                            v.set(i, rng.chance(0.5));
                        }
                        v
                    })
                    .collect();
                let mut out = Vec::new();
                m.hamming_all_batch(&queries, &mut out);
                assert_eq!(out.len(), nq * m.rows());
                for (q, query) in queries.iter().enumerate() {
                    for (r, row) in rows.iter().enumerate() {
                        assert_eq!(
                            out[q * m.rows() + r],
                            row.hamming(query),
                            "cols={cols} nq={nq} q={q} r={r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn hamming_rows_batch_into_respects_row_limit_and_stride() {
        let mut rng = Rng::new(4, 44);
        let rows: Vec<BitVec> = (0..10)
            .map(|_| {
                let mut v = BitVec::zeros(130);
                for i in 0..130 {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect();
        let m = BitMatrix::from_rows(&rows);
        let q = rows[3].clone();
        let queries = vec![q.clone(), rows[7].clone()];
        let stride = 16; // > row limit: tail entries must stay untouched
        let mut out = vec![u32::MAX; 2 * stride];
        m.hamming_rows_batch_into(6, &queries, &mut out, stride);
        for (qi, query) in queries.iter().enumerate() {
            for r in 0..6 {
                assert_eq!(out[qi * stride + r], rows[r].hamming(query));
            }
            for r in 6..stride {
                assert_eq!(out[qi * stride + r], u32::MAX, "tail clobbered");
            }
        }
    }

    #[test]
    fn hamming_words_masked_matches_naive() {
        let mut rng = Rng::new(8, 18);
        for len in [1usize, 64, 65, 700] {
            let mut a = BitVec::zeros(len);
            let mut b = BitVec::zeros(len);
            let mut k = BitVec::zeros(len);
            for i in 0..len {
                a.set(i, rng.chance(0.5));
                b.set(i, rng.chance(0.5));
                k.set(i, rng.chance(0.5));
            }
            let naive = (0..len)
                .filter(|&i| k.get(i) && a.get(i) != b.get(i))
                .count() as u32;
            assert_eq!(
                hamming_words_masked(a.words(), b.words(), k.words()),
                naive,
                "len {len}"
            );
        }
    }

    #[test]
    fn row_ones_walks_exactly_the_set_bits() {
        let mut rng = Rng::new(6, 66);
        let mut m = BitMatrix::zeros(4, 300);
        for r in 0..4 {
            for c in 0..300 {
                m.set(r, c, rng.chance(0.1));
            }
        }
        for r in 0..4 {
            let got: Vec<usize> = m.row_ones(r).collect();
            let want: Vec<usize> = (0..300).filter(|&c| m.get(r, c)).collect();
            assert_eq!(got, want, "row {r}");
        }
        // empty row and empty matrix
        let z = BitMatrix::zeros(1, 128);
        assert_eq!(z.row_ones(0).count(), 0);
        let e = BitMatrix::default();
        assert_eq!(e.rows(), 0);
    }

    #[test]
    fn reset_reuses_the_allocation() {
        let mut m = BitMatrix::zeros(8, 512);
        m.set(3, 100, true);
        let cap = m.data.capacity();
        let ptr = m.data.as_ptr();
        m.reset(8, 512);
        assert_eq!(m.data.capacity(), cap);
        assert_eq!(m.data.as_ptr(), ptr);
        assert!(!m.get(3, 100), "reset must zero the contents");
        // shrinking then growing back stays within the first allocation
        m.reset(2, 64);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 64);
        m.reset(8, 512);
        assert_eq!(m.data.capacity(), cap);
    }

    #[test]
    fn hamming_all_matches_per_row() {
        let mut rng = Rng::new(2, 2);
        let rows: Vec<BitVec> = (0..8)
            .map(|_| {
                let mut v = BitVec::zeros(257);
                for i in 0..257 {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect();
        let m = BitMatrix::from_rows(&rows);
        let mut q = BitVec::zeros(257);
        for i in 0..257 {
            q.set(i, rng.chance(0.5));
        }
        let mut out = Vec::new();
        m.hamming_all(&q, &mut out);
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(out[r], row.hamming(&q));
        }
    }

    #[test]
    fn backend_names_parse_and_unknown_values_fall_through() {
        for b in [
            HammingBackend::Scalar,
            HammingBackend::Swar,
            HammingBackend::Avx2,
        ] {
            assert_eq!(parse_backend(b.name()), Some(b), "{}", b.name());
        }
        assert_eq!(parse_backend("sse42"), None);
        assert_eq!(parse_backend(""), None);
        assert_eq!(parse_backend("AVX2"), None, "names are lower-case");
    }

    #[test]
    fn active_backend_is_runnable_on_this_host() {
        // whatever the environment forced (CI re-runs the suite under
        // PICBNN_FORCE_BACKEND=scalar), the latched backend must be one
        // this host can execute — the downgrade rule's whole point
        let b = active_backend();
        assert!(available_backends().contains(&b), "{b:?}");
        // and scalar + swar are available everywhere
        assert!(available_backends().contains(&HammingBackend::Scalar));
        assert!(available_backends().contains(&HammingBackend::Swar));
    }

    #[test]
    fn swar_popcount_is_exact() {
        assert_eq!(popcount64(0), 0);
        assert_eq!(popcount64(!0), 64);
        assert_eq!(popcount64(1), 1);
        assert_eq!(popcount64(1 << 63), 1);
        let mut rng = Rng::new(12, 21);
        for _ in 0..2000 {
            let x = rng.next_u64();
            assert_eq!(popcount64(x), x.count_ones(), "{x:#x}");
        }
    }

    #[test]
    fn every_backend_matches_scalar_on_pairs_and_masks() {
        // widths straddling the 4-word SWAR/AVX2 chunk and the word tail
        let mut rng = Rng::new(3, 33);
        for len in [1usize, 63, 64, 65, 255, 256, 257, 511, 700, 1024, 2048] {
            let mut a = BitVec::zeros(len);
            let mut b = BitVec::zeros(len);
            let mut k = BitVec::zeros(len);
            for i in 0..len {
                a.set(i, rng.chance(0.5));
                b.set(i, rng.chance(0.5));
                k.set(i, rng.chance(0.5));
            }
            let want = hamming_words_with(HammingBackend::Scalar, a.words(), b.words());
            let want_masked = hamming_words_masked_with(
                HammingBackend::Scalar,
                a.words(),
                b.words(),
                k.words(),
            );
            for backend in available_backends() {
                assert_eq!(
                    hamming_words_with(backend, a.words(), b.words()),
                    want,
                    "{backend:?} len {len}"
                );
                assert_eq!(
                    hamming_words_masked_with(backend, a.words(), b.words(), k.words()),
                    want_masked,
                    "{backend:?} masked len {len}"
                );
            }
        }
    }

    #[test]
    fn every_backend_matches_scalar_on_the_batched_kernel() {
        // batch sizes crossing the QUERY_TILE boundary × widths crossing
        // the 4-word chunk boundary, per backend
        let mut rng = Rng::new(14, 41);
        for cols in [64usize, 130, 257, 1024] {
            let rows: Vec<BitVec> = (0..13)
                .map(|_| {
                    let mut v = BitVec::zeros(cols);
                    for i in 0..cols {
                        v.set(i, rng.chance(0.5));
                    }
                    v
                })
                .collect();
            let m = BitMatrix::from_rows(&rows);
            for nq in [1usize, 7, 8, 9, 17] {
                let queries: Vec<BitVec> = (0..nq)
                    .map(|_| {
                        let mut v = BitVec::zeros(cols);
                        for i in 0..cols {
                            v.set(i, rng.chance(0.5));
                        }
                        v
                    })
                    .collect();
                let mut want = Vec::new();
                m.hamming_all_batch_with(HammingBackend::Scalar, &queries, &mut want);
                for backend in available_backends() {
                    let mut got = Vec::new();
                    m.hamming_all_batch_with(backend, &queries, &mut got);
                    assert_eq!(got, want, "{backend:?} cols {cols} nq {nq}");
                }
                // the dispatched entry agrees with whatever is active
                let mut dispatched = Vec::new();
                m.hamming_all_batch(&queries, &mut dispatched);
                assert_eq!(dispatched, want, "dispatched cols {cols} nq {nq}");
            }
        }
    }

    #[test]
    fn batch_from_query_block_matches_bitvec_queries() {
        // the allocation-free entry: queries as rows of a BitMatrix are
        // bit-identical to the same queries as BitVecs, including a row
        // limit below the matrix height and a wider output stride
        let mut rng = Rng::new(21, 52);
        for cols in [100usize, 512, 1030] {
            let rows: Vec<BitVec> = (0..9)
                .map(|_| {
                    let mut v = BitVec::zeros(cols);
                    for i in 0..cols {
                        v.set(i, rng.chance(0.5));
                    }
                    v
                })
                .collect();
            let m = BitMatrix::from_rows(&rows);
            let queries: Vec<BitVec> = (0..10)
                .map(|_| {
                    let mut v = BitVec::zeros(cols);
                    for i in 0..cols {
                        v.set(i, rng.chance(0.5));
                    }
                    v
                })
                .collect();
            let block = BitMatrix::from_rows(&queries);
            let stride = 12;
            let mut want = vec![u32::MAX; queries.len() * stride];
            let mut got = want.clone();
            m.hamming_rows_batch_into(7, &queries, &mut want, stride);
            m.hamming_rows_batch_from(7, &block, &mut got, stride);
            assert_eq!(got, want, "cols {cols}");
        }
    }
}
