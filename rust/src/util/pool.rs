//! A small scoped thread pool (tokio is unavailable offline; the inference
//! batch paths only need fork-join data parallelism, not async I/O).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Run `f(chunk_index, item_index_range)` across `n_items` split into
/// per-thread chunks, using scoped threads. `f` must be Sync.
pub fn parallel_chunks<F>(n_items: usize, n_threads: usize, f: F)
where
    F: Fn(usize, std::ops::Range<usize>) + Sync,
{
    if n_items == 0 {
        return;
    }
    let n_threads = n_threads.max(1).min(n_items);
    let chunk = n_items.div_ceil(n_threads);
    std::thread::scope(|s| {
        for t in 0..n_threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n_items);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(t, lo..hi));
        }
    });
}

/// Map each index in [0, n) to a value, in parallel, preserving order.
pub fn parallel_map<T, F>(n: usize, n_threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    let slots: Vec<std::sync::Mutex<&mut [T]>> = {
        // split the output into per-thread windows up front
        let n_threads = n_threads.max(1).min(n.max(1));
        let chunk = n.div_ceil(n_threads);
        out.chunks_mut(chunk.max(1))
            .map(std::sync::Mutex::new)
            .collect()
    };
    let chunk = if slots.is_empty() {
        0
    } else {
        n.div_ceil(slots.len())
    };
    std::thread::scope(|s| {
        for (t, slot) in slots.iter().enumerate() {
            let f = &f;
            s.spawn(move || {
                let mut guard = slot.lock().unwrap();
                for (i, out_slot) in guard.iter_mut().enumerate() {
                    *out_slot = f(t * chunk + i);
                }
            });
        }
    });
    drop(slots);
    out
}

/// A shared atomic work queue: threads steal indices until exhausted.
/// Better than fixed chunks when per-item cost is highly variable.
pub fn parallel_queue<F>(n_items: usize, n_threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let next = Arc::new(AtomicUsize::new(0));
    let n_threads = n_threads.max(1).min(n_items.max(1));
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            let next = Arc::clone(&next);
            let f = &f;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_items {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_all_items() {
        let sum = AtomicU64::new(0);
        parallel_chunks(1000, 4, |_, range| {
            for i in range {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            }
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(100, 7, |i| i * i);
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn queue_processes_each_once() {
        let counts: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        parallel_queue(500, 8, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_and_single() {
        parallel_chunks(0, 4, |_, _| panic!("no items"));
        let v = parallel_map(1, 4, |i| i + 1);
        assert_eq!(v, vec![1]);
    }
}
