//! Deterministic PRNGs for simulation: SplitMix64 (seeding/streams) and
//! PCG32 (bulk draws), plus Gaussian sampling via Box–Muller.
//!
//! The analog Monte-Carlo machinery (per-cell process variation, MLSA
//! offsets, supply noise) must be reproducible across runs and across
//! threads; every consumer derives an independent stream with
//! [`Rng::fork`], so simulation results do not depend on thread schedule.

/// SplitMix64: tiny, high-quality 64-bit mixer (Steele et al.).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG32 (XSH-RR 64/32) with a SplitMix64-seeded state and stream.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// Cached second Gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed a generator; `stream` selects one of 2^63 independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm);
        let mut rng = Rng {
            state: 0,
            inc: (stream << 1) | 1,
            gauss_spare: None,
        };
        rng.state = s0.wrapping_add(rng.inc);
        rng.next_u32();
        rng
    }

    /// Derive an independent child stream (deterministic, collision-safe
    /// for < 2^32 forks per parent).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(seed, tag.wrapping_add(0x0DDB_1A5E_5BAD_5EED))
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let k = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * k);
                return u * k;
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, sigma: f64) -> f64 {
        mean + sigma * self.gaussian()
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42, 1);
        let mut b = Rng::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_independent() {
        let mut a = Rng::new(42, 1);
        let mut b = Rng::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7, 0);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_range() {
        let mut r = Rng::new(3, 9);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11, 4);
        let n = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Rng::new(5, 0);
        let mut a = parent.fork(0);
        let mut b = parent.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(1, 1);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
