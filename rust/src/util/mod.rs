//! Foundation substrates: PRNG, packed bit tensors, statistics, CLI/JSON
//! parsing, and a scoped thread pool.  Hand-rolled because the offline
//! crate set lacks rand/clap/serde/tokio (DESIGN.md §1).

pub mod bitops;
pub mod config;
pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;

/// Wall-clock timer for coarse phase timing.
pub struct Timer(std::time::Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(std::time::Instant::now())
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}
