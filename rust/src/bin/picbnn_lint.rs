//! `picbnn-lint` — the repo's determinism/concurrency invariant checker.
//!
//! ```text
//! cargo run --release --bin picbnn-lint            # human output, repo root
//! cargo run --release --bin picbnn-lint -- --json  # machine output
//! cargo run --release --bin picbnn-lint -- --root /path/to/checkout
//! cargo run --release --bin picbnn-lint -- --file path.rs --as rust/src/server/x.rs
//! ```
//!
//! `--file` lints a single file instead of the tree; `--as` supplies
//! the repo-relative path used for rule scoping (CI points this at the
//! firing fixtures to prove each rule still exits nonzero).
//!
//! Exit codes: `0` clean (suppressed findings allowed), `1` at least
//! one unsuppressed finding, `2` I/O error.  The rule catalogue and
//! pragma syntax live in DETERMINISM.md; the same scan runs as the
//! `lint_clean` tier-1 test so `cargo test` fails on regressions even
//! where CI doesn't invoke the binary.

use picbnn::analysis;
use picbnn::util::cli::Args;
use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args = Args::parse(&["json"]);
    let root = args.get_or("root", ".").to_string();
    let scanned = match args.get("file") {
        Some(file) => {
            let rel = args.get_or("as", file).to_string();
            std::fs::read_to_string(file)
                .map(|src| analysis::lint_source(&rel, &src))
                .map_err(|e| format!("read {file}: {e}"))
        }
        None => analysis::lint_tree(Path::new(&root)),
    };
    match scanned {
        Ok(report) => {
            if args.flag("json") {
                println!("{}", report.to_json().to_string());
            } else {
                print!("{}", report.render_human());
            }
            if report.clean() {
                ExitCode::SUCCESS
            } else {
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("picbnn-lint: {e}");
            ExitCode::from(2)
        }
    }
}
