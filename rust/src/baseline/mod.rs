//! Comparison baselines: the conventional digital BNN accelerator (and the
//! software-accuracy reference), and the TDC-readout CAM whose PVT
//! susceptibility motivates PiC-BNN's majority-vote scheme (paper §II-C).

pub mod digital;
pub mod tdc;

pub use digital::{digital_predict, digital_scores, digital_top2, DigitalCost};
pub use tdc::{tdc_predict, tdc_predict_fixed_threshold, TdcReadout};
