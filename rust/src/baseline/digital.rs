//! Digital BNN baseline: the conventional-accelerator comparison point
//! (paper §II-C category 1) and the software-accuracy reference of Fig. 5.
//!
//! Computes the exact integer XNOR+POPCOUNT forward pass with full-
//! precision POPCOUNT at the output layer (argmax over dot+C rather than a
//! thermometer vote) — the thing PiC-BNN eliminates.  Also carries a gate-
//! level cost model so benches can compare energy/area against the CAM.

use crate::bnn::model::MappedModel;
use crate::util::bitops::BitVec;

/// Full-precision-output digital forward: per-class score = dot + C.
pub fn digital_scores(model: &MappedModel, x: &BitVec) -> Vec<i32> {
    let mut act = x.clone();
    for layer in &model.layers[..model.layers.len() - 1] {
        act = crate::bnn::infer::digital_hidden(layer, &act);
    }
    let out = model.layers.last().unwrap();
    (0..out.n_out())
        .map(|j| out.weights.row(j).dot_pm1(&act) + out.c_effective(0, j))
        .collect()
}

/// Digital prediction: argmax score, lowest index on ties.
pub fn digital_predict(model: &MappedModel, x: &BitVec) -> usize {
    let scores = digital_scores(model, x);
    let mut best = 0;
    for (i, &s) in scores.iter().enumerate() {
        if s > scores[best] {
            best = i;
        }
    }
    best
}

/// Top-2 classes by score.
pub fn digital_top2(model: &MappedModel, x: &BitVec) -> [usize; 2] {
    let scores = digital_scores(model, x);
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].cmp(&scores[a]).then(a.cmp(&b)));
    [idx[0], *idx.get(1).unwrap_or(&idx[0])]
}

/// Gate-level cost model of the equivalent digital accelerator:
/// XNOR array + popcount adder tree + accumulators, 65 nm energies.
/// Used by the ablation benches for an order-of-magnitude comparison.
#[derive(Clone, Copy, Debug)]
pub struct DigitalCost {
    /// Energy per XNOR gate evaluation [J].
    pub e_xnor: f64,
    /// Energy per full-adder in the popcount tree [J].
    pub e_fa: f64,
    /// Energy per output accumulator update [J].
    pub e_acc: f64,
}

impl Default for DigitalCost {
    fn default() -> Self {
        // 65 nm standard-cell ballpark (~1 fJ/gate at 1.2 V)
        DigitalCost {
            e_xnor: 1.0e-15,
            e_fa: 1.5e-15,
            e_acc: 12.0e-15,
        }
    }
}

impl DigitalCost {
    /// Energy for one n-input binary dot product + popcount.
    pub fn dot_energy(&self, n: usize) -> f64 {
        // popcount tree over n bits uses ~n full adders
        n as f64 * self.e_xnor + n as f64 * self.e_fa + self.e_acc
    }

    /// Energy for one full inference of the mapped model.
    pub fn inference_energy(&self, model: &MappedModel) -> f64 {
        model
            .layers
            .iter()
            .map(|l| l.n_out() as f64 * self.dot_energy(l.n_in()))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::util::rng::Rng;

    fn rand_x(n: usize, seed: u64) -> BitVec {
        let mut rng = Rng::new(seed, 3);
        let mut v = BitVec::zeros(n);
        for i in 0..n {
            v.set(i, rng.chance(0.5));
        }
        v
    }

    #[test]
    fn scores_consistent_with_hd() {
        // score = n - 2*HD_w + C  (dot identity)
        let m = tiny_model(80, 12, 4, 9);
        let x = rand_x(80, 1);
        let scores = digital_scores(&m, &x);
        let mut act = x.clone();
        act = crate::bnn::infer::digital_hidden(&m.layers[0], &act);
        let out = &m.layers[1];
        for (j, &s) in scores.iter().enumerate() {
            let hd = out.weights.row(j).hamming(&act) as i32;
            assert_eq!(s, out.n_in() as i32 - 2 * hd + out.c_effective(0, j));
        }
    }

    #[test]
    fn predict_matches_argmax() {
        let m = tiny_model(80, 12, 5, 10);
        for seed in 0..20 {
            let x = rand_x(80, seed);
            let scores = digital_scores(&m, &x);
            let p = digital_predict(&m, &x);
            assert!(scores.iter().all(|&s| s <= scores[p]));
        }
    }

    #[test]
    fn digital_and_cam_argmax_agree_when_hd_in_window() {
        // thermometer votes preserve the argmax when every HD ≤ 64
        use crate::bnn::infer::{digital_forward, digital_output_hd, digital_hidden};
        let m = tiny_model(80, 12, 4, 11);
        for seed in 0..30 {
            let x = rand_x(80, 100 + seed);
            let h = digital_hidden(&m.layers[0], &x);
            let hd = digital_output_hd(&m.layers[1], &h);
            if hd.iter().all(|&d| d <= 64) && {
                // unique minimum (ties can legitimately differ)
                let min = hd.iter().min().unwrap();
                hd.iter().filter(|&d| d == min).count() == 1
            } {
                let (_, cam_pred) = digital_forward(&m, &x, &m.schedule);
                assert_eq!(cam_pred, digital_predict(&m, &x), "seed {seed}");
            }
        }
    }

    #[test]
    fn cost_model_scales_with_model() {
        let small = tiny_model(64, 8, 4, 1);
        let big = tiny_model(512, 64, 10, 1);
        let c = DigitalCost::default();
        assert!(c.inference_energy(&big) > c.inference_energy(&small));
        assert!(c.dot_energy(100) > 0.0);
    }
}
