//! TDC-readout CAM-BNN baseline (the [5]/[34]-style comparator of §II-C).
//!
//! A time-to-digital readout associates *when* the matchline crosses a
//! fixed reference with the analog popcount: the crossing time
//! t_cross = C·ln(V_DD/V_ref)/(m·g) is inverted to an estimate of m by a
//! bank of delay taps.  The paper's criticism: the tap↔count mapping is
//! calibrated at one PVT point; temperature or supply drift shifts every
//! crossing time *systematically*, so the decoded popcount — and therefore
//! the winning class — is consistently wrong, which majority voting over
//! identically-biased samples cannot fix.
//!
//! We model exactly that: taps are placed at the crossing times of each
//! integer mismatch count at the *calibration* PVT; at run time crossings
//! are computed at the *actual* PVT and decoded through the stale taps.

use crate::analog::matchline::{MatchlineModel, Voltages};
use crate::analog::transistor::Pvt;
use crate::bnn::infer::digital_hidden;
use crate::bnn::model::MappedModel;
use crate::util::bitops::BitVec;
use crate::util::rng::Rng;

/// TDC readout for rows of `n_cells`, calibrated at a fixed PVT point.
#[derive(Clone, Debug)]
pub struct TdcReadout {
    /// Crossing-time taps: `taps[m]` = nominal crossing time of m
    /// mismatches at the calibration corner [s]; taps[0] = +inf sentinel.
    taps: Vec<f64>,
    /// Sense voltages used for both calibration and runtime.
    pub voltages: Voltages,
    /// Per-sample timing jitter sigma (fraction).
    pub jitter: f64,
    n_cells: usize,
}

impl TdcReadout {
    /// Calibrate taps at `cal_pvt` for rows of `n_cells`.
    pub fn calibrate(n_cells: usize, cal_pvt: Pvt, voltages: Voltages) -> Self {
        let model = MatchlineModel::new(n_cells, cal_pvt);
        let mut taps = Vec::with_capacity(n_cells + 1);
        for m in 0..=n_cells as u32 {
            taps.push(crossing_time(&model, m, &voltages));
        }
        TdcReadout {
            taps,
            voltages,
            jitter: 0.005,
            n_cells,
        }
    }

    /// Decode a crossing time into a mismatch-count estimate using the
    /// calibration taps (nearest-tap decision, as a tapped delay line does).
    pub fn decode(&self, t_cross: f64) -> u32 {
        // taps decrease with m; binary search over the reversed ordering
        let mut best = 0u32;
        let mut best_err = f64::INFINITY;
        for (m, &tap) in self.taps.iter().enumerate() {
            let err = if tap.is_finite() && t_cross.is_finite() {
                (tap - t_cross).abs()
            } else if tap.is_finite() != t_cross.is_finite() {
                f64::INFINITY
            } else {
                0.0
            };
            if err < best_err {
                best_err = err;
                best = m as u32;
            }
        }
        best
    }

    /// Measure a row with true mismatch count `m` at the *actual* PVT and
    /// return the decoded popcount estimate.
    pub fn measure(&self, m: u32, actual_pvt: Pvt, rng: &mut Rng) -> u32 {
        let model = MatchlineModel::new(self.n_cells, actual_pvt);
        let t = crossing_time(&model, m, &self.voltages);
        let t_noisy = if t.is_finite() {
            t * (1.0 + rng.normal(0.0, self.jitter))
        } else {
            t
        };
        self.decode(t_noisy)
    }
}

/// Time at which V_ML crosses V_ref: C·ln(V_DD/V_ref)/(m·g); +inf if the
/// line never discharges.
fn crossing_time(model: &MatchlineModel, m: u32, v: &Voltages) -> f64 {
    if m == 0 {
        return f64::INFINITY;
    }
    let g = crate::analog::transistor::g_eval(v.veval, &model.pvt);
    if g <= 0.0 || v.vref >= model.pvt.vdd {
        return f64::INFINITY;
    }
    model.c_ml() * (model.pvt.vdd / v.vref).ln() / (m as f64 * g)
}

/// TDC-based classification of a mapped model at an actual PVT corner:
/// hidden layers run digitally (the comparison isolates the *readout*);
/// the output layer's **weight-part popcount** is decoded through the TDC
/// and combined with the batch-norm constant in the decoded-count domain —
/// score_j = (n − 2·m̂_j) + C_j, prediction = argmax — exactly how an
/// ADC/TDC pipeline consumes the analog popcount ([5], [34]).
///
/// This is where the §II-C systematic error lives: PVT drift rescales all
/// crossing times, so the decoded counts m̂_j ≈ α·m_j are *consistently*
/// misweighted against the unscaled constants C_j, biasing the argmax the
/// same way on every inference — no amount of repetition averages it out.
pub fn tdc_predict(
    model: &MappedModel,
    tdc: &TdcReadout,
    x: &BitVec,
    actual_pvt: Pvt,
    rng: &mut Rng,
) -> usize {
    let mut act = x.clone();
    for layer in &model.layers[..model.layers.len() - 1] {
        act = digital_hidden(layer, &act);
    }
    let out = model.layers.last().unwrap();
    let n = out.n_in() as i64;
    let mut best = 0usize;
    let mut best_score = i64::MIN;
    for j in 0..out.n_out() {
        // the TDC senses the weight cells' matchline (the C_j constant is a
        // digital-side correction in these designs, not extra cells)
        let m_true = out.weights.row(j).hamming(&act);
        let m_decoded = tdc.measure(m_true, actual_pvt, rng) as i64;
        let score = (n - 2 * m_decoded) + out.c_effective(0, j) as i64;
        if score > best_score {
            best_score = score;
            best = j;
        }
    }
    best
}

/// The [34]-style *absolute* scheme: "a certain sampling time point is
/// associated with a certain class" — each class decision is a binary
/// comparison of the decoded count against a threshold fixed at
/// calibration time.  Prediction = lowest-index firing class (priority
/// encoder), falling back to argmin decoded HD when none fires.
///
/// This is the readout the paper singles out (§II-C): under PVT drift the
/// decoded counts scale while the hardwired threshold does not, so either
/// *nothing* fires (cold: counts inflate) or *everything* fires (hot:
/// counts deflate, priority encoder returns class 0 forever) — a
/// systematic error that repetition cannot average away.
pub fn tdc_predict_fixed_threshold(
    model: &MappedModel,
    tdc: &TdcReadout,
    x: &BitVec,
    actual_pvt: Pvt,
    rng: &mut Rng,
    threshold: u32,
) -> usize {
    let mut act = x.clone();
    for layer in &model.layers[..model.layers.len() - 1] {
        act = digital_hidden(layer, &act);
    }
    let out = model.layers.last().unwrap();
    let mut fallback = 0usize;
    let mut fallback_hd = u32::MAX;
    for j in 0..out.n_out() {
        let m_true = crate::bnn::mapping::expected_mismatches(out, 0, j, &act);
        let m_decoded = tdc.measure(m_true, actual_pvt, rng);
        if m_decoded <= threshold {
            return j; // priority encoder: first firing class wins
        }
        if m_decoded < fallback_hd {
            fallback_hd = m_decoded;
            fallback = j;
        }
    }
    fallback
}

#[cfg(test)]
mod tests {
    use super::*;

    fn readout() -> TdcReadout {
        TdcReadout::calibrate(512, Pvt::nominal(), Voltages::new(0.8, 0.7, 1.0))
    }

    #[test]
    fn decode_exact_at_calibration_corner() {
        let tdc = readout();
        let model = MatchlineModel::new(512, Pvt::nominal());
        for m in [1u32, 5, 50, 200, 511] {
            let t = crossing_time(&model, m, &tdc.voltages);
            assert_eq!(tdc.decode(t), m, "m={m}");
        }
    }

    #[test]
    fn zero_mismatch_never_crosses() {
        let tdc = readout();
        assert_eq!(tdc.decode(f64::INFINITY), 0);
    }

    #[test]
    fn pvt_drift_biases_decode_systematically() {
        // at a hot corner every decoded count shifts the same direction
        let tdc = readout();
        let mut rng = Rng::new(4, 4);
        let hot = Pvt {
            temp_c: 85.0,
            ..Pvt::nominal()
        };
        let mut signed_err = 0i64;
        let mut nonzero = 0;
        for m in (10u32..200).step_by(10) {
            let d = tdc.measure(m, hot, &mut rng);
            signed_err += d as i64 - m as i64;
            if d != m {
                nonzero += 1;
            }
        }
        assert!(nonzero > 10, "drift should corrupt most decodes");
        // systematic: |sum of signed errors| is large (not averaging out)
        assert!(signed_err.abs() > 20, "{signed_err}");
    }

    #[test]
    fn nominal_corner_decodes_with_small_error() {
        let tdc = readout();
        let mut rng = Rng::new(5, 5);
        let mut max_err = 0u32;
        for m in (10u32..200).step_by(10) {
            let d = tdc.measure(m, Pvt::nominal(), &mut rng);
            max_err = max_err.max(d.abs_diff(m));
        }
        assert!(max_err <= 4, "jitter-only error should be small: {max_err}");
    }
}
