//! `picbnn` CLI: the leader entrypoint for the simulated accelerator.
//!
//! Subcommands:
//!   classify   — run Algorithm-1 inference over a test set (CAM backend)
//!   calibrate  — print the regenerated Table I voltage/tolerance table
//!   report     — hardware report (Table II) for a workload
//!   serve      — run the batched inference server over a synthetic load
//!   info       — artifact + model summary

use picbnn::accel::{evaluate, Pipeline, PipelineOptions, VoltageController};
use picbnn::analog::Pvt;
use picbnn::benchkit::Table;
use picbnn::bnn::model::MappedModel;
use picbnn::cam::NoiseMode;
use picbnn::data::{ModelMeta, TestSet};
use picbnn::energy;
use picbnn::util::cli::Args;

fn load_model(name: &str) -> (MappedModel, TestSet, ModelMeta) {
    let dir = picbnn::artifacts_dir();
    let model = MappedModel::load(dir.join(format!("{name}_weights.bin")))
        .unwrap_or_else(|e| die(&format!("load model: {e} (run `make artifacts` first)")));
    let test = TestSet::load(dir.join(format!("{name}_test.bin")))
        .unwrap_or_else(|e| die(&format!("load test set: {e}")));
    let meta = ModelMeta::load(dir.join(format!("{name}_meta.json")))
        .unwrap_or_else(|e| die(&format!("load meta: {e}")));
    (model, test, meta)
}

fn die(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(1)
}

fn main() {
    let args = Args::parse(&["nominal", "help"]);
    let cmd = args.positional().first().map(String::as_str).unwrap_or("help");
    match cmd {
        "run" => cmd_run(&args),
        "classify" => cmd_classify(&args),
        "calibrate" => cmd_calibrate(&args),
        "report" => cmd_report(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(&args),
        _ => {
            println!("picbnn {} — processing-in-CAM BNN accelerator", picbnn::version());
            println!();
            println!("usage: picbnn <command> [--model mnist|hg] [options]");
            println!();
            println!("  run        launcher: execute an experiment config");
            println!("             --config configs/<name>.toml");
            println!("  classify   run Algorithm-1 inference over the test set");
            println!("             [--limit N] [--batch N] [--executions K] [--nominal]");
            println!("  calibrate  regenerate the Table I voltage/tolerance table");
            println!("             [--cells N]");
            println!("  report     Table II hardware report for the workload");
            println!("             [--limit N] [--batch N]");
            println!("  serve      batched inference server over a synthetic load");
            println!("             [--requests N] [--max-batch N] [--producers N]");
            println!("  info       artifact + model summary");
        }
    }
}

fn cmd_run(args: &Args) {
    use picbnn::util::config::{Config, RunConfig};
    let path = args.get("config").unwrap_or_else(|| die("run requires --config <path>"));
    let cfg = Config::load(path).unwrap_or_else(|e| die(&e));
    let rc = RunConfig::from_config(&cfg).unwrap_or_else(|e| die(&e));
    let (model, test, meta) = load_model(&rc.model);
    let n = rc.limit.min(test.len());
    let opts = PipelineOptions {
        noise: if rc.noise == "nominal" { NoiseMode::Nominal } else { NoiseMode::Analog },
        pvt: Pvt { temp_c: rc.temp_c, vdd: rc.vdd, ..Pvt::nominal() },
        seed: rc.seed,
        schedule_prefix: rc.executions,
        noise_scale: 1.0,
    };
    println!(
        "run: model={} n={} batch={} threads={} noise={} backend={} pvt=({} °C, {} V)",
        rc.model, n, rc.batch, rc.threads, rc.noise, rc.backend, rc.temp_c, rc.vdd
    );
    let t = picbnn::util::Timer::start();
    if rc.backend == "cam" || rc.backend == "both" {
        let (results, stats) = picbnn::accel::classify_parallel(
            &model, opts, &test.images[..n], rc.batch, rc.threads,
        );
        let votes: Vec<_> = results.into_iter().map(|(v, _)| v).collect();
        let acc = evaluate(&votes, &test.labels[..n]);
        println!(
            "CAM backend:  top1 {:.4}  top2 {:.4}  (paper CAM {:.3}, software {:.3})  [{:.2}s host]",
            acc.top1, acc.top2, meta.paper_cam_top1, meta.software_top1, t.elapsed_s()
        );
        if rc.report_energy {
            let r = energy::report(&stats);
            println!(
                "device: {:.1} cyc/inf  {:.0} inf/s  {:.3} mW  {:.0} M inf/s/W  {:.0} TOPS/W",
                r.cycles_per_inference, r.inf_per_s, r.power_w * 1e3,
                r.inf_per_s_per_w / 1e6, r.ops_per_w / 1e12
            );
        }
    }
    if rc.backend == "pjrt" || rc.backend == "both" {
        match picbnn::runtime::InferEngine::load(&rc.model, &model) {
            Ok(engine) => {
                let t = picbnn::util::Timer::start();
                let results = engine
                    .classify_all(&test.images[..n])
                    .unwrap_or_else(|e| die(&format!("pjrt: {e}")));
                let votes: Vec<_> = results.into_iter().map(|(v, _)| v).collect();
                let acc = evaluate(&votes, &test.labels[..n]);
                println!(
                    "PJRT backend: top1 {:.4}  top2 {:.4}  (nominal semantics)  [{:.2}s host]",
                    acc.top1, acc.top2, t.elapsed_s()
                );
            }
            Err(e) => println!("PJRT backend unavailable: {e}"),
        }
    }
}

fn pipeline_opts(args: &Args) -> PipelineOptions {
    PipelineOptions {
        noise: if args.flag("nominal") {
            NoiseMode::Nominal
        } else {
            NoiseMode::Analog
        },
        seed: args.get_parse("seed", 0xB11Au64),
        schedule_prefix: args.get("executions").map(|s| s.parse().unwrap_or(33)),
        ..Default::default()
    }
}

fn cmd_classify(args: &Args) {
    let name = args.get_or("model", "mnist");
    let (model, test, meta) = load_model(name);
    let limit = args.get_parse("limit", test.len());
    let batch = args.get_parse("batch", 256usize);
    let mut pipe = Pipeline::new(&model, pipeline_opts(args));
    let n = limit.min(test.len());
    let t = picbnn::util::Timer::start();
    let mut votes = Vec::with_capacity(n);
    for chunk in test.images[..n].chunks(batch) {
        for (v, _) in pipe.classify_batch(chunk) {
            votes.push(v);
        }
    }
    let acc = evaluate(&votes, &test.labels[..n]);
    let stats = pipe.take_stats(n as u64);
    println!(
        "{name}: {} images  top1 {:.4}  top2 {:.4}  (paper CAM top1 {:.3}, software {:.3})",
        n, acc.top1, acc.top2, meta.paper_cam_top1, meta.software_top1
    );
    println!(
        "device: {:.1} cycles/inf  {:.0} inf/s (modelled)  |  host sim {:.2}s",
        stats.cycles_per_inference(),
        stats.inferences_per_s(),
        t.elapsed_s()
    );
}

fn cmd_calibrate(args: &Args) {
    let cells = args.get_parse("cells", 256usize);
    let ctl = VoltageController::new(cells, Pvt::nominal());
    let mut table = Table::new(
        &format!("Table I — calibrated HD tolerance points ({cells}-cell rows)"),
        &["HD tol", "V_ref (mV)", "V_eval (mV)", "V_st (mV)", "achieved"],
    );
    for target in (0..=36).step_by(4) {
        match ctl.calibrate(target, 0.5).or_else(|| ctl.calibrate(target, 2.0)) {
            Some(p) => table.row(vec![
                target.to_string(),
                format!("{:.0}", p.voltages.vref * 1e3),
                format!("{:.0}", p.voltages.veval * 1e3),
                format!("{:.0}", p.voltages.vst * 1e3),
                format!("{:.2}", p.achieved_tol),
            ]),
            None => table.row(vec![
                target.to_string(),
                "-".into(),
                "-".into(),
                "-".into(),
                "unreachable".into(),
            ]),
        }
    }
    table.print();
}

fn cmd_report(args: &Args) {
    let name = args.get_or("model", "mnist");
    let (model, test, _) = load_model(name);
    let limit = args.get_parse("limit", 512usize).min(test.len());
    let batch = args.get_parse("batch", 256usize);
    let mut pipe = Pipeline::new(&model, pipeline_opts(args));
    for chunk in test.images[..limit].chunks(batch) {
        pipe.classify_batch(chunk);
    }
    let stats = pipe.take_stats(limit as u64);
    let r = energy::report(&stats);
    let mut table = Table::new(
        &format!("Table II — hardware report ({name}, {limit} inferences)"),
        &["metric", "measured", "paper"],
    );
    table.row(vec!["throughput (inf/s)".into(), format!("{:.0}", r.inf_per_s), "560000".into()]);
    table.row(vec!["power (mW)".into(), format!("{:.3}", r.power_w * 1e3), "0.8".into()]);
    table.row(vec![
        "efficiency (M inf/s/W)".into(),
        format!("{:.0}", r.inf_per_s_per_w / 1e6),
        "703".into(),
    ]);
    table.row(vec![
        "efficiency (TOPS/W)".into(),
        format!("{:.0}", r.ops_per_w / 1e12),
        "184".into(),
    ]);
    table.row(vec!["macro area (mm²)".into(), format!("{:.2}", r.macro_area_mm2), "0.87".into()]);
    table.row(vec!["SoC area (mm²)".into(), format!("{:.2}", r.soc_area_mm2), "2.38".into()]);
    table.row(vec![
        "cycles/inference".into(),
        format!("{:.1}", r.cycles_per_inference),
        "~44.6".into(),
    ]);
    table.print();
}

fn cmd_serve(args: &Args) {
    use picbnn::accel::BatchPolicy;
    use std::time::Duration;
    let name = args.get_or("model", "mnist");
    let (model, test, _) = load_model(name);
    let requests = args.get_parse("requests", 2000usize);
    let max_batch = args.get_parse("max-batch", 256usize);
    let producers = args.get_parse("producers", 4usize);
    let images: Vec<_> = (0..requests)
        .map(|i| test.images[i % test.len()].clone())
        .collect();
    let t = picbnn::util::Timer::start();
    let (responses, metrics) = picbnn::server::serve_workload(
        &model,
        pipeline_opts(args),
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(2),
        },
        &images,
        producers,
        Duration::ZERO,
    );
    println!(
        "served {} requests in {:.2}s host time: {:.0} req/s host-side",
        responses.len(),
        t.elapsed_s(),
        responses.len() as f64 / t.elapsed_s()
    );
    println!(
        "batches {}  mean batch {:.1}  latency p50 {:.2} ms  p99 {:.2} ms",
        metrics.batches,
        metrics.mean_batch(),
        metrics.p50_ms(),
        metrics.p99_ms()
    );
}

fn cmd_info(args: &Args) {
    let name = args.get_or("model", "mnist");
    let (model, test, meta) = load_model(name);
    println!("model {name}:");
    println!("  dims {} -> {} -> {}", meta.n_in, meta.n_hidden, meta.n_classes);
    for (i, l) in model.layers.iter().enumerate() {
        println!(
            "  layer {i}: {}x{} weights, {} segment(s) of {} cells ({} pads in seg 0)",
            l.n_out(),
            l.n_in(),
            l.n_seg(),
            l.seg_width,
            l.seg_pads(0)
        );
    }
    println!("  schedule: {} thresholds {:?}..{:?}", model.schedule.len(),
             model.schedule.first(), model.schedule.last());
    println!("  test set: {} images, {} classes", test.len(), test.n_classes);
    println!(
        "  python-side accuracies: software {:.4}, CAM-nominal {:.4}",
        meta.software_top1, meta.cam_nominal_top1
    );
}
