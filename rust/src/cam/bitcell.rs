//! Cell-level model of the 10T PiC-BNN bitcell (paper Fig. 3c): a 9T NOR
//! CAM cell (6T SRAM + 3T compare stack) with an extra series transistor
//! `M_eval` in the matchline discharge path whose gate voltage V_eval
//! throttles the discharge rate.
//!
//! The array hot path never instantiates per-cell objects — storage is
//! packed words (`util::bitops`) and the discharge physics is aggregated
//! per row (`analog::matchline`).  This module carries the cell *truth
//! table* (used by tests as the definitional reference) and the cell-level
//! area/energy figures used by the energy model.

use crate::analog::constants as k;

/// Stored datum of one cell: a binary weight (+1 encoded as logic '1').
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bitcell {
    pub stored: bool,
}

impl Bitcell {
    pub fn new(stored: bool) -> Self {
        Bitcell { stored }
    }

    /// Does this cell open its matchline discharge path for the given
    /// searchline assertion?
    ///
    /// NOR-type CAM: the pulldown opens on a *mismatch* between the SL pair
    /// and the stored pair — XNOR(W, X) = match keeps the ML up.  A search
    /// may also mask the cell (SL = /SL = 0), which never discharges
    /// (ternary "don't care" drive; not used by the BNN mapping but part of
    /// the device behaviour).
    pub fn opens_discharge(&self, sl: Option<bool>) -> bool {
        match sl {
            None => false, // masked: both searchlines low
            Some(q) => q != self.stored,
        }
    }

    /// Cell area [mm²] (paper: ≈3.24 µm² in 65 nm).
    pub const fn area_mm2() -> f64 {
        k::AREA_BITCELL_MM2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xnor_truth_table() {
        // (stored, query) -> discharge on mismatch only
        for (w, x, open) in [
            (false, false, false),
            (false, true, true),
            (true, false, true),
            (true, true, false),
        ] {
            assert_eq!(Bitcell::new(w).opens_discharge(Some(x)), open);
        }
    }

    #[test]
    fn masked_cell_never_discharges() {
        assert!(!Bitcell::new(true).opens_discharge(None));
        assert!(!Bitcell::new(false).opens_discharge(None));
    }

    #[test]
    fn area_positive() {
        assert!(Bitcell::area_mm2() > 0.0);
    }
}
