//! Higher-level associative operations composed from tolerance-tuned
//! searches — the approximate-search-CAM capability set of the underlying
//! silicon (paper ref. [1]: "128-kbit approximate search-capable CAM with
//! tunable Hamming distance") that PiC-BNN specialises for BNN inference.
//!
//! * [`masked_search`] — ternary search: masked ("don't care") columns are
//!   simply not driven (SL = /SL = 0), so they can never open a discharge
//!   path regardless of the stored bit (`cam::bitcell` models the cell
//!   truth table; `CamArray::search_masked_into` the array behaviour).
//! * [`nearest_match`] — best-match search: binary-search the HD tolerance
//!   (via the voltage controller) until exactly one/few rows fire; this is
//!   how an associative memory retrieves the closest stored code without
//!   any ADC (the same primitive Algorithm 1 exploits per class).
//! * [`priority_encode`] — multi-match resolution: lowest-index firing row
//!   (the hardware's matchline priority encoder).

use crate::accel::VoltageController;
use crate::util::bitops::BitVec;

use super::array::CamArray;

/// Lowest-index set entry of a fire vector (the priority encoder).
pub fn priority_encode(fires: &[bool]) -> Option<usize> {
    fires.iter().position(|&f| f)
}

/// Ternary search: columns where `mask` is clear are "don't care".
///
/// The NOR cell opens its pulldown only when the driven query bit differs
/// from the stored bit; masking a column means *not driving* its
/// searchline pair, which can never discharge the matchline.  At the
/// functional level that equals excluding the column from the HD — we
/// realise it by searching with per-row mismatch counts computed over the
/// masked query (host-side assist mirrors the SL-driver masking registers
/// the silicon has).
pub fn masked_search(
    cam: &mut CamArray,
    query: &BitVec,
    mask: &BitVec,
    out_fires: &mut Vec<bool>,
) {
    assert_eq!(query.len(), mask.len());
    // honour the out-parameter contract: fires land directly in the
    // caller's buffer and the mismatch-count scratch is owned (and reused)
    // by the array — steady-state calls perform zero allocations.  The
    // masked and exact paths share one row kernel (`CamArray::search_one`),
    // differing only in the mismatch-count primitive, so both benefit from
    // the precomputed per-row MLSA thresholds.
    cam.search_masked_fires(query, mask, out_fires);
}

/// Result of a nearest-match retrieval.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NearestMatch {
    /// Firing rows at the smallest tolerance that produced any match.
    pub rows: Vec<usize>,
    /// The tolerance step at which they fired.
    pub tolerance: u32,
    /// Searches issued (the retrieval cost).
    pub searches: u32,
}

/// Best-match retrieval: binary-search the HD tolerance until the smallest
/// level with ≥1 firing row is found (ADC-free nearest-neighbour lookup).
pub fn nearest_match(
    cam: &mut CamArray,
    ctl: &VoltageController,
    query: &BitVec,
    max_tol: u32,
) -> NearestMatch {
    let (mut m, mut f) = (Vec::new(), Vec::new());
    let fires_at = |cam: &mut CamArray, m: &mut Vec<u32>, f: &mut Vec<bool>, tol: u32| {
        let p = ctl
            .calibrate(tol, 0.5)
            .or_else(|| ctl.calibrate(tol, 2.0))
            .unwrap_or_else(|| ctl.calibrate_best(tol));
        cam.set_voltages(p.voltages);
        cam.search_into(query, m, f);
        f.iter().any(|&x| x)
    };
    let mut searches = 0u32;
    // exponential probe up, then binary search down
    let mut hi = 1u32;
    while hi < max_tol {
        searches += 1;
        if fires_at(cam, &mut m, &mut f, hi) {
            break;
        }
        hi = (hi * 2).min(max_tol);
    }
    if hi >= max_tol {
        searches += 1;
        if !fires_at(cam, &mut m, &mut f, max_tol) {
            return NearestMatch {
                rows: Vec::new(),
                tolerance: max_tol,
                searches,
            };
        }
        hi = max_tol;
    }
    let mut lo = 0u32; // no match at lo (or lo == 0 trivially handled below)
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        searches += 1;
        if fires_at(cam, &mut m, &mut f, mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    // final state must reflect `hi`
    searches += 1;
    fires_at(cam, &mut m, &mut f, hi);
    NearestMatch {
        rows: f
            .iter()
            .enumerate()
            .filter_map(|(i, &x)| x.then_some(i))
            .collect(),
        tolerance: hi,
        searches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analog::Pvt;
    use crate::cam::{CamArray, CamConfig};
    use crate::util::rng::Rng;

    fn rand_bits(n: usize, rng: &mut Rng) -> BitVec {
        let mut v = BitVec::zeros(n);
        for i in 0..n {
            v.set(i, rng.chance(0.5));
        }
        v
    }

    #[test]
    fn priority_encoder() {
        assert_eq!(priority_encode(&[false, false, true, true]), Some(2));
        assert_eq!(priority_encode(&[false; 4]), None);
    }

    #[test]
    fn nearest_match_finds_closest_row() {
        let mut rng = Rng::new(2, 8);
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let base = rand_bits(512, &mut rng);
        // rows at HD 3, 9, 40 from the eventual query
        let mut rows = Vec::new();
        for hd in [3usize, 9, 40] {
            let mut r = base.clone();
            for i in 0..hd {
                r.flip(i);
            }
            rows.push(r);
        }
        for (i, r) in rows.iter().enumerate() {
            cam.write_row(i, r);
        }
        let ctl = VoltageController::new(512, Pvt::nominal());
        let got = nearest_match(&mut cam, &ctl, &base, 256);
        assert_eq!(got.rows, vec![0], "row at HD 3 is nearest");
        assert!(got.tolerance >= 3 && got.tolerance < 9, "{got:?}");
        // retrieval cost is logarithmic, not linear, in the tolerance range
        assert!(got.searches <= 14, "{got:?}");
    }

    #[test]
    fn nearest_match_empty_array() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let ctl = VoltageController::new(512, Pvt::nominal());
        let q = BitVec::ones(512);
        let got = nearest_match(&mut cam, &ctl, &q, 64);
        assert!(got.rows.is_empty());
    }

    #[test]
    fn masked_search_ignores_masked_columns() {
        let mut rng = Rng::new(5, 1);
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let stored = rand_bits(512, &mut rng);
        cam.write_row(0, &stored);
        // query differs from the row ONLY in the first 16 columns
        let mut q = stored.clone();
        for i in 0..16 {
            q.flip(i);
        }
        // exact-match tolerance, but mask out those 16 columns
        cam.set_voltages(crate::analog::Voltages::exact());
        let mut mask = BitVec::ones(512);
        for i in 0..16 {
            mask.set(i, false);
        }
        let mut fires = Vec::new();
        masked_search(&mut cam, &q, &mask, &mut fires);
        assert!(fires[0], "masked mismatches must not discharge");
        // unmasked search does not fire
        let plain = cam.search(&q);
        assert!(!plain[0]);
    }

    #[test]
    fn masked_search_reuses_caller_buffer_without_reallocating() {
        let mut rng = Rng::new(6, 2);
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        cam.write_row(0, &rand_bits(512, &mut rng));
        cam.set_voltages(crate::analog::Voltages::exact());
        let q = rand_bits(512, &mut rng);
        let mask = rand_bits(512, &mut rng);
        let mut fires = Vec::new();
        // first call grows the buffer to the row count …
        masked_search(&mut cam, &q, &mask, &mut fires);
        assert_eq!(fires.len(), 256);
        let cap = fires.capacity();
        let ptr = fires.as_ptr();
        // … and repeated calls never reallocate it (or any scratch)
        for _ in 0..100 {
            masked_search(&mut cam, &q, &mask, &mut fires);
        }
        assert_eq!(fires.capacity(), cap, "out buffer reallocated");
        assert_eq!(fires.as_ptr(), ptr, "out buffer moved");
        assert_eq!(fires.len(), 256);
    }
}
