//! The simulated 128-kbit PiC-BNN CAM macro: bank/config geometry, the
//! cell truth-table reference, and the array-level search engine with
//! analog matchline evaluation and event accounting.

pub mod array;
pub mod bitcell;
pub mod ops;
pub mod config;
pub mod faults;

pub use array::{CamArray, NoiseMode};
pub use faults::{
    ArrayFaults, DegradedMode, FaultEvent, FaultKind, FaultPlan, FaultSite, HealthRegistry,
    HealthState, RailId, SiteGeometry, SiteHealth, DEFAULT_PROBATION_LAPS, DEFAULT_SPARE_ROWS,
    PROBATION_BACKOFF_CAP,
};
pub use config::{CamConfig, BANK_COLS, BANK_ROWS, CAPACITY_BITS, N_BANKS};
