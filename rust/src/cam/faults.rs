//! Deterministic hardware-fault taxonomy and seed-replayable fault plans.
//!
//! The variation stack (PVT corners, per-row mismatch, DAC quantization,
//! matchline noise) models a *healthy* device.  This module adds the
//! unhealthy one: discrete failures that production silicon accumulates
//! mid-flight, injected deterministically so every drill is replayable.
//!
//! ## Fault taxonomy
//!
//! * **Stuck-at bitcell** ([`FaultKind::StuckBit`]) — one cell reads a
//!   constant regardless of what was programmed.  Modeled in the *store*:
//!   the stuck value is forced at injection time and re-forced on every
//!   subsequent row write, so mismatch counting (and therefore every
//!   downstream prediction path) sees it with zero extra hot-path work.
//! * **Dead matchline row** ([`FaultKind::DeadRow`]) — the row's MLSA
//!   output is pinned (`always_fire` or never-fire) independent of the
//!   mismatch count: a shorted or open matchline.
//! * **DAC stuck code** ([`FaultKind::StuckDac`]) — the rail's DAC stops
//!   accepting new codes and freezes at its current level.
//! * **DAC drift** ([`FaultKind::DacDrift`]) — the rail's static offset
//!   walks away from its factory trim (aging, temperature).
//! * **Transient search upset** ([`FaultKind::Transient`]) — the row's
//!   next `searches` MLSA evaluations are inverted, then the fault clears
//!   itself (particle strike / supply glitch class).
//!
//! ## Determinism and virtual-time scheduling
//!
//! A [`FaultPlan`] schedules [`FaultEvent`]s in *image-stream time*
//! (`at_image` = the pool's global noise-stream index), not wall or device
//! time: the stream index is the one clock every execution path shares, so
//! the same plan replayed against the same workload trace lands each fault
//! on the same image boundary regardless of worker count, batch shape, or
//! Hamming backend.  An event becomes active on the first batch whose base
//! stream index reaches `at_image`.
//!
//! ## Fire-decision override ordering (identical-seeding interaction)
//!
//! Dead-row and transient overrides are applied *after* the healthy MLSA
//! decision has been evaluated (and after any metastable-band RNG draw it
//! consumed).  This keeps the RNG draw order of a faulty array identical
//! to a healthy one, which is what lets a repaired array — and the
//! identically-seeded sibling replicas of a faulty one — return to
//! bit-exact agreement with a never-faulted twin.
//!
//! ## Quarantine and spare-remap invariants
//!
//! Each array carries [`DEFAULT_SPARE_ROWS`] spare physical rows.
//! `CamArray::remap_row_to_spare` models address-level redundancy (a fuse
//! remaps the logical row onto a spare in place): the row keeps its
//! logical index — neuron indexing, prefix layout, and RNG interleave are
//! untouched — and all of the row's active faults are cleared because the
//! defective physical row is no longer addressed.  As a documented
//! idealization the spare inherits the logical row's frozen per-row
//! variation (repair rewrites go through `CamArray::rewrite_row`, which
//! does not redraw variation), so a completed repair restores bit-exact
//! predictions in both noise modes.  When spares are exhausted the repair
//! escalates: replica rebuild, then replica quarantine (failover to the
//! bit-identical siblings), then typed refusal — never a silent wrong
//! answer.
//!
//! ## Scrub amortization rule
//!
//! The scrub pass (`accel::scrub`) runs on the engine's maintenance seam
//! and verifies a bounded number of rows per inter-batch gap
//! (`ScrubConfig::rows_per_turn`), round-robin over every resident site,
//! so detection latency is bounded by `total_rows / rows_per_turn` gaps
//! while the steady-state serving path never stalls on scrubbing.
//!
//! ## Health state machine (fleet supervision)
//!
//! On top of the per-event ladder, every physical macro carries a
//! [`HealthState`] in a [`HealthRegistry`]:
//!
//! ```text
//! Healthy ──fault detected──▶ Suspect ──clean scrub lap──▶ Healthy
//!    Suspect ──spares exhausted──▶ Quarantined
//!    Quarantined ──operator un_quarantine──▶ Probation
//!    Probation ──N consecutive clean canary laps──▶ Readmitted
//!    Probation ──any canary failure──▶ Quarantined (back-off: N doubles)
//! ```
//!
//! Transitions are stamped in *image-stream time* (`since_image`), the
//! same virtual clock fault plans use, so a whole
//! quarantine → un-quarantine → re-admission drill replays bit-exactly
//! from its seeds — the registry never reads a wall clock.  Re-admission
//! is **never silent**: a replaced macro must pass
//! [`SiteHealth::required_laps`] consecutive canary laps while carrying
//! zero load, and each probation failure doubles the requirement (capped)
//! before the next attempt.

use std::collections::BTreeMap;

use crate::util::rng::Rng;

/// Spare physical rows per array available for address-level remap.
pub const DEFAULT_SPARE_ROWS: usize = 4;

/// Typed degradation ladder of a self-healing pool.  Degradation is
/// *graceful and typed*: a pool never silently serves known-wrong
/// answers — it repairs, then routes around quarantined copies
/// ([`DegradedMode::Failover`]), and when a site is beyond repair it
/// refuses new work ([`DegradedMode::Refusing`]) with a typed rejection
/// at admission.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum DegradedMode {
    /// Every site healthy (or repaired back to bit-exact nominal).
    #[default]
    Nominal,
    /// One or more physical copies quarantined; serving routes around
    /// them (bit-exact siblings, or the cold-spill funnel).
    Failover,
    /// An unrepairable site remains: new admissions are refused, typed.
    Refusing,
}

/// One of the three user-configurable voltage rails.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RailId {
    Vref,
    Veval,
    Vst,
}

/// A single hardware failure (taxonomy in the module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Bitcell at (`row`, `col`) reads a constant `bit`.
    StuckBit { row: usize, col: usize, bit: bool },
    /// Row's MLSA output is pinned: `always_fire` or never-fire.
    DeadRow { row: usize, always_fire: bool },
    /// The rail's DAC freezes at its current code.
    StuckDac { rail: RailId },
    /// The rail's static offset drifts by `volts` from factory trim.
    DacDrift { rail: RailId, volts: f64 },
    /// The row's next `searches` MLSA evaluations are inverted.
    Transient { row: usize, searches: u64 },
}

/// Which physical array a fault lands on, in the pool's logical
/// placement coordinates (stable across re-plans of the same shape).
/// `Ord` follows the derived variant/field order — a stable total order
/// so [`HealthRegistry`] iteration is deterministic.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultSite {
    /// A hidden-layer load.  `replica: None` hits every identically-seeded
    /// replica the same way (the determinism drills); `Some(k)` hits one
    /// physical copy (the failover drills).
    Hidden {
        layer: usize,
        load: usize,
        replica: Option<usize>,
    },
    /// An output slot.  `None` = every output slot; `Some(i)` = one.
    Output { slot: Option<usize> },
}

/// One scheduled failure: at image-stream index `at_image`, apply `kind`
/// to `site`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub at_image: u64,
    pub site: FaultSite,
    pub kind: FaultKind,
}

/// A deterministic, seed-replayable schedule of failures.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn push(&mut self, at_image: u64, site: FaultSite, kind: FaultKind) {
        self.events.push(FaultEvent {
            at_image,
            site,
            kind,
        });
    }

    /// Earliest scheduled image index (`u64::MAX` when empty) — the
    /// pool's fast-path activation gate.
    pub fn first_at(&self) -> u64 {
        self.events.iter().map(|e| e.at_image).min().unwrap_or(u64::MAX)
    }

    /// Stable sort by activation time (injection order within one image
    /// index is preserved).
    pub fn sorted(mut self) -> Self {
        self.events.sort_by_key(|e| e.at_image);
        self
    }

    /// The fault-drill generator: an escalating, seed-replayable schedule
    /// over the given resident sites — transient upsets first, then
    /// stuck bits within the per-array spare budget, then dead rows and
    /// rail drift, and finally (when a replicated site exists) a stuck
    /// rail that writes off one whole replica.  Same `(seed, sites,
    /// start_image, stride)` → identical plan, run to run.
    pub fn escalating(seed: u64, sites: &[SiteGeometry], start_image: u64, stride: u64) -> Self {
        let mut rng = Rng::new(seed, 0xFA17);
        let mut plan = FaultPlan::default();
        if sites.is_empty() {
            return plan;
        }
        let stride = stride.max(1);
        let mut at = start_image;
        // phase 1 — transient upsets (self-clearing; no repair needed)
        for _ in 0..sites.len().min(3) {
            let g = &sites[rng.below(sites.len() as u64) as usize];
            let row = rng.below(g.rows.max(1) as u64) as usize;
            let searches = 1 + rng.below(4);
            plan.push(at, g.site, FaultKind::Transient { row, searches });
            at += stride;
        }
        // phase 2 — stuck bitcells, at most half the spare budget per
        // site so the dead rows below still have spares to land on
        for g in sites {
            for _ in 0..(DEFAULT_SPARE_ROWS / 2) {
                let row = rng.below(g.rows.max(1) as u64) as usize;
                let col = rng.below(g.width.max(1) as u64) as usize;
                let bit = rng.chance(0.5);
                plan.push(at, g.site, FaultKind::StuckBit { row, col, bit });
                at += stride;
            }
        }
        // phase 3 — dead matchlines + slow reference drift
        for g in sites.iter().take(2) {
            let row = rng.below(g.rows.max(1) as u64) as usize;
            let always_fire = rng.chance(0.5);
            plan.push(at, g.site, FaultKind::DeadRow { row, always_fire });
            at += stride;
        }
        let g = &sites[rng.below(sites.len() as u64) as usize];
        plan.push(
            at,
            g.site,
            FaultKind::DacDrift {
                rail: RailId::Vref,
                volts: 0.004,
            },
        );
        at += stride;
        // phase 4 — a stuck rail kills one copy of a replicated load
        // outright (failover drill); skipped when nothing is replicated
        if let Some(g) = sites.iter().find(|g| g.replicas > 1) {
            if let FaultSite::Hidden { layer, load, .. } = g.site {
                plan.push(
                    at,
                    FaultSite::Hidden {
                        layer,
                        load,
                        replica: Some(0),
                    },
                    FaultKind::StuckDac { rail: RailId::Veval },
                );
            }
        }
        plan.sorted()
    }
}

/// Geometry of one injectable site (from `MacroPool::fault_sites`), so
/// generators like [`FaultPlan::escalating`] can place faults in range.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SiteGeometry {
    pub site: FaultSite,
    /// Programmed rows at the site.
    pub rows: usize,
    /// Row width in bits.
    pub width: usize,
    /// Physical copies of the site (1 = unreplicated).
    pub replicas: usize,
}

/// The faults currently active inside one [`crate::cam::CamArray`]
/// (empty in a healthy array; every vector scan below is gated on that).
#[derive(Clone, Debug, Default)]
pub struct ArrayFaults {
    /// `(row, col, stuck_value)` — forced in the store on injection and
    /// on every subsequent write to the row.
    pub stuck_bits: Vec<(usize, usize, bool)>,
    /// `(row, always_fire)` — pinned MLSA outputs.
    pub dead_rows: Vec<(usize, bool)>,
    /// `(row, remaining_evaluations)` — self-clearing upsets.
    pub transients: Vec<(usize, u64)>,
}

impl ArrayFaults {
    pub fn is_empty(&self) -> bool {
        self.stuck_bits.is_empty() && self.dead_rows.is_empty() && self.transients.is_empty()
    }

    /// Any fault that overrides the fire decision (the search loops hoist
    /// this so a healthy array pays one branch per batch, not per row).
    #[inline]
    pub fn has_fire_faults(&self) -> bool {
        !self.dead_rows.is_empty() || !self.transients.is_empty()
    }

    /// Drop every fault recorded against `row` (the spare-remap repair:
    /// the defective physical row is no longer addressed).
    pub fn clear_row(&mut self, row: usize) {
        self.stuck_bits.retain(|&(r, _, _)| r != row);
        self.dead_rows.retain(|&(r, _)| r != row);
        self.transients.retain(|&(r, _)| r != row);
    }

    /// Override the healthy fire decision for `row` (called *after* the
    /// MLSA evaluated, so RNG draw order is fault-independent).  Dead
    /// rows pin the output; otherwise a pending transient inverts one
    /// evaluation and burns down.
    #[inline]
    pub fn apply_fire(&mut self, row: usize, natural: bool) -> bool {
        if let Some(&(_, always)) = self.dead_rows.iter().find(|&&(r, _)| r == row) {
            return always;
        }
        let mut hit = false;
        for t in self.transients.iter_mut() {
            if t.0 == row {
                t.1 -= 1;
                hit = true;
                break;
            }
        }
        if hit {
            self.transients.retain(|&(_, left)| left > 0);
            return !natural;
        }
        natural
    }
}

/// Consecutive clean canary laps a probation macro must pass before
/// re-admission, on its first attempt.  Each probation failure doubles
/// the requirement (capped by [`PROBATION_BACKOFF_CAP`]).
pub const DEFAULT_PROBATION_LAPS: u32 = 3;

/// Back-off exponent cap: `required_laps` never exceeds
/// `DEFAULT_PROBATION_LAPS << PROBATION_BACKOFF_CAP`.
pub const PROBATION_BACKOFF_CAP: u32 = 6;

/// Macro health ladder (transition diagram in the module docs).  The
/// derived `Ord` ranks states by how much the planner should trust the
/// macro: `Healthy < Suspect < Quarantined < Probation < Readmitted`
/// is *declaration* order, so comparisons are only meaningful through
/// [`HealthState::load_bearing`] / [`HealthState::penalized`], not `<`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum HealthState {
    /// No open findings; full planner weight.
    #[default]
    Healthy,
    /// A fault was detected and repaired within spares; the macro keeps
    /// serving but the planner avoids adding load until a clean lap.
    Suspect,
    /// Written off (spares exhausted / rebuild strikes spent).  Carries
    /// no load; its physical macro is held out of the planner budget.
    Quarantined,
    /// Operator re-admitted the (replaced/repaired) macro; it is
    /// canary-lapped while carrying zero load.
    Probation,
    /// Passed probation; load-bearing again (planner treats it as
    /// healthy; a new fault sends it back to `Suspect`).
    Readmitted,
}

impl HealthState {
    /// May the planner place load here at all?
    pub fn load_bearing(self) -> bool {
        matches!(self, HealthState::Healthy | HealthState::Readmitted | HealthState::Suspect)
    }

    /// Should the planner prefer other macros when it has a choice?
    pub fn penalized(self) -> bool {
        matches!(self, HealthState::Suspect | HealthState::Probation | HealthState::Quarantined)
    }
}

/// Health record of one physical macro.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SiteHealth {
    pub state: HealthState,
    /// Image-stream index of the last transition (virtual time — never a
    /// wall-clock read, so drills replay bit-exactly).
    pub since_image: u64,
    /// Consecutive clean canary laps accumulated this probation.
    pub canary_laps: u32,
    /// Laps required for re-admission this probation (doubles per prior
    /// failure, capped).
    pub required_laps: u32,
    /// Lifetime probation failures (drives the back-off).
    pub probation_failures: u32,
    /// Lifetime completed re-admissions.
    pub readmissions: u32,
}

impl Default for SiteHealth {
    fn default() -> Self {
        SiteHealth {
            state: HealthState::Healthy,
            since_image: 0,
            canary_laps: 0,
            required_laps: DEFAULT_PROBATION_LAPS,
            probation_failures: 0,
            readmissions: 0,
        }
    }
}

/// Fleet-wide health supervisor: one [`SiteHealth`] per physical macro,
/// keyed by [`FaultSite`] in a `BTreeMap` (deterministic iteration —
/// the `no-hash-iter` rule).  All transition methods take the current
/// image-stream index; none reads a clock.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HealthRegistry {
    sites: BTreeMap<FaultSite, SiteHealth>,
}

impl HealthRegistry {
    /// Health of `site` (absent = never touched = `Healthy`).
    pub fn get(&self, site: &FaultSite) -> SiteHealth {
        self.sites.get(site).copied().unwrap_or_default()
    }

    pub fn state(&self, site: &FaultSite) -> HealthState {
        self.get(site).state
    }

    /// Deterministic (sorted-by-site) iteration over every tracked site.
    pub fn iter(&self) -> impl Iterator<Item = (&FaultSite, &SiteHealth)> {
        self.sites.iter()
    }

    /// Sites currently in `Quarantined` (held out of the planner budget).
    pub fn quarantined(&self) -> usize {
        self.sites
            .values()
            .filter(|h| h.state == HealthState::Quarantined)
            .count()
    }

    /// A fault was detected at `site`.  `Healthy`/`Readmitted` →
    /// `Suspect` (stamped); an already-`Suspect` site keeps its original
    /// stamp; `Quarantined`/`Probation` are owned by their own
    /// transitions and are left alone.
    pub fn mark_suspect(&mut self, site: FaultSite, at_image: u64) {
        let h = self.sites.entry(site).or_default();
        if matches!(h.state, HealthState::Healthy | HealthState::Readmitted) {
            h.state = HealthState::Suspect;
            h.since_image = at_image;
        }
    }

    /// A full scrub lap over `site` found nothing: `Suspect` → `Healthy`.
    pub fn mark_clean(&mut self, site: FaultSite, at_image: u64) {
        let h = self.sites.entry(site).or_default();
        if h.state == HealthState::Suspect {
            h.state = HealthState::Healthy;
            h.since_image = at_image;
        }
    }

    /// Write the site off (any state → `Quarantined`).  A quarantine
    /// while on probation is routed through [`Self::probation_failed`]
    /// so the back-off is never skipped.
    pub fn quarantine(&mut self, site: FaultSite, at_image: u64) {
        if self.state(&site) == HealthState::Probation {
            self.probation_failed(site, at_image);
            return;
        }
        let h = self.sites.entry(site).or_default();
        h.state = HealthState::Quarantined;
        h.canary_laps = 0;
        h.since_image = at_image;
    }

    /// Operator re-admission: `Quarantined` → `Probation` with the
    /// escalated lap requirement.  Returns `false` (no-op) from any
    /// other state — re-admission is explicit, never implied.
    pub fn un_quarantine(&mut self, site: FaultSite, at_image: u64) -> bool {
        let h = self.sites.entry(site).or_default();
        if h.state != HealthState::Quarantined {
            return false;
        }
        h.state = HealthState::Probation;
        h.canary_laps = 0;
        h.required_laps =
            DEFAULT_PROBATION_LAPS << h.probation_failures.min(PROBATION_BACKOFF_CAP);
        h.since_image = at_image;
        true
    }

    /// One clean canary lap on a probation site.  Returns `true` when
    /// this lap completed probation (`Probation` → `Readmitted`).
    pub fn canary_lap_passed(&mut self, site: FaultSite, at_image: u64) -> bool {
        let h = self.sites.entry(site).or_default();
        if h.state != HealthState::Probation {
            return false;
        }
        h.canary_laps += 1;
        if h.canary_laps >= h.required_laps {
            h.state = HealthState::Readmitted;
            h.readmissions += 1;
            h.since_image = at_image;
            return true;
        }
        false
    }

    /// A canary failed during probation: back to `Quarantined`, with the
    /// lap requirement doubled for the next attempt.
    pub fn probation_failed(&mut self, site: FaultSite, at_image: u64) {
        let h = self.sites.entry(site).or_default();
        if h.state != HealthState::Probation {
            return;
        }
        h.state = HealthState::Quarantined;
        h.probation_failures += 1;
        h.canary_laps = 0;
        h.since_image = at_image;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sites() -> Vec<SiteGeometry> {
        vec![
            SiteGeometry {
                site: FaultSite::Hidden {
                    layer: 0,
                    load: 0,
                    replica: None,
                },
                rows: 64,
                width: 256,
                replicas: 2,
            },
            SiteGeometry {
                site: FaultSite::Output { slot: Some(0) },
                rows: 16,
                width: 256,
                replicas: 1,
            },
        ]
    }

    #[test]
    fn escalating_plan_is_seed_replayable() {
        let s = sites();
        let a = FaultPlan::escalating(0xFA17, &s, 32, 16);
        let b = FaultPlan::escalating(0xFA17, &s, 32, 16);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert_eq!(a.first_at(), 32);
        // sorted by activation time
        assert!(a.events.windows(2).all(|w| w[0].at_image <= w[1].at_image));
        // a different seed produces a different schedule
        let c = FaultPlan::escalating(0xFA18, &s, 32, 16);
        assert_ne!(a, c);
        // the failover phase targeted one replica of the replicated site
        assert!(a.events.iter().any(|e| matches!(
            e.site,
            FaultSite::Hidden {
                replica: Some(0),
                ..
            }
        )));
    }

    #[test]
    fn dead_row_pins_and_transient_inverts_then_clears() {
        let mut f = ArrayFaults::default();
        assert!(!f.has_fire_faults());
        f.dead_rows.push((3, true));
        assert!(f.apply_fire(3, false));
        assert!(f.apply_fire(3, false), "dead rows are persistent");
        f.transients.push((5, 2));
        assert!(f.apply_fire(5, false));
        assert!(f.apply_fire(5, false));
        assert!(!f.apply_fire(5, false), "transient cleared after 2 evals");
        assert!(f.has_fire_faults(), "dead row still active");
        f.clear_row(3);
        assert!(!f.has_fire_faults());
    }

    #[test]
    fn empty_plan_gates_the_fast_path() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        assert_eq!(p.first_at(), u64::MAX);
    }

    fn hidden(load: usize) -> FaultSite {
        FaultSite::Hidden {
            layer: 0,
            load,
            replica: Some(0),
        }
    }

    #[test]
    fn health_ladder_walks_suspect_quarantine_probation_readmit() {
        let mut reg = HealthRegistry::default();
        let s = hidden(0);
        assert_eq!(reg.state(&s), HealthState::Healthy);
        reg.mark_suspect(s, 10);
        assert_eq!(reg.state(&s), HealthState::Suspect);
        assert_eq!(reg.get(&s).since_image, 10);
        // repeated detections keep the original stamp
        reg.mark_suspect(s, 20);
        assert_eq!(reg.get(&s).since_image, 10);
        reg.mark_clean(s, 30);
        assert_eq!(reg.state(&s), HealthState::Healthy);
        reg.quarantine(s, 40);
        assert_eq!(reg.state(&s), HealthState::Quarantined);
        assert_eq!(reg.quarantined(), 1);
        // re-admission is explicit: canary laps outside probation are no-ops
        assert!(!reg.canary_lap_passed(s, 41));
        assert!(reg.un_quarantine(s, 50));
        assert!(!reg.un_quarantine(s, 50), "already on probation");
        assert_eq!(reg.state(&s), HealthState::Probation);
        assert_eq!(reg.get(&s).required_laps, DEFAULT_PROBATION_LAPS);
        assert_eq!(reg.quarantined(), 0);
        for lap in 0..DEFAULT_PROBATION_LAPS {
            let done = reg.canary_lap_passed(s, 60 + u64::from(lap));
            assert_eq!(done, lap + 1 == DEFAULT_PROBATION_LAPS);
        }
        assert_eq!(reg.state(&s), HealthState::Readmitted);
        assert_eq!(reg.get(&s).readmissions, 1);
        // a new fault on a readmitted macro restarts at Suspect
        reg.mark_suspect(s, 70);
        assert_eq!(reg.state(&s), HealthState::Suspect);
    }

    #[test]
    fn probation_failure_escalates_the_lap_requirement() {
        let mut reg = HealthRegistry::default();
        let s = hidden(1);
        reg.quarantine(s, 0);
        for failures in 0..3u32 {
            assert!(reg.un_quarantine(s, 100 + u64::from(failures)));
            let want = DEFAULT_PROBATION_LAPS << failures;
            assert_eq!(reg.get(&s).required_laps, want, "back-off doubles");
            // pass all but the last required lap, then fail
            for _ in 0..want - 1 {
                assert!(!reg.canary_lap_passed(s, 200));
            }
            reg.probation_failed(s, 300);
            assert_eq!(reg.state(&s), HealthState::Quarantined);
            assert_eq!(reg.get(&s).canary_laps, 0);
        }
        // a quarantine call during probation also counts as a failure
        assert!(reg.un_quarantine(s, 400));
        reg.quarantine(s, 401);
        assert_eq!(reg.get(&s).probation_failures, 4);
        // the exponent is capped
        let mut capped = HealthRegistry::default();
        let c = hidden(2);
        capped.quarantine(c, 0);
        for _ in 0..PROBATION_BACKOFF_CAP + 8 {
            assert!(capped.un_quarantine(c, 1));
            capped.probation_failed(c, 2);
        }
        assert!(capped.un_quarantine(c, 3));
        assert_eq!(
            capped.get(&c).required_laps,
            DEFAULT_PROBATION_LAPS << PROBATION_BACKOFF_CAP
        );
    }

    #[test]
    fn registry_iteration_is_site_ordered() {
        let mut reg = HealthRegistry::default();
        reg.mark_suspect(FaultSite::Output { slot: Some(1) }, 1);
        reg.mark_suspect(hidden(3), 2);
        reg.mark_suspect(hidden(1), 3);
        let order: Vec<FaultSite> = reg.iter().map(|(s, _)| *s).collect();
        assert_eq!(
            order,
            vec![hidden(1), hidden(3), FaultSite::Output { slot: Some(1) }]
        );
    }
}
