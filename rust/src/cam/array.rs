//! The 128-kbit PiC-BNN array: packed storage, voltage rails, matchline
//! evaluation, and event/cycle accounting.
//!
//! One `search` = one device clock cycle: precharge all matchlines, assert
//! the query on the searchlines, let the MLs discharge through mismatching
//! cells (throttled by V_eval), and sample every MLSA at t_s(V_st) against
//! V_ref.  All rows evaluate in parallel in silicon; the simulator charges
//! one cycle regardless of row count.

use crate::analog::constants as k;
use crate::analog::dac::VoltageRails;
use crate::analog::matchline::{MatchlineModel, RowVariation, Voltages};
use crate::analog::transistor::Pvt;
use crate::sim::{EventCounters, SimClock};
use crate::util::bitops::{hamming_words, BitMatrix, BitVec};
use crate::util::rng::Rng;

use super::config::CamConfig;

/// Noise fidelity of the simulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NoiseMode {
    /// Deterministic nominal model (cross-validation vs the L2 graph).
    Nominal,
    /// Full Monte-Carlo variation + per-evaluation noise (the device).
    Analog,
}

/// The simulated PiC-BNN macro.
pub struct CamArray {
    config: CamConfig,
    store: BitMatrix,
    row_valid: Vec<bool>,
    row_var: Vec<RowVariation>,
    /// Voltage sources for (V_ref, V_eval, V_st).
    pub rails: VoltageRails,
    model: MatchlineModel,
    pub clock: SimClock,
    pub events: EventCounters,
    rng: Rng,
    pvt: Pvt,
    noise: NoiseMode,
    /// Internal mismatch-count scratch for fire-only entry points
    /// ([`CamArray::search`], [`CamArray::search_masked_fires`]): reused
    /// across calls so the hot path allocates nothing.
    scratch_m: Vec<u32>,
}

impl CamArray {
    /// Fresh array in `config` at the given PVT point.
    pub fn new(config: CamConfig, pvt: Pvt, noise: NoiseMode, seed: u64) -> Self {
        let mut rng = Rng::new(seed, 0x0CA8);
        let rails = match noise {
            NoiseMode::Nominal => VoltageRails::ideal(Voltages::exact()),
            NoiseMode::Analog => VoltageRails::new(Voltages::exact(), &mut rng),
        };
        CamArray {
            config,
            store: BitMatrix::zeros(config.rows(), config.width()),
            row_valid: vec![false; config.rows()],
            row_var: vec![RowVariation::nominal(); config.rows()],
            rails,
            model: MatchlineModel::new(config.width(), pvt),
            clock: SimClock::new(),
            events: EventCounters::default(),
            rng,
            pvt,
            noise,
            scratch_m: Vec::new(),
        }
    }

    /// Convenience: analog-noise array at nominal PVT.
    pub fn analog(config: CamConfig, seed: u64) -> Self {
        CamArray::new(config, Pvt::nominal(), NoiseMode::Analog, seed)
    }

    /// Convenience: deterministic array (bit-exact vs the L2 graph).
    pub fn nominal(config: CamConfig) -> Self {
        CamArray::new(config, Pvt::nominal(), NoiseMode::Nominal, 0)
    }

    pub fn config(&self) -> CamConfig {
        self.config
    }

    pub fn pvt(&self) -> Pvt {
        self.pvt
    }

    pub fn noise_mode(&self) -> NoiseMode {
        self.noise
    }

    /// Reconfigure the logical geometry; clears contents (the physical
    /// banks are re-tiled).
    pub fn reconfigure(&mut self, config: CamConfig) {
        let scale = self.model.noise_scale;
        self.config = config;
        self.store = BitMatrix::zeros(config.rows(), config.width());
        self.row_valid = vec![false; config.rows()];
        self.row_var = vec![RowVariation::nominal(); config.rows()];
        self.model = MatchlineModel::with_noise_scale(config.width(), self.pvt, scale);
    }

    /// Scale every per-evaluation noise sigma (ablations; 1.0 = shipped).
    pub fn set_noise_scale(&mut self, scale: f64) {
        self.model.noise_scale = scale;
    }

    /// Program one row (one cycle per word write; silicon writes a word per
    /// cycle through the write circuitry).  Draws fresh per-row variation.
    pub fn write_row(&mut self, row: usize, data: &BitVec) {
        assert_eq!(data.len(), self.config.width(), "row width mismatch");
        assert!(row < self.config.rows(), "row index out of range");
        self.store.row_words_mut(row).copy_from_slice(data.words());
        self.row_valid[row] = true;
        self.row_var[row] = match self.noise {
            NoiseMode::Nominal => RowVariation::nominal(),
            NoiseMode::Analog => RowVariation::draw(&mut self.rng),
        };
        self.clock.tick(1);
        self.events.cells_written += self.config.width() as u64;
        self.events.row_writes += 1;
    }

    /// Invalidate a row (its MLSA output is ignored by searches).
    pub fn clear_row(&mut self, row: usize) {
        self.row_valid[row] = false;
    }

    /// Read a row back (diagnostic path; one cycle).
    pub fn read_row(&mut self, row: usize) -> Option<BitVec> {
        self.clock.tick(1);
        self.events.reads += 1;
        if self.row_valid[row] {
            Some(self.store.row(row))
        } else {
            None
        }
    }

    /// Retune the three voltage rails; stalls for the DAC settle time.
    pub fn set_voltages(&mut self, v: Voltages) {
        let stall = self.rails.retune(v.clamped());
        if stall > 0.0 {
            self.clock.stall(stall);
            self.events.retunes += 1;
        }
    }

    /// Voltages the array currently sees (incl. DAC non-idealities).
    pub fn delivered_voltages(&self) -> Voltages {
        self.rails.delivered()
    }

    /// Nominal HD tolerance at the current rails (diagnostic).
    pub fn current_tolerance(&self) -> f64 {
        self.model.hd_tolerance(&self.rails.delivered())
    }

    /// One search cycle: per-row mismatch counts + MLSA decisions.
    ///
    /// `fires[r]` is meaningful only for valid rows; invalid rows report
    /// `false`.  Reuses caller buffers — the hot path allocates nothing.
    /// Per-evaluation noise draws come from the array's own stream.
    pub fn search_into(&mut self, query: &BitVec, mismatches: &mut Vec<u32>, fires: &mut Vec<bool>) {
        // advance the device stream through an external handle: clone in,
        // draw, write back (Rng is two words; this is the cheap way to
        // split the borrow of `self.rng` from the rest of the array)
        let mut rng = self.rng.clone();
        self.search_into_rng(query, mismatches, fires, &mut rng);
        self.rng = rng;
    }

    /// [`CamArray::search_into`] with an explicit noise stream.
    ///
    /// The pool execution engine (`accel::macro_pool`) threads a per-image
    /// RNG through every macro an image touches, so analog-mode results
    /// are deterministic regardless of how worker threads interleave on
    /// the shared macros (the frozen per-row variation was already drawn
    /// from the macro's own stream at programming time).
    pub fn search_into_rng(
        &mut self,
        query: &BitVec,
        mismatches: &mut Vec<u32>,
        fires: &mut Vec<bool>,
        rng: &mut Rng,
    ) {
        assert_eq!(query.len(), self.config.width(), "query width mismatch");
        let rows = self.config.rows();
        mismatches.clear();
        mismatches.reserve(rows);
        fires.clear();
        fires.reserve(rows);
        let v = self.rails.delivered();
        // cycle-global noise (supply, strobe jitter) drawn once per search:
        // every row of a cycle shares the rails and the MLSA strobe
        let cycle = match self.noise {
            NoiseMode::Analog => Some(self.model.begin_cycle(&v, rng)),
            NoiseMode::Nominal => None,
        };
        for r in 0..rows {
            if !self.row_valid[r] {
                mismatches.push(0);
                fires.push(false);
                continue;
            }
            let m = hamming_words(self.store.row_words(r), query.words());
            mismatches.push(m);
            let fire = match &cycle {
                None => self.model.fires_nominal(m, &v, &self.row_var[r]),
                Some(c) => c.fires(m, &self.row_var[r], rng),
            };
            fires.push(fire);
        }
        self.account_search();
    }

    /// Ternary (masked) search cycle: columns with a clear `mask` bit are
    /// "don't care" — their searchline pair is not driven, so they can
    /// never open a discharge path (see `cam::bitcell::opens_discharge`).
    pub fn search_masked_into(
        &mut self,
        query: &BitVec,
        mask: &BitVec,
        mismatches: &mut Vec<u32>,
        fires: &mut Vec<bool>,
    ) {
        assert_eq!(query.len(), self.config.width());
        assert_eq!(mask.len(), self.config.width());
        let rows = self.config.rows();
        mismatches.clear();
        fires.clear();
        let v = self.rails.delivered();
        let cycle = match self.noise {
            NoiseMode::Analog => Some(self.model.begin_cycle(&v, &mut self.rng)),
            NoiseMode::Nominal => None,
        };
        for r in 0..rows {
            if !self.row_valid[r] {
                mismatches.push(0);
                fires.push(false);
                continue;
            }
            // HD over driven columns only: popcount((row ^ query) & mask)
            let m: u32 = self
                .store
                .row_words(r)
                .iter()
                .zip(query.words())
                .zip(mask.words())
                .map(|((&a, &b), &k)| ((a ^ b) & k).count_ones())
                .sum();
            mismatches.push(m);
            let fire = match &cycle {
                None => self.model.fires_nominal(m, &v, &self.row_var[r]),
                Some(c) => c.fires(m, &self.row_var[r], &mut self.rng),
            };
            fires.push(fire);
        }
        self.account_search();
    }

    /// Allocating convenience wrapper around [`CamArray::search_into`].
    pub fn search(&mut self, query: &BitVec) -> Vec<bool> {
        let mut m = std::mem::take(&mut self.scratch_m);
        let mut f = Vec::new();
        self.search_into(query, &mut m, &mut f);
        self.scratch_m = m;
        f
    }

    /// Fire-only masked search that honours the out-parameter contract:
    /// the mismatch-count scratch is owned by the array and reused, so
    /// repeated calls allocate nothing once `out_fires` has grown to the
    /// row count (see `cam::ops::masked_search`).
    pub fn search_masked_fires(
        &mut self,
        query: &BitVec,
        mask: &BitVec,
        out_fires: &mut Vec<bool>,
    ) {
        let mut m = std::mem::take(&mut self.scratch_m);
        self.search_masked_into(query, mask, &mut m, out_fires);
        self.scratch_m = m;
    }

    /// Matchline voltage trace for row `row` under the current rails
    /// (Fig. 4 regeneration): returns (t, V_ML) samples + the sampling time.
    pub fn ml_trace(&self, row: usize, query: &BitVec, n_pts: usize) -> (Vec<(f64, f64)>, f64) {
        let m = hamming_words(self.store.row_words(row), query.words());
        let v = self.rails.delivered();
        let ts = self.model.sampling_time(&v);
        (self.model.trace(m, ts * 2.0, n_pts, &v), ts)
    }

    fn account_search(&mut self) {
        self.clock.tick(1);
        self.events.searches += 1;
        let width = self.config.width() as u64;
        let rows = self.config.rows() as u64;
        self.events.cells_precharged += width * rows;
        self.events.sl_toggles += width;
        self.events.mlsa_evals += rows;
    }

    /// Reset cycle/event accounting (contents preserved).
    pub fn reset_accounting(&mut self) {
        self.clock.reset();
        self.events = EventCounters::default();
    }

    /// Fraction of rows currently programmed.
    pub fn occupancy(&self) -> f64 {
        self.row_valid.iter().filter(|&&v| v).count() as f64 / self.config.rows() as f64
    }

    /// Macro area [mm²] from the cell count + periphery factor (Table II).
    pub fn area_mm2(&self) -> f64 {
        super::config::CAPACITY_BITS as f64 * k::AREA_BITCELL_MM2 * k::BANK_PERIPHERY_FACTOR
            * 2.0 // CAM cell pitch overhead vs raw bitcell tiling (routing, taps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn query(width: usize, flip_first: usize) -> (BitVec, BitVec) {
        // stored row of all +1; query with `flip_first` mismatches
        let stored = BitVec::ones(width);
        let mut q = BitVec::ones(width);
        for i in 0..flip_first {
            q.set(i, false);
        }
        (stored, q)
    }

    #[test]
    fn exact_search_matches_only_identical() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let (stored, q1) = query(512, 1);
        cam.write_row(0, &stored);
        cam.write_row(1, &q1);
        cam.set_voltages(Voltages::exact());
        let fires = cam.search(&stored);
        assert!(fires[0]);
        assert!(!fires[1]);
        // unprogrammed rows never fire
        assert!(!fires[2]);
    }

    #[test]
    fn tolerance_widens_matches() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let (stored, _) = query(512, 0);
        cam.write_row(0, &stored);
        // find rails giving tolerance ~8 via the model (grid scan)
        let mut v8 = None;
        for vref in [0.7, 0.8, 0.9, 1.0, 1.1] {
            for veval in [0.4, 0.6, 0.8, 1.0] {
                for vst in [0.7, 0.9, 1.1] {
                    let v = Voltages::new(vref, veval, vst);
                    let cand = MatchlineModel::new(512, Pvt::nominal()).hd_tolerance(&v);
                    if (cand - 8.0).abs() < 1.5 {
                        v8 = Some(v);
                    }
                }
            }
        }
        let v8 = v8.expect("some grid point near tol=8");
        cam.set_voltages(v8);
        let tol = cam.current_tolerance();
        let (_, q_in) = query(512, (tol as usize).saturating_sub(2));
        let (_, q_out) = query(512, tol as usize + 4);
        assert!(cam.search(&q_in)[0]);
        assert!(!cam.search(&q_out)[0]);
    }

    #[test]
    fn search_counts_cycles_and_events() {
        let mut cam = CamArray::nominal(CamConfig::W1024x128);
        let row = BitVec::ones(1024);
        cam.write_row(0, &row);
        cam.reset_accounting();
        let _ = cam.search(&row);
        let _ = cam.search(&row);
        assert_eq!(cam.clock.cycles, 2);
        assert_eq!(cam.events.searches, 2);
        assert_eq!(cam.events.mlsa_evals, 2 * 128);
        assert_eq!(cam.events.cells_precharged, 2 * 1024 * 128);
    }

    #[test]
    fn reconfigure_clears() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        cam.write_row(3, &BitVec::ones(512));
        cam.reconfigure(CamConfig::W2048x64);
        assert_eq!(cam.config().width(), 2048);
        assert_eq!(cam.occupancy(), 0.0);
    }

    #[test]
    fn read_row_roundtrip() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let mut data = BitVec::zeros(512);
        data.set(17, true);
        data.set(400, true);
        cam.write_row(5, &data);
        assert_eq!(cam.read_row(5), Some(data));
        assert_eq!(cam.read_row(6), None);
    }

    #[test]
    fn analog_mode_is_deterministic_given_seed() {
        let run = |seed| {
            let mut cam = CamArray::analog(CamConfig::W512x256, seed);
            // rails giving tolerance near the probe's mismatch count so the
            // decision sits in the metastable band and noise matters
            cam.set_voltages(Voltages::new(0.75, 0.5, 1.0));
            let tol = cam.current_tolerance().round() as usize;
            let (stored, q) = query(512, tol.max(1));
            cam.write_row(0, &stored);
            (0..64).map(|_| cam.search(&q)[0]).collect::<Vec<bool>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8)); // different seed, different noise draw
    }

    #[test]
    fn mismatch_counts_exposed() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let (stored, q) = query(512, 33);
        cam.write_row(0, &stored);
        let (mut m, mut f) = (Vec::new(), Vec::new());
        cam.search_into(&q, &mut m, &mut f);
        assert_eq!(m[0], 33);
    }

    #[test]
    fn area_near_paper() {
        let cam = CamArray::nominal(CamConfig::W512x256);
        let a = cam.area_mm2();
        assert!(a > 0.6 && a < 1.2, "{a} should be near the paper's 0.87 mm²");
    }
}
