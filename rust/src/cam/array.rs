//! The 128-kbit PiC-BNN array: packed storage, voltage rails, matchline
//! evaluation, and event/cycle accounting.
//!
//! One `search` = one device clock cycle: precharge all matchlines, assert
//! the query on the searchlines, let the MLs discharge through mismatching
//! cells (throttled by V_eval), and sample every MLSA at t_s(V_st) against
//! V_ref.  All rows evaluate in parallel in silicon; the simulator charges
//! one cycle regardless of row count.
//!
//! ## Precomputed per-row thresholds
//!
//! The MLSA decision depends only on state frozen between programming and
//! retune events, so the array caches it ([`RowCache`], rebuilt lazily):
//! in nominal mode an integer `m_max[r]` turns the decision into
//! `m <= m_max[r]` (zero transcendentals; built by binary-searching the
//! exact `fires_nominal` curve, so it is bit-identical to evaluating the
//! closed form per search); in analog mode `ln(vref + mlsa_offset[r])`
//! and `g_row_factor[r]` are cached in SoA form so each row costs one
//! multiply + compare after the cycle-global `ln(vdd)` (see
//! [`SearchCycle::fires_cached`]).  The cache is invalidated by
//! [`CamArray::set_voltages`] (delivered rails change), `write_row` /
//! `clear_row` (row variation or validity change), and `reconfigure`.
//!
//! ## Query batching and draw-order compatibility
//!
//! [`CamArray::search_batch_into_rngs`] amortises rails/model reads and
//! streams the stored rows once per query tile
//! (`BitMatrix::hamming_all_batch`, dispatched to the runtime-selected
//! Hamming backend — see `util::bitops`), charging exactly one device
//! cycle and one cycle-global noise draw per query.  The
//! `search_batch_rows_*` twins take the queries as rows of one packed
//! `BitMatrix` so the execution engines can reuse a query block across
//! batches (the allocation-free path); both forms are bit-identical.  The batch kernel is
//! **pinned to the sequential path's RNG draw order**: for each query, the
//! cycle-global draw comes first, then metastable-band rows draw in
//! ascending row order, all from that query's own stream.  This is why
//! mismatch counting (RNG-free, any traversal order) and MLSA decisions
//! (RNG-consuming, fixed order) are two separate passes — fusing them in
//! tiled order would permute draws and silently change analog results.
//!
//! ## Fault injection and repair (see `cam::faults`)
//!
//! The array owns an [`ArrayFaults`] set, empty on a healthy device.
//! Stuck bitcells live in the *store* (forced at injection and re-forced
//! by every row write), so mismatch counting sees them for free; dead
//! rows and transient upsets override the fire decision **after** the
//! healthy MLSA evaluated — the RNG draw order is identical with or
//! without faults, which is what keeps identically-seeded replicas and
//! repaired arrays bit-exact against a never-faulted twin.  Both search
//! kernels hoist `has_fire_faults()` so the healthy hot path pays one
//! branch per batch.  Repairs: [`CamArray::remap_row_to_spare`] models
//! address-level spare-row redundancy (logical index, prefix layout and
//! frozen variation preserved — the module docs in `cam::faults` spell
//! out the invariants), [`CamArray::rewrite_row`] reprograms contents
//! without redrawing variation, and [`CamArray::recalibrate_rails`]
//! re-trims drifted DACs, each charged through the normal cycle/stall
//! accounting.

use crate::analog::constants as k;
use crate::analog::dac::VoltageRails;
use crate::analog::matchline::{MatchlineModel, RowVariation, SearchCycle, Voltages};
use crate::analog::transistor::Pvt;
use crate::sim::{EventCounters, SimClock};
use crate::util::bitops::{hamming_words, hamming_words_masked, BitMatrix, BitVec};
use crate::util::rng::Rng;

use super::config::CamConfig;
use super::faults::{ArrayFaults, FaultKind, DEFAULT_SPARE_ROWS};

/// Noise fidelity of the simulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NoiseMode {
    /// Deterministic nominal model (cross-validation vs the L2 graph).
    Nominal,
    /// Full Monte-Carlo variation + per-evaluation noise (the device).
    Analog,
}

/// Precomputed per-row MLSA decision state (module docs).  Everything in
/// here is a pure function of the delivered rails, the frozen per-row
/// variation, and row validity — all of which only change through
/// `set_voltages` / `write_row` / `clear_row` / `reconfigure`, each of
/// which clears `valid`.
#[derive(Default)]
struct RowCache {
    valid: bool,
    /// Nominal mode: largest mismatch count that still fires, per row
    /// (decision: `m <= m_max[r]`).
    m_max: Vec<u32>,
    /// Analog mode: `ln(vref + mlsa_offset[r])` at the delivered rails.
    ln_sense: Vec<f64>,
    /// Analog mode: per-row systematic conductance factor (SoA copy of
    /// `RowVariation::g_row_factor`).
    g_row: Vec<f64>,
    /// `Some(k)` when rows `[0, k)` are exactly the valid rows (the
    /// programmed-prefix layout every load planner produces) — lets the
    /// batch kernel tile the live prefix without per-row validity checks.
    prefix: Option<usize>,
}

/// Per-cycle decision plan: the nominal threshold compare or the analog
/// cycle-global noise constants.
enum CyclePlan {
    Nominal,
    Analog(SearchCycle),
}

/// MLSA decision for row `r` with mismatch count `m` (free function so the
/// search loops can borrow the cache alongside other array fields).
#[inline]
fn row_fires(plan: &CyclePlan, cache: &RowCache, m: u32, r: usize, rng: &mut Rng) -> bool {
    match plan {
        CyclePlan::Nominal => m <= cache.m_max[r],
        CyclePlan::Analog(c) => c.fires_cached(m, cache.g_row[r], cache.ln_sense[r], rng),
    }
}

/// Noise-stream source for a batched search: the serving engines thread
/// one independent stream per image; the single-macro paths thread the
/// array's own stream through every query in order.
enum BatchRngs<'a> {
    Shared(&'a mut Rng),
    PerQuery(&'a mut [Rng]),
}

/// Query operands of a batched search: independent `BitVec`s, or the
/// rows of one packed `BitMatrix` (the allocation-free engines reuse a
/// query block across batches instead of building per-query `BitVec`s).
enum Queries<'a> {
    Slice(&'a [BitVec]),
    Block(&'a BitMatrix),
}

impl Queries<'_> {
    fn len(&self) -> usize {
        match self {
            Queries::Slice(q) => q.len(),
            Queries::Block(m) => m.rows(),
        }
    }

    fn words(&self, i: usize) -> &[u64] {
        match self {
            Queries::Slice(q) => q[i].words(),
            Queries::Block(m) => m.row_words(i),
        }
    }
}

/// The simulated PiC-BNN macro.
pub struct CamArray {
    config: CamConfig,
    store: BitMatrix,
    row_valid: Vec<bool>,
    row_var: Vec<RowVariation>,
    /// Voltage sources for (V_ref, V_eval, V_st).
    pub rails: VoltageRails,
    model: MatchlineModel,
    pub clock: SimClock,
    pub events: EventCounters,
    rng: Rng,
    pvt: Pvt,
    noise: NoiseMode,
    /// Internal mismatch-count scratch for fire-only entry points
    /// ([`CamArray::search`], [`CamArray::search_masked_fires`]): reused
    /// across calls so the hot path allocates nothing.
    scratch_m: Vec<u32>,
    /// Internal fires scratch backing [`CamArray::search`]'s borrowed
    /// return value (same zero-allocation contract as `scratch_m`).
    scratch_f: Vec<bool>,
    /// Lazily rebuilt per-row decision state (module docs).
    cache: RowCache,
    /// Injected hardware faults (empty on a healthy device — module docs).
    faults: ArrayFaults,
    /// Spare physical rows remaining for address-level remap repairs.
    spare_rows: usize,
}

impl CamArray {
    /// Fresh array in `config` at the given PVT point.
    pub fn new(config: CamConfig, pvt: Pvt, noise: NoiseMode, seed: u64) -> Self {
        let mut rng = Rng::new(seed, 0x0CA8);
        let rails = match noise {
            NoiseMode::Nominal => VoltageRails::ideal(Voltages::exact()),
            NoiseMode::Analog => VoltageRails::new(Voltages::exact(), &mut rng),
        };
        CamArray {
            config,
            store: BitMatrix::zeros(config.rows(), config.width()),
            row_valid: vec![false; config.rows()],
            row_var: vec![RowVariation::nominal(); config.rows()],
            rails,
            model: MatchlineModel::new(config.width(), pvt),
            clock: SimClock::new(),
            events: EventCounters::default(),
            rng,
            pvt,
            noise,
            scratch_m: Vec::new(),
            scratch_f: Vec::new(),
            cache: RowCache::default(),
            faults: ArrayFaults::default(),
            spare_rows: DEFAULT_SPARE_ROWS,
        }
    }

    /// Convenience: analog-noise array at nominal PVT.
    pub fn analog(config: CamConfig, seed: u64) -> Self {
        CamArray::new(config, Pvt::nominal(), NoiseMode::Analog, seed)
    }

    /// Convenience: deterministic array (bit-exact vs the L2 graph).
    pub fn nominal(config: CamConfig) -> Self {
        CamArray::new(config, Pvt::nominal(), NoiseMode::Nominal, 0)
    }

    pub fn config(&self) -> CamConfig {
        self.config
    }

    pub fn pvt(&self) -> Pvt {
        self.pvt
    }

    pub fn noise_mode(&self) -> NoiseMode {
        self.noise
    }

    /// Reconfigure the logical geometry; clears contents (the physical
    /// banks are re-tiled).
    pub fn reconfigure(&mut self, config: CamConfig) {
        let scale = self.model.noise_scale;
        self.config = config;
        self.store = BitMatrix::zeros(config.rows(), config.width());
        self.row_valid = vec![false; config.rows()];
        self.row_var = vec![RowVariation::nominal(); config.rows()];
        self.model = MatchlineModel::with_noise_scale(config.width(), self.pvt, scale);
        self.cache.valid = false;
    }

    /// Scale every per-evaluation noise sigma (ablations; 1.0 = shipped).
    pub fn set_noise_scale(&mut self, scale: f64) {
        self.model.noise_scale = scale;
    }

    /// Program one row (one cycle per word write; silicon writes a word per
    /// cycle through the write circuitry).  Draws fresh per-row variation.
    pub fn write_row(&mut self, row: usize, data: &BitVec) {
        assert_eq!(data.len(), self.config.width(), "row width mismatch");
        assert!(row < self.config.rows(), "row index out of range");
        self.store.row_words_mut(row).copy_from_slice(data.words());
        self.apply_stuck_bits(row);
        self.row_valid[row] = true;
        self.row_var[row] = match self.noise {
            NoiseMode::Nominal => RowVariation::nominal(),
            NoiseMode::Analog => RowVariation::draw(&mut self.rng),
        };
        self.cache.valid = false;
        self.clock.tick(1);
        self.events.cells_written += self.config.width() as u64;
        self.events.row_writes += 1;
    }

    /// Reprogram a row's contents *without* redrawing its frozen per-row
    /// variation — the scrub repair path.  Keeping the variation is the
    /// documented spare-remap idealization (`cam::faults` module docs):
    /// it is what makes a completed repair bit-exact against a
    /// never-faulted twin in analog mode.  Costs one cycle like any row
    /// write; still-active stuck bits re-assert themselves.
    pub fn rewrite_row(&mut self, row: usize, data: &BitVec) {
        assert_eq!(data.len(), self.config.width(), "row width mismatch");
        assert!(row < self.config.rows(), "row index out of range");
        self.store.row_words_mut(row).copy_from_slice(data.words());
        self.apply_stuck_bits(row);
        if !self.row_valid[row] {
            self.row_valid[row] = true;
            self.cache.valid = false;
        }
        self.clock.tick(1);
        self.events.cells_written += self.config.width() as u64;
        self.events.row_writes += 1;
    }

    /// Re-force every stuck bitcell recorded against `row` in the store.
    fn apply_stuck_bits(&mut self, row: usize) {
        let store = &mut self.store;
        for &(r, c, b) in &self.faults.stuck_bits {
            if r == row {
                store.set(row, c, b);
            }
        }
    }

    /// Invalidate a row (its MLSA output is ignored by searches).
    pub fn clear_row(&mut self, row: usize) {
        self.row_valid[row] = false;
        self.cache.valid = false;
    }

    /// Read a row back (diagnostic path; one cycle).
    pub fn read_row(&mut self, row: usize) -> Option<BitVec> {
        self.clock.tick(1);
        self.events.reads += 1;
        if self.row_valid[row] {
            Some(self.store.row(row))
        } else {
            None
        }
    }

    /// Inject one hardware fault (taxonomy in `cam::faults`).  Stuck bits
    /// corrupt the store immediately (and re-assert on every row write);
    /// dead rows / transients arm the post-decision fire override; DAC
    /// faults land on the rails.  Injection itself is instantaneous —
    /// silicon does not announce its failures.
    pub fn inject_fault(&mut self, kind: &FaultKind) {
        match *kind {
            FaultKind::StuckBit { row, col, bit } => {
                assert!(row < self.config.rows(), "fault row out of range");
                assert!(col < self.config.width(), "fault col out of range");
                self.faults.stuck_bits.retain(|&(r, c, _)| (r, c) != (row, col));
                self.faults.stuck_bits.push((row, col, bit));
                self.store.set(row, col, bit);
            }
            FaultKind::DeadRow { row, always_fire } => {
                assert!(row < self.config.rows(), "fault row out of range");
                self.faults.dead_rows.retain(|&(r, _)| r != row);
                self.faults.dead_rows.push((row, always_fire));
            }
            FaultKind::Transient { row, searches } => {
                assert!(row < self.config.rows(), "fault row out of range");
                if searches > 0 {
                    self.faults.transients.push((row, searches));
                }
            }
            FaultKind::StuckDac { rail } => self.rails.stick(rail),
            FaultKind::DacDrift { rail, volts } => {
                self.rails.drift(rail, volts);
                // the delivered level moved under the cached thresholds
                self.cache.valid = false;
            }
        }
    }

    /// The faults currently active in this array (scrub diagnostics).
    pub fn active_faults(&self) -> &ArrayFaults {
        &self.faults
    }

    /// Spare physical rows still available for remap repairs.
    pub fn spares_left(&self) -> usize {
        self.spare_rows
    }

    /// Remap logical `row` onto a spare physical row (address-level
    /// redundancy; invariants in `cam::faults`).  The row keeps its
    /// logical index and frozen variation; all faults recorded against it
    /// clear because the defective cells are no longer addressed.  The
    /// caller reprograms the row via [`CamArray::rewrite_row`].  Blowing
    /// the remap fuse costs one cycle.  Returns `false` (and does
    /// nothing) once the spare budget is exhausted.
    pub fn remap_row_to_spare(&mut self, row: usize) -> bool {
        assert!(row < self.config.rows(), "row index out of range");
        if self.spare_rows == 0 {
            return false;
        }
        self.spare_rows -= 1;
        self.faults.clear_row(row);
        self.clock.tick(1);
        true
    }

    /// Re-trim drifted rails back to factory offsets (the scrub drift
    /// repair).  Charged like any retune: settle stall + one retune event
    /// when something actually moved; returns the stall [s].
    pub fn recalibrate_rails(&mut self) -> f64 {
        let stall = self.rails.trim_all();
        if stall > 0.0 {
            self.cache.valid = false;
            self.clock.stall(stall);
            self.events.retunes += 1;
        }
        stall
    }

    /// Retune the three voltage rails; stalls for the DAC settle time.
    pub fn set_voltages(&mut self, v: Voltages) {
        let stall = self.rails.retune(v.clamped());
        if stall > 0.0 {
            // delivered rails changed — the per-row threshold caches are
            // stale (a zero stall means every DAC kept its level, so the
            // cache stays warm across repeated parks at one point)
            self.cache.valid = false;
            self.clock.stall(stall);
            self.events.retunes += 1;
        }
    }

    /// Voltages the array currently sees (incl. DAC non-idealities).
    pub fn delivered_voltages(&self) -> Voltages {
        self.rails.delivered()
    }

    /// Nominal HD tolerance at the current rails (diagnostic).
    pub fn current_tolerance(&self) -> f64 {
        self.model.hd_tolerance(&self.rails.delivered())
    }

    /// Rebuild the per-row decision cache if a programming/retune event
    /// invalidated it (see the module docs for the exact dependency set).
    fn ensure_row_cache(&mut self) {
        if self.cache.valid {
            return;
        }
        let rows = self.config.rows();
        let v = self.rails.delivered();
        let n_prefix = self.row_valid.iter().take_while(|&&b| b).count();
        let contiguous = self.row_valid[n_prefix..].iter().all(|&b| !b);
        self.cache.prefix = contiguous.then_some(n_prefix);
        match self.noise {
            NoiseMode::Nominal => {
                self.cache.m_max.clear();
                self.cache.m_max.reserve(rows);
                let n_cells = self.config.width() as u32;
                // binary search the exact fires_nominal curve (monotone
                // non-increasing in m), so `m <= m_max[r]` reproduces the
                // closed form bit-for-bit; rows sharing one variation
                // (every nominal-mode row) share one search via the memo
                let mut memo: Option<(RowVariation, u32)> = None;
                for r in 0..rows {
                    if !self.row_valid[r] {
                        self.cache.m_max.push(0);
                        continue;
                    }
                    let var = self.row_var[r];
                    let hit = memo.filter(|(mv, _)| {
                        mv.g_row_factor == var.g_row_factor && mv.mlsa_offset == var.mlsa_offset
                    });
                    let m_max = match hit {
                        Some((_, m_max)) => m_max,
                        None => {
                            let m_max = if self.model.fires_nominal(n_cells, &v, &var) {
                                n_cells
                            } else {
                                // invariant: fires(lo), !fires(hi)
                                let (mut lo, mut hi) = (0u32, n_cells);
                                while lo + 1 < hi {
                                    let mid = lo + (hi - lo) / 2;
                                    if self.model.fires_nominal(mid, &v, &var) {
                                        lo = mid;
                                    } else {
                                        hi = mid;
                                    }
                                }
                                lo
                            };
                            memo = Some((var, m_max));
                            m_max
                        }
                    };
                    self.cache.m_max.push(m_max);
                }
            }
            NoiseMode::Analog => {
                self.cache.ln_sense.clear();
                self.cache.ln_sense.reserve(rows);
                self.cache.g_row.clear();
                self.cache.g_row.reserve(rows);
                for r in 0..rows {
                    let var = &self.row_var[r];
                    self.cache.ln_sense.push((v.vref + var.mlsa_offset).ln());
                    self.cache.g_row.push(var.g_row_factor);
                }
            }
        }
        self.cache.valid = true;
    }

    /// The per-cycle decision plan (draws the analog cycle-global noise).
    fn begin_plan(&self, rng: &mut Rng) -> CyclePlan {
        match self.noise {
            NoiseMode::Nominal => CyclePlan::Nominal,
            NoiseMode::Analog => {
                CyclePlan::Analog(self.model.begin_cycle(&self.rails.delivered(), rng))
            }
        }
    }

    /// One search cycle: per-row mismatch counts + MLSA decisions.
    ///
    /// `fires[r]` is meaningful only for valid rows; invalid rows report
    /// `false`.  Reuses caller buffers — the hot path allocates nothing.
    /// Per-evaluation noise draws come from the array's own stream.
    pub fn search_into(&mut self, query: &BitVec, mismatches: &mut Vec<u32>, fires: &mut Vec<bool>) {
        // advance the device stream through an external handle: clone in,
        // draw, write back (Rng is two words; this is the cheap way to
        // split the borrow of `self.rng` from the rest of the array)
        let mut rng = self.rng.clone();
        self.search_into_rng(query, mismatches, fires, &mut rng);
        self.rng = rng;
    }

    /// [`CamArray::search_into`] with an explicit noise stream.
    ///
    /// The pool execution engine (`accel::macro_pool`) threads a per-image
    /// RNG through every macro an image touches, so analog-mode results
    /// are deterministic regardless of how worker threads interleave on
    /// the shared macros (the frozen per-row variation was already drawn
    /// from the macro's own stream at programming time).
    pub fn search_into_rng(
        &mut self,
        query: &BitVec,
        mismatches: &mut Vec<u32>,
        fires: &mut Vec<bool>,
        rng: &mut Rng,
    ) {
        self.search_one(query, None, mismatches, fires, rng);
    }

    /// Ternary (masked) search cycle: columns with a clear `mask` bit are
    /// "don't care" — their searchline pair is not driven, so they can
    /// never open a discharge path (see `cam::bitcell::opens_discharge`).
    pub fn search_masked_into(
        &mut self,
        query: &BitVec,
        mask: &BitVec,
        mismatches: &mut Vec<u32>,
        fires: &mut Vec<bool>,
    ) {
        let mut rng = self.rng.clone();
        self.search_one(query, Some(mask), mismatches, fires, &mut rng);
        self.rng = rng;
    }

    /// The unified single-query kernel behind the exact and masked search
    /// entry points: one row loop, one decision path (the same cached
    /// thresholds the batch kernel uses), masked searches differing only
    /// in the mismatch-count primitive.
    fn search_one(
        &mut self,
        query: &BitVec,
        mask: Option<&BitVec>,
        mismatches: &mut Vec<u32>,
        fires: &mut Vec<bool>,
        rng: &mut Rng,
    ) {
        assert_eq!(query.len(), self.config.width(), "query width mismatch");
        if let Some(mask) = mask {
            assert_eq!(mask.len(), self.config.width(), "mask width mismatch");
        }
        self.ensure_row_cache();
        let rows = self.config.rows();
        mismatches.clear();
        mismatches.reserve(rows);
        fires.clear();
        fires.reserve(rows);
        // cycle-global noise (supply, strobe jitter) drawn once per search:
        // every row of a cycle shares the rails and the MLSA strobe
        let plan = self.begin_plan(rng);
        // hoisted so a healthy array pays one branch per search, and the
        // override runs *after* the MLSA decision (draw order preserved)
        let have_row_faults = self.faults.has_fire_faults();
        for r in 0..rows {
            if !self.row_valid[r] {
                mismatches.push(0);
                fires.push(false);
                continue;
            }
            let m = match mask {
                None => hamming_words(self.store.row_words(r), query.words()),
                Some(mask) => {
                    hamming_words_masked(self.store.row_words(r), query.words(), mask.words())
                }
            };
            mismatches.push(m);
            let mut fired = row_fires(&plan, &self.cache, m, r, rng);
            if have_row_faults {
                fired = self.faults.apply_fire(r, fired);
            }
            fires.push(fired);
        }
        self.account_searches(1);
    }

    /// Query-batched search: `queries.len()` device cycles, one per query,
    /// with one cycle-global noise draw per query from that query's own
    /// stream — accounting and per-stream draw order bit-identical to
    /// issuing the same queries through [`CamArray::search_into_rng`]
    /// sequentially (the serving engines rely on this; module docs).
    ///
    /// Outputs: `mismatches[q * rows + r]` and one packed fires bitmask
    /// per query (`fires.row_ones(q)` walks query `q`'s firing rows).
    /// Both buffers are reshaped in place and never reallocate once grown.
    pub fn search_batch_into_rngs(
        &mut self,
        queries: &[BitVec],
        rngs: &mut [Rng],
        mismatches: &mut Vec<u32>,
        fires: &mut BitMatrix,
    ) {
        assert_eq!(queries.len(), rngs.len(), "one noise stream per query");
        let q = Queries::Slice(queries);
        self.search_batch_core(q, BatchRngs::PerQuery(rngs), mismatches, fires);
    }

    /// [`CamArray::search_batch_into_rngs`] with the queries packed as
    /// the rows of a [`BitMatrix`] (`queries.rows()` queries of
    /// `queries.cols() ==` width bits) — the allocation-free batch path:
    /// the execution engines pack one reusable query block per batch
    /// instead of building per-query `BitVec`s.  Results, accounting,
    /// and RNG draw order are bit-identical to the `&[BitVec]` entry.
    pub fn search_batch_rows_into_rngs(
        &mut self,
        queries: &BitMatrix,
        rngs: &mut [Rng],
        mismatches: &mut Vec<u32>,
        fires: &mut BitMatrix,
    ) {
        assert_eq!(queries.rows(), rngs.len(), "one noise stream per query");
        let q = Queries::Block(queries);
        self.search_batch_core(q, BatchRngs::PerQuery(rngs), mismatches, fires);
    }

    /// [`CamArray::search_batch_into_rngs`] drawing every query's noise
    /// from the array's own stream, in query order — the draw sequence of
    /// the equivalent [`CamArray::search_into`] loop (single-macro paths).
    pub fn search_batch_into(
        &mut self,
        queries: &[BitVec],
        mismatches: &mut Vec<u32>,
        fires: &mut BitMatrix,
    ) {
        let mut rng = self.rng.clone();
        let q = Queries::Slice(queries);
        self.search_batch_core(q, BatchRngs::Shared(&mut rng), mismatches, fires);
        self.rng = rng;
    }

    /// [`CamArray::search_batch_rows_into_rngs`] drawing from the
    /// array's own stream (the reload `Pipeline`'s batch path).
    pub fn search_batch_rows_into(
        &mut self,
        queries: &BitMatrix,
        mismatches: &mut Vec<u32>,
        fires: &mut BitMatrix,
    ) {
        let mut rng = self.rng.clone();
        let q = Queries::Block(queries);
        self.search_batch_core(q, BatchRngs::Shared(&mut rng), mismatches, fires);
        self.rng = rng;
    }

    fn search_batch_core(
        &mut self,
        queries: Queries<'_>,
        mut rngs: BatchRngs<'_>,
        mismatches: &mut Vec<u32>,
        fires: &mut BitMatrix,
    ) {
        let rows = self.config.rows();
        let nq = queries.len();
        match &queries {
            Queries::Slice(qs) => {
                for q in *qs {
                    assert_eq!(q.len(), self.config.width(), "query width mismatch");
                }
            }
            Queries::Block(m) => {
                assert_eq!(m.cols(), self.config.width(), "query width mismatch");
            }
        }
        fires.reset(nq, rows);
        mismatches.clear();
        mismatches.resize(nq * rows, 0);
        if nq == 0 {
            return;
        }
        self.ensure_row_cache();

        // pass 1 — mismatch counts (RNG-free): stream the store once per
        // query tile over the programmed prefix; arrays with cleared holes
        // (diagnostics only) fall back to a row-major loop
        match (self.cache.prefix, &queries) {
            (Some(live), Queries::Slice(qs)) => {
                self.store.hamming_rows_batch_into(live, qs, mismatches, rows);
            }
            (Some(live), Queries::Block(m)) => {
                self.store.hamming_rows_batch_from(live, m, mismatches, rows);
            }
            (None, _) => {
                for r in 0..rows {
                    if !self.row_valid[r] {
                        continue;
                    }
                    let row = self.store.row_words(r);
                    for qi in 0..nq {
                        mismatches[qi * rows + r] = hamming_words(row, queries.words(qi));
                    }
                }
            }
        }

        // pass 2 — MLSA decisions in the sequential path's exact draw
        // order: per query, the cycle-global draw, then metastable rows
        // ascending (see the module docs for why the passes are split).
        // Fault overrides run after each row's decision (and its draws),
        // gated on one hoisted branch so the healthy path is unchanged.
        let have_row_faults = self.faults.has_fire_faults();
        for qi in 0..nq {
            let rng: &mut Rng = match &mut rngs {
                BatchRngs::Shared(r) => &mut **r,
                BatchRngs::PerQuery(rs) => &mut rs[qi],
            };
            let plan = self.begin_plan(rng);
            let m_row = &mismatches[qi * rows..(qi + 1) * rows];
            let fire_words = fires.row_words_mut(qi);
            let mut word = 0u64;
            let mut widx = 0usize;
            for (r, &m) in m_row.iter().enumerate() {
                let mut fired = self.row_valid[r] && row_fires(&plan, &self.cache, m, r, rng);
                if have_row_faults && self.row_valid[r] {
                    fired = self.faults.apply_fire(r, fired);
                }
                if fired {
                    word |= 1 << (r % 64);
                }
                if r % 64 == 63 {
                    fire_words[widx] = word;
                    word = 0;
                    widx += 1;
                }
            }
            if rows % 64 != 0 {
                fire_words[widx] = word;
            }
        }
        self.account_searches(nq as u64);
    }

    /// Allocation-free convenience wrapper around [`CamArray::search_into`]:
    /// the returned slice borrows array-owned scratch, reused across calls.
    pub fn search(&mut self, query: &BitVec) -> &[bool] {
        let mut m = std::mem::take(&mut self.scratch_m);
        let mut f = std::mem::take(&mut self.scratch_f);
        self.search_into(query, &mut m, &mut f);
        self.scratch_m = m;
        self.scratch_f = f;
        &self.scratch_f
    }

    /// Fire-only masked search that honours the out-parameter contract:
    /// the mismatch-count scratch is owned by the array and reused, so
    /// repeated calls allocate nothing once `out_fires` has grown to the
    /// row count (see `cam::ops::masked_search`).
    pub fn search_masked_fires(
        &mut self,
        query: &BitVec,
        mask: &BitVec,
        out_fires: &mut Vec<bool>,
    ) {
        let mut m = std::mem::take(&mut self.scratch_m);
        self.search_masked_into(query, mask, &mut m, out_fires);
        self.scratch_m = m;
    }

    /// Matchline voltage trace for row `row` under the current rails
    /// (Fig. 4 regeneration): returns (t, V_ML) samples + the sampling time.
    pub fn ml_trace(&self, row: usize, query: &BitVec, n_pts: usize) -> (Vec<(f64, f64)>, f64) {
        let m = hamming_words(self.store.row_words(row), query.words());
        let v = self.rails.delivered();
        let ts = self.model.sampling_time(&v);
        (self.model.trace(m, ts * 2.0, n_pts, &v), ts)
    }

    /// Charge `n` search cycles (one per query — batching amortises host
    /// work, never device work; totals match `n` sequential searches).
    fn account_searches(&mut self, n: u64) {
        self.clock.tick(n);
        self.events.searches += n;
        let width = self.config.width() as u64;
        let rows = self.config.rows() as u64;
        self.events.cells_precharged += width * rows * n;
        self.events.sl_toggles += width * n;
        self.events.mlsa_evals += rows * n;
    }

    /// Reset cycle/event accounting (contents preserved).
    pub fn reset_accounting(&mut self) {
        self.clock.reset();
        self.events = EventCounters::default();
    }

    /// Fraction of rows currently programmed.
    pub fn occupancy(&self) -> f64 {
        self.row_valid.iter().filter(|&&v| v).count() as f64 / self.config.rows() as f64
    }

    /// Macro area [mm²] from the cell count + periphery factor (Table II).
    pub fn area_mm2(&self) -> f64 {
        super::config::CAPACITY_BITS as f64 * k::AREA_BITCELL_MM2 * k::BANK_PERIPHERY_FACTOR
            * 2.0 // CAM cell pitch overhead vs raw bitcell tiling (routing, taps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn query(width: usize, flip_first: usize) -> (BitVec, BitVec) {
        // stored row of all +1; query with `flip_first` mismatches
        let stored = BitVec::ones(width);
        let mut q = BitVec::ones(width);
        for i in 0..flip_first {
            q.set(i, false);
        }
        (stored, q)
    }

    #[test]
    fn stuck_bit_survives_rewrites_until_remapped() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let stored = BitVec::ones(512);
        cam.write_row(0, &stored);
        assert!(cam.search(&stored)[0]);
        // a stuck-at-0 cell corrupts the stored pattern
        cam.inject_fault(&FaultKind::StuckBit {
            row: 0,
            col: 7,
            bit: false,
        });
        let mut m = Vec::new();
        let mut f = Vec::new();
        cam.search_into(&stored, &mut m, &mut f);
        assert_eq!(m[0], 1, "one mismatching cell");
        // rewriting the golden data does not help: the cell re-sticks
        cam.rewrite_row(0, &stored);
        cam.search_into(&stored, &mut m, &mut f);
        assert_eq!(m[0], 1, "stuck bit re-asserts on write");
        // spare-row remap clears the fault; the rewrite then lands clean
        assert_eq!(cam.spares_left(), DEFAULT_SPARE_ROWS);
        assert!(cam.remap_row_to_spare(0));
        assert_eq!(cam.spares_left(), DEFAULT_SPARE_ROWS - 1);
        cam.rewrite_row(0, &stored);
        cam.search_into(&stored, &mut m, &mut f);
        assert_eq!(m[0], 0);
        assert!(f[0]);
    }

    #[test]
    fn dead_rows_pin_the_fire_decision_in_both_kernels() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let (stored, far) = query(512, 400);
        cam.write_row(0, &stored);
        cam.write_row(1, &stored);
        cam.set_voltages(Voltages::exact());
        cam.inject_fault(&FaultKind::DeadRow {
            row: 0,
            always_fire: false,
        });
        cam.inject_fault(&FaultKind::DeadRow {
            row: 1,
            always_fire: true,
        });
        let fires = cam.search(&stored);
        assert!(!fires[0], "never-fire row ignores a perfect match");
        assert!(fires[1]);
        let fires = cam.search(&far).to_vec();
        assert!(!fires[0]);
        assert!(fires[1], "always-fire row ignores 400 mismatches");
        // the batched kernel applies the same overrides
        let mut mm = Vec::new();
        let mut fm = BitMatrix::zeros(1, 1);
        let mut rngs = vec![Rng::new(1, 1), Rng::new(2, 2)];
        cam.search_batch_into_rngs(
            &[stored.clone(), far.clone()],
            &mut rngs,
            &mut mm,
            &mut fm,
        );
        for qi in 0..2 {
            assert!(!fm.get(qi, 0));
            assert!(fm.get(qi, 1));
        }
    }

    #[test]
    fn transient_upset_inverts_then_self_clears() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let stored = BitVec::ones(512);
        cam.write_row(0, &stored);
        cam.set_voltages(Voltages::exact());
        cam.inject_fault(&FaultKind::Transient {
            row: 0,
            searches: 2,
        });
        assert!(!cam.search(&stored)[0], "upset inverts the match");
        assert!(!cam.search(&stored)[0]);
        assert!(cam.search(&stored)[0], "fault burned down");
        assert!(cam.active_faults().is_empty());
    }

    #[test]
    fn faultless_array_is_bit_identical_to_a_pristine_twin() {
        // zero-cost abstraction at the array level: an array that owns an
        // (empty) fault set takes the exact same decisions and draws as
        // one that never heard of faults — here: inject + fully repair,
        // then compare against the twin on the same query/noise stream
        for noise in [NoiseMode::Nominal, NoiseMode::Analog] {
            let mut a = CamArray::new(CamConfig::W512x256, Pvt::nominal(), noise, 9);
            let mut b = CamArray::new(CamConfig::W512x256, Pvt::nominal(), noise, 9);
            let mut rng = Rng::new(77, 1);
            let rows: Vec<BitVec> = (0..8)
                .map(|_| {
                    let mut v = BitVec::zeros(512);
                    for i in 0..512 {
                        v.set(i, rng.chance(0.5));
                    }
                    v
                })
                .collect();
            for (r, data) in rows.iter().enumerate() {
                a.write_row(r, data);
                b.write_row(r, data);
            }
            a.set_voltages(Voltages::new(0.72, 0.48, 1.05));
            b.set_voltages(Voltages::new(0.72, 0.48, 1.05));
            // fault + repair on `a`; `b` stays pristine
            a.inject_fault(&FaultKind::StuckBit {
                row: 3,
                col: 11,
                bit: true,
            });
            assert!(a.remap_row_to_spare(3));
            a.rewrite_row(3, &rows[3]);
            b.rewrite_row(3, &rows[3]); // same cycle/event charge on the twin
            let (mut ma, mut fa) = (Vec::new(), Vec::new());
            let (mut mb, mut fb) = (Vec::new(), Vec::new());
            let mut ra = Rng::new(5, 5);
            let mut rb = Rng::new(5, 5);
            for q in &rows {
                a.search_into_rng(q, &mut ma, &mut fa, &mut ra);
                b.search_into_rng(q, &mut mb, &mut fb, &mut rb);
                assert_eq!(ma, mb, "{noise:?}");
                assert_eq!(fa, fb, "{noise:?}");
            }
            assert_eq!(format!("{ra:?}"), format!("{rb:?}"), "draw order");
        }
    }

    #[test]
    fn exact_search_matches_only_identical() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let (stored, q1) = query(512, 1);
        cam.write_row(0, &stored);
        cam.write_row(1, &q1);
        cam.set_voltages(Voltages::exact());
        let fires = cam.search(&stored);
        assert!(fires[0]);
        assert!(!fires[1]);
        // unprogrammed rows never fire
        assert!(!fires[2]);
    }

    #[test]
    fn tolerance_widens_matches() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let (stored, _) = query(512, 0);
        cam.write_row(0, &stored);
        // find rails giving tolerance ~8 via the model (grid scan)
        let mut v8 = None;
        for vref in [0.7, 0.8, 0.9, 1.0, 1.1] {
            for veval in [0.4, 0.6, 0.8, 1.0] {
                for vst in [0.7, 0.9, 1.1] {
                    let v = Voltages::new(vref, veval, vst);
                    let cand = MatchlineModel::new(512, Pvt::nominal()).hd_tolerance(&v);
                    if (cand - 8.0).abs() < 1.5 {
                        v8 = Some(v);
                    }
                }
            }
        }
        let v8 = v8.expect("some grid point near tol=8");
        cam.set_voltages(v8);
        let tol = cam.current_tolerance();
        let (_, q_in) = query(512, (tol as usize).saturating_sub(2));
        let (_, q_out) = query(512, tol as usize + 4);
        assert!(cam.search(&q_in)[0]);
        assert!(!cam.search(&q_out)[0]);
    }

    #[test]
    fn search_counts_cycles_and_events() {
        let mut cam = CamArray::nominal(CamConfig::W1024x128);
        let row = BitVec::ones(1024);
        cam.write_row(0, &row);
        cam.reset_accounting();
        let _ = cam.search(&row);
        let _ = cam.search(&row);
        assert_eq!(cam.clock.cycles, 2);
        assert_eq!(cam.events.searches, 2);
        assert_eq!(cam.events.mlsa_evals, 2 * 128);
        assert_eq!(cam.events.cells_precharged, 2 * 1024 * 128);
    }

    #[test]
    fn reconfigure_clears() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        cam.write_row(3, &BitVec::ones(512));
        cam.reconfigure(CamConfig::W2048x64);
        assert_eq!(cam.config().width(), 2048);
        assert_eq!(cam.occupancy(), 0.0);
    }

    #[test]
    fn read_row_roundtrip() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let mut data = BitVec::zeros(512);
        data.set(17, true);
        data.set(400, true);
        cam.write_row(5, &data);
        assert_eq!(cam.read_row(5), Some(data));
        assert_eq!(cam.read_row(6), None);
    }

    #[test]
    fn analog_mode_is_deterministic_given_seed() {
        let run = |seed| {
            let mut cam = CamArray::analog(CamConfig::W512x256, seed);
            // rails giving tolerance near the probe's mismatch count so the
            // decision sits in the metastable band and noise matters
            cam.set_voltages(Voltages::new(0.75, 0.5, 1.0));
            let tol = cam.current_tolerance().round() as usize;
            let (stored, q) = query(512, tol.max(1));
            cam.write_row(0, &stored);
            (0..64).map(|_| cam.search(&q)[0]).collect::<Vec<bool>>()
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8)); // different seed, different noise draw
    }

    #[test]
    fn mismatch_counts_exposed() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let (stored, q) = query(512, 33);
        cam.write_row(0, &stored);
        let (mut m, mut f) = (Vec::new(), Vec::new());
        cam.search_into(&q, &mut m, &mut f);
        assert_eq!(m[0], 33);
    }

    fn rand_bits(n: usize, rng: &mut Rng) -> BitVec {
        let mut v = BitVec::zeros(n);
        for i in 0..n {
            v.set(i, rng.chance(0.5));
        }
        v
    }

    /// Two bit-identical arrays (same seed, same writes, same rails).
    fn twin_arrays(noise: NoiseMode, seed: u64, n_rows: usize) -> (CamArray, CamArray) {
        let mk = || {
            let mut cam = CamArray::new(CamConfig::W512x256, Pvt::nominal(), noise, seed);
            let mut rng = Rng::new(seed ^ 0xF00D, 2);
            for r in 0..n_rows {
                cam.write_row(r, &rand_bits(512, &mut rng));
            }
            cam.set_voltages(Voltages::new(0.72, 0.48, 1.05));
            cam
        };
        (mk(), mk())
    }

    #[test]
    fn batch_search_matches_sequential_in_both_modes() {
        for noise in [NoiseMode::Nominal, NoiseMode::Analog] {
            let (mut seq, mut bat) = twin_arrays(noise, 11, 20);
            let mut rng = Rng::new(99, 1);
            let queries: Vec<BitVec> = (0..6).map(|_| rand_bits(512, &mut rng)).collect();
            let mut rngs_a: Vec<Rng> = (0..6).map(|i| Rng::new(7, i)).collect();
            let mut rngs_b = rngs_a.clone();
            let (mut sm, mut sf) = (Vec::new(), Vec::new());
            let (mut seq_m, mut seq_f) = (Vec::new(), Vec::new());
            for (i, q) in queries.iter().enumerate() {
                seq.search_into_rng(q, &mut sm, &mut sf, &mut rngs_a[i]);
                seq_m.extend_from_slice(&sm);
                seq_f.push(sf.clone());
            }
            let (mut bm, mut bf) = (Vec::new(), BitMatrix::default());
            bat.search_batch_into_rngs(&queries, &mut rngs_b, &mut bm, &mut bf);
            assert_eq!(bm, seq_m, "{noise:?}: mismatch counts diverge");
            for (i, f) in seq_f.iter().enumerate() {
                for r in 0..256 {
                    assert_eq!(bf.get(i, r), f[r], "{noise:?}: fires q{i} r{r}");
                }
            }
            for (ra, rb) in rngs_a.iter().zip(&rngs_b) {
                assert_eq!(format!("{ra:?}"), format!("{rb:?}"), "{noise:?}: rng stream");
            }
            assert_eq!(seq.clock.cycles, bat.clock.cycles, "{noise:?}");
            assert_eq!(seq.events, bat.events, "{noise:?}");
        }
    }

    #[test]
    fn batch_search_shared_stream_matches_search_into_loop() {
        // single-macro paths: the array's own stream, threaded through
        // every query in order, must see the sequential draw sequence
        let (mut seq, mut bat) = twin_arrays(NoiseMode::Analog, 31, 12);
        let mut rng = Rng::new(5, 5);
        let queries: Vec<BitVec> = (0..5).map(|_| rand_bits(512, &mut rng)).collect();
        let (mut sm, mut sf) = (Vec::new(), Vec::new());
        let mut seq_f = Vec::new();
        for q in &queries {
            seq.search_into(q, &mut sm, &mut sf);
            seq_f.push(sf.clone());
        }
        let (mut bm, mut bf) = (Vec::new(), BitMatrix::default());
        bat.search_batch_into(&queries, &mut bm, &mut bf);
        for (i, f) in seq_f.iter().enumerate() {
            for r in 0..256 {
                assert_eq!(bf.get(i, r), f[r], "q{i} r{r}");
            }
        }
        // the internal streams advanced identically: subsequent single
        // searches still agree
        let probe = rand_bits(512, &mut rng);
        assert_eq!(seq.search(&probe), bat.search(&probe));
    }

    #[test]
    fn batch_search_query_block_matches_bitvec_queries() {
        // the allocation-free entry (queries as rows of one BitMatrix)
        // must be bit-identical to the BitVec entry: counts, fires, RNG
        // stream positions, and accounting — in both noise modes
        for noise in [NoiseMode::Nominal, NoiseMode::Analog] {
            let (mut a, mut b) = twin_arrays(noise, 23, 18);
            let mut rng = Rng::new(61, 2);
            let queries: Vec<BitVec> = (0..7).map(|_| rand_bits(512, &mut rng)).collect();
            let block = BitMatrix::from_rows(&queries);
            let mut rngs_a: Vec<Rng> = (0..7).map(|i| Rng::new(9, i)).collect();
            let mut rngs_b = rngs_a.clone();
            let (mut am, mut af) = (Vec::new(), BitMatrix::default());
            let (mut bm, mut bf) = (Vec::new(), BitMatrix::default());
            a.search_batch_into_rngs(&queries, &mut rngs_a, &mut am, &mut af);
            b.search_batch_rows_into_rngs(&block, &mut rngs_b, &mut bm, &mut bf);
            assert_eq!(am, bm, "{noise:?}: mismatch counts");
            for q in 0..7 {
                for r in 0..256 {
                    assert_eq!(af.get(q, r), bf.get(q, r), "{noise:?}: fires q{q} r{r}");
                }
            }
            for (ra, rb) in rngs_a.iter().zip(&rngs_b) {
                assert_eq!(format!("{ra:?}"), format!("{rb:?}"), "{noise:?}: rng stream");
            }
            assert_eq!(a.clock.cycles, b.clock.cycles, "{noise:?}");
            assert_eq!(a.events, b.events, "{noise:?}");
            // shared-stream twin entry as well
            let (mut sm, mut sf) = (Vec::new(), BitMatrix::default());
            let (mut tm, mut tf) = (Vec::new(), BitMatrix::default());
            a.search_batch_into(&queries, &mut sm, &mut sf);
            b.search_batch_rows_into(&block, &mut tm, &mut tf);
            assert_eq!(sm, tm, "{noise:?}: shared-stream counts");
            assert_eq!(a.events, b.events, "{noise:?}: shared-stream events");
        }
    }

    #[test]
    fn threshold_cache_invalidated_by_writes_and_retunes() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        let stored = BitVec::ones(512);
        cam.write_row(0, &stored);
        cam.set_voltages(Voltages::exact());
        assert!(cam.search(&stored)[0], "exact match fires");
        // reprogram the row after a search has built the cache: the stale
        // m_max must not leak into the next decision
        let mut other = BitVec::ones(512);
        other.set(0, false);
        cam.write_row(0, &other);
        assert!(!cam.search(&stored)[0], "stale cache after write_row");
        assert!(cam.search(&other)[0]);
        // retune to a tolerant point: the same query now fires
        let mut v8 = None;
        for vref in [0.7, 0.8, 0.9] {
            for veval in [0.4, 0.6] {
                let v = Voltages::new(vref, veval, 1.0);
                if MatchlineModel::new(512, Pvt::nominal()).hd_tolerance(&v) > 4.0 {
                    v8 = Some(v);
                }
            }
        }
        cam.set_voltages(v8.expect("a tolerant grid point"));
        assert!(cam.search(&stored)[0], "stale cache after set_voltages");
        // clearing the row silences it without touching other rows
        cam.write_row(1, &stored);
        cam.clear_row(0);
        let fires = cam.search(&stored);
        assert!(!fires[0], "cleared row fired");
        assert!(fires[1]);
    }

    #[test]
    fn search_reuses_owned_scratch_without_reallocating() {
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        cam.write_row(0, &BitVec::ones(512));
        let q = BitVec::ones(512);
        let p1 = cam.search(&q).as_ptr();
        for _ in 0..50 {
            cam.search(&q);
        }
        let p2 = cam.search(&q).as_ptr();
        assert_eq!(p1, p2, "fires scratch reallocated");
    }

    #[test]
    fn batch_search_with_cleared_hole_matches_sequential() {
        // a non-contiguous validity pattern exercises the kernel's
        // row-major fallback path
        for noise in [NoiseMode::Nominal, NoiseMode::Analog] {
            let (mut seq, mut bat) = twin_arrays(noise, 17, 10);
            seq.clear_row(4);
            bat.clear_row(4);
            let mut rng = Rng::new(3, 9);
            let queries: Vec<BitVec> = (0..3).map(|_| rand_bits(512, &mut rng)).collect();
            let mut rngs_a: Vec<Rng> = (0..3).map(|i| Rng::new(41, i)).collect();
            let mut rngs_b = rngs_a.clone();
            let (mut sm, mut sf) = (Vec::new(), Vec::new());
            let mut seq_all = Vec::new();
            for (i, q) in queries.iter().enumerate() {
                seq.search_into_rng(q, &mut sm, &mut sf, &mut rngs_a[i]);
                seq_all.extend_from_slice(&sm);
            }
            let (mut bm, mut bf) = (Vec::new(), BitMatrix::default());
            bat.search_batch_into_rngs(&queries, &mut rngs_b, &mut bm, &mut bf);
            assert_eq!(bm, seq_all, "{noise:?}");
            assert!(bf.row_ones(0).all(|r| r != 4), "cleared row fired");
        }
    }

    #[test]
    fn area_near_paper() {
        let cam = CamArray::nominal(CamConfig::W512x256);
        let a = cam.area_mm2();
        assert!(a > 0.6 && a < 1.2, "{a} should be near the paper's 0.87 mm²");
    }
}
