//! Logical configurations of the 128-kbit PiC-BNN array (paper §III).
//!
//! The macro comprises four 32-kbit banks, each physically 64 rows × 512
//! columns.  Logical configurations tile the banks:
//!
//! * `512x256`  — banks stacked vertically: 256 rows of 512-bit words;
//! * `1024x128` — two banks ganged horizontally, two pairs stacked:
//!                128 rows of 1024-bit words;
//! * `2048x64`  — all four banks ganged horizontally: 64 rows of 2048 bits.
//!
//! Names follow the paper: `<word width>x<word count>`.

/// Physical bank geometry (fixed by the silicon).
pub const BANK_ROWS: usize = 64;
pub const BANK_COLS: usize = 512;
pub const N_BANKS: usize = 4;
/// Total capacity in bits (128 kbit).
pub const CAPACITY_BITS: usize = BANK_ROWS * BANK_COLS * N_BANKS;

/// A logical array configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CamConfig {
    /// 256 words × 512 bits.
    W512x256,
    /// 128 words × 1024 bits.
    W1024x128,
    /// 64 words × 2048 bits.
    W2048x64,
}

impl CamConfig {
    /// Word width in bits (cells per matchline).
    pub const fn width(self) -> usize {
        match self {
            CamConfig::W512x256 => 512,
            CamConfig::W1024x128 => 1024,
            CamConfig::W2048x64 => 2048,
        }
    }

    /// Number of logical rows (words).
    pub const fn rows(self) -> usize {
        match self {
            CamConfig::W512x256 => 256,
            CamConfig::W1024x128 => 128,
            CamConfig::W2048x64 => 64,
        }
    }

    /// Banks ganged per logical row.
    pub const fn banks_per_row(self) -> usize {
        self.width() / BANK_COLS
    }

    /// Parse a paper-style name ("1024x128").
    pub fn parse(s: &str) -> Option<CamConfig> {
        match s {
            "512x256" => Some(CamConfig::W512x256),
            "1024x128" => Some(CamConfig::W1024x128),
            "2048x64" => Some(CamConfig::W2048x64),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CamConfig::W512x256 => "512x256",
            CamConfig::W1024x128 => "1024x128",
            CamConfig::W2048x64 => "2048x64",
        }
    }

    /// Smallest configuration whose word width fits `bits`
    /// (mirrors `python/compile/model.py::pick_config`).
    pub fn fitting(bits: usize) -> Option<CamConfig> {
        [
            CamConfig::W512x256,
            CamConfig::W1024x128,
            CamConfig::W2048x64,
        ]
        .into_iter()
        .find(|c| bits <= c.width())
    }

    pub fn all() -> [CamConfig; 3] {
        [
            CamConfig::W512x256,
            CamConfig::W1024x128,
            CamConfig::W2048x64,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_is_128_kbit_in_every_config() {
        assert_eq!(CAPACITY_BITS, 131_072);
        for c in CamConfig::all() {
            assert_eq!(c.width() * c.rows(), CAPACITY_BITS, "{}", c.name());
        }
    }

    #[test]
    fn bank_tiling_consistent() {
        for c in CamConfig::all() {
            let banks_used = c.banks_per_row() * (c.rows() / BANK_ROWS).max(1);
            assert_eq!(banks_used, N_BANKS, "{}", c.name());
        }
    }

    #[test]
    fn parse_roundtrip() {
        for c in CamConfig::all() {
            assert_eq!(CamConfig::parse(c.name()), Some(c));
        }
        assert_eq!(CamConfig::parse("bogus"), None);
    }

    #[test]
    fn fitting_picks_smallest() {
        assert_eq!(CamConfig::fitting(512), Some(CamConfig::W512x256));
        assert_eq!(CamConfig::fitting(513), Some(CamConfig::W1024x128));
        assert_eq!(CamConfig::fitting(1024), Some(CamConfig::W1024x128));
        assert_eq!(CamConfig::fitting(2048), Some(CamConfig::W2048x64));
        assert_eq!(CamConfig::fitting(2049), None);
    }
}
