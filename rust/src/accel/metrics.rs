//! Accuracy metrics shared by the experiment benches: TOP-1 / TOP-2 with
//! the device's lowest-class-index tie-breaking.

use crate::bnn::infer::top_k;

/// TOP-1/TOP-2 accuracy over a labelled evaluation run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Accuracy {
    pub top1: f64,
    pub top2: f64,
    pub n: usize,
}

/// Compute accuracy from (votes, label) pairs.
pub fn evaluate(votes: &[Vec<u32>], labels: &[u8]) -> Accuracy {
    assert_eq!(votes.len(), labels.len());
    let mut hit1 = 0usize;
    let mut hit2 = 0usize;
    for (v, &y) in votes.iter().zip(labels) {
        let top = top_k(v, 2);
        if top.first() == Some(&(y as usize)) {
            hit1 += 1;
        }
        if top.contains(&(y as usize)) {
            hit2 += 1;
        }
    }
    let n = votes.len().max(1);
    Accuracy {
        top1: hit1 as f64 / n as f64,
        top2: hit2 as f64 / n as f64,
        n: votes.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_and_partial() {
        let votes = vec![vec![9, 1, 0], vec![1, 9, 0], vec![0, 9, 1]];
        let labels = vec![0u8, 1, 2];
        let acc = evaluate(&votes, &labels);
        assert!((acc.top1 - 2.0 / 3.0).abs() < 1e-12);
        assert!((acc.top2 - 3.0 / 3.0).abs() < 1e-12);
        assert_eq!(acc.n, 3);
    }

    #[test]
    fn tie_breaks_to_lowest_index() {
        // class 0 and 1 tie; device predicts 0
        let votes = vec![vec![5, 5]];
        assert_eq!(evaluate(&votes, &[0]).top1, 1.0);
        assert_eq!(evaluate(&votes, &[1]).top1, 0.0);
        assert_eq!(evaluate(&votes, &[1]).top2, 1.0);
    }

    #[test]
    fn empty_is_zero() {
        let acc = evaluate(&[], &[]);
        assert_eq!(acc.n, 0);
        assert_eq!(acc.top1, 0.0);
    }
}
