//! The Algorithm-1 inference pipeline over the simulated CAM — the paper's
//! L3 coordination contribution.
//!
//! Per batch of images (batching amortises weight loads *and* voltage
//! retunes, paper §V-B):
//!
//! 1. For each hidden layer: reconfigure the array to the layer's word
//!    width, program the rows load-by-load (a "load" is one segment's
//!    neuron chunk that fits the configured row count — the weight-reload
//!    scheduler for layers exceeding the 128-kbit capacity), set the
//!    midpoint-tolerance voltages once, and search every image's segment
//!    query; combine per-segment fires by majority into the hidden code.
//! 2. For the output layer: program the class rows, then sweep the
//!    HD-threshold schedule with thresholds in the *outer* loop — one
//!    voltage retune per threshold per batch — accumulating one vote per
//!    (image, class, threshold) where the class row fires.
//! 3. Prediction = arg max votes (lowest class index on ties).

use crate::analog::transistor::Pvt;
use crate::bnn::infer::argmax_vote;
use crate::bnn::mapping::{pack_segment_query, program_row};
use crate::bnn::model::MappedModel;
use crate::cam::{CamArray, CamConfig, NoiseMode};
use crate::sim::EventCounters;
use crate::util::bitops::{BitMatrix, BitVec};
use crate::util::rng::Rng;

use super::voltage::{CalibratedPoint, VoltageController};

/// Reusable per-batch scratch for the batched execution engines: flat,
/// stride-indexed buffers packed once per batch and reused across hidden
/// loads, output slots, and layers.  The hidden layer's codes become the
/// next layer's activation block by swapping `acts`/`next`, so the
/// steady-state batch path performs zero heap allocations once every
/// buffer has grown to its working shape (pointer-stability tests in
/// this module and `macro_pool`).
#[derive(Default)]
pub(crate) struct BatchScratch {
    /// Per-image noise streams (serving engines; the reload `Pipeline`
    /// draws from the array's own stream and leaves this empty).
    pub(crate) rngs: Vec<Rng>,
    /// Activations entering the current layer, one packed row per image.
    pub(crate) acts: BitMatrix,
    /// The current layer's output codes (swapped with `acts` per layer).
    pub(crate) next: BitMatrix,
    /// Query block for the current load / output sweep, one row per image.
    pub(crate) queries: BitMatrix,
    /// Flat `[image × n_out]` firing-segment counters (stride `n_out`).
    pub(crate) seg_fires: Vec<u8>,
    /// Flat `[image × n_classes]` vote accumulators (stride `n_classes`).
    pub(crate) votes: Vec<u32>,
    /// Mismatch counts from the batched search kernel.
    pub(crate) m: Vec<u32>,
    /// Packed fires bitmasks from the batched search kernel.
    pub(crate) fires: BitMatrix,
}

impl BatchScratch {
    /// Pack a batch of images as the activation block entering layer 0.
    pub(crate) fn pack_inputs(&mut self, images: &[BitVec], n_in: usize) {
        self.acts.reset(images.len(), n_in);
        for (i, img) in images.iter().enumerate() {
            assert_eq!(img.len(), n_in, "image width mismatch");
            self.acts.row_words_mut(i).copy_from_slice(img.words());
        }
    }

    /// Pack one segment query per activation row into the query block
    /// (bit-identical to building `segment_query_wide` per image).
    pub(crate) fn pack_queries(
        &mut self,
        layer: &crate::bnn::model::MappedLayer,
        seg: usize,
        width: usize,
    ) {
        let n = self.acts.rows();
        self.queries.reset(n, width);
        for i in 0..n {
            pack_segment_query(
                layer,
                seg,
                self.acts.row_words(i),
                self.queries.row_words_mut(i),
                width,
            );
        }
    }

    /// Fold the flat segment-fire counters into packed hidden codes in
    /// `next` (majority of segments, ties fire — MLSA convention).
    pub(crate) fn fold_majority(&mut self, n_out: usize, n_seg: usize) {
        let n = self.acts.rows();
        self.next.reset(n, n_out);
        for i in 0..n {
            let fires = &self.seg_fires[i * n_out..(i + 1) * n_out];
            for (j, &cnt) in fires.iter().enumerate() {
                if (cnt as usize) * 2 >= n_seg {
                    self.next.set(i, j, true);
                }
            }
        }
    }

    /// The per-image (votes, prediction) result vector (the only
    /// allocations of a steady-state batch — they are the return value).
    pub(crate) fn results(&self, n_cls: usize) -> Vec<(Vec<u32>, usize)> {
        self.votes
            .chunks(n_cls)
            .map(|v| {
                let v = v.to_vec();
                let p = argmax_vote(&v);
                (v, p)
            })
            .collect()
    }
}

/// Pipeline construction options.
#[derive(Clone, Copy, Debug)]
pub struct PipelineOptions {
    pub noise: NoiseMode,
    pub pvt: Pvt,
    pub seed: u64,
    /// Use only the first k schedule entries (Fig. 5 x-axis); None = all.
    pub schedule_prefix: Option<usize>,
    /// Per-evaluation noise multiplier (ablations; 1.0 = shipped device).
    pub noise_scale: f64,
}

impl Default for PipelineOptions {
    fn default() -> Self {
        PipelineOptions {
            noise: NoiseMode::Analog,
            pvt: Pvt::nominal(),
            seed: 0xB11A,
            schedule_prefix: None,
            noise_scale: 1.0,
        }
    }
}

/// One weight load: a contiguous chunk of neurons of one segment.
#[derive(Clone, Debug)]
pub(crate) struct Load {
    pub(crate) seg: usize,
    pub(crate) neuron_lo: usize,
    pub(crate) neuron_hi: usize,
}

/// Extend a row/query image to the configured word width: spare columns
/// store '1' and are driven with '1', so they always match and contribute
/// nothing to the mismatch count (how the silicon handles words narrower
/// than the configured width).
pub(crate) fn fit_width(v: &BitVec, width: usize) -> BitVec {
    if v.len() == width {
        return v.clone();
    }
    debug_assert!(v.len() < width);
    let mut out = BitVec::ones(width);
    for i in 0..v.len() {
        if !v.get(i) {
            out.set(i, false);
        }
    }
    out
}

/// Midpoint operating point per non-output layer (calibrated against the
/// *physical* word width the layer runs at; see `Pipeline::new`).
pub(crate) fn calibrate_hidden_points(model: &MappedModel, pvt: Pvt) -> Vec<CalibratedPoint> {
    model.layers[..model.layers.len() - 1]
        .iter()
        .map(|l| {
            let cfg = CamConfig::fitting(l.seg_width)
                .unwrap_or_else(|| panic!("word width {} unsupported", l.seg_width));
            let ctl = VoltageController::new(cfg.width(), pvt);
            let target = (l.seg_width / 2) as u32;
            ctl.calibrate(target, 2.0)
                .or_else(|| ctl.calibrate(target, 4.0))
                .unwrap_or_else(|| ctl.calibrate_best(target))
        })
        .collect()
}

/// The active schedule under `opts` (possibly a prefix of the model's).
pub(crate) fn resolve_schedule(model: &MappedModel, opts: &PipelineOptions) -> Vec<i32> {
    match opts.schedule_prefix {
        Some(k) => model.schedule.iter().copied().take(k).collect(),
        None => model.schedule.clone(),
    }
}

/// Operating point per schedule threshold at the output word width.
pub(crate) fn calibrate_output_points(
    model: &MappedModel,
    schedule: &[i32],
    pvt: Pvt,
) -> Vec<CalibratedPoint> {
    let out_layer = model.layers.last().expect("model has layers");
    let out_cfg = CamConfig::fitting(out_layer.seg_width).expect("output word width unsupported");
    let ctl_out = VoltageController::new(out_cfg.width(), pvt);
    ctl_out.calibrate_schedule(&schedule.iter().map(|&t| t.max(0) as u32).collect::<Vec<_>>())
}

/// Per-layer load plans: each load is one segment's neuron chunk that fits
/// the configured row count (the weight-reload scheduler's unit).
pub(crate) fn plan_loads(model: &MappedModel) -> Vec<Vec<Load>> {
    model
        .layers
        .iter()
        .map(|l| {
            let cfg = CamConfig::fitting(l.seg_width)
                .unwrap_or_else(|| panic!("word width {} unsupported", l.seg_width));
            let rows = cfg.rows();
            let mut loads = Vec::new();
            for seg in 0..l.n_seg() {
                let mut lo = 0;
                while lo < l.n_out() {
                    let hi = (lo + rows).min(l.n_out());
                    loads.push(Load {
                        seg,
                        neuron_lo: lo,
                        neuron_hi: hi,
                    });
                    lo = hi;
                }
            }
            loads
        })
        .collect()
}

/// Host-device I/O cycles per image (128-bit bus, paper SoC): input
/// vector in, hidden activations out+in (through the control CPU), and
/// the per-execution MLSA fire words out.  Shared by the single-macro
/// `Pipeline` and the `MacroPool` (same bus either way).
pub(crate) fn io_cycles_per_image(model: &MappedModel, schedule_len: usize) -> u64 {
    let bus = crate::analog::constants::IO_BUS_BITS;
    let n_in = model.n_in().div_ceil(bus) as u64;
    let hidden: u64 = model.layers[..model.layers.len() - 1]
        .iter()
        .map(|l| 2 * l.n_out().div_ceil(bus) as u64) // readout + reload
        .sum();
    let votes_bits = model.n_classes() * schedule_len;
    n_in + hidden + votes_bits.div_ceil(bus) as u64
}

/// Program one load's rows into `cam` (reconfiguring the array if its
/// geometry doesn't match the layer's word width), invalidating stale rows
/// beyond the load.  Shared by the reload `Pipeline` (per batch) and the
/// resident `MacroPool` (once at construction).
pub(crate) fn program_load_into(
    cam: &mut CamArray,
    layer: &crate::bnn::model::MappedLayer,
    load: &Load,
) {
    let cfg = CamConfig::fitting(layer.seg_width)
        .unwrap_or_else(|| panic!("word width {} unsupported", layer.seg_width));
    if cam.config() != cfg {
        cam.reconfigure(cfg);
    }
    let width = cfg.width();
    for (row, neuron) in (load.neuron_lo..load.neuron_hi).enumerate() {
        let image = fit_width(&program_row(layer, load.seg, neuron), width);
        cam.write_row(row, &image);
    }
    for row in (load.neuron_hi - load.neuron_lo)..cfg.rows() {
        cam.clear_row(row);
    }
}

/// Device-accurate inference engine for one mapped model.
pub struct Pipeline<'m> {
    model: &'m MappedModel,
    cam: CamArray,
    opts: PipelineOptions,
    /// Midpoint operating point per non-output layer.
    hidden_points: Vec<CalibratedPoint>,
    /// Operating point per schedule threshold (output word width).
    output_points: Vec<CalibratedPoint>,
    /// Active schedule (possibly a prefix of the model's).
    schedule: Vec<i32>,
    /// Per-layer load plans.
    plans: Vec<Vec<Load>>,
    /// Which layer's weights are currently resident (load caching).
    resident: Option<(usize, usize)>, // (layer, load index)
    /// Per-batch scratch arena (the batched search and the flat
    /// activation/query/vote buffers reshape in place; the steady-state
    /// batch path allocates nothing beyond the returned votes).
    scratch: BatchScratch,
    // per-category retune/programming attribution (drained by take_stats)
    attr_hidden: CategoryCost,
    attr_output: CategoryCost,
}

/// Where a retune or programming event was spent: hidden-layer loads vs
/// the output threshold sweep.  The placement planner trades exactly these
/// two costs against each other, so reports keep them separate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CategoryCost {
    /// DAC retune events attributed to the category.
    pub retunes: u64,
    /// Weight-programming row writes attributed to the category.
    pub row_writes: u64,
}

impl CategoryCost {
    pub fn add(&mut self, other: &CategoryCost) {
        self.retunes += other.retunes;
        self.row_writes += other.row_writes;
    }
}

/// Accumulated device statistics for a run.
#[derive(Clone, Copy, Debug, Default)]
pub struct RunStats {
    pub inferences: u64,
    pub cycles: u64,
    pub stall_s: f64,
    pub events: EventCounters,
    /// Retune/programming cost attributed to hidden-layer loads.
    pub hidden_cost: CategoryCost,
    /// Retune/programming cost attributed to the output threshold sweep.
    pub output_cost: CategoryCost,
    /// Simulated macros that accrued these stats: 1 for the single-macro
    /// `Pipeline`, the resident macro count for a `MacroPool`, summed
    /// across shards/tenants when reports are merged.  The energy model
    /// multiplies the per-macro leakage power by this count
    /// (`energy::report`); 0 (an empty/default report) is treated as 1.
    pub macros: usize,
    /// Health of the pool that produced this report (always
    /// [`crate::cam::faults::DegradedMode::Nominal`] for the reload
    /// `Pipeline`; a self-healing `MacroPool` stamps its current ladder
    /// rung so degradation is visible wherever stats flow).
    pub degraded: crate::cam::faults::DegradedMode,
}

impl RunStats {
    pub fn elapsed_s(&self) -> f64 {
        self.cycles as f64 / crate::analog::constants::F_CLK + self.stall_s
    }

    pub fn inferences_per_s(&self) -> f64 {
        self.inferences as f64 / self.elapsed_s()
    }

    pub fn cycles_per_inference(&self) -> f64 {
        self.cycles as f64 / self.inferences.max(1) as f64
    }

    /// Device cycles spent programming weight rows (one per row write).
    /// Zero at steady state on a resident [`super::MacroPool`]; the
    /// reload scheduler pays it on every batch.
    pub fn programming_cycles(&self) -> u64 {
        self.events.row_writes
    }
}

impl<'m> Pipeline<'m> {
    pub fn new(model: &'m MappedModel, opts: PipelineOptions) -> Self {
        let out_layer = model.layers.last().expect("model has layers");
        assert_eq!(out_layer.n_seg(), 1, "output layer must fit one CAM word");
        // calibrate hidden midpoints + the output threshold schedule once
        // NOTE: tolerances are calibrated against the *physical* word width
        // of the configuration the layer runs at (C_ML scales with the full
        // row), while thresholds stay in logical mismatch counts — padded
        // spare columns always match and never discharge.
        let hidden_points = calibrate_hidden_points(model, opts.pvt);
        let schedule = resolve_schedule(model, &opts);
        let output_points = calibrate_output_points(model, &schedule, opts.pvt);
        // load plans per layer
        let plans = plan_loads(model);
        let first_cfg = CamConfig::fitting(model.layers[0].seg_width)
            .unwrap_or_else(|| panic!("word width {} unsupported", model.layers[0].seg_width));
        let mut cam = CamArray::new(first_cfg, opts.pvt, opts.noise, opts.seed);
        cam.set_noise_scale(opts.noise_scale);
        Pipeline {
            model,
            cam,
            opts,
            hidden_points,
            output_points,
            schedule,
            plans,
            resident: None,
            scratch: BatchScratch::default(),
            attr_hidden: CategoryCost::default(),
            attr_output: CategoryCost::default(),
        }
    }

    pub fn schedule(&self) -> &[i32] {
        &self.schedule
    }

    pub fn cam(&self) -> &CamArray {
        &self.cam
    }

    /// Program one load's rows (reconfiguring the array if needed).
    fn program_load(&mut self, layer_idx: usize, load_idx: usize) {
        if self.resident == Some((layer_idx, load_idx)) {
            return;
        }
        let layer = &self.model.layers[layer_idx];
        let load = &self.plans[layer_idx][load_idx];
        program_load_into(&mut self.cam, layer, load);
        self.resident = Some((layer_idx, load_idx));
    }

    /// Retune/row-write totals on the single macro (attribution snapshot).
    fn cost_snapshot(&self) -> (u64, u64) {
        (self.cam.events.retunes, self.cam.events.row_writes)
    }

    /// Execute one hidden layer for a batch held in `s.acts`; leaves the
    /// packed hidden codes in `s.next`.
    fn run_hidden(&mut self, layer_idx: usize, s: &mut BatchScratch) {
        let before = self.cost_snapshot();
        let model = self.model;
        let layer = &model.layers[layer_idx];
        let n = s.acts.rows();
        let n_out = layer.n_out();
        let n_seg = layer.n_seg();
        // seg_fires[image * n_out + neuron] counts firing segments
        s.seg_fires.clear();
        s.seg_fires.resize(n * n_out, 0);
        let n_loads = self.plans[layer_idx].len();
        for load_idx in 0..n_loads {
            self.program_load(layer_idx, load_idx);
            let point = self.hidden_points[layer_idx];
            self.cam.set_voltages(point.voltages);
            let load = self.plans[layer_idx][load_idx].clone();
            let width = self.cam.config().width();
            let payload = (load.neuron_hi - load.neuron_lo) as u64
                * (layer.seg_bounds[load.seg + 1] - layer.seg_bounds[load.seg]) as u64;
            // one batched search per load: the store streams once per
            // query tile instead of once per image (util::bitops docs);
            // the query block is repacked in place, never reallocated
            s.pack_queries(layer, load.seg, width);
            self.cam.search_batch_rows_into(&s.queries, &mut s.m, &mut s.fires);
            self.cam.events.useful_macs += payload * n as u64;
            for i in 0..n {
                // rows past the load are cleared and can never fire
                let base = i * n_out + load.neuron_lo;
                for row in s.fires.row_ones(i) {
                    s.seg_fires[base + row] += 1;
                }
            }
        }
        s.fold_majority(n_out, n_seg);
        let after = self.cost_snapshot();
        self.attr_hidden.retunes += after.0 - before.0;
        self.attr_hidden.row_writes += after.1 - before.1;
    }

    /// Execute the output layer sweep for the batch whose hidden codes
    /// sit in `s.acts`; leaves the flat votes in `s.votes`.
    fn run_output(&mut self, s: &mut BatchScratch) {
        let before = self.cost_snapshot();
        let model = self.model;
        let layer_idx = model.layers.len() - 1;
        let layer = model.layers.last().expect("model has layers");
        let n_cls = layer.n_out();
        assert_eq!(
            self.plans[layer_idx].len(),
            1,
            "output layer fits one load"
        );
        self.program_load(layer_idx, 0);
        // queries are threshold-independent: pack once per batch
        let width = self.cam.config().width();
        let n = s.acts.rows();
        s.pack_queries(layer, 0, width);
        s.votes.clear();
        s.votes.resize(n * n_cls, 0);
        // thresholds outer, images inner: one retune per threshold per
        // batch, and one batched search per threshold
        let payload = (layer.n_in() * n_cls) as u64;
        for k in 0..self.schedule.len() {
            let point = self.output_points[k];
            self.cam.set_voltages(point.voltages);
            self.cam.search_batch_rows_into(&s.queries, &mut s.m, &mut s.fires);
            self.cam.events.useful_macs += payload * n as u64;
            for i in 0..n {
                let base = i * n_cls;
                for c in s.fires.row_ones(i) {
                    s.votes[base + c] += 1;
                }
            }
        }
        let after = self.cost_snapshot();
        self.attr_output.retunes += after.0 - before.0;
        self.attr_output.row_writes += after.1 - before.1;
    }

    /// Host-device I/O cycles per image (see [`io_cycles_per_image`]).
    fn io_cycles_per_image(&self) -> u64 {
        io_cycles_per_image(self.model, self.schedule.len())
    }

    /// Classify a batch: returns (votes, prediction) per image.
    pub fn classify_batch(&mut self, images: &[BitVec]) -> Vec<(Vec<u32>, usize)> {
        // the scratch arena moves out for the duration of the batch (it
        // is Default-empty to take, so taking allocates nothing)
        let mut s = std::mem::take(&mut self.scratch);
        s.pack_inputs(images, self.model.layers[0].n_in());
        for layer_idx in 0..self.model.layers.len() - 1 {
            self.run_hidden(layer_idx, &mut s);
            // the hidden codes become the next layer's activation block
            std::mem::swap(&mut s.acts, &mut s.next);
        }
        self.run_output(&mut s);
        // host I/O shares the device clock domain (RISC-V at the same 25 MHz)
        self.cam
            .clock
            .tick(self.io_cycles_per_image() * images.len() as u64);
        let out = s.results(self.model.n_classes());
        self.scratch = s;
        out
    }

    /// Classify one image (single-image batch; no amortisation).
    pub fn classify(&mut self, image: &BitVec) -> usize {
        self.classify_batch(std::slice::from_ref(image))[0].1
    }

    /// Drain device statistics accumulated since the last call.
    pub fn take_stats(&mut self, inferences: u64) -> RunStats {
        let stats = RunStats {
            inferences,
            cycles: self.cam.clock.cycles,
            stall_s: self.cam.clock.stall_s,
            events: self.cam.events,
            hidden_cost: self.attr_hidden,
            output_cost: self.attr_output,
            macros: 1,
        };
        self.cam.reset_accounting();
        self.attr_hidden = CategoryCost::default();
        self.attr_output = CategoryCost::default();
        stats
    }

    /// The options this pipeline was built with.
    pub fn options(&self) -> &PipelineOptions {
        &self.opts
    }

    /// Calibrated output operating points (diagnostics / Table I bench).
    pub fn output_points(&self) -> &[CalibratedPoint] {
        &self.output_points
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::infer::digital_forward;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::util::rng::Rng;

    fn rand_images(n: usize, bits: usize, seed: u64) -> Vec<BitVec> {
        let mut rng = Rng::new(seed, 1);
        (0..n)
            .map(|_| {
                let mut v = BitVec::zeros(bits);
                for i in 0..bits {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect()
    }

    #[test]
    fn nominal_pipeline_matches_digital_reference() {
        let model = tiny_model(100, 16, 4, 42);
        let mut pipe = Pipeline::new(
            &model,
            PipelineOptions {
                noise: NoiseMode::Nominal,
                ..Default::default()
            },
        );
        let images = rand_images(12, 100, 7);
        let got = pipe.classify_batch(&images);
        for (img, (votes, pred)) in images.iter().zip(&got) {
            let (want_votes, want_pred) = digital_forward(&model, img, pipe.schedule());
            assert_eq!(votes, &want_votes, "votes for image");
            assert_eq!(pred, &want_pred);
        }
    }

    #[test]
    fn schedule_prefix_truncates() {
        let model = tiny_model(64, 8, 3, 1);
        let pipe = Pipeline::new(
            &model,
            PipelineOptions {
                noise: NoiseMode::Nominal,
                schedule_prefix: Some(5),
                ..Default::default()
            },
        );
        assert_eq!(pipe.schedule(), &model.schedule[..5]);
    }

    #[test]
    fn stats_accumulate_and_drain() {
        let model = tiny_model(64, 8, 3, 2);
        let mut pipe = Pipeline::new(
            &model,
            PipelineOptions {
                noise: NoiseMode::Nominal,
                ..Default::default()
            },
        );
        let images = rand_images(4, 64, 3);
        pipe.classify_batch(&images);
        let s = pipe.take_stats(4);
        assert!(s.cycles > 0);
        assert!(s.events.searches > 0);
        assert!(s.inferences_per_s() > 0.0);
        // drained: second take sees zero cycles
        let s2 = pipe.take_stats(0);
        assert_eq!(s2.cycles, 0);
    }

    #[test]
    fn steady_state_batches_reuse_scratch_without_reallocating() {
        // the allocation-free contract at the reload engine: after the
        // first batch has grown every scratch buffer to its working
        // shape, further same-shaped batches keep the exact allocations
        // (acts/next swap roles per layer, so compare them as a pair)
        let model = tiny_model(100, 16, 4, 42);
        let mut pipe = Pipeline::new(
            &model,
            PipelineOptions {
                noise: NoiseMode::Nominal,
                ..Default::default()
            },
        );
        let images = rand_images(12, 100, 7);
        pipe.classify_batch(&images); // warmup
        let grab = |p: &Pipeline| {
            let s = &p.scratch;
            let mut acts_pair = [
                s.acts.words().as_ptr() as usize,
                s.next.words().as_ptr() as usize,
            ];
            acts_pair.sort_unstable();
            (
                acts_pair,
                s.queries.words().as_ptr() as usize,
                s.seg_fires.as_ptr() as usize,
                s.votes.as_ptr() as usize,
                s.m.as_ptr() as usize,
                s.fires.words().as_ptr() as usize,
            )
        };
        let before = grab(&pipe);
        for _ in 0..3 {
            pipe.classify_batch(&images);
        }
        assert_eq!(grab(&pipe), before, "steady-state batch reallocated scratch");
    }

    #[test]
    fn batching_reduces_cycles_per_inference() {
        let model = tiny_model(64, 8, 3, 5);
        let images = rand_images(32, 64, 9);
        let run = |batch: usize| {
            let mut pipe = Pipeline::new(
                &model,
                PipelineOptions {
                    noise: NoiseMode::Nominal,
                    ..Default::default()
                },
            );
            for chunk in images.chunks(batch) {
                pipe.classify_batch(chunk);
            }
            pipe.take_stats(images.len() as u64).cycles_per_inference()
        };
        let cpi_1 = run(1);
        let cpi_32 = run(32);
        assert!(
            cpi_32 < cpi_1,
            "batching should amortise programming: {cpi_32} vs {cpi_1}"
        );
    }

    #[test]
    fn stats_attribute_costs_per_category() {
        // the reload scheduler pays hidden programming every batch and one
        // output retune per threshold per batch; the two categories must
        // partition the totals exactly
        let model = tiny_model(64, 8, 3, 6);
        let mut pipe = Pipeline::new(
            &model,
            PipelineOptions {
                noise: NoiseMode::Nominal,
                ..Default::default()
            },
        );
        let images = rand_images(8, 64, 21);
        pipe.classify_batch(&images);
        pipe.classify_batch(&images);
        let s = pipe.take_stats(16);
        assert_eq!(
            s.hidden_cost.retunes + s.output_cost.retunes,
            s.events.retunes
        );
        assert_eq!(
            s.hidden_cost.row_writes + s.output_cost.row_writes,
            s.events.row_writes
        );
        assert!(s.hidden_cost.row_writes > 0, "hidden reprograms per batch");
        assert!(s.output_cost.retunes > 0, "threshold sweep retunes");
        // attribution drains with the stats
        let s2 = pipe.take_stats(0);
        assert_eq!(s2.hidden_cost, CategoryCost::default());
        assert_eq!(s2.output_cost, CategoryCost::default());
    }

    #[test]
    fn analog_noise_changes_votes_but_rarely_flips_easy_predictions() {
        // an easy instance: image equals one neuron's weights strongly
        let model = tiny_model(100, 16, 4, 11);
        let images = rand_images(8, 100, 13);
        let mut nominal = Pipeline::new(
            &model,
            PipelineOptions {
                noise: NoiseMode::Nominal,
                ..Default::default()
            },
        );
        let mut analog = Pipeline::new(
            &model,
            PipelineOptions {
                noise: NoiseMode::Analog,
                seed: 77,
                ..Default::default()
            },
        );
        let a = nominal.classify_batch(&images);
        let b = analog.classify_batch(&images);
        // votes may differ, but the structures agree in shape
        assert_eq!(a.len(), b.len());
        for ((va, _), (vb, _)) in a.iter().zip(&b) {
            assert_eq!(va.len(), vb.len());
        }
    }
}
