//! Fleet-wide maintenance supervision: one shared budget, every tenant
//! healthy.
//!
//! A [`super::MultiPool`] engine runs one scrub controller and one
//! re-planning controller *per lane*, each sized as if it owned the
//! maintenance gap alone.  That breaks down exactly when maintenance
//! matters most: a fault-heavy tenant's scrub pass detects, repairs,
//! rebuilds, and migrates every gap, and with per-lane constants the
//! total maintenance work per gap scales with how unlucky the fleet is —
//! while each healthy sibling still pays its own full scrub quantum on
//! silicon that needed none of it.  The [`FleetMaintenance`] supervisor
//! inverts the contract: the *fleet* owns one row budget per gap
//! ([`FleetConfig::rows_per_gap`]) and meters it across lanes by deficit
//! round-robin:
//!
//! 1. **Quantum.** Each gap credits every lane `rows_per_gap / n_lanes`
//!    scrub rows (at least one).  Unspent credit banks up to
//!    [`FleetConfig::carry_cap`] rows, so a lane whose turn was consumed
//!    by a whole-turn action (a post-quarantine migration step) catches
//!    its cursor up in later gaps instead of losing the work forever.
//!
//! 2. **Isolation.** A lane's detections, rebuilds, and migrations spend
//!    only that lane's credit.  The fairness property this buys — and
//!    the reason the supervisor exists — is that one fault-heavy tenant
//!    cannot starve a sibling's scrub cursor: every lane's cursor
//!    completes laps within a bounded gap of every other's
//!    (property-tested in `tests/faults.rs` over random tenant mixes).
//!
//! 3. **Rotation.** The first-served lane rotates every gap, so quantum
//!    remainders and turn order never systematically favor lane 0.
//!
//! 4. **Determinism.** Lane controllers get [`splitmix64`]-derived seeds
//!    from one base seed, and the round-robin state is plain counters:
//!    a fleet drill replays bit-exactly from (seed, fault plans, trace).
//!
//! The serving engine attaches one supervisor per [`super::MultiPool`]
//! via `Engine::with_fleet_maintenance` and calls [`FleetMaintenance::maintain`]
//! once per inter-batch gap, in place of per-lane scrub/replan tasks.

use crate::util::rng::splitmix64;

use super::macro_pool::MultiPool;
use super::replan::{ReplanConfig, ReplanController};
use super::scrub::{ScrubConfig, ScrubController, ScrubStats};

/// Tuning for the shared maintenance budget (role of each knob in the
/// module docs).
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// Scrub-row budget per maintenance gap, shared across all lanes.
    pub rows_per_gap: usize,
    /// Most unspent credit a lane may bank across gaps [rows].
    pub carry_cap: usize,
    /// Per-lane scrub tuning.  `rows_per_turn` is superseded by the
    /// round-robin quantum; the ladder knobs (drift tolerance, rebuild
    /// strikes, re-plan workers) apply per lane unchanged.
    pub scrub: ScrubConfig,
    /// Attach a re-planning controller to every resident lane
    /// (`None` = scrub-and-repair only).
    pub replan: Option<ReplanConfig>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            rows_per_gap: 8,
            carry_cap: 32,
            scrub: ScrubConfig::default(),
            replan: None,
        }
    }
}

/// One tenant's maintenance machinery plus its deficit counter.
struct FleetLane {
    scrub: ScrubController,
    replan: Option<ReplanController>,
    /// Banked scrub credit [rows] (deficit round-robin state).
    deficit: usize,
}

/// Deficit-round-robin maintenance supervisor for one [`MultiPool`]
/// (module docs).  Owns every lane's scrub and re-plan controller.
pub struct FleetMaintenance {
    cfg: FleetConfig,
    lanes: Vec<FleetLane>,
    /// Lane served first this gap (rotates).
    next: usize,
}

impl FleetMaintenance {
    /// One scrub controller per lane (seeds derived from `seed` by lane
    /// index, so drills replay bit-exactly), plus a re-planning
    /// controller per resident lane when the config asks for one —
    /// budgeted at the lane's live plan, matching `Engine::with_replan`.
    pub fn new(pool: &MultiPool<'_>, seed: u64, cfg: FleetConfig) -> Self {
        assert!(cfg.rows_per_gap >= 1, "the fleet budget must make progress");
        let lanes = (0..pool.n_tenants())
            .map(|t| {
                let mut s = seed ^ (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                let lane_seed = splitmix64(&mut s);
                let tenant = pool.tenant(t);
                let replan = cfg.replan.and_then(|rc| {
                    tenant
                        .plan()
                        .map(|p| ReplanController::new(tenant, p.macros_used(), rc))
                });
                FleetLane {
                    scrub: ScrubController::new(lane_seed, cfg.scrub),
                    replan,
                    deficit: 0,
                }
            })
            .collect();
        FleetMaintenance {
            cfg,
            lanes,
            next: 0,
        }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// One shared maintenance gap: serve every lane once in rotating
    /// order, each spending at most its banked credit on scrub rows
    /// (whole-turn actions — a post-quarantine migration step — charge
    /// one quantum), then give each lane's re-planning controller its
    /// turn (at most one migration step per lane per gap, by the
    /// controller's own contract).  Returns this gap's per-lane scrub
    /// deltas for the engine's metrics.
    pub fn maintain(&mut self, pool: &MultiPool<'_>) -> Vec<ScrubStats> {
        let n = self.lanes.len();
        let mut deltas = vec![ScrubStats::default(); n];
        if n == 0 {
            return deltas;
        }
        let quantum = (self.cfg.rows_per_gap / n).max(1);
        let cap = self.cfg.carry_cap.max(quantum);
        for i in 0..n {
            let t = (self.next + i) % n;
            let lane = &mut self.lanes[t];
            lane.deficit = (lane.deficit + quantum).min(cap);
            let d = lane.scrub.maintain_budgeted(pool.tenant(t), lane.deficit);
            let spent = if d.rows_scrubbed > 0 {
                d.rows_scrubbed as usize
            } else {
                // a whole-turn action (or an idle reload lane) consumed
                // this lane's slot: charge the quantum so banked credit
                // reflects cursor progress, not turn count
                quantum
            };
            lane.deficit = lane.deficit.saturating_sub(spent);
            deltas[t] = d;
        }
        for (t, lane) in self.lanes.iter_mut().enumerate() {
            if let Some(rc) = lane.replan.as_mut() {
                rc.maintain(pool.tenant(t));
            }
        }
        self.next = (self.next + 1) % n;
        deltas
    }

    /// Lane `t`'s scrub controller (mode, cumulative stats, reports).
    pub fn lane_scrub(&self, t: usize) -> &ScrubController {
        &self.lanes[t].scrub
    }

    /// Mutable access for draining a lane's fault reports.
    pub fn lane_scrub_mut(&mut self, t: usize) -> &mut ScrubController {
        &mut self.lanes[t].scrub
    }

    /// Lane `t`'s re-planning controller, when one is attached.
    pub fn lane_replan(&self, t: usize) -> Option<&ReplanController> {
        self.lanes[t].replan.as_ref()
    }

    /// Full scrub-cursor laps lane `t` has completed — the fairness
    /// observable: under any tenant mix, `max_laps - min_laps` across
    /// resident lanes stays bounded.
    pub fn lane_laps(&self, t: usize) -> u64 {
        self.lanes[t].scrub.laps_completed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::pipeline::PipelineOptions;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::cam::NoiseMode;

    fn nominal() -> PipelineOptions {
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        }
    }

    #[test]
    fn healthy_fleet_laps_every_lane() {
        let a = tiny_model(64, 8, 3, 44);
        let b = tiny_model(64, 8, 3, 45);
        let models = [&a, &b];
        let pool = MultiPool::new(&models, nominal(), 8);
        let mut fleet = FleetMaintenance::new(&pool, 11, FleetConfig::default());
        for _ in 0..4096 {
            fleet.maintain(&pool);
        }
        for t in 0..pool.n_tenants() {
            assert!(
                fleet.lane_laps(t) >= 1,
                "lane {t} never lapped: the shared budget starved it"
            );
            assert_eq!(fleet.lane_scrub(t).stats().faults_detected, 0);
        }
    }

    #[test]
    fn rotation_and_deficit_replay_bit_exactly() {
        let a = tiny_model(64, 8, 3, 44);
        let b = tiny_model(64, 8, 3, 45);
        let models = [&a, &b];
        let run = |seed| {
            let pool = MultiPool::new(&models, nominal(), 8);
            let mut fleet = FleetMaintenance::new(&pool, seed, FleetConfig::default());
            let mut total = ScrubStats::default();
            for _ in 0..512 {
                for d in fleet.maintain(&pool) {
                    total.add(&d);
                }
            }
            (total, fleet.lane_laps(0), fleet.lane_laps(1))
        };
        assert_eq!(run(11), run(11));
    }
}
