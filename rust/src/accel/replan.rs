//! Online re-planning control loop: placement follows the workload.
//!
//! A [`super::MacroPool`] plans its placement once, from whatever traffic
//! histogram it was built with.  When the live skew drifts — a different
//! band of output thresholds turns hot — the frozen pinned set keeps
//! paying funnel retunes for positions that no longer deserve them.  The
//! [`ReplanController`] closes the loop:
//!
//! 1. **Period.** Every [`ReplanConfig::period`] calls to
//!    [`ReplanController::maintain`] (the serving engine calls it once
//!    per inter-batch maintenance gap), the controller drains
//!    [`super::MacroPool::take_output_traffic`] and re-plans.  Between
//!    periods it only applies at most one step of an in-flight
//!    migration, so no serving gap ever waits on more than one step.
//!
//! 2. **EWMA decay.** The drained delta folds into a running histogram
//!    as `h ← decay·h + delta` with `decay ∈ [0, 1)`
//!    ([`ReplanConfig::decay`]).  Decay keeps enough history to ride out
//!    a quiet period (an all-zero delta leaves the shape intact) while
//!    letting a genuine skew flip dominate within a few periods.
//!
//! 3. **Hysteresis.** A candidate plan replaces the incumbent only when
//!    its predicted retunes/batch undercut the incumbent's — both priced
//!    under the *same* decayed histogram — by at least
//!    [`ReplanConfig::min_improvement`] (a fraction).  Oscillating skew
//!    that flips faster than the improvement threshold never thrashes
//!    the placement back and forth.
//!
//! 4. **Cost horizon.** Even an improving migration only executes when
//!    its one-shot programming cycles are repaid by predicted savings
//!    within [`ReplanConfig::horizon_batches`]
//!    ([`super::planner::MigrationPlan::pays_off`]).  The controller
//!    never applies a step of a plan whose modeled cost exceeds its
//!    horizon savings — rejected plans are dropped whole, not partially
//!    applied.
//!
//! Migrations execute incrementally: one
//! [`super::MacroPool::apply_migration_step`] per `maintain` call, in
//! the gaps between batches, so the pool keeps serving bit-stably while
//! it converges (the identical-seeding rule makes every intermediate
//! placement's predictions equal the static pool's).

use super::macro_pool::{MacroPool, MigrationStats};
use super::planner::{self, MigrationPlan};

/// Tuning for the re-planning control loop (see the module docs for the
/// role each knob plays).
#[derive(Clone, Copy, Debug)]
pub struct ReplanConfig {
    /// Maintenance calls between re-plans (each call applies at most one
    /// migration step regardless).  Must be ≥ 1.
    pub period: u64,
    /// EWMA retention of the traffic histogram per period, in `[0, 1)`:
    /// `0.0` = only the latest delta counts, `0.75` = a few periods of
    /// memory.
    pub decay: f64,
    /// Minimum fractional retunes/batch improvement before a candidate
    /// plan is even considered (hysteresis against thrash): `0.25`
    /// demands the candidate undercut the incumbent by a quarter.
    pub min_improvement: f64,
    /// Batches over which a migration's programming cycles must be
    /// repaid by its predicted per-batch savings.
    pub horizon_batches: u64,
    /// Device cycles one avoided retune is worth (a retune stalls the
    /// DAC settle time; at the 25 MHz device clock the settle dwarfs a
    /// row write, so this is typically ≫ 1).
    pub cycles_per_retune: u64,
    /// Worker count handed to the planner (replica cap), matching how
    /// the pool was built.
    pub workers: usize,
}

impl Default for ReplanConfig {
    fn default() -> Self {
        ReplanConfig {
            period: 8,
            decay: 0.5,
            min_improvement: 0.2,
            horizon_batches: 64,
            cycles_per_retune: 100,
            workers: 1,
        }
    }
}

/// Drives one [`MacroPool`] toward the placement its measured traffic
/// deserves.  Owns the decayed histogram and the in-flight migration;
/// call [`Self::maintain`] from the serving engine's maintenance gap.
#[derive(Debug)]
pub struct ReplanController {
    cfg: ReplanConfig,
    /// Planner budget the pool was built with (re-plans never grow it).
    budget: usize,
    /// EWMA-decayed per-position heat (fractional from decay).
    ewma: Vec<f64>,
    /// Calls since the last re-plan.
    since_replan: u64,
    /// Migration in flight: the plan and the next step to apply.
    inflight: Option<(MigrationPlan, usize)>,
    /// Re-plans that produced a migration the cost model accepted.
    pub migrations_started: u64,
    /// Candidate plans rejected by hysteresis or the cost horizon.
    pub migrations_rejected: u64,
    /// Steps applied across all migrations.
    pub steps_applied: u64,
    /// Predicted steady-state retunes/batch saved, summed over started
    /// migrations (the cost model's claim; the serving engine surfaces
    /// it in `ServerMetrics`).
    pub retunes_saved: i64,
}

impl ReplanController {
    /// Controller for a resident pool (panics in reload mode — there is
    /// no placement to steer).  `budget` caps every re-plan, normally
    /// the budget the pool was built with.
    pub fn new(pool: &MacroPool<'_>, budget: usize, cfg: ReplanConfig) -> Self {
        assert!(cfg.period >= 1, "period must be at least one call");
        assert!(
            (0.0..1.0).contains(&cfg.decay),
            "decay must be in [0, 1): the histogram must forget eventually"
        );
        let plan = pool
            .plan()
            .expect("re-planning controls a resident pool's placement");
        assert!(budget >= plan.macros_used(), "budget below the live plan");
        ReplanController {
            cfg,
            budget,
            ewma: vec![0.0; plan.schedule_len],
            since_replan: 0,
            inflight: None,
            migrations_started: 0,
            migrations_rejected: 0,
            steps_applied: 0,
            retunes_saved: 0,
        }
    }

    /// A migration is currently being applied step by step.
    pub fn migration_in_flight(&self) -> bool {
        self.inflight.is_some()
    }

    /// The migration currently being applied, if any (tests and
    /// properties audit its cost model against the config's horizon).
    pub fn inflight_plan(&self) -> Option<&MigrationPlan> {
        self.inflight.as_ref().map(|(mp, _)| mp)
    }

    /// One maintenance turn: apply at most one in-flight migration step,
    /// or — on period boundaries with no migration in flight — drain
    /// traffic, re-plan, and admit a new migration through hysteresis
    /// and the cost horizon.  Returns the device cost actually spent
    /// this turn (zero when idle).
    pub fn maintain(&mut self, pool: &MacroPool<'_>) -> MigrationStats {
        if let Some((mp, next)) = self.inflight.as_mut() {
            let cost = pool.apply_migration_step(mp, *next);
            *next += 1;
            self.steps_applied += 1;
            if *next == mp.steps.len() {
                self.inflight = None;
            }
            return cost;
        }
        self.since_replan += 1;
        if self.since_replan < self.cfg.period {
            return MigrationStats::default();
        }
        self.since_replan = 0;
        self.absorb(&pool.take_output_traffic());
        if self.ewma.iter().all(|&h| h <= 0.0) {
            // nothing measured yet — leave the placement alone
            return MigrationStats::default();
        }
        let hist = self.rounded();
        let rows = pool.hidden_load_rows();
        let cur = pool
            .plan()
            .expect("controller pools stay resident")
            .repriced(Some(&hist));
        // health-aware candidate: quarantined macros stay out of the
        // budget and penalized loads out of the replica surplus, so a
        // re-plan migrates load toward recovered capacity as macros are
        // readmitted (the score goes nominal again)
        let health = pool.health_scores();
        let cand = match planner::plan_traffic(
            &rows,
            &pool.schedule_points(),
            Some(&hist),
            Some(&health),
            self.budget,
            self.cfg.workers,
        ) {
            Some(p) => p,
            None => return MigrationStats::default(),
        };
        // hysteresis: the candidate must undercut the incumbent — both
        // priced under the same decayed histogram — by the threshold
        let bar = cur.predicted_retunes_per_batch() as f64 * (1.0 - self.cfg.min_improvement);
        if cand.predicted_retunes_per_batch() as f64 > bar {
            return MigrationStats::default();
        }
        let mp = cur.diff(&cand);
        if mp.is_empty() {
            return MigrationStats::default();
        }
        // cost horizon: programming cycles must be repaid in time
        if !mp.pays_off(
            &rows,
            pool.output_rows(),
            self.cfg.horizon_batches,
            self.cfg.cycles_per_retune,
        ) {
            self.migrations_rejected += 1;
            return MigrationStats::default();
        }
        self.migrations_started += 1;
        self.retunes_saved += mp.predicted_retunes_saved_per_batch();
        self.inflight = Some((mp, 0));
        MigrationStats::default()
    }

    /// Fold a drained traffic delta into the EWMA histogram.
    fn absorb(&mut self, delta: &[u64]) {
        assert_eq!(delta.len(), self.ewma.len(), "histogram shape is fixed");
        for (h, &d) in self.ewma.iter_mut().zip(delta) {
            *h = *h * self.cfg.decay + d as f64;
        }
    }

    /// The decayed histogram as integer planner weights (half-up, so a
    /// faded-but-nonzero position still counts as accessed).
    fn rounded(&self) -> Vec<u64> {
        self.ewma.iter().map(|&h| (h + 0.5) as u64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::macro_pool::PoolMode;
    use crate::accel::pipeline::PipelineOptions;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::cam::NoiseMode;
    use crate::util::bitops::BitVec;
    use crate::util::rng::Rng;

    fn nominal() -> PipelineOptions {
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        }
    }

    fn rand_images(n: usize, bits: usize, seed: u64) -> Vec<BitVec> {
        let mut rng = Rng::new(seed, 1);
        (0..n)
            .map(|_| {
                let mut v = BitVec::zeros(bits);
                for i in 0..bits {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect()
    }

    /// Skewed fixture: one point class holds 8 of 12 positions, so the
    /// pinned set genuinely matters at a 4-macro budget.
    fn skewed_model() -> crate::bnn::model::MappedModel {
        let mut model = tiny_model(64, 8, 3, 44);
        model.schedule = vec![0, 0, 0, 0, 0, 0, 0, 0, 8, 16, 24, 32];
        model
    }

    #[test]
    fn controller_converges_on_a_skew_flip() {
        let model = skewed_model();
        let images = rand_images(8, 64, 29);
        let pool = MacroPool::with_capacity(&model, nominal(), 4);
        assert_eq!(pool.mode(), PoolMode::Resident);
        let before = pool.plan().unwrap();
        let mut ctl = ReplanController::new(
            &pool,
            4,
            ReplanConfig {
                period: 2,
                decay: 0.0, // no memory: track the flip immediately
                ..ReplanConfig::default()
            },
        );
        // sustained banded traffic on three tail points: the incumbent
        // pins at most one of them, so its funnel keeps cycling, while a
        // re-plan pins two and leaves a single point to park for free
        let band = [8usize, 9, 10];
        let mut base = 0;
        for _ in 0..12 {
            pool.classify_batch_positions(&images, base, &band);
            base += images.len() as u64;
            ctl.maintain(&pool);
        }
        assert!(!ctl.migration_in_flight(), "migration must have finished");
        assert_eq!(ctl.migrations_started, 1, "one decisive migration");
        let after = pool.plan().unwrap();
        assert_ne!(after.pin_slot, before.pin_slot, "the pinned set moved");
        // both pin slots now sit inside the hot band
        assert_eq!(
            band.iter().filter(|&&k| after.pin_slot[k].is_some()).count(),
            2
        );
        pool.take_stats(0);
        for _ in 0..3 {
            pool.classify_batch_positions(&images, base, &band);
            base += images.len() as u64;
        }
        assert_eq!(pool.take_stats(24).events.retunes, 0);
    }

    #[test]
    fn hysteresis_holds_the_placement_under_oscillating_skew() {
        let model = skewed_model();
        let images = rand_images(8, 64, 29);
        let pool = MacroPool::with_capacity(&model, nominal(), 4);
        let before = pool.plan().unwrap();
        let mut ctl = ReplanController::new(
            &pool,
            4,
            ReplanConfig {
                period: 1,
                decay: 0.75, // remember several periods
                min_improvement: 0.5,
                ..ReplanConfig::default()
            },
        );
        // alternate the hot band every batch: the decayed histogram
        // stays near-uniform and the 50% bar never clears
        let bands: [&[usize]; 2] = [&[0, 1, 2, 3], &[8, 9, 10, 11]];
        let mut base = 0;
        for i in 0..10 {
            pool.classify_batch_positions(&images, base, bands[i % 2]);
            base += images.len() as u64;
            ctl.maintain(&pool);
        }
        assert_eq!(ctl.migrations_started, 0, "oscillation must not thrash");
        assert_eq!(ctl.steps_applied, 0);
        assert_eq!(pool.plan().unwrap(), before);
    }

    #[test]
    fn idle_pool_is_left_alone() {
        let model = skewed_model();
        let pool = MacroPool::with_capacity(&model, nominal(), 4);
        let before = pool.plan().unwrap();
        let mut ctl = ReplanController::new(&pool, 4, ReplanConfig::default());
        for _ in 0..40 {
            assert_eq!(ctl.maintain(&pool), MigrationStats::default());
        }
        assert_eq!(ctl.migrations_started, 0);
        assert_eq!(pool.plan().unwrap(), before);
    }
}
