//! L3 accelerator coordination: voltage calibration (Table I), the
//! Algorithm-1 inference pipeline, the capacity-aware placement planner
//! (single-model and multi-tenant), the multi-macro resident execution
//! pools, request batching, scrub-and-repair self-healing with
//! fleet-wide health supervision, and accuracy metrics.

pub mod batcher;
pub mod fleet;
pub mod macro_pool;
pub mod metrics;
pub mod parallel;
pub mod pipeline;
pub mod planner;
pub mod replan;
pub mod scrub;
pub mod voltage;

pub use batcher::{BatchPolicy, Batcher, Request};
pub use fleet::{FleetConfig, FleetMaintenance};
pub use macro_pool::{
    MacroPool, MigrationStats, MultiPool, PoolMode, ProbationDelta, DEFAULT_POOL_MACROS,
};
pub use metrics::{evaluate, Accuracy};
pub use parallel::{classify_parallel, classify_parallel_with_budget};
pub use pipeline::{CategoryCost, Pipeline, PipelineOptions, RunStats};
pub use planner::{
    HealthScores, MigrationPlan, MigrationStep, PlacementPlan, TenantPlan, TenantSpec,
};
pub use replan::{ReplanConfig, ReplanController};
pub use scrub::{
    DetectedBy, FaultReport, RepairAction, ScrubConfig, ScrubController, ScrubStats,
};
pub use voltage::{CalibratedPoint, VoltageController};
