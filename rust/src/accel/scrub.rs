//! Scrub-and-repair control loop: the self-healing half of the fault
//! model in `cam::faults`.
//!
//! Silicon does not announce its failures.  The [`ScrubController`]
//! finds them the way real memories do — a background scrub pass that
//! read-verifies stored rows against the golden model and fires canary
//! searches at the matchline sense amps — and repairs what it finds
//! along an escalation ladder that ends in typed refusal, never in
//! silent wrong answers:
//!
//! 1. **Amortization.** Each call to [`ScrubController::maintain`] (the
//!    serving engine calls it once per inter-batch maintenance gap)
//!    verifies at most [`ScrubConfig::rows_per_turn`] rows, walking a
//!    persistent `(site, row)` cursor over every resident macro in
//!    [`super::MacroPool::fault_sites`] order.  A full pass over a
//!    128-kbit pool therefore spreads across many gaps; no single batch
//!    ever waits on a bulk verify.
//!
//! 2. **Detection.** Per row: a store readback against the pure mapping
//!    (`bnn::mapping::program_row` — scrub needs no shadow copy), then a
//!    canary pair (the row's own pattern must fire, its complement must
//!    not).  The readback catches stuck bitcells; the canary catches
//!    dead rows and transient upsets, which lie at the sense amp, not in
//!    the cells.  Rails are checked first: stuck DAC codes and drift
//!    beyond [`ScrubConfig::drift_tol`] (repaired by factory re-trim).
//!
//! 3. **Escalation.** In-place repairs (rewrite, spare-row remap, rail
//!    re-trim) happen inside [`super::MacroPool::scrub_rows`].  What
//!    comes back as [`RepairAction::NeedsRebuild`] escalates here: up to
//!    [`ScrubConfig::max_rebuilds`] whole-macro rebuilds per copy
//!    (identical seeding makes a rebuilt macro bit-exact to a
//!    never-faulted one), then — for hidden replicas — quarantine: the
//!    dying copy is retired, surviving replicas fail over
//!    (bit-identically), the pool drops to [`DegradedMode::Failover`],
//!    and a planner-level re-plan is launched whose
//!    [`super::planner::PlacementPlan::diff`] emits exactly the
//!    migration steps that move capacity off the quarantined macro (one
//!    step per later gap, like the re-planning controller).  An output
//!    slot that exhausts its rebuild budget has no quarantine path — the
//!    threshold sweep needs every slot — so the pool drops to
//!    [`DegradedMode::Refusing`] and the engine sheds new work with a
//!    typed rejection.
//!
//! 4. **Determinism.**  The controller owns its own [`Rng`]; scrub
//!    searches never touch the per-image noise streams, so scrubbing a
//!    healthy pool is invisible to predictions.  Given the same seed,
//!    fault plan, and workload trace, the reports, repair schedule, and
//!    predictions replay bit-identically (property-tested).
//!
//! 5. **Re-admission.**  Recovery is operator-gated, never silent:
//!    [`super::MacroPool::un_quarantine`] puts a replaced macro on
//!    probation as an identically-seeded side-array carrying zero load,
//!    and every maintenance turn canary-laps it
//!    ([`super::MacroPool::probation_scrub`]).  Passing the required
//!    consecutive clean laps re-admits it as a live replica — the only
//!    transition that lifts [`DegradedMode::Failover`] back to
//!    `Nominal` — while any canary failure re-quarantines it with the
//!    lap requirement doubled (`cam::faults` health ladder).

use crate::cam::faults::{DegradedMode, FaultSite};
use crate::util::rng::Rng;

use super::macro_pool::MacroPool;
use super::planner::{self, MigrationPlan};

/// How a fault was noticed by the scrub pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DetectedBy {
    /// Store readback differed from the golden mapping (stuck bitcells).
    ReadVerify,
    /// The canary search pair misfired (dead rows, transient upsets).
    Canary,
    /// A rail's static error left its factory-trim tolerance.
    RailDrift,
    /// A rail DAC stopped accepting codes.
    RailStuck,
}

/// What the repair ladder did about a detection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RepairAction {
    /// Reprogramming the row restored it (soft corruption).
    Rewritten,
    /// The row moved to a spare physical row and reprogrammed clean.
    Remapped,
    /// Drifted rails were re-trimmed to factory offsets.
    Recalibrated,
    /// A stuck rail swapped onto its spare DAC leg (output slots).
    RailRepaired,
    /// The canary failure did not reproduce — a transient burned down.
    SelfCleared,
    /// In-place repair is out of budget; the macro needs a rebuild.
    NeedsRebuild,
    /// The whole macro was rebuilt from the model (identical seeding).
    Rebuilt,
    /// A hidden replica was retired; surviving copies fail over.
    Quarantined,
    /// No repair path remains; the pool refuses new work.
    Unrepairable,
}

/// One detection (and its outcome) from a scrub pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultReport {
    /// The fault site the affected macro belongs to.
    pub site: FaultSite,
    /// Replica index (hidden sites) or slot index (output sites).
    pub copy: usize,
    /// Affected logical row; `None` for rail-level detections.
    pub row: Option<usize>,
    pub detected: DetectedBy,
    pub action: RepairAction,
}

/// Counters summarizing scrub work (per turn and cumulative).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScrubStats {
    /// Rows read-verified + canary-checked.
    pub rows_scrubbed: u64,
    /// Detections of any kind (one per [`FaultReport`]).
    pub faults_detected: u64,
    /// In-place repairs (rewrite, remap, re-trim, rail swap, self-clear).
    pub repairs: u64,
    /// Whole-macro rebuilds performed.
    pub rebuilds: u64,
    /// Hidden replicas quarantined.
    pub quarantines: u64,
    /// Detections with no remaining repair path.
    pub unrepairable: u64,
    /// Clean canary laps credited to probation macros.
    pub probation_laps: u64,
    /// Probation macros re-admitted into serving.
    pub readmissions: u64,
    /// Probations failed (macro re-quarantined, requirement doubled).
    pub probation_failures: u64,
}

impl ScrubStats {
    pub fn add(&mut self, other: &ScrubStats) {
        self.rows_scrubbed += other.rows_scrubbed;
        self.faults_detected += other.faults_detected;
        self.repairs += other.repairs;
        self.rebuilds += other.rebuilds;
        self.quarantines += other.quarantines;
        self.unrepairable += other.unrepairable;
        self.probation_laps += other.probation_laps;
        self.readmissions += other.readmissions;
        self.probation_failures += other.probation_failures;
    }
}

/// Tuning for the scrub loop (role of each knob in the module docs).
#[derive(Clone, Copy, Debug)]
pub struct ScrubConfig {
    /// Row-verify budget per maintenance turn (amortization grain).
    pub rows_per_turn: usize,
    /// Rail drift beyond this triggers a factory re-trim [V].
    pub drift_tol: f64,
    /// Whole-macro rebuilds granted per copy before quarantine/refusal.
    pub max_rebuilds: u32,
    /// Worker count handed to the post-quarantine re-plan (replica cap),
    /// matching how the pool was built.
    pub workers: usize,
}

impl Default for ScrubConfig {
    fn default() -> Self {
        ScrubConfig {
            rows_per_turn: 4,
            drift_tol: 0.002,
            max_rebuilds: 2,
            workers: 1,
        }
    }
}

/// Background scrub-and-repair driver for one [`MacroPool`].  Owns the
/// scrub cursor, the per-copy strike counts, and any in-flight
/// post-quarantine migration; call [`Self::maintain`] from the serving
/// engine's maintenance gap.
#[derive(Debug)]
pub struct ScrubController {
    cfg: ScrubConfig,
    /// Scrub cursor: index into the pool's current site list.
    site: usize,
    /// Next row to verify within the cursor site.
    row: usize,
    /// Private noise stream for canary searches (module docs, rule 4).
    rng: Rng,
    /// `NeedsRebuild` strikes per (site, copy) — the escalation memory.
    strikes: Vec<(FaultSite, usize, u32)>,
    /// Post-quarantine migration being applied one step per turn.
    inflight: Option<(MigrationPlan, usize)>,
    /// Cumulative counters since construction.
    stats: ScrubStats,
    /// Reports not yet drained by [`Self::take_reports`].
    reports: Vec<FaultReport>,
    /// Sticky degradation rung.  It never improves on its own while a
    /// macro is written off; it lifts back to `Nominal` only when the
    /// last quarantined macro completes operator-initiated probation
    /// ([`MacroPool::un_quarantine`]) — never silently.
    mode: DegradedMode,
    /// Full cursor laps over the site list (fairness accounting).
    laps: u64,
    /// A detection named the cursor site since the cursor entered it —
    /// blocks the clean-lap health credit for that site.
    cursor_dirty: bool,
}

impl ScrubController {
    pub fn new(seed: u64, cfg: ScrubConfig) -> Self {
        assert!(cfg.rows_per_turn >= 1, "scrub must make progress");
        ScrubController {
            cfg,
            site: 0,
            row: 0,
            rng: Rng::new(seed, 0x5C_4B),
            strikes: Vec::new(),
            inflight: None,
            stats: ScrubStats::default(),
            reports: Vec::new(),
            mode: DegradedMode::Nominal,
            laps: 0,
            cursor_dirty: false,
        }
    }

    /// One maintenance turn: apply at most one in-flight migration step,
    /// or spend the row budget scrubbing from the cursor, repairing and
    /// escalating as the module docs describe.  Returns the work done
    /// *this turn* (the serving engine feeds it to `ServerMetrics`);
    /// cumulative counters accrue in [`Self::stats`].
    pub fn maintain(&mut self, pool: &MacroPool<'_>) -> ScrubStats {
        self.maintain_budgeted(pool, self.cfg.rows_per_turn)
    }

    /// [`Self::maintain`] with an explicit row budget for this turn —
    /// the seam the fleet supervisor meters shared maintenance through
    /// (`super::fleet`): the configured `rows_per_turn` becomes a
    /// per-lane quantum instead of a constant.
    pub fn maintain_budgeted(&mut self, pool: &MacroPool<'_>, rows_budget: usize) -> ScrubStats {
        let mut delta = ScrubStats::default();
        // a migration moving capacity off a quarantined macro consumes
        // the whole turn, mirroring the re-planning controller: no gap
        // ever waits on more than one step
        if let Some((mp, next)) = self.inflight.as_mut() {
            pool.apply_migration_step(mp, *next);
            *next += 1;
            if *next == mp.steps.len() {
                self.inflight = None;
            }
            return delta;
        }
        let sites = pool.fault_sites();
        if sites.is_empty() {
            return delta; // reload pool: nothing resident to scrub
        }
        let before = self.reports.len();
        let mut budget = rows_budget;
        // `visited` bounds the walk to one lap even if every site is
        // void (e.g. the placement shrank under the cursor)
        let mut visited = 0;
        while budget > 0 && visited <= sites.len() {
            if self.site >= sites.len() {
                self.site = 0;
                self.laps += 1;
            }
            let g = &sites[self.site];
            if self.row >= g.rows {
                // the cursor cleared the whole site: credit the health
                // ladder (Suspect → Healthy) unless a detection landed
                // somewhere in this traversal
                if !self.cursor_dirty {
                    pool.health_lap_clean(&g.site);
                }
                self.cursor_dirty = false;
                self.site += 1;
                self.row = 0;
                visited += 1;
                continue;
            }
            let reports_before = self.reports.len();
            let want = budget.min(g.rows - self.row);
            let n = pool.scrub_rows(
                &g.site,
                self.row,
                want,
                self.cfg.drift_tol,
                &mut self.rng,
                &mut self.reports,
            );
            if self.reports[reports_before..].iter().any(|r| r.site == g.site) {
                self.cursor_dirty = true;
            }
            if n == 0 {
                // site went void since the snapshot (migration raced us)
                self.cursor_dirty = false;
                self.site += 1;
                self.row = 0;
                visited += 1;
                continue;
            }
            self.row += n;
            budget -= n.min(budget);
            delta.rows_scrubbed += n as u64;
        }
        // tally this turn's detections, then escalate what the in-place
        // ladder could not fix — once per (site, copy), not per row
        let mut rebuild: Vec<(FaultSite, usize)> = Vec::new();
        for r in &self.reports[before..] {
            delta.faults_detected += 1;
            match r.action {
                RepairAction::Rewritten
                | RepairAction::Remapped
                | RepairAction::Recalibrated
                | RepairAction::RailRepaired
                | RepairAction::SelfCleared => delta.repairs += 1,
                RepairAction::NeedsRebuild => {
                    if !rebuild.contains(&(r.site, r.copy)) {
                        rebuild.push((r.site, r.copy));
                    }
                }
                // terminal outcomes are only ever appended by the
                // escalation below, never by the in-place ladder
                RepairAction::Rebuilt
                | RepairAction::Quarantined
                | RepairAction::Unrepairable => {}
            }
        }
        for (site, copy) in rebuild {
            self.escalate(pool, site, copy, &mut delta);
        }
        // canary-lap whatever is on probation (its own equal allotment —
        // probation work must not starve the serving-copy scrub cursor)
        let p = pool.probation_scrub(rows_budget, &mut self.rng);
        delta.probation_laps += p.laps;
        delta.readmissions += p.readmitted;
        delta.probation_failures += p.failures;
        if p.readmitted > 0
            && self.mode == DegradedMode::Failover
            && pool.health_quarantined() == 0
        {
            // the last written-off macro just earned its way back in:
            // the only path out of Failover, and it runs through the
            // operator plus the full canary gate
            self.mode = DegradedMode::Nominal;
        }
        pool.set_degraded_mode(self.mode);
        self.stats.add(&delta);
        delta
    }

    /// Full scrub-cursor laps completed (fairness accounting: the
    /// property tests bound the lap gap between tenants sharing a
    /// maintenance budget).
    pub fn laps_completed(&self) -> u64 {
        self.laps
    }

    /// Escalate one copy that in-place repair gave up on: rebuild while
    /// the strike budget lasts, then quarantine (hidden) or refuse
    /// (output).
    fn escalate(&mut self, pool: &MacroPool<'_>, site: FaultSite, copy: usize, delta: &mut ScrubStats) {
        let strikes = self.strike(site, copy);
        let report = |row, detected, action| FaultReport {
            site,
            copy,
            row,
            detected,
            action,
        };
        match site {
            FaultSite::Hidden { layer, load, .. } => {
                if strikes <= self.cfg.max_rebuilds {
                    if pool.rebuild_replica(layer, load, copy) {
                        delta.rebuilds += 1;
                        self.reports
                            .push(report(None, DetectedBy::ReadVerify, RepairAction::Rebuilt));
                    }
                } else {
                    let left = pool.quarantine_replica(layer, load, copy);
                    if left == usize::MAX {
                        return; // site went void: nothing to retire
                    }
                    delta.quarantines += 1;
                    self.mode = self.mode.max(DegradedMode::Failover);
                    self.reports
                        .push(report(None, DetectedBy::ReadVerify, RepairAction::Quarantined));
                    // copy indices shifted under the removal: old strike
                    // history for this site no longer names real copies
                    self.strikes.retain(|(s, _, _)| *s != site);
                    self.launch_replan(pool);
                }
            }
            FaultSite::Output { .. } => {
                if strikes <= self.cfg.max_rebuilds {
                    if pool.rebuild_output_slot(copy) {
                        delta.rebuilds += 1;
                        self.reports
                            .push(report(None, DetectedBy::ReadVerify, RepairAction::Rebuilt));
                    }
                } else {
                    // every output slot is load-bearing for the threshold
                    // sweep — with the rebuild budget spent, refusing new
                    // work beats serving silently wrong votes
                    delta.unrepairable += 1;
                    self.mode = DegradedMode::Refusing;
                    self.reports
                        .push(report(None, DetectedBy::ReadVerify, RepairAction::Unrepairable));
                }
            }
        }
    }

    /// Increment and return the strike count for (site, copy).
    fn strike(&mut self, site: FaultSite, copy: usize) -> u32 {
        for (s, c, n) in self.strikes.iter_mut() {
            if *s == site && *c == copy {
                *n += 1;
                return *n;
            }
        }
        self.strikes.push((site, copy, 1));
        1
    }

    /// Re-plan within the shrunken macro budget so the placement stops
    /// leaning on the quarantined copy; `PlacementPlan::diff` emits the
    /// steps off the dying macro and they apply one per later turn.
    /// Health-aware: the target plan spills penalized loads first and
    /// keeps surplus replicas off Suspect/Probation silicon.
    fn launch_replan(&mut self, pool: &MacroPool<'_>) {
        let Some(cur) = pool.plan() else {
            return;
        };
        let health = pool.health_scores();
        let target = planner::plan_traffic(
            &pool.hidden_load_rows(),
            &pool.schedule_points(),
            None,
            Some(&health),
            cur.macros_used(),
            self.cfg.workers,
        );
        if let Some(target) = target {
            let mp = cur.diff(&target);
            if !mp.is_empty() {
                self.inflight = Some((mp, 0));
            }
        }
    }

    /// A post-quarantine migration is still being applied.
    pub fn migration_in_flight(&self) -> bool {
        self.inflight.is_some()
    }

    /// Cumulative counters since construction.
    pub fn stats(&self) -> ScrubStats {
        self.stats
    }

    /// The degradation rung the controller has driven the pool to.
    pub fn degraded_mode(&self) -> DegradedMode {
        self.mode
    }

    /// Drain the accumulated fault reports (diagnostics / tests).
    pub fn take_reports(&mut self) -> Vec<FaultReport> {
        std::mem::take(&mut self.reports)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::macro_pool::PoolMode;
    use crate::accel::pipeline::PipelineOptions;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::cam::faults::{FaultKind, FaultPlan};
    use crate::cam::NoiseMode;
    use crate::util::bitops::BitVec;
    use crate::util::rng::Rng;

    fn nominal() -> PipelineOptions {
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        }
    }

    fn rand_images(n: usize, bits: usize, seed: u64) -> Vec<BitVec> {
        let mut rng = Rng::new(seed, 1);
        (0..n)
            .map(|_| {
                let mut v = BitVec::zeros(bits);
                for i in 0..bits {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect()
    }

    /// Exhaustive single-turn config: one maintain() laps the pool.
    fn full_pass() -> ScrubConfig {
        ScrubConfig {
            rows_per_turn: 1 << 20,
            ..ScrubConfig::default()
        }
    }

    #[test]
    fn healthy_pool_scrubs_clean_and_stays_nominal() {
        let model = tiny_model(64, 8, 3, 44);
        let pool = MacroPool::with_capacity(&model, nominal(), 4);
        assert_eq!(pool.mode(), PoolMode::Resident);
        let mut ctl = ScrubController::new(7, full_pass());
        let d = ctl.maintain(&pool);
        assert!(d.rows_scrubbed > 0, "the cursor visited real rows");
        assert_eq!(d.faults_detected, 0);
        assert_eq!(ctl.degraded_mode(), DegradedMode::Nominal);
        assert!(ctl.take_reports().is_empty());
    }

    #[test]
    fn stuck_bits_are_detected_and_repaired_bit_exact() {
        let model = tiny_model(64, 8, 3, 44);
        let images = rand_images(6, 64, 29);
        let pool = MacroPool::with_capacity(&model, nominal(), 4);
        let twin = MacroPool::with_capacity(&model, nominal(), 4);
        let site = pool.fault_sites()[0].site;
        let mut plan = FaultPlan::default();
        // stick the cell at the complement of its programmed value, so
        // the corruption is guaranteed (a stuck-at that happens to agree
        // with the stored bit is genuinely harmless and undetectable)
        let golden = crate::bnn::mapping::program_row(&model.layers[0], 0, 0);
        for col in 0..2 {
            let bit = !golden.get(col);
            plan.push(0, site, FaultKind::StuckBit { row: 0, col, bit });
        }
        pool.inject_fault_plan(plan);
        // activate on the first batch, then scrub the corruption away
        pool.classify_batch_at(&images, 0);
        twin.classify_batch_at(&images, 0);
        let mut ctl = ScrubController::new(7, full_pass());
        let d = ctl.maintain(&pool);
        assert!(d.faults_detected > 0, "a polarity must have corrupted");
        assert_eq!(d.repairs, d.faults_detected, "all repaired in place");
        assert!(ctl
            .take_reports()
            .iter()
            .all(|r| r.action == RepairAction::Remapped),
            "stuck cells re-assert through rewrites: repair must remap");
        // post-repair predictions are bit-exact against the twin
        let a = pool.classify_batch_at(&images, images.len() as u64);
        let b = twin.classify_batch_at(&images, images.len() as u64);
        assert_eq!(a, b);
        assert_eq!(ctl.degraded_mode(), DegradedMode::Nominal);
    }

    #[test]
    fn scrubbing_a_healthy_pool_is_invisible_to_predictions() {
        let model = tiny_model(64, 8, 3, 44);
        let images = rand_images(6, 64, 31);
        for noise in [NoiseMode::Nominal, NoiseMode::Analog] {
            let opts = PipelineOptions {
                noise,
                ..Default::default()
            };
            let pool = MacroPool::with_capacity(&model, opts, 4);
            let twin = MacroPool::with_capacity(&model, opts, 4);
            let mut ctl = ScrubController::new(9, full_pass());
            let mut base = 0;
            for _ in 0..3 {
                let a = pool.classify_batch_at(&images, base);
                let b = twin.classify_batch_at(&images, base);
                assert_eq!(a, b, "scrub must not perturb noise streams");
                base += images.len() as u64;
                ctl.maintain(&pool);
            }
            assert_eq!(ctl.stats().faults_detected, 0);
        }
    }
}
