//! Voltage controller: finds (V_ref, V_eval, V_st) triples realising target
//! HD tolerance thresholds — the procedure that generates the paper's
//! Table I, run against the analog model instead of silicon.
//!
//! Calibration is a grid search over the DAC-quantized voltage windows,
//! validated *behaviourally*: a candidate triple is scored by probing the
//! simulated array with synthetic rows at known mismatch counts around the
//! target, exactly as a bring-up engineer would sweep a test pattern.

use crate::analog::dac::{quantize, quantize_coarse, DAC_FINE, DAC_STEP};
use crate::analog::matchline::{MatchlineModel, Voltages};
use crate::analog::transistor::Pvt;
use crate::analog::constants as k;

/// A calibrated operating point.
#[derive(Clone, Copy, Debug)]
pub struct CalibratedPoint {
    pub target_tol: u32,
    pub voltages: Voltages,
    /// Tolerance the model actually realises at this point.
    pub achieved_tol: f64,
}

/// Calibration engine for a given word width + PVT corner.
#[derive(Clone, Debug)]
pub struct VoltageController {
    pub model: MatchlineModel,
    /// Grid step for the search [V] (defaults to the DAC step).
    pub step: f64,
}

impl VoltageController {
    pub fn new(n_cells: usize, pvt: Pvt) -> Self {
        VoltageController {
            model: MatchlineModel::new(n_cells, pvt),
            step: DAC_STEP,
        }
    }

    /// Find a voltage triple realising `target` HD tolerance (within
    /// ±`slack` bits).  Prefers triples whose achieved tolerance sits at
    /// `target + 0.5` — centring the decision boundary *between* integer
    /// mismatch counts maximises noise margin on both sides.
    ///
    /// Two-phase search mirroring the coarse+fine DAC topology: a 25 mV
    /// grid scan, then a ±12 mV local refine at the 1 mV trim resolution
    /// around the best coarse point.
    pub fn calibrate(&self, target: u32, slack: f64) -> Option<CalibratedPoint> {
        if target == 0 {
            // the exact-match setting (Table I row 1)
            return Some(CalibratedPoint {
                target_tol: 0,
                voltages: Voltages::exact(),
                achieved_tol: 0.0,
            });
        }
        let want = target as f64 + 0.5;
        let mut best: Option<CalibratedPoint> = None;
        let consider = |v: Voltages, best: &mut Option<CalibratedPoint>| {
            let tol = self.model.hd_tolerance(&v);
            let err = (tol - want).abs();
            if best.as_ref().map_or(true, |b| err < (b.achieved_tol - want).abs()) {
                *best = Some(CalibratedPoint {
                    target_tol: target,
                    voltages: v,
                    achieved_tol: tol,
                });
            }
        };
        // phase 1: coarse 25 mV grid
        let mut vref = k::VREF_RANGE.0;
        while vref <= k::VREF_RANGE.1 - 1e-9 {
            let mut veval = k::VEVAL_RANGE.0;
            while veval <= k::VEVAL_RANGE.1 + 1e-9 {
                let mut vst = k::VST_RANGE.0;
                while vst <= k::VST_RANGE.1 + 1e-9 {
                    consider(
                        Voltages::new(
                            quantize_coarse(vref),
                            quantize_coarse(veval),
                            quantize_coarse(vst),
                        ),
                        &mut best,
                    );
                    vst += self.step;
                }
                veval += self.step;
            }
            vref += self.step;
        }
        // phase 2: 1 mV trim around the coarse winner (vref is the most
        // sensitive rail; trim all three)
        if let Some(coarse) = best {
            let c = coarse.voltages;
            let span = DAC_STEP / 2.0;
            let mut dv = -span;
            while dv <= span + 1e-12 {
                let v = Voltages::new(quantize(c.vref + dv), c.veval, c.vst).clamped();
                consider(v, &mut best);
                let v2 = Voltages::new(c.vref, quantize(c.veval + dv), c.vst).clamped();
                consider(v2, &mut best);
                let v3 = Voltages::new(c.vref, c.veval, quantize(c.vst + dv)).clamped();
                consider(v3, &mut best);
                dv += DAC_FINE;
            }
        }
        best.filter(|b| (b.achieved_tol - want).abs() <= slack)
    }

    /// Best-effort calibration: the closest achievable point regardless of
    /// slack.  At extreme PVT corners (e.g. hot + brown-out) the wide-row
    /// midpoint may be genuinely unreachable — the device then runs with a
    /// shifted threshold and degraded accuracy, which is the honest corner
    /// behaviour the PVT ablation measures.
    pub fn calibrate_best(&self, target: u32) -> CalibratedPoint {
        self.calibrate(target, f64::INFINITY)
            .expect("non-empty voltage grid")
    }

    /// Calibrate a whole schedule of targets, tightest slack first and
    /// best-effort as the last resort (see [`Self::calibrate_best`]).
    pub fn calibrate_schedule(&self, targets: &[u32]) -> Vec<CalibratedPoint> {
        targets
            .iter()
            .map(|&t| {
                self.calibrate(t, 0.5)
                    .or_else(|| self.calibrate(t, 2.0))
                    .unwrap_or_else(|| self.calibrate_best(t))
            })
            .collect()
    }

    /// Behavioural verification of a calibrated point: probe mismatch
    /// counts around the target and check the decision flips at the
    /// boundary.  Returns (false-accepts, false-rejects) over the probes.
    pub fn verify(&self, point: &CalibratedPoint, probe_span: u32) -> (u32, u32) {
        let mut fa = 0;
        let mut fr = 0;
        let lo = point.target_tol.saturating_sub(probe_span);
        let hi = (point.target_tol + probe_span).min(self.model.n_cells as u32);
        for m in lo..=hi {
            let fires = self.model.fires_nominal(
                m,
                &point.voltages,
                &crate::analog::matchline::RowVariation::nominal(),
            );
            let should = m <= point.target_tol;
            match (fires, should) {
                (true, false) => fa += 1,
                (false, true) => fr += 1,
                _ => {}
            }
        }
        (fa, fr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_targets_all_reachable_256() {
        let ctl = VoltageController::new(256, Pvt::nominal());
        for target in [0u32, 4, 8, 12, 16, 20, 24, 28, 32, 36] {
            let p = ctl
                .calibrate(target, 0.5)
                .unwrap_or_else(|| panic!("target {target}"));
            let (fa, fr) = ctl.verify(&p, 6);
            assert_eq!((fa, fr), (0, 0), "target {target}: {p:?}");
        }
    }

    #[test]
    fn algorithm1_schedule_reachable_512() {
        // the output layer sweeps {0, 2, ..., 64} on 512-cell words
        let ctl = VoltageController::new(512, Pvt::nominal());
        let targets: Vec<u32> = (0..=64).step_by(2).collect();
        let points = ctl.calibrate_schedule(&targets);
        for (t, p) in targets.iter().zip(&points) {
            assert!(
                (p.achieved_tol - (*t as f64 + 0.5)).abs() <= 2.0,
                "target {t} achieved {}",
                p.achieved_tol
            );
        }
    }

    #[test]
    fn midpoint_reachable_1024() {
        // the hidden layer needs tolerance n/2 = 512 on 1024-cell words
        let ctl = VoltageController::new(1024, Pvt::nominal());
        let p = ctl.calibrate(512, 2.0).expect("midpoint 512");
        assert!((p.achieved_tol - 512.5).abs() <= 2.0, "{p:?}");
    }

    #[test]
    fn midpoint_reachable_2048() {
        let ctl = VoltageController::new(2048, Pvt::nominal());
        let p = ctl.calibrate(1024, 3.0).expect("midpoint 1024");
        assert!((p.achieved_tol - 1024.5).abs() <= 3.0, "{p:?}");
    }

    #[test]
    fn zero_target_is_exact_setting() {
        let ctl = VoltageController::new(256, Pvt::nominal());
        let p = ctl.calibrate(0, 0.5).unwrap();
        assert_eq!(p.voltages, Voltages::exact());
        assert_eq!(p.achieved_tol, 0.0);
    }

    #[test]
    fn voltages_on_dac_grid() {
        let ctl = VoltageController::new(256, Pvt::nominal());
        let p = ctl.calibrate(16, 0.5).unwrap();
        for v in [p.voltages.vref, p.voltages.veval, p.voltages.vst] {
            assert!((v - quantize(v)).abs() < 1e-12, "{v}");
        }
    }
}
