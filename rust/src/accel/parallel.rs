//! Host-parallel evaluation over one shared [`MacroPool`]: worker threads
//! pull disjoint image ranges through the same set of resident macros and
//! merge results in order.
//!
//! This models a *fleet-shared* PiC-BNN pool (weights stay resident while
//! many workers stream queries), and its practical role here is simulation
//! throughput: large accuracy sweeps (Fig. 5 regenerates 20 full-test-set
//! runs) are embarrassingly parallel across images.
//!
//! The pool is planned for the worker count, so surplus macro budget buys
//! hidden-load *replicas* — workers grab a free replica instead of
//! serialising on one `Mutex<CamArray>` (see [`super::planner`]).  Budgets
//! too small for full residency degrade to threshold sharing, and only a
//! budget that cannot hold the hidden loads falls back to the seed
//! behaviour: one reload `Pipeline` per shard, seeded `opts.seed + shard`.
//!
//! Each `batch`-sized chunk a worker pulls is one call into the
//! query-batched search kernel (`CamArray::search_batch_rows_into_rngs`,
//! running on the runtime-dispatched Hamming backend — `util::bitops`),
//! so the chunk size doubles as the kernel's query-tile feed: larger
//! chunks amortise lock acquisitions and store streaming, and — because
//! noise streams are per-image — any chunking yields bit-identical
//! results.  Workers allocate nothing at steady state: each pops a
//! `BatchScratch` arena from the pool's free-list per batch (the pool
//! converges to one arena per worker — see `MacroPool`).
//!
//! Determinism: frozen per-macro variation comes from the pool seed at
//! construction (replicas are seeded identically), and per-evaluation
//! noise comes from per-image streams indexed by each image's *global*
//! position — so results are identical for any thread count, interleaving,
//! or macro budget (see `CamArray::search_into_rng`).

use crate::bnn::model::MappedModel;
use crate::cam::NoiseMode;
use crate::util::bitops::BitVec;

use super::macro_pool::{MacroPool, DEFAULT_POOL_MACROS};
use super::pipeline::{Pipeline, PipelineOptions, RunStats};

/// Classify `images` using `n_threads` workers under the default macro
/// budget; returns per-image (votes, prediction) in input order plus the
/// merged device statistics.
pub fn classify_parallel(
    model: &MappedModel,
    opts: PipelineOptions,
    images: &[BitVec],
    batch: usize,
    n_threads: usize,
) -> (Vec<(Vec<u32>, usize)>, RunStats) {
    classify_parallel_with_budget(model, opts, images, batch, n_threads, DEFAULT_POOL_MACROS)
}

/// [`classify_parallel`] with an explicit macro budget (degraded budgets
/// run resident with threshold sharing; infeasible ones reload per shard).
pub fn classify_parallel_with_budget(
    model: &MappedModel,
    opts: PipelineOptions,
    images: &[BitVec],
    batch: usize,
    n_threads: usize,
    budget: usize,
) -> (Vec<(Vec<u32>, usize)>, RunStats) {
    let n_threads = n_threads.max(1).min(images.len().max(1));
    let batch = batch.max(1);
    let chunk = images.len().div_ceil(n_threads).max(1);
    // cheap placement probe (no calibration) before building anything:
    // infeasible budgets go straight to the per-shard reload path.  So
    // do analog-mode *spill* plans: concurrent workers would interleave
    // funnel reloads, and each reload redraws frozen row variation from
    // the funnel's own stream — arrival order would leak into analog
    // results, breaking this evaluator's any-interleaving determinism
    // contract (nominal mode draws nothing, so spill stays eligible).
    let spill_racy = |p: &super::planner::PlacementPlan| {
        p.spill_active() && opts.noise == NoiseMode::Analog && n_threads > 1
    };
    match MacroPool::plan_for(model, &opts, budget) {
        None => return classify_parallel_reload(model, opts, images, batch, n_threads),
        Some(p) if spill_racy(&p) => {
            return classify_parallel_reload(model, opts, images, batch, n_threads)
        }
        Some(_) => {}
    }
    let pool = MacroPool::with_capacity_for_workers(model, opts, budget, n_threads);
    let mut shard_results: Vec<Option<Vec<(Vec<u32>, usize)>>> =
        (0..n_threads).map(|_| None).collect();
    std::thread::scope(|s| {
        for (t, (shard, slot)) in images
            .chunks(chunk)
            .zip(shard_results.iter_mut())
            .enumerate()
        {
            let pool = &pool;
            s.spawn(move || {
                let base = (t * chunk) as u64;
                let mut out = Vec::with_capacity(shard.len());
                for (b, sub) in shard.chunks(batch).enumerate() {
                    out.extend(pool.classify_batch_at(sub, base + (b * batch) as u64));
                }
                *slot = Some(out);
            });
        }
    });
    let mut results = Vec::with_capacity(images.len());
    for slot in shard_results.into_iter().flatten() {
        results.extend(slot);
    }
    let stats = pool.take_stats(images.len() as u64);
    (results, stats)
}

/// Fallback for models exceeding the pool capacity: one reload pipeline
/// per shard with deterministic per-shard seeds (the seed behaviour).
fn classify_parallel_reload(
    model: &MappedModel,
    opts: PipelineOptions,
    images: &[BitVec],
    batch: usize,
    n_threads: usize,
) -> (Vec<(Vec<u32>, usize)>, RunStats) {
    let chunk = images.len().div_ceil(n_threads).max(1);
    let mut shard_results: Vec<Option<(Vec<(Vec<u32>, usize)>, RunStats)>> =
        (0..n_threads).map(|_| None).collect();
    std::thread::scope(|s| {
        for (t, (shard, slot)) in images
            .chunks(chunk)
            .zip(shard_results.iter_mut())
            .enumerate()
        {
            s.spawn(move || {
                let shard_opts = PipelineOptions {
                    seed: opts.seed.wrapping_add(t as u64),
                    ..opts
                };
                let mut pipe = Pipeline::new(model, shard_opts);
                let mut out = Vec::with_capacity(shard.len());
                for b in shard.chunks(batch) {
                    out.extend(pipe.classify_batch(b));
                }
                let stats = pipe.take_stats(shard.len() as u64);
                *slot = Some((out, stats));
            });
        }
    });
    let mut results = Vec::with_capacity(images.len());
    let mut stats = RunStats::default();
    for slot in shard_results.into_iter().flatten() {
        results.extend(slot.0);
        stats.inferences += slot.1.inferences;
        stats.cycles += slot.1.cycles;
        stats.stall_s += slot.1.stall_s;
        stats.events.add(&slot.1.events);
        stats.hidden_cost.add(&slot.1.hidden_cost);
        stats.output_cost.add(&slot.1.output_cost);
        // per-shard elapsed times are *summed* into the merged report, so
        // each shard's single macro already leaks over exactly its own
        // slice of that serialized timeline — summing `macros` here would
        // multiply leakage by the shard count on top of the summed time.
        // (A resident pool is different: all its macros stay powered for
        // the pool's whole reported duration, so take_stats reports the
        // full resident count.)
        stats.macros = stats.macros.max(slot.1.macros);
    }
    (results, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::cam::NoiseMode;
    use crate::util::rng::Rng;

    fn images(n: usize, bits: usize) -> Vec<BitVec> {
        let mut rng = Rng::new(3, 14);
        (0..n)
            .map(|_| {
                let mut v = BitVec::zeros(bits);
                for i in 0..bits {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect()
    }

    #[test]
    fn parallel_matches_serial_nominal() {
        let model = tiny_model(64, 8, 4, 55);
        let imgs = images(50, 64);
        let opts = PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        };
        let mut serial = Pipeline::new(&model, opts);
        let mut want = Vec::new();
        for b in imgs.chunks(16) {
            want.extend(serial.classify_batch(b));
        }
        for threads in [1, 2, 4, 7] {
            let (got, stats) = classify_parallel(&model, opts, &imgs, 16, threads);
            assert_eq!(got, want, "threads={threads}");
            assert_eq!(stats.inferences, 50);
        }
    }

    #[test]
    fn degraded_budgets_match_serial_nominal() {
        // the planner's sharing (small budgets) and replication (surplus
        // budgets, multi-worker) must both be invisible in the results
        let model = tiny_model(64, 8, 4, 55);
        let imgs = images(50, 64);
        let opts = PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        };
        let mut serial = Pipeline::new(&model, opts);
        let mut want = Vec::new();
        for b in imgs.chunks(16) {
            want.extend(serial.classify_batch(b));
        }
        let required = MacroPool::macros_required(&model, &opts);
        for budget in [2usize, required / 2, required + 8] {
            let (got, stats) =
                classify_parallel_with_budget(&model, opts, &imgs, 16, 4, budget);
            assert_eq!(got, want, "budget={budget}");
            assert_eq!(stats.inferences, 50);
        }
    }

    #[test]
    fn parallel_deterministic_given_threads() {
        let model = tiny_model(64, 8, 4, 56);
        let imgs = images(40, 64);
        let opts = PipelineOptions::default(); // analog noise
        let (a, _) = classify_parallel(&model, opts, &imgs, 8, 4);
        let (b, _) = classify_parallel(&model, opts, &imgs, 8, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn parallel_deterministic_across_thread_counts() {
        // the shared-pool path goes further than the seed contract: with
        // per-image noise streams the result is independent of the worker
        // count entirely — including when the worker count changes the
        // plan's replica layout
        let model = tiny_model(64, 8, 4, 58);
        let imgs = images(30, 64);
        let opts = PipelineOptions::default(); // analog noise
        let (one, _) = classify_parallel(&model, opts, &imgs, 8, 1);
        for threads in [2, 3, 5, 8] {
            let (many, _) = classify_parallel(&model, opts, &imgs, 8, threads);
            assert_eq!(one, many, "threads={threads}");
        }
    }

    #[test]
    fn stats_merge_counts_everything() {
        let model = tiny_model(64, 8, 4, 57);
        let imgs = images(30, 64);
        let opts = PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        };
        let (_, stats) = classify_parallel(&model, opts, &imgs, 8, 3);
        assert_eq!(stats.inferences, 30);
        assert!(stats.events.searches > 0);
        assert!(stats.cycles > 0);
    }
}
