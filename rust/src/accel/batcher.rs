//! Request batcher: groups incoming inference requests so the pipeline can
//! amortise weight loads and voltage retunes across a batch (paper §V-B).
//!
//! Policy: flush when `max_batch` requests are pending, or when the oldest
//! pending request has waited `max_wait`.  This is the classic dynamic-
//! batching latency/throughput dial: larger batches amortise the 33
//! per-batch retunes over more images but add queueing delay.

use std::time::{Duration, Instant};

use crate::util::bitops::BitVec;

/// A pending inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Tenant the request targets (0 for single-model servers).  A
    /// multi-tenant server keeps one batcher lane per tenant, so a
    /// drained batch is always tenant-homogeneous.
    pub tenant: usize,
    pub image: BitVec,
    pub enqueued: Instant,
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 256,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// FIFO batcher.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    queue: Vec<Request>,
    next_id: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            queue: Vec::new(),
            next_id: 0,
        }
    }

    /// Enqueue an image for tenant 0; returns its request id.
    pub fn push(&mut self, image: BitVec) -> u64 {
        self.push_tagged(0, image)
    }

    /// Enqueue an image tagged with a tenant; returns its request id
    /// (unique within this batcher — a multi-tenant server uses one
    /// batcher lane per tenant and disambiguates by `Response::tenant`).
    pub fn push_tagged(&mut self, tenant: usize, image: BitVec) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push(Request {
            id,
            tenant,
            image,
            enqueued: Instant::now(),
        });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Should the current queue be flushed now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.first() {
            Some(first) => now.duration_since(first.enqueued) >= self.policy.max_wait,
            None => false,
        }
    }

    /// Take up to `max_batch` requests (FIFO order).
    pub fn drain_batch(&mut self) -> Vec<Request> {
        let n = self.queue.len().min(self.policy.max_batch);
        self.queue.drain(..n).collect()
    }

    /// Force-flush everything (shutdown).
    pub fn drain_all(&mut self) -> Vec<Request> {
        std::mem::take(&mut self.queue)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn img() -> BitVec {
        BitVec::ones(16)
    }

    #[test]
    fn flushes_at_max_batch() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(100),
        });
        b.push(img());
        b.push(img());
        assert!(!b.ready(Instant::now()));
        b.push(img());
        assert!(b.ready(Instant::now()));
        let batch = b.drain_batch();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].id, 0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flushes_on_timeout() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(1),
        });
        b.push(img());
        assert!(!b.ready(Instant::now()));
        assert!(b.ready(Instant::now() + Duration::from_millis(5)));
    }

    #[test]
    fn drain_batch_caps_at_policy() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::ZERO,
        });
        for _ in 0..5 {
            b.push(img());
        }
        assert_eq!(b.drain_batch().len(), 2);
        assert_eq!(b.pending(), 3);
        assert_eq!(b.drain_all().len(), 3);
    }

    #[test]
    fn tenant_tags_ride_along() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.push(img()); // untagged requests land on tenant 0
        b.push_tagged(3, img());
        let batch = b.drain_all();
        assert_eq!(batch[0].tenant, 0);
        assert_eq!(batch[1].tenant, 3);
    }

    #[test]
    fn ids_monotone_fifo() {
        let mut b = Batcher::new(BatchPolicy::default());
        let a = b.push(img());
        let c = b.push(img());
        assert!(c > a);
        let batch = b.drain_all();
        assert_eq!(batch[0].id, a);
        assert_eq!(batch[1].id, c);
    }
}
