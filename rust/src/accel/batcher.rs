//! Request batcher: groups incoming inference requests so the pipeline can
//! amortise weight loads and voltage retunes across a batch (paper §V-B).
//!
//! Closing policy — the serving engine's "lane" stage: a batch closes when
//! `max_batch` requests are pending, *or* when the oldest pending request
//! has spent half of its latency budget queueing (the half-budget deadline
//! rule: half the budget is reserved for service + downstream time, so a
//! request never burns its whole budget waiting for co-batched peers).
//! Requests admitted without an explicit budget default to
//! `2 × max_wait`, which makes the half-budget rule reduce to the classic
//! "oldest waited `max_wait`" timeout dial.
//!
//! Time enters exclusively as [`Timestamp`]s handed in by the caller (the
//! engine reads its [`crate::server::Clock`] once per scheduler tick) —
//! the batcher itself never consults a time source, so closing decisions
//! are replayable under simulated time.
//!
//! The queue is a `VecDeque`: draining a batch pops a front range in
//! O(batch) — the previous `Vec` + `drain(..n)` shifted the entire
//! remainder on every batch close, an O(pending) tax per batch that
//! dominated exactly when the server was backlogged.

use std::collections::VecDeque;
use std::time::Duration;

use crate::server::clock::Timestamp;
use crate::util::bitops::BitVec;

/// A pending inference request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Lane-unique id, assigned in admission order.  Doubles as the
    /// request's noise-stream index: batches drain FIFO, so a drained
    /// batch covers the contiguous stream range `[batch[0].id, +len)`
    /// and the executor can replay exactly the streams a sequential run
    /// would have used (rejected submissions never consume an id).
    pub id: u64,
    /// Tenant the request targets (0 for single-model servers).  A
    /// multi-tenant server keeps one batcher lane per tenant, so a
    /// drained batch is always tenant-homogeneous.
    pub tenant: usize,
    pub image: BitVec,
    /// Admission time (engine clock).
    pub enqueued: Timestamp,
    /// End-to-end latency budget; the lane closes its batch once half of
    /// this is spent queueing (module docs).
    pub budget: Duration,
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    /// Queueing-delay dial: requests without an explicit budget get
    /// `2 × max_wait`, so their batch closes after `max_wait` in queue.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 256,
            max_wait: Duration::from_millis(2),
        }
    }
}

impl BatchPolicy {
    /// Latency budget assumed for requests admitted without one.
    pub fn default_budget(&self) -> Duration {
        self.max_wait * 2
    }
}

/// FIFO batcher with deadline-aware closing.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    queue: VecDeque<Request>,
    next_id: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            queue: VecDeque::new(),
            next_id: 0,
        }
    }

    /// Enqueue an image for tenant 0 at time `now`; returns its id.
    pub fn push(&mut self, image: BitVec, now: Timestamp) -> u64 {
        self.push_tagged(0, image, now)
    }

    /// Enqueue a tenant-tagged image with the policy's default budget.
    pub fn push_tagged(&mut self, tenant: usize, image: BitVec, now: Timestamp) -> u64 {
        self.push_with_budget(tenant, image, now, self.policy.default_budget())
    }

    /// Enqueue with an explicit latency budget; returns the request id
    /// (unique within this batcher — a multi-tenant server uses one
    /// batcher lane per tenant and disambiguates by `Response::tenant`).
    pub fn push_with_budget(
        &mut self,
        tenant: usize,
        image: BitVec,
        now: Timestamp,
        budget: Duration,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request {
            id,
            tenant,
            image,
            enqueued: now,
            budget,
        });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// The lane's batching policy (the engine reads it to resolve the
    /// default per-request latency budget at the ingress boundary).
    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// Should the current queue be flushed now?  True when full, or when
    /// the oldest request has spent half its budget queueing.
    pub fn ready(&self, now: Timestamp) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.front() {
            Some(first) => now.saturating_sub(first.enqueued) >= first.budget / 2,
            None => false,
        }
    }

    /// When the current queue next becomes ready, if ever: `None` when
    /// empty, the oldest request's enqueue time when the queue is
    /// already full (ready immediately), otherwise the oldest request's
    /// half-budget deadline.  The engine's parked workers sleep until
    /// this instant instead of spin-polling `ready`.
    pub fn next_deadline(&self) -> Option<Timestamp> {
        let first = self.queue.front()?;
        if self.queue.len() >= self.policy.max_batch {
            return Some(first.enqueued);
        }
        Some(first.enqueued + first.budget / 2)
    }

    /// Take up to `max_batch` requests (FIFO order).
    pub fn drain_batch(&mut self) -> Vec<Request> {
        let n = self.queue.len().min(self.policy.max_batch);
        self.queue.drain(..n).collect()
    }

    /// Force-flush everything (shutdown).
    pub fn drain_all(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn img() -> BitVec {
        BitVec::ones(16)
    }

    fn ms(n: u64) -> Timestamp {
        Duration::from_millis(n)
    }

    #[test]
    fn flushes_at_max_batch() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_secs(100),
        });
        b.push(img(), ms(0));
        b.push(img(), ms(0));
        assert!(!b.ready(ms(0)));
        b.push(img(), ms(0));
        assert!(b.ready(ms(0)));
        let batch = b.drain_batch();
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].id, 0);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flushes_when_half_the_default_budget_is_spent() {
        // default budget = 2×max_wait, so the half-budget rule closes the
        // batch after exactly max_wait in queue — the classic timeout
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(1),
        });
        b.push(img(), ms(0));
        assert!(!b.ready(ms(0)));
        assert!(b.ready(ms(1)), "half of the 2 ms default budget spent");
        assert!(b.ready(ms(5)));
    }

    #[test]
    fn explicit_budget_overrides_the_policy_timeout() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(1),
        });
        b.push_with_budget(0, img(), ms(0), Duration::from_millis(10));
        assert!(!b.ready(ms(1)), "policy max_wait must not close it");
        assert!(!b.ready(ms(4)));
        assert!(b.ready(ms(5)), "half of the 10 ms budget spent");
    }

    #[test]
    fn readiness_tracks_the_oldest_request() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(2),
        });
        b.push(img(), ms(0));
        b.push(img(), ms(3));
        // oldest (t=0, half-budget 2 ms) governs, not the newcomer
        assert!(b.ready(ms(2)));
        b.drain_batch();
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn drain_batch_caps_at_policy() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::ZERO,
        });
        for _ in 0..5 {
            b.push(img(), ms(0));
        }
        assert_eq!(b.drain_batch().len(), 2);
        assert_eq!(b.pending(), 3);
        assert_eq!(b.drain_all().len(), 3);
    }

    #[test]
    fn next_deadline_tracks_the_closing_rule() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 2,
            max_wait: Duration::from_millis(2),
        });
        assert_eq!(b.next_deadline(), None, "empty queue: nothing to wait for");
        b.push_with_budget(0, img(), ms(3), Duration::from_millis(10));
        // half the 10 ms budget queues before the batch closes
        assert_eq!(b.next_deadline(), Some(ms(8)));
        assert!(!b.ready(ms(7)));
        assert!(b.ready(ms(8)), "ready exactly at the reported deadline");
        // a second request fills the batch: ready immediately
        b.push_with_budget(0, img(), ms(4), Duration::from_millis(10));
        assert_eq!(b.next_deadline(), Some(ms(3)), "full queue is due now");
        assert!(b.ready(ms(4)));
    }

    #[test]
    fn tenant_tags_ride_along() {
        let mut b = Batcher::new(BatchPolicy::default());
        b.push(img(), ms(0)); // untagged requests land on tenant 0
        b.push_tagged(3, img(), ms(0));
        let batch = b.drain_all();
        assert_eq!(batch[0].tenant, 0);
        assert_eq!(batch[1].tenant, 3);
    }

    #[test]
    fn ids_monotone_fifo() {
        let mut b = Batcher::new(BatchPolicy::default());
        let a = b.push(img(), ms(0));
        let c = b.push(img(), ms(0));
        assert!(c > a);
        let batch = b.drain_all();
        assert_eq!(batch[0].id, a);
        assert_eq!(batch[1].id, c);
    }

    #[test]
    fn large_backlog_drains_fifo_in_policy_batches() {
        // the VecDeque queue: a deep backlog drains as contiguous FIFO
        // id ranges without shifting the remainder on every close (the
        // old Vec::drain(..n) paid O(pending) per batch)
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 64,
            max_wait: Duration::ZERO,
        });
        let n = 50_000u64;
        for _ in 0..n {
            b.push(img(), ms(0));
        }
        let mut seen = 0u64;
        while b.pending() > 0 {
            let batch = b.drain_batch();
            assert!(batch.len() == 64 || b.pending() == 0);
            for r in &batch {
                assert_eq!(r.id, seen, "FIFO order broken");
                seen += 1;
            }
        }
        assert_eq!(seen, n);
        // the drained batcher keeps assigning fresh ids
        assert_eq!(b.push(img(), ms(1)), n);
    }
}
