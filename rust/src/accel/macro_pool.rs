//! Multi-macro sharded execution engine with persistent weight residency —
//! for one model ([`MacroPool`]) or several tenants sharing one macro
//! budget ([`MultiPool`]).
//!
//! The single-macro [`Pipeline`] reprograms every layer's rows into one
//! simulated 128-kbit macro on **every batch** and retunes the rails for
//! every output threshold of every batch — pure overhead at steady state.
//! A `MacroPool` instead executes a [`PlacementPlan`] built by
//! [`super::planner`] against an explicit macro budget:
//!
//! * every hidden-layer *load* (one segment's neuron chunk that fits the
//!   configured row count) gets at least one dedicated macro, programmed
//!   **once** and parked at the layer's midpoint operating point; surplus
//!   budget buys *replicas* of the largest loads so parallel workers
//!   search a free replica instead of serialising on one mutex;
//! * the output layer's rows are programmed into `pinned + shared` slot
//!   macros.  Pinned slots park one **operating point**'s calibrated
//!   (V_ref, V_eval, V_st) triple forever — schedule positions with equal
//!   threshold values share the point, and the slot ([`PlacementPlan`]'s
//!   `pin_slot`/`point_of`).  Shared slots serve the remaining points,
//!   parking one triple at a time and paying a tracked retune when the
//!   sweep switches operating points (LRU over parked points);
//! * under a **sub-minimum budget** (fewer macros than hidden loads + 1)
//!   the plan *cold-spills* its smallest hidden loads: they are
//!   reprogrammed into the shared funnel slot per batch while the hottest
//!   loads stay resident — strictly less programming than the reload
//!   scheduler, which reloads *every* load.  Only budgets below the
//!   spill floor (2 macros, or full residency for single-load models)
//!   fall back to reload ([`Pipeline`]).
//!
//! The pool also measures a per-schedule-position **traffic histogram**
//! ([`MacroPool::take_output_traffic`]); feeding it back into
//! [`MacroPool::with_traffic`] re-plans the pinned set against observed
//! access frequencies instead of the schedule prefix, which beats the
//! cyclic `K − d` retune bound whenever the schedule (or live traffic)
//! is skewed.
//!
//! Concurrency & determinism: every macro sits behind a `Mutex`, so one
//! pool can be shared across worker threads (`classify_parallel`,
//! `Server`).  Replicas of a hidden load — and all output slots — are
//! seeded identically, so their frozen per-row variation is bit-identical
//! and an image's result does not depend on *which* replica or slot
//! served it; per-evaluation noise is drawn from a per-image stream
//! derived from (pool seed, image index) — see
//! [`CamArray::search_into_rng`].  Analog results are therefore
//! bit-stable across budgets, worker counts, and slot routing for every
//! *non-spill* plan; a cold-spilled load redraws its frozen variation at
//! each reprogram (exactly as the reload scheduler does), so spill plans
//! are deterministic per (seed, plan, batch sequence) but not bit-equal
//! to fully-resident placements in analog mode — and because concurrent
//! searchers reload the funnel in arrival order, analog spill pools
//! should be driven single-threaded (`classify_parallel` detects this
//! and falls back to reload shards).  Nominal-mode predictions are
//! bit-identical to the reload [`Pipeline`] under every plan, spill
//! included.  Only retune/stall *accounting* can vary with thread
//! interleaving on shared slots.

use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, RwLock};

use crate::bnn::mapping::program_row;
use crate::bnn::model::{MappedLayer, MappedModel};
use crate::cam::faults::{
    DegradedMode, FaultEvent, FaultKind, FaultPlan, FaultSite, HealthRegistry, HealthState,
    SiteGeometry,
};
use crate::cam::{CamArray, CamConfig};
use crate::sim::SimClock;
use crate::util::bitops::BitVec;
use crate::util::rng::{splitmix64, Rng};

use super::pipeline::{
    calibrate_hidden_points, calibrate_output_points, fit_width, io_cycles_per_image, plan_loads,
    program_load_into, resolve_schedule, BatchScratch, CategoryCost, Load,
};
use super::pipeline::{Pipeline, PipelineOptions, RunStats};
use super::planner::{self, HealthScores, MigrationPlan, PlacementPlan, TenantPlan, TenantSpec};
use super::scrub::{DetectedBy, FaultReport, RepairAction};
use super::voltage::CalibratedPoint;

/// Default number of simulated macros a pool may instantiate.
pub const DEFAULT_POOL_MACROS: usize = 64;

/// How the pool executes a model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolMode {
    /// Hidden loads (and some or all output thresholds) are resident.
    Resident,
    /// The budget cannot hold even a spill plan; the reload scheduler runs.
    Reload,
}

/// Deterministic per-macro seed derivation (stable across runs/threads).
fn macro_seed(base: u64, idx: u64) -> u64 {
    let mut s = base ^ idx.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

/// A fresh, identically-seeded macro for seed slot `seed_idx` — the one
/// constructor both `build` and live migration use, so a macro rebuilt
/// mid-migration carries frozen per-row variation bit-identical to the
/// one a fresh pool of the same plan would hold.
fn fresh_cam(opts: &PipelineOptions, cfg: CamConfig, seed_idx: u64) -> CamArray {
    let mut cam = CamArray::new(cfg, opts.pvt, opts.noise, macro_seed(opts.seed, seed_idx));
    cam.set_noise_scale(opts.noise_scale);
    cam
}

/// Operating-point classes of a schedule: a position's class is the first
/// position holding the same threshold value.  Calibration is a pure
/// function of the target (see `accel::voltage`), so equal values park
/// identical triples and retunes between them are free — the planner
/// exploits this by pinning whole points instead of prefix positions.
pub(crate) fn point_classes(schedule: &[i32]) -> Vec<usize> {
    (0..schedule.len())
        .map(|k| {
            schedule[..k]
                .iter()
                .position(|&u| u == schedule[k])
                .unwrap_or(k)
        })
        .collect()
}

/// One hidden load's replica set: identically seeded + programmed macros.
/// `acquire` hands out a free replica (round-robin try-lock) so parallel
/// workers only serialise when every replica is busy.
struct LoadSlots {
    replicas: Vec<Mutex<CamArray>>,
    next: AtomicUsize,
}

impl LoadSlots {
    fn acquire(&self) -> MutexGuard<'_, CamArray> {
        let n = self.replicas.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed) % n;
        for k in 0..n {
            if let Ok(guard) = self.replicas[(start + k) % n].try_lock() {
                return guard;
            }
        }
        self.replicas[start].lock().unwrap()
    }
}

/// What an output slot's rows currently hold: the class rows, or a
/// cold-spilled hidden load parked mid-reload in the funnel slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SlotRows {
    Output,
    Hidden(usize, usize), // (layer, load)
}

/// One output slot: its programmed rows plus the operating point the
/// rails are currently parked at (guarded together, so the parked record
/// can never drift from the actual rails).
struct OutputSlotState {
    cam: CamArray,
    /// Operating-point class currently parked (`None` after a spill use
    /// re-routed the rails to a hidden midpoint).
    parked: Option<usize>,
    rows: SlotRows,
}

/// LRU routing metadata for the shared output slots, keyed by operating
/// point.  Held briefly per dispatch; the authoritative parked state
/// lives in the slot.
struct SharedRouter {
    parked: Vec<Option<usize>>,
    stamp: Vec<u64>,
    tick: u64,
}

impl SharedRouter {
    fn new(n_slots: usize) -> Self {
        SharedRouter {
            parked: vec![None; n_slots],
            stamp: vec![0; n_slots],
            tick: 0,
        }
    }

    /// Slot index (within the shared set) to serve operating point
    /// `point`: a slot already parked there if any, else the least
    /// recently used.
    fn route(&mut self, point: usize) -> usize {
        self.tick += 1;
        let idx = match self.parked.iter().position(|&p| p == Some(point)) {
            Some(hit) => hit,
            None => {
                let (lru, _) = self
                    .stamp
                    .iter()
                    .enumerate()
                    .min_by_key(|&(_, &s)| s)
                    .expect("router has slots");
                self.parked[lru] = Some(point);
                lru
            }
        };
        self.stamp[idx] = self.tick;
        idx
    }
}

/// Aggregate device cost of applied live-migration steps, drained by
/// [`MacroPool::take_migration_stats`].  Migration work also lands in
/// the regular per-category device statistics (it *is* device work);
/// this record attributes it so callers can tell a migration's
/// programming price apart from the serving steady state.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MigrationStats {
    /// Migration steps executed.
    pub steps: u64,
    /// Rows programmed by those steps (one write cycle per row).
    pub row_writes: u64,
    /// Rail retunes those steps paid (re-parks; DAC settle stalls).
    pub retunes: u64,
}

impl MigrationStats {
    pub fn add(&mut self, other: &MigrationStats) {
        self.steps += other.steps;
        self.row_writes += other.row_writes;
        self.retunes += other.retunes;
    }

    /// Programming cycles spent (a row write is one cycle through the
    /// write circuitry — same unit as `RunStats::programming_cycles`).
    pub fn programming_cycles(&self) -> u64 {
        self.row_writes
    }
}

/// The placement-dependent half of a resident pool, swapped atomically
/// by live migration.  The batch path takes the state read-lock once
/// per batch; [`MacroPool::apply_migration_step`] takes the write lock
/// in the gap between batches — no batch ever observes a half-applied
/// step, and untouched macros (moved, not rebuilt) keep their
/// accumulated device accounting.
struct ResidentState {
    plan: PlacementPlan,
    /// Replica sets per hidden (layer, load), parked at the layer's
    /// midpoint operating point.  `None` = cold-spilled to the funnel.
    hidden_slots: Vec<Vec<Option<LoadSlots>>>,
    /// Output slots: the first `plan.pinned` are permanently parked, the
    /// rest are the LRU-shared set (slot `plan.pinned`, the first shared
    /// one, doubles as the spill funnel).
    output_slots: Vec<Mutex<OutputSlotState>>,
    router: Mutex<SharedRouter>,
}

/// One replaced macro earning re-admission: an identically-seeded
/// side-array that carries zero serving load while the scrub controller
/// canary-laps it ([`MacroPool::probation_scrub`]).
struct ProbationSlot {
    layer: usize,
    load: usize,
    /// Health-registry key — the quarantine ordinal this macro re-enters
    /// under (stable where live replica indices shift on removal).
    site: FaultSite,
    cam: CamArray,
    /// Canary cursor within the current lap.
    row: usize,
}

struct Resident {
    state: RwLock<ResidentState>,
    /// Host-device I/O cycles (shared 128-bit bus; same clock domain).
    io_clock: Mutex<SimClock>,
    /// Funnel retunes/row-writes spent serving cold-spilled hidden loads
    /// (moved from the output to the hidden category by `take_stats`).
    spill_cost: Mutex<CategoryCost>,
    /// Device cost of applied migration steps since the last drain.
    migration: Mutex<MigrationStats>,
    /// Accounting carried over from macros a migration retired: their
    /// accumulated cycles/events would otherwise vanish with the drop
    /// and deflate the next `take_stats` report.
    carry: Mutex<RunStats>,
    /// Per-schedule-position access counts (images × visits): the
    /// measured traffic histogram for [`MacroPool::with_traffic`] and
    /// the re-planning controller.  Positionally stable across
    /// migrations (the schedule never changes), so it lives outside the
    /// placement lock.
    traffic: Vec<AtomicU64>,
    /// Pending injected-fault events, sorted by activation image index
    /// ([`MacroPool::inject_fault_plan`]; `cam::faults` module docs).
    fault_plan: Mutex<Vec<FaultEvent>>,
    /// Image index of the earliest pending fault (`u64::MAX` = none) —
    /// the batch path's one-load fast gate, so an empty plan costs one
    /// relaxed atomic read per batch and nothing else.
    next_fault_at: AtomicU64,
    /// Fleet health supervisor: one ladder entry per physical macro
    /// (state machine in `cam::faults`).  Leaf lock — never held while
    /// taking another pool lock.
    health_reg: Mutex<HealthRegistry>,
    /// Replaced macros on probation: side-arrays serving nothing until
    /// their canary laps complete ([`MacroPool::un_quarantine`]).
    probation: Mutex<Vec<ProbationSlot>>,
}

/// Sharded multi-macro execution engine for one mapped model.
pub struct MacroPool<'m> {
    model: &'m MappedModel,
    opts: PipelineOptions,
    schedule: Vec<i32>,
    plans: Vec<Vec<Load>>,
    hidden_points: Vec<CalibratedPoint>,
    output_points: Vec<CalibratedPoint>,
    resident: Option<Resident>,
    /// Reload fallback when the budget cannot hold even a spill plan.
    fallback: Option<Mutex<Pipeline<'m>>>,
    /// Next per-image noise-stream index for [`MacroPool::classify_batch`].
    stream_cursor: AtomicU64,
    /// Free-list of per-batch scratch arenas: each concurrent
    /// `classify_batch` pops one (building it on first use) and parks it
    /// back afterwards, so the pool converges to one arena per peak
    /// concurrent caller and the steady-state batch path allocates
    /// nothing (pointer-stability test in this module).
    scratch: Mutex<Vec<BatchScratch>>,
    /// Current [`DegradedMode`] rung (0/1/2), maintained by the scrub
    /// controller and stamped into every [`MacroPool::take_stats`].
    health: AtomicU8,
}

impl<'m> MacroPool<'m> {
    /// Pool with the default macro budget ([`DEFAULT_POOL_MACROS`]).
    pub fn new(model: &'m MappedModel, opts: PipelineOptions) -> Self {
        Self::with_capacity(model, opts, DEFAULT_POOL_MACROS)
    }

    /// Macros *full* residency needs for `model` under `opts`: one per
    /// hidden load plus one per output-schedule threshold.  Budgets below
    /// this still run resident via threshold sharing (and, below hidden
    /// loads + 1, cold-spill); budgets above it buy hidden-load replicas.
    pub fn macros_required(model: &MappedModel, opts: &PipelineOptions) -> usize {
        Self::required_for(&plan_loads(model), resolve_schedule(model, opts).len())
    }

    /// Single source of the full-residency formula.
    fn required_for(plans: &[Vec<Load>], schedule_len: usize) -> usize {
        let hidden: usize = plans[..plans.len() - 1].iter().map(Vec::len).sum();
        hidden + schedule_len
    }

    /// Hidden-load row counts in planner shape (`[layer][load]`).
    fn load_rows(plans: &[Vec<Load>]) -> Vec<Vec<usize>> {
        plans[..plans.len() - 1]
            .iter()
            .map(|layer| layer.iter().map(|l| l.neuron_hi - l.neuron_lo).collect())
            .collect()
    }

    /// The placement the planner would choose for `model` under `budget`
    /// macros, without building anything (no calibration, no macros).
    /// `None` means the pool would run in reload mode; feasibility never
    /// depends on the worker count.
    pub fn plan_for(
        model: &MappedModel,
        opts: &PipelineOptions,
        budget: usize,
    ) -> Option<PlacementPlan> {
        let plans = plan_loads(model);
        let schedule = resolve_schedule(model, opts);
        planner::plan(&Self::load_rows(&plans), schedule.len(), budget, 1)
    }

    /// Pool with an explicit macro budget, planned for a single searcher
    /// (no hidden-load replicas; see [`Self::with_capacity_for_workers`]).
    pub fn with_capacity(model: &'m MappedModel, opts: PipelineOptions, max_macros: usize) -> Self {
        Self::with_capacity_for_workers(model, opts, max_macros, 1)
    }

    /// Pool with an explicit macro budget serving `workers` concurrent
    /// searchers.  The planner decides the placement (see
    /// [`super::planner`]): surplus budget beyond full threshold pinning
    /// buys hidden-load replicas, up to one per worker; budgets below
    /// hidden loads + 1 cold-spill; only below the spill floor does the
    /// pool fall back to the reload scheduler.
    pub fn with_capacity_for_workers(
        model: &'m MappedModel,
        opts: PipelineOptions,
        max_macros: usize,
        workers: usize,
    ) -> Self {
        let schedule = resolve_schedule(model, &opts);
        let plans = plan_loads(model);
        let plan = planner::plan(&Self::load_rows(&plans), schedule.len(), max_macros, workers);
        Self::build(model, opts, schedule, plans, plan)
    }

    /// Pool planned against a measured per-position traffic histogram
    /// (`traffic[k]` = accesses of schedule position `k`, e.g. from
    /// [`Self::take_output_traffic`] of a previous deployment): schedule
    /// positions with equal threshold values are grouped into one
    /// operating point and the hottest points pin first — at most the
    /// prefix rule's `K − d` retunes/batch, strictly fewer on skew.
    pub fn with_traffic(
        model: &'m MappedModel,
        opts: PipelineOptions,
        max_macros: usize,
        workers: usize,
        traffic: &[u64],
    ) -> Self {
        let schedule = resolve_schedule(model, &opts);
        // an empty histogram (a reload-mode pool measured nothing) means
        // uniform traffic; anything else must cover every position
        assert!(
            traffic.is_empty() || traffic.len() == schedule.len(),
            "one count per schedule position (or an empty histogram)"
        );
        let plans = plan_loads(model);
        let points = point_classes(&schedule);
        let plan = planner::plan_traffic(
            &Self::load_rows(&plans),
            &points,
            Some(traffic),
            None,
            max_macros,
            workers,
        );
        Self::build(model, opts, schedule, plans, plan)
    }

    /// Pool executing an externally built [`PlacementPlan`] — the
    /// multi-tenant path: [`MultiPool`] partitions one budget into per-
    /// tenant plans and builds each tenant through here.  The plan's
    /// shape must match the model's load plans and active schedule.
    pub fn with_plan(model: &'m MappedModel, opts: PipelineOptions, plan: PlacementPlan) -> Self {
        let schedule = resolve_schedule(model, &opts);
        let plans = plan_loads(model);
        assert_eq!(plan.schedule_len, schedule.len(), "plan schedule mismatch");
        let rows = Self::load_rows(&plans);
        assert_eq!(plan.hidden_replicas.len(), rows.len(), "plan layer mismatch");
        for (p, r) in plan.hidden_replicas.iter().zip(&rows) {
            assert_eq!(p.len(), r.len(), "plan load mismatch");
        }
        Self::build(model, opts, schedule, plans, Some(plan))
    }

    fn build(
        model: &'m MappedModel,
        opts: PipelineOptions,
        schedule: Vec<i32>,
        plans: Vec<Vec<Load>>,
        plan: Option<PlacementPlan>,
    ) -> Self {
        let out_layer = model.layers.last().expect("model has layers");
        assert_eq!(out_layer.n_seg(), 1, "output layer must fit one CAM word");
        let out_idx = model.layers.len() - 1;
        assert_eq!(plans[out_idx].len(), 1, "output layer fits one load");

        // calibration (a voltage grid search per hidden layer + per
        // threshold) only runs for the resident path; the reload fallback's
        // Pipeline performs its own identical calibration internally
        let (resident, fallback, hidden_points, output_points) = if let Some(plan) = plan {
            let hidden_points = calibrate_hidden_points(model, opts.pvt);
            let output_points = calibrate_output_points(model, &schedule, opts.pvt);
            // replicas of a load (and all output slots) share one seed, so
            // frozen per-row variation is identical and results never
            // depend on which replica served an image; spilled loads still
            // consume a seed index so placements stay seed-stable across
            // budgets — and the index is a pure function of (layer, load),
            // so live migration can rebuild any macro bit-identically
            let mk_cam = |cfg: CamConfig, seed_idx: u64| fresh_cam(&opts, cfg, seed_idx);
            let mut seed_idx = 0u64;
            let mut hidden_slots = Vec::with_capacity(out_idx);
            for (li, layer) in model.layers[..out_idx].iter().enumerate() {
                let cfg = CamConfig::fitting(layer.seg_width)
                    .unwrap_or_else(|| panic!("word width {} unsupported", layer.seg_width));
                let mut slots = Vec::with_capacity(plans[li].len());
                for (di, load) in plans[li].iter().enumerate() {
                    let n_replicas = plan.hidden_replicas[li][di];
                    let built = (n_replicas > 0).then(|| {
                        let replicas = (0..n_replicas)
                            .map(|_| {
                                let mut cam = mk_cam(cfg, seed_idx);
                                program_load_into(&mut cam, layer, load);
                                cam.set_voltages(hidden_points[li].voltages);
                                Mutex::new(cam)
                            })
                            .collect();
                        LoadSlots {
                            replicas,
                            next: AtomicUsize::new(0),
                        }
                    });
                    seed_idx += 1;
                    slots.push(built);
                }
                hidden_slots.push(slots);
            }
            let out_cfg = CamConfig::fitting(out_layer.seg_width)
                .expect("output word width unsupported");
            let out_load = &plans[out_idx][0];
            // a pinned slot parks the triple of the first schedule
            // position it serves (all its positions share the point)
            let rep_of_slot: Vec<usize> = (0..plan.pinned)
                .map(|s| {
                    plan.pin_slot
                        .iter()
                        .position(|&p| p == Some(s))
                        .expect("pinned slot serves a position")
                })
                .collect();
            let output_slots: Vec<Mutex<OutputSlotState>> = (0..plan.output_macros())
                .map(|slot| {
                    let mut cam = mk_cam(out_cfg, seed_idx);
                    program_load_into(&mut cam, out_layer, out_load);
                    let parked = if slot < plan.pinned {
                        let k = rep_of_slot[slot];
                        cam.set_voltages(output_points[k].voltages);
                        Some(plan.point_of[k])
                    } else {
                        None
                    };
                    Mutex::new(OutputSlotState {
                        cam,
                        parked,
                        rows: SlotRows::Output,
                    })
                })
                .collect();
            let router = Mutex::new(SharedRouter::new(plan.shared_slots));
            let traffic = (0..plan.schedule_len).map(|_| AtomicU64::new(0)).collect();
            (
                Some(Resident {
                    state: RwLock::new(ResidentState {
                        plan,
                        hidden_slots,
                        output_slots,
                        router,
                    }),
                    io_clock: Mutex::new(SimClock::new()),
                    spill_cost: Mutex::new(CategoryCost::default()),
                    migration: Mutex::new(MigrationStats::default()),
                    carry: Mutex::new(RunStats::default()),
                    traffic,
                    fault_plan: Mutex::new(Vec::new()),
                    next_fault_at: AtomicU64::new(u64::MAX),
                    health_reg: Mutex::new(HealthRegistry::default()),
                    probation: Mutex::new(Vec::new()),
                }),
                None,
                hidden_points,
                output_points,
            )
        } else {
            (
                None,
                Some(Mutex::new(Pipeline::new(model, opts))),
                Vec::new(),
                Vec::new(),
            )
        };

        MacroPool {
            model,
            opts,
            schedule,
            plans,
            hidden_points,
            output_points,
            resident,
            fallback,
            stream_cursor: AtomicU64::new(0),
            scratch: Mutex::new(Vec::new()),
            health: AtomicU8::new(0),
        }
    }

    pub fn mode(&self) -> PoolMode {
        if self.resident.is_some() {
            PoolMode::Resident
        } else {
            PoolMode::Reload
        }
    }

    /// The placement plan backing a resident pool (`None` in reload
    /// mode).  Returned by value: live migration can swap the plan
    /// between batches, so callers get a consistent snapshot instead of
    /// a reference into the placement lock.
    pub fn plan(&self) -> Option<PlacementPlan> {
        self.resident
            .as_ref()
            .map(|r| r.state.read().unwrap().plan.clone())
    }

    /// Simulated macros instantiated by this pool (1 in reload mode).
    pub fn n_macros(&self) -> usize {
        match &self.resident {
            Some(r) => r.state.read().unwrap().plan.macros_used(),
            None => 1,
        }
    }

    /// Hidden-load row counts in planner shape (`[layer][load]`) — the
    /// migration cost model prices steps in programmed rows, which live
    /// in the load plans, not in the [`PlacementPlan`].
    pub fn hidden_load_rows(&self) -> Vec<Vec<usize>> {
        Self::load_rows(&self.plans)
    }

    /// Programmed rows of the output load (every output slot holds them).
    pub fn output_rows(&self) -> usize {
        let out = &self.plans[self.plans.len() - 1][0];
        out.neuron_hi - out.neuron_lo
    }

    /// Operating-point classes of the active schedule (planner input).
    pub fn schedule_points(&self) -> Vec<usize> {
        point_classes(&self.schedule)
    }

    pub fn schedule(&self) -> &[i32] {
        &self.schedule
    }

    pub fn options(&self) -> &PipelineOptions {
        &self.opts
    }

    /// Calibrated output operating points (diagnostics; empty in reload
    /// mode — the fallback `Pipeline` owns its own calibration).
    pub fn output_points(&self) -> &[CalibratedPoint] {
        &self.output_points
    }

    /// Calibrated hidden midpoint per non-output layer (diagnostics;
    /// empty in reload mode).
    pub fn hidden_points(&self) -> &[CalibratedPoint] {
        &self.hidden_points
    }

    /// Drain the measured per-schedule-position access histogram (counts
    /// accumulate per served image per sweep visit).  Feed this back into
    /// [`Self::with_traffic`] to re-plan the pinned set against observed
    /// traffic instead of the schedule prefix.  Empty in reload mode —
    /// the planner treats an empty histogram as uniform, so the feedback
    /// loop is safe regardless of the previous deployment's mode.
    pub fn take_output_traffic(&self) -> Vec<u64> {
        match &self.resident {
            Some(r) => r.traffic.iter().map(|a| a.swap(0, Ordering::Relaxed)).collect(),
            None => Vec::new(),
        }
    }

    /// Per-image noise stream: independent of thread scheduling, derived
    /// from (pool seed, global image index).
    fn image_rng(&self, global_idx: u64) -> Rng {
        Rng::new(self.opts.seed ^ 0xA11A_0F0E_5EED_0001, global_idx)
    }

    /// Scratch arenas currently parked in the free-list (diagnostics:
    /// the pool converges to one arena per peak number of concurrent
    /// `classify_batch` callers; quiescent pools park them all here).
    pub fn scratch_arenas(&self) -> usize {
        self.scratch.lock().unwrap().len()
    }

    /// Classify a batch; noise-stream indices assigned from the pool's
    /// internal cursor (serving path).
    pub fn classify_batch(&self, images: &[BitVec]) -> Vec<(Vec<u32>, usize)> {
        let base = self
            .stream_cursor
            .fetch_add(images.len() as u64, Ordering::Relaxed);
        self.classify_batch_at(images, base)
    }

    /// Classify a batch with explicit noise-stream base index `stream_base`
    /// (the sharded parallel path passes each image's global index so
    /// results do not depend on thread count or interleaving).
    pub fn classify_batch_at(
        &self,
        images: &[BitVec],
        stream_base: u64,
    ) -> Vec<(Vec<u32>, usize)> {
        self.classify_inner(images, stream_base, None)
    }

    /// Classify a batch sweeping only the given schedule positions (in
    /// the given order): the banded/partial-sweep serving mode.  Votes
    /// accumulate from the swept thresholds alone, so predictions are a
    /// coarser read than the full Algorithm-1 sweep — but they are
    /// bit-identical across pools of any placement of this model (the
    /// identical-seeding rule does not care which slot serves a point).
    /// Only the swept positions accrue traffic, so sustained banded
    /// workloads skew the measured histogram and the re-planning
    /// controller repins toward the band.  Resident pools only (the
    /// reload fallback has no per-position path).
    pub fn classify_batch_positions(
        &self,
        images: &[BitVec],
        stream_base: u64,
        positions: &[usize],
    ) -> Vec<(Vec<u32>, usize)> {
        assert!(
            self.resident.is_some(),
            "position-restricted sweeps need a resident pool"
        );
        self.classify_inner(images, stream_base, Some(positions))
    }

    fn classify_inner(
        &self,
        images: &[BitVec],
        stream_base: u64,
        positions: Option<&[usize]>,
    ) -> Vec<(Vec<u32>, usize)> {
        if images.is_empty() {
            return Vec::new();
        }
        if let Some(fb) = &self.fallback {
            return fb.lock().unwrap().classify_batch(images);
        }
        let resident = self
            .resident
            .as_ref()
            .expect("non-fallback pool always has a resident placement");
        // one placement read-lock per batch: migration steps apply under
        // the write lock in the gaps between batches, so no batch ever
        // waits on (or observes) a half-applied step
        let st = resident.state.read().unwrap();
        // injected-fault activation (virtual time): an event becomes
        // active on the first batch whose base stream index reaches its
        // `at_image`; the empty-plan fast path is this one atomic load
        if resident.next_fault_at.load(Ordering::Acquire) <= stream_base {
            self.activate_faults(resident, &st, stream_base);
        }
        // pop a scratch arena (first caller builds it); every buffer
        // below reshapes in place, so steady-state batches allocate
        // nothing beyond the returned votes
        let mut s = self.scratch.lock().unwrap().pop().unwrap_or_default();
        s.rngs.clear();
        s.rngs
            .extend((0..images.len() as u64).map(|i| self.image_rng(stream_base + i)));
        s.pack_inputs(images, self.model.layers[0].n_in());
        for layer_idx in 0..self.model.layers.len() - 1 {
            self.run_hidden(resident, &st, layer_idx, &mut s);
            // the hidden codes become the next layer's activation block
            std::mem::swap(&mut s.acts, &mut s.next);
        }
        self.run_output(resident, &st, &mut s, positions);
        let sweep_len = positions.map_or(self.schedule.len(), <[usize]>::len);
        resident
            .io_clock
            .lock()
            .unwrap()
            .tick(io_cycles_per_image(self.model, sweep_len) * images.len() as u64);
        let out = s.results(self.model.n_classes());
        self.scratch.lock().unwrap().push(s);
        out
    }

    /// Execute one hidden layer for the batch held in `s.acts` over the
    /// layer's resident load macros (cold-spilled loads reprogram into
    /// the funnel slot); leaves the packed hidden codes (majority across
    /// segments) in `s.next`.
    ///
    /// One [`CamArray::search_batch_rows_into_rngs`] call per load: the
    /// stored rows stream once per query tile, per-image noise streams
    /// advance exactly as the sequential path would, and the lock is
    /// held for one batched kernel instead of one search per image.
    fn run_hidden(
        &self,
        resident: &Resident,
        st: &ResidentState,
        layer_idx: usize,
        s: &mut BatchScratch,
    ) {
        let layer = &self.model.layers[layer_idx];
        let n = s.acts.rows();
        let n_out = layer.n_out();
        let n_seg = layer.n_seg();
        let cfg = CamConfig::fitting(layer.seg_width)
            .unwrap_or_else(|| panic!("word width {} unsupported", layer.seg_width));
        let width = cfg.width();
        s.seg_fires.clear();
        s.seg_fires.resize(n * n_out, 0);
        // resident rails were parked at the layer's midpoint at
        // construction — no set_voltages on the resident batch path
        for (load_idx, load) in self.plans[layer_idx].iter().enumerate() {
            let payload = (load.neuron_hi - load.neuron_lo) as u64
                * (layer.seg_bounds[load.seg + 1] - layer.seg_bounds[load.seg]) as u64;
            // the query block is repacked in place, never reallocated
            s.pack_queries(layer, load.seg, width);
            match &st.hidden_slots[layer_idx][load_idx] {
                Some(slots) => {
                    let mut cam = slots.acquire();
                    cam.search_batch_rows_into_rngs(
                        &s.queries,
                        &mut s.rngs,
                        &mut s.m,
                        &mut s.fires,
                    );
                    cam.events.useful_macs += payload * n as u64;
                }
                None => {
                    // cold-spill: reload this load into the shared funnel
                    // slot (the last output slot), park the layer midpoint,
                    // search, and attribute the funnel's cost to the hidden
                    // category
                    let mut slot = st.output_slots[st.plan.pinned].lock().unwrap();
                    let before = (slot.cam.events.retunes, slot.cam.events.row_writes);
                    let want = SlotRows::Hidden(layer_idx, load_idx);
                    if slot.rows != want {
                        program_load_into(&mut slot.cam, layer, load);
                        slot.rows = want;
                        slot.parked = None;
                    }
                    // counted by set_voltages; free when already parked here
                    slot.cam.set_voltages(self.hidden_points[layer_idx].voltages);
                    slot.cam.search_batch_rows_into_rngs(
                        &s.queries,
                        &mut s.rngs,
                        &mut s.m,
                        &mut s.fires,
                    );
                    slot.cam.events.useful_macs += payload * n as u64;
                    let after = (slot.cam.events.retunes, slot.cam.events.row_writes);
                    // picbnn: allow(lock-discipline) — leaf spill-cost mutex under the funnel-slot guard; strict slot→leaf order, leaf never taken first
                    let mut spill = resident.spill_cost.lock().unwrap();
                    spill.retunes += after.0 - before.0;
                    spill.row_writes += after.1 - before.1;
                }
            }
            for i in 0..n {
                // rows past the load are cleared and can never fire
                let base = i * n_out + load.neuron_lo;
                for row in s.fires.row_ones(i) {
                    s.seg_fires[base + row] += 1;
                }
            }
        }
        s.fold_majority(n_out, n_seg);
    }

    /// Output-layer threshold sweep over the hidden codes in `s.acts`:
    /// pinned operating points hit their permanently parked macro
    /// (positions of one point share a slot); the rest route through the
    /// shared slots, paying a retune only when the slot must switch
    /// operating points.  The funnel re-lands the class rows first when
    /// a cold-spilled load used it this batch.  Leaves the flat votes in
    /// `s.votes`.
    fn run_output(
        &self,
        resident: &Resident,
        st: &ResidentState,
        s: &mut BatchScratch,
        positions: Option<&[usize]>,
    ) {
        let out_idx = self.model.layers.len() - 1;
        let layer = self.model.layers.last().expect("model has layers");
        let out_load = &self.plans[out_idx][0];
        let n_cls = layer.n_out();
        let width = CamConfig::fitting(layer.seg_width)
            .unwrap_or_else(|| panic!("word width {} unsupported", layer.seg_width))
            .width();
        let n = s.acts.rows();
        // queries are threshold-independent: pack once per batch
        s.pack_queries(layer, 0, width);
        s.votes.clear();
        s.votes.resize(n * n_cls, 0);
        let payload = (layer.n_in() * n_cls) as u64;
        let pinned = st.plan.pinned;
        let mut sweep_position = |k: usize, s: &mut BatchScratch| {
            resident.traffic[k].fetch_add(n as u64, Ordering::Relaxed);
            let point = st.plan.point_of[k];
            let slot_idx = match st.plan.pin_slot[k] {
                Some(slot) => slot,
                None => pinned + st.router.lock().unwrap().route(point),
            };
            let mut slot = st.output_slots[slot_idx].lock().unwrap();
            if slot.rows != SlotRows::Output {
                program_load_into(&mut slot.cam, layer, out_load);
                slot.rows = SlotRows::Output;
                slot.parked = None;
            }
            if slot.parked != Some(point) {
                // switching operating points: the retune + stall is
                // counted by set_voltages (free if the triples coincide)
                slot.cam.set_voltages(self.output_points[k].voltages);
                slot.parked = Some(point);
            }
            let cam = &mut slot.cam;
            cam.search_batch_rows_into_rngs(&s.queries, &mut s.rngs, &mut s.m, &mut s.fires);
            cam.events.useful_macs += payload * n as u64;
            for i in 0..n {
                let base = i * n_cls;
                for c in s.fires.row_ones(i) {
                    s.votes[base + c] += 1;
                }
            }
        };
        match positions {
            None => {
                for k in 0..self.schedule.len() {
                    sweep_position(k, s);
                }
            }
            Some(ps) => {
                for &k in ps {
                    assert!(k < self.schedule.len(), "schedule position out of range");
                    sweep_position(k, s);
                }
            }
        }
    }

    /// Drain device statistics accumulated since the last call, summed
    /// across every macro in the pool (aggregate device work, not
    /// wall-clock: resident macros operate concurrently in silicon).
    /// Hidden-load and output-slot costs are attributed per category —
    /// funnel work done on behalf of cold-spilled hidden loads is moved
    /// to the hidden category.  Call between batches (quiescent pool) for
    /// exact attribution.
    pub fn take_stats(&self, inferences: u64) -> RunStats {
        if let Some(fb) = &self.fallback {
            let mut stats = fb.lock().unwrap().take_stats(inferences);
            stats.degraded = self.degraded_mode();
            return stats;
        }
        let resident = self
            .resident
            .as_ref()
            .expect("non-fallback pool always has a resident placement");
        let st = resident.state.read().unwrap();
        let mut stats = RunStats {
            inferences,
            macros: st.plan.macros_used(),
            degraded: self.degraded_mode(),
            ..RunStats::default()
        };
        let mut drain = |cam: &mut CamArray, cost: &mut CategoryCost| {
            stats.cycles += cam.clock.cycles;
            stats.stall_s += cam.clock.stall_s;
            stats.events.add(&cam.events);
            cost.retunes += cam.events.retunes;
            cost.row_writes += cam.events.row_writes;
            cam.reset_accounting();
        };
        let mut hidden_cost = CategoryCost::default();
        let mut output_cost = CategoryCost::default();
        for slots in &st.hidden_slots {
            for slot in slots.iter().flatten() {
                for replica in &slot.replicas {
                    drain(&mut replica.lock().unwrap(), &mut hidden_cost);
                }
            }
        }
        for slot in &st.output_slots {
            drain(&mut slot.lock().unwrap().cam, &mut output_cost);
        }
        // accounting of macros a migration retired mid-epoch — merged
        // before the spill reattribution so a retired funnel slot's
        // spill work still lands in the hidden category below
        let carry = std::mem::take(&mut *resident.carry.lock().unwrap());
        stats.cycles += carry.cycles;
        stats.stall_s += carry.stall_s;
        stats.events.add(&carry.events);
        hidden_cost.add(&carry.hidden_cost);
        output_cost.add(&carry.output_cost);
        let spill = std::mem::take(&mut *resident.spill_cost.lock().unwrap());
        output_cost.retunes = output_cost.retunes.saturating_sub(spill.retunes);
        output_cost.row_writes = output_cost.row_writes.saturating_sub(spill.row_writes);
        hidden_cost.add(&spill);
        stats.hidden_cost = hidden_cost;
        stats.output_cost = output_cost;
        let mut io = resident.io_clock.lock().unwrap();
        stats.cycles += io.cycles;
        stats.stall_s += io.stall_s;
        io.reset();
        stats
    }

    /// Execute step `k` of a [`MigrationPlan`] against the live pool:
    /// the placement transform ([`MigrationPlan::apply_step`]) plus the
    /// physical reconcile — new macros built with the identical-seeding
    /// rule (so the pool after any step prefix is bit-indistinguishable
    /// from a fresh pool of the transformed plan), pinned slots
    /// re-parked, retired macros dropped with their accounting carried
    /// into the next `take_stats`.  Runs under the placement write
    /// lock: call it in the gap between batches (the engine's
    /// maintenance seam does) and in-flight batches are never stalled
    /// mid-sweep or split across placements.
    ///
    /// Returns this step's device cost; the same cost accumulates into
    /// [`Self::take_migration_stats`].  Panics in reload mode and on a
    /// step that does not apply to the current plan.
    pub fn apply_migration_step(&self, mp: &MigrationPlan, k: usize) -> MigrationStats {
        let resident = self
            .resident
            .as_ref()
            .expect("live migration needs a resident pool");
        let mut st = resident.state.write().unwrap();
        let next = mp.apply_step(&st.plan, k);
        let cost = self.reconcile(resident, &mut st, next);
        // picbnn: allow(lock-discipline) — leaf migration-stats mutex under the placement write lock; strict placement→leaf order
        resident.migration.lock().unwrap().add(&cost);
        cost
    }

    /// Drain the device cost of migration steps applied since the last
    /// call (zero / empty in reload mode).
    pub fn take_migration_stats(&self) -> MigrationStats {
        match &self.resident {
            Some(r) => std::mem::take(&mut *r.migration.lock().unwrap()),
            None => MigrationStats::default(),
        }
    }

    // ------------------------------------------------------------------
    // Fault injection and self-healing (taxonomy in `cam::faults`, scrub
    // control loop in `accel::scrub`)
    // ------------------------------------------------------------------

    /// Queue a deterministic [`FaultPlan`] against the live pool.  Events
    /// activate in virtual time — on the first batch whose base stream
    /// index reaches their `at_image` — so the same plan against the same
    /// workload trace injects at identical points regardless of batch
    /// sizes, shard splits, or worker interleaving.  An empty plan costs
    /// one relaxed atomic load per batch and nothing else.  Resident
    /// pools only (the reload fallback is outside the fault model).
    pub fn inject_fault_plan(&self, plan: FaultPlan) {
        let resident = self
            .resident
            .as_ref()
            .expect("fault injection needs a resident pool");
        let mut queue = resident.fault_plan.lock().unwrap();
        queue.extend(plan.events);
        queue.sort_by_key(|e| e.at_image);
        let first = queue.first().map_or(u64::MAX, |e| e.at_image);
        resident.next_fault_at.store(first, Ordering::Release);
    }

    /// Drain and land every queued fault event due at `stream_base`.
    /// Out of line so the healthy batch path pays only the atomic gate.
    #[cold]
    fn activate_faults(&self, resident: &Resident, st: &ResidentState, stream_base: u64) {
        let mut queue = resident.fault_plan.lock().unwrap();
        while queue.first().is_some_and(|e| e.at_image <= stream_base) {
            let e = queue.remove(0);
            Self::apply_fault(resident, st, &e.site, &e.kind);
        }
        let first = queue.first().map_or(u64::MAX, |e| e.at_image);
        resident.next_fault_at.store(first, Ordering::Release);
    }

    /// Land one fault on the physical macro(s) its site names.  A site
    /// the current placement does not instantiate (a cold-spilled load,
    /// an out-of-range replica or slot) is void — silicon that was never
    /// built cannot fail.  `replica: None` injects into every live copy
    /// identically, preserving the rule that results never depend on
    /// which replica served an image — under faults too.  Replica
    /// indices past the live copies address the load's probation
    /// side-arrays in admission order, so drills can flake a macro
    /// mid-probation.
    fn apply_fault(resident: &Resident, st: &ResidentState, site: &FaultSite, kind: &FaultKind) {
        match *site {
            FaultSite::Hidden {
                layer,
                load,
                replica,
            } => {
                let live = st
                    .hidden_slots
                    .get(layer)
                    .and_then(|l| l.get(load))
                    .and_then(Option::as_ref);
                let n_live = live.map_or(0, |s| s.replicas.len());
                match replica {
                    Some(k) if k < n_live => {
                        let slots = live.expect("k < n_live implies live slots");
                        slots.replicas[k].lock().unwrap().inject_fault(kind);
                    }
                    Some(k) => {
                        let mut probation = resident.probation.lock().unwrap();
                        if let Some(p) = probation
                            .iter_mut()
                            .filter(|p| p.layer == layer && p.load == load)
                            .nth(k - n_live)
                        {
                            p.cam.inject_fault(kind);
                        }
                    }
                    None => {
                        let Some(slots) = live else {
                            return;
                        };
                        for m in &slots.replicas {
                            m.lock().unwrap().inject_fault(kind);
                        }
                    }
                }
            }
            FaultSite::Output { slot } => match slot {
                Some(i) => {
                    if let Some(s) = st.output_slots.get(i) {
                        s.lock().unwrap().cam.inject_fault(kind);
                    }
                }
                None => {
                    for s in &st.output_slots {
                        s.lock().unwrap().cam.inject_fault(kind);
                    }
                }
            },
        }
    }

    /// Geometry of every physical fault site the current placement
    /// instantiates, in scrub-cursor order: hidden loads by (layer,
    /// load), then output slots.  Cold-spilled loads are skipped — no
    /// resident silicon to fail or scrub.  Empty in reload mode.
    pub fn fault_sites(&self) -> Vec<SiteGeometry> {
        let Some(resident) = &self.resident else {
            return Vec::new();
        };
        let st = resident.state.read().unwrap();
        let out_idx = self.model.layers.len() - 1;
        let mut sites = Vec::new();
        for (li, layer) in self.model.layers[..out_idx].iter().enumerate() {
            let width = CamConfig::fitting(layer.seg_width).map_or(layer.seg_width, |c| c.width());
            for (di, load) in self.plans[li].iter().enumerate() {
                if let Some(slots) = st.hidden_slots[li][di].as_ref() {
                    sites.push(SiteGeometry {
                        site: FaultSite::Hidden {
                            layer: li,
                            load: di,
                            replica: None,
                        },
                        rows: load.neuron_hi - load.neuron_lo,
                        width,
                        replicas: slots.replicas.len(),
                    });
                }
            }
        }
        let out_layer = &self.model.layers[out_idx];
        let out_width =
            CamConfig::fitting(out_layer.seg_width).map_or(out_layer.seg_width, |c| c.width());
        let out_rows = self.output_rows();
        for i in 0..st.output_slots.len() {
            sites.push(SiteGeometry {
                site: FaultSite::Output { slot: Some(i) },
                rows: out_rows,
                width: out_width,
                replicas: 1,
            });
        }
        sites
    }

    /// Flat identical-seeding index of hidden load (`layer`, `load`) —
    /// the exact counter `build` and `reconcile` walk (spilled loads
    /// still consume an index), so a replica rebuilt here is
    /// bit-identical to a fresh pool's.
    fn hidden_seed_index(&self, layer: usize, load: usize) -> u64 {
        self.plans[..layer].iter().map(|p| p.len() as u64).sum::<u64>() + load as u64
    }

    /// The shared post-hidden seed index every output slot uses.
    fn output_seed_index(&self) -> u64 {
        self.plans[..self.plans.len() - 1]
            .iter()
            .map(|p| p.len() as u64)
            .sum()
    }

    /// The pool's graceful-degradation rung, as maintained by the scrub
    /// controller: stamped into every [`MacroPool::take_stats`] and
    /// checked at engine admission (`Refusing` sheds with a typed
    /// rejection instead of risking silent wrong answers).
    pub fn degraded_mode(&self) -> DegradedMode {
        match self.health.load(Ordering::Acquire) {
            0 => DegradedMode::Nominal,
            1 => DegradedMode::Failover,
            _ => DegradedMode::Refusing,
        }
    }

    /// Record the degradation rung (scrub controller only).
    pub fn set_degraded_mode(&self, mode: DegradedMode) {
        self.health.store(mode as u8, Ordering::Release);
    }

    /// Read-verify and canary-check `count` logical rows of one fault
    /// site starting at `row_lo`, repairing in place along the
    /// escalation ladder (rewrite → spare remap → [`RepairAction::NeedsRebuild`];
    /// rail drift → factory re-trim; stuck output rail → spare-leg
    /// swap).  The golden source is the mapped model itself —
    /// [`program_row`] is pure, so scrub needs no stored shadow copy.
    /// Appends one [`FaultReport`] per detection; returns rows verified
    /// per copy (0 for a void site or a reload pool).  Takes the
    /// placement read lock: safe to interleave with serving batches.
    pub fn scrub_rows(
        &self,
        site: &FaultSite,
        row_lo: usize,
        count: usize,
        drift_tol: f64,
        rng: &mut Rng,
        out: &mut Vec<FaultReport>,
    ) -> usize {
        let Some(resident) = &self.resident else {
            return 0;
        };
        let st = resident.state.read().unwrap();
        let out_idx = self.model.layers.len() - 1;
        let before = out.len();
        let scrubbed = match *site {
            FaultSite::Hidden {
                layer,
                load,
                replica,
            } => {
                let Some(slots) = st
                    .hidden_slots
                    .get(layer)
                    .and_then(|l| l.get(load))
                    .and_then(Option::as_ref)
                else {
                    return 0;
                };
                let lay = &self.model.layers[layer];
                let ld = &self.plans[layer][load];
                let mut scrubbed = 0;
                for (k, m) in slots.replicas.iter().enumerate() {
                    if replica.is_some_and(|want| want != k) {
                        continue;
                    }
                    let mut cam = m.lock().unwrap();
                    let (n, _) = Self::scrub_cam(
                        &mut cam, lay, ld, site, k, row_lo, count, drift_tol, false, rng, out,
                    );
                    scrubbed = n;
                }
                scrubbed
            }
            FaultSite::Output { slot } => {
                let mut scrubbed = 0;
                for (i, s) in st.output_slots.iter().enumerate() {
                    if slot.is_some_and(|want| want != i) {
                        continue;
                    }
                    let mut guard = s.lock().unwrap();
                    let sl = &mut *guard;
                    // the funnel slot may hold a cold-spilled hidden load
                    // right now: verify against what is *programmed*
                    let (lay, ld) = match sl.rows {
                        SlotRows::Output => (&self.model.layers[out_idx], &self.plans[out_idx][0]),
                        SlotRows::Hidden(li, di) => (&self.model.layers[li], &self.plans[li][di]),
                    };
                    let (n, rails_swapped) = Self::scrub_cam(
                        &mut sl.cam,
                        lay,
                        ld,
                        site,
                        i,
                        row_lo,
                        count,
                        drift_tol,
                        true,
                        rng,
                        out,
                    );
                    if rails_swapped {
                        // the spare DAC leg comes up at whatever codes the
                        // fault froze — force a re-park on next use
                        sl.parked = None;
                    }
                    scrubbed = n;
                }
                scrubbed
            }
        };
        if out.len() > before {
            // any detection demotes the site to Suspect on the health
            // ladder; clean full laps promote it back (scrub controller)
            let now = self.stream_cursor.load(Ordering::Relaxed);
            resident.health_reg.lock().unwrap().mark_suspect(*site, now);
        }
        scrubbed
    }

    /// The per-macro scrub ladder (invariants in `cam::faults`): rails
    /// first — a stuck rail swaps to its spare DAC leg on output slots
    /// (`rail_spare_leg`) and escalates to rebuild on hidden replicas;
    /// drift beyond `drift_tol` re-trims to factory — then `count` rows
    /// of read-verify against the golden mapping plus a canary search
    /// pair: the row's own pattern must fire (0 mismatches) and its
    /// complement must not (width mismatches), both far outside the
    /// metastable band, so the checks are deterministic in both noise
    /// modes and consume no draws for the row under test.  Returns
    /// (rows verified, rails swapped to the spare leg).
    #[allow(clippy::too_many_arguments)]
    fn scrub_cam(
        cam: &mut CamArray,
        layer: &MappedLayer,
        load: &Load,
        site: &FaultSite,
        copy: usize,
        row_lo: usize,
        count: usize,
        drift_tol: f64,
        rail_spare_leg: bool,
        rng: &mut Rng,
        out: &mut Vec<FaultReport>,
    ) -> (usize, bool) {
        fn canary_fires(
            cam: &mut CamArray,
            q: &BitVec,
            r: usize,
            m: &mut Vec<u32>,
            fires: &mut Vec<bool>,
            rng: &mut Rng,
        ) -> bool {
            cam.search_into_rng(q, m, fires, rng);
            fires.get(r).copied().unwrap_or(false)
        }
        let report = |row: Option<usize>, detected: DetectedBy, action: RepairAction| FaultReport {
            site: *site,
            copy,
            row,
            detected,
            action,
        };
        let mut rails_swapped = false;
        if cam.rails.any_stuck() {
            if rail_spare_leg {
                cam.rails.unstick_all();
                rails_swapped = true;
                out.push(report(None, DetectedBy::RailStuck, RepairAction::RailRepaired));
            } else {
                // hidden replicas have no spare leg: a whole-macro rebuild
                // is the only repair that restores retunability
                out.push(report(None, DetectedBy::RailStuck, RepairAction::NeedsRebuild));
                return (0, false);
            }
        }
        if cam.rails.max_drift() > drift_tol {
            cam.recalibrate_rails();
            out.push(report(None, DetectedBy::RailDrift, RepairAction::Recalibrated));
        }
        let rows = load.neuron_hi - load.neuron_lo;
        let width = cam.config().width();
        let hi = rows.min(row_lo + count);
        let mut m = Vec::new();
        let mut fires = Vec::new();
        let mut scrubbed = 0;
        for r in row_lo..hi {
            scrubbed += 1;
            let golden = fit_width(&program_row(layer, load.seg, load.neuron_lo + r), width);
            // (a) read-verify the stored pattern against the golden model
            let stored_ok = cam.read_row(r).is_some_and(|s| s.words() == golden.words());
            if !stored_ok {
                cam.rewrite_row(r, &golden);
                if cam.read_row(r).is_some_and(|s| s.words() == golden.words()) {
                    out.push(report(Some(r), DetectedBy::ReadVerify, RepairAction::Rewritten));
                } else if cam.remap_row_to_spare(r) {
                    // a stuck cell re-asserted through the rewrite: burn a
                    // spare (remap clears the row's recorded faults) and
                    // land the pattern on healthy silicon
                    cam.rewrite_row(r, &golden);
                    out.push(report(Some(r), DetectedBy::ReadVerify, RepairAction::Remapped));
                } else {
                    out.push(report(
                        Some(r),
                        DetectedBy::ReadVerify,
                        RepairAction::NeedsRebuild,
                    ));
                    continue;
                }
            }
            // (b) canary pair: catches dead rows and transients, which a
            // store readback cannot see (the MLSA, not the cells, lies)
            let mut anti = golden.clone();
            for c in 0..width {
                anti.flip(c);
            }
            let ok = canary_fires(cam, &golden, r, &mut m, &mut fires, rng)
                && !canary_fires(cam, &anti, r, &mut m, &mut fires, rng);
            if ok {
                continue;
            }
            // transient upsets self-clear: retry once before burning a spare
            let again = canary_fires(cam, &golden, r, &mut m, &mut fires, rng)
                && !canary_fires(cam, &anti, r, &mut m, &mut fires, rng);
            if again {
                out.push(report(Some(r), DetectedBy::Canary, RepairAction::SelfCleared));
            } else if cam.remap_row_to_spare(r) {
                cam.rewrite_row(r, &golden);
                let healed = canary_fires(cam, &golden, r, &mut m, &mut fires, rng)
                    && !canary_fires(cam, &anti, r, &mut m, &mut fires, rng);
                out.push(report(
                    Some(r),
                    DetectedBy::Canary,
                    if healed {
                        RepairAction::Remapped
                    } else {
                        RepairAction::NeedsRebuild
                    },
                ));
            } else {
                out.push(report(Some(r), DetectedBy::Canary, RepairAction::NeedsRebuild));
            }
        }
        (scrubbed, rails_swapped)
    }

    /// Carry a retired macro's accounting into the next `take_stats`
    /// (the same bookkeeping as migration's retire path).
    fn retire_into_carry(resident: &Resident, cam: &CamArray, output: bool) {
        let mut carry = resident.carry.lock().unwrap();
        carry.cycles += cam.clock.cycles;
        carry.stall_s += cam.clock.stall_s;
        carry.events.add(&cam.events);
        let cat = if output {
            &mut carry.output_cost
        } else {
            &mut carry.hidden_cost
        };
        cat.retunes += cam.events.retunes;
        cat.row_writes += cam.events.row_writes;
    }

    /// Replace one hidden replica with a freshly built macro — fresh
    /// rails, fresh store, full spare budget, zero faults — programmed
    /// under the identical-seeding rule, so the rebuilt copy is
    /// bit-identical to a never-faulted one.  The self-healing
    /// escalation past the spare-row budget.  The retired macro's
    /// accounting carries into the next `take_stats`; the build cost
    /// stays on the new macro's meters.  Returns `false` for a void
    /// site or a reload pool.
    pub fn rebuild_replica(&self, layer: usize, load: usize, replica: usize) -> bool {
        let Some(resident) = &self.resident else {
            return false;
        };
        let st = resident.state.read().unwrap();
        let Some(slots) = st
            .hidden_slots
            .get(layer)
            .and_then(|l| l.get(load))
            .and_then(Option::as_ref)
        else {
            return false;
        };
        let Some(m) = slots.replicas.get(replica) else {
            return false;
        };
        let lay = &self.model.layers[layer];
        let cfg = CamConfig::fitting(lay.seg_width)
            .unwrap_or_else(|| panic!("word width {} unsupported", lay.seg_width));
        let mut cam = fresh_cam(&self.opts, cfg, self.hidden_seed_index(layer, load));
        program_load_into(&mut cam, lay, &self.plans[layer][load]);
        cam.set_voltages(self.hidden_points[layer].voltages);
        let mut guard = m.lock().unwrap();
        Self::retire_into_carry(resident, &guard, false);
        *guard = cam;
        true
    }

    /// Replace one output slot with a freshly built macro (shared seed
    /// index: bit-identical to any never-faulted slot).  Comes up
    /// unparked holding the class rows; the next sweep re-parks it at
    /// whatever point routes there (counted by `set_voltages`).
    pub fn rebuild_output_slot(&self, slot: usize) -> bool {
        let Some(resident) = &self.resident else {
            return false;
        };
        let st = resident.state.read().unwrap();
        let Some(s) = st.output_slots.get(slot) else {
            return false;
        };
        let out_idx = self.model.layers.len() - 1;
        let out_layer = &self.model.layers[out_idx];
        let out_cfg =
            CamConfig::fitting(out_layer.seg_width).expect("output word width unsupported");
        let mut cam = fresh_cam(&self.opts, out_cfg, self.output_seed_index());
        program_load_into(&mut cam, out_layer, &self.plans[out_idx][0]);
        let mut guard = s.lock().unwrap();
        Self::retire_into_carry(resident, &guard.cam, true);
        *guard = OutputSlotState {
            cam,
            parked: None,
            rows: SlotRows::Output,
        };
        true
    }

    /// Permanently remove a dying hidden replica from service — the
    /// escalation past the rebuild budget.  Runs under the placement
    /// write lock: call it in an inter-batch gap.  Surviving replicas
    /// keep serving (failover — bit-identical results, by identical
    /// seeding); removing the last copy cold-spills the load through the
    /// output funnel, which stays correct but reprograms per batch, so
    /// the scrub controller follows up with a planner-level re-plan that
    /// migrates capacity off the quarantined macro.  The plan's replica
    /// count is updated in place, so `PlacementPlan::diff` against a
    /// fresh target emits exactly the steps that move off the dying
    /// macro.  Returns surviving copies (`usize::MAX` for a void site).
    pub fn quarantine_replica(&self, layer: usize, load: usize, replica: usize) -> usize {
        let Some(resident) = &self.resident else {
            return usize::MAX;
        };
        let left = {
            let mut st = resident.state.write().unwrap();
            let Some(slot) = st.hidden_slots.get_mut(layer).and_then(|l| l.get_mut(load)) else {
                return usize::MAX;
            };
            let Some(slots) = slot.as_mut() else {
                return usize::MAX;
            };
            if replica >= slots.replicas.len() {
                return slots.replicas.len();
            }
            let removed = slots.replicas.remove(replica);
            Self::retire_into_carry(resident, &removed.into_inner().unwrap(), false);
            let left = slots.replicas.len();
            if left == 0 {
                *slot = None;
            }
            st.plan.hidden_replicas[layer][load] = left;
            left
        };
        // record the removed macro on the health ladder under a stable
        // quarantine ordinal (live replica indices shift on removal);
        // `un_quarantine` re-admits the lowest ordinal first
        let now = self.stream_cursor.load(Ordering::Relaxed);
        let mut reg = resident.health_reg.lock().unwrap();
        let ord = Self::quarantine_ordinal(&reg, layer, load);
        reg.quarantine(
            FaultSite::Hidden {
                layer,
                load,
                replica: Some(ord),
            },
            now,
        );
        left
    }

    /// Next free quarantine ordinal for a load: one past the entries
    /// already on the ladder (ordinals are never reused, so back-off
    /// counters survive re-quarantine of the same physical macro).
    fn quarantine_ordinal(reg: &HealthRegistry, layer: usize, load: usize) -> usize {
        reg.iter()
            .filter(|(s, _)| {
                matches!(**s, FaultSite::Hidden { layer: l, load: d, replica: Some(_) }
                    if l == layer && d == load)
            })
            .count()
    }

    /// Reshape the physical state to `next` (already validated by the
    /// plan transform).  Only macros whose assignment changed are
    /// touched: survivors move, never rebuild, so their frozen variation
    /// and accounting are untouched.
    fn reconcile(
        &self,
        resident: &Resident,
        st: &mut ResidentState,
        next: PlacementPlan,
    ) -> MigrationStats {
        let mut cost = MigrationStats {
            steps: 1,
            ..MigrationStats::default()
        };
        let mut carry = resident.carry.lock().unwrap();
        let out_idx = self.model.layers.len() - 1;
        // the retired macro's history must survive into take_stats
        let retire = |carry: &mut RunStats, cam: &CamArray, output: bool| {
            carry.cycles += cam.clock.cycles;
            carry.stall_s += cam.clock.stall_s;
            carry.events.add(&cam.events);
            let cat = if output {
                &mut carry.output_cost
            } else {
                &mut carry.hidden_cost
            };
            cat.retunes += cam.events.retunes;
            cat.row_writes += cam.events.row_writes;
        };
        // --- hidden loads: replica counts follow the plan ---
        let mut seed_idx = 0u64;
        for li in 0..out_idx {
            let layer = &self.model.layers[li];
            let cfg = CamConfig::fitting(layer.seg_width)
                .unwrap_or_else(|| panic!("word width {} unsupported", layer.seg_width));
            for (di, load) in self.plans[li].iter().enumerate() {
                let want = next.hidden_replicas[li][di];
                let slot = &mut st.hidden_slots[li][di];
                let have = slot.as_ref().map_or(0, |s| s.replicas.len());
                if want < have {
                    let removed = if want == 0 {
                        slot.take().expect("have > 0").replicas
                    } else {
                        slot.as_mut().expect("have > 0").replicas.split_off(want)
                    };
                    for replica in removed {
                        retire(&mut carry, &replica.into_inner().unwrap(), false);
                    }
                } else if want > have {
                    let slots = slot.get_or_insert_with(|| LoadSlots {
                        replicas: Vec::new(),
                        next: AtomicUsize::new(0),
                    });
                    for _ in have..want {
                        // identical seeding: the seed index is the flat
                        // hidden (layer, load) index, exactly as build()
                        // assigns it, so the rebuilt macro's frozen
                        // variation is bit-identical to a fresh pool's
                        let mut cam = fresh_cam(&self.opts, cfg, seed_idx);
                        program_load_into(&mut cam, layer, load);
                        cam.set_voltages(self.hidden_points[li].voltages);
                        cost.row_writes += cam.events.row_writes;
                        cost.retunes += cam.events.retunes;
                        slots.replicas.push(Mutex::new(cam));
                    }
                }
                seed_idx += 1;
            }
        }
        // --- output slots: count, then programming, then parking ---
        let out_layer = self.model.layers.last().expect("model has layers");
        let out_cfg =
            CamConfig::fitting(out_layer.seg_width).expect("output word width unsupported");
        let out_load = &self.plans[out_idx][0];
        let want_slots = next.output_macros();
        if want_slots < st.output_slots.len() {
            for slot in st.output_slots.split_off(want_slots) {
                retire(&mut carry, &slot.into_inner().unwrap().cam, true);
            }
        }
        for _ in st.output_slots.len()..want_slots {
            // every output slot shares the post-hidden seed index
            let mut cam = fresh_cam(&self.opts, out_cfg, seed_idx);
            program_load_into(&mut cam, out_layer, out_load);
            cost.row_writes += cam.events.row_writes;
            st.output_slots.push(Mutex::new(OutputSlotState {
                cam,
                parked: None,
                rows: SlotRows::Output,
            }));
        }
        // re-park the pinned prefix at its (possibly new) points; free
        // when the triples coincide, counted by set_voltages otherwise
        for s in 0..next.pinned {
            let k = next
                .pin_slot
                .iter()
                .position(|&p| p == Some(s))
                .expect("pinned slot serves a position");
            let slot = st.output_slots[s].get_mut().unwrap();
            if slot.rows != SlotRows::Output {
                // the slot served as the spill funnel before this step
                let before = slot.cam.events.row_writes;
                program_load_into(&mut slot.cam, out_layer, out_load);
                cost.row_writes += slot.cam.events.row_writes - before;
                slot.rows = SlotRows::Output;
                slot.parked = None;
            }
            let point = next.point_of[k];
            if slot.parked != Some(point) {
                let before = slot.cam.events.retunes;
                slot.cam.set_voltages(self.output_points[k].voltages);
                cost.retunes += slot.cam.events.retunes - before;
                slot.parked = Some(point);
            }
        }
        // shared-slot routing restarts whenever the funnel moved or
        // resized (slot indices are relative to the pinned prefix)
        if next.shared_slots != st.plan.shared_slots || next.pinned != st.plan.pinned {
            *st.router.get_mut().unwrap() = SharedRouter::new(next.shared_slots);
        }
        st.plan = next;
        cost
    }

    // --- fleet health: supervision ladder + canary-gated re-admission ---

    /// Snapshot of the macro health ladder (operator / metrics view).
    pub fn health_registry(&self) -> HealthRegistry {
        self.resident
            .as_ref()
            .map_or_else(HealthRegistry::default, |r| {
                r.health_reg.lock().unwrap().clone()
            })
    }

    /// Macros currently written off and awaiting operator re-admission.
    pub fn health_quarantined(&self) -> usize {
        self.resident
            .as_ref()
            .map_or(0, |r| r.health_reg.lock().unwrap().quarantined())
    }

    /// Record one clean scrub lap over `site` (`Suspect` → `Healthy`).
    pub fn health_lap_clean(&self, site: &FaultSite) {
        if let Some(r) = &self.resident {
            let now = self.stream_cursor.load(Ordering::Relaxed);
            r.health_reg.lock().unwrap().mark_clean(*site, now);
        }
    }

    /// Per-load health in planner shape (`hidden[layer][load]`), worst
    /// state wins per load: the load-level ladder entry carries
    /// `Healthy`/`Suspect`, quarantine ordinals carry
    /// `Quarantined`/`Probation`/`Readmitted`.  A load with written-off
    /// silicon stays penalized until the operator re-admits it and the
    /// canary laps pass — which is exactly what steers re-plans toward
    /// recovered capacity.  `quarantined_macros` shrinks the planner
    /// budget by the held-out silicon.
    pub fn health_scores(&self) -> HealthScores {
        let hidden_plans = &self.plans[..self.plans.len() - 1];
        let mut hidden: Vec<Vec<HealthState>> = hidden_plans
            .iter()
            .map(|p| vec![HealthState::Healthy; p.len()])
            .collect();
        let mut quarantined_macros = 0;
        if let Some(r) = &self.resident {
            // severity rank — the enum's declaration order is not one
            let rank = |s: HealthState| match s {
                HealthState::Healthy => 0,
                HealthState::Readmitted => 1,
                HealthState::Suspect => 2,
                HealthState::Probation => 3,
                HealthState::Quarantined => 4,
            };
            let reg = r.health_reg.lock().unwrap();
            for (site, h) in reg.iter() {
                if h.state == HealthState::Quarantined {
                    quarantined_macros += 1;
                }
                let FaultSite::Hidden { layer, load, .. } = *site else {
                    continue;
                };
                let Some(cell) = hidden.get_mut(layer).and_then(|l| l.get_mut(load)) else {
                    continue;
                };
                if rank(h.state) > rank(*cell) {
                    *cell = h.state;
                }
            }
        }
        HealthScores {
            hidden,
            quarantined_macros,
        }
    }

    /// Operator re-admission of a written-off macro on hidden load
    /// (`layer`, `load`): builds an identically-seeded side-array,
    /// programs the load into it, and parks it on probation — zero
    /// serving traffic until [`Self::probation_scrub`] credits the
    /// required consecutive clean canary laps.  Re-admits the lowest
    /// quarantined ordinal first.  Returns `false` when nothing on that
    /// load is quarantined (or the pool runs in reload mode).
    pub fn un_quarantine(&self, layer: usize, load: usize) -> bool {
        let Some(resident) = &self.resident else {
            return false;
        };
        if layer + 1 >= self.plans.len() || load >= self.plans[layer].len() {
            return false;
        }
        let now = self.stream_cursor.load(Ordering::Relaxed);
        let site = {
            let mut reg = resident.health_reg.lock().unwrap();
            let Some(site) = reg
                .iter()
                .find(|(s, h)| {
                    h.state == HealthState::Quarantined
                        && matches!(**s, FaultSite::Hidden { layer: l, load: d, replica: Some(_) }
                            if l == layer && d == load)
                })
                .map(|(s, _)| *s)
            else {
                return false;
            };
            reg.un_quarantine(site, now);
            site
        };
        // identical seeding: the probation macro is bit-identical to the
        // replica a never-faulted pool would hold for this load
        let lay = &self.model.layers[layer];
        let cfg = CamConfig::fitting(lay.seg_width)
            .unwrap_or_else(|| panic!("word width {} unsupported", lay.seg_width));
        let mut cam = fresh_cam(&self.opts, cfg, self.hidden_seed_index(layer, load));
        program_load_into(&mut cam, lay, &self.plans[layer][load]);
        cam.set_voltages(self.hidden_points[layer].voltages);
        resident.probation.lock().unwrap().push(ProbationSlot {
            layer,
            load,
            site,
            cam,
            row: 0,
        });
        true
    }

    /// Canary-lap every probation macro: read-verify each row against
    /// the golden mapping plus the fires / must-not-fire canary pair —
    /// strictly, with no retry and no repair; probation silicon has to
    /// prove itself, not be nursed.  Any anomaly fails the probation
    /// (re-quarantined, lap requirement doubled).  A slot earns at most
    /// one lap credit per call, so `required_laps` means that many
    /// consecutive clean maintenance turns.  Completing the requirement
    /// re-admits the macro as a live serving replica of its load
    /// (bit-identical to a never-faulted copy, by identical seeding).
    /// The canary patterns sit far outside the metastable band, so the
    /// pass is deterministic in both noise modes.
    pub fn probation_scrub(&self, rows_budget: usize, rng: &mut Rng) -> ProbationDelta {
        let Some(resident) = &self.resident else {
            return ProbationDelta::default();
        };
        let mut delta = ProbationDelta::default();
        let mut budget = rows_budget;
        let mut failed: Vec<FaultSite> = Vec::new();
        let mut lap_done: Vec<FaultSite> = Vec::new();
        {
            let mut slots = resident.probation.lock().unwrap();
            for slot in slots.iter_mut() {
                let lay = &self.model.layers[slot.layer];
                let ld = &self.plans[slot.layer][slot.load];
                let rows = ld.neuron_hi - ld.neuron_lo;
                let width = slot.cam.config().width();
                let mut m = Vec::new();
                let mut fires = Vec::new();
                let mut fires_at = |cam: &mut CamArray, q: &BitVec, r: usize, rng: &mut Rng| {
                    cam.search_into_rng(q, &mut m, &mut fires, rng);
                    fires.get(r).copied().unwrap_or(false)
                };
                while budget > 0 {
                    budget -= 1;
                    delta.rows_checked += 1;
                    let r = slot.row;
                    let golden = fit_width(&program_row(lay, ld.seg, ld.neuron_lo + r), width);
                    let stored_ok = slot
                        .cam
                        .read_row(r)
                        .is_some_and(|s| s.words() == golden.words());
                    let mut anti = golden.clone();
                    for c in 0..width {
                        anti.flip(c);
                    }
                    let ok = stored_ok
                        && fires_at(&mut slot.cam, &golden, r, rng)
                        && !fires_at(&mut slot.cam, &anti, r, rng);
                    if !ok {
                        failed.push(slot.site);
                        break;
                    }
                    slot.row += 1;
                    if slot.row >= rows {
                        slot.row = 0;
                        lap_done.push(slot.site);
                        break; // one lap credit per maintenance turn
                    }
                }
                if budget == 0 {
                    break;
                }
            }
            slots.retain(|s| !failed.contains(&s.site));
        }
        let now = self.stream_cursor.load(Ordering::Relaxed);
        let mut readmit: Vec<FaultSite> = Vec::new();
        {
            let mut reg = resident.health_reg.lock().unwrap();
            for site in &failed {
                reg.probation_failed(*site, now);
                delta.failures += 1;
            }
            for site in &lap_done {
                delta.laps += 1;
                if reg.canary_lap_passed(*site, now) {
                    readmit.push(*site);
                }
            }
        }
        if !readmit.is_empty() {
            let mut graduating = Vec::new();
            {
                let mut slots = resident.probation.lock().unwrap();
                let mut i = 0;
                while i < slots.len() {
                    if readmit.contains(&slots[i].site) {
                        graduating.push(slots.remove(i));
                    } else {
                        i += 1;
                    }
                }
            }
            for p in graduating {
                self.attach_readmitted(resident, p);
                delta.readmitted += 1;
            }
        }
        delta
    }

    /// Attach a re-admitted probation macro to its load as a live
    /// serving replica.  If the load had cold-spilled (last copy
    /// quarantined), this converts it back to resident; the plan's
    /// replica count and budget are updated in place so the next
    /// re-plan diffs from reality.
    fn attach_readmitted(&self, resident: &Resident, p: ProbationSlot) {
        let mut st = resident.state.write().unwrap();
        let slot = st.hidden_slots[p.layer][p.load].get_or_insert_with(|| LoadSlots {
            replicas: Vec::new(),
            next: AtomicUsize::new(0),
        });
        slot.replicas.push(Mutex::new(p.cam));
        let n = slot.replicas.len();
        st.plan.hidden_replicas[p.layer][p.load] = n;
        let used = st.plan.macros_used();
        if st.plan.budget < used {
            st.plan.budget = used;
        }
    }
}

/// What one [`MacroPool::probation_scrub`] pass accomplished (merged
/// into the scrub controller's stats).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProbationDelta {
    /// Canary rows checked across all probation macros.
    pub rows_checked: u64,
    /// Clean full laps credited.
    pub laps: u64,
    /// Macros that completed probation and rejoined serving.
    pub readmitted: u64,
    /// Probations failed (macro re-quarantined with doubled requirement).
    pub failures: u64,
}

/// Multi-tenant pool: N models served from one macro budget.
///
/// [`planner::plan_tenants`] partitions the budget (floors first, surplus
/// proportional-fair by traffic share) and every tenant executes its own
/// [`PlacementPlan`] on its own macros — tenants never share a macro, so
/// a tenant's predictions are bit-identical (nominal *and* analog) to the
/// same model running alone on a [`MacroPool`] built from the same plan,
/// for any budget split and any interleaving of tenant batches.  When
/// even the tenancy floors don't fit, the budget is split evenly and each
/// tenant degrades independently (down to the reload scheduler).
pub struct MultiPool<'m> {
    tenants: Vec<MacroPool<'m>>,
    /// Budget of the tenancy partition (`None` = even-split fallback).
    /// The per-tenant plans themselves live in the tenants — moved
    /// there at construction, reassembled on demand by [`Self::plan`].
    tenancy_budget: Option<usize>,
    // re-partitioning inputs, kept so runtime tenant churn
    // (add_tenant/remove_tenant) re-plans under the original contract
    opts: PipelineOptions,
    budget: usize,
    workers: usize,
    shares: Vec<f64>,
}

impl<'m> MultiPool<'m> {
    /// Multi-tenant pool with equal traffic shares and one searcher.
    pub fn new(models: &[&'m MappedModel], opts: PipelineOptions, budget: usize) -> Self {
        Self::with_shares(models, opts, budget, 1, &[])
    }

    /// Multi-tenant pool with explicit per-tenant traffic shares
    /// (surplus budget follows the shares) serving `workers` concurrent
    /// searchers per tenant.  An empty `shares` slice means equal shares
    /// — the default path builds no throwaway allocation.
    pub fn with_shares(
        models: &[&'m MappedModel],
        opts: PipelineOptions,
        budget: usize,
        workers: usize,
        shares: &[f64],
    ) -> Self {
        Self::with_traffic(models, opts, budget, workers, shares, &[])
    }

    /// [`Self::with_shares`] with measured per-tenant output-traffic
    /// histograms (`traffic[t]` from `tenant(t).take_output_traffic()`;
    /// `None` = uniform, and an empty slice = uniform everywhere): each
    /// tenant's pinned set follows its observed per-threshold access
    /// frequencies.
    pub fn with_traffic(
        models: &[&'m MappedModel],
        opts: PipelineOptions,
        budget: usize,
        workers: usize,
        shares: &[f64],
        traffic: &[Option<Vec<u64>>],
    ) -> Self {
        assert!(
            shares.is_empty() || shares.len() == models.len(),
            "one share per tenant (or an empty slice for equal shares)"
        );
        assert!(
            traffic.is_empty() || traffic.len() == models.len(),
            "one histogram per tenant (or an empty slice for uniform)"
        );
        let hist = |t: usize| traffic.get(t).and_then(Option::as_deref);
        let resolved_shares: Vec<f64> = (0..models.len())
            .map(|t| shares.get(t).copied().unwrap_or(1.0))
            .collect();
        let specs: Vec<TenantSpec<'_>> = models
            .iter()
            .enumerate()
            .map(|(t, m)| {
                let plans = plan_loads(m);
                let schedule = resolve_schedule(m, &opts);
                TenantSpec {
                    hidden_load_rows: MacroPool::load_rows(&plans),
                    schedule_points: point_classes(&schedule),
                    traffic: hist(t),
                    share: resolved_shares[t],
                    health: None,
                }
            })
            .collect();
        match planner::plan_tenants(&specs, budget, workers) {
            Some(tp) => {
                // the tenant plans move into their pools — no clones on
                // the construction path; `plan()` reassembles the
                // partition from the tenants when diagnostics ask
                let tenants = models
                    .iter()
                    .zip(tp.plans)
                    .map(|(m, p)| MacroPool::with_plan(m, opts, p))
                    .collect();
                MultiPool {
                    tenants,
                    tenancy_budget: Some(tp.budget),
                    opts,
                    budget,
                    workers,
                    shares: resolved_shares,
                }
            }
            None => {
                // below the tenancy floors: split evenly, let every
                // tenant degrade on its own (spill, then reload), still
                // honouring any measured histogram the caller supplied.
                // A budget below one macro per tenant is physically
                // unservable — the fallback still instantiates one
                // reload macro per tenant, so `n_macros()` may exceed
                // such a sub-physical budget (check `plan()` for `None`
                // to detect this regime).
                let per = (budget / models.len().max(1)).max(1);
                let tenants = models
                    .iter()
                    .enumerate()
                    .map(|(t, m)| match hist(t) {
                        Some(h) => MacroPool::with_traffic(m, opts, per, workers, h),
                        None => MacroPool::with_capacity_for_workers(m, opts, per, workers),
                    })
                    .collect();
                MultiPool {
                    tenants,
                    tenancy_budget: None,
                    opts,
                    budget,
                    workers,
                    shares: resolved_shares,
                }
            }
        }
    }

    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// The tenant's backing single-model pool (plan, mode, diagnostics).
    pub fn tenant(&self, t: usize) -> &MacroPool<'m> {
        &self.tenants[t]
    }

    /// Operator re-admission of a quarantined macro in one tenant's
    /// pool ([`MacroPool::un_quarantine`]): the macro goes on probation
    /// there; the next re-partition sees it through that tenant's
    /// health scores.
    pub fn un_quarantine(&self, tenant: usize, layer: usize, load: usize) -> bool {
        self.tenants[tenant].un_quarantine(layer, load)
    }

    /// The budget partition (`None` when the floors didn't fit and the
    /// pool fell back to an even split).  Diagnostics path: the
    /// partition is reassembled from the plans the tenants own (one
    /// clone per tenant here, zero on the construction path).
    pub fn plan(&self) -> Option<TenantPlan> {
        let budget = self.tenancy_budget?;
        Some(TenantPlan {
            budget,
            plans: self
                .tenants
                .iter()
                .map(|t| t.plan().expect("tenancy plans are resident"))
                .collect(),
        })
    }

    /// Simulated macros instantiated across every tenant.
    pub fn n_macros(&self) -> usize {
        self.tenants.iter().map(MacroPool::n_macros).sum()
    }

    /// Classify a batch for `tenant` (tenant-tagged routing; noise-stream
    /// indices from that tenant's internal cursor).
    pub fn classify_batch(&self, tenant: usize, images: &[BitVec]) -> Vec<(Vec<u32>, usize)> {
        self.tenants[tenant].classify_batch(images)
    }

    /// [`Self::classify_batch`] with an explicit noise-stream base index.
    pub fn classify_batch_at(
        &self,
        tenant: usize,
        images: &[BitVec],
        stream_base: u64,
    ) -> Vec<(Vec<u32>, usize)> {
        self.tenants[tenant].classify_batch_at(images, stream_base)
    }

    /// Drain one tenant's device statistics (see [`MacroPool::take_stats`]).
    pub fn take_stats(&self, tenant: usize, inferences: u64) -> RunStats {
        self.tenants[tenant].take_stats(inferences)
    }

    /// Drain and merge every tenant's statistics into one report (macro
    /// counts sum, so the energy model charges pool-wide leakage).
    pub fn take_stats_total(&self, inferences: u64) -> RunStats {
        let mut total = RunStats {
            inferences,
            ..RunStats::default()
        };
        for t in &self.tenants {
            let s = t.take_stats(0);
            total.cycles += s.cycles;
            total.stall_s += s.stall_s;
            total.events.add(&s.events);
            total.hidden_cost.add(&s.hidden_cost);
            total.output_cost.add(&s.output_cost);
            total.macros += s.macros;
            // the fleet is only as healthy as its sickest tenant
            total.degraded = total.degraded.max(s.degraded);
        }
        total
    }

    /// Execute one step of `mp` against `tenant`'s pool (see
    /// [`MacroPool::apply_migration_step`]) — sibling tenants never share
    /// a macro, so their bit-exactness is untouched while one migrates.
    pub fn apply_migration_step(
        &self,
        tenant: usize,
        mp: &MigrationPlan,
        k: usize,
    ) -> MigrationStats {
        self.tenants[tenant].apply_migration_step(mp, k)
    }

    /// Drain one tenant's migration cost counters.
    pub fn take_migration_stats(&self, tenant: usize) -> MigrationStats {
        self.tenants[tenant].take_migration_stats()
    }

    /// Admit a new tenant at runtime.  The partition is re-planned from
    /// every sitting tenant's freshly drained traffic; the new tenant is
    /// built directly at its target plan, and each sitting tenant gets a
    /// [`MigrationPlan`] from its current placement to its new one —
    /// apply the steps incrementally via [`Self::apply_migration_step`]
    /// (index = position in the returned vec) in the gaps between
    /// batches.  Until a tenant's migration completes it keeps serving
    /// bit-stably from its current placement.
    ///
    /// Returns one migration per tenant (the new tenant's is empty), or
    /// an empty vec when the enlarged tenancy no longer fits its floors:
    /// then sitting tenants are left untouched on their current plans and
    /// the newcomer gets an even-split degraded pool of its own.
    pub fn add_tenant(&mut self, model: &'m MappedModel, share: f64) -> Vec<MigrationPlan> {
        self.repartition(Some((model, share)))
    }

    /// Retire tenant `t` at runtime: its macros are released back to the
    /// budget and the survivors re-partition over the freed capacity.
    /// Tenant indices above `t` shift down by one; the returned
    /// migrations are indexed by the *new* tenant order (empty vec = the
    /// shrunken tenancy fell below its floors; survivors stay put).
    pub fn remove_tenant(&mut self, t: usize) -> Vec<MigrationPlan> {
        self.tenants.remove(t);
        self.shares.remove(t);
        self.repartition(None)
    }

    /// Re-plan the partition over the current tenant set (plus an
    /// optional incoming tenant) using drained live traffic, and emit
    /// per-tenant incremental migrations toward the new plans.
    fn repartition(&mut self, incoming: Option<(&'m MappedModel, f64)>) -> Vec<MigrationPlan> {
        // freshly drained per-tenant heat; an all-zero histogram carries
        // no signal (tenant idle since the last drain) → uniform pricing
        let hists: Vec<Option<Vec<u64>>> = self
            .tenants
            .iter()
            .map(|p| {
                let h = p.take_output_traffic();
                (h.iter().any(|&x| x != 0)).then_some(h)
            })
            .collect();
        let mut specs: Vec<TenantSpec<'_>> = self
            .tenants
            .iter()
            .enumerate()
            .map(|(t, p)| TenantSpec {
                hidden_load_rows: p.hidden_load_rows(),
                schedule_points: p.schedule_points(),
                traffic: hists[t].as_deref(),
                share: self.shares[t],
                // sitting tenants re-plan around their quarantined and
                // probation silicon; recovered capacity pulls load back
                health: Some(p.health_scores()),
            })
            .collect();
        if let Some((m, share)) = incoming {
            let plans = plan_loads(m);
            let schedule = resolve_schedule(m, &self.opts);
            specs.push(TenantSpec {
                hidden_load_rows: MacroPool::load_rows(&plans),
                schedule_points: point_classes(&schedule),
                traffic: None, // no history yet
                share,
                health: None, // fresh silicon
            });
        }
        match planner::plan_tenants(&specs, self.budget, self.workers) {
            Some(tp) => {
                self.tenancy_budget = Some(tp.budget);
                let mut plans = tp.plans.into_iter();
                let mut migrations = Vec::with_capacity(specs.len());
                for (t, pool) in self.tenants.iter_mut().enumerate() {
                    let target = plans.next().expect("one plan per sitting tenant");
                    migrations.push(match pool.plan() {
                        // price the current placement under the same
                        // measured histogram the re-plan saw, so the
                        // migration's before/after costs are comparable
                        Some(cur) => cur.repriced(hists[t].as_deref()).diff(&target),
                        None => {
                            // the tenant had degraded to reload mode —
                            // nothing is resident, so swap in a fresh
                            // resident pool outright (seeding is
                            // plan-independent: bit-stable by build)
                            let empty = target.diff(&target);
                            *pool = MacroPool::with_plan(pool.model, self.opts, target);
                            empty
                        }
                    });
                }
                if let Some((m, share)) = incoming {
                    let target = plans.next().expect("one plan for the new tenant");
                    migrations.push(target.diff(&target));
                    self.tenants.push(MacroPool::with_plan(m, self.opts, target));
                    self.shares.push(share);
                }
                migrations
            }
            None => {
                // below the tenancy floors: never force sitting tenants
                // through a disruptive rebuild — they keep their current
                // placements; only a newcomer degrades onto an even split
                self.tenancy_budget = None;
                if let Some((m, share)) = incoming {
                    let per = (self.budget / (self.tenants.len() + 1)).max(1);
                    self.tenants.push(MacroPool::with_capacity_for_workers(
                        m,
                        self.opts,
                        per,
                        self.workers,
                    ));
                    self.shares.push(share);
                }
                Vec::new()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::infer::digital_forward;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::cam::NoiseMode;

    fn rand_images(n: usize, bits: usize, seed: u64) -> Vec<BitVec> {
        let mut rng = Rng::new(seed, 1);
        (0..n)
            .map(|_| {
                let mut v = BitVec::zeros(bits);
                for i in 0..bits {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect()
    }

    fn nominal() -> PipelineOptions {
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        }
    }

    #[test]
    fn resident_pool_matches_single_macro_pipeline_bit_exactly() {
        // acceptance: sharded pool predictions (and votes) are identical
        // to the single-macro Pipeline under NoiseMode::Nominal
        let model = tiny_model(100, 16, 4, 42);
        let images = rand_images(24, 100, 7);
        let pool = MacroPool::new(&model, nominal());
        assert_eq!(pool.mode(), PoolMode::Resident);
        let mut pipe = Pipeline::new(&model, nominal());
        for chunk_len in [1usize, 5, 24] {
            for chunk in images.chunks(chunk_len) {
                let got = pool.classify_batch(chunk);
                let want = pipe.classify_batch(chunk);
                assert_eq!(got, want, "chunk_len {chunk_len}");
            }
        }
        // and both agree with the digital oracle
        let got = pool.classify_batch(&images);
        for (img, (votes, pred)) in images.iter().zip(&got) {
            let (want_votes, want_pred) = digital_forward(&model, img, pool.schedule());
            assert_eq!(votes, &want_votes);
            assert_eq!(pred, &want_pred);
        }
    }

    #[test]
    fn budget_constrained_plan_matches_reload_pipeline_bit_exactly() {
        // satellite acceptance: threshold sharing active (most thresholds
        // funnel through one shared slot) must not change a single vote
        let model = tiny_model(100, 16, 4, 42);
        let images = rand_images(24, 100, 7);
        let required = MacroPool::macros_required(&model, &nominal());
        for budget in [2usize, 5, required / 2] {
            let pool = MacroPool::with_capacity(&model, nominal(), budget);
            assert_eq!(pool.mode(), PoolMode::Resident, "budget {budget}");
            let plan = pool.plan().unwrap();
            assert!(plan.sharing_active(), "budget {budget}");
            assert!(plan.macros_used() <= budget);
            let mut pipe = Pipeline::new(&model, nominal());
            for chunk in images.chunks(8) {
                assert_eq!(
                    pool.classify_batch(chunk),
                    pipe.classify_batch(chunk),
                    "budget {budget}"
                );
            }
        }
    }

    #[test]
    fn replicated_plan_matches_pipeline_bit_exactly() {
        // surplus budget buys hidden-load replicas; identical seeding
        // keeps results bit-identical to the unreplicated engines
        let model = tiny_model(100, 16, 4, 42);
        let images = rand_images(16, 100, 7);
        let required = MacroPool::macros_required(&model, &nominal());
        let pool = MacroPool::with_capacity_for_workers(&model, nominal(), required + 5, 4);
        let plan = pool.plan().unwrap();
        assert!(plan.replication_active());
        assert!(plan.macros_used() <= required + 5);
        let mut pipe = Pipeline::new(&model, nominal());
        assert_eq!(pool.classify_batch(&images), pipe.classify_batch(&images));
    }

    #[test]
    fn steady_state_batches_pay_zero_programming_and_zero_retunes() {
        let model = tiny_model(64, 8, 3, 2);
        let images = rand_images(16, 64, 3);
        let pool = MacroPool::new(&model, nominal());
        // warmup: construction programmed the macros; drain that epoch
        pool.classify_batch(&images);
        let warm = pool.take_stats(16);
        assert!(warm.events.row_writes > 0, "construction programs rows");
        assert_eq!(warm.macros, pool.n_macros());
        // steady state: no programming, no retunes, no stalls — searches only
        pool.classify_batch(&images);
        pool.classify_batch(&images);
        let steady = pool.take_stats(32);
        assert_eq!(steady.programming_cycles(), 0, "{:?}", steady.events);
        assert_eq!(steady.events.row_writes, 0);
        assert_eq!(steady.events.cells_written, 0);
        assert_eq!(steady.events.retunes, 0);
        assert_eq!(steady.stall_s, 0.0);
        assert!(steady.events.searches > 0);
        assert!(steady.cycles > 0);
    }

    #[test]
    fn steady_state_classify_batch_reuses_scratch_without_reallocating() {
        // the allocation-free contract at the pool: after the first
        // batch has grown every scratch buffer to its working shape,
        // further same-shaped batches keep the exact allocations
        // (acts/next swap roles per hidden layer — compare as a pair)
        let model = tiny_model(100, 16, 4, 42);
        let images = rand_images(16, 100, 7);
        let pool = MacroPool::new(&model, nominal());
        assert_eq!(pool.scratch_arenas(), 0, "no arena before the first batch");
        pool.classify_batch(&images); // warmup builds the arena
        let grab = |pool: &MacroPool| {
            let arenas = pool.scratch.lock().unwrap();
            assert_eq!(arenas.len(), 1, "single-threaded pool keeps one arena");
            let s = &arenas[0];
            let mut acts_pair = [
                s.acts.words().as_ptr() as usize,
                s.next.words().as_ptr() as usize,
            ];
            acts_pair.sort_unstable();
            (
                acts_pair,
                s.rngs.as_ptr() as usize,
                s.queries.words().as_ptr() as usize,
                s.seg_fires.as_ptr() as usize,
                s.votes.as_ptr() as usize,
                s.m.as_ptr() as usize,
                s.fires.words().as_ptr() as usize,
            )
        };
        let before = grab(&pool);
        for _ in 0..3 {
            pool.classify_batch(&images);
        }
        assert_eq!(grab(&pool), before, "steady-state batch reallocated scratch");
    }

    #[test]
    fn concurrent_batches_share_the_arena_free_list() {
        // N workers hammering one pool converge to at most N parked
        // arenas, and arena recycling never corrupts results
        let model = tiny_model(64, 8, 3, 2);
        let images = rand_images(32, 64, 3);
        let pool = MacroPool::new(&model, nominal());
        let want = pool.classify_batch_at(&images, 0);
        std::thread::scope(|sc| {
            for _ in 0..4 {
                let (pool, images, want) = (&pool, &images, &want);
                sc.spawn(move || {
                    for _ in 0..3 {
                        assert_eq!(&pool.classify_batch_at(images, 0), want);
                    }
                });
            }
        });
        let arenas = pool.scratch_arenas();
        assert!((1..=4).contains(&arenas), "{arenas} arenas for 4 workers");
    }

    #[test]
    fn degraded_budget_stays_resident_with_bounded_retunes() {
        // the Resident/Reload cliff is gone: half the full budget still
        // pays zero programming, and per-batch retunes respect the plan's
        // cost model while beating the reload scheduler
        let model = tiny_model(64, 8, 3, 2);
        let images = rand_images(16, 64, 3);
        let required = MacroPool::macros_required(&model, &nominal());
        let budget = required / 2;
        let pool = MacroPool::with_capacity(&model, nominal(), budget);
        assert_eq!(pool.mode(), PoolMode::Resident);
        let predicted = pool.plan().unwrap().predicted_retunes_per_batch();
        assert!(predicted > 0);
        // warmup epoch (construction programming + first shared parks)
        pool.classify_batch(&images);
        pool.take_stats(16);
        let batches = 4u64;
        for _ in 0..batches {
            pool.classify_batch(&images);
        }
        let steady = pool.take_stats(batches * 16);
        assert_eq!(steady.programming_cycles(), 0, "steady state reprograms");
        assert!(steady.events.retunes > 0, "sharing must retune");
        assert!(
            steady.events.retunes <= predicted * batches,
            "{} > {predicted}/batch",
            steady.events.retunes
        );
        // all retunes are output-sweep switches, none from hidden loads
        assert_eq!(steady.hidden_cost.retunes, 0);
        assert_eq!(steady.output_cost.retunes, steady.events.retunes);

        // strictly fewer retunes per batch than the reload scheduler
        let mut pipe = Pipeline::new(&model, nominal());
        pipe.classify_batch(&images);
        pipe.take_stats(16);
        for _ in 0..batches {
            pipe.classify_batch(&images);
        }
        let reload = pipe.take_stats(batches * 16);
        assert!(
            steady.events.retunes < reload.events.retunes,
            "shared {} vs reload {}",
            steady.events.retunes,
            reload.events.retunes
        );
        assert!(reload.programming_cycles() > 0);
    }

    #[test]
    fn resident_pool_beats_reload_pipeline_on_steady_state_cycles() {
        let model = tiny_model(100, 16, 4, 11);
        let images = rand_images(32, 100, 5);
        let pool = MacroPool::new(&model, nominal());
        pool.classify_batch(&images); // warmup
        pool.take_stats(0);
        for _ in 0..4 {
            pool.classify_batch(&images);
        }
        let pool_cpi = pool.take_stats(4 * 32).cycles_per_inference();

        let mut pipe = Pipeline::new(&model, nominal());
        pipe.classify_batch(&images); // same warmup treatment
        pipe.take_stats(0);
        for _ in 0..4 {
            pipe.classify_batch(&images);
        }
        let pipe_cpi = pipe.take_stats(4 * 32).cycles_per_inference();
        assert!(
            pool_cpi < pipe_cpi,
            "resident {pool_cpi} should beat reload {pipe_cpi}"
        );
    }

    /// Two hidden loads (300 neurons exceed the 256-row config), so
    /// sub-minimum budgets exercise the cold-spill path.
    fn two_load_model(seed: u64) -> MappedModel {
        tiny_model(100, 300, 4, seed)
    }

    #[test]
    fn cold_spill_matches_pipeline_and_beats_full_reload() {
        let model = two_load_model(23);
        let images = rand_images(8, 100, 9);
        let required = MacroPool::macros_required(&model, &nominal());
        let hidden = required - 33; // 33-threshold fixture schedule
        assert!(hidden >= 2, "fixture must have ≥2 hidden loads");
        // budget below hidden + 1: previously reload, now a spill plan
        let budget = hidden; // one load spills, the rest stay resident
        let pool = MacroPool::with_capacity(&model, nominal(), budget);
        assert_eq!(pool.mode(), PoolMode::Resident);
        let plan = pool.plan().unwrap();
        assert!(plan.spill_active());
        assert_eq!(plan.spilled_loads(), 1);
        assert!(plan.macros_used() <= budget);
        // nominal predictions are bit-identical to the reload pipeline
        let mut pipe = Pipeline::new(&model, nominal());
        for chunk in images.chunks(4) {
            assert_eq!(pool.classify_batch(chunk), pipe.classify_batch(chunk));
        }
        // steady state: the funnel reprograms only the spilled load (+ the
        // output rows), strictly less than the reload scheduler's full
        // reload; retunes respect the plan's cost model
        pool.take_stats(0);
        pipe.take_stats(0);
        let batches = 3u64;
        for _ in 0..batches {
            pool.classify_batch(&images);
            pipe.classify_batch(&images);
        }
        let spill = pool.take_stats(batches * 8);
        let reload = pipe.take_stats(batches * 8);
        assert!(spill.programming_cycles() > 0, "spill must reprogram");
        assert!(
            spill.programming_cycles() < reload.programming_cycles(),
            "spill {} vs reload {}",
            spill.programming_cycles(),
            reload.programming_cycles()
        );
        assert!(
            spill.events.retunes <= plan.predicted_retunes_per_batch() * batches,
            "{} > {}/batch",
            spill.events.retunes,
            plan.predicted_retunes_per_batch()
        );
        // the spilled load's reprograms are attributed to the hidden
        // category, the funnel's output re-landing to the output category
        assert!(spill.hidden_cost.row_writes > 0);
        assert!(spill.output_cost.row_writes > 0);
        assert_eq!(
            spill.hidden_cost.row_writes + spill.output_cost.row_writes,
            spill.events.row_writes
        );
    }

    #[test]
    fn budget_below_spill_floor_falls_back_to_reload_scheduler() {
        // single-load models have nothing to spill: below full residency
        // the pool gives up residency entirely
        let model = tiny_model(64, 8, 3, 9);
        assert!(MacroPool::plan_for(&model, &nominal(), 1).is_none());
        let pool = MacroPool::with_capacity(&model, nominal(), 1);
        assert_eq!(pool.mode(), PoolMode::Reload);
        assert!(pool.plan().is_none());
        assert!(pool.take_output_traffic().is_empty());
        // still bit-exact vs the pipeline in nominal mode
        let images = rand_images(10, 64, 13);
        let mut pipe = Pipeline::new(&model, nominal());
        assert_eq!(pool.classify_batch(&images), pipe.classify_batch(&images));
        // stats flow through the fallback, attribution included
        let s = pool.take_stats(10);
        assert!(s.cycles > 0);
        assert!(s.events.searches > 0);
        assert!(s.hidden_cost.row_writes > 0);
        assert_eq!(s.macros, 1);
    }

    #[test]
    fn macro_budget_matches_plan() {
        let model = tiny_model(100, 16, 4, 21);
        let opts = nominal();
        let pool = MacroPool::new(&model, opts);
        assert_eq!(pool.mode(), PoolMode::Resident);
        // 1 hidden load + 33 output thresholds for the tiny fixture
        assert_eq!(pool.n_macros(), MacroPool::macros_required(&model, &opts));
        assert_eq!(pool.n_macros(), 1 + pool.schedule().len());
        assert_eq!(pool.n_macros(), pool.plan().unwrap().macros_used());
    }

    #[test]
    fn analog_mode_deterministic_for_fixed_stream_indices() {
        let model = tiny_model(64, 8, 4, 31);
        let images = rand_images(12, 64, 17);
        let opts = PipelineOptions::default(); // analog noise
        let a = MacroPool::new(&model, opts).classify_batch_at(&images, 0);
        let b = MacroPool::new(&model, opts).classify_batch_at(&images, 0);
        assert_eq!(a, b);
        // a different seed draws different noise
        let c = MacroPool::new(
            &model,
            PipelineOptions {
                seed: opts.seed ^ 0xDEAD,
                ..opts
            },
        )
        .classify_batch_at(&images, 0);
        // votes are near-deterministic on easy instances; only require the
        // structures to be well-formed rather than identical
        assert_eq!(c.len(), a.len());
    }

    #[test]
    fn analog_results_independent_of_budget() {
        // identical seeding of replicas/slots + per-image noise streams:
        // a non-spill placement is an execution detail, never a semantic
        // one
        let model = tiny_model(64, 8, 4, 31);
        let images = rand_images(12, 64, 17);
        let opts = PipelineOptions::default(); // analog noise
        let required = MacroPool::macros_required(&model, &opts);
        let full = MacroPool::with_capacity(&model, opts, required);
        let want = full.classify_batch_at(&images, 0);
        for budget in [2usize, required / 2, required + 6] {
            // plan for several workers so the largest budget replicates
            let pool = MacroPool::with_capacity_for_workers(&model, opts, budget, 3);
            assert_eq!(pool.mode(), PoolMode::Resident);
            assert!(!pool.plan().unwrap().spill_active());
            assert_eq!(
                pool.classify_batch_at(&images, 0),
                want,
                "budget {budget}"
            );
        }
    }

    #[test]
    fn schedule_prefix_respected() {
        let model = tiny_model(64, 8, 3, 1);
        let pool = MacroPool::new(
            &model,
            PipelineOptions {
                noise: NoiseMode::Nominal,
                schedule_prefix: Some(5),
                ..Default::default()
            },
        );
        assert_eq!(pool.schedule(), &model.schedule[..5]);
        // 1 hidden load + 5 pinned thresholds; the single-worker default
        // leaves the rest of the budget unspent (no idle replicas)
        let plan = pool.plan().unwrap();
        assert_eq!(plan.pinned, 5);
        assert_eq!(plan.output_macros(), 5);
        assert_eq!(pool.n_macros(), plan.macros_used());
        assert_eq!(pool.n_macros(), 1 + 5);
    }

    #[test]
    fn traffic_aware_pinning_beats_prefix_on_a_skewed_schedule() {
        // tentpole acceptance: a schedule where one threshold value holds
        // 8 of 12 positions (skew 8× ≥ 2×).  Point-grouped, histogram-
        // driven pinning must pay ≤ the cyclic K − d bound and strictly
        // fewer measured retunes than prefix pinning at the same budget.
        let mut model = tiny_model(64, 8, 3, 44);
        model.schedule = vec![0, 0, 0, 0, 0, 0, 0, 0, 8, 16, 24, 32];
        let k_len = model.schedule.len() as u64;
        let images = rand_images(8, 64, 29);
        let budget = 4; // 1 hidden load + 3 output macros
        let prefix = MacroPool::with_capacity(&model, nominal(), budget);
        let traffic_pool = MacroPool::with_traffic(&model, nominal(), budget, 1, &[1; 12]);
        let d = prefix.plan().unwrap().pinned as u64;
        let bound = k_len - d; // the PR 2 cyclic rule at this budget
        assert!(traffic_pool.plan().unwrap().predicted_retunes_per_batch() < bound);
        // both placements classify identically (nominal = reload pipeline)
        let mut pipe = Pipeline::new(&model, nominal());
        let want = pipe.classify_batch(&images);
        assert_eq!(prefix.classify_batch(&images), want);
        assert_eq!(traffic_pool.classify_batch(&images), want);
        // measured steady-state retunes: traffic-aware < prefix ≤ bound
        prefix.take_stats(0);
        traffic_pool.take_stats(0);
        let batches = 4u64;
        for _ in 0..batches {
            prefix.classify_batch(&images);
            traffic_pool.classify_batch(&images);
        }
        let p = prefix.take_stats(batches * 8);
        let t = traffic_pool.take_stats(batches * 8);
        assert_eq!(p.programming_cycles(), 0);
        assert_eq!(t.programming_cycles(), 0);
        assert!(
            t.events.retunes <= bound * batches,
            "traffic {} vs bound {}/batch",
            t.events.retunes,
            bound
        );
        assert!(
            t.events.retunes < p.events.retunes,
            "traffic {} must beat prefix {}",
            t.events.retunes,
            p.events.retunes
        );
        // the histogram the pool measured is the schedule frequency ×
        // served images, and it drains
        let h = traffic_pool.take_output_traffic();
        assert_eq!(h.len(), 12);
        assert!(h.iter().all(|&c| c == (batches + 1) * 8));
        assert!(traffic_pool.take_output_traffic().iter().all(|&c| c == 0));
    }

    #[test]
    fn multi_pool_serves_tenants_bit_identically_to_standalone_pools() {
        // tenancy acceptance at the pool layer: one budget, two models —
        // per-tenant predictions equal the same model running alone on a
        // pool built from the same per-tenant plan (nominal and analog)
        let a = tiny_model(100, 16, 4, 42);
        let b = tiny_model(64, 8, 3, 7);
        let imgs_a = rand_images(12, 100, 5);
        let imgs_b = rand_images(12, 64, 6);
        for opts in [nominal(), PipelineOptions::default()] {
            let budget = MacroPool::macros_required(&a, &opts)
                + MacroPool::macros_required(&b, &opts);
            let pool = MultiPool::new(&[&a, &b], opts, budget);
            assert_eq!(pool.n_tenants(), 2);
            let tp = pool.plan().expect("budget covers the floors");
            assert!(tp.macros_used() <= budget);
            assert_eq!(pool.n_macros(), tp.macros_used());
            let alone_a = MacroPool::with_plan(&a, opts, tp.plans[0].clone());
            let alone_b = MacroPool::with_plan(&b, opts, tp.plans[1].clone());
            // interleave tenant batches in chunks: isolation must hold
            // for any interleaving
            for chunk in [3usize, 5] {
                let mut base = 0u64;
                for (ca, cb) in imgs_a.chunks(chunk).zip(imgs_b.chunks(chunk)) {
                    assert_eq!(
                        pool.classify_batch_at(0, ca, base),
                        alone_a.classify_batch_at(ca, base)
                    );
                    assert_eq!(
                        pool.classify_batch_at(1, cb, base),
                        alone_b.classify_batch_at(cb, base)
                    );
                    base += chunk as u64;
                }
            }
        }
    }

    #[test]
    fn multi_pool_steady_state_pays_zero_programming_at_full_budget() {
        let a = tiny_model(100, 16, 4, 42);
        let b = tiny_model(64, 8, 3, 7);
        let imgs_a = rand_images(8, 100, 5);
        let imgs_b = rand_images(8, 64, 6);
        let budget = MacroPool::macros_required(&a, &nominal())
            + MacroPool::macros_required(&b, &nominal());
        let pool = MultiPool::new(&[&a, &b], nominal(), budget);
        // warmup both tenants, drain construction programming
        pool.classify_batch(0, &imgs_a);
        pool.classify_batch(1, &imgs_b);
        pool.take_stats_total(16);
        // steady state across interleaved tenant batches
        for _ in 0..2 {
            pool.classify_batch(0, &imgs_a);
            pool.classify_batch(1, &imgs_b);
        }
        let steady = pool.take_stats_total(32);
        assert_eq!(steady.programming_cycles(), 0);
        assert_eq!(steady.events.retunes, 0);
        assert!(steady.events.searches > 0);
        assert_eq!(steady.macros, pool.n_macros());
        // per-tenant stats drained into the total: nothing left
        assert_eq!(pool.take_stats(0, 0).cycles, 0);
        assert_eq!(pool.take_stats(1, 0).cycles, 0);
    }

    #[test]
    fn multi_pool_below_floors_splits_evenly_and_degrades() {
        // two single-load tenants on 2 macros: the tenancy floors (2
        // each) don't fit, so each tenant gets 1 macro and reloads —
        // still bit-exact vs the pipeline
        let a = tiny_model(64, 8, 3, 1);
        let b = tiny_model(64, 8, 3, 2);
        let pool = MultiPool::new(&[&a, &b], nominal(), 2);
        assert!(pool.plan().is_none());
        assert_eq!(pool.tenant(0).mode(), PoolMode::Reload);
        assert_eq!(pool.tenant(1).mode(), PoolMode::Reload);
        let imgs = rand_images(6, 64, 3);
        let mut pipe_a = Pipeline::new(&a, nominal());
        let mut pipe_b = Pipeline::new(&b, nominal());
        assert_eq!(pool.classify_batch(0, &imgs), pipe_a.classify_batch(&imgs));
        assert_eq!(pool.classify_batch(1, &imgs), pipe_b.classify_batch(&imgs));
    }

    #[test]
    fn live_migration_is_bit_stable_and_lands_on_the_target_plan() {
        // tentpole acceptance at the pool layer: re-pin toward a skewed
        // histogram step by step, serving (analog noise) after every
        // step — predictions never move, and the final placement equals
        // the target plan field for field
        let mut model = tiny_model(64, 8, 3, 44);
        model.schedule = vec![0, 0, 0, 0, 0, 0, 0, 0, 8, 16, 24, 32];
        let images = rand_images(8, 64, 29);
        let opts = PipelineOptions::default(); // analog noise
        let budget = 4; // 1 hidden load + 2 pinned + 1 shared slot
        let pool = MacroPool::with_capacity(&model, opts, budget);
        let old = pool.plan().unwrap();
        // the measured heat flips to the tail positions
        let hot: Vec<u64> = (0..12).map(|k| if k >= 8 { 90 } else { 1 }).collect();
        let target = MacroPool::with_traffic(&model, opts, budget, 1, &hot)
            .plan()
            .unwrap();
        let mp = old.repriced(Some(&hot)).diff(&target);
        assert!(!mp.is_empty(), "the skew flip must move the pinned set");
        assert!(
            mp.predicted_retunes_saved_per_batch() > 0,
            "re-pinning onto the hot band must save retunes"
        );
        let want = pool.classify_batch_at(&images, 0);
        for k in 0..mp.steps.len() {
            pool.apply_migration_step(&mp, k);
            // identical seeding: the placement is invisible to results
            assert_eq!(pool.classify_batch_at(&images, 0), want, "step {k}");
        }
        assert_eq!(pool.plan().unwrap(), mp.target(&old));
        let mig = pool.take_migration_stats();
        assert_eq!(mig.steps, mp.steps.len() as u64);
        assert_eq!(
            mig.programming_cycles(),
            mp.programming_cycles_to_apply(&pool.hidden_load_rows(), pool.output_rows())
        );
    }

    #[test]
    fn migration_from_spill_to_full_residency_pays_programming_once() {
        let model = two_load_model(23);
        let images = rand_images(8, 100, 9);
        let required = MacroPool::macros_required(&model, &nominal());
        let budget = required - 33; // one hidden load cold-spills
        let pool = MacroPool::with_capacity(&model, nominal(), budget);
        let old = pool.plan().unwrap();
        assert!(old.spill_active());
        let target = MacroPool::plan_for(&model, &nominal(), required).unwrap();
        assert!(!target.spill_active());
        let mp = old.diff(&target);
        assert!(!mp.is_empty());
        // serve on every intermediate placement: nominal predictions are
        // placement-independent, so results never move mid-migration
        let mut pipe = Pipeline::new(&model, nominal());
        let want = pipe.classify_batch(&images);
        for k in 0..mp.steps.len() {
            assert_eq!(pool.classify_batch(&images), want, "before step {k}");
            pool.apply_migration_step(&mp, k);
        }
        assert_eq!(pool.classify_batch(&images), want);
        assert_eq!(pool.plan().unwrap(), target);
        let mig = pool.take_migration_stats();
        assert_eq!(mig.steps, mp.steps.len() as u64);
        assert_eq!(
            mig.programming_cycles(),
            mp.programming_cycles_to_apply(&pool.hidden_load_rows(), pool.output_rows())
        );
        assert!(mig.programming_cycles() > 0, "promotion must program rows");
        // converged: full residency serves with zero recurring cost
        pool.take_stats(0);
        for _ in 0..2 {
            pool.classify_batch(&images);
        }
        let steady = pool.take_stats(16);
        assert_eq!(steady.programming_cycles(), 0);
        assert_eq!(steady.events.retunes, 0);
    }

    #[test]
    fn banded_sweeps_skew_the_measured_histogram() {
        // classify_batch_positions sweeps only its band, so the drained
        // histogram reflects the band — the drift signal the re-planning
        // controller consumes
        let model = tiny_model(64, 8, 3, 1);
        let pool = MacroPool::new(&model, nominal());
        let imgs = rand_images(4, 64, 3);
        let band = [2usize, 3];
        let full = pool.classify_batch_at(&imgs, 0);
        let banded = pool.classify_batch_positions(&imgs, 0, &band);
        assert_eq!(banded.len(), full.len());
        let h = pool.take_output_traffic();
        for (k, &c) in h.iter().enumerate() {
            let want = if band.contains(&k) { 8 } else { 4 };
            assert_eq!(c, want, "position {k}");
        }
    }

    #[test]
    fn tenant_churn_migrates_without_disturbing_siblings() {
        // runtime add/remove: the sitting tenant keeps serving bit-exact
        // analog results through every incremental migration step while
        // the partition reshapes around it
        let a = tiny_model(100, 16, 4, 42);
        let b = tiny_model(64, 8, 3, 7);
        let imgs_a = rand_images(12, 100, 5);
        let imgs_b = rand_images(12, 64, 6);
        let opts = PipelineOptions::default(); // analog noise
        let budget = MacroPool::macros_required(&a, &opts) + 4;
        let mut pool = MultiPool::new(&[&a], opts, budget);
        let want_a = pool.classify_batch_at(0, &imgs_a, 0);
        let migs = pool.add_tenant(&b, 1.0);
        assert_eq!(pool.n_tenants(), 2);
        assert_eq!(migs.len(), 2);
        assert!(migs[1].is_empty(), "the newcomer builds at its target");
        assert!(!migs[0].is_empty(), "the sitting tenant must cede slots");
        for k in 0..migs[0].steps.len() {
            pool.apply_migration_step(0, &migs[0], k);
            assert_eq!(pool.classify_batch_at(0, &imgs_a, 0), want_a, "step {k}");
        }
        assert_eq!(
            pool.take_migration_stats(0).steps,
            migs[0].steps.len() as u64
        );
        // the newcomer serves exactly like a standalone pool of its plan
        let want_b = pool.classify_batch_at(1, &imgs_b, 0);
        let alone_b = MacroPool::with_plan(&b, opts, pool.tenant(1).plan().unwrap());
        assert_eq!(alone_b.classify_batch_at(&imgs_b, 0), want_b);
        // retiring the newcomer hands its macros back to the survivor
        let migs = pool.remove_tenant(1);
        assert_eq!(pool.n_tenants(), 1);
        assert_eq!(migs.len(), 1);
        for k in 0..migs[0].steps.len() {
            pool.apply_migration_step(0, &migs[0], k);
            assert_eq!(pool.classify_batch_at(0, &imgs_a, 0), want_a, "step {k}");
        }
        assert!(pool.tenant(0).n_macros() > MacroPool::macros_required(&a, &opts) / 2);
    }
}
