//! Multi-macro sharded execution engine with persistent weight residency.
//!
//! The single-macro [`Pipeline`] reprograms every layer's rows into one
//! simulated 128-kbit macro on **every batch** and retunes the rails for
//! every output threshold of every batch — pure overhead at steady state.
//! A `MacroPool` instead partitions a model's layer segments across N
//! simulated [`CamArray`] macros at construction time:
//!
//! * every hidden-layer *load* (one segment's neuron chunk that fits the
//!   configured row count) gets its own macro, programmed **once** and
//!   parked at the layer's midpoint operating point;
//! * the output layer is replicated across one macro **per schedule
//!   threshold**, each parked at its calibrated (V_ref, V_eval, V_st)
//!   triple — so the per-batch threshold sweep becomes a walk across
//!   pre-tuned macros with **zero retunes and zero reprogramming**.
//!
//! This is the paper's §V-B amortisation argument taken to its limit (and
//! the way PIMBALL / ChewBaccaNN scale BNN inference across many in-memory
//! arrays): weight loads and voltage retunes are paid once per deployment,
//! not once per batch.  Models whose load count exceeds the pool capacity
//! fall back to the existing reload scheduler ([`Pipeline`]) transparently.
//!
//! Concurrency: every macro sits behind a `Mutex`, so one pool can be
//! shared across worker threads (`classify_parallel`, `Server`).  Analog
//! noise stays deterministic under any thread interleaving because frozen
//! per-row variation is drawn from each macro's own seed at construction,
//! while per-evaluation noise is drawn from a per-image stream derived
//! from (pool seed, image index) — see [`CamArray::search_into_rng`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::bnn::mapping::segment_query_wide;
use crate::bnn::model::MappedModel;
use crate::cam::{CamArray, CamConfig};
use crate::sim::SimClock;
use crate::util::bitops::BitVec;
use crate::util::rng::{splitmix64, Rng};

use super::pipeline::{
    calibrate_hidden_points, calibrate_output_points, io_cycles_per_image, plan_loads,
    program_load_into, resolve_schedule, Load,
};
use super::pipeline::{Pipeline, PipelineOptions, RunStats};
use super::voltage::CalibratedPoint;

/// Default number of simulated macros a pool may instantiate.
pub const DEFAULT_POOL_MACROS: usize = 64;

/// How the pool executes a model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolMode {
    /// Every load and every output threshold is resident on its own macro.
    Resident,
    /// The model exceeds the pool capacity; the reload scheduler runs it.
    Reload,
}

/// Deterministic per-macro seed derivation (stable across runs/threads).
fn macro_seed(base: u64, idx: u64) -> u64 {
    let mut s = base ^ idx.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    splitmix64(&mut s)
}

struct Resident {
    /// One programmed macro per hidden (layer, load), parked at the
    /// layer's midpoint operating point.
    hidden_slots: Vec<Vec<Mutex<CamArray>>>,
    /// One programmed macro per output-schedule threshold, parked at that
    /// threshold's operating point.
    output_slots: Vec<Mutex<CamArray>>,
    /// Host-device I/O cycles (shared 128-bit bus; same clock domain).
    io_clock: Mutex<SimClock>,
}

/// Sharded multi-macro execution engine for one mapped model.
pub struct MacroPool<'m> {
    model: &'m MappedModel,
    opts: PipelineOptions,
    schedule: Vec<i32>,
    plans: Vec<Vec<Load>>,
    hidden_points: Vec<CalibratedPoint>,
    output_points: Vec<CalibratedPoint>,
    resident: Option<Resident>,
    /// Reload fallback when the model exceeds the pool capacity.
    fallback: Option<Mutex<Pipeline<'m>>>,
    /// Next per-image noise-stream index for [`MacroPool::classify_batch`].
    stream_cursor: AtomicU64,
}

impl<'m> MacroPool<'m> {
    /// Pool with the default macro budget ([`DEFAULT_POOL_MACROS`]).
    pub fn new(model: &'m MappedModel, opts: PipelineOptions) -> Self {
        Self::with_capacity(model, opts, DEFAULT_POOL_MACROS)
    }

    /// Macros a resident pool needs for `model` under `opts`:
    /// one per hidden load plus one per output-schedule threshold.
    pub fn macros_required(model: &MappedModel, opts: &PipelineOptions) -> usize {
        Self::required_for(&plan_loads(model), resolve_schedule(model, opts).len())
    }

    /// Single source of the residency formula (shared by the public probe
    /// and the constructor's capacity check).
    fn required_for(plans: &[Vec<Load>], schedule_len: usize) -> usize {
        let hidden: usize = plans[..plans.len() - 1].iter().map(Vec::len).sum();
        hidden + schedule_len
    }

    /// Pool with an explicit macro budget; falls back to the reload
    /// scheduler when the model needs more macros than `max_macros`.
    pub fn with_capacity(model: &'m MappedModel, opts: PipelineOptions, max_macros: usize) -> Self {
        let out_layer = model.layers.last().expect("model has layers");
        assert_eq!(out_layer.n_seg(), 1, "output layer must fit one CAM word");
        let schedule = resolve_schedule(model, &opts);
        let plans = plan_loads(model);
        let out_idx = model.layers.len() - 1;
        assert_eq!(plans[out_idx].len(), 1, "output layer fits one load");
        let needed = Self::required_for(&plans, schedule.len());

        // calibration (a voltage grid search per hidden layer + per
        // threshold) only runs for the resident path; the reload fallback's
        // Pipeline performs its own identical calibration internally
        let (resident, fallback, hidden_points, output_points) = if needed <= max_macros {
            let hidden_points = calibrate_hidden_points(model, opts.pvt);
            let output_points = calibrate_output_points(model, &schedule, opts.pvt);
            let mut next_macro = 0u64;
            let mut mk_cam = |cfg: CamConfig| {
                let mut cam =
                    CamArray::new(cfg, opts.pvt, opts.noise, macro_seed(opts.seed, next_macro));
                next_macro += 1;
                cam.set_noise_scale(opts.noise_scale);
                cam
            };
            let mut hidden_slots = Vec::with_capacity(out_idx);
            for (li, layer) in model.layers[..out_idx].iter().enumerate() {
                let cfg = CamConfig::fitting(layer.seg_width)
                    .unwrap_or_else(|| panic!("word width {} unsupported", layer.seg_width));
                let mut slots = Vec::with_capacity(plans[li].len());
                for load in &plans[li] {
                    let mut cam = mk_cam(cfg);
                    program_load_into(&mut cam, layer, load);
                    cam.set_voltages(hidden_points[li].voltages);
                    slots.push(Mutex::new(cam));
                }
                hidden_slots.push(slots);
            }
            let out_cfg = CamConfig::fitting(out_layer.seg_width)
                .expect("output word width unsupported");
            let out_load = &plans[out_idx][0];
            let mut output_slots = Vec::with_capacity(schedule.len());
            for point in &output_points {
                let mut cam = mk_cam(out_cfg);
                program_load_into(&mut cam, out_layer, out_load);
                cam.set_voltages(point.voltages);
                output_slots.push(Mutex::new(cam));
            }
            (
                Some(Resident {
                    hidden_slots,
                    output_slots,
                    io_clock: Mutex::new(SimClock::new()),
                }),
                None,
                hidden_points,
                output_points,
            )
        } else {
            (
                None,
                Some(Mutex::new(Pipeline::new(model, opts))),
                Vec::new(),
                Vec::new(),
            )
        };

        MacroPool {
            model,
            opts,
            schedule,
            plans,
            hidden_points,
            output_points,
            resident,
            fallback,
            stream_cursor: AtomicU64::new(0),
        }
    }

    pub fn mode(&self) -> PoolMode {
        if self.resident.is_some() {
            PoolMode::Resident
        } else {
            PoolMode::Reload
        }
    }

    /// Simulated macros instantiated by this pool (1 in reload mode).
    pub fn n_macros(&self) -> usize {
        match &self.resident {
            Some(r) => {
                r.hidden_slots.iter().map(Vec::len).sum::<usize>() + r.output_slots.len()
            }
            None => 1,
        }
    }

    pub fn schedule(&self) -> &[i32] {
        &self.schedule
    }

    pub fn options(&self) -> &PipelineOptions {
        &self.opts
    }

    /// Calibrated output operating points (diagnostics; empty in reload
    /// mode — the fallback `Pipeline` owns its own calibration).
    pub fn output_points(&self) -> &[CalibratedPoint] {
        &self.output_points
    }

    /// Calibrated hidden midpoint per non-output layer (diagnostics;
    /// empty in reload mode).
    pub fn hidden_points(&self) -> &[CalibratedPoint] {
        &self.hidden_points
    }

    /// Per-image noise stream: independent of thread scheduling, derived
    /// from (pool seed, global image index).
    fn image_rng(&self, global_idx: u64) -> Rng {
        Rng::new(self.opts.seed ^ 0xA11A_0F0E_5EED_0001, global_idx)
    }

    /// Classify a batch; noise-stream indices assigned from the pool's
    /// internal cursor (serving path).
    pub fn classify_batch(&self, images: &[BitVec]) -> Vec<(Vec<u32>, usize)> {
        let base = self
            .stream_cursor
            .fetch_add(images.len() as u64, Ordering::Relaxed);
        self.classify_batch_at(images, base)
    }

    /// Classify a batch with explicit noise-stream base index `stream_base`
    /// (the sharded parallel path passes each image's global index so
    /// results do not depend on thread count or interleaving).
    pub fn classify_batch_at(
        &self,
        images: &[BitVec],
        stream_base: u64,
    ) -> Vec<(Vec<u32>, usize)> {
        if images.is_empty() {
            return Vec::new();
        }
        if let Some(fb) = &self.fallback {
            return fb.lock().unwrap().classify_batch(images);
        }
        let resident = self.resident.as_ref().unwrap();
        let mut rngs: Vec<Rng> = (0..images.len())
            .map(|i| self.image_rng(stream_base + i as u64))
            .collect();
        let mut acts: Vec<BitVec> = images.to_vec();
        for layer_idx in 0..self.model.layers.len() - 1 {
            acts = self.run_hidden(resident, layer_idx, &acts, &mut rngs);
        }
        let votes = self.run_output(resident, &acts, &mut rngs);
        resident
            .io_clock
            .lock()
            .unwrap()
            .tick(io_cycles_per_image(self.model, self.schedule.len()) * images.len() as u64);
        votes
            .into_iter()
            .map(|v| {
                let p = crate::bnn::infer::argmax_vote(&v);
                (v, p)
            })
            .collect()
    }

    /// Execute one hidden layer for a batch over the layer's resident
    /// load macros; returns the hidden codes (majority across segments).
    fn run_hidden(
        &self,
        resident: &Resident,
        layer_idx: usize,
        inputs: &[BitVec],
        rngs: &mut [Rng],
    ) -> Vec<BitVec> {
        let layer = &self.model.layers[layer_idx];
        let n_out = layer.n_out();
        let n_seg = layer.n_seg();
        let mut seg_fires = vec![vec![0u8; n_out]; inputs.len()];
        let (mut m, mut f) = (Vec::new(), Vec::new());
        // rails were parked at the layer's midpoint at construction — no
        // set_voltages on the batch path
        for (load_idx, load) in self.plans[layer_idx].iter().enumerate() {
            let mut cam = resident.hidden_slots[layer_idx][load_idx].lock().unwrap();
            let width = cam.config().width();
            let payload = (load.neuron_hi - load.neuron_lo) as u64
                * (layer.seg_bounds[load.seg + 1] - layer.seg_bounds[load.seg]) as u64;
            for (img_idx, x) in inputs.iter().enumerate() {
                let q = segment_query_wide(layer, load.seg, x, width);
                cam.search_into_rng(&q, &mut m, &mut f, &mut rngs[img_idx]);
                cam.events.useful_macs += payload;
                for (row, neuron) in (load.neuron_lo..load.neuron_hi).enumerate() {
                    if f[row] {
                        seg_fires[img_idx][neuron] += 1;
                    }
                }
            }
        }
        seg_fires
            .into_iter()
            .map(|fires| {
                let mut h = BitVec::zeros(n_out);
                for (j, &cnt) in fires.iter().enumerate() {
                    // majority of segments, ties fire (MLSA convention)
                    h.set(j, (cnt as usize) * 2 >= n_seg);
                }
                h
            })
            .collect()
    }

    /// Output-layer threshold sweep: one pre-tuned macro per threshold, so
    /// a batch is a pure sequence of searches — no retunes.
    fn run_output(
        &self,
        resident: &Resident,
        hidden: &[BitVec],
        rngs: &mut [Rng],
    ) -> Vec<Vec<u32>> {
        let layer = self.model.layers.last().unwrap();
        let n_cls = layer.n_out();
        let width = CamConfig::fitting(layer.seg_width).unwrap().width();
        // queries are threshold-independent: build once per batch
        let queries: Vec<BitVec> = hidden
            .iter()
            .map(|h| segment_query_wide(layer, 0, h, width))
            .collect();
        let mut votes = vec![vec![0u32; n_cls]; hidden.len()];
        let (mut m, mut f) = (Vec::new(), Vec::new());
        let payload = (layer.n_in() * n_cls) as u64;
        for slot in &resident.output_slots {
            let mut cam = slot.lock().unwrap();
            for (img_idx, q) in queries.iter().enumerate() {
                cam.search_into_rng(q, &mut m, &mut f, &mut rngs[img_idx]);
                cam.events.useful_macs += payload;
                for (c, vote) in votes[img_idx].iter_mut().enumerate() {
                    if f[c] {
                        *vote += 1;
                    }
                }
            }
        }
        votes
    }

    /// Drain device statistics accumulated since the last call, summed
    /// across every macro in the pool (aggregate device work, not
    /// wall-clock: resident macros operate concurrently in silicon).
    pub fn take_stats(&self, inferences: u64) -> RunStats {
        if let Some(fb) = &self.fallback {
            return fb.lock().unwrap().take_stats(inferences);
        }
        let resident = self.resident.as_ref().unwrap();
        let mut stats = RunStats {
            inferences,
            ..RunStats::default()
        };
        let mut drain = |cam: &mut CamArray| {
            stats.cycles += cam.clock.cycles;
            stats.stall_s += cam.clock.stall_s;
            stats.events.add(&cam.events);
            cam.reset_accounting();
        };
        for slots in &resident.hidden_slots {
            for slot in slots {
                drain(&mut slot.lock().unwrap());
            }
        }
        for slot in &resident.output_slots {
            drain(&mut slot.lock().unwrap());
        }
        let mut io = resident.io_clock.lock().unwrap();
        stats.cycles += io.cycles;
        stats.stall_s += io.stall_s;
        io.reset();
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::infer::digital_forward;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::cam::NoiseMode;

    fn rand_images(n: usize, bits: usize, seed: u64) -> Vec<BitVec> {
        let mut rng = Rng::new(seed, 1);
        (0..n)
            .map(|_| {
                let mut v = BitVec::zeros(bits);
                for i in 0..bits {
                    v.set(i, rng.chance(0.5));
                }
                v
            })
            .collect()
    }

    fn nominal() -> PipelineOptions {
        PipelineOptions {
            noise: NoiseMode::Nominal,
            ..Default::default()
        }
    }

    #[test]
    fn resident_pool_matches_single_macro_pipeline_bit_exactly() {
        // acceptance: sharded pool predictions (and votes) are identical
        // to the single-macro Pipeline under NoiseMode::Nominal
        let model = tiny_model(100, 16, 4, 42);
        let images = rand_images(24, 100, 7);
        let pool = MacroPool::new(&model, nominal());
        assert_eq!(pool.mode(), PoolMode::Resident);
        let mut pipe = Pipeline::new(&model, nominal());
        for chunk_len in [1usize, 5, 24] {
            for chunk in images.chunks(chunk_len) {
                let got = pool.classify_batch(chunk);
                let want = pipe.classify_batch(chunk);
                assert_eq!(got, want, "chunk_len {chunk_len}");
            }
        }
        // and both agree with the digital oracle
        let got = pool.classify_batch(&images);
        for (img, (votes, pred)) in images.iter().zip(&got) {
            let (want_votes, want_pred) = digital_forward(&model, img, pool.schedule());
            assert_eq!(votes, &want_votes);
            assert_eq!(pred, &want_pred);
        }
    }

    #[test]
    fn steady_state_batches_pay_zero_programming_and_zero_retunes() {
        let model = tiny_model(64, 8, 3, 2);
        let images = rand_images(16, 64, 3);
        let pool = MacroPool::new(&model, nominal());
        // warmup: construction programmed the macros; drain that epoch
        pool.classify_batch(&images);
        let warm = pool.take_stats(16);
        assert!(warm.events.row_writes > 0, "construction programs rows");
        // steady state: no programming, no retunes, no stalls — searches only
        pool.classify_batch(&images);
        pool.classify_batch(&images);
        let steady = pool.take_stats(32);
        assert_eq!(steady.programming_cycles(), 0, "{:?}", steady.events);
        assert_eq!(steady.events.row_writes, 0);
        assert_eq!(steady.events.cells_written, 0);
        assert_eq!(steady.events.retunes, 0);
        assert_eq!(steady.stall_s, 0.0);
        assert!(steady.events.searches > 0);
        assert!(steady.cycles > 0);
    }

    #[test]
    fn resident_pool_beats_reload_pipeline_on_steady_state_cycles() {
        let model = tiny_model(100, 16, 4, 11);
        let images = rand_images(32, 100, 5);
        let pool = MacroPool::new(&model, nominal());
        pool.classify_batch(&images); // warmup
        pool.take_stats(0);
        for _ in 0..4 {
            pool.classify_batch(&images);
        }
        let pool_cpi = pool.take_stats(4 * 32).cycles_per_inference();

        let mut pipe = Pipeline::new(&model, nominal());
        pipe.classify_batch(&images); // same warmup treatment
        pipe.take_stats(0);
        for _ in 0..4 {
            pipe.classify_batch(&images);
        }
        let pipe_cpi = pipe.take_stats(4 * 32).cycles_per_inference();
        assert!(
            pool_cpi < pipe_cpi,
            "resident {pool_cpi} should beat reload {pipe_cpi}"
        );
    }

    #[test]
    fn capacity_overflow_falls_back_to_reload_scheduler() {
        let model = tiny_model(64, 8, 3, 9);
        let needed = MacroPool::macros_required(&model, &nominal());
        assert!(needed > 2);
        let pool = MacroPool::with_capacity(&model, nominal(), 2);
        assert_eq!(pool.mode(), PoolMode::Reload);
        // still bit-exact vs the pipeline in nominal mode
        let images = rand_images(10, 64, 13);
        let mut pipe = Pipeline::new(&model, nominal());
        assert_eq!(pool.classify_batch(&images), pipe.classify_batch(&images));
        // stats flow through the fallback
        let s = pool.take_stats(10);
        assert!(s.cycles > 0);
        assert!(s.events.searches > 0);
    }

    #[test]
    fn macro_budget_matches_plan() {
        let model = tiny_model(100, 16, 4, 21);
        let opts = nominal();
        let pool = MacroPool::new(&model, opts);
        assert_eq!(pool.mode(), PoolMode::Resident);
        // 1 hidden load + 33 output thresholds for the tiny fixture
        assert_eq!(pool.n_macros(), MacroPool::macros_required(&model, &opts));
        assert_eq!(pool.n_macros(), 1 + pool.schedule().len());
    }

    #[test]
    fn analog_mode_deterministic_for_fixed_stream_indices() {
        let model = tiny_model(64, 8, 4, 31);
        let images = rand_images(12, 64, 17);
        let opts = PipelineOptions::default(); // analog noise
        let a = MacroPool::new(&model, opts).classify_batch_at(&images, 0);
        let b = MacroPool::new(&model, opts).classify_batch_at(&images, 0);
        assert_eq!(a, b);
        // a different seed draws different noise
        let c = MacroPool::new(
            &model,
            PipelineOptions {
                seed: opts.seed ^ 0xDEAD,
                ..opts
            },
        )
        .classify_batch_at(&images, 0);
        // votes are near-deterministic on easy instances; only require the
        // structures to be well-formed rather than identical
        assert_eq!(c.len(), a.len());
    }

    #[test]
    fn schedule_prefix_respected() {
        let model = tiny_model(64, 8, 3, 1);
        let pool = MacroPool::new(
            &model,
            PipelineOptions {
                noise: NoiseMode::Nominal,
                schedule_prefix: Some(5),
                ..Default::default()
            },
        );
        assert_eq!(pool.schedule(), &model.schedule[..5]);
        assert_eq!(pool.n_macros(), 1 + 5);
    }
}
