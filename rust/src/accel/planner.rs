//! Capacity-aware macro placement: how a fixed budget of simulated
//! 128-kbit macros is spent on one mapped model.
//!
//! PR 1's pool was all-or-nothing — either every hidden load *and* every
//! output threshold got its own macro, or the model dropped to the
//! single-macro reload scheduler.  The planner replaces that cliff with a
//! cost-model-driven [`PlacementPlan`]:
//!
//! 1. **Hidden loads come first.**  Sharing a hidden macro would mean
//!    reprogramming rows mid-batch (the 138-cycle-per-load reload tax the
//!    pool exists to kill), so a plan is only resident when every hidden
//!    load owns a macro.
//! 2. **Output thresholds share.**  All output slots hold the *same*
//!    programmed rows and differ only in their parked (V_ref, V_eval,
//!    V_st) triple, so a threshold that loses its dedicated macro costs a
//!    *retune*, never a reprogram.  With `d` pinned thresholds and `s`
//!    shared slots serving the remaining `r = K − d` (LRU over parked
//!    triples), a cyclic Algorithm-1 sweep pays 0 retunes/batch when
//!    `r ≤ s` and `r` retunes/batch otherwise — LRU misses every access
//!    of a cycle longer than the slot pool.  That makes pins strictly
//!    better than extra shared slots for sweep traffic, so the planner
//!    maximises `d` and keeps a single shared slot (`s = 1`) as the
//!    funnel; the LRU mechanism still pays off for non-cyclic operating
//!    point traffic (schedule prefixes, future per-request points).
//! 3. **Surplus replicates hidden loads.**  Budget beyond full pinning
//!    buys hidden-load replicas so `classify_parallel` workers search a
//!    free replica instead of serialising on one `Mutex<CamArray>`.
//!    Every image touches every load once per batch, so "hot" means
//!    longest lock hold — loads are replicated in descending row count,
//!    and never past the worker count the pool serves (a replica no
//!    searcher can reach is pure simulated area).
//!
//! Cost model summary (steady state, per batch): resident plans pay
//! `predicted_retunes_per_batch()` retune stalls and zero programming;
//! the reload `Pipeline` pays `K` output retunes plus a full reprogram of
//! every hidden load.  A plan is only worth emitting when its budget
//! covers all hidden loads plus one output slot; below that the caller
//! falls back to reload mode.

/// How a macro budget is spent on one model: replicas per hidden load,
/// pinned output thresholds, and LRU-shared output slots.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlacementPlan {
    /// The budget the plan was built against (`macros_used() <= budget`).
    pub budget: usize,
    /// Macro replicas per hidden (layer, load); parallel to the layer
    /// load plans, every entry ≥ 1.
    pub hidden_replicas: Vec<Vec<usize>>,
    /// The first `pinned` schedule thresholds own a permanently parked
    /// macro each (zero steady-state retunes).
    pub pinned: usize,
    /// Shared output slots serving thresholds `pinned..schedule_len`,
    /// parked at one triple each and evicted LRU.
    pub shared_slots: usize,
    /// Total output-schedule thresholds.
    pub schedule_len: usize,
}

/// Build a plan for a model with the given hidden-load row counts
/// (`hidden_load_rows[layer][load]` = programmed rows of that load) and
/// output schedule length, under `budget` macros, serving `workers`
/// concurrent searchers.  A load is never replicated beyond `workers`
/// copies — more replicas than searchers can only sit idle — so a
/// single-worker plan leaves surplus budget unspent rather than burning
/// area on macros nobody can reach.  Returns `None` when the budget
/// cannot hold every hidden load plus one output slot — the caller
/// should then run the reload scheduler.
pub fn plan(
    hidden_load_rows: &[Vec<usize>],
    schedule_len: usize,
    budget: usize,
    workers: usize,
) -> Option<PlacementPlan> {
    let hidden: usize = hidden_load_rows.iter().map(Vec::len).sum();
    let min_output = schedule_len.min(1);
    if budget < hidden + min_output {
        return None;
    }
    let output_budget = budget - hidden;
    let (pinned, shared_slots) = if schedule_len == 0 {
        (0, 0)
    } else if output_budget >= schedule_len {
        // full pinning: every threshold parked forever, zero retunes
        (schedule_len, 0)
    } else {
        // maximise pins, funnel the rest through one LRU slot (see the
        // module docs for why one funnel beats a balanced split)
        (output_budget - 1, 1)
    };
    let mut hidden_replicas: Vec<Vec<usize>> = hidden_load_rows
        .iter()
        .map(|layer| vec![1; layer.len()])
        .collect();
    let cap = workers.max(1);
    let mut surplus = budget - hidden - pinned - shared_slots;
    if surplus > 0 && hidden > 0 && cap > 1 {
        // replicate hottest-first: largest loads hold their lock longest
        let mut order: Vec<(usize, usize)> = hidden_load_rows
            .iter()
            .enumerate()
            .flat_map(|(li, layer)| (0..layer.len()).map(move |di| (li, di)))
            .collect();
        order.sort_by_key(|&(li, di)| std::cmp::Reverse(hidden_load_rows[li][di]));
        let mut cursor = 0usize;
        let mut at_cap = 0usize;
        while surplus > 0 && at_cap < order.len() {
            let (li, di) = order[cursor % order.len()];
            cursor += 1;
            if hidden_replicas[li][di] < cap {
                hidden_replicas[li][di] += 1;
                surplus -= 1;
                at_cap = 0;
            } else {
                at_cap += 1;
            }
        }
    }
    Some(PlacementPlan {
        budget,
        hidden_replicas,
        pinned,
        shared_slots,
        schedule_len,
    })
}

impl PlacementPlan {
    /// Macros spent on hidden loads (replicas included).
    pub fn hidden_macros(&self) -> usize {
        self.hidden_replicas.iter().flatten().sum()
    }

    /// Macros spent on the output sweep (pinned + shared).
    pub fn output_macros(&self) -> usize {
        self.pinned + self.shared_slots
    }

    /// Total macros the plan instantiates (never exceeds the budget).
    pub fn macros_used(&self) -> usize {
        self.hidden_macros() + self.output_macros()
    }

    /// Whether any threshold lost its dedicated macro.
    pub fn sharing_active(&self) -> bool {
        self.pinned < self.schedule_len
    }

    /// Whether surplus budget bought hidden-load replicas.
    pub fn replication_active(&self) -> bool {
        self.hidden_replicas.iter().flatten().any(|&r| r > 1)
    }

    /// Steady-state retune upper bound per batch for the cyclic
    /// Algorithm-1 sweep: the `r = schedule_len − pinned` unpinned
    /// thresholds all miss when they outnumber the shared slots (LRU on a
    /// cycle longer than the pool), and all park permanently otherwise.
    /// Thresholds whose calibrated triples coincide retune for free, so
    /// the measured count may come in below this bound.
    pub fn predicted_retunes_per_batch(&self) -> u64 {
        let rest = self.schedule_len - self.pinned;
        if rest <= self.shared_slots {
            0
        } else {
            rest as u64
        }
    }

    /// One-line human description for reports and examples.
    pub fn describe(&self) -> String {
        let h: usize = self.hidden_replicas.iter().map(Vec::len).sum();
        format!(
            "{} macros: {} hidden loads ({} replicas), {}/{} thresholds pinned, \
             {} shared slot(s), ≤{} retunes/batch",
            self.macros_used(),
            h,
            self.hidden_macros() - h,
            self.pinned,
            self.schedule_len,
            self.shared_slots,
            self.predicted_retunes_per_batch()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infeasible_budgets_return_none() {
        // 3 hidden loads + ≥1 output slot → 4 macros minimum
        let rows = vec![vec![64, 64], vec![16]];
        for budget in 0..4 {
            assert!(plan(&rows, 33, budget, 1).is_none(), "budget {budget}");
        }
        assert!(plan(&rows, 33, 4, 1).is_some());
    }

    #[test]
    fn full_budget_pins_everything_and_replicates_surplus() {
        let rows = vec![vec![64, 64], vec![16]];
        let p = plan(&rows, 33, 3 + 33, 4).unwrap();
        assert_eq!(p.pinned, 33);
        assert_eq!(p.shared_slots, 0);
        assert!(!p.sharing_active());
        assert!(!p.replication_active());
        assert_eq!(p.predicted_retunes_per_batch(), 0);
        assert_eq!(p.macros_used(), 36);

        // 5 surplus macros: hottest loads (64 rows) replicate first
        let p = plan(&rows, 33, 3 + 33 + 5, 4).unwrap();
        assert!(p.replication_active());
        assert_eq!(p.macros_used(), 41);
        assert_eq!(p.predicted_retunes_per_batch(), 0);
        // round-robin over [64, 64, 16] hottest-first: 2+2+1
        assert_eq!(p.hidden_replicas, vec![vec![3, 3], vec![2]]);
    }

    #[test]
    fn replication_never_exceeds_the_worker_count() {
        let rows = vec![vec![64], vec![16]];
        // huge surplus, 3 workers: every load caps at 3 replicas and the
        // rest of the budget stays unspent
        let p = plan(&rows, 4, 100, 3).unwrap();
        assert_eq!(p.hidden_replicas, vec![vec![3], vec![3]]);
        assert_eq!(p.macros_used(), 6 + 4);
        // one worker: replicas can only idle, so none are built
        let p = plan(&rows, 4, 100, 1).unwrap();
        assert!(!p.replication_active());
        assert_eq!(p.macros_used(), 2 + 4);
    }

    #[test]
    fn degraded_budget_shares_thresholds_through_one_slot() {
        // the acceptance shape: 6 hidden loads + 33 thresholds = 39 full,
        // planned into 16
        let rows = vec![vec![64; 6]];
        let p = plan(&rows, 33, 16, 1).unwrap();
        assert_eq!(p.hidden_macros(), 6);
        assert_eq!(p.pinned, 9);
        assert_eq!(p.shared_slots, 1);
        assert_eq!(p.macros_used(), 16);
        assert!(p.sharing_active());
        // 24 unpinned thresholds funnel through the shared slot
        assert_eq!(p.predicted_retunes_per_batch(), 24);
    }

    #[test]
    fn minimum_viable_budget_runs_everything_shared() {
        let rows = vec![vec![64]];
        let p = plan(&rows, 33, 2, 1).unwrap();
        assert_eq!(p.pinned, 0);
        assert_eq!(p.shared_slots, 1);
        assert_eq!(p.predicted_retunes_per_batch(), 33);
        assert_eq!(p.macros_used(), 2);
    }

    #[test]
    fn pinning_dominates_extra_shared_slots_for_cyclic_sweeps() {
        // the cost-model claim: at equal budget, d pins + 1 funnel beats
        // any balanced shared split (whose LRU thrashes the full cycle)
        let rows = vec![vec![64]];
        for budget in 3..34 {
            let p = plan(&rows, 33, budget, 1).unwrap();
            let balanced_cost = 33u64; // s ≥ 2 shared slots, r > s → all miss
            assert!(
                p.predicted_retunes_per_batch() < balanced_cost,
                "budget {budget}: {}",
                p.predicted_retunes_per_batch()
            );
        }
    }

    #[test]
    fn empty_schedule_needs_no_output_macros() {
        let rows = vec![vec![64, 32]];
        let p = plan(&rows, 0, 2, 1).unwrap();
        assert_eq!(p.output_macros(), 0);
        assert_eq!(p.predicted_retunes_per_batch(), 0);
        assert!(plan(&rows, 0, 1, 1).is_none());
    }

    #[test]
    fn describe_mentions_the_split() {
        let p = plan(&[vec![64; 6]], 33, 16, 1).unwrap();
        let d = p.describe();
        assert!(d.contains("16 macros"), "{d}");
        assert!(d.contains("9/33"), "{d}");
    }
}
