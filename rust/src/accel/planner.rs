//! Capacity-aware macro placement: how a fixed budget of simulated
//! 128-kbit macros is spent on one model — or partitioned across a
//! multi-tenant pool of models.
//!
//! PR 1's pool was all-or-nothing — either every hidden load *and* every
//! output threshold got its own macro, or the model dropped to the
//! single-macro reload scheduler.  The planner replaces that cliff with a
//! cost-model-driven [`PlacementPlan`]:
//!
//! 1. **Hidden loads come first.**  Sharing a hidden macro would mean
//!    reprogramming rows mid-batch (the 138-cycle-per-load reload tax the
//!    pool exists to kill), so a plan keeps every hidden load it can
//!    afford resident.  Budgets below hidden-loads + 1 no longer drop the
//!    whole model to the reload scheduler: the **coldest** hidden loads
//!    (smallest programmed row count — cheapest to reprogram) *spill* to
//!    the shared funnel slot and are reloaded there per batch
//!    (`hidden_replicas[li][di] == 0`), while the hottest `budget − 1`
//!    loads stay resident.  Only budgets that cannot hold one resident
//!    load plus the funnel (or a single-load model below full residency)
//!    fall back to reload.
//! 2. **Output thresholds share.**  All output slots hold the *same*
//!    programmed rows and differ only in their parked (V_ref, V_eval,
//!    V_st) triple, so a threshold that loses its dedicated macro costs a
//!    *retune*, never a reprogram.  Schedule positions whose calibrated
//!    triples coincide (equal threshold values — calibration is a pure
//!    function of the target) are grouped into one **operating point**
//!    ([`PlacementPlan::point_of`]); pinning a point parks *one* macro
//!    that serves every position of that point.  Points are pinned
//!    hottest-first by the per-position traffic histogram (schedule
//!    frequency by default, measured access counts when fed back from the
//!    pool — see `MacroPool::take_output_traffic`), and the remaining
//!    points funnel through a single LRU-parked shared slot.  For an
//!    all-distinct uniform schedule this reduces to the PR 2 rule — pin a
//!    prefix of `d` thresholds, pay exactly `K − d` retunes/batch on the
//!    cyclic sweep — while skewed schedules (repeated values, measured
//!    hot spots) pay strictly less: the predicted cost is the number of
//!    operating-point *transitions* the funnel sees per batch
//!    ([`PlacementPlan::predicted_retunes_per_batch`]).
//! 3. **Surplus replicates hidden loads.**  Budget beyond full pinning
//!    buys hidden-load replicas so `classify_parallel` workers search a
//!    free replica instead of serialising on one `Mutex<CamArray>`.
//!    Every image touches every load once per batch, so "hot" means
//!    longest lock hold — loads are replicated in descending row count,
//!    and never past the worker count the pool serves (a replica no
//!    searcher can reach is pure simulated area).
//!
//! **Multi-tenant pools** ([`plan_tenants`]) partition one budget across
//! N models: every tenant first receives its feasibility floor (full
//! hidden residency + one output slot, degrading through cold-spill down
//! to two macros), then the surplus is distributed proportional-fair by
//! each tenant's measured traffic share, capped at the budget past which
//! extra macros would idle (full point pinning + worker-capped
//! replicas).  Tenants never share macros — different models' rows
//! differ — so isolation is structural: a tenant's plan is exactly a
//! single-model [`PlacementPlan`] over its sub-budget, and its results
//! are bit-identical to that model running alone on its own pool.
//!
//! Cost model summary (steady state, per batch): resident plans pay
//! [`PlacementPlan::predicted_retunes_per_batch`] retune stalls and zero
//! programming; spill plans additionally reprogram each spilled load (and
//! re-land the output rows in the funnel once); the reload `Pipeline`
//! pays `K` output retunes plus a full reprogram of every hidden load.

/// How a macro budget is spent on one model: replicas per hidden load,
/// pinned output operating points, and LRU-shared output slots.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlacementPlan {
    /// The budget the plan was built against (`macros_used() <= budget`).
    pub budget: usize,
    /// Macro replicas per hidden (layer, load); parallel to the layer
    /// load plans.  `0` marks a cold-spilled load: it owns no macro and
    /// is reprogrammed into the shared funnel slot per batch.
    pub hidden_replicas: Vec<Vec<usize>>,
    /// Pinned slot per schedule position: `Some(s)` routes to pinned
    /// macro `s` (positions sharing an operating point share a slot),
    /// `None` routes through the shared LRU funnel.
    pub pin_slot: Vec<Option<usize>>,
    /// Operating-point class per schedule position: positions with equal
    /// class park identical calibrated triples (retunes between them are
    /// free).  The compat [`plan`] entry point treats every position as
    /// its own point.
    pub point_of: Vec<usize>,
    /// Number of pinned output slot macros.
    pub pinned: usize,
    /// Shared output slots serving the unpinned points (and any spilled
    /// hidden loads), parked at one triple each and evicted LRU.
    pub shared_slots: usize,
    /// Total output-schedule positions.
    pub schedule_len: usize,
    /// Cost-model retunes/batch (funnel operating-point transitions).
    predicted_retunes: u64,
}

/// Build a plan for a model with the given hidden-load row counts
/// (`hidden_load_rows[layer][load]` = programmed rows of that load) and
/// output schedule length, under `budget` macros, serving `workers`
/// concurrent searchers.  Every schedule position is treated as its own
/// operating point with uniform traffic (the PR 2 behaviour: prefix
/// pinning, `K − d` retunes/batch); see [`plan_traffic`] for
/// point-grouped, histogram-driven pinning.  Returns `None` when the
/// budget cannot run the model resident even with cold-spill — the
/// caller should then run the reload scheduler.
pub fn plan(
    hidden_load_rows: &[Vec<usize>],
    schedule_len: usize,
    budget: usize,
    workers: usize,
) -> Option<PlacementPlan> {
    let points: Vec<usize> = (0..schedule_len).collect();
    plan_traffic(hidden_load_rows, &points, None, budget, workers)
}

/// The traffic-aware planner core.  `schedule_points[k]` is the
/// operating-point class of schedule position `k` (positions with equal
/// class share one calibrated triple); `traffic[k]` is the measured (or
/// assumed) access count of position `k` per batch — `None` means
/// uniform.  Pinning is hottest-point-first; ties break toward the
/// earliest schedule position so plans are deterministic.
pub fn plan_traffic(
    hidden_load_rows: &[Vec<usize>],
    schedule_points: &[usize],
    traffic: Option<&[u64]>,
    budget: usize,
    workers: usize,
) -> Option<PlacementPlan> {
    let schedule_len = schedule_points.len();
    // an empty histogram means "nothing measured yet" (e.g. fed back
    // from a pool that ran in reload mode) — treat it as uniform rather
    // than panicking on the length mismatch
    let traffic = traffic.filter(|t| !t.is_empty());
    if let Some(t) = traffic {
        assert_eq!(t.len(), schedule_len, "one traffic count per position");
    }
    let hidden: usize = hidden_load_rows.iter().map(Vec::len).sum();
    let min_output = schedule_len.min(1);
    let spill = budget < hidden + min_output;
    if spill && (hidden < 2 || budget < 2) {
        return None;
    }

    let (mut hidden_replicas, resident_hidden) = if spill {
        // cold-spill: keep the hottest budget−1 loads resident (largest
        // row count = most expensive to reprogram), run the rest through
        // the shared funnel slot per batch
        let mut order: Vec<(usize, usize)> = load_order(hidden_load_rows);
        order.truncate(budget - 1);
        let mut replicas: Vec<Vec<usize>> = hidden_load_rows
            .iter()
            .map(|layer| vec![0; layer.len()])
            .collect();
        for &(li, di) in &order {
            replicas[li][di] = 1;
        }
        (replicas, budget - 1)
    } else {
        let replicas: Vec<Vec<usize>> = hidden_load_rows
            .iter()
            .map(|layer| vec![1; layer.len()])
            .collect();
        (replicas, hidden)
    };

    // --- output placement: pin whole operating points hottest-first ---
    // distinct points in first-appearance order, with accumulated weight
    let mut point_ids: Vec<usize> = Vec::new();
    let mut weights: Vec<u64> = Vec::new();
    for (k, &p) in schedule_points.iter().enumerate() {
        let w = traffic.map_or(1, |t| t[k]);
        match point_ids.iter().position(|&q| q == p) {
            Some(i) => weights[i] += w,
            None => {
                point_ids.push(p);
                weights.push(w);
            }
        }
    }
    let n_points = point_ids.len();
    let output_budget = if spill { 1 } else { budget - hidden };
    let (pinned_points, shared_slots): (Vec<usize>, usize) = if schedule_len == 0 {
        // no output sweep; spill plans still keep the funnel for loads
        (Vec::new(), usize::from(spill))
    } else if !spill && output_budget >= n_points {
        // full pinning: every point parked forever, zero retunes
        ((0..n_points).collect(), 0)
    } else {
        // maximise pins under the histogram, funnel the rest through one
        // LRU slot (see the module docs for why one funnel beats a
        // balanced split); spill plans keep the whole sweep in the funnel
        let d = output_budget.saturating_sub(1).min(n_points);
        let mut by_heat: Vec<usize> = (0..n_points).collect();
        by_heat.sort_by_key(|&i| std::cmp::Reverse(weights[i])); // stable: ties → earliest
        (by_heat[..d].to_vec(), 1)
    };

    // per-position routing: positions of a pinned point share its slot,
    // slots numbered by the point's first appearance for determinism
    let mut slot_of_point: Vec<Option<usize>> = vec![None; n_points];
    let mut ordered: Vec<usize> = pinned_points;
    ordered.sort_unstable();
    for (slot, &pi) in ordered.iter().enumerate() {
        slot_of_point[pi] = Some(slot);
    }
    let pinned = ordered.len();
    let point_of: Vec<usize> = schedule_points
        .iter()
        .map(|&p| point_ids.iter().position(|&q| q == p).unwrap())
        .collect();
    let pin_slot: Vec<Option<usize>> = point_of.iter().map(|&pi| slot_of_point[pi]).collect();

    // --- surplus buys hidden-load replicas (never on spill plans) ---
    let cap = workers.max(1);
    let mut surplus = budget - resident_hidden - pinned - shared_slots;
    if !spill && surplus > 0 && hidden > 0 && cap > 1 {
        // replicate hottest-first: largest loads hold their lock longest
        let order = load_order(hidden_load_rows);
        let mut cursor = 0usize;
        let mut at_cap = 0usize;
        while surplus > 0 && at_cap < order.len() {
            let (li, di) = order[cursor % order.len()];
            cursor += 1;
            if hidden_replicas[li][di] < cap {
                hidden_replicas[li][di] += 1;
                surplus -= 1;
                at_cap = 0;
            } else {
                at_cap += 1;
            }
        }
    }

    // --- cost model: funnel operating-point transitions per batch ---
    // the funnel's per-batch access sequence is every spilled load (in
    // execution order; loads of one layer share the layer midpoint) then
    // every unpinned schedule position in sweep order.  A retune is paid
    // exactly when the parked triple changes, cyclically across batches.
    let mut funnel: Vec<(u8, usize)> = Vec::new();
    for (li, layer) in hidden_replicas.iter().enumerate() {
        for &r in layer.iter() {
            if r == 0 {
                funnel.push((1, li)); // spilled load parks the layer midpoint
            }
        }
    }
    for (k, slot) in pin_slot.iter().enumerate() {
        if slot.is_none() {
            funnel.push((0, point_of[k]));
        }
    }
    let distinct_funnel = {
        let mut seen: Vec<(u8, usize)> = Vec::new();
        for &e in &funnel {
            if !seen.contains(&e) {
                seen.push(e);
            }
        }
        seen.len()
    };
    let predicted_retunes = if distinct_funnel <= shared_slots {
        0 // every funnel point parks permanently
    } else {
        cyclic_transitions(&funnel)
    };

    Some(PlacementPlan {
        budget,
        hidden_replicas,
        pin_slot,
        point_of,
        pinned,
        shared_slots,
        schedule_len,
        predicted_retunes,
    })
}

/// Hidden loads ordered hottest-first (descending row count; stable, so
/// ties keep (layer, load) order) — shared by replication and spill.
fn load_order(hidden_load_rows: &[Vec<usize>]) -> Vec<(usize, usize)> {
    let mut order: Vec<(usize, usize)> = hidden_load_rows
        .iter()
        .enumerate()
        .flat_map(|(li, layer)| (0..layer.len()).map(move |di| (li, di)))
        .collect();
    order.sort_by_key(|&(li, di)| std::cmp::Reverse(hidden_load_rows[li][di]));
    order
}

/// Transitions in a cyclic sequence (how often adjacent entries differ,
/// wrapping the end around to the start): the steady-state retunes/batch
/// a single LRU funnel slot pays for this access pattern.
fn cyclic_transitions(seq: &[(u8, usize)]) -> u64 {
    if seq.len() <= 1 {
        return 0;
    }
    let mut t = 0u64;
    let mut prev = *seq.last().unwrap();
    for &e in seq {
        if e != prev {
            t += 1;
        }
        prev = e;
    }
    t
}

impl PlacementPlan {
    /// Macros spent on hidden loads (replicas included; spilled loads
    /// contribute nothing).
    pub fn hidden_macros(&self) -> usize {
        self.hidden_replicas.iter().flatten().sum()
    }

    /// Macros spent on the output sweep / funnel (pinned + shared).
    pub fn output_macros(&self) -> usize {
        self.pinned + self.shared_slots
    }

    /// Total macros the plan instantiates (never exceeds the budget).
    pub fn macros_used(&self) -> usize {
        self.hidden_macros() + self.output_macros()
    }

    /// Schedule positions served by a permanently pinned macro.
    pub fn pinned_positions(&self) -> usize {
        self.pin_slot.iter().filter(|s| s.is_some()).count()
    }

    /// Whether any schedule position lost its dedicated operating point.
    pub fn sharing_active(&self) -> bool {
        self.pinned_positions() < self.schedule_len
    }

    /// Whether surplus budget bought hidden-load replicas.
    pub fn replication_active(&self) -> bool {
        self.hidden_replicas.iter().flatten().any(|&r| r > 1)
    }

    /// Whether any hidden load is cold-spilled to the funnel slot.
    pub fn spill_active(&self) -> bool {
        self.hidden_replicas.iter().flatten().any(|&r| r == 0)
    }

    /// Cold-spilled hidden loads (reprogrammed into the funnel per batch).
    pub fn spilled_loads(&self) -> usize {
        self.hidden_replicas
            .iter()
            .flatten()
            .filter(|&&r| r == 0)
            .count()
    }

    /// Steady-state retune upper bound per batch: the number of
    /// operating-point transitions the shared funnel sees on one cyclic
    /// Algorithm-1 sweep (spilled loads included).  Pinned points and
    /// consecutive same-point accesses are free; for an all-distinct
    /// uniform schedule this is exactly the classic `K − d`.  Measured
    /// counts may come in below the bound when triples of *different*
    /// points happen to coincide at the DAC grid.
    pub fn predicted_retunes_per_batch(&self) -> u64 {
        self.predicted_retunes
    }

    /// One-line human description for reports and examples.
    pub fn describe(&self) -> String {
        let h: usize = self.hidden_replicas.iter().map(Vec::len).sum();
        format!(
            "{} macros: {} hidden loads ({} replicas, {} spilled), {}/{} thresholds pinned \
             on {} slot(s), {} shared slot(s), ≤{} retunes/batch",
            self.macros_used(),
            h,
            self.hidden_macros().saturating_sub(h - self.spilled_loads()),
            self.spilled_loads(),
            self.pinned_positions(),
            self.schedule_len,
            self.pinned,
            self.shared_slots,
            self.predicted_retunes_per_batch()
        )
    }
}

/// One tenant's shape and traffic, as seen by [`plan_tenants`].
#[derive(Clone, Debug)]
pub struct TenantSpec<'t> {
    /// Programmed rows per hidden (layer, load) — `MacroPool` shape.
    pub hidden_load_rows: Vec<Vec<usize>>,
    /// Operating-point class per schedule position (see [`plan_traffic`]).
    pub schedule_points: Vec<usize>,
    /// Measured per-position access histogram (`None` = uniform),
    /// borrowed from the caller — specs are planning inputs, so they
    /// never need to own a copy.
    pub traffic: Option<&'t [u64]>,
    /// Relative batch-traffic share of this tenant (surplus allotment);
    /// non-positive shares are treated as equal weight.
    pub share: f64,
}

impl TenantSpec<'_> {
    fn hidden(&self) -> usize {
        self.hidden_load_rows.iter().map(Vec::len).sum()
    }

    /// Smallest budget this tenant can run resident on (cold-spill floor).
    fn min_budget(&self) -> usize {
        let hidden = self.hidden();
        let min_output = self.schedule_points.len().min(1);
        if hidden >= 2 {
            2.min(hidden + min_output)
        } else {
            hidden + min_output
        }
    }

    /// Budget past which extra macros can only idle: full point pinning
    /// plus worker-capped replicas of every load.
    fn max_useful_budget(&self, workers: usize) -> usize {
        let mut points: Vec<usize> = self.schedule_points.clone();
        points.sort_unstable();
        points.dedup();
        self.hidden() * workers.max(1) + points.len()
    }
}

/// A macro budget partitioned across tenants: `plans[t]` is tenant `t`'s
/// single-model placement over its sub-budget (Σ sub-budgets ≤ `budget`).
#[derive(Clone, Debug)]
pub struct TenantPlan {
    pub budget: usize,
    pub plans: Vec<PlacementPlan>,
}

impl TenantPlan {
    /// Macros instantiated across every tenant.
    pub fn macros_used(&self) -> usize {
        self.plans.iter().map(PlacementPlan::macros_used).sum()
    }

    /// One-line description per tenant.
    pub fn describe(&self) -> String {
        self.plans
            .iter()
            .enumerate()
            .map(|(t, p)| format!("tenant {t}: {}", p.describe()))
            .collect::<Vec<_>>()
            .join("; ")
    }
}

/// Partition `budget` macros across `specs` tenants and plan each one.
///
/// Allocation: every tenant first receives its feasibility floor
/// ([`TenantSpec::min_budget`] — full residency preferred, cold-spill
/// accepted); `None` if even the floors don't fit.  The surplus is then
/// handed out one macro at a time, proportional-fair by traffic share
/// (each macro goes to the tenant maximising `share / (extra + 1)`, ties
/// to the lowest tenant index), capped at each tenant's
/// [`TenantSpec::max_useful_budget`].
pub fn plan_tenants(specs: &[TenantSpec<'_>], budget: usize, workers: usize) -> Option<TenantPlan> {
    let mins: Vec<usize> = specs.iter().map(TenantSpec::min_budget).collect();
    let maxs: Vec<usize> = specs
        .iter()
        .map(|s| s.max_useful_budget(workers))
        .collect();
    let floor: usize = mins.iter().sum();
    if floor > budget {
        return None;
    }
    let any_positive = specs.iter().any(|s| s.share > 0.0);
    let share = |i: usize| -> f64 {
        if any_positive {
            specs[i].share.max(0.0)
        } else {
            1.0
        }
    };
    let mut alloc = mins.clone();
    let mut surplus = budget - floor;
    while surplus > 0 {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..specs.len() {
            if alloc[i] >= maxs[i] {
                continue;
            }
            let score = share(i) / (alloc[i] - mins[i] + 1) as f64;
            if best.map_or(true, |(_, b)| score > b) {
                best = Some((i, score));
            }
        }
        match best {
            Some((i, _)) => {
                alloc[i] += 1;
                surplus -= 1;
            }
            None => break, // every tenant saturated; leave the rest unspent
        }
    }
    let plans: Option<Vec<PlacementPlan>> = specs
        .iter()
        .zip(&alloc)
        .map(|(s, &b)| {
            plan_traffic(
                &s.hidden_load_rows,
                &s.schedule_points,
                s.traffic,
                b,
                workers,
            )
        })
        .collect();
    plans.map(|plans| TenantPlan { budget, plans })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infeasible_budgets_return_none() {
        // 3 hidden loads + ≥1 output slot → 4 macros for full residency;
        // cold-spill takes the floor down to 2 (1 resident + the funnel)
        let rows = vec![vec![64, 64], vec![16]];
        for budget in 0..2 {
            assert!(plan(&rows, 33, budget, 1).is_none(), "budget {budget}");
        }
        for budget in 2..4 {
            let p = plan(&rows, 33, budget, 1).unwrap();
            assert!(p.spill_active(), "budget {budget}");
        }
        assert!(!plan(&rows, 33, 4, 1).unwrap().spill_active());
        // a single hidden load has nothing to spill: below full residency
        // the model must reload
        assert!(plan(&[vec![64]], 33, 1, 1).is_none());
    }

    #[test]
    fn full_budget_pins_everything_and_replicates_surplus() {
        let rows = vec![vec![64, 64], vec![16]];
        let p = plan(&rows, 33, 3 + 33, 4).unwrap();
        assert_eq!(p.pinned, 33);
        assert_eq!(p.pinned_positions(), 33);
        assert_eq!(p.shared_slots, 0);
        assert!(!p.sharing_active());
        assert!(!p.replication_active());
        assert_eq!(p.predicted_retunes_per_batch(), 0);
        assert_eq!(p.macros_used(), 36);

        // 5 surplus macros: hottest loads (64 rows) replicate first
        let p = plan(&rows, 33, 3 + 33 + 5, 4).unwrap();
        assert!(p.replication_active());
        assert_eq!(p.macros_used(), 41);
        assert_eq!(p.predicted_retunes_per_batch(), 0);
        // round-robin over [64, 64, 16] hottest-first: 2+2+1
        assert_eq!(p.hidden_replicas, vec![vec![3, 3], vec![2]]);
    }

    #[test]
    fn replication_never_exceeds_the_worker_count() {
        let rows = vec![vec![64], vec![16]];
        // huge surplus, 3 workers: every load caps at 3 replicas and the
        // rest of the budget stays unspent
        let p = plan(&rows, 4, 100, 3).unwrap();
        assert_eq!(p.hidden_replicas, vec![vec![3], vec![3]]);
        assert_eq!(p.macros_used(), 6 + 4);
        // one worker: replicas can only idle, so none are built
        let p = plan(&rows, 4, 100, 1).unwrap();
        assert!(!p.replication_active());
        assert_eq!(p.macros_used(), 2 + 4);
    }

    #[test]
    fn degraded_budget_shares_thresholds_through_one_slot() {
        // the acceptance shape: 6 hidden loads + 33 thresholds = 39 full,
        // planned into 16
        let rows = vec![vec![64; 6]];
        let p = plan(&rows, 33, 16, 1).unwrap();
        assert_eq!(p.hidden_macros(), 6);
        assert_eq!(p.pinned, 9);
        assert_eq!(p.shared_slots, 1);
        assert_eq!(p.macros_used(), 16);
        assert!(p.sharing_active());
        assert!(!p.spill_active());
        // 24 unpinned thresholds funnel through the shared slot; with the
        // uniform compat histogram the pins are the schedule prefix
        assert_eq!(p.predicted_retunes_per_batch(), 24);
        for k in 0..9 {
            assert_eq!(p.pin_slot[k], Some(k));
        }
        assert!(p.pin_slot[9..].iter().all(Option::is_none));
    }

    #[test]
    fn minimum_viable_budget_runs_everything_shared() {
        let rows = vec![vec![64]];
        let p = plan(&rows, 33, 2, 1).unwrap();
        assert_eq!(p.pinned, 0);
        assert_eq!(p.shared_slots, 1);
        assert_eq!(p.predicted_retunes_per_batch(), 33);
        assert_eq!(p.macros_used(), 2);
    }

    #[test]
    fn pinning_dominates_extra_shared_slots_for_cyclic_sweeps() {
        // the cost-model claim: at equal budget, d pins + 1 funnel beats
        // any balanced shared split (whose LRU thrashes the full cycle)
        let rows = vec![vec![64]];
        for budget in 3..34 {
            let p = plan(&rows, 33, budget, 1).unwrap();
            let balanced_cost = 33u64; // s ≥ 2 shared slots, r > s → all miss
            assert!(
                p.predicted_retunes_per_batch() < balanced_cost,
                "budget {budget}: {}",
                p.predicted_retunes_per_batch()
            );
        }
    }

    #[test]
    fn empty_schedule_needs_no_output_macros() {
        let rows = vec![vec![64, 32]];
        let p = plan(&rows, 0, 2, 1).unwrap();
        assert_eq!(p.output_macros(), 0);
        assert_eq!(p.predicted_retunes_per_batch(), 0);
        assert!(plan(&rows, 0, 1, 1).is_none());
    }

    #[test]
    fn cold_spill_keeps_the_hottest_loads_resident() {
        // 4 loads of distinct heat + 4 thresholds, budget 3: the two
        // hottest loads keep macros, the two coldest spill to the funnel
        let rows = vec![vec![64, 16], vec![48, 8]];
        let p = plan(&rows, 4, 3, 1).unwrap();
        assert!(p.spill_active());
        assert_eq!(p.hidden_replicas, vec![vec![1, 0], vec![1, 0]]);
        assert_eq!(p.spilled_loads(), 2);
        assert_eq!(p.pinned, 0);
        assert_eq!(p.shared_slots, 1);
        assert_eq!(p.macros_used(), 3);
        // funnel cycle: spill(l0), spill(l1), 4 distinct output points →
        // 6 transitions/batch
        assert_eq!(p.predicted_retunes_per_batch(), 6);
        // spill with an empty schedule still keeps the funnel slot
        let p = plan(&rows, 0, 3, 1).unwrap();
        assert!(p.spill_active());
        assert_eq!(p.shared_slots, 1);
        assert_eq!(p.macros_used(), 3);
    }

    #[test]
    fn skewed_schedule_pins_by_point_weight_not_prefix() {
        // threshold value 0 occupies 8 of 12 positions; grouping by
        // operating point + weight-first pinning serves all 8 from one
        // pinned macro, so the funnel sees only the cold tail
        let points = vec![0, 0, 0, 0, 0, 0, 0, 0, 1, 2, 3, 4];
        let rows = vec![vec![64]];
        // budget 4 → output budget 3 → pin 2 points + 1 funnel
        let p = plan_traffic(&rows, &points, None, 4, 1).unwrap();
        assert_eq!(p.pinned, 2);
        // the heavy point (weight 8) and the earliest unit point pin
        assert_eq!(p.pin_slot[0], Some(0), "heavy point pinned");
        assert_eq!(p.pin_slot[7], Some(0), "all its positions share the slot");
        assert_eq!(p.pin_slot[8], Some(1), "tie-break: earliest unit point");
        assert!(p.pin_slot[9..].iter().all(Option::is_none));
        assert_eq!(p.pinned_positions(), 9);
        // funnel: points {2, 3, 4} → 3 transitions, strictly below the
        // distinct-point prefix rule's K − d = 12 − 2 = 10
        assert_eq!(p.predicted_retunes_per_batch(), 3);
        let prefix = plan(&rows, points.len(), 4, 1).unwrap();
        assert!(p.predicted_retunes_per_batch() < prefix.predicted_retunes_per_batch());
        // measured traffic can override the schedule frequencies: make
        // position 11 the hot one
        let mut traffic = vec![1u64; 12];
        traffic[11] = 100;
        let p = plan_traffic(&rows, &points, Some(&traffic), 3, 1).unwrap();
        assert_eq!(p.pinned, 1);
        assert_eq!(p.pin_slot[11], Some(0), "measured-hot point pinned first");
    }

    #[test]
    fn empty_histogram_means_uniform_traffic() {
        // feeding back take_output_traffic() from a reload-mode pool
        // yields an empty histogram — that must plan exactly like the
        // uniform default, never panic on a length mismatch
        let points = vec![0, 1, 2, 3];
        let uniform = plan_traffic(&[vec![64]], &points, None, 3, 1).unwrap();
        let empty = plan_traffic(&[vec![64]], &points, Some(&[]), 3, 1).unwrap();
        assert_eq!(uniform, empty);
    }

    #[test]
    fn repeated_points_pin_into_one_macro() {
        // full pinning of 3 distinct points over 6 positions costs 3
        // macros, not 6
        let points = vec![0, 1, 0, 2, 1, 0];
        let p = plan_traffic(&[vec![64]], &points, None, 1 + 3, 1).unwrap();
        assert_eq!(p.pinned, 3);
        assert_eq!(p.shared_slots, 0);
        assert_eq!(p.pinned_positions(), 6);
        assert_eq!(p.predicted_retunes_per_batch(), 0);
        assert_eq!(p.macros_used(), 4);
    }

    #[test]
    fn describe_mentions_the_split() {
        let p = plan(&[vec![64; 6]], 33, 16, 1).unwrap();
        let d = p.describe();
        assert!(d.contains("16 macros"), "{d}");
        assert!(d.contains("9/33"), "{d}");
    }

    fn spec(rows: Vec<Vec<usize>>, sched: usize, share: f64) -> TenantSpec<'static> {
        TenantSpec {
            hidden_load_rows: rows,
            schedule_points: (0..sched).collect(),
            traffic: None,
            share,
        }
    }

    #[test]
    fn tenant_floors_come_before_shares() {
        // two tenants, budget exactly the sum of full-residency needs:
        // both fully pinned regardless of the share skew
        let specs = vec![
            spec(vec![vec![64]], 4, 100.0),
            spec(vec![vec![64, 64]], 4, 1.0),
        ];
        let tp = plan_tenants(&specs, (1 + 4) + (2 + 4), 1).unwrap();
        assert!(!tp.plans[0].sharing_active());
        assert!(!tp.plans[1].sharing_active());
        assert!(tp.macros_used() <= tp.budget);
        // below the spill floors there is no tenancy plan
        assert!(plan_tenants(&specs, 2, 1).is_none());
    }

    #[test]
    fn surplus_follows_traffic_share() {
        // equal shapes, 3:1 shares: the hot tenant pins ~3× the surplus
        let specs = vec![
            spec(vec![vec![64]], 20, 3.0),
            spec(vec![vec![64]], 20, 1.0),
        ];
        let floor = 2 + 2;
        let tp = plan_tenants(&specs, floor + 8, 1).unwrap();
        let extra: Vec<usize> = tp.plans.iter().map(|p| p.budget - 2).collect();
        assert_eq!(extra[0] + extra[1], 8);
        assert!(extra[0] >= 3 * extra[1], "{extra:?}");
        assert!(tp.macros_used() <= tp.budget);
    }

    #[test]
    fn tenant_surplus_never_exceeds_useful_budget() {
        // a huge budget saturates both tenants at full pinning (+ capped
        // replicas) and leaves the rest unspent
        let specs = vec![spec(vec![vec![64]], 4, 1.0), spec(vec![vec![32]], 2, 1.0)];
        let tp = plan_tenants(&specs, 500, 2).unwrap();
        for (t, p) in tp.plans.iter().enumerate() {
            assert!(!p.sharing_active(), "tenant {t}");
            assert!(
                p.hidden_replicas.iter().flatten().all(|&r| r <= 2),
                "tenant {t}"
            );
        }
        assert!(tp.macros_used() < 500);
    }

    #[test]
    fn tenant_spill_floor_keeps_many_models_viable() {
        // three multi-load tenants on a budget far below full residency:
        // every tenant still plans (cold-spill), none reloads
        let specs = vec![
            spec(vec![vec![64; 6]], 33, 1.0),
            spec(vec![vec![64; 4]], 33, 1.0),
            spec(vec![vec![64; 2]], 33, 1.0),
        ];
        let tp = plan_tenants(&specs, 9, 1).unwrap();
        assert_eq!(tp.plans.len(), 3);
        for p in &tp.plans {
            assert!(p.macros_used() >= 2);
        }
        assert!(tp.macros_used() <= 9);
    }
}
