//! Capacity-aware macro placement: how a fixed budget of simulated
//! 128-kbit macros is spent on one model — or partitioned across a
//! multi-tenant pool of models.
//!
//! PR 1's pool was all-or-nothing — either every hidden load *and* every
//! output threshold got its own macro, or the model dropped to the
//! single-macro reload scheduler.  The planner replaces that cliff with a
//! cost-model-driven [`PlacementPlan`]:
//!
//! 1. **Hidden loads come first.**  Sharing a hidden macro would mean
//!    reprogramming rows mid-batch (the 138-cycle-per-load reload tax the
//!    pool exists to kill), so a plan keeps every hidden load it can
//!    afford resident.  Budgets below hidden-loads + 1 no longer drop the
//!    whole model to the reload scheduler: the **coldest** hidden loads
//!    (smallest programmed row count — cheapest to reprogram) *spill* to
//!    the shared funnel slot and are reloaded there per batch
//!    (`hidden_replicas[li][di] == 0`), while the hottest `budget − 1`
//!    loads stay resident.  Only budgets that cannot hold one resident
//!    load plus the funnel (or a single-load model below full residency)
//!    fall back to reload.
//! 2. **Output thresholds share.**  All output slots hold the *same*
//!    programmed rows and differ only in their parked (V_ref, V_eval,
//!    V_st) triple, so a threshold that loses its dedicated macro costs a
//!    *retune*, never a reprogram.  Schedule positions whose calibrated
//!    triples coincide (equal threshold values — calibration is a pure
//!    function of the target) are grouped into one **operating point**
//!    ([`PlacementPlan::point_of`]); pinning a point parks *one* macro
//!    that serves every position of that point.  Points are pinned
//!    hottest-first by the per-position traffic histogram (schedule
//!    frequency by default, measured access counts when fed back from the
//!    pool — see `MacroPool::take_output_traffic`), and the remaining
//!    points funnel through a single LRU-parked shared slot.  For an
//!    all-distinct uniform schedule this reduces to the PR 2 rule — pin a
//!    prefix of `d` thresholds, pay exactly `K − d` retunes/batch on the
//!    cyclic sweep — while skewed schedules (repeated values, measured
//!    hot spots) pay strictly less: the predicted cost is the number of
//!    operating-point *transitions* the funnel sees per batch
//!    ([`PlacementPlan::predicted_retunes_per_batch`]).
//! 3. **Surplus replicates hidden loads.**  Budget beyond full pinning
//!    buys hidden-load replicas so `classify_parallel` workers search a
//!    free replica instead of serialising on one `Mutex<CamArray>`.
//!    Every image touches every load once per batch, so "hot" means
//!    longest lock hold — loads are replicated in descending row count,
//!    and never past the worker count the pool serves (a replica no
//!    searcher can reach is pure simulated area).
//!
//! **Multi-tenant pools** ([`plan_tenants`]) partition one budget across
//! N models: every tenant first receives its feasibility floor (full
//! hidden residency + one output slot, degrading through cold-spill down
//! to two macros), then the surplus is distributed proportional-fair by
//! each tenant's measured traffic share, capped at the budget past which
//! extra macros would idle (full point pinning + worker-capped
//! replicas).  Tenants never share macros — different models' rows
//! differ — so isolation is structural: a tenant's plan is exactly a
//! single-model [`PlacementPlan`] over its sub-budget, and its results
//! are bit-identical to that model running alone on its own pool.
//!
//! Cost model summary (steady state, per batch): resident plans pay
//! [`PlacementPlan::predicted_retunes_per_batch`] retune stalls and zero
//! programming; spill plans additionally reprogram each spilled load (and
//! re-land the output rows in the funnel once); the reload `Pipeline`
//! pays `K` output retunes plus a full reprogram of every hidden load.
//!
//! **Health-aware planning** ([`HealthScores`]): the pool's fleet
//! supervisor (`cam::faults::HealthRegistry`) feeds the planner a
//! per-load health summary.  Quarantined macros are *held out of the
//! budget* — a re-plan never places pins or replicas on written-off
//! capacity — and penalized loads (Suspect) receive surplus replicas
//! only after every healthy load is saturated, while loads with a copy
//! on probation receive none at all: their capacity comes back through
//! canary-gated re-admission, not by re-buying macros.  `None` (or a
//! nominal score) plans exactly as before, bit for bit.

use crate::cam::HealthState;

/// Per-macro health summary the planner scores against, produced by
/// `MacroPool::health_scores` from its `HealthRegistry`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HealthScores {
    /// Worst live-copy health per hidden (layer, load), shaped exactly
    /// like `hidden_load_rows`.  Empty = every load nominal.
    pub hidden: Vec<Vec<HealthState>>,
    /// Physical macros currently written off (quarantined copies
    /// awaiting canary-gated re-admission): held out of the usable
    /// budget so a plan never re-buys them.
    pub quarantined_macros: usize,
}

impl HealthScores {
    /// Health of hidden load (`li`, `di`); out-of-shape = `Healthy`.
    fn state(&self, li: usize, di: usize) -> HealthState {
        self.hidden
            .get(li)
            .and_then(|layer| layer.get(di))
            .copied()
            .unwrap_or_default()
    }

    /// Whether the score changes nothing (every load healthy, nothing
    /// quarantined) — callers may skip a re-plan on nominal health.
    pub fn is_nominal(&self) -> bool {
        self.quarantined_macros == 0 && self.hidden.iter().flatten().all(|h| !h.penalized())
    }
}

/// How a macro budget is spent on one model: replicas per hidden load,
/// pinned output operating points, and LRU-shared output slots.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlacementPlan {
    /// The budget the plan was built against (`macros_used() <= budget`).
    pub budget: usize,
    /// Macro replicas per hidden (layer, load); parallel to the layer
    /// load plans.  `0` marks a cold-spilled load: it owns no macro and
    /// is reprogrammed into the shared funnel slot per batch.
    pub hidden_replicas: Vec<Vec<usize>>,
    /// Pinned slot per schedule position: `Some(s)` routes to pinned
    /// macro `s` (positions sharing an operating point share a slot),
    /// `None` routes through the shared LRU funnel.
    pub pin_slot: Vec<Option<usize>>,
    /// Operating-point class per schedule position: positions with equal
    /// class park identical calibrated triples (retunes between them are
    /// free).  The compat [`plan`] entry point treats every position as
    /// its own point.
    pub point_of: Vec<usize>,
    /// Number of pinned output slot macros.
    pub pinned: usize,
    /// Shared output slots serving the unpinned points (and any spilled
    /// hidden loads), parked at one triple each and evicted LRU.
    pub shared_slots: usize,
    /// Total output-schedule positions.
    pub schedule_len: usize,
    /// Cost-model retunes/batch (funnel operating-point transitions).
    predicted_retunes: u64,
}

/// Build a plan for a model with the given hidden-load row counts
/// (`hidden_load_rows[layer][load]` = programmed rows of that load) and
/// output schedule length, under `budget` macros, serving `workers`
/// concurrent searchers.  Every schedule position is treated as its own
/// operating point with uniform traffic (the PR 2 behaviour: prefix
/// pinning, `K − d` retunes/batch); see [`plan_traffic`] for
/// point-grouped, histogram-driven pinning.  Returns `None` when the
/// budget cannot run the model resident even with cold-spill — the
/// caller should then run the reload scheduler.
pub fn plan(
    hidden_load_rows: &[Vec<usize>],
    schedule_len: usize,
    budget: usize,
    workers: usize,
) -> Option<PlacementPlan> {
    let points: Vec<usize> = (0..schedule_len).collect();
    plan_traffic(hidden_load_rows, &points, None, None, budget, workers)
}

/// The traffic-aware planner core.  `schedule_points[k]` is the
/// operating-point class of schedule position `k` (positions with equal
/// class share one calibrated triple); `traffic[k]` is the measured (or
/// assumed) access count of position `k` per batch — `None` means
/// uniform.  `health` is the pool's per-macro health summary (`None` =
/// nominal): quarantined macros shrink the usable budget and penalized
/// loads are last in line for surplus replicas (module docs).  Pinning
/// is hottest-point-first; ties break toward the earliest schedule
/// position so plans are deterministic.
pub fn plan_traffic(
    hidden_load_rows: &[Vec<usize>],
    schedule_points: &[usize],
    traffic: Option<&[u64]>,
    health: Option<&HealthScores>,
    budget: usize,
    workers: usize,
) -> Option<PlacementPlan> {
    let schedule_len = schedule_points.len();
    // an empty histogram means "nothing measured yet" (e.g. fed back
    // from a pool that ran in reload mode) — treat it as uniform rather
    // than panicking on the length mismatch
    let traffic = traffic.filter(|t| !t.is_empty());
    if let Some(t) = traffic {
        assert_eq!(t.len(), schedule_len, "one traffic count per position");
    }
    if let Some(h) = health {
        if !h.hidden.is_empty() {
            let shape: Vec<usize> = hidden_load_rows.iter().map(Vec::len).collect();
            let hshape: Vec<usize> = h.hidden.iter().map(Vec::len).collect();
            assert_eq!(shape, hshape, "one health state per hidden load");
        }
    }
    // quarantined macros are unusable capacity: held out of the budget,
    // so the plan below never places pins or replicas on them and a
    // drained budget degrades through cold-spill / `None` exactly like
    // a genuinely smaller pool
    let budget = budget.saturating_sub(health.map_or(0, |h| h.quarantined_macros));
    let hidden: usize = hidden_load_rows.iter().map(Vec::len).sum();
    let min_output = schedule_len.min(1);
    let spill = budget < hidden + min_output;
    if spill && (hidden < 2 || budget < 2) {
        return None;
    }

    let (mut hidden_replicas, resident_hidden) = if spill {
        // cold-spill: keep the hottest budget−1 loads resident (largest
        // row count = most expensive to reprogram), run the rest through
        // the shared funnel slot per batch.  Penalized loads sort after
        // healthy ones, so a Suspect load spills preferentially — its
        // traffic moves off the suspect macro and into the funnel.
        let mut order: Vec<(usize, usize)> = load_order_health(hidden_load_rows, health);
        order.truncate(budget - 1);
        let mut replicas: Vec<Vec<usize>> = hidden_load_rows
            .iter()
            .map(|layer| vec![0; layer.len()])
            .collect();
        for &(li, di) in &order {
            replicas[li][di] = 1;
        }
        (replicas, budget - 1)
    } else {
        let replicas: Vec<Vec<usize>> = hidden_load_rows
            .iter()
            .map(|layer| vec![1; layer.len()])
            .collect();
        (replicas, hidden)
    };

    // --- output placement: pin whole operating points hottest-first ---
    // distinct points in first-appearance order, with accumulated weight
    let mut point_ids: Vec<usize> = Vec::new();
    let mut weights: Vec<u64> = Vec::new();
    for (k, &p) in schedule_points.iter().enumerate() {
        let w = traffic.map_or(1, |t| t[k]);
        match point_ids.iter().position(|&q| q == p) {
            Some(i) => weights[i] += w,
            None => {
                point_ids.push(p);
                weights.push(w);
            }
        }
    }
    let n_points = point_ids.len();
    let output_budget = if spill { 1 } else { budget - hidden };
    let (pinned_points, shared_slots): (Vec<usize>, usize) = if schedule_len == 0 {
        // no output sweep; spill plans still keep the funnel for loads
        (Vec::new(), usize::from(spill))
    } else if !spill && output_budget >= n_points {
        // full pinning: every point parked forever, zero retunes
        ((0..n_points).collect(), 0)
    } else {
        // maximise pins under the histogram, funnel the rest through one
        // LRU slot (see the module docs for why one funnel beats a
        // balanced split); spill plans keep the whole sweep in the funnel
        let d = output_budget.saturating_sub(1).min(n_points);
        let mut by_heat: Vec<usize> = (0..n_points).collect();
        by_heat.sort_by_key(|&i| std::cmp::Reverse(weights[i])); // stable: ties → earliest
        (by_heat[..d].to_vec(), 1)
    };

    // per-position routing: positions of a pinned point share its slot,
    // slots numbered by the point's first appearance for determinism
    let mut slot_of_point: Vec<Option<usize>> = vec![None; n_points];
    let mut ordered: Vec<usize> = pinned_points;
    ordered.sort_unstable();
    for (slot, &pi) in ordered.iter().enumerate() {
        slot_of_point[pi] = Some(slot);
    }
    let pinned = ordered.len();
    let point_of: Vec<usize> = schedule_points
        .iter()
        .map(|&p| {
            point_ids
                .iter()
                .position(|&q| q == p)
                .expect("schedule points come from point_ids")
        })
        .collect();
    let pin_slot: Vec<Option<usize>> = point_of.iter().map(|&pi| slot_of_point[pi]).collect();

    // --- surplus buys hidden-load replicas (never on spill plans) ---
    let cap = workers.max(1);
    let mut surplus = budget - resident_hidden - pinned - shared_slots;
    if !spill && surplus > 0 && hidden > 0 && cap > 1 {
        // replicate hottest-first: largest loads hold their lock longest.
        // Health partitions the round-robin: healthy/readmitted loads
        // saturate first, Suspect loads absorb only what is left, and
        // loads with a copy quarantined or on probation receive no
        // surplus at all — their capacity comes back through canary-
        // gated re-admission, not by re-buying macros.
        let mut good: Vec<(usize, usize)> = Vec::new();
        let mut shaky: Vec<(usize, usize)> = Vec::new();
        for (li, di) in load_order(hidden_load_rows) {
            match health.map_or(HealthState::Healthy, |h| h.state(li, di)) {
                HealthState::Healthy | HealthState::Readmitted => good.push((li, di)),
                HealthState::Suspect => shaky.push((li, di)),
                HealthState::Quarantined | HealthState::Probation => {}
            }
        }
        for group in [good, shaky] {
            let mut cursor = 0usize;
            let mut at_cap = 0usize;
            while surplus > 0 && at_cap < group.len() {
                let (li, di) = group[cursor % group.len()];
                cursor += 1;
                if hidden_replicas[li][di] < cap {
                    hidden_replicas[li][di] += 1;
                    surplus -= 1;
                    at_cap = 0;
                } else {
                    at_cap += 1;
                }
            }
        }
    }

    let predicted_retunes =
        funnel_retunes(&hidden_replicas, &pin_slot, &point_of, shared_slots, traffic);

    Some(PlacementPlan {
        budget,
        hidden_replicas,
        pin_slot,
        point_of,
        pinned,
        shared_slots,
        schedule_len,
        predicted_retunes,
    })
}

/// Hidden loads ordered hottest-first (descending row count; stable, so
/// ties keep (layer, load) order) — shared by replication and spill.
fn load_order(hidden_load_rows: &[Vec<usize>]) -> Vec<(usize, usize)> {
    let mut order: Vec<(usize, usize)> = hidden_load_rows
        .iter()
        .enumerate()
        .flat_map(|(li, layer)| (0..layer.len()).map(move |di| (li, di)))
        .collect();
    order.sort_by_key(|&(li, di)| std::cmp::Reverse(hidden_load_rows[li][di]));
    order
}

/// [`load_order`] with penalized loads sunk to the back (stable, so the
/// descending-row order survives within each health group).  With no
/// health score this is exactly `load_order`.
fn load_order_health(
    hidden_load_rows: &[Vec<usize>],
    health: Option<&HealthScores>,
) -> Vec<(usize, usize)> {
    let mut order = load_order(hidden_load_rows);
    if let Some(h) = health {
        order.sort_by_key(|&(li, di)| h.state(li, di).penalized());
    }
    order
}

/// Cost model: funnel operating-point transitions per batch.  The
/// funnel's per-batch access sequence is every spilled load (in
/// execution order; loads of one layer share the layer midpoint) then
/// every unpinned schedule position in sweep order.  A retune is paid
/// exactly when the parked triple changes, cyclically across batches.
///
/// The histogram *weights* the model: `traffic[k]` is position `k`'s
/// measured access count, so a position that position-restricted sweeps
/// (`MacroPool::classify_batch_positions`) never touch contributes
/// nothing, and one accessed in a fraction of batches contributes that
/// fraction of a transition (normalised by the hottest position, rounded
/// half-up).  Uniform traffic (`None`, or all counts equal) reproduces
/// the unweighted transition count exactly.  Shared between
/// [`plan_traffic`], [`PlacementPlan::repriced`] and
/// [`MigrationStep::apply_to`] so a migrated plan prices its funnel
/// exactly like a freshly planned one.
fn funnel_retunes(
    hidden_replicas: &[Vec<usize>],
    pin_slot: &[Option<usize>],
    point_of: &[usize],
    shared_slots: usize,
    traffic: Option<&[u64]>,
) -> u64 {
    let w_of = |k: usize| traffic.map_or(1, |t| t[k]);
    let w_max = (0..pin_slot.len()).map(&w_of).max().unwrap_or(1).max(1);
    let mut funnel: Vec<((u8, usize), u64)> = Vec::new();
    for (li, layer) in hidden_replicas.iter().enumerate() {
        for &r in layer.iter() {
            if r == 0 {
                // spilled loads reload every batch: full weight
                funnel.push(((1, li), w_max));
            }
        }
    }
    for (k, slot) in pin_slot.iter().enumerate() {
        if slot.is_none() && w_of(k) > 0 {
            funnel.push(((0, point_of[k]), w_of(k)));
        }
    }
    let distinct_funnel = {
        let mut seen: Vec<(u8, usize)> = Vec::new();
        for &(e, _) in &funnel {
            if !seen.contains(&e) {
                seen.push(e);
            }
        }
        seen.len()
    };
    if distinct_funnel <= shared_slots {
        return 0; // every funnel point parks permanently
    }
    if funnel.len() <= 1 {
        return 0;
    }
    // weighted cyclic transitions: a switch *to* an entry costs that
    // entry's access frequency (w / w_max) of a retune per batch
    let mut acc = 0u64;
    let mut prev = funnel.last().expect("len > 1 checked above").0;
    for &(e, w) in &funnel {
        if e != prev {
            acc += w;
        }
        prev = e;
    }
    (acc + w_max / 2) / w_max
}

impl PlacementPlan {
    /// Macros spent on hidden loads (replicas included; spilled loads
    /// contribute nothing).
    pub fn hidden_macros(&self) -> usize {
        self.hidden_replicas.iter().flatten().sum()
    }

    /// Macros spent on the output sweep / funnel (pinned + shared).
    pub fn output_macros(&self) -> usize {
        self.pinned + self.shared_slots
    }

    /// Total macros the plan instantiates (never exceeds the budget).
    pub fn macros_used(&self) -> usize {
        self.hidden_macros() + self.output_macros()
    }

    /// Schedule positions served by a permanently pinned macro.
    pub fn pinned_positions(&self) -> usize {
        self.pin_slot.iter().filter(|s| s.is_some()).count()
    }

    /// Whether any schedule position lost its dedicated operating point.
    pub fn sharing_active(&self) -> bool {
        self.pinned_positions() < self.schedule_len
    }

    /// Whether surplus budget bought hidden-load replicas.
    pub fn replication_active(&self) -> bool {
        self.hidden_replicas.iter().flatten().any(|&r| r > 1)
    }

    /// Whether any hidden load is cold-spilled to the funnel slot.
    pub fn spill_active(&self) -> bool {
        self.hidden_replicas.iter().flatten().any(|&r| r == 0)
    }

    /// Cold-spilled hidden loads (reprogrammed into the funnel per batch).
    pub fn spilled_loads(&self) -> usize {
        self.hidden_replicas
            .iter()
            .flatten()
            .filter(|&&r| r == 0)
            .count()
    }

    /// Steady-state retune upper bound per batch: the number of
    /// operating-point transitions the shared funnel sees on one cyclic
    /// Algorithm-1 sweep (spilled loads included).  Pinned points and
    /// consecutive same-point accesses are free; for an all-distinct
    /// uniform schedule this is exactly the classic `K − d`.  Measured
    /// counts may come in below the bound when triples of *different*
    /// points happen to coincide at the DAC grid.
    pub fn predicted_retunes_per_batch(&self) -> u64 {
        self.predicted_retunes
    }

    /// Distinct operating-point classes (`point_of` is a dense 0..n map).
    fn n_points(&self) -> usize {
        self.point_of.iter().copied().max().map_or(0, |m| m + 1)
    }

    /// Sorted distinct operating points currently holding a pinned slot.
    fn pinned_point_ids(&self) -> Vec<usize> {
        let mut pts: Vec<usize> = self
            .point_of
            .iter()
            .zip(&self.pin_slot)
            .filter_map(|(&p, s)| s.map(|_| p))
            .collect();
        pts.sort_unstable();
        pts.dedup();
        pts
    }

    /// Rebuild `pin_slot`/`pinned` from a sorted, deduped pinned-point
    /// set, using the same canonical slot numbering as [`plan_traffic`]
    /// (slots ascend with the point id) so a migrated plan is
    /// indistinguishable from a freshly planned one.
    fn set_pinned_points(&mut self, pts: &[usize]) {
        let mut slot_of_point: Vec<Option<usize>> = vec![None; self.n_points()];
        for (slot, &p) in pts.iter().enumerate() {
            slot_of_point[p] = Some(slot);
        }
        self.pin_slot = self.point_of.iter().map(|&p| slot_of_point[p]).collect();
        self.pinned = pts.len();
    }

    /// Recurring programming rows per batch a spill plan pays: every
    /// cold-spilled load reprograms into the funnel each batch, and the
    /// funnel re-lands the output rows once afterwards.  Zero for
    /// resident plans.  Row counts come from the pool
    /// (`MacroPool::hidden_load_rows` / `output_rows`) — the plan itself
    /// only stores replica counts.
    pub fn recurring_spill_rows_per_batch(
        &self,
        hidden_load_rows: &[Vec<usize>],
        output_rows: usize,
    ) -> u64 {
        let mut rows = 0u64;
        for (li, layer) in self.hidden_replicas.iter().enumerate() {
            for (di, &r) in layer.iter().enumerate() {
                if r == 0 {
                    rows += hidden_load_rows[li][di] as u64;
                }
            }
        }
        if rows > 0 {
            rows += output_rows as u64;
        }
        rows
    }

    /// Clone with the cost model re-priced under a fresh traffic
    /// histogram (`None` or empty = uniform): the re-planning
    /// controller prices the *current* plan and a candidate under the
    /// same measured histogram before deciding whether a migration's
    /// saving is real, instead of trusting the stale cost the current
    /// plan was built with.
    pub fn repriced(&self, traffic: Option<&[u64]>) -> PlacementPlan {
        let traffic = traffic.filter(|t| !t.is_empty());
        if let Some(t) = traffic {
            assert_eq!(t.len(), self.schedule_len, "one traffic count per position");
        }
        let mut plan = self.clone();
        plan.predicted_retunes = funnel_retunes(
            &plan.hidden_replicas,
            &plan.pin_slot,
            &plan.point_of,
            plan.shared_slots,
            traffic,
        );
        plan
    }

    /// The minimal typed step sequence migrating `self` into `new`
    /// (both plans must describe the same model and operating-point
    /// map).  Steps are ordered so that **every prefix is a valid,
    /// canonical plan**: replica drops and the funnel's appearance come
    /// first (freeing budget and giving demoted loads somewhere to
    /// land), re-pins and releases next, and capacity growth
    /// (promotions, replicas) last, with a funnel drop only once
    /// nothing routes through it.  Transiently the pool may hold one
    /// macro above both budgets when the funnel flips absent → present
    /// before a release — the price of never stopping the world.
    pub fn diff(&self, new: &PlacementPlan) -> MigrationPlan {
        assert_eq!(
            self.point_of, new.point_of,
            "diff requires plans of one model and schedule"
        );
        assert_eq!(self.schedule_len, new.schedule_len);
        let shape: Vec<usize> = self.hidden_replicas.iter().map(Vec::len).collect();
        let new_shape: Vec<usize> = new.hidden_replicas.iter().map(Vec::len).collect();
        assert_eq!(shape, new_shape, "diff requires identical load shapes");

        let mut steps: Vec<MigrationStep> = Vec::new();
        let loads = || {
            self.hidden_replicas
                .iter()
                .enumerate()
                .flat_map(|(li, layer)| (0..layer.len()).map(move |di| (li, di)))
        };

        // 1. drop surplus replicas (demoted loads keep one for now)
        for (layer, load) in loads() {
            let (ro, rn) = (self.hidden_replicas[layer][load], new.hidden_replicas[layer][load]);
            if ro >= 1 {
                for _ in rn.max(1)..ro {
                    steps.push(MigrationStep::DropReplica { layer, load });
                }
            }
        }
        // 2. the funnel appears before anything needs to route through it
        for _ in self.shared_slots..new.shared_slots {
            steps.push(MigrationStep::Reprogram { point: None });
        }
        // 3. cold-spill demotions (the funnel now exists to serve them)
        for (layer, load) in loads() {
            if self.hidden_replicas[layer][load] >= 1 && new.hidden_replicas[layer][load] == 0 {
                steps.push(MigrationStep::SpillDemote { layer, load });
            }
        }
        // 4-6. output pinning: re-pin pairs first (slot count unchanged),
        // then free surplus pins, then program missing ones
        let po = self.pinned_point_ids();
        let pn = new.pinned_point_ids();
        let unpins: Vec<usize> = po.iter().copied().filter(|p| !pn.contains(p)).collect();
        let pins: Vec<usize> = pn.iter().copied().filter(|p| !po.contains(p)).collect();
        let paired = unpins.len().min(pins.len());
        for i in 0..paired {
            steps.push(MigrationStep::Repin {
                from: unpins[i],
                to: pins[i],
            });
        }
        for &p in &unpins[paired..] {
            steps.push(MigrationStep::Release { point: Some(p) });
        }
        for &p in &pins[paired..] {
            steps.push(MigrationStep::Reprogram { point: Some(p) });
        }
        // 7-8. capacity growth: promotions first, then extra replicas
        for (layer, load) in loads() {
            if self.hidden_replicas[layer][load] == 0 && new.hidden_replicas[layer][load] >= 1 {
                steps.push(MigrationStep::SpillPromote { layer, load });
            }
        }
        for (layer, load) in loads() {
            let (ro, rn) = (self.hidden_replicas[layer][load], new.hidden_replicas[layer][load]);
            let held = if ro == 0 { rn.min(1) } else { ro.min(rn).max(1) };
            for _ in held..rn {
                steps.push(MigrationStep::AddReplica { layer, load });
            }
        }
        // 9. the funnel drops only once the plan is fully pinned + resident
        for _ in new.shared_slots..self.shared_slots {
            steps.push(MigrationStep::Release { point: None });
        }

        MigrationPlan {
            steps,
            target_budget: new.budget,
            retunes_before: self.predicted_retunes,
            retunes_after: new.predicted_retunes,
            spill_before: self.spill_active(),
            spill_after: new.spill_active(),
        }
    }

    /// One-line human description for reports and examples.
    pub fn describe(&self) -> String {
        let h: usize = self.hidden_replicas.iter().map(Vec::len).sum();
        format!(
            "{} macros: {} hidden loads ({} replicas, {} spilled), {}/{} thresholds pinned \
             on {} slot(s), {} shared slot(s), ≤{} retunes/batch",
            self.macros_used(),
            h,
            self.hidden_macros().saturating_sub(h - self.spilled_loads()),
            self.spilled_loads(),
            self.pinned_positions(),
            self.schedule_len,
            self.pinned,
            self.shared_slots,
            self.predicted_retunes_per_batch()
        )
    }
}

/// One typed, independently-applicable unit of a live migration between
/// two [`PlacementPlan`]s of the same model.  Each step is a *pure plan
/// transform* ([`MigrationStep::apply_to`]) — the pool mirrors it
/// physically in the gap between batches, so after any step prefix the
/// pool is exactly a freshly built pool of the transformed plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MigrationStep {
    /// Move a pinned output slot from operating point `from` to `to`:
    /// one retune, zero row writes (every output slot holds the same
    /// programmed rows and differs only in its parked triple).
    Repin { from: usize, to: usize },
    /// Program one additional output-row macro: `Some(p)` pins operating
    /// point `p`, `None` adds a shared funnel slot.  Costs the output
    /// rows once.
    Reprogram { point: Option<usize> },
    /// Free one output macro: `Some(p)` unpins operating point `p` (its
    /// positions fall back to the funnel), `None` drops a funnel slot —
    /// valid only once nothing routes through it.  Free to apply.
    Release { point: Option<usize> },
    /// Program one more replica of a resident hidden load (costs its
    /// rows once; replicas share the load's seed, so results are
    /// bit-identical whichever replica serves).
    AddReplica { layer: usize, load: usize },
    /// Drop one replica of a hidden load, keeping at least one.  Free.
    DropReplica { layer: usize, load: usize },
    /// Give a cold-spilled hidden load a dedicated macro back: costs
    /// its rows once, then stops paying the per-batch funnel reload.
    SpillPromote { layer: usize, load: usize },
    /// Cold-spill a resident hidden load to the funnel — free to apply
    /// (dropping a macro writes nothing); the reload cost moves into
    /// the steady-state model.
    SpillDemote { layer: usize, load: usize },
}

impl MigrationStep {
    /// The plan this step turns `plan` into.  Panics on an invalid
    /// application (steps come from [`PlacementPlan::diff`], which
    /// orders them so every prefix is valid).  The result is canonical
    /// — same slot numbering and cost model as [`plan_traffic`] — and
    /// its budget only grows past the original on the documented
    /// transient funnel overshoot.
    pub fn apply_to(&self, plan: &PlacementPlan) -> PlacementPlan {
        let mut next = plan.clone();
        match *self {
            MigrationStep::AddReplica { layer, load } => {
                assert!(
                    next.hidden_replicas[layer][load] >= 1,
                    "AddReplica on a spilled load — promote first"
                );
                next.hidden_replicas[layer][load] += 1;
            }
            MigrationStep::DropReplica { layer, load } => {
                assert!(
                    next.hidden_replicas[layer][load] >= 2,
                    "DropReplica would evict the last replica — demote instead"
                );
                next.hidden_replicas[layer][load] -= 1;
            }
            MigrationStep::SpillPromote { layer, load } => {
                assert_eq!(next.hidden_replicas[layer][load], 0, "load already resident");
                next.hidden_replicas[layer][load] = 1;
            }
            MigrationStep::SpillDemote { layer, load } => {
                assert_eq!(
                    next.hidden_replicas[layer][load], 1,
                    "demote expects exactly one replica (drop the rest first)"
                );
                assert!(next.shared_slots >= 1, "demote needs a funnel to land in");
                next.hidden_replicas[layer][load] = 0;
            }
            MigrationStep::Reprogram { point: None } => {
                next.shared_slots += 1;
            }
            MigrationStep::Release { point: None } => {
                assert!(next.shared_slots >= 1, "no funnel slot to release");
                next.shared_slots -= 1;
                if next.shared_slots == 0 {
                    assert!(
                        !next.spill_active() && next.pinned_positions() == next.schedule_len,
                        "funnel released while positions or spilled loads still route through it"
                    );
                }
            }
            MigrationStep::Reprogram { point: Some(p) } => {
                let mut pts = next.pinned_point_ids();
                assert!(!pts.contains(&p), "point {p} already pinned");
                assert!(p < next.n_points(), "unknown operating point {p}");
                pts.push(p);
                pts.sort_unstable();
                next.set_pinned_points(&pts);
            }
            MigrationStep::Release { point: Some(p) } => {
                let mut pts = next.pinned_point_ids();
                let i = pts.iter().position(|&q| q == p).expect("point not pinned");
                assert!(next.shared_slots >= 1, "unpin needs a funnel to absorb the point");
                pts.remove(i);
                next.set_pinned_points(&pts);
            }
            MigrationStep::Repin { from, to } => {
                let mut pts = next.pinned_point_ids();
                let i = pts.iter().position(|&q| q == from).expect("`from` not pinned");
                assert!(!pts.contains(&to), "`to` already pinned");
                assert!(to < next.n_points(), "unknown operating point {to}");
                assert!(next.shared_slots >= 1, "repin needs a funnel to absorb `from`");
                pts.remove(i);
                pts.push(to);
                pts.sort_unstable();
                next.set_pinned_points(&pts);
            }
        }
        // mid-flight plans are priced under uniform traffic; the final
        // step of a MigrationPlan restores the target's traffic-priced
        // cost (see `MigrationPlan::apply_step`)
        next.predicted_retunes = funnel_retunes(
            &next.hidden_replicas,
            &next.pin_slot,
            &next.point_of,
            next.shared_slots,
            None,
        );
        next.budget = next.budget.max(next.macros_used());
        next
    }

    /// Row writes applying this step costs (the one-shot programming
    /// price; retunes are priced separately via the cost model).
    pub fn programming_rows(&self, hidden_load_rows: &[Vec<usize>], output_rows: usize) -> u64 {
        match *self {
            MigrationStep::Reprogram { .. } => output_rows as u64,
            MigrationStep::AddReplica { layer, load }
            | MigrationStep::SpillPromote { layer, load } => hidden_load_rows[layer][load] as u64,
            MigrationStep::Repin { .. }
            | MigrationStep::Release { .. }
            | MigrationStep::DropReplica { .. }
            | MigrationStep::SpillDemote { .. } => 0,
        }
    }
}

/// The typed step sequence migrating one [`PlacementPlan`] into another,
/// plus the cost-model summary the controller weighs before applying it:
/// the one-shot programming price ([`MigrationPlan::programming_cycles_to_apply`])
/// against the recurring steady-state saving
/// ([`MigrationPlan::predicted_retunes_saved_per_batch`] and the spill
/// reload-row delta), amortised over a configurable horizon
/// ([`MigrationPlan::pays_off`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MigrationPlan {
    /// Steps in application order; every prefix leaves a valid plan.
    pub steps: Vec<MigrationStep>,
    /// Budget of the target plan (the fold restores it on completion —
    /// intermediate plans may transiently exceed it by one funnel slot).
    pub target_budget: usize,
    /// Cost-model retunes/batch of the source plan.
    pub retunes_before: u64,
    /// Cost-model retunes/batch of the target plan.
    pub retunes_after: u64,
    spill_before: bool,
    spill_after: bool,
}

impl MigrationPlan {
    /// No step to apply — the plans already agree.
    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Apply step `k` to the current plan: [`MigrationStep::apply_to`],
    /// plus — on the final step — restoring the target's budget and
    /// traffic-priced cost so the fold reproduces the diff's target
    /// exactly, field for field.
    pub fn apply_step(&self, current: &PlacementPlan, k: usize) -> PlacementPlan {
        let mut next = self.steps[k].apply_to(current);
        if k + 1 == self.steps.len() {
            debug_assert!(next.macros_used() <= self.target_budget);
            next.budget = self.target_budget;
            next.predicted_retunes = self.retunes_after;
        }
        next
    }

    /// The plan after applying the first `k` steps to `from`.
    pub fn apply(&self, from: &PlacementPlan, k: usize) -> PlacementPlan {
        assert!(k <= self.steps.len());
        (0..k).fold(from.clone(), |p, i| self.apply_step(&p, i))
    }

    /// The migration's destination: the full fold of `steps` over `from`.
    pub fn target(&self, from: &PlacementPlan) -> PlacementPlan {
        self.apply(from, self.steps.len())
    }

    /// Retunes/batch the steady state stops paying once the migration
    /// completes (negative when the target plan is *worse* — the
    /// controller never applies those).
    pub fn predicted_retunes_saved_per_batch(&self) -> i64 {
        self.retunes_before as i64 - self.retunes_after as i64
    }

    /// One-shot programming cycles applying every step costs (a row
    /// write is one cycle through the write circuitry, matching
    /// `RunStats::programming_cycles`).  Row counts come from the pool —
    /// plans store replica counts, not row counts.
    pub fn programming_cycles_to_apply(
        &self,
        hidden_load_rows: &[Vec<usize>],
        output_rows: usize,
    ) -> u64 {
        self.steps
            .iter()
            .map(|s| s.programming_rows(hidden_load_rows, output_rows))
            .sum()
    }

    /// Steady-state cycles saved per batch: retunes priced at
    /// `cycles_per_retune` (a retune is a DAC settle stall, not a row
    /// write — the exchange rate is the caller's) plus the spill reload
    /// rows the target plan stops (or starts) paying.  Spilled loads
    /// common to both plans cancel, so only promotions/demotions and
    /// the funnel's output re-land toggle appear.
    pub fn steady_cycles_saved_per_batch(
        &self,
        hidden_load_rows: &[Vec<usize>],
        output_rows: usize,
        cycles_per_retune: u64,
    ) -> i64 {
        let mut saved = self.predicted_retunes_saved_per_batch() * cycles_per_retune as i64;
        for s in &self.steps {
            match *s {
                MigrationStep::SpillPromote { layer, load } => {
                    saved += hidden_load_rows[layer][load] as i64;
                }
                MigrationStep::SpillDemote { layer, load } => {
                    saved -= hidden_load_rows[layer][load] as i64;
                }
                _ => {}
            }
        }
        saved += output_rows as i64 * (self.spill_before as i64 - self.spill_after as i64);
        saved
    }

    /// Whether the one-shot programming price is repaid by the
    /// steady-state saving within `horizon_batches`: the cost-model gate
    /// the controller checks before touching the pool.  An empty
    /// migration trivially pays off; one with no positive saving never
    /// does.
    pub fn pays_off(
        &self,
        hidden_load_rows: &[Vec<usize>],
        output_rows: usize,
        horizon_batches: u64,
        cycles_per_retune: u64,
    ) -> bool {
        if self.steps.is_empty() {
            return true;
        }
        let saved =
            self.steady_cycles_saved_per_batch(hidden_load_rows, output_rows, cycles_per_retune);
        if saved <= 0 {
            return false;
        }
        let cost = self.programming_cycles_to_apply(hidden_load_rows, output_rows);
        cost <= horizon_batches.saturating_mul(saved as u64)
    }
}

/// One tenant's shape and traffic, as seen by [`plan_tenants`].
#[derive(Clone, Debug)]
pub struct TenantSpec<'t> {
    /// Programmed rows per hidden (layer, load) — `MacroPool` shape.
    pub hidden_load_rows: Vec<Vec<usize>>,
    /// Operating-point class per schedule position (see [`plan_traffic`]).
    pub schedule_points: Vec<usize>,
    /// Measured per-position access histogram (`None` = uniform),
    /// borrowed from the caller — specs are planning inputs, so they
    /// never need to own a copy.
    pub traffic: Option<&'t [u64]>,
    /// Relative batch-traffic share of this tenant (surplus allotment);
    /// non-positive shares are treated as equal weight.
    pub share: f64,
    /// Per-macro health of this tenant's pool (`None` = nominal).  Its
    /// quarantined count inflates the tenant's floor and cap so the
    /// allocation covers the held-out macros, and the per-tenant plan
    /// applies the same penalties as [`plan_traffic`].
    pub health: Option<HealthScores>,
}

impl TenantSpec<'_> {
    fn hidden(&self) -> usize {
        self.hidden_load_rows.iter().map(Vec::len).sum()
    }

    /// Smallest budget this tenant can run resident on (cold-spill floor).
    fn min_budget(&self) -> usize {
        let hidden = self.hidden();
        let min_output = self.schedule_points.len().min(1);
        if hidden >= 2 {
            2.min(hidden + min_output)
        } else {
            hidden + min_output
        }
    }

    /// Budget past which extra macros can only idle: full point pinning
    /// plus worker-capped replicas of every load.
    fn max_useful_budget(&self, workers: usize) -> usize {
        let mut points: Vec<usize> = self.schedule_points.clone();
        points.sort_unstable();
        points.dedup();
        self.hidden() * workers.max(1) + points.len()
    }
}

/// A macro budget partitioned across tenants: `plans[t]` is tenant `t`'s
/// single-model placement over its sub-budget (Σ sub-budgets ≤ `budget`).
#[derive(Clone, Debug)]
pub struct TenantPlan {
    pub budget: usize,
    pub plans: Vec<PlacementPlan>,
}

impl TenantPlan {
    /// Macros instantiated across every tenant.
    pub fn macros_used(&self) -> usize {
        self.plans.iter().map(PlacementPlan::macros_used).sum()
    }

    /// One-line description per tenant.
    pub fn describe(&self) -> String {
        self.plans
            .iter()
            .enumerate()
            .map(|(t, p)| format!("tenant {t}: {}", p.describe()))
            .collect::<Vec<_>>()
            .join("; ")
    }
}

/// Partition `budget` macros across `specs` tenants and plan each one.
///
/// Allocation: every tenant first receives its feasibility floor
/// ([`TenantSpec::min_budget`] — full residency preferred, cold-spill
/// accepted); `None` if even the floors don't fit.  The surplus is then
/// handed out one macro at a time, proportional-fair by traffic share
/// (each macro goes to the tenant maximising `share / (extra + 1)`, ties
/// to the lowest tenant index), capped at each tenant's
/// [`TenantSpec::max_useful_budget`].
pub fn plan_tenants(specs: &[TenantSpec<'_>], budget: usize, workers: usize) -> Option<TenantPlan> {
    // quarantined macros are dead weight inside a tenant's sub-budget:
    // inflate its floor and cap by that count so the share it receives
    // buys the same usable capacity a healthy tenant would get
    let quarantined =
        |s: &TenantSpec| s.health.as_ref().map_or(0, |h| h.quarantined_macros);
    let mins: Vec<usize> = specs
        .iter()
        .map(|s| s.min_budget() + quarantined(s))
        .collect();
    let maxs: Vec<usize> = specs
        .iter()
        .map(|s| s.max_useful_budget(workers) + quarantined(s))
        .collect();
    let floor: usize = mins.iter().sum();
    if floor > budget {
        return None;
    }
    let any_positive = specs.iter().any(|s| s.share > 0.0);
    let share = |i: usize| -> f64 {
        if any_positive {
            specs[i].share.max(0.0)
        } else {
            1.0
        }
    };
    let mut alloc = mins.clone();
    let mut surplus = budget - floor;
    while surplus > 0 {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..specs.len() {
            if alloc[i] >= maxs[i] {
                continue;
            }
            let score = share(i) / (alloc[i] - mins[i] + 1) as f64;
            if best.map_or(true, |(_, b)| score > b) {
                best = Some((i, score));
            }
        }
        match best {
            Some((i, _)) => {
                alloc[i] += 1;
                surplus -= 1;
            }
            None => break, // every tenant saturated; leave the rest unspent
        }
    }
    let plans: Option<Vec<PlacementPlan>> = specs
        .iter()
        .zip(&alloc)
        .map(|(s, &b)| {
            plan_traffic(
                &s.hidden_load_rows,
                &s.schedule_points,
                s.traffic,
                s.health.as_ref(),
                b,
                workers,
            )
        })
        .collect();
    plans.map(|plans| TenantPlan { budget, plans })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn infeasible_budgets_return_none() {
        // 3 hidden loads + ≥1 output slot → 4 macros for full residency;
        // cold-spill takes the floor down to 2 (1 resident + the funnel)
        let rows = vec![vec![64, 64], vec![16]];
        for budget in 0..2 {
            assert!(plan(&rows, 33, budget, 1).is_none(), "budget {budget}");
        }
        for budget in 2..4 {
            let p = plan(&rows, 33, budget, 1).unwrap();
            assert!(p.spill_active(), "budget {budget}");
        }
        assert!(!plan(&rows, 33, 4, 1).unwrap().spill_active());
        // a single hidden load has nothing to spill: below full residency
        // the model must reload
        assert!(plan(&[vec![64]], 33, 1, 1).is_none());
    }

    #[test]
    fn full_budget_pins_everything_and_replicates_surplus() {
        let rows = vec![vec![64, 64], vec![16]];
        let p = plan(&rows, 33, 3 + 33, 4).unwrap();
        assert_eq!(p.pinned, 33);
        assert_eq!(p.pinned_positions(), 33);
        assert_eq!(p.shared_slots, 0);
        assert!(!p.sharing_active());
        assert!(!p.replication_active());
        assert_eq!(p.predicted_retunes_per_batch(), 0);
        assert_eq!(p.macros_used(), 36);

        // 5 surplus macros: hottest loads (64 rows) replicate first
        let p = plan(&rows, 33, 3 + 33 + 5, 4).unwrap();
        assert!(p.replication_active());
        assert_eq!(p.macros_used(), 41);
        assert_eq!(p.predicted_retunes_per_batch(), 0);
        // round-robin over [64, 64, 16] hottest-first: 2+2+1
        assert_eq!(p.hidden_replicas, vec![vec![3, 3], vec![2]]);
    }

    #[test]
    fn replication_never_exceeds_the_worker_count() {
        let rows = vec![vec![64], vec![16]];
        // huge surplus, 3 workers: every load caps at 3 replicas and the
        // rest of the budget stays unspent
        let p = plan(&rows, 4, 100, 3).unwrap();
        assert_eq!(p.hidden_replicas, vec![vec![3], vec![3]]);
        assert_eq!(p.macros_used(), 6 + 4);
        // one worker: replicas can only idle, so none are built
        let p = plan(&rows, 4, 100, 1).unwrap();
        assert!(!p.replication_active());
        assert_eq!(p.macros_used(), 2 + 4);
    }

    #[test]
    fn degraded_budget_shares_thresholds_through_one_slot() {
        // the acceptance shape: 6 hidden loads + 33 thresholds = 39 full,
        // planned into 16
        let rows = vec![vec![64; 6]];
        let p = plan(&rows, 33, 16, 1).unwrap();
        assert_eq!(p.hidden_macros(), 6);
        assert_eq!(p.pinned, 9);
        assert_eq!(p.shared_slots, 1);
        assert_eq!(p.macros_used(), 16);
        assert!(p.sharing_active());
        assert!(!p.spill_active());
        // 24 unpinned thresholds funnel through the shared slot; with the
        // uniform compat histogram the pins are the schedule prefix
        assert_eq!(p.predicted_retunes_per_batch(), 24);
        for k in 0..9 {
            assert_eq!(p.pin_slot[k], Some(k));
        }
        assert!(p.pin_slot[9..].iter().all(Option::is_none));
    }

    #[test]
    fn minimum_viable_budget_runs_everything_shared() {
        let rows = vec![vec![64]];
        let p = plan(&rows, 33, 2, 1).unwrap();
        assert_eq!(p.pinned, 0);
        assert_eq!(p.shared_slots, 1);
        assert_eq!(p.predicted_retunes_per_batch(), 33);
        assert_eq!(p.macros_used(), 2);
    }

    #[test]
    fn pinning_dominates_extra_shared_slots_for_cyclic_sweeps() {
        // the cost-model claim: at equal budget, d pins + 1 funnel beats
        // any balanced shared split (whose LRU thrashes the full cycle)
        let rows = vec![vec![64]];
        for budget in 3..34 {
            let p = plan(&rows, 33, budget, 1).unwrap();
            let balanced_cost = 33u64; // s ≥ 2 shared slots, r > s → all miss
            assert!(
                p.predicted_retunes_per_batch() < balanced_cost,
                "budget {budget}: {}",
                p.predicted_retunes_per_batch()
            );
        }
    }

    #[test]
    fn empty_schedule_needs_no_output_macros() {
        let rows = vec![vec![64, 32]];
        let p = plan(&rows, 0, 2, 1).unwrap();
        assert_eq!(p.output_macros(), 0);
        assert_eq!(p.predicted_retunes_per_batch(), 0);
        assert!(plan(&rows, 0, 1, 1).is_none());
    }

    #[test]
    fn cold_spill_keeps_the_hottest_loads_resident() {
        // 4 loads of distinct heat + 4 thresholds, budget 3: the two
        // hottest loads keep macros, the two coldest spill to the funnel
        let rows = vec![vec![64, 16], vec![48, 8]];
        let p = plan(&rows, 4, 3, 1).unwrap();
        assert!(p.spill_active());
        assert_eq!(p.hidden_replicas, vec![vec![1, 0], vec![1, 0]]);
        assert_eq!(p.spilled_loads(), 2);
        assert_eq!(p.pinned, 0);
        assert_eq!(p.shared_slots, 1);
        assert_eq!(p.macros_used(), 3);
        // funnel cycle: spill(l0), spill(l1), 4 distinct output points →
        // 6 transitions/batch
        assert_eq!(p.predicted_retunes_per_batch(), 6);
        // spill with an empty schedule still keeps the funnel slot
        let p = plan(&rows, 0, 3, 1).unwrap();
        assert!(p.spill_active());
        assert_eq!(p.shared_slots, 1);
        assert_eq!(p.macros_used(), 3);
    }

    #[test]
    fn skewed_schedule_pins_by_point_weight_not_prefix() {
        // threshold value 0 occupies 8 of 12 positions; grouping by
        // operating point + weight-first pinning serves all 8 from one
        // pinned macro, so the funnel sees only the cold tail
        let points = vec![0, 0, 0, 0, 0, 0, 0, 0, 1, 2, 3, 4];
        let rows = vec![vec![64]];
        // budget 4 → output budget 3 → pin 2 points + 1 funnel
        let p = plan_traffic(&rows, &points, None, None, 4, 1).unwrap();
        assert_eq!(p.pinned, 2);
        // the heavy point (weight 8) and the earliest unit point pin
        assert_eq!(p.pin_slot[0], Some(0), "heavy point pinned");
        assert_eq!(p.pin_slot[7], Some(0), "all its positions share the slot");
        assert_eq!(p.pin_slot[8], Some(1), "tie-break: earliest unit point");
        assert!(p.pin_slot[9..].iter().all(Option::is_none));
        assert_eq!(p.pinned_positions(), 9);
        // funnel: points {2, 3, 4} → 3 transitions, strictly below the
        // distinct-point prefix rule's K − d = 12 − 2 = 10
        assert_eq!(p.predicted_retunes_per_batch(), 3);
        let prefix = plan(&rows, points.len(), 4, 1).unwrap();
        assert!(p.predicted_retunes_per_batch() < prefix.predicted_retunes_per_batch());
        // measured traffic can override the schedule frequencies: make
        // position 11 the hot one
        let mut traffic = vec![1u64; 12];
        traffic[11] = 100;
        let p = plan_traffic(&rows, &points, Some(&traffic), None, 3, 1).unwrap();
        assert_eq!(p.pinned, 1);
        assert_eq!(p.pin_slot[11], Some(0), "measured-hot point pinned first");
    }

    #[test]
    fn empty_histogram_means_uniform_traffic() {
        // feeding back take_output_traffic() from a reload-mode pool
        // yields an empty histogram — that must plan exactly like the
        // uniform default, never panic on a length mismatch
        let points = vec![0, 1, 2, 3];
        let uniform = plan_traffic(&[vec![64]], &points, None, None, 3, 1).unwrap();
        let empty = plan_traffic(&[vec![64]], &points, Some(&[]), None, 3, 1).unwrap();
        assert_eq!(uniform, empty);
    }

    #[test]
    fn repeated_points_pin_into_one_macro() {
        // full pinning of 3 distinct points over 6 positions costs 3
        // macros, not 6
        let points = vec![0, 1, 0, 2, 1, 0];
        let p = plan_traffic(&[vec![64]], &points, None, None, 1 + 3, 1).unwrap();
        assert_eq!(p.pinned, 3);
        assert_eq!(p.shared_slots, 0);
        assert_eq!(p.pinned_positions(), 6);
        assert_eq!(p.predicted_retunes_per_batch(), 0);
        assert_eq!(p.macros_used(), 4);
    }

    #[test]
    fn describe_mentions_the_split() {
        let p = plan(&[vec![64; 6]], 33, 16, 1).unwrap();
        let d = p.describe();
        assert!(d.contains("16 macros"), "{d}");
        assert!(d.contains("9/33"), "{d}");
    }

    #[test]
    fn diff_of_equal_plans_is_empty() {
        let rows = vec![vec![64, 32]];
        let p = plan(&rows, 8, 6, 1).unwrap();
        let mp = p.diff(&p);
        assert!(mp.is_empty());
        assert!(mp.pays_off(&rows, 10, 1, 138), "empty migration is free");
        assert_eq!(mp.target(&p), p);
    }

    #[test]
    fn diff_repins_on_a_skew_flip_and_the_fold_reproduces_the_target() {
        // 6 distinct points, budget 4 → 2 pins + funnel.  The histogram
        // flips from low-positions-hot to high-positions-hot: the diff
        // is two repins (zero row writes), and folding the steps over
        // the old plan reproduces the new one field for field.
        let rows = vec![vec![64]];
        let points: Vec<usize> = (0..6).collect();
        let hot_lo = [9u64, 9, 9, 1, 1, 1];
        let hot_hi = [1u64, 1, 1, 9, 9, 9];
        let old = plan_traffic(&rows, &points, Some(&hot_lo), None, 4, 1).unwrap();
        let new = plan_traffic(&rows, &points, Some(&hot_hi), None, 4, 1).unwrap();
        let mp = old.diff(&new);
        assert_eq!(
            mp.steps,
            vec![
                MigrationStep::Repin { from: 0, to: 3 },
                MigrationStep::Repin { from: 1, to: 4 },
            ]
        );
        assert_eq!(mp.target(&old), new);
        assert_eq!(mp.programming_cycles_to_apply(&rows, 10), 0);
        // every step prefix is a valid, fully-provisioned plan
        for k in 0..=mp.steps.len() {
            let p = mp.apply(&old, k);
            assert_eq!(p.macros_used(), 4, "prefix {k}");
            assert_eq!(p.pinned, 2, "prefix {k}");
        }
        // re-priced under the flipped histogram the saving is real: the
        // old pins sit on positions the workload no longer touches hard
        let mp = old.repriced(Some(&hot_hi)).diff(&new);
        assert!(mp.predicted_retunes_saved_per_batch() > 0);
        assert!(mp.pays_off(&rows, 10, 1, 138));
    }

    #[test]
    fn weighted_cost_model_ignores_unaccessed_positions() {
        // positions the measured histogram never saw contribute nothing:
        // a plan whose funnel only carries dead positions prices at zero
        let rows = vec![vec![64]];
        let points: Vec<usize> = (0..6).collect();
        let p = plan(&rows, 6, 4, 1).unwrap(); // pins 0,1; funnel 2..6
        let dead_tail = [5u64, 5, 0, 0, 0, 0];
        assert_eq!(p.repriced(Some(&dead_tail)).predicted_retunes_per_batch(), 0);
        // uniform re-pricing reproduces the unweighted transition count
        assert_eq!(
            p.repriced(None).predicted_retunes_per_batch(),
            p.predicted_retunes_per_batch()
        );
    }

    #[test]
    fn diff_grows_a_spill_plan_to_full_residency() {
        let rows = vec![vec![64, 16], vec![48, 8]];
        let old = plan(&rows, 4, 3, 1).unwrap(); // 2 resident + funnel, 2 spilled
        let new = plan(&rows, 4, 8, 1).unwrap(); // fully resident + 4 pins
        let mp = old.diff(&new);
        assert_eq!(mp.target(&old), new);
        // promotions program the spilled rows, pins the output rows; the
        // funnel drops only at the end (4 pins × 10 + loads 16 + 8)
        assert_eq!(mp.programming_cycles_to_apply(&rows, 10), 4 * 10 + 16 + 8);
        assert_eq!(
            mp.steps.last(),
            Some(&MigrationStep::Release { point: None }),
            "funnel drops last"
        );
        // steady saving: 6 retunes + 24 spill rows + 10 output re-land
        assert_eq!(mp.steady_cycles_saved_per_batch(&rows, 10, 1), 6 + 24 + 10);
        assert!(!mp.pays_off(&rows, 10, 1, 1), "one batch cannot repay 64 cycles");
        assert!(mp.pays_off(&rows, 10, 2, 1));
        // the reverse migration makes the steady state worse: no horizon
        // ever justifies it
        let back = new.diff(&old);
        assert_eq!(back.target(&new), old);
        assert!(back.predicted_retunes_saved_per_batch() < 0);
        assert!(!back.pays_off(&rows, 10, 1_000_000, 138));
        // intermediate plans stay valid through the funnel flip: the
        // documented transient overshoot is at most one macro
        let peak = (0..=back.steps.len())
            .map(|k| back.apply(&new, k).macros_used())
            .max()
            .unwrap();
        assert_eq!(peak, new.macros_used() + 1);
    }

    #[test]
    fn diff_retargets_replicas_without_touching_residents() {
        let rows = vec![vec![64], vec![16]];
        let big = plan(&rows, 4, 8, 3).unwrap(); // surplus 2 → replicas [[2],[2]]
        let small = plan(&rows, 4, 6, 3).unwrap(); // no surplus → [[1],[1]]
        assert_eq!(big.hidden_replicas, vec![vec![2], vec![2]]);
        let down = big.diff(&small);
        assert_eq!(
            down.steps,
            vec![
                MigrationStep::DropReplica { layer: 0, load: 0 },
                MigrationStep::DropReplica { layer: 1, load: 0 },
            ]
        );
        assert_eq!(down.programming_cycles_to_apply(&rows, 10), 0);
        assert_eq!(down.target(&big), small);
        let up = small.diff(&big);
        assert_eq!(
            up.steps,
            vec![
                MigrationStep::AddReplica { layer: 0, load: 0 },
                MigrationStep::AddReplica { layer: 1, load: 0 },
            ]
        );
        assert_eq!(up.programming_cycles_to_apply(&rows, 10), 64 + 16);
        assert_eq!(up.target(&small), big);
    }

    fn health(hidden: Vec<Vec<HealthState>>, quarantined: usize) -> HealthScores {
        HealthScores {
            hidden,
            quarantined_macros: quarantined,
        }
    }

    #[test]
    fn quarantined_macros_are_held_out_of_the_budget() {
        let rows = vec![vec![64, 64], vec![16]];
        // 2 quarantined macros: a budget of 38 buys exactly what a
        // healthy budget of 36 would — nothing lands on dead capacity
        let h = health(Vec::new(), 2);
        let p = plan_traffic(&rows, &(0..33).collect::<Vec<_>>(), None, Some(&h), 38, 4).unwrap();
        let base = plan(&rows, 33, 36, 4).unwrap();
        assert_eq!(p, base);
        assert!(p.macros_used() <= 38 - 2);
        // nominal health plans bit-identically to no health at all
        let nominal = health(vec![vec![HealthState::Healthy; 2], vec![HealthState::Healthy]], 0);
        assert!(nominal.is_nominal());
        let p = plan_traffic(&rows, &(0..33).collect::<Vec<_>>(), None, Some(&nominal), 36, 4)
            .unwrap();
        assert_eq!(p, base);
        // when the held-out capacity leaves less than the spill floor,
        // the plan is infeasible — never silently placed on dead macros
        let h = health(Vec::new(), 3);
        assert!(plan_traffic(&rows, &(0..33).collect::<Vec<_>>(), None, Some(&h), 4, 1).is_none());
    }

    #[test]
    fn health_penalty_steers_replicas_toward_healthy_loads() {
        // two loads, two distinct points, 1 surplus macro, 2 workers:
        // healthy planning replicates the hottest (64-row) load
        let rows = vec![vec![64, 48]];
        let points = vec![0, 1];
        let base = plan_traffic(&rows, &points, None, None, 5, 2).unwrap();
        assert_eq!(base.hidden_replicas, vec![vec![2, 1]]);
        // with the hot load Suspect, the replica goes to the healthy one
        let h = health(vec![vec![HealthState::Suspect, HealthState::Healthy]], 0);
        let p = plan_traffic(&rows, &points, None, Some(&h), 5, 2).unwrap();
        assert_eq!(p.hidden_replicas, vec![vec![1, 2]]);
        // a load with a copy on probation takes no surplus at all, even
        // with budget to burn: its capacity returns via re-admission
        let h = health(vec![vec![HealthState::Probation, HealthState::Healthy]], 0);
        let p = plan_traffic(&rows, &points, None, Some(&h), 10, 2).unwrap();
        assert_eq!(p.hidden_replicas, vec![vec![1, 2]]);
        // once every healthy load is worker-capped, a Suspect load may
        // still absorb leftover surplus (penalized, not excluded)
        let h = health(vec![vec![HealthState::Suspect, HealthState::Healthy]], 0);
        let p = plan_traffic(&rows, &points, None, Some(&h), 6, 2).unwrap();
        assert_eq!(p.hidden_replicas, vec![vec![2, 2]]);
    }

    #[test]
    fn suspect_loads_spill_before_healthy_ones() {
        // budget 3 keeps 2 of 4 loads resident; normally the two hottest
        // (64, 48) stay.  Marking the hottest Suspect spills it instead.
        let rows = vec![vec![64, 16], vec![48, 8]];
        let h = health(
            vec![
                vec![HealthState::Suspect, HealthState::Healthy],
                vec![HealthState::Healthy, HealthState::Healthy],
            ],
            0,
        );
        let p = plan_traffic(&rows, &[0, 1, 2, 3], None, Some(&h), 3, 1).unwrap();
        assert_eq!(p.hidden_replicas, vec![vec![0, 1], vec![1, 0]]);
    }

    fn spec(rows: Vec<Vec<usize>>, sched: usize, share: f64) -> TenantSpec<'static> {
        TenantSpec {
            hidden_load_rows: rows,
            schedule_points: (0..sched).collect(),
            traffic: None,
            share,
            health: None,
        }
    }

    #[test]
    fn tenant_floors_come_before_shares() {
        // two tenants, budget exactly the sum of full-residency needs:
        // both fully pinned regardless of the share skew
        let specs = vec![
            spec(vec![vec![64]], 4, 100.0),
            spec(vec![vec![64, 64]], 4, 1.0),
        ];
        let tp = plan_tenants(&specs, (1 + 4) + (2 + 4), 1).unwrap();
        assert!(!tp.plans[0].sharing_active());
        assert!(!tp.plans[1].sharing_active());
        assert!(tp.macros_used() <= tp.budget);
        // below the spill floors there is no tenancy plan
        assert!(plan_tenants(&specs, 2, 1).is_none());
    }

    #[test]
    fn surplus_follows_traffic_share() {
        // equal shapes, 3:1 shares: the hot tenant pins ~3× the surplus
        let specs = vec![
            spec(vec![vec![64]], 20, 3.0),
            spec(vec![vec![64]], 20, 1.0),
        ];
        let floor = 2 + 2;
        let tp = plan_tenants(&specs, floor + 8, 1).unwrap();
        let extra: Vec<usize> = tp.plans.iter().map(|p| p.budget - 2).collect();
        assert_eq!(extra[0] + extra[1], 8);
        assert!(extra[0] >= 3 * extra[1], "{extra:?}");
        assert!(tp.macros_used() <= tp.budget);
    }

    #[test]
    fn tenant_surplus_never_exceeds_useful_budget() {
        // a huge budget saturates both tenants at full pinning (+ capped
        // replicas) and leaves the rest unspent
        let specs = vec![spec(vec![vec![64]], 4, 1.0), spec(vec![vec![32]], 2, 1.0)];
        let tp = plan_tenants(&specs, 500, 2).unwrap();
        for (t, p) in tp.plans.iter().enumerate() {
            assert!(!p.sharing_active(), "tenant {t}");
            assert!(
                p.hidden_replicas.iter().flatten().all(|&r| r <= 2),
                "tenant {t}"
            );
        }
        assert!(tp.macros_used() < 500);
    }

    #[test]
    fn tenant_spill_floor_keeps_many_models_viable() {
        // three multi-load tenants on a budget far below full residency:
        // every tenant still plans (cold-spill), none reloads
        let specs = vec![
            spec(vec![vec![64; 6]], 33, 1.0),
            spec(vec![vec![64; 4]], 33, 1.0),
            spec(vec![vec![64; 2]], 33, 1.0),
        ];
        let tp = plan_tenants(&specs, 9, 1).unwrap();
        assert_eq!(tp.plans.len(), 3);
        for p in &tp.plans {
            assert!(p.macros_used() >= 2);
        }
        assert!(tp.macros_used() <= 9);
    }
}
