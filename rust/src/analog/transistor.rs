//! Device-level models: M_eval pulldown conductance and the current-starved
//! delay element that sets the MLSA sampling time, with temperature and
//! process dependence.

use super::constants as k;

/// Process/voltage/temperature operating point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pvt {
    /// Junction temperature [°C].
    pub temp_c: f64,
    /// Actual supply [V] (nominal 1.2; drifts model brown-out / IR drop).
    pub vdd: f64,
    /// Global process corner shift on V_TH [V] (die-to-die; 0 = typical).
    pub vth_shift: f64,
    /// Global conductance multiplier (die-to-die; 1.0 = typical).
    pub g_scale: f64,
}

impl Default for Pvt {
    fn default() -> Self {
        Pvt::nominal()
    }
}

impl Pvt {
    pub fn nominal() -> Self {
        Pvt {
            temp_c: k::T_NOMINAL,
            vdd: k::V_DD,
            vth_shift: 0.0,
            g_scale: 1.0,
        }
    }

    /// Classic corners for the PVT ablation bench.
    pub fn corner(name: &str) -> Pvt {
        match name {
            // slow-slow: high V_TH, weak devices, hot
            "ss" => Pvt {
                temp_c: 85.0,
                vdd: 1.14,
                vth_shift: 0.03,
                g_scale: 0.88,
            },
            // fast-fast: low V_TH, strong devices, cold
            "ff" => Pvt {
                temp_c: 0.0,
                vdd: 1.26,
                vth_shift: -0.03,
                g_scale: 1.12,
            },
            _ => Pvt::nominal(),
        }
    }

    /// Effective threshold voltage at this operating point.
    pub fn vth(&self) -> f64 {
        k::V_TH + self.vth_shift + k::VTH_TEMP_COEFF * (self.temp_c - k::T_NOMINAL)
    }

    /// Temperature scaling of carrier mobility (g ∝ (T/T0)^-1.5 in Kelvin).
    pub fn mobility_scale(&self) -> f64 {
        let t = self.temp_c + 273.15;
        let t0 = k::T_NOMINAL + 273.15;
        (t / t0).powf(k::MU_TEMP_EXP)
    }
}

/// Conductance of one mismatching pulldown path gated by V_eval [S].
///
/// Triode-ish linear law above threshold, clamped at zero below — the same
/// closed form as `python/compile/physics.py::g_eval`, extended with PVT.
#[inline]
pub fn g_eval(veval: f64, pvt: &Pvt) -> f64 {
    let overdrive = (veval - pvt.vth()).max(0.0);
    k::K_G * overdrive * pvt.g_scale * pvt.mobility_scale()
}

/// MLSA sampling time from the V_st-starved delay line [s].
///
/// t_s = TAU0 · V_DD / (V_st − V_TH): raising V_st speeds the delay chain
/// up, sampling *earlier*, which tolerates more discharge → higher HD
/// tolerance (paper §III, Fig. 4).
#[inline]
pub fn t_sample(vst: f64, pvt: &Pvt) -> f64 {
    let overdrive = (vst - pvt.vth()).max(k::EPS);
    k::TAU0 * pvt.vdd / overdrive
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_matches_python_constants() {
        let pvt = Pvt::nominal();
        assert!((pvt.vth() - 0.25).abs() < 1e-12);
        assert!((pvt.mobility_scale() - 1.0).abs() < 1e-12);
        // g(0.95) = K_G * 0.7
        assert!((g_eval(0.95, &pvt) - k::K_G * 0.7).abs() < 1e-18);
        // t_s(1.2) = TAU0 * 1.2 / 0.95
        assert!((t_sample(1.2, &pvt) - k::TAU0 * 1.2 / 0.95).abs() < 1e-18);
    }

    #[test]
    fn subthreshold_cutoff() {
        let pvt = Pvt::nominal();
        assert_eq!(g_eval(0.2, &pvt), 0.0);
        assert_eq!(g_eval(pvt.vth(), &pvt), 0.0);
    }

    #[test]
    fn hot_is_slower_and_lower_vth() {
        let hot = Pvt {
            temp_c: 85.0,
            ..Pvt::nominal()
        };
        assert!(hot.vth() < Pvt::nominal().vth());
        assert!(hot.mobility_scale() < 1.0);
    }

    #[test]
    fn corners_ordered() {
        let ff = Pvt::corner("ff");
        let ss = Pvt::corner("ss");
        let tt = Pvt::nominal();
        assert!(g_eval(0.9, &ff) > g_eval(0.9, &tt));
        assert!(g_eval(0.9, &ss) < g_eval(0.9, &tt));
    }

    #[test]
    fn higher_vst_samples_earlier() {
        let pvt = Pvt::nominal();
        assert!(t_sample(1.2, &pvt) < t_sample(0.7, &pvt));
    }
}
