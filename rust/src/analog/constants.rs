//! Nominal 65 nm-flavoured device constants and variation sigmas.
//!
//! Mirror of `python/compile/physics.py` — keep the nominal values in sync
//! (rust/tests/analog_cross_check.rs enforces agreement on the functional
//! model).  The variation/PVT parameters below only exist on the rust side:
//! the python twin is the deterministic nominal model.

/// Supply voltage [V].
pub const V_DD: f64 = 1.2;
/// Effective NMOS threshold at 25 °C [V].
pub const V_TH: f64 = 0.25;
/// Transconductance-ish slope of the M_eval pulldown stack [S/V].
pub const K_G: f64 = 8.93e-7;
/// Matchline capacitance for a 256-cell row [F].
pub const C_ML_256: f64 = 12e-15;
/// Per-cell matchline capacitance [F].
pub const C_ML_PER_CELL: f64 = C_ML_256 / 256.0;
/// Delay-element unit time constant [s].
pub const TAU0: f64 = 0.8e-9;
/// Guard for the sampling-time denominator.
pub const EPS: f64 = 1e-3;

/// Legal tuning windows for the three user-configurable voltages [V].
pub const VREF_RANGE: (f64, f64) = (0.6, 1.2);
pub const VEVAL_RANGE: (f64, f64) = (0.3, 1.2);
pub const VST_RANGE: (f64, f64) = (0.6, 1.2);

// ---------------------------------------------------------------------
// Variation / PVT parameters (rust-only; drive the Monte-Carlo machinery).
// ---------------------------------------------------------------------

/// Per-cell pulldown-conductance mismatch sigma (fraction; *frozen* at
/// fabrication — enters the per-row systematic factor, not per-eval noise).
pub const SIGMA_G_CELL: f64 = 0.05;
/// Per-row systematic conductance sigma as fabricated (layout gradient +
/// averaged cell mismatch; frozen).  The bring-up flow trims the MLSA
/// references per row (auto-zeroing, as in the HD-CAM / JSSC'25 silicon
/// this design builds on), leaving the post-trim residual below.
pub const SIGMA_G_ROW_RAW: f64 = 0.008;
/// Post-trim residual row-conductance sigma (what inference sees).
pub const SIGMA_G_ROW: f64 = 0.002;
/// Per-cell threshold-voltage mismatch sigma [V] (local variation).
pub const SIGMA_VTH_CELL: f64 = 0.012;
/// MLSA comparator input-referred offset sigma as fabricated [V].
pub const SIGMA_MLSA_OFFSET_RAW: f64 = 0.003;
/// Post-trim residual MLSA offset sigma [V].
pub const SIGMA_MLSA_OFFSET: f64 = 0.001;
/// Per-evaluation stochastic conductance noise (thermal/shot, fraction).
/// Calibrated so the end-to-end analog accuracy reproduces the silicon's
/// reported behaviour (the hidden layer's single-shot majority at n/2 over
/// 1024/2048 cells needs ~0.1% evaluation-to-evaluation repeatability —
/// implied by the paper reaching baseline software accuracy on MNIST).
pub const SIGMA_G_EVAL: f64 = 0.001;
/// Cycle-to-cycle supply noise sigma [V] (affects V_DD each evaluation).
pub const SIGMA_VDD_NOISE: f64 = 0.001;
/// Cycle-to-cycle sampling-time jitter sigma (fraction of t_s).
pub const SIGMA_TS_JITTER: f64 = 0.001;

/// Temperature coefficient of V_TH [V/°C] (V_TH drops as T rises).
pub const VTH_TEMP_COEFF: f64 = -0.8e-3;
/// Mobility/conductance temperature exponent: g ∝ (T/T0)^MU_TEMP_EXP.
pub const MU_TEMP_EXP: f64 = -1.5;
/// Nominal temperature [°C].
pub const T_NOMINAL: f64 = 25.0;

// ---------------------------------------------------------------------
// Timing / energy events (65 nm-calibrated; feed rust/src/energy).
// ---------------------------------------------------------------------

/// Operating frequency of the evaluated silicon [Hz] (Table II).
pub const F_CLK: f64 = 25.0e6;
/// Search energy per cell [J]: ML precharge + compare-stack switching.
/// Decoupled from C_ML_PER_CELL (the *discharge-path timing* capacitance):
/// the switched capacitance per search also includes the SL gate loads and
/// the precharge network — ~0.21 fF effective at 1.2 V -> ~0.3 fJ/cell,
/// the 65 nm CAM regime (Pagiamtzis & Sheikholeslami, JSSC'06 scaling).
pub const E_PRECHARGE_PER_CELL: f64 = 0.30e-15;
/// Searchline toggle energy per cell [J] (SL + /SL pair, ~2 fF/64 cells).
pub const E_SL_PER_CELL: f64 = 0.10e-15;
/// MLSA evaluation energy per row [J].
pub const E_MLSA_PER_ROW: f64 = 2.0e-15;
/// SRAM write energy per cell [J] (weight programming).
pub const E_WRITE_PER_CELL: f64 = 0.25e-15;
/// Voltage-DAC retune energy per event [J] and settle time [s].
pub const E_RETUNE: f64 = 40e-12;
pub const T_RETUNE_SETTLE: f64 = 2.0e-6;
/// Static leakage power of the 128-kbit macro [W].
pub const P_LEAKAGE: f64 = 55e-6;

/// I/O bus width between the control CPU and the CAM macro [bits/cycle]
/// (query load, activation readout, vote readout all cross this bus).
pub const IO_BUS_BITS: usize = 128;

// ---------------------------------------------------------------------
// Area model (Table II; paper-reported footprints).
// ---------------------------------------------------------------------

/// 10T PiC-BNN bitcell area [mm^2] (paper: ~3.24 µm²).
pub const AREA_BITCELL_MM2: f64 = 3.24e-6;
/// Per-bank peripheral overhead factor (drivers, MLSA, write, precharge):
/// calibrated so 4 banks × 32 kbit land near the paper's 0.87 mm².
pub const BANK_PERIPHERY_FACTOR: f64 = 1.05;
/// SoC area excluding the CAM macro (RISC-V + uncore) [mm^2].
pub const AREA_SOC_REST_MM2: f64 = 1.51;
