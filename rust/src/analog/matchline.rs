//! Matchline discharge + MLSA sensing model (DESIGN.md §4).
//!
//! Two levels of fidelity over the same physics:
//!  * [`MatchlineModel::v_ml`] / [`MatchlineModel::trace`] — explicit
//!    voltage waveform V_ML(t) for figure regeneration (Fig. 4).
//!  * [`MatchlineModel::fires`] — the hot-path decision: closed-form
//!    threshold comparison with per-evaluation noise draws, no waveform.
//!
//! Per-row process variation (cell conductance mismatch) is precomputed
//! once per programmed row (`RowVariation`), so the hot path costs one
//! multiply-add per row, not per cell.

use super::constants as k;
use super::transistor::{g_eval, t_sample, Pvt};
use crate::util::rng::Rng;

/// The three user-configurable voltages (paper Fig. 3, yellow).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Voltages {
    pub vref: f64,
    pub veval: f64,
    pub vst: f64,
}

impl Voltages {
    pub fn new(vref: f64, veval: f64, vst: f64) -> Self {
        Voltages { vref, veval, vst }
    }

    /// Clamp into the legal tuning windows.
    pub fn clamped(self) -> Self {
        Voltages {
            vref: self.vref.clamp(k::VREF_RANGE.0, k::VREF_RANGE.1),
            veval: self.veval.clamp(k::VEVAL_RANGE.0, k::VEVAL_RANGE.1),
            vst: self.vst.clamp(k::VST_RANGE.0, k::VST_RANGE.1),
        }
    }

    /// The "exact search" setting: zero HD tolerance (Table I row 1).
    pub fn exact() -> Self {
        Voltages::new(k::V_DD, k::V_DD, k::V_DD)
    }
}

/// Precomputed per-row Monte-Carlo variation (drawn at programming time).
///
/// The sum of n_mismatch per-cell conductances with fractional sigma σ_c
/// concentrates: mean m·g, sigma ≈ √m·σ_c·g.  We carry a per-row
/// *systematic* conductance factor (layout gradient) plus the per-cell
/// sigma for the stochastic term drawn per evaluation.
#[derive(Clone, Copy, Debug)]
pub struct RowVariation {
    /// Systematic conductance multiplier for this row (≈ N(1, σ_sys)).
    pub g_row_factor: f64,
    /// This row's MLSA comparator offset [V].
    pub mlsa_offset: f64,
}

impl RowVariation {
    pub fn nominal() -> Self {
        RowVariation {
            g_row_factor: 1.0,
            mlsa_offset: 0.0,
        }
    }

    /// Draw variation for a freshly programmed row: frozen process
    /// variation *after* the bring-up trim (auto-zeroed MLSA references).
    pub fn draw(rng: &mut Rng) -> Self {
        RowVariation {
            g_row_factor: (1.0 + rng.normal(0.0, k::SIGMA_G_ROW)).max(0.5),
            mlsa_offset: rng.normal(0.0, k::SIGMA_MLSA_OFFSET),
        }
    }

    /// As-fabricated variation with no trim (ablation benches only).
    pub fn draw_untrimmed(rng: &mut Rng) -> Self {
        RowVariation {
            g_row_factor: (1.0 + rng.normal(0.0, k::SIGMA_G_ROW_RAW)).max(0.5),
            mlsa_offset: rng.normal(0.0, k::SIGMA_MLSA_OFFSET_RAW),
        }
    }
}

/// Matchline + MLSA model for rows of a fixed cell count.
#[derive(Clone, Copy, Debug)]
pub struct MatchlineModel {
    pub n_cells: usize,
    pub pvt: Pvt,
    /// Multiplier on every per-evaluation noise sigma (1.0 = the shipped
    /// device; the law-of-large-numbers ablation sweeps it up).
    pub noise_scale: f64,
}

impl MatchlineModel {
    pub fn new(n_cells: usize, pvt: Pvt) -> Self {
        MatchlineModel {
            n_cells,
            pvt,
            noise_scale: 1.0,
        }
    }

    pub fn with_noise_scale(n_cells: usize, pvt: Pvt, noise_scale: f64) -> Self {
        MatchlineModel {
            n_cells,
            pvt,
            noise_scale,
        }
    }

    /// Row capacitance [F].
    #[inline]
    pub fn c_ml(&self) -> f64 {
        k::C_ML_PER_CELL * self.n_cells as f64
    }

    /// Matchline voltage at time `t` with `m` mismatching cells (nominal
    /// variation): V_ML(t) = V_DD · exp(−m·g·t/C).
    pub fn v_ml(&self, m: u32, t: f64, v: &Voltages) -> f64 {
        let g = g_eval(v.veval, &self.pvt);
        self.pvt.vdd * (-(m as f64) * g * t / self.c_ml()).exp()
    }

    /// Waveform V_ML(t) sampled at `n_pts` points over [0, t_end] (Fig. 4).
    pub fn trace(&self, m: u32, t_end: f64, n_pts: usize, v: &Voltages) -> Vec<(f64, f64)> {
        (0..n_pts)
            .map(|i| {
                let t = t_end * i as f64 / (n_pts - 1).max(1) as f64;
                (t, self.v_ml(m, t, v))
            })
            .collect()
    }

    /// MLSA sampling time for this operating point [s].
    pub fn sampling_time(&self, v: &Voltages) -> f64 {
        t_sample(v.vst, &self.pvt)
    }

    /// Deterministic HD tolerance threshold (nominal, no noise):
    /// a row with `m` mismatches fires iff m ≤ tol.
    ///
    /// tol = C_ML · ln(V_DD / V_ref) / (g(V_eval) · t_s(V_st)), the closed
    /// form shared with `python/compile/physics.py::hd_tolerance`.
    pub fn hd_tolerance(&self, v: &Voltages) -> f64 {
        if v.vref >= self.pvt.vdd {
            return 0.0;
        }
        let denom = g_eval(v.veval, &self.pvt) * self.sampling_time(v);
        if denom <= 0.0 {
            return self.n_cells as f64;
        }
        self.c_ml() * (self.pvt.vdd / v.vref).ln() / denom
    }

    /// Hot-path MLSA decision with per-evaluation noise.
    ///
    /// Frozen process variation enters via `var` (row conductance factor,
    /// MLSA offset); per-evaluation noise via thermal conductance noise,
    /// supply noise and sampling jitter.  `rng` advances once per call —
    /// evaluations are independent draws, which is what the paper's
    /// repeated-execution majority vote averages over.
    ///
    /// One-off convenience over [`MatchlineModel::begin_cycle`]: batched
    /// searches should hold a [`SearchCycle`] instead — supply noise and
    /// sampling jitter are *cycle-global* in silicon (every row of a search
    /// shares the same rails and strobe), and hoisting them (plus the
    /// per-row `ln(vref + off)` cache, [`SearchCycle::fires_cached`]) keeps
    /// the hot loop transcendental-free.
    pub fn fires(&self, m: u32, v: &Voltages, var: &RowVariation, rng: &mut Rng) -> bool {
        self.begin_cycle(v, rng).fires(m, var, rng)
    }

    /// Draw the cycle-global noise and precompute per-search constants.
    #[inline]
    pub fn begin_cycle(&self, v: &Voltages, rng: &mut Rng) -> SearchCycle {
        let g_nom = g_eval(v.veval, &self.pvt);
        let ts = self.sampling_time(v)
            * (1.0 + rng.normal(0.0, k::SIGMA_TS_JITTER * self.noise_scale));
        let vdd = self.pvt.vdd + rng.normal(0.0, k::SIGMA_VDD_NOISE * self.noise_scale);
        SearchCycle {
            vref: v.vref,
            // m fires iff m·g·ts/C < ln(vdd) − ln(vref + off): ln(vdd) is
            // cycle-global, ln(vref + off) is frozen per row until the next
            // retune/reprogram (cached by `cam::CamArray`), so the per-row
            // cost is one subtract + one multiply + a compare
            ln_vdd: vdd.ln(),
            c_over_gts: if g_nom > 0.0 {
                self.c_ml() / (g_nom * ts)
            } else {
                f64::INFINITY
            },
            sigma_g: k::SIGMA_G_EVAL * self.noise_scale,
        }
    }

    /// Noise-free decision (used by tests and the functional cross-check).
    pub fn fires_nominal(&self, m: u32, v: &Voltages, var: &RowVariation) -> bool {
        if m == 0 {
            return true;
        }
        let g_nom = g_eval(v.veval, &self.pvt);
        if g_nom <= 0.0 {
            return true;
        }
        let g = g_nom * var.g_row_factor;
        let ts = self.sampling_time(v);
        let v_ml = self.pvt.vdd * (-(m as f64) * g * ts / self.c_ml()).exp();
        v_ml > v.vref + var.mlsa_offset
    }
}

/// Per-search-cycle state for the noisy hot path: the cycle-global noise
/// draws (supply, strobe jitter) folded into precomputed constants.  With
/// the per-row `ln(vref + off)` cached at retune/programming time (see
/// `cam::CamArray`), each row evaluation costs one multiply and a compare;
/// only metastable-band rows pay for a gaussian draw.
///
/// Algebra: V_ML(t_s) > V_ref + off
///   ⇔ vdd·exp(−m·g·ts/C) > vref + off
///   ⇔ m·(g_row·(1+ε)) < (C/(g_nom·ts))·(ln(vdd) − ln(vref+off))
///
/// Note on reproducibility: `ln(vdd) − ln(vref+off)` can differ from the
/// former `ln(vdd/(vref+off))` by an ulp, so analog decisions for rows
/// sitting *exactly* on a comparison boundary may differ from pre-cache
/// builds of the simulator (and with them that stream's later draw
/// positions).  Within a build every path shares this one formula —
/// batched and sequential searches are bit-identical — and nominal mode
/// is bit-identical across builds (integer thresholds from the exact
/// closed form).
#[derive(Clone, Copy, Debug)]
pub struct SearchCycle {
    vref: f64,
    ln_vdd: f64,
    c_over_gts: f64,
    sigma_g: f64,
}

impl SearchCycle {
    /// MLSA decision for one row in this cycle (computes the row's
    /// `ln(vref + off)` on the fly; batched searches pass the cached value
    /// to [`SearchCycle::fires_cached`] instead).
    #[inline]
    pub fn fires(&self, m: u32, var: &RowVariation, rng: &mut Rng) -> bool {
        self.fires_cached(m, var.g_row_factor, (self.vref + var.mlsa_offset).ln(), rng)
    }

    /// MLSA decision for one row given its precomputed threshold state:
    /// `g_row_factor` and `ln_sense = ln(vref + mlsa_offset)` are frozen
    /// between retune/programming events, so the hot path never touches a
    /// transcendental.  `rng` advances only for metastable-band rows —
    /// callers must present rows in a fixed order for reproducibility.
    #[inline]
    pub fn fires_cached(&self, m: u32, g_row_factor: f64, ln_sense: f64, rng: &mut Rng) -> bool {
        if m == 0 {
            // no discharge path: ML holds V_DD above any legal reference
            return true;
        }
        if self.c_over_gts.is_infinite() {
            return true; // M_eval cut off
        }
        if ln_sense >= self.ln_vdd {
            return false; // reference above the precharged rail
        }
        // decision: m · g_row·(1+ε) < budget, ε ~ N(0, σ_g_eval)
        let budget = self.c_over_gts * (self.ln_vdd - ln_sense);
        let base = (m as f64) * g_row_factor;
        // fast path: rows further than 6σ from the boundary decide
        // deterministically (P(flip) < 1e-9) without burning a gaussian —
        // only metastable-band rows pay for the noise draw
        let band = 6.0 * self.sigma_g * base;
        if base + band < budget {
            return true;
        }
        if base - band > budget {
            return false;
        }
        let g_rel = base * (1.0 + rng.normal(0.0, self.sigma_g)).max(0.0);
        g_rel < budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> MatchlineModel {
        MatchlineModel::new(256, Pvt::nominal())
    }

    #[test]
    fn vml_monotone_decreasing_in_time_and_mismatches() {
        let m = model();
        let v = Voltages::new(0.8, 0.9, 1.0);
        assert!(m.v_ml(4, 1e-9, &v) > m.v_ml(4, 2e-9, &v));
        assert!(m.v_ml(2, 1e-9, &v) > m.v_ml(8, 1e-9, &v));
        assert_eq!(m.v_ml(0, 5e-9, &v), k::V_DD);
    }

    #[test]
    fn tolerance_decision_consistency() {
        // fires_nominal must agree with m <= hd_tolerance away from boundary
        let mm = model();
        for v in [
            Voltages::new(0.8, 0.9, 1.1),
            Voltages::new(0.65, 0.5, 0.9),
            Voltages::new(1.1, 1.1, 0.7),
        ] {
            let tol = mm.hd_tolerance(&v);
            for m in 0..=256u32 {
                if (m as f64 - tol).abs() < 1e-6 {
                    continue;
                }
                let want = (m as f64) <= tol;
                assert_eq!(
                    mm.fires_nominal(m, &v, &RowVariation::nominal()),
                    want,
                    "m={m} tol={tol} v={v:?}"
                );
            }
        }
    }

    #[test]
    fn exact_setting_zero_tolerance() {
        let mm = model();
        let v = Voltages::exact();
        assert!(mm.fires_nominal(0, &v, &RowVariation::nominal()));
        assert!(!mm.fires_nominal(1, &v, &RowVariation::nominal()));
    }

    #[test]
    fn knob_monotonicity() {
        let mm = model();
        let base = Voltages::new(0.9, 0.8, 0.9);
        let t0 = mm.hd_tolerance(&base);
        assert!(mm.hd_tolerance(&Voltages { vref: 0.8, ..base }) > t0);
        assert!(mm.hd_tolerance(&Voltages { veval: 0.6, ..base }) > t0);
        assert!(mm.hd_tolerance(&Voltages { vst: 1.1, ..base }) > t0);
    }

    #[test]
    fn noisy_fires_converges_to_nominal_majority() {
        // far from the boundary, noise almost never flips the decision
        let mm = model();
        let v = Voltages::new(0.8, 0.7, 1.0);
        let tol = mm.hd_tolerance(&v);
        let var = RowVariation::nominal();
        let mut rng = Rng::new(9, 9);
        let m_low = (tol * 0.5) as u32;
        let m_high = ((tol * 2.0) as u32).min(256);
        let mut low_fires = 0;
        let mut high_fires = 0;
        for _ in 0..500 {
            if mm.fires(m_low, &v, &var, &mut rng) {
                low_fires += 1;
            }
            if mm.fires(m_high, &v, &var, &mut rng) {
                high_fires += 1;
            }
        }
        assert!(low_fires > 480, "{low_fires}");
        assert!(high_fires < 20, "{high_fires}");
    }

    #[test]
    fn boundary_is_stochastic() {
        // near the threshold there must be a metastable band: some m whose
        // fire probability is neither 0 nor 1 under per-evaluation noise
        // pick a mid-range tolerance (~32): the band width scales with m·σ,
        // so sub-bit noise at tol≈10 is physical, not a bug
        let mm = model();
        let v = Voltages::new(0.7, 0.45, 1.1);
        let tol = mm.hd_tolerance(&v);
        assert!(tol > 20.0 && tol < 60.0, "probe point moved: {tol}");
        let var = RowVariation::nominal();
        let mut rng = Rng::new(5, 5);
        let lo = (tol as u32).saturating_sub(3);
        let hi = (tol as u32) + 3;
        let mut stochastic = 0;
        for m in lo..=hi {
            let fires = (0..500).filter(|_| mm.fires(m, &v, &var, &mut rng)).count();
            if (10..490).contains(&fires) {
                stochastic += 1;
            }
        }
        assert!(stochastic >= 1, "no metastable band around tol={tol}");
    }

    #[test]
    fn fires_cached_identical_to_fires_including_draw_positions() {
        // the cached-threshold entry point is the same decision (and the
        // same RNG consumption) as the convenience wrapper
        let mm = model();
        let v = Voltages::new(0.7, 0.45, 1.1);
        let mut rng = Rng::new(21, 2);
        for trial in 0..200 {
            let var = RowVariation::draw(&mut rng);
            let m = (trial % 64) as u32;
            let cycle = mm.begin_cycle(&v, &mut rng);
            let mut ra = rng.clone();
            let mut rb = rng.clone();
            let a = cycle.fires(m, &var, &mut ra);
            let b = cycle.fires_cached(
                m,
                var.g_row_factor,
                (v.vref + var.mlsa_offset).ln(),
                &mut rb,
            );
            assert_eq!(a, b, "trial {trial} m={m}");
            assert_eq!(format!("{ra:?}"), format!("{rb:?}"), "draw count diverged");
        }
    }

    #[test]
    fn trace_shape() {
        let mm = model();
        let v = Voltages::new(0.8, 0.9, 1.0);
        let tr = mm.trace(8, 4e-9, 33, &v);
        assert_eq!(tr.len(), 33);
        assert_eq!(tr[0].1, k::V_DD);
        assert!(tr.last().unwrap().1 < tr[0].1);
    }

    #[test]
    fn row_variation_draw_reasonable() {
        let mut rng = Rng::new(1, 2);
        for _ in 0..100 {
            let v = RowVariation::draw(&mut rng);
            assert!(v.g_row_factor > 0.5 && v.g_row_factor < 1.5);
            assert!(v.mlsa_offset.abs() < 0.05);
        }
    }
}
