//! Analog substrate: the transistor/matchline/MLSA/DAC circuit models the
//! 65 nm silicon is replaced with (DESIGN.md §1, §4).

pub mod constants;
pub mod dac;
pub mod matchline;
pub mod transistor;

pub use dac::{VoltageDac, VoltageRails};
pub use matchline::{MatchlineModel, RowVariation, SearchCycle, Voltages};
pub use transistor::Pvt;
