//! Voltage-DAC model: the three user-configurable sources are not ideal —
//! they quantize to the DAC step, take time to settle after retuning, and
//! carry a small static error.  The accelerator's batching policy (paper
//! §V-B) exists precisely because retuning is "not an immediate operation".

use super::constants as k;
use super::matchline::Voltages;
use crate::cam::faults::RailId;
use crate::util::rng::Rng;

/// Coarse DAC resolution [V] — 25 mV steps as in the paper's Table I grid.
pub const DAC_STEP: f64 = 0.025;
/// Fine trim resolution [V] — a 1 mV trim DAC rides on each rail (the
/// standard coarse+fine reference topology; bring-up needs sub-bit
/// tolerance placement at the 1024/2048-cell midpoints).
pub const DAC_FINE: f64 = 0.001;

/// A settable voltage source with settling latency and quantization.
#[derive(Clone, Debug)]
pub struct VoltageDac {
    target: f64,
    /// Static per-instance error (trimmed at production; small).
    offset: f64,
    /// Factory-trimmed value of `offset` — drift is measured against it.
    factory: f64,
    /// Stuck-code fault: the DAC no longer accepts new codes.
    stuck: bool,
    /// Number of retune events so far (for energy accounting).
    pub retune_count: u64,
}

impl VoltageDac {
    pub fn new(initial: f64, rng: &mut Rng) -> Self {
        // Static rail error after closed-loop bring-up trim: the raw DAC
        // offset (~2 mV sigma) is nulled by calibrating *through* the rail
        // (the achieved tolerance, not the programmed voltage, is what the
        // trim loop measures), leaving only the residual drift below.
        let offset = rng.normal(0.0, 0.0003);
        VoltageDac {
            target: quantize(initial),
            offset,
            factory: offset,
            stuck: false,
            retune_count: 0,
        }
    }

    /// Ideal (test) source with zero offset.
    pub fn ideal(initial: f64) -> Self {
        VoltageDac {
            target: quantize(initial),
            offset: 0.0,
            factory: 0.0,
            stuck: false,
            retune_count: 0,
        }
    }

    /// Program a new level. Returns the settle time [s] charged to the
    /// schedule (0 if the quantized level is unchanged).  A stuck DAC
    /// (`cam::faults::FaultKind::StuckDac`) ignores the request outright.
    pub fn set(&mut self, v: f64) -> f64 {
        if self.stuck {
            return 0.0;
        }
        let q = quantize(v);
        if (q - self.target).abs() < DAC_FINE / 4.0 {
            return 0.0;
        }
        self.target = q;
        self.retune_count += 1;
        k::T_RETUNE_SETTLE
    }

    /// The voltage actually delivered.
    pub fn value(&self) -> f64 {
        self.target + self.offset
    }

    /// Freeze the DAC at its current code (stuck-code fault injection).
    pub fn stick(&mut self) {
        self.stuck = true;
    }

    /// Release a stuck code — the repair models switching the rail onto
    /// its spare DAC leg (scrub escalation charges the settle elsewhere).
    pub fn unstick(&mut self) {
        self.stuck = false;
    }

    pub fn is_stuck(&self) -> bool {
        self.stuck
    }

    /// Walk the delivered level away from factory trim (drift fault).
    pub fn drift(&mut self, volts: f64) {
        self.offset += volts;
    }

    /// How far the static error has drifted from its factory trim [V].
    pub fn drift_from_factory(&self) -> f64 {
        self.offset - self.factory
    }

    /// Re-trim the static error back to factory (drift repair).  Returns
    /// the settle time [s] charged, 0 when already on trim.
    pub fn trim(&mut self) -> f64 {
        if (self.offset - self.factory).abs() < 1e-12 {
            return 0.0;
        }
        self.offset = self.factory;
        self.retune_count += 1;
        k::T_RETUNE_SETTLE
    }
}

/// Quantize to the fine (coarse + trim) DAC grid.  Exact rational
/// arithmetic — `round(1000 v)/1000` — avoids representation drift like
/// `48 × 0.025 = 1.2000000000000002`.
pub fn quantize(v: f64) -> f64 {
    (v * 1000.0).round() / 1000.0
}

/// Quantize to the coarse 25 mV grid (calibration's outer search).
pub fn quantize_coarse(v: f64) -> f64 {
    (v * 40.0).round() / 40.0
}

/// The triple of sources driving (V_ref, V_eval, V_st).
#[derive(Clone, Debug)]
pub struct VoltageRails {
    pub vref: VoltageDac,
    pub veval: VoltageDac,
    pub vst: VoltageDac,
}

impl VoltageRails {
    pub fn new(init: Voltages, rng: &mut Rng) -> Self {
        VoltageRails {
            vref: VoltageDac::new(init.vref, rng),
            veval: VoltageDac::new(init.veval, rng),
            vst: VoltageDac::new(init.vst, rng),
        }
    }

    pub fn ideal(init: Voltages) -> Self {
        VoltageRails {
            vref: VoltageDac::ideal(init.vref),
            veval: VoltageDac::ideal(init.veval),
            vst: VoltageDac::ideal(init.vst),
        }
    }

    /// Retune all three rails; returns the total settle time [s]
    /// (rails settle in parallel → max, not sum).
    pub fn retune(&mut self, v: Voltages) -> f64 {
        let a = self.vref.set(v.vref);
        let b = self.veval.set(v.veval);
        let c = self.vst.set(v.vst);
        a.max(b).max(c)
    }

    /// The voltages the array actually sees.
    pub fn delivered(&self) -> Voltages {
        Voltages::new(self.vref.value(), self.veval.value(), self.vst.value())
    }

    pub fn total_retunes(&self) -> u64 {
        self.vref.retune_count + self.veval.retune_count + self.vst.retune_count
    }

    fn rail_mut(&mut self, rail: RailId) -> &mut VoltageDac {
        match rail {
            RailId::Vref => &mut self.vref,
            RailId::Veval => &mut self.veval,
            RailId::Vst => &mut self.vst,
        }
    }

    /// Freeze one rail's DAC at its current code (fault injection).
    pub fn stick(&mut self, rail: RailId) {
        self.rail_mut(rail).stick();
    }

    /// Drift one rail's delivered level by `volts` (fault injection).
    pub fn drift(&mut self, rail: RailId, volts: f64) {
        self.rail_mut(rail).drift(volts);
    }

    /// Any rail frozen by a stuck-code fault?
    pub fn any_stuck(&self) -> bool {
        self.vref.is_stuck() || self.veval.is_stuck() || self.vst.is_stuck()
    }

    /// Release every stuck rail (the spare-DAC-leg repair; the caller
    /// re-parks the rails so the next retune lands the correct codes).
    pub fn unstick_all(&mut self) {
        self.vref.unstick();
        self.veval.unstick();
        self.vst.unstick();
    }

    /// Largest absolute drift from factory trim across the rails [V] —
    /// the scrub pass's drift detector (healthy rails report 0.0).
    pub fn max_drift(&self) -> f64 {
        self.vref
            .drift_from_factory()
            .abs()
            .max(self.veval.drift_from_factory().abs())
            .max(self.vst.drift_from_factory().abs())
    }

    /// Re-trim every rail back to its factory offset; rails settle in
    /// parallel → max settle time [s], 0 when nothing had drifted.
    pub fn trim_all(&mut self) -> f64 {
        let a = self.vref.trim();
        let b = self.veval.trim();
        let c = self.vst.trim();
        a.max(b).max(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_grid() {
        // fine (1 mV) grid
        assert_eq!(quantize(0.7512), 0.751);
        assert_eq!(quantize(0.7636), 0.764);
        assert_eq!(quantize(1.2), 1.2);
        // coarse (25 mV) grid
        assert_eq!(quantize_coarse(0.751), 0.75);
        assert_eq!(quantize_coarse(0.763), 0.775);
        assert_eq!(quantize_coarse(1.2), 1.2);
    }

    #[test]
    fn set_charges_settle_once() {
        let mut d = VoltageDac::ideal(1.2);
        assert_eq!(d.set(1.2), 0.0); // no-op
        assert!(d.set(0.8) > 0.0);
        assert_eq!(d.set(0.8), 0.0); // already there
        assert_eq!(d.retune_count, 1);
    }

    #[test]
    fn rails_settle_in_parallel() {
        let mut r = VoltageRails::ideal(Voltages::exact());
        let t = r.retune(Voltages::new(0.8, 0.9, 1.0));
        assert_eq!(t, k::T_RETUNE_SETTLE);
        assert_eq!(r.total_retunes(), 3);
        let d = r.delivered();
        assert!((d.vref - 0.8).abs() < 1e-12);
    }

    #[test]
    fn stuck_dac_ignores_retunes_until_released() {
        let mut d = VoltageDac::ideal(1.2);
        d.stick();
        assert_eq!(d.set(0.8), 0.0);
        assert_eq!(d.retune_count, 0);
        assert!((d.value() - 1.2).abs() < 1e-12, "frozen at the old code");
        d.unstick();
        assert!(d.set(0.8) > 0.0);
        assert!((d.value() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn drift_is_measured_and_trimmed_against_factory() {
        let mut rng = Rng::new(7, 7);
        let mut r = VoltageRails::new(Voltages::exact(), &mut rng);
        assert_eq!(r.max_drift(), 0.0, "fresh rails sit on factory trim");
        let before = r.delivered();
        r.drift(RailId::Vref, 0.004);
        assert!((r.max_drift() - 0.004).abs() < 1e-12);
        assert!(r.trim_all() > 0.0);
        assert_eq!(r.max_drift(), 0.0);
        let after = r.delivered();
        assert!((after.vref - before.vref).abs() < 1e-12, "trim restores");
        assert_eq!(r.trim_all(), 0.0, "already on trim");
    }

    #[test]
    fn delivered_includes_offset() {
        let mut rng = Rng::new(3, 3);
        let r = VoltageRails::new(Voltages::new(0.8, 0.9, 1.0), &mut rng);
        let d = r.delivered();
        assert!((d.vref - 0.8).abs() < 0.01);
    }
}
