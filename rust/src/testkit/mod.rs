//! Property-based testing mini-framework (proptest is unavailable offline;
//! DESIGN.md §1).
//!
//! ```no_run
//! use picbnn::testkit::{forall, prop_assert};
//! forall(100, 42, |g| {
//!     let n = g.usize_in(1, 64);
//!     let v = g.vec_i32(n, -5, 5);
//!     let sum: i32 = v.iter().sum();
//!     prop_assert(sum.abs() <= 5 * n as i32, format!("sum {sum}"))
//! });
//! ```
//!
//! On failure, the failing case index and seed are reported so the case can
//! be replayed deterministically with [`replay`].

use crate::util::rng::Rng;

/// Input generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    /// Human-readable log of drawn values for failure reports.
    log: Vec<String>,
}

impl Gen {
    fn new(seed: u64, case: u64) -> Self {
        Gen {
            rng: Rng::new(seed, case.wrapping_add(1)),
            log: Vec::new(),
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range_u64(lo as u64, hi as u64) as usize;
        self.log.push(format!("usize {v}"));
        v
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        let span = (hi - lo) as u64;
        let v = lo + self.rng.below(span + 1) as i64;
        self.log.push(format!("i64 {v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.range_f64(lo, hi);
        self.log.push(format!("f64 {v}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.log.push(format!("bool {v}"));
        v
    }

    pub fn vec_i32(&mut self, len: usize, lo: i32, hi: i32) -> Vec<i32> {
        let v: Vec<i32> = (0..len)
            .map(|_| self.i64_in(lo as i64, hi as i64) as i32)
            .collect();
        v
    }

    /// A ±1 vector of the given length.
    pub fn pm1_vec(&mut self, len: usize) -> Vec<i8> {
        (0..len)
            .map(|_| if self.rng.chance(0.5) { 1 } else { -1 })
            .collect()
    }

    /// Raw access for custom draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Property outcome: Err carries the failure message.
pub type PropResult = Result<(), String>;

/// Assertion helper for property bodies.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `prop` over `cases` random inputs; panics (with seed + case index +
/// draw log) on the first failure.
pub fn forall<F>(cases: u64, seed: u64, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    for case in 0..cases {
        let mut g = Gen::new(seed, case);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed at case {case} (seed {seed}): {msg}\n  draws: [{}]\n  replay with testkit::replay(seed={seed}, case={case}, prop)",
                g.log.join(", ")
            );
        }
    }
}

/// Re-run a single failing case deterministically.
pub fn replay<F>(seed: u64, case: u64, prop: F) -> PropResult
where
    F: Fn(&mut Gen) -> PropResult,
{
    let mut g = Gen::new(seed, case);
    prop(&mut g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(50, 1, |g| {
            let a = g.i64_in(-100, 100);
            prop_assert(a >= -100 && a <= 100, "range")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        forall(50, 2, |g| {
            let a = g.usize_in(0, 10);
            prop_assert(a < 10, format!("drew {a}"))
        });
    }

    #[test]
    fn replay_reproduces_draws() {
        // record draws from case 0, then assert replay sees the same
        let seen = std::cell::Cell::new(None);
        forall(8, 3, |g| {
            let v = g.usize_in(0, 1_000_000);
            if seen.get().is_none() {
                seen.set(Some(v));
            }
            Ok(())
        });
        let first = seen.get().unwrap();
        replay(3, 0, |g| {
            prop_assert(g.usize_in(0, 1_000_000) == first, "replay mismatch")
        })
        .unwrap();
    }

    #[test]
    fn pm1_vec_is_pm1() {
        forall(20, 4, |g| {
            let n = g.usize_in(0, 100);
            let v = g.pm1_vec(n);
            prop_assert(v.iter().all(|&x| x == 1 || x == -1), "pm1")
        });
    }
}
