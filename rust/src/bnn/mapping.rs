//! Weight → CAM row materialisation: turns a [`MappedLayer`] into the
//! physical bit patterns programmed into the array (weights + pad cells),
//! and the query extension that drives the searchlines.
//!
//! A neuron's segment row of `seg_width` cells holds its payload weight
//! bits followed by pad cells.  Pads encode the batch-norm constant: for a
//! segment with P pads and q mismatching pads, the first (P − q) pads are
//! programmed to match the (fixed) pad drive pattern and the remaining q to
//! mismatch it, contributing dot_pad = P − 2q to the ±1 dot product
//! (paper §IV: "C_j = +12 is represented by 12 matching CAM cells").
//!
//! The pad drive pattern is all-'1' (+1 on every pad searchline), so a
//! matching pad stores '1' and a mismatching pad stores '0'.

use crate::util::bitops::{copy_bits, words_for, BitVec};

use super::model::MappedLayer;

/// Physical row image for (layer, segment, neuron).
pub fn program_row(layer: &MappedLayer, seg: usize, neuron: usize) -> BitVec {
    let lo = layer.seg_bounds[seg];
    let hi = layer.seg_bounds[seg + 1];
    let payload = hi - lo;
    let pads = layer.seg_width - payload;
    let q = layer.q[seg][neuron] as usize;
    debug_assert!(q <= pads);
    let mut row = BitVec::zeros(layer.seg_width);
    // payload: the neuron's weight bits for this segment's input slice
    // (word-level copy; the weights row is a packed BitVec)
    let wrow = layer.weights.row(neuron);
    row.write_range(0, &wrow, lo, payload);
    // pads: (pads - q) matching ('1' vs all-ones drive), q mismatching ('0')
    for p in 0..pads - q {
        row.set(payload + p, true);
    }
    row
}

/// Query image for one segment: the activation slice followed by the
/// all-'1' pad drive.
pub fn segment_query(layer: &MappedLayer, seg: usize, activations: &BitVec) -> BitVec {
    debug_assert_eq!(activations.len(), layer.n_in());
    segment_query_wide(layer, seg, activations, layer.seg_width)
}

/// `segment_query` extended directly to an arbitrary physical word width
/// (spare columns drive '1'); one allocation, word-level copies.
pub fn segment_query_wide(
    layer: &MappedLayer,
    seg: usize,
    activations: &BitVec,
    width: usize,
) -> BitVec {
    debug_assert!(width >= layer.seg_width);
    let lo = layer.seg_bounds[seg];
    let hi = layer.seg_bounds[seg + 1];
    let payload = hi - lo;
    let mut q = BitVec::ones(width);
    q.write_range(0, activations, lo, payload);
    q
}

/// [`segment_query_wide`] packed straight into a reusable query-block
/// row — the allocation-free twin of the `BitVec`-returning builder,
/// bit-identical words by construction.  `acts` is the packed activation
/// vector (e.g. one row of a batch `BitMatrix`); `out` is one row of a
/// query block with `width` logical columns (`words_for(width)` words).
/// Spare columns drive '1' and the tail bits of the last word stay
/// clear, exactly as `BitVec::ones(width)` would leave them.
pub fn pack_segment_query(
    layer: &MappedLayer,
    seg: usize,
    acts: &[u64],
    out: &mut [u64],
    width: usize,
) {
    debug_assert!(width >= layer.seg_width);
    debug_assert_eq!(out.len(), words_for(width));
    let lo = layer.seg_bounds[seg];
    let payload = layer.seg_bounds[seg + 1] - lo;
    for w in out.iter_mut() {
        *w = !0u64;
    }
    let tail = width % 64;
    if tail != 0 {
        if let Some(last) = out.last_mut() {
            *last &= (1u64 << tail) - 1;
        }
    }
    copy_bits(acts, lo, payload, out, 0);
}

/// The expected mismatch count of (row, query) for a neuron segment:
/// HD(weights_slice, x_slice) + q — the identity the CAM realises.
pub fn expected_mismatches(
    layer: &MappedLayer,
    seg: usize,
    neuron: usize,
    activations: &BitVec,
) -> u32 {
    let lo = layer.seg_bounds[seg];
    let hi = layer.seg_bounds[seg + 1];
    let mut hd = 0u32;
    for c in lo..hi {
        if layer.weights.get(neuron, c) != activations.get(c) {
            hd += 1;
        }
    }
    hd + layer.q[seg][neuron] as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::util::bitops::hamming_words;
    use crate::util::rng::Rng;

    fn rand_act(n: usize, seed: u64) -> BitVec {
        let mut rng = Rng::new(seed, 0);
        let mut v = BitVec::zeros(n);
        for i in 0..n {
            v.set(i, rng.chance(0.5));
        }
        v
    }

    #[test]
    fn row_query_mismatch_identity() {
        // HD(program_row, segment_query) == HD(w_slice, x_slice) + q
        let m = tiny_model(100, 16, 4, 9);
        let l = &m.layers[0];
        let x = rand_act(100, 3);
        for neuron in 0..l.n_out() {
            let row = program_row(l, 0, neuron);
            let q = segment_query(l, 0, &x);
            let got = hamming_words(row.words(), q.words());
            let want = expected_mismatches(l, 0, neuron, &x);
            assert_eq!(got, want, "neuron {neuron}");
        }
    }

    #[test]
    fn pad_encoding_realises_c() {
        // dot(row, query) over the pad region == pads - 2q
        let m = tiny_model(100, 16, 4, 10);
        let l = &m.layers[0];
        let payload = l.seg_payload(0);
        let pads = l.seg_pads(0);
        for neuron in 0..4 {
            let row = program_row(l, 0, neuron);
            let matching = (payload..payload + pads).filter(|&i| row.get(i)).count() as i32;
            let mismatching = pads as i32 - matching;
            assert_eq!(matching - mismatching, l.c_effective(0, neuron));
        }
    }

    #[test]
    fn zero_hd_when_weights_equal_activations_and_q_zero() {
        let mut m = tiny_model(64, 8, 4, 11);
        let l = &mut m.layers[0];
        l.q[0].iter_mut().for_each(|q| *q = 0);
        let x = l.weights.row(2); // activations identical to neuron 2 weights
        let row = program_row(l, 0, 2);
        let query = segment_query(l, 0, &x);
        assert_eq!(hamming_words(row.words(), query.words()), 0);
    }

    #[test]
    fn pack_segment_query_matches_the_allocating_builder() {
        // the packed twin must produce bit-identical words, including the
        // spare-column drive and the masked tail of the last word
        use crate::util::bitops::words_for;
        let m = tiny_model(100, 16, 4, 12);
        for (li, l) in m.layers.iter().enumerate() {
            let x = rand_act(l.n_in(), 40 + li as u64);
            for width in [l.seg_width, l.seg_width + 37, 2 * l.seg_width] {
                for seg in 0..l.n_seg() {
                    let want = segment_query_wide(l, seg, &x, width);
                    let mut out = vec![0xDEAD_BEEF_DEAD_BEEFu64; words_for(width)];
                    pack_segment_query(l, seg, x.words(), &mut out, width);
                    assert_eq!(out, want.words(), "layer {li} seg {seg} width {width}");
                }
            }
        }
    }

    #[test]
    fn segmented_layer_covers_all_inputs() {
        // construct a 2-segment layer manually and check query slicing
        use crate::util::bitops::BitMatrix;
        let n_in = 150;
        let width = 128;
        let rows: Vec<BitVec> = (0..3).map(|_| BitVec::ones(n_in)).collect();
        let l = MappedLayer {
            weights: BitMatrix::from_rows(&rows),
            q: vec![vec![0; 3], vec![0; 3]],
            seg_bounds: vec![0, 75, 150],
            seg_width: width,
        };
        l.validate().unwrap();
        let x = BitVec::ones(n_in);
        for s in 0..2 {
            let row = program_row(&l, s, 0);
            let q = segment_query(&l, s, &x);
            assert_eq!(hamming_words(row.words(), q.words()), 0);
        }
    }
}
