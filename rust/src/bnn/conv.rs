//! Binary convolution on the CAM — the extension the paper's introduction
//! motivates ("in a convolutional BNN, the first layer is typically
//! implemented with full precision"): PiC-BNN's pad-cell BN encoding makes
//! the conv layer end-to-end binary too.
//!
//! Mapping: a k×k binary filter is one CAM row (k² payload bits + BN pad
//! cells); an image patch is one search query; all filters evaluate in
//! parallel rows per search, so a conv layer costs one search per patch —
//! im2col where the "matrix multiply" is the matchline.

use crate::util::bitops::BitVec;

use super::model::MappedLayer;

/// Patch geometry of a single-channel binary conv layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PatchSpec {
    pub img_h: usize,
    pub img_w: usize,
    pub k: usize,
    pub stride: usize,
}

impl PatchSpec {
    pub fn out_h(&self) -> usize {
        (self.img_h - self.k) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.img_w - self.k) / self.stride + 1
    }

    pub fn n_patches(&self) -> usize {
        self.out_h() * self.out_w()
    }

    pub fn patch_bits(&self) -> usize {
        self.k * self.k
    }

    /// im2col: extract all patches of a packed ±1 image, row-major.
    pub fn extract_patches(&self, image: &BitVec) -> Vec<BitVec> {
        assert_eq!(image.len(), self.img_h * self.img_w, "image size");
        let mut out = Vec::with_capacity(self.n_patches());
        for oy in 0..self.out_h() {
            for ox in 0..self.out_w() {
                let mut p = BitVec::zeros(self.patch_bits());
                for dy in 0..self.k {
                    let src_row = (oy * self.stride + dy) * self.img_w + ox * self.stride;
                    // word-level copy of one patch row (k bits)
                    p.write_range(dy * self.k, image, src_row, self.k);
                }
                out.push(p);
            }
        }
        out
    }
}

/// Digital reference for a CAM-mapped binary conv layer: feature map bit
/// (filter f, patch p) = [ dot(w_f, patch_p) + C_f ≥ 0 ], flattened
/// filter-major (all patches of filter 0, then filter 1, …).
pub fn digital_conv(layer: &MappedLayer, spec: &PatchSpec, image: &BitVec) -> BitVec {
    assert_eq!(layer.n_in(), spec.patch_bits(), "filter size");
    assert_eq!(layer.n_seg(), 1, "conv filters fit one word");
    let patches = spec.extract_patches(image);
    let mut out = BitVec::zeros(layer.n_out() * patches.len());
    for (pi, patch) in patches.iter().enumerate() {
        let h = super::infer::digital_hidden(layer, patch);
        for f in 0..layer.n_out() {
            if h.get(f) {
                out.set(f * patches.len() + pi, true);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bitops::BitMatrix;
    use crate::util::rng::Rng;

    fn rand_bits(n: usize, rng: &mut Rng) -> BitVec {
        let mut v = BitVec::zeros(n);
        for i in 0..n {
            v.set(i, rng.chance(0.5));
        }
        v
    }

    #[test]
    fn patch_geometry() {
        let s = PatchSpec {
            img_h: 28,
            img_w: 28,
            k: 5,
            stride: 3,
        };
        assert_eq!(s.out_h(), 8);
        assert_eq!(s.out_w(), 8);
        assert_eq!(s.n_patches(), 64);
        assert_eq!(s.patch_bits(), 25);
    }

    #[test]
    fn patches_match_naive_extraction() {
        let s = PatchSpec {
            img_h: 12,
            img_w: 10,
            k: 3,
            stride: 2,
        };
        let mut rng = Rng::new(4, 4);
        let img = rand_bits(120, &mut rng);
        let patches = s.extract_patches(&img);
        assert_eq!(patches.len(), s.n_patches());
        for (pi, p) in patches.iter().enumerate() {
            let oy = pi / s.out_w();
            let ox = pi % s.out_w();
            for dy in 0..3 {
                for dx in 0..3 {
                    let want = img.get((oy * 2 + dy) * 10 + ox * 2 + dx);
                    assert_eq!(p.get(dy * 3 + dx), want, "patch {pi} ({dy},{dx})");
                }
            }
        }
    }

    #[test]
    fn conv_layer_on_cam_matches_digital_reference() {
        use crate::accel::VoltageController;
        use crate::analog::Pvt;
        use crate::bnn::mapping::{program_row, segment_query};
        use crate::cam::{CamArray, CamConfig};

        let spec = PatchSpec {
            img_h: 16,
            img_w: 16,
            k: 5,
            stride: 3,
        };
        let mut rng = Rng::new(11, 3);
        // 8 random binary filters mapped with random (even) BN constants
        let n_f = 8;
        let filters: Vec<BitVec> = (0..n_f).map(|_| rand_bits(25, &mut rng)).collect();
        let width = 512usize;
        let pads = width - 25;
        let layer = MappedLayer {
            weights: BitMatrix::from_rows(&filters),
            q: vec![(0..n_f)
                .map(|_| (pads / 2) as i32 + rng.range_u64(0, 10) as i32 - 5)
                .collect()],
            seg_bounds: vec![0, 25],
            seg_width: width,
        };
        layer.validate().unwrap();

        // the device: program filter rows, midpoint voltages, one search
        // per patch
        let mut cam = CamArray::nominal(CamConfig::W512x256);
        for (f, _) in filters.iter().enumerate() {
            cam.write_row(f, &program_row(&layer, 0, f));
        }
        let ctl = VoltageController::new(width, Pvt::nominal());
        let mid = ctl
            .calibrate((width / 2) as u32, 2.0)
            .unwrap_or_else(|| ctl.calibrate_best((width / 2) as u32));
        cam.set_voltages(mid.voltages);

        let image = rand_bits(256, &mut rng);
        let want = digital_conv(&layer, &spec, &image);
        let patches = spec.extract_patches(&image);
        let mut got = BitVec::zeros(n_f * patches.len());
        for (pi, patch) in patches.iter().enumerate() {
            let q = segment_query(&layer, 0, patch);
            let fires = cam.search(&q);
            for f in 0..n_f {
                if fires[f] {
                    got.set(f * patches.len() + pi, true);
                }
            }
        }
        assert_eq!(got, want, "CAM conv vs digital reference");
        // cost: one search per patch regardless of filter count
        assert_eq!(cam.events.searches, patches.len() as u64);
    }
}
