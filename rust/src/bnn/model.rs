//! Mapped-model container + loader for the `PICBNN1` export format written
//! by `python/compile/train.py::write_weights_bin`.
//!
//! Layout (little-endian):
//! ```text
//! magic   8 B   "PICBNN1\0"
//! u32           n_layers
//! per layer:
//!   u32 × 4     n_out, n_in, n_seg, seg_width
//!   u32 × (n_seg+1)        seg_bounds (payload slice bounds into the input)
//!   i32 × (n_seg × n_out)  q — mismatching-pad count per (segment, neuron)
//!   u64 × (n_out × ceil(n_in/64))  packed ±1 weights (bit set = +1)
//! u32           schedule_len
//! i32 × len     HD-threshold schedule (Algorithm 1)
//! ```

use std::io::Read;
use std::path::Path;

use crate::util::bitops::{words_for, BitMatrix};

/// One binary layer mapped onto CAM rows (mirror of python `LayerMap`).
#[derive(Clone, Debug)]
pub struct MappedLayer {
    /// Packed ±1 weights, n_out rows × n_in bits.
    pub weights: BitMatrix,
    /// Mismatching-pad counts, `q[seg][neuron]`.
    pub q: Vec<Vec<i32>>,
    /// Payload slice bounds: segment s covers input bits
    /// `seg_bounds[s]..seg_bounds[s+1]`.
    pub seg_bounds: Vec<usize>,
    /// CAM word width the layer's rows are programmed at.
    pub seg_width: usize,
}

impl MappedLayer {
    pub fn n_out(&self) -> usize {
        self.weights.rows()
    }

    pub fn n_in(&self) -> usize {
        self.weights.cols()
    }

    pub fn n_seg(&self) -> usize {
        self.seg_bounds.len() - 1
    }

    pub fn seg_payload(&self, s: usize) -> usize {
        self.seg_bounds[s + 1] - self.seg_bounds[s]
    }

    pub fn seg_pads(&self, s: usize) -> usize {
        self.seg_width - self.seg_payload(s)
    }

    /// The integer constant segment `s` realises for neuron `j`:
    /// dot_pad = pads − 2·q.
    pub fn c_effective(&self, s: usize, j: usize) -> i32 {
        self.seg_pads(s) as i32 - 2 * self.q[s][j]
    }

    /// Sanity-check structural invariants; returns an error description.
    pub fn validate(&self) -> Result<(), String> {
        if self.seg_bounds.first() != Some(&0) || self.seg_bounds.last() != Some(&self.n_in()) {
            return Err("seg_bounds must span [0, n_in]".into());
        }
        if self.q.len() != self.n_seg() {
            return Err("q segment count mismatch".into());
        }
        for s in 0..self.n_seg() {
            if self.seg_payload(s) > self.seg_width {
                return Err(format!("segment {s} payload exceeds word width"));
            }
            if self.q[s].len() != self.n_out() {
                return Err(format!("q[{s}] neuron count mismatch"));
            }
            for (j, &qv) in self.q[s].iter().enumerate() {
                if qv < 0 || qv as usize > self.seg_pads(s) {
                    return Err(format!("q[{s}][{j}]={qv} outside [0, pads]"));
                }
            }
        }
        Ok(())
    }
}

/// A fully mapped model: layers + the Algorithm-1 HD schedule.
#[derive(Clone, Debug)]
pub struct MappedModel {
    pub layers: Vec<MappedLayer>,
    /// HD-threshold sweep for the output layer ({0, 2, …, 64} in the paper).
    pub schedule: Vec<i32>,
}

impl MappedModel {
    pub fn n_in(&self) -> usize {
        self.layers[0].n_in()
    }

    pub fn n_classes(&self) -> usize {
        self.layers.last().unwrap().n_out()
    }

    /// Load from a `PICBNN1` file.
    pub fn load(path: impl AsRef<Path>) -> Result<MappedModel, String> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .map_err(|e| format!("open {}: {e}", path.as_ref().display()))?
            .read_to_end(&mut buf)
            .map_err(|e| e.to_string())?;
        Self::from_bytes(&buf)
    }

    pub fn from_bytes(buf: &[u8]) -> Result<MappedModel, String> {
        let mut c = Cursor { buf, pos: 0 };
        let magic = c.take(8)?;
        if magic != b"PICBNN1\x00" {
            return Err("bad magic (not a PICBNN1 file)".into());
        }
        let n_layers = c.u32()? as usize;
        if n_layers == 0 || n_layers > 16 {
            return Err(format!("implausible layer count {n_layers}"));
        }
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let n_out = c.u32()? as usize;
            let n_in = c.u32()? as usize;
            let n_seg = c.u32()? as usize;
            let seg_width = c.u32()? as usize;
            let mut seg_bounds = Vec::with_capacity(n_seg + 1);
            for _ in 0..=n_seg {
                seg_bounds.push(c.u32()? as usize);
            }
            let mut q = Vec::with_capacity(n_seg);
            for _ in 0..n_seg {
                let mut row = Vec::with_capacity(n_out);
                for _ in 0..n_out {
                    row.push(c.i32()?);
                }
                q.push(row);
            }
            let words = words_for(n_in);
            let mut data = Vec::with_capacity(n_out * words);
            for _ in 0..n_out * words {
                data.push(c.u64()?);
            }
            let layer = MappedLayer {
                weights: BitMatrix::from_words(data, n_out, n_in),
                q,
                seg_bounds,
                seg_width,
            };
            layer.validate()?;
            layers.push(layer);
        }
        let k = c.u32()? as usize;
        let mut schedule = Vec::with_capacity(k);
        for _ in 0..k {
            schedule.push(c.i32()?);
        }
        if c.pos != buf.len() {
            return Err(format!(
                "trailing {} bytes after schedule",
                buf.len() - c.pos
            ));
        }
        // layers must chain: layer[i].n_out == layer[i+1].n_in
        for w in layers.windows(2) {
            if w[0].n_out() != w[1].n_in() {
                return Err("layer dimension chain mismatch".into());
            }
        }
        Ok(MappedModel { layers, schedule })
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err(format!("truncated file at byte {}", self.pos));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32, String> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
pub(crate) mod test_fixtures {
    use super::*;
    use crate::util::bitops::BitVec;
    use crate::util::rng::Rng;

    /// Build a small random mapped model in memory (n_in -> h -> n_cls).
    pub fn tiny_model(n_in: usize, h: usize, n_cls: usize, seed: u64) -> MappedModel {
        let mut rng = Rng::new(seed, 77);
        let mk_layer = |rng: &mut Rng, n_out: usize, n_in: usize, width: usize| {
            let rows: Vec<BitVec> = (0..n_out)
                .map(|_| {
                    let mut v = BitVec::zeros(n_in);
                    for i in 0..n_in {
                        v.set(i, rng.chance(0.5));
                    }
                    v
                })
                .collect();
            let pads = width - n_in;
            let q = vec![(0..n_out)
                .map(|_| rng.range_u64(0, pads as u64) as i32)
                .collect()];
            MappedLayer {
                weights: BitMatrix::from_rows(&rows),
                q,
                seg_bounds: vec![0, n_in],
                seg_width: width,
            }
        };
        let l1 = mk_layer(&mut rng, h, n_in, (n_in + 64).next_power_of_two().max(128));
        let l2 = mk_layer(&mut rng, n_cls, h, (h + 64).next_power_of_two().max(128));
        MappedModel {
            layers: vec![l1, l2],
            schedule: (0..=64).step_by(2).collect(),
        }
    }

    /// Serialize a model back to the PICBNN1 byte format (round-trip tests).
    pub fn to_bytes(m: &MappedModel) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(b"PICBNN1\x00");
        out.extend_from_slice(&(m.layers.len() as u32).to_le_bytes());
        for l in &m.layers {
            for v in [
                l.n_out() as u32,
                l.n_in() as u32,
                l.n_seg() as u32,
                l.seg_width as u32,
            ] {
                out.extend_from_slice(&v.to_le_bytes());
            }
            for &b in &l.seg_bounds {
                out.extend_from_slice(&(b as u32).to_le_bytes());
            }
            for seg in &l.q {
                for &qv in seg {
                    out.extend_from_slice(&qv.to_le_bytes());
                }
            }
            for r in 0..l.n_out() {
                for &w in l.weights.row_words(r) {
                    out.extend_from_slice(&w.to_le_bytes());
                }
            }
        }
        out.extend_from_slice(&(m.schedule.len() as u32).to_le_bytes());
        for &s in &m.schedule {
            out.extend_from_slice(&s.to_le_bytes());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::test_fixtures::{tiny_model, to_bytes};
    use super::*;

    #[test]
    fn roundtrip_bytes() {
        let m = tiny_model(100, 16, 4, 1);
        let bytes = to_bytes(&m);
        let m2 = MappedModel::from_bytes(&bytes).unwrap();
        assert_eq!(m2.layers.len(), 2);
        assert_eq!(m2.n_in(), 100);
        assert_eq!(m2.n_classes(), 4);
        assert_eq!(m2.schedule, m.schedule);
        for (a, b) in m.layers.iter().zip(&m2.layers) {
            assert_eq!(a.seg_bounds, b.seg_bounds);
            assert_eq!(a.q, b.q);
            for r in 0..a.n_out() {
                assert_eq!(a.weights.row_words(r), b.weights.row_words(r));
            }
        }
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(MappedModel::from_bytes(b"NOTMAGIC\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let bytes = to_bytes(&tiny_model(50, 8, 3, 2));
        for cut in [8, 13, 40, bytes.len() - 2] {
            assert!(
                MappedModel::from_bytes(&bytes[..cut]).is_err(),
                "cut at {cut}"
            );
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        let mut bytes = to_bytes(&tiny_model(50, 8, 3, 2));
        bytes.push(0);
        assert!(MappedModel::from_bytes(&bytes).is_err());
    }

    #[test]
    fn c_effective_sign() {
        let m = tiny_model(100, 16, 4, 3);
        let l = &m.layers[0];
        for j in 0..l.n_out() {
            let c = l.c_effective(0, j);
            assert!(c.abs() as usize <= l.seg_pads(0));
            assert_eq!(
                c,
                l.seg_pads(0) as i32 - 2 * l.q[0][j],
                "definition of pad encoding"
            );
        }
    }

    #[test]
    fn validate_catches_bad_q() {
        let mut m = tiny_model(100, 16, 4, 4);
        m.layers[0].q[0][0] = -1;
        assert!(m.layers[0].validate().is_err());
        m.layers[0].q[0][0] = m.layers[0].seg_pads(0) as i32 + 1;
        assert!(m.layers[0].validate().is_err());
    }
}
