//! BNN model layer: the mapped-model container/loader, the weight→row
//! materialisation, and the digital reference execution semantics.

pub mod conv;
pub mod infer;
pub mod mapping;
pub mod model;

pub use infer::{argmax_vote, digital_forward, sweep_votes, top_k};
pub use model::{MappedLayer, MappedModel};
