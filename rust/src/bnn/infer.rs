//! Reference (digital, in-memory-free) execution of a mapped model, and the
//! vote/prediction semantics shared by every backend.
//!
//! `digital_*` computes exactly what the nominal CAM computes — packed
//! XNOR-popcount, integer pad constants, midpoint thresholds, threshold-
//! sweep votes — but without the device simulation.  It is the bit-exact
//! oracle the CAM path (`accel::Pipeline`) and the PJRT path
//! (`runtime::InferEngine`) are both validated against.

use crate::util::bitops::BitVec;

use super::model::{MappedLayer, MappedModel};

/// Hidden-layer execution: per-segment midpoint threshold + majority.
///
/// Segment s of neuron j fires iff HD_w + q ≤ seg_width/2 (ties fire — the
/// MLSA convention); the neuron output is the majority of segment fires
/// (ties fire).  Single-segment layers reduce to sign(dot + C).
pub fn digital_hidden(layer: &MappedLayer, x: &BitVec) -> BitVec {
    let mut out = BitVec::zeros(layer.n_out());
    let half = layer.seg_width as u32 / 2;
    let n_seg = layer.n_seg();
    for j in 0..layer.n_out() {
        let mut fires = 0usize;
        for s in 0..n_seg {
            let m = super::mapping::expected_mismatches(layer, s, j, x);
            if m <= half {
                fires += 1;
            }
        }
        out.set(j, fires * 2 >= n_seg);
    }
    out
}

/// Output-layer HD per class: HD_w + q (single segment required).
pub fn digital_output_hd(layer: &MappedLayer, h: &BitVec) -> Vec<u32> {
    assert_eq!(layer.n_seg(), 1, "output layer must fit one CAM word");
    (0..layer.n_out())
        .map(|j| super::mapping::expected_mismatches(layer, 0, j, h))
        .collect()
}

/// Threshold-sweep vote counts: votes_c = #{τ ∈ schedule : hd_c ≤ τ}.
pub fn sweep_votes(hd: &[u32], schedule: &[i32]) -> Vec<u32> {
    hd.iter()
        .map(|&h| schedule.iter().filter(|&&t| h as i64 <= t as i64).count() as u32)
        .collect()
}

/// Argmax with lowest-class-index tie-break (the device has no secondary
/// comparison signal; ties resolve by priority-encoder order).
pub fn argmax_vote(votes: &[u32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in votes.iter().enumerate() {
        if v > votes[best] {
            best = i;
        }
    }
    best
}

/// Indices of the top-k vote counts (stable order: higher votes first,
/// lower class index wins ties).
pub fn top_k(votes: &[u32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..votes.len()).collect();
    idx.sort_by(|&a, &b| votes[b].cmp(&votes[a]).then(a.cmp(&b)));
    idx.truncate(k);
    idx
}

/// Full digital forward pass: (votes, prediction).
pub fn digital_forward(model: &MappedModel, x: &BitVec, schedule: &[i32]) -> (Vec<u32>, usize) {
    assert_eq!(x.len(), model.n_in());
    let mut act = x.clone();
    for layer in &model.layers[..model.layers.len() - 1] {
        act = digital_hidden(layer, &act);
    }
    let hd = digital_output_hd(model.layers.last().unwrap(), &act);
    let votes = sweep_votes(&hd, schedule);
    let pred = argmax_vote(&votes);
    (votes, pred)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::model::test_fixtures::tiny_model;
    use crate::testkit::{forall, prop_assert};
    use crate::util::rng::Rng;

    fn rand_act(n: usize, rng: &mut Rng) -> BitVec {
        let mut v = BitVec::zeros(n);
        for i in 0..n {
            v.set(i, rng.chance(0.5));
        }
        v
    }

    #[test]
    fn hidden_equals_sign_dot_plus_c() {
        // single-segment: fire iff dot + C >= 0 with ties firing
        forall(50, 21, |g| {
            let seed = g.usize_in(0, 1 << 30) as u64;
            let m = tiny_model(60, 12, 4, seed);
            let l = &m.layers[0];
            let mut rng = Rng::new(seed ^ 1, 5);
            let x = rand_act(60, &mut rng);
            let h = digital_hidden(l, &x);
            for j in 0..l.n_out() {
                let dot = l.weights.row(j).dot_pm1(&x);
                let want = dot + l.c_effective(0, j) >= 0;
                prop_assert(h.get(j) == want, format!("neuron {j}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn votes_monotone_decreasing_in_hd() {
        let schedule: Vec<i32> = (0..=64).step_by(2).collect();
        let hd: Vec<u32> = (0..200).collect();
        let votes = sweep_votes(&hd, &schedule);
        for w in votes.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert_eq!(votes[0], 33);
        assert_eq!(votes[64], 1); // hd=64 <= only the last threshold
        assert_eq!(votes[65], 0);
    }

    #[test]
    fn argmax_vote_prefers_lowest_on_tie() {
        assert_eq!(argmax_vote(&[3, 5, 5, 1]), 1);
        assert_eq!(argmax_vote(&[7, 7]), 0);
        assert_eq!(argmax_vote(&[0]), 0);
    }

    #[test]
    fn top_k_ordering() {
        assert_eq!(top_k(&[3, 9, 9, 4], 3), vec![1, 2, 3]);
        assert_eq!(top_k(&[1, 2, 3], 5), vec![2, 1, 0]);
    }

    #[test]
    fn forward_prediction_tracks_min_hd() {
        // with the full schedule, argmax votes == argmin hd (when hd <= 64)
        forall(30, 23, |g| {
            let seed = g.usize_in(0, 1 << 30) as u64;
            let m = tiny_model(60, 12, 5, seed);
            let mut rng = Rng::new(seed ^ 2, 6);
            let x = rand_act(60, &mut rng);
            let mut act = x.clone();
            act = digital_hidden(&m.layers[0], &act);
            let hd = digital_output_hd(&m.layers[1], &act);
            let (votes, pred) = digital_forward(&m, &x, &m.schedule);
            if hd.iter().any(|&h| h <= 64) {
                // the even-threshold sweep quantizes HD in steps of 2, so
                // the winner's HD can exceed the minimum by at most 1
                let min_hd = *hd.iter().min().unwrap();
                prop_assert(
                    hd[pred] <= min_hd + 1,
                    format!("pred {pred} hd {hd:?}"),
                )?;
                let max_votes = *votes.iter().max().unwrap();
                prop_assert(
                    votes[pred] == max_votes,
                    format!("votes {votes:?} pred {pred}"),
                )?;
            }
            Ok(())
        });
    }
}
