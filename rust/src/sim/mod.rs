//! Clocked-simulation core: cycle accounting and per-event energy hooks.
//!
//! The CAM device is synchronous (25 MHz): every search, write, or read is
//! one clock cycle; voltage retunes stall for their settle time.  `SimClock`
//! tracks cycles and stall time; `EventCounters` tallies the primitive
//! events the energy model (rust/src/energy) converts to joules.

use crate::analog::constants as k;

/// Primitive device events, counted per workload.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EventCounters {
    /// Search cycles issued (one per array-wide compare).
    pub searches: u64,
    /// Row-cells precharged across all searches (cells × searches).
    pub cells_precharged: u64,
    /// Searchline (column) toggles driven across all searches.
    pub sl_toggles: u64,
    /// MLSA evaluations (rows sensed × searches).
    pub mlsa_evals: u64,
    /// SRAM cells written (weight programming).
    pub cells_written: u64,
    /// Row-write cycles (weight programming; one device cycle per row) —
    /// the reload overhead the resident `MacroPool` eliminates.
    pub row_writes: u64,
    /// DAC retune events.
    pub retunes: u64,
    /// Read cycles (diagnostics; not on the inference path).
    pub reads: u64,
    /// Logical binary MACs performed (payload XNOR+accumulate pairs —
    /// excludes pad/spare cells; the BNN-accelerator "ops" convention
    /// counts 2 ops per MAC).
    pub useful_macs: u64,
}

impl EventCounters {
    pub fn add(&mut self, other: &EventCounters) {
        self.searches += other.searches;
        self.cells_precharged += other.cells_precharged;
        self.sl_toggles += other.sl_toggles;
        self.mlsa_evals += other.mlsa_evals;
        self.cells_written += other.cells_written;
        self.row_writes += other.row_writes;
        self.retunes += other.retunes;
        self.reads += other.reads;
        self.useful_macs += other.useful_macs;
    }
}

/// Cycle/time accounting at the device clock.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    /// Clock cycles consumed by array operations.
    pub cycles: u64,
    /// Stall time from DAC settling etc. [s].
    pub stall_s: f64,
}

impl SimClock {
    pub fn new() -> Self {
        SimClock::default()
    }

    /// Advance by n device cycles.
    pub fn tick(&mut self, n: u64) {
        self.cycles += n;
    }

    /// Stall for `t` seconds (retune settling).
    pub fn stall(&mut self, t: f64) {
        self.stall_s += t;
    }

    /// Total elapsed device time [s] at the nominal clock.
    pub fn elapsed_s(&self) -> f64 {
        self.cycles as f64 / k::F_CLK + self.stall_s
    }

    pub fn reset(&mut self) {
        self.cycles = 0;
        self.stall_s = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_accumulates() {
        let mut c = SimClock::new();
        c.tick(25_000_000);
        assert!((c.elapsed_s() - 1.0).abs() < 1e-12);
        c.stall(0.5);
        assert!((c.elapsed_s() - 1.5).abs() < 1e-12);
        c.reset();
        assert_eq!(c.cycles, 0);
    }

    #[test]
    fn counters_add() {
        let mut a = EventCounters {
            searches: 1,
            mlsa_evals: 10,
            ..Default::default()
        };
        let b = EventCounters {
            searches: 2,
            cells_written: 5,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.searches, 3);
        assert_eq!(a.mlsa_evals, 10);
        assert_eq!(a.cells_written, 5);
    }
}
